//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! Implements the subset this repository uses — `Result`, `Error`,
//! the `Context` extension trait for `Result`/`Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros — with matching semantics:
//!
//! * `Error` does NOT implement `std::error::Error` (exactly like real
//!   anyhow), so the blanket `From<E: std::error::Error>` conversion and
//!   the identity `From<Error>` never overlap and `?` works from both.
//! * `Display` shows the outermost message/context; `Debug` shows the
//!   full cause chain (what `fn main() -> Result<()>` prints on exit).

use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error {
            msg: m.to_string(),
            cause: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error {
            msg: c.to_string(),
            cause: Some(Box::new(self)),
        }
    }

    /// The `Display` strings of the chain, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = vec![self.msg.as_str()];
        let mut cur = &self.cause;
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = &e.cause;
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let chain = self.chain();
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        // Preserve the source chain as context layers.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err = Error::msg(msgs.pop().unwrap());
        while let Some(m) = msgs.pop() {
            err = err.context(m);
        }
        err
    }
}

pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "Condition failed: `{}`",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn io_fail() -> Result<String> {
        let r = std::fs::read_to_string("/definitely/not/here/x");
        r.with_context(|| format!("reading {}", "/definitely/not/here/x"))
    }

    #[test]
    fn context_is_outermost_display() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("reading /definitely/not/here/x"));
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");

        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x > 2, "x too small: {x}");
            if x > 100 {
                crate::bail!("x too big");
            }
            Ok(x)
        }
        assert!(f(1).unwrap_err().to_string().contains("too small: 1"));
        assert!(f(101).unwrap_err().to_string().contains("too big"));
        assert_eq!(f(7).unwrap(), 7);

        fn bare(x: u32) -> Result<u32> {
            crate::ensure!(x != 0);
            Ok(x)
        }
        assert!(bare(0).unwrap_err().to_string().contains("Condition failed"));
    }

    #[test]
    fn question_mark_from_std_error() {
        fn g() -> Result<i32> {
            let n: i32 = "not a number".parse()?;
            Ok(n)
        }
        assert!(g().is_err());
        // identity ? from Error works too
        fn h() -> Result<i32> {
            let v = g()?;
            Ok(v)
        }
        assert!(h().is_err());
        let _ = Error::msg("x");
    }
}
