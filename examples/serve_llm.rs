//! END-TO-END VALIDATION DRIVER: serve a real (tiny) LLaMa-style model
//! through the full three-layer stack on a Mooncake-like trace, with
//! batched continuous decoding, and report latency/throughput —
//! proving L1 (Pallas flash kernel) -> L2 (JAX model, AOT to HLO text)
//! -> L3 (rust coordinator + PJRT runtime) compose with Python never on
//! the request path.
//!
//!     cargo run --release --example serve_llm
//!
//! The run is recorded in EXPERIMENTS.md §E8.

use flashlight::serve::{run_trace, summarize, PjrtBackend, SchedulerConfig};
use flashlight::tracegen::{generate, TraceConfig};

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        println!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }
    let trace = generate(&TraceConfig {
        n_requests: 48,
        rate: 50.0,
        input_mu: 4.2,
        input_sigma: 0.7,
        mean_output: 12.0,
        max_input: 240,
        max_output: 24,
        ..Default::default()
    });
    let total_in: usize = trace.iter().map(|r| r.input_tokens).sum();
    let total_out: usize = trace.iter().map(|r| r.output_tokens).sum();
    println!(
        "trace: {} requests, {} prompt tokens, {} tokens to generate",
        trace.len(),
        total_in,
        total_out
    );

    let mut rows = vec![];
    for (label, variant, fused) in [
        ("flashlight/causal", "causal", true),
        ("naive/causal", "causal", false),
        ("flashlight/softcap", "softcap", true),
        ("naive/softcap", "softcap", false),
    ] {
        let mut backend = PjrtBackend::new("artifacts", variant, fused)?;
        let vocab = backend.vocab();
        let t0 = std::time::Instant::now();
        let done = run_trace(&mut backend, &trace, SchedulerConfig::default(), vocab)?;
        let wall = t0.elapsed().as_secs_f64();
        let s = summarize(&done);
        anyhow::ensure!(s.n_requests == trace.len(), "requests lost");
        println!(
            "{label:<20} wall {wall:6.2}s | TTFT mean {:7.1} ms p99 {:7.1} ms | \
             ITL mean {:6.2} ms | throughput {:6.1} tok/s",
            s.ttft_mean_s * 1e3,
            s.ttft_p99_s * 1e3,
            s.itl_mean_s * 1e3,
            s.tokens_per_s
        );
        rows.push((label, s.tokens_per_s));
    }

    // Fused vs naive on this substrate: at the tiny model's S <= 256
    // prefill, interpret-mode Pallas (which serializes its grid on CPU)
    // runs close to — typically slightly behind — the naive XLA path;
    // the GPU-scale advantage is carried by the traffic counters and
    // cost model (EXPERIMENTS.md E1-E5). What this driver *proves* is
    // composition: both artifact families serve the full trace through
    // the rust coordinator with Python never on the request path.
    let tput = |l: &str| rows.iter().find(|(n, _)| *n == l).unwrap().1;
    let causal_ratio = tput("flashlight/causal") / tput("naive/causal");
    let softcap_ratio = tput("flashlight/softcap") / tput("naive/softcap");
    println!(
        "fused/naive throughput ratio on CPU substrate: causal {causal_ratio:.2}x, \
         softcap {softcap_ratio:.2}x (see EXPERIMENTS.md E8 for why CPU \
         inverts the GPU result at this scale)"
    );
    anyhow::ensure!(causal_ratio > 0.5 && softcap_ratio > 0.5);
    println!("serve_llm OK — three layers compose end-to-end");
    Ok(())
}
