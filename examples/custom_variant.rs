//! Author a *new* attention variant that exists in no template — the
//! paper's core promise: "developers rapidly explore new attention
//! models without sacrificing performance".
//!
//! The variant below combines a sliding window, tanh soft-capping AND an
//! ALiBi-style distance penalty with a learned per-head gate — nothing
//! FlexAttention's `score_mod`/`mask_mod` split can express as-is. The
//! Flashlight planner still discovers one fused FlashAttention-style
//! kernel for it.
//!
//!     cargo run --release --example custom_variant

use std::collections::HashMap;

use flashlight::exec::{eval, execute_plan, Tensor};
use flashlight::fusion::{plan, FusionMode, Rule, TileConfig};
use flashlight::ir::{CmpOp, GraphBuilder};

fn main() -> anyhow::Result<()> {
    let (b, h, s, d) = (1usize, 4usize, 128usize, 32usize);
    let window = 48f32;
    let cap = 10f32;

    let mut gb = GraphBuilder::new("windowed_softcap_alibi_gated");
    let q = gb.input("q", &[b, h, s, d]);
    let k = gb.input("k", &[b, h, s, d]);
    let v = gb.input("v", &[b, h, s, d]);
    let gate = gb.input("gate", &[b, h, s, d]); // learned output gate

    let scores = gb.matmul_nt(q, k);
    let mut x = gb.mul_scalar(scores, 1.0 / (d as f32).sqrt());

    // tanh soft-capping (Gemma-2 style)
    let inner = gb.mul_scalar(x, 1.0 / cap);
    let t = gb.tanh(inner);
    x = gb.mul_scalar(t, cap);

    // ALiBi-style distance penalty with per-head slope
    let qi = gb.iota(&[b, h, s, s], 2);
    let ki = gb.iota(&[b, h, s, s], 3);
    let hi = gb.iota(&[b, h, s, s], 1);
    let h1 = gb.add_scalar(hi, 1.0);
    let e = gb.mul_scalar(h1, -8.0 * std::f32::consts::LN_2 / h as f32);
    let slope = gb.exp(e);
    let dist = gb.sub(qi, ki);
    let pen = gb.mul(slope, dist);
    x = gb.sub(x, pen);

    // causal sliding window
    let causal = gb.cmp(CmpOp::Le, ki, qi);
    let win = gb.constant(window, &[b, h, s, s]);
    let near = gb.cmp(CmpOp::Le, dist, win);
    let keep = gb.cmp(CmpOp::And, causal, near);
    x = gb.masked_fill_neg(x, keep);

    // softmax + PV + sigmoid gate epilogue
    let w = gb.softmax(x, 3);
    let o = gb.matmul(w, v);
    let gs = gb.sigmoid(gate);
    let out = gb.mul(gs, o);
    let g = gb.finish(&[out]);

    let fused = plan(&g, FusionMode::Flashlight);
    println!("{}", fused.describe(&g));
    assert_eq!(
        fused.num_pipelines(),
        1,
        "the custom variant must fuse into one flash pipeline"
    );
    let rules: Vec<Rule> = fused.log.iter().map(|e| e.rule).collect();
    assert!(rules.contains(&Rule::AlgebraicOnline), "online softmax rewrite");
    assert!(rules.contains(&Rule::EpilogueFusion), "gate epilogue fused");

    let inductor = plan(&g, FusionMode::TorchCompile);
    println!(
        "kernel count: flashlight {} vs torch.compile {}",
        fused.groups.len(),
        inductor.groups.len()
    );

    // Numerics: the fused online execution must match the eager oracle.
    let mut inputs = HashMap::new();
    for (name, seed) in [("q", 1u64), ("k", 2), ("v", 3), ("gate", 4)] {
        inputs.insert(name.to_string(), Tensor::synthetic(&[b, h, s, d], seed));
    }
    let (want, ce) = eval(&g, &inputs);
    let tile = TileConfig {
        block_q: 32,
        block_k: 32,
        ..Default::default()
    };
    let (got, cf) = execute_plan(&g, &fused, &inputs, tile);
    let err = got[0].max_abs_diff(&want[0]);
    println!("max |fused - eager| = {err:.2e}");
    assert!(err < 1e-5);
    println!(
        "traffic: eager {} KiB -> fused {} KiB ({:.1}x), launches {} -> {}",
        ce.total_traffic() >> 10,
        cf.total_traffic() >> 10,
        ce.total_traffic() as f64 / cf.total_traffic() as f64,
        ce.launches,
        cf.launches
    );
    println!("custom variant OK");
    Ok(())
}
