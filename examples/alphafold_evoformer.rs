//! AlphaFold Evoformer experiment (paper §4.4): run a stack of Evoformer
//! blocks — row-wise gated self-attention + transition — through the
//! real AOT artifacts (fused Pallas kernel vs materializing jnp
//! reference) on PJRT, and reproduce the end-to-end dilution arithmetic.
//!
//!     cargo run --release --example alphafold_evoformer

use std::time::Instant;

use flashlight::runtime::Engine;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        println!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }
    let mut engine = Engine::new("artifacts")?;
    let weights = engine.load_weights("evoformer")?.literals();
    let meta = engine.artifact("evoformer_block_fused")?.clone();
    let x0 = Engine::synthetic_input(&meta.inputs[weights.len()], 100);
    let bias = Engine::synthetic_input(&meta.inputs[weights.len() + 1], 101);

    const LAYERS: usize = 8; // scaled-down stack (paper: 48)
    let mut results = vec![];
    for (label, artifact) in [
        ("fused (flashlight)", "evoformer_block_fused"),
        ("naive (torch.compile)", "evoformer_block_naive"),
    ] {
        engine.compile(artifact)?; // exclude compilation from timing
        // warmup
        let mut inputs: Vec<xla::Literal> = weights.clone();
        inputs.push(x0.clone());
        inputs.push(bias.clone());
        let _ = engine.run(artifact, &inputs)?;

        let t0 = Instant::now();
        let mut x = x0.clone();
        for _ in 0..LAYERS {
            let mut inputs: Vec<xla::Literal> = weights.clone();
            inputs.push(x);
            inputs.push(bias.clone());
            let mut outs = engine.run(artifact, &inputs)?;
            x = outs.remove(0);
        }
        let dt = t0.elapsed().as_secs_f64();
        let out: Vec<f32> = x.to_vec()?;
        println!(
            "{label:<22}: {LAYERS}-layer stack in {:7.1} ms  (out[0..3] = {:?})",
            dt * 1e3,
            &out[..3]
        );
        results.push((label, dt, out));
    }

    // Fused and naive stacks must compute the same function.
    let err = results[0]
        .2
        .iter()
        .zip(&results[1].2)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max |fused - naive| after {LAYERS} layers = {err:.2e}");
    anyhow::ensure!(err < 1e-2, "stacks diverged");

    let cpu_speedup = results[1].1 / results[0].1;
    println!(
        "measured CPU-PJRT block ratio naive/fused: {cpu_speedup:.2}x \
         (interpret-mode Pallas serializes the grid on CPU — a substitution \
         artifact, see DESIGN.md §3; the GPU story comes from the traffic model)"
    );

    // The H100 projection from the compiler's own traffic counters —
    // this is the number that reproduces the paper's §4.4 claim.
    use flashlight::baselines::{estimate_attention, System};
    use flashlight::cost::h100;
    use flashlight::fusion::TileConfig;
    use flashlight::variants::{AttnShape, Variant};
    let shape = AttnShape::evoformer(1, 128, 256, 32);
    let tile = TileConfig::default();
    let fl = estimate_attention(System::Flashlight, Variant::Evoformer, &shape, &h100(), tile)
        .unwrap();
    let tc = estimate_attention(System::TorchCompile, Variant::Evoformer, &shape, &h100(), tile)
        .unwrap();
    let speedup = tc.total() / fl.total();
    println!("modeled H100 gated-attention speedup: {speedup:.1}x (paper: >= 5x)");

    // End-to-end dilution (paper: 48 layers, attention ~8% of layer
    // time, 6-9% E2E gain): t_layer = t_attn + t_other.
    let attn_share = 0.08;
    let e2e_gain = attn_share * (1.0 - 1.0 / speedup);
    println!(
        "projected AlphaFold E2E improvement at {:.0}% attention share: {:.1}% \
         (paper: 6-9%)",
        attn_share * 100.0,
        e2e_gain * 100.0
    );
    Ok(())
}
