//! Quickstart: write attention as idiomatic tensor code, let the
//! Flashlight compiler fuse it, and execute it — pure rust first, then
//! (if `make artifacts` has been run) the real AOT JAX/Pallas path
//! through PJRT.
//!
//!     cargo run --release --example quickstart

use std::collections::HashMap;

use flashlight::exec::{eval, execute_plan, Tensor};
use flashlight::fusion::{plan, FusionMode, TileConfig};
use flashlight::ir::GraphBuilder;

fn main() -> anyhow::Result<()> {
    // 1. Write attention the way the paper's Listing 1 writes it in
    //    PyTorch: matmul, masked softmax, matmul. No templates.
    let (b, h, s, d) = (2usize, 4usize, 128usize, 32usize);
    let mut gb = GraphBuilder::new("quickstart_attention");
    let q = gb.input("q", &[b, h, s, d]);
    let k = gb.input("k", &[b, h, s, d]);
    let v = gb.input("v", &[b, h, s, d]);
    let scores = gb.matmul_nt(q, k);
    let scaled = gb.mul_scalar(scores, 1.0 / (d as f32).sqrt());
    // causal mask built from materialized index tensors (Listing 3 style)
    let qi = gb.iota(&[b, h, s, s], 2);
    let ki = gb.iota(&[b, h, s, s], 3);
    let keep = gb.cmp(flashlight::ir::CmpOp::Le, ki, qi);
    let masked = gb.masked_fill_neg(scaled, keep);
    let weights = gb.softmax(masked, 3);
    let out = gb.matmul(weights, v);
    let g = gb.finish(&[out]);

    // 2. Compile: the planner discovers the FlashAttention structure.
    let fused = plan(&g, FusionMode::Flashlight);
    println!("{}", fused.describe(&g));
    let inductor = plan(&g, FusionMode::TorchCompile);
    println!(
        "flashlight: {} kernel(s) | torch.compile: {} kernels | eager: {} kernels",
        fused.groups.len(),
        inductor.groups.len(),
        plan(&g, FusionMode::Eager).groups.len()
    );

    // 3. Execute fused vs eager reference and compare.
    let mut inputs = HashMap::new();
    inputs.insert("q".into(), Tensor::synthetic(&[b, h, s, d], 1));
    inputs.insert("k".into(), Tensor::synthetic(&[b, h, s, d], 2));
    inputs.insert("v".into(), Tensor::synthetic(&[b, h, s, d], 3));
    let (want, c_eager) = eval(&g, &inputs);
    let tile = TileConfig {
        block_q: 32,
        block_k: 32,
        ..Default::default()
    };
    let (got, c_fused) = execute_plan(&g, &fused, &inputs, tile);
    println!(
        "max |fused - eager| = {:.2e} (online softmax is exact in reals)",
        got[0].max_abs_diff(&want[0])
    );
    println!(
        "HBM traffic: eager {} KiB -> fused {} KiB ({:.1}x less); launches {} -> {}",
        c_eager.total_traffic() >> 10,
        c_fused.total_traffic() >> 10,
        c_eager.total_traffic() as f64 / c_fused.total_traffic() as f64,
        c_eager.launches,
        c_fused.launches
    );

    // 4. Estimated time on the paper's testbeds.
    for spec in [flashlight::cost::h100(), flashlight::cost::a100()] {
        let t_f = flashlight::cost::kernel_time(
            &spec,
            &c_fused,
            flashlight::baselines::EFF_FLASHLIGHT,
        );
        let t_e = flashlight::cost::kernel_time(
            &spec,
            &c_eager,
            flashlight::baselines::EFF_INDUCTOR,
        );
        println!(
            "{}: fused {:.1} us vs eager {:.1} us (modeled)",
            spec.name,
            t_f * 1e6,
            t_e * 1e6
        );
    }

    // 5. The parallel engine: the same fused plan over its launch grid
    //    on all cores — bit-identical outputs and traffic counters.
    let par = flashlight::exec::Parallelism::available();
    let (got_par, c_par) =
        flashlight::exec::execute_plan_par(&g, &fused, &inputs, tile, &par);
    println!(
        "parallel engine ({} threads): bit-identical to sequential: {}",
        par.num_threads,
        got_par == got && c_par == c_fused
    );

    // 6. The same computation through the real three-layer stack:
    //    Pallas flash kernel (L1) inside a JAX module (L2), AOT-lowered
    //    to HLO text and executed from rust via PJRT (L3).
    pjrt_demo()?;
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_demo() -> anyhow::Result<()> {
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        let mut engine = flashlight::runtime::Engine::new("artifacts")?;
        let meta = engine.artifact("attn_causal_fused")?.clone();
        let inputs: Vec<xla::Literal> = meta
            .inputs
            .iter()
            .enumerate()
            .map(|(i, m)| flashlight::runtime::Engine::synthetic_input(m, 42 + i as u64))
            .collect();
        let fused_out: Vec<f32> = engine.run("attn_causal_fused", &inputs)?[0].to_vec()?;
        let naive_out: Vec<f32> = engine.run("attn_causal_naive", &inputs)?[0].to_vec()?;
        let err = fused_out
            .iter()
            .zip(&naive_out)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!(
            "PJRT: fused Pallas kernel vs naive jnp reference agree to {err:.2e} \
             ({} elements)",
            fused_out.len()
        );
    } else {
        println!("(run `make artifacts` to also exercise the PJRT path)");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_demo() -> anyhow::Result<()> {
    println!("(build with --features pjrt and run `make artifacts` to also exercise the PJRT path)");
    Ok(())
}
