//! Property tests for the persistent topology-aware worker runtime.
//!
//! The contract (see `rust/src/exec/runtime.rs`): chunked claims, the
//! per-shard single-block tail, and hierarchical (within-domain, then
//! cross-domain) stealing together claim **every index exactly once**,
//! and the index-ordered merge makes outputs — and, at the engine
//! level, `Counters` — `to_bits`-identical to sequential at any thread
//! count under any topology, including adversarial ones (domains with
//! no workers, wildly skewed weights, more domains than items) and
//! forced-steal schedules.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use flashlight::exec::runtime::{self, map_with_topology};
use flashlight::exec::topology::Topology;
use flashlight::exec::{execute_plan, execute_plan_par, Parallelism, Tensor};
use flashlight::fusion::{plan, FusionMode, TileConfig};
use flashlight::ir::Op;
use flashlight::variants::{build, AttnShape, Variant};

fn adversarial_topologies() -> Vec<Topology> {
    vec![
        Topology::flat(1),
        Topology::flat(64),
        Topology::from_domains(vec![1, 1], "env"),
        Topology::from_domains(vec![1, 63], "env"),
        Topology::from_domains(vec![1; 8], "env"),
        Topology::from_domains(vec![3, 1, 5, 1], "env"),
        // more domains than any test below has items or workers
        Topology::from_domains(vec![1; 32], "env"),
    ]
}

/// A float-valued work item whose result depends on accumulation order
/// within the item (but not across items): any scheduling bug that
/// reran or reordered an item would flip bits.
fn work(i: usize) -> f32 {
    let mut acc = 0.0f32;
    for k in 0..(i % 7) + 3 {
        acc = (i as f32 * 0.37 + k as f32).sin().mul_add(0.25, acc);
    }
    acc
}

/// Every index claimed exactly once + output bits identical to
/// sequential, across 1/2/4/available threads, sizes that land chunked
/// claims, mid-chunk clamps, and the single-block tail, and every
/// adversarial topology.
#[test]
fn exactly_once_and_bit_identical_across_topologies() {
    let avail = Parallelism::available().num_threads;
    let mut threads = vec![1usize, 2, 4, avail];
    threads.dedup();
    // n around chunk/tail boundaries: workers*CLAIM_CHUNK = 16 at 4
    // threads; cover below, at, straddling, and far above it.
    for n in [1usize, 2, 7, 15, 16, 17, 31, 97, 256] {
        let seq: Vec<f32> = (0..n).map(work).collect();
        for topo in adversarial_topologies() {
            for &t in &threads {
                let claims: Arc<Vec<AtomicUsize>> =
                    Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
                let c2 = claims.clone();
                let got = map_with_topology(
                    &topo,
                    &Parallelism::with_threads(t),
                    n,
                    || (),
                    move |_, i| {
                        c2[i].fetch_add(1, Ordering::Relaxed);
                        work(i)
                    },
                );
                for (i, c) in claims.iter().enumerate() {
                    assert_eq!(
                        c.load(Ordering::Relaxed),
                        1,
                        "item {i} claimed != once (n={n} t={t} topo={topo:?})"
                    );
                }
                assert_eq!(got.len(), n);
                for (i, (a, b)) in seq.iter().zip(&got).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "item {i} bits differ (n={n} t={t} topo={topo:?})"
                    );
                }
            }
        }
    }
}

/// Forced-steal schedule: whichever worker claims item 0 (shard 0's
/// first chunk) blocks inside it until every item *outside that chunk*
/// has run. The chunk holds at most `CLAIM_CHUNK = 4` items, so the
/// other `n - 4` items must all be executed by the *other* worker —
/// and 8 of them live in shard 0, reachable by the domain-1 worker
/// only via the cross-domain steal leg. Without stealing, progress
/// stalls at shard 1's 12 items and the bounded wait fails loudly.
#[test]
fn cross_domain_steal_drains_a_blocked_domains_shard() {
    let n = 24usize;
    let done = Arc::new(AtomicUsize::new(0));
    let topo = Topology::from_domains(vec![1, 1], "env");
    let d2 = done.clone();
    let out = map_with_topology(
        &topo,
        &Parallelism::with_threads(2),
        n,
        || (),
        move |_, i| {
            if i == 0 {
                // Items 1..4 may sit behind us in our own claimed
                // chunk; everything else must flow through the other
                // worker — which requires stealing across domains.
                let mut spins = 0u64;
                while d2.load(Ordering::Acquire) < (n - 4) {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    spins += 1;
                    assert!(
                        spins < 20_000,
                        "hierarchical steal never drained the sibling shard"
                    );
                }
            } else {
                d2.fetch_add(1, Ordering::Release);
            }
            i as u64
        },
    );
    assert_eq!(out, (0..n as u64).collect::<Vec<_>>());
    assert_eq!(done.load(Ordering::Relaxed), n - 1);
}

fn synthetic_inputs(
    g: &flashlight::ir::Graph,
    seed: u64,
) -> std::collections::HashMap<String, Tensor> {
    let mut m = std::collections::HashMap::new();
    for (i, &id) in g.inputs.iter().enumerate() {
        let node = g.node(id);
        let Op::Input { name } = &node.op else { unreachable!() };
        let t = if name.starts_with("doc") {
            let n: usize = node.shape.iter().product();
            Tensor::from_vec(&node.shape, (0..n).map(|j| (j * 3 / n) as f32).collect())
        } else {
            Tensor::synthetic(&node.shape, seed + i as u64)
        };
        m.insert(name.clone(), t);
    }
    m
}

/// The engine-level gate: under every adversarial *process* topology,
/// parallel execution stays bit-identical to sequential — outputs AND
/// Counters (HBM/L2 attribution). Topology swaps are safe to run
/// concurrently with other tests because topology only moves shard
/// boundaries, never results.
#[test]
fn engine_parity_holds_under_adversarial_topologies() {
    let shape = AttnShape {
        batch: 2,
        rows: 1,
        heads_q: 4,
        heads_kv: 2,
        seq: 48, // not a block multiple: tail tiles everywhere
        head_dim: 8,
    };
    let tile = TileConfig {
        block_q: 8,
        block_k: 16,
        l2_capacity: 40 << 20,
    };
    for v in [Variant::Causal, Variant::Alibi, Variant::DiffAttn { lambda: 0.5 }] {
        let g = build(v, &shape);
        let inputs = synthetic_inputs(&g, 31);
        let p = plan(&g, FusionMode::Flashlight);
        let (seq_out, seq_c) = execute_plan(&g, &p, &inputs, tile);
        for topo in adversarial_topologies() {
            runtime::set_topology(topo.clone());
            for threads in [2usize, 4, 7] {
                let (par_out, par_c) = execute_plan_par(
                    &g,
                    &p,
                    &inputs,
                    tile,
                    &Parallelism::with_threads(threads),
                );
                assert_eq!(
                    seq_out, par_out,
                    "{} outputs diverge (threads={threads} topo={topo:?})",
                    v.name()
                );
                assert_eq!(
                    seq_c, par_c,
                    "{} counters diverge (threads={threads} topo={topo:?})",
                    v.name()
                );
            }
        }
    }
    // Leave the process on its real detected topology.
    runtime::set_topology(Topology::detect());
}

/// Per-worker scratch persists across launches (the serving engine's
/// warm-pool contract) — verified on the deterministic sequential path.
#[test]
fn caller_scratch_survives_launches() {
    struct Warmth(Vec<f32>);
    let a = runtime::map_with(
        &Parallelism::sequential(),
        3,
        || Warmth(Vec::new()),
        |s, i| {
            s.0.push(i as f32);
            s.0.len()
        },
    );
    assert_eq!(a, vec![1, 2, 3]);
    let b = runtime::map_with(
        &Parallelism::sequential(),
        1,
        || Warmth(Vec::new()),
        |s, _| s.0.len(),
    );
    assert_eq!(b, vec![3], "scratch must survive between launches");
}

/// Thread spawns are monotonic and warm() makes later same-width
/// launches spawn-free (attributed per calling thread, so this is
/// exact even when the harness runs tests concurrently).
#[test]
fn warmed_launches_never_spawn() {
    runtime::warm(&Parallelism::with_threads(4));
    let s0 = runtime::spawns_on_this_thread();
    for round in 0..5 {
        let out = runtime::map_with(
            &Parallelism::with_threads(4),
            64,
            || (),
            move |_, i| i + round,
        );
        assert_eq!(out[3], 3 + round);
    }
    assert_eq!(runtime::spawns_on_this_thread(), s0);
    assert!(runtime::thread_spawns() >= 3);
}
