//! Parallel/sequential parity for the tiled execution engine.
//!
//! The contract (see `rust/src/exec/README.md`): at ANY thread count,
//! `execute_plan_par` produces bit-identical outputs AND bit-identical
//! [`Counters`] — including the HBM-vs-L2 split, which depends on the
//! order regions are first touched — to the sequential path. These are
//! property-style tests over every built-in variant, several tile
//! configs, and randomized shapes.

use std::collections::HashMap;

use flashlight::exec::{execute_plan, execute_plan_par, Parallelism, Tensor};
use flashlight::fusion::{plan, FusionMode, TileConfig};
use flashlight::ir::{Graph, Op};
use flashlight::tracegen::Rng;
use flashlight::variants::{build, paper_variants, AttnShape, Variant};

fn inputs_for(g: &Graph, seed: u64) -> HashMap<String, Tensor> {
    let mut m = HashMap::new();
    for (i, &id) in g.inputs.iter().enumerate() {
        let node = g.node(id);
        let Op::Input { name } = &node.op else { unreachable!() };
        let t = if name.starts_with("doc") {
            let n: usize = node.shape.iter().product();
            Tensor::from_vec(&node.shape, (0..n).map(|j| (j * 3 / n) as f32).collect())
        } else {
            Tensor::synthetic(&node.shape, seed + i as u64)
        };
        m.insert(name.clone(), t);
    }
    m
}

fn all_variants(s: usize) -> Vec<Variant> {
    let mut v: Vec<Variant> = paper_variants()
        .into_iter()
        .map(|v| match v {
            Variant::SlidingWindow { .. } => Variant::SlidingWindow { window: s / 3 },
            Variant::PrefixLm { .. } => Variant::PrefixLm { prefix: s / 2 },
            other => other,
        })
        .collect();
    v.push(Variant::DiffAttn { lambda: 0.3 });
    v.push(Variant::Evoformer);
    v.push(Variant::Rectified { tau: 0.05 });
    v
}

fn assert_parity(g: &Graph, inputs: &HashMap<String, Tensor>, tile: TileConfig, label: &str) {
    let p = plan(g, FusionMode::Flashlight);
    let (seq_out, seq_c) = execute_plan(g, &p, inputs, tile);
    for threads in [2, 3, 7] {
        let par = Parallelism::with_threads(threads);
        let (par_out, par_c) = execute_plan_par(g, &p, inputs, tile, &par);
        assert_eq!(seq_out.len(), par_out.len(), "{label} threads={threads}");
        for (i, (a, b)) in seq_out.iter().zip(&par_out).enumerate() {
            assert_eq!(a.shape, b.shape, "{label} out[{i}] shape, threads={threads}");
            assert!(
                a.data == b.data,
                "{label} out[{i}] data not bit-identical at threads={threads}"
            );
        }
        assert_eq!(
            seq_c, par_c,
            "{label}: counters diverge at threads={threads}"
        );
    }
}

/// Every built-in variant, across several tile configs, at several
/// thread counts: outputs and counters bit-identical to sequential.
#[test]
fn parity_all_variants_multiple_tile_configs() {
    let shape = AttnShape {
        batch: 2,
        rows: 1,
        heads_q: 4,
        heads_kv: 2,
        seq: 48,
        head_dim: 8,
    };
    let tiles = [
        TileConfig {
            block_q: 8,
            block_k: 8,
            l2_capacity: 40 << 20,
        },
        TileConfig {
            block_q: 16,
            block_k: 32,
            l2_capacity: 40 << 20,
        },
        // block_q > seq: the whole q range is one grid block
        TileConfig {
            block_q: 64,
            block_k: 16,
            l2_capacity: 40 << 20,
        },
    ];
    for v in all_variants(shape.seq) {
        let shape = if matches!(v, Variant::Evoformer) {
            AttnShape { rows: 2, ..shape }
        } else {
            shape
        };
        let g = build(v, &shape);
        let inputs = inputs_for(&g, 23);
        for (ti, tile) in tiles.iter().enumerate() {
            assert_parity(&g, &inputs, *tile, &format!("{} tile#{ti}", v.name()));
        }
    }
}

/// Randomized shapes/tiles (deterministic RNG): parity must hold for
/// uneven tails, GQA group broadcasts, and multi-pipeline graphs alike.
#[test]
fn parity_random_shapes_property() {
    let mut rng = Rng::new(4242);
    for case in 0..12 {
        let variants = all_variants(32);
        let variant = variants[rng.range(0, variants.len())];
        let block = [8usize, 16, 24][rng.range(0, 3)];
        let s = 8 * rng.range(2, 7); // 16..48, often not divisible by block
        let hkv = [1usize, 2][rng.range(0, 2)];
        let group = [1usize, 2][rng.range(0, 2)];
        let shape = AttnShape {
            batch: rng.range(1, 3),
            rows: if matches!(variant, Variant::Evoformer) {
                rng.range(1, 3)
            } else {
                1
            },
            heads_q: hkv * group,
            heads_kv: hkv,
            seq: s,
            head_dim: [8usize, 16][rng.range(0, 2)],
        };
        let variant = match variant {
            Variant::SlidingWindow { .. } => Variant::SlidingWindow {
                window: rng.range(1, s),
            },
            Variant::PrefixLm { .. } => Variant::PrefixLm {
                prefix: rng.range(1, s),
            },
            other => other,
        };
        let g = build(variant, &shape);
        let inputs = inputs_for(&g, case as u64 * 13 + 1);
        let tile = TileConfig {
            block_q: block,
            block_k: [8usize, 16, 32][rng.range(0, 3)],
            ..Default::default()
        };
        assert_parity(
            &g,
            &inputs,
            tile,
            &format!("case {case} {} {shape:?}", variant.name()),
        );
    }
}

/// The `Plan::execute` convenience API routes through the same engine.
#[test]
fn plan_execute_is_bit_identical_too() {
    let shape = AttnShape {
        batch: 1,
        rows: 1,
        heads_q: 4,
        heads_kv: 4,
        seq: 32,
        head_dim: 8,
    };
    let g = build(Variant::Causal, &shape);
    let inputs = inputs_for(&g, 3);
    let p = plan(&g, FusionMode::Flashlight);
    let tile = TileConfig {
        block_q: 8,
        block_k: 16,
        ..Default::default()
    };
    let (a, ca) = p.execute(&g, &inputs, tile, Parallelism::sequential());
    let (b, cb) = p.execute(&g, &inputs, tile, Parallelism::with_threads(4));
    assert_eq!(a, b);
    assert_eq!(ca, cb);
}

/// Oversubscription: far more threads than grid blocks must still be
/// correct (workers that never claim a block are fine).
#[test]
fn parity_with_more_threads_than_blocks() {
    let shape = AttnShape {
        batch: 1,
        rows: 1,
        heads_q: 2,
        heads_kv: 2,
        seq: 16,
        head_dim: 8,
    };
    let g = build(Variant::Vanilla, &shape);
    let inputs = inputs_for(&g, 9);
    let p = plan(&g, FusionMode::Flashlight);
    let tile = TileConfig {
        block_q: 16,
        block_k: 8,
        ..Default::default()
    };
    let (seq_out, seq_c) = execute_plan(&g, &p, &inputs, tile);
    let (par_out, par_c) =
        execute_plan_par(&g, &p, &inputs, tile, &Parallelism::with_threads(64));
    assert_eq!(seq_out, par_out);
    assert_eq!(seq_c, par_c);
}
