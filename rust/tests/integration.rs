//! Cross-module integration tests: variants -> planner -> executors ->
//! cost model, plus randomized property tests (proptest is unavailable
//! offline; properties are driven by the crate's deterministic RNG over
//! many sampled cases).

use std::collections::HashMap;

use flashlight::exec::{eager_counters, eval, execute_plan, Tensor};
use flashlight::fusion::{plan, FusionMode, GroupKind, TileConfig};
use flashlight::ir::{CmpOp, Graph, GraphBuilder, Op};
use flashlight::sketch::analyze;
use flashlight::tracegen::Rng;
use flashlight::variants::{build, paper_variants, AttnShape, Variant};

fn inputs_for(g: &Graph, seed: u64) -> HashMap<String, Tensor> {
    let mut m = HashMap::new();
    for (i, &id) in g.inputs.iter().enumerate() {
        let node = g.node(id);
        let Op::Input { name } = &node.op else { unreachable!() };
        let t = if name.starts_with("doc") {
            let n: usize = node.shape.iter().product();
            Tensor::from_vec(&node.shape, (0..n).map(|j| (j * 3 / n) as f32).collect())
        } else {
            Tensor::synthetic(&node.shape, seed + i as u64)
        };
        m.insert(name.clone(), t);
    }
    m
}

fn all_variants() -> Vec<Variant> {
    let mut v = paper_variants();
    v.push(Variant::DiffAttn { lambda: 0.3 });
    v.push(Variant::Evoformer);
    v.push(Variant::Rectified { tau: 0.05 });
    v
}

/// Property: for random shapes and tile configs, the fused plan executes
/// to the same values as the eager reference, for every variant.
#[test]
fn property_fused_equals_eager_over_random_shapes() {
    let mut rng = Rng::new(2024);
    for case in 0..24 {
        let variant = all_variants()[rng.range(0, 10)];
        let block = [8usize, 16, 32][rng.range(0, 3)];
        let s = block * rng.range(1, 4);
        let hkv = [1usize, 2][rng.range(0, 2)];
        let group = [1usize, 2][rng.range(0, 2)];
        let shape = AttnShape {
            batch: rng.range(1, 3),
            rows: if matches!(variant, Variant::Evoformer) { rng.range(1, 4) } else { 1 },
            heads_q: hkv * group,
            heads_kv: hkv,
            seq: s,
            head_dim: [8usize, 16][rng.range(0, 2)],
        };
        let variant = match variant {
            Variant::SlidingWindow { .. } => Variant::SlidingWindow {
                window: rng.range(1, s),
            },
            Variant::PrefixLm { .. } => Variant::PrefixLm {
                prefix: rng.range(1, s),
            },
            other => other,
        };
        let g = build(variant, &shape);
        let inputs = inputs_for(&g, case as u64 * 31 + 7);
        let (want, _) = eval(&g, &inputs);
        let p = plan(&g, FusionMode::Flashlight);
        assert!(
            p.num_pipelines() >= 1,
            "case {case} {}: no pipeline found",
            variant.name()
        );
        let tile = TileConfig {
            block_q: block,
            block_k: [8usize, 16, 32][rng.range(0, 3)],
            ..Default::default()
        };
        let (got, _) = execute_plan(&g, &p, &inputs, tile);
        let err = got[0].max_abs_diff(&want[0]);
        assert!(
            err < 1e-4,
            "case {case} {} shape {shape:?}: err {err}",
            variant.name()
        );
    }
}

/// Property: every plan is a partition — each non-input node belongs to
/// exactly one group, and group node lists are disjoint and complete.
#[test]
fn property_plans_partition_the_graph() {
    for variant in all_variants() {
        let shape = AttnShape {
            batch: 1,
            rows: 2,
            heads_q: 4,
            heads_kv: 2,
            seq: 32,
            head_dim: 8,
        };
        let g = build(variant, &shape);
        for mode in [
            FusionMode::Eager,
            FusionMode::TorchCompile,
            FusionMode::Flashlight,
        ] {
            let p = plan(&g, mode);
            let mut seen = std::collections::HashSet::new();
            for grp in &p.groups {
                for &n in &grp.nodes {
                    assert!(
                        seen.insert(n),
                        "{} {:?}: node {n:?} in two groups",
                        variant.name(),
                        mode
                    );
                }
            }
            for id in g.ids() {
                let is_input = matches!(g.node(id).op, Op::Input { .. });
                assert_eq!(
                    !is_input,
                    seen.contains(&id),
                    "{} {:?}: node {id:?} coverage",
                    variant.name(),
                    mode
                );
            }
        }
    }
}

/// Property: the fusion-mode ordering of traffic and launches holds for
/// every variant at paper-like (scaled) shapes.
#[test]
fn property_traffic_ordering_all_variants() {
    for variant in all_variants() {
        let shape = AttnShape {
            batch: 1,
            rows: 4,
            heads_q: 4,
            heads_kv: 2,
            seq: 256,
            head_dim: 32,
        };
        let g = build(variant, &shape);
        let tc = TileConfig::default();
        let fl = plan(&g, FusionMode::Flashlight).counters(&g, tc);
        let ind = plan(&g, FusionMode::TorchCompile).counters(&g, tc);
        let eag = plan(&g, FusionMode::Eager).counters(&g, tc);
        assert!(
            fl.total_traffic() < ind.total_traffic(),
            "{}: {} !< {}",
            variant.name(),
            fl.total_traffic(),
            ind.total_traffic()
        );
        assert!(ind.total_traffic() <= eag.total_traffic(), "{}", variant.name());
        assert!(fl.launches < ind.launches, "{}", variant.name());
        assert!(ind.launches < eag.launches, "{}", variant.name());
    }
}

/// Property: counters scale quadratically in S for eager (materialized
/// S^2) but the fused pipeline's *workspace* does not.
#[test]
fn property_fused_workspace_is_subquadratic() {
    let mk = |s: usize| AttnShape {
        batch: 1,
        rows: 1,
        heads_q: 2,
        heads_kv: 2,
        seq: s,
        head_dim: 16,
    };
    let tc = TileConfig::default();
    let w = |s: usize, mode: FusionMode| {
        let g = build(Variant::Causal, &mk(s));
        plan(&g, mode).counters(&g, tc).peak_workspace as f64
    };
    let eager_ratio = w(512, FusionMode::Eager) / w(128, FusionMode::Eager);
    assert!(eager_ratio > 12.0, "eager should be ~16x (quadratic): {eager_ratio}");
    let fl128 = w(128, FusionMode::Flashlight);
    let fl512 = w(512, FusionMode::Flashlight);
    let fused_ratio = fl512 / fl128.max(1.0);
    assert!(
        fused_ratio < 8.0,
        "fused workspace should be subquadratic: {fused_ratio}"
    );
}

/// Random pointwise/reduce/matmul graphs (not attention-shaped): the
/// planner must stay legal — whatever it fuses still evaluates to the
/// eager result.
#[test]
fn property_random_graphs_execute_correctly_under_all_modes() {
    let mut rng = Rng::new(77);
    for case in 0..20 {
        let mut gb = GraphBuilder::new("rand");
        let m = 8 * rng.range(1, 4);
        let n = 8 * rng.range(1, 4);
        let k = 8 * rng.range(1, 3);
        let a = gb.input("a", &[m, k]);
        let b = gb.input("b", &[k, n]);
        let mut x = gb.matmul(a, b);
        // random pointwise chain
        for _ in 0..rng.range(0, 4) {
            x = match rng.range(0, 4) {
                0 => gb.mul_scalar(x, 0.5),
                1 => gb.tanh(x),
                2 => gb.add_scalar(x, 1.0),
                _ => gb.sigmoid(x),
            };
        }
        // optionally a softmax and a second matmul
        let with_softmax = rng.range(0, 2) == 1;
        if with_softmax {
            x = gb.softmax(x, 1);
        }
        let out = if rng.range(0, 2) == 1 {
            let c = gb.input("c", &[n, 8]);
            gb.matmul(x, c)
        } else {
            x
        };
        let g = gb.finish(&[out]);
        let inputs = inputs_for(&g, case as u64);
        let (want, _) = eval(&g, &inputs);
        for mode in [FusionMode::TorchCompile, FusionMode::Flashlight] {
            let p = plan(&g, mode);
            let (got, _) = execute_plan(
                &g,
                &p,
                &inputs,
                TileConfig {
                    block_q: 8,
                    block_k: 8,
                    ..Default::default()
                },
            );
            let err = got[0].max_abs_diff(&want[0]);
            assert!(err < 1e-4, "case {case} {mode:?}: err {err}");
        }
    }
}

/// The causal mask built from iota/cmp is exactly lower-triangular, and
/// the masked softmax renormalizes over the visible prefix only.
#[test]
fn causal_masking_semantics() {
    let mut gb = GraphBuilder::new("mask");
    let s = 16;
    let x = gb.input("x", &[s, s]);
    let qi = gb.iota(&[s, s], 0);
    let ki = gb.iota(&[s, s], 1);
    let keep = gb.cmp(CmpOp::Le, ki, qi);
    let masked = gb.masked_fill_neg(x, keep);
    let w = gb.softmax(masked, 1);
    let g = gb.finish(&[w]);
    let mut inputs = HashMap::new();
    inputs.insert("x".into(), Tensor::synthetic(&[s, s], 5));
    let (outs, _) = eval(&g, &inputs);
    for i in 0..s {
        let row = &outs[0].data[i * s..(i + 1) * s];
        let visible: f32 = row[..=i].iter().sum();
        let hidden: f32 = row[i + 1..].iter().sum();
        assert!((visible - 1.0).abs() < 1e-5, "row {i} sums to {visible}");
        assert!(hidden.abs() < 1e-12, "row {i} leaks {hidden}");
    }
}

/// Dimension analysis agrees with the executors: for every variant, the
/// pipeline's q/kv classes have the extents the shape dictates.
#[test]
fn pipeline_dim_classes_match_shape() {
    let shape = AttnShape {
        batch: 2,
        rows: 1,
        heads_q: 4,
        heads_kv: 2,
        seq: 64,
        head_dim: 16,
    };
    for variant in paper_variants() {
        let g = build(variant, &shape);
        let an = analyze(&g);
        let p = plan(&g, FusionMode::Flashlight);
        for grp in &p.groups {
            if let GroupKind::Pipeline(pipe) = &grp.kind {
                assert_eq!(an.size(pipe.q_class), 64, "{}", variant.name());
                assert_eq!(an.size(pipe.kv_class), 64, "{}", variant.name());
            }
        }
    }
}

/// Eager analytic counters equal executed counters for all variants.
#[test]
fn eager_counters_consistency_all_variants() {
    for variant in all_variants() {
        let shape = AttnShape {
            batch: 1,
            rows: 2,
            heads_q: 2,
            heads_kv: 2,
            seq: 32,
            head_dim: 8,
        };
        let g = build(variant, &shape);
        let inputs = inputs_for(&g, 3);
        let (_, c_run) = eval(&g, &inputs);
        let c_model = eager_counters(&g);
        assert_eq!(c_run, c_model, "{}", variant.name());
    }
}

/// AOT artifact round-trip (skipped when artifacts are absent): the
/// manifest parses, and one fused/naive pair agrees through PJRT.
#[cfg(feature = "pjrt")]
#[test]
fn artifact_roundtrip_if_present() {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut engine = flashlight::runtime::Engine::new("artifacts").unwrap();
    let meta = engine.artifact("attn_causal_fused").unwrap().clone();
    let inputs: Vec<xla::Literal> = meta
        .inputs
        .iter()
        .enumerate()
        .map(|(i, m)| flashlight::runtime::Engine::synthetic_input(m, i as u64))
        .collect();
    let a: Vec<f32> = engine.run("attn_causal_fused", &inputs).unwrap()[0]
        .to_vec()
        .unwrap();
    let b: Vec<f32> = engine.run("attn_causal_naive", &inputs).unwrap()[0]
        .to_vec()
        .unwrap();
    let err = a
        .iter()
        .zip(&b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(err < 1e-4, "PJRT fused/naive diverge: {err}");
}
