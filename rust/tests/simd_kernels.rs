//! Property tests for the SIMD kernel tier.
//!
//! The contract (see `rust/src/exec/simd/mod.rs`): the dispatched
//! vector tier and the scalar tier agree **bit-exactly** — `to_bits`
//! equality, not tolerance — over odd shapes and tails, the
//! `FLASHLIGHT_SIMD=0` kill switch forces the scalar tier, and the
//! engine's parity gates (fused vs eager, sequential vs parallel) hold
//! with SIMD dispatch on.
//!
//! On a host whose best tier *is* scalar these bit-equality tests
//! compare scalar against scalar and pass trivially; the
//! `scripts/bench_regress.sh` CI pass runs the whole suite both ways
//! (default and `FLASHLIGHT_SIMD=0`) so each tier gets a full-suite
//! run wherever vector hardware exists.

use std::collections::HashMap;

use flashlight::exec::simd::{self, PackedB, SimdLevel};
use flashlight::exec::{eval, execute_plan, execute_plan_par, Parallelism, Tensor};
use flashlight::fusion::{plan, FusionMode, TileConfig};
use flashlight::ir::{Graph, Op};
use flashlight::variants::{build, AttnShape, Variant};

/// Deterministic fill with negatives, exact zeros, and magnitude spread.
fn fill(n: usize, seed: u64) -> Vec<f32> {
    (0..n)
        .map(|i| {
            if i % 13 == 7 {
                0.0 // exercise the exact-zero skip paths
            } else {
                ((seed as f64 + i as f64 * 0.7).sin() * 4.0) as f32
            }
        })
        .collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{label}: lane {i} differs ({x} vs {y})"
        );
    }
}

/// Odd shapes + tails: every combination of tiny, just-past-vector,
/// and just-past-block extents.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 3, 5),
    (1, 17, 129),
    (2, 2, 2),
    (3, 5, 7),
    (5, 17, 3),
    (7, 9, 31),
    (8, 16, 64),
    (9, 17, 65),
    (16, 33, 17),
    (17, 129, 5),
    (33, 31, 130),
];

#[test]
fn gemm_nt_dispatched_is_bit_exact_vs_scalar() {
    let lvl = simd::level();
    for &(m, n, k) in SHAPES {
        let a = fill(m * k, 1);
        let b = fill(n * k, 2);
        let mut c_s = vec![0.0f32; m * n];
        let mut c_v = vec![0.0f32; m * n];
        simd::gemm_nt_with(SimdLevel::Scalar, &a, &b, &mut c_s, m, n, k);
        simd::gemm_nt_with(lvl, &a, &b, &mut c_v, m, n, k);
        assert_bits_eq(&c_s, &c_v, &format!("gemm_nt {m}x{n}x{k}"));
    }
}

#[test]
fn gemm_nt_packed_is_bit_exact_for_any_packing_width() {
    let lvl = simd::level();
    for &(m, n, k) in SHAPES {
        if m < 2 {
            continue; // m = 1 never packs (decode dot path)
        }
        let a = fill(m * k, 3);
        let b = fill(n * k, 4);
        let mut c_plain = vec![0.0f32; m * n];
        simd::gemm_nt_with(SimdLevel::Scalar, &a, &b, &mut c_plain, m, n, k);
        for pack_level in [SimdLevel::Scalar, lvl] {
            let bp = PackedB::pack_with(pack_level, &b, n, k, Vec::new());
            let mut c_p = vec![0.0f32; m * n];
            simd::gemm_nt_packed_with(lvl, &a, &bp, &mut c_p, m, n, k);
            assert_bits_eq(
                &c_plain,
                &c_p,
                &format!("gemm_nt_packed {m}x{n}x{k} nr={}", bp.nr),
            );
        }
    }
}

#[test]
fn gemm_nn_dispatched_is_bit_exact_vs_scalar() {
    let lvl = simd::level();
    for &(m, n, k) in SHAPES {
        let a = fill(m * k, 5);
        let b = fill(k * n, 6);
        // non-zero initial accumulator: NN must chain off it
        let init = fill(m * n, 7);
        let mut c_s = init.clone();
        let mut c_v = init.clone();
        simd::gemm_nn_with(SimdLevel::Scalar, &a, &b, &mut c_s, m, n, k);
        simd::gemm_nn_with(lvl, &a, &b, &mut c_v, m, n, k);
        assert_bits_eq(&c_s, &c_v, &format!("gemm_nn {m}x{n}x{k}"));
    }
}

#[test]
fn exp_and_sigmoid_are_bit_exact_vs_scalar() {
    let lvl = simd::level();
    for n in [1usize, 3, 7, 8, 9, 16, 31, 129, 1000] {
        let mut x = fill(n, 8);
        // splice in the boundary cases wherever they fit
        let specials = [
            -1e30f32,
            f32::NEG_INFINITY,
            f32::INFINITY,
            -87.4,
            -87.3,
            0.0,
            88.0,
            88.9,
            1e30,
        ];
        for (i, s) in specials.iter().enumerate() {
            if i < n {
                x[i] = *s;
            }
        }
        for shift in [0.0f32, -1.5, 2.25] {
            let mut d_s = vec![0.0f32; n];
            let mut d_v = vec![0.0f32; n];
            simd::vexp_shift_with(SimdLevel::Scalar, &mut d_s, &x, shift);
            simd::vexp_shift_with(lvl, &mut d_v, &x, shift);
            assert_bits_eq(&d_s, &d_v, &format!("vexp n={n} shift={shift}"));
            // and both match the single-lane reference
            for i in 0..n {
                assert_eq!(d_s[i].to_bits(), simd::exp_f32(x[i] + shift).to_bits());
            }
        }
        let mut d_s = vec![0.0f32; n];
        let mut d_v = vec![0.0f32; n];
        simd::vsigmoid_with(SimdLevel::Scalar, &mut d_s, &x);
        simd::vsigmoid_with(lvl, &mut d_v, &x);
        assert_bits_eq(&d_s, &d_v, &format!("vsigmoid n={n}"));
    }
}

#[test]
fn row_reductions_are_bit_exact_vs_scalar() {
    let lvl = simd::level();
    for n in [0usize, 1, 2, 7, 8, 9, 15, 16, 17, 63, 64, 65, 1000] {
        let x = fill(n, 9);
        assert_eq!(
            simd::row_sum_with(SimdLevel::Scalar, &x).to_bits(),
            simd::row_sum_with(lvl, &x).to_bits(),
            "row_sum n={n}"
        );
        assert_eq!(
            simd::row_max_with(SimdLevel::Scalar, &x).to_bits(),
            simd::row_max_with(lvl, &x).to_bits(),
            "row_max n={n}"
        );
        // row_max against the plain fold (order-insensitive for
        // non-NaN input)
        let naive = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert_eq!(simd::row_max_with(lvl, &x), naive, "row_max value n={n}");
    }
}

#[test]
fn scale_axpy_and_assign_folds_are_bit_exact_vs_scalar() {
    let lvl = simd::level();
    for n in [1usize, 5, 8, 13, 64, 127] {
        let v = fill(n, 10);
        let mut acc_s = fill(n, 11);
        let mut acc_v = acc_s.clone();
        simd::scale_with(SimdLevel::Scalar, &mut acc_s, 0.37);
        simd::scale_with(lvl, &mut acc_v, 0.37);
        assert_bits_eq(&acc_s, &acc_v, &format!("scale n={n}"));
        simd::axpy_with(SimdLevel::Scalar, &mut acc_s, 1.7, &v);
        simd::axpy_with(lvl, &mut acc_v, 1.7, &v);
        assert_bits_eq(&acc_s, &acc_v, &format!("axpy n={n}"));
        simd::vadd_assign_with(SimdLevel::Scalar, &mut acc_s, &v);
        simd::vadd_assign_with(lvl, &mut acc_v, &v);
        assert_bits_eq(&acc_s, &acc_v, &format!("vadd n={n}"));
        simd::vmax_assign_with(SimdLevel::Scalar, &mut acc_s, &v);
        simd::vmax_assign_with(lvl, &mut acc_v, &v);
        assert_bits_eq(&acc_s, &acc_v, &format!("vmax n={n}"));
    }
}

#[test]
fn kill_switch_forces_the_scalar_tier() {
    // The env override is parsed by `resolve`; `level()` caches it per
    // process, so the full-suite scalar run is driven by
    // `FLASHLIGHT_SIMD=0 cargo test` (see scripts/bench_regress.sh).
    assert_eq!(simd::resolve(Some("0")), SimdLevel::Scalar);
    assert_eq!(simd::resolve(Some("off")), SimdLevel::Scalar);
    assert_eq!(simd::resolve(Some("scalar")), SimdLevel::Scalar);
    assert_eq!(simd::resolve(None), simd::detect());
    if std::env::var("FLASHLIGHT_SIMD").map(|v| v.trim() == "0").unwrap_or(false) {
        assert_eq!(simd::level(), SimdLevel::Scalar);
    }
}

fn synthetic_inputs(g: &Graph, seed: u64) -> HashMap<String, Tensor> {
    let mut m = HashMap::new();
    for (i, &id) in g.inputs.iter().enumerate() {
        let node = g.node(id);
        let Op::Input { name } = &node.op else { unreachable!() };
        let t = if name.starts_with("doc") {
            let n: usize = node.shape.iter().product();
            Tensor::from_vec(&node.shape, (0..n).map(|j| (j * 3 / n) as f32).collect())
        } else {
            Tensor::synthetic(&node.shape, seed + i as u64)
        };
        m.insert(name.clone(), t);
    }
    m
}

/// The engine-level gates the tier must not perturb: fused/eager parity
/// (tolerance) and seq/par bit-identity (outputs AND counters), with
/// SIMD dispatch live in both executors.
#[test]
fn engine_gates_hold_with_simd_dispatch() {
    let shape = AttnShape {
        batch: 2,
        rows: 1,
        heads_q: 4,
        heads_kv: 2,
        seq: 48, // not a multiple of block_k: tail tiles everywhere
        head_dim: 24,
    };
    let tile = TileConfig {
        block_q: 16,
        block_k: 32,
        l2_capacity: 40 << 20,
    };
    for v in [
        Variant::Vanilla,
        Variant::Causal,
        Variant::Softcap { cap: 20.0 },
        Variant::Rectified { tau: 0.05 },
    ] {
        let g = build(v, &shape);
        let inputs = synthetic_inputs(&g, 23);
        let p = plan(&g, FusionMode::Flashlight);
        let (seq_out, seq_c) = execute_plan(&g, &p, &inputs, tile);
        let (want, _) = eval(&g, &inputs);
        let err = seq_out[0].max_abs_diff(&want[0]);
        assert!(err < 1e-4, "{}: fused/eager err {err}", v.name());
        for threads in [2, 5] {
            let (par_out, par_c) =
                execute_plan_par(&g, &p, &inputs, tile, &Parallelism::with_threads(threads));
            assert_eq!(seq_out, par_out, "{} outputs, threads={threads}", v.name());
            assert_eq!(seq_c, par_c, "{} counters, threads={threads}", v.name());
        }
    }
}
