//! Failure-injection tests: the system must fail loudly and precisely,
//! never silently compute garbage.

#[cfg(feature = "pjrt")]
use flashlight::runtime::Engine;
use flashlight::exec::Parallelism;
use flashlight::runtime::{Manifest, TensorMeta};
use flashlight::serve::{
    run_lifecycle, run_lifecycle_ext, run_trace, spawn_ingress, Backend, ClockMode,
    EngineBackend, EngineModel, FaultPlan, Ingress, LifecycleConfig, LifecycleReport, Outcome,
    SchedulerConfig, StreamEvent, StreamHub,
};
use flashlight::tracegen::{generate, Request, TraceConfig};

#[test]
fn manifest_load_fails_cleanly_on_missing_dir() {
    let err = Manifest::load(std::path::Path::new("/definitely/not/here"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("manifest"), "{err}");
}

#[test]
fn manifest_rejects_malformed_lines() {
    let dir = std::path::Path::new("/tmp/flashlight_bad_manifest");
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "artifact broken broken.hlo.txt in notashape out f32:4\n",
    )
    .unwrap();
    assert!(Manifest::load(dir).is_err());
}

#[test]
fn tensor_meta_rejects_garbage() {
    assert!(TensorMeta::parse("f32").is_err());
    assert!(TensorMeta::parse("f32:4xBANANA").is_err());
    assert!(TensorMeta::parse("f32:1x2x3").is_ok());
}

#[cfg(feature = "pjrt")]
#[test]
fn engine_reports_unknown_artifact_and_arity_mismatch() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut engine = Engine::new("artifacts").unwrap();
    let err = match engine.run("no_such_artifact", &[]) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected error"),
    };
    assert!(err.contains("unknown artifact"), "{err}");
    // wrong input arity must be rejected before execution
    let err = match engine.run("attn_causal_fused", &[]) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected error"),
    };
    assert!(err.contains("expected"), "{err}");
}

#[cfg(feature = "pjrt")]
#[test]
fn weight_blob_length_is_validated() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // Truncated blob in a scratch dir with a doctored manifest.
    let dir = std::path::Path::new("/tmp/flashlight_trunc_weights");
    std::fs::create_dir_all(dir).unwrap();
    let manifest = std::fs::read_to_string("artifacts/manifest.txt").unwrap();
    std::fs::write(dir.join("manifest.txt"), &manifest).unwrap();
    // copy one real artifact file so Engine::new parses
    let blob = std::fs::read("artifacts/llama_weights.bin").unwrap();
    std::fs::write(dir.join("llama_weights.bin"), &blob[..blob.len() / 2]).unwrap();
    let engine = Engine::new(dir).unwrap();
    let err = match engine.load_weights("llama") {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected error"),
    };
    assert!(err.contains("too short"), "{err}");
}

/// Backend that always reports a fixed-size context window.
struct TinyContextBackend;

impl Backend for TinyContextBackend {
    fn n_slots(&self) -> usize {
        2
    }
    fn max_context(&self) -> usize {
        64
    }
    fn prefill(
        &mut self,
        _s: usize,
        _req: &Request,
        t: &[u32],
    ) -> anyhow::Result<(f64, u32)> {
        assert!(t.len() <= 64);
        Ok((1e-4, 0))
    }
    fn decode(&mut self, a: &[usize]) -> anyhow::Result<(f64, Vec<u32>)> {
        Ok((1e-4, vec![0; a.len()]))
    }
    fn release(&mut self, _s: usize) {}
    fn is_virtual_time(&self) -> bool {
        true
    }
}

#[test]
fn coordinator_rejects_requests_exceeding_context() {
    let trace = vec![Request {
        id: 0,
        arrival_s: 0.0,
        input_tokens: 100, // > 64-token window
        output_tokens: 8,
        conversation: 0,
        turn: 0,
        ..Request::default()
    }];
    let mut b = TinyContextBackend;
    let err = run_trace(&mut b, &trace, SchedulerConfig::default(), 512)
        .unwrap_err()
        .to_string();
    assert!(err.contains("exceeds context"), "{err}");
}

#[test]
fn coordinator_survives_empty_and_single_token_requests() {
    let mut trace = generate(&TraceConfig {
        n_requests: 8,
        max_input: 32,
        max_output: 2,
        ..Default::default()
    });
    // degenerate: 1 input token, 1 output token
    trace[0].input_tokens = 1;
    trace[0].output_tokens = 1;
    let mut b = TinyContextBackend;
    let done = run_trace(&mut b, &trace, SchedulerConfig::default(), 512).unwrap();
    assert_eq!(done.len(), 8);
    assert!(done[0].itls.is_empty()); // single-token: no inter-token gaps
}

// ---------------------------------------------------------------------
// Fault-tolerant serving lifecycle: the chaos gates.
//
// Every scenario below runs through `assert_lifecycle_gates`, which
// enforces the lifecycle's three invariants at 1, 2, and 4 worker
// threads:
//   1. exactly one terminal state per request;
//   2. no KV pages leak (allocated returns to free + parked, and to
//      free alone once the prefix cache is cleared);
//   3. every emitted token stream is a prefix of the unconstrained
//      fault-free run's stream — equal for completed requests — so
//      survivors are bit-identical and victims died mid-stream, not
//      corrupted.
// The deterministic round clock makes all three thread counts produce
// identical outcomes, which is asserted too.
// ---------------------------------------------------------------------

fn lifecycle_trace(n: usize) -> Vec<Request> {
    generate(&TraceConfig {
        n_requests: n,
        rate: 100.0,
        input_mu: 3.6,
        input_sigma: 0.4,
        mean_output: 6.0,
        max_input: 120,
        max_output: 10,
        ..Default::default()
    })
}

fn rounds_lc() -> LifecycleConfig {
    LifecycleConfig {
        clock: ClockMode::Rounds,
        ..Default::default()
    }
}

fn run_engine_lifecycle(
    trace: &[Request],
    threads: usize,
    page_cap: usize,
    plan: &FaultPlan,
    lc: LifecycleConfig,
) -> LifecycleReport {
    let mut b = EngineBackend::new(
        EngineModel::tiny(),
        4,
        1024,
        Parallelism::with_threads(threads),
    );
    if page_cap > 0 {
        b.set_page_cap(page_cap);
    }
    let vocab = b.model.vocab;
    let cfg = SchedulerConfig {
        prefill_chunk_tokens: 64,
        prefill_round_tokens: 128,
        ..Default::default()
    };
    let rep = run_lifecycle(&mut b, trace, cfg, lc, plan, vocab).unwrap();
    let (alloc, free) = b.kv_pages();
    let parked = b.prefix_stats().parked_pages;
    assert_eq!(
        alloc,
        free + parked,
        "pages leaked at {threads} threads (beyond the parked prefixes)"
    );
    b.clear_prefix_cache();
    let (alloc, free) = b.kv_pages();
    assert_eq!(alloc, free, "pages leaked at {threads} threads after cache clear");
    rep
}

fn assert_lifecycle_gates(
    trace: &[Request],
    page_cap: usize,
    plan: &FaultPlan,
    lc: LifecycleConfig,
) -> LifecycleReport {
    // Unconstrained fault-free reference: same prompts, no deadlines or
    // cancels, no faults. Everything admissible completes here.
    let mut plain = trace.to_vec();
    for r in &mut plain {
        r.deadline_s = f64::INFINITY;
        r.cancel_s = f64::INFINITY;
    }
    let healthy = run_engine_lifecycle(&plain, 1, page_cap, &FaultPlan::none(), rounds_lc());
    let reference: std::collections::HashMap<usize, Vec<u32>> = healthy
        .outcomes
        .into_iter()
        .filter(|o| o.outcome == Outcome::Completed)
        .map(|o| (o.id, o.tokens))
        .collect();

    let mut per_thread: Vec<Vec<(usize, Outcome, Vec<u32>)>> = Vec::new();
    let mut last = None;
    for threads in [1usize, 2, 4] {
        let rep = run_engine_lifecycle(trace, threads, page_cap, plan, lc);
        assert_eq!(
            rep.summary.total(),
            trace.len(),
            "terminal accounting broken at {threads} threads"
        );
        for o in &rep.outcomes {
            match reference.get(&o.id) {
                Some(want) => {
                    assert!(
                        o.tokens.len() <= want.len(),
                        "request {} emitted more tokens than the fault-free run",
                        o.id
                    );
                    assert_eq!(
                        &o.tokens[..],
                        &want[..o.tokens.len()],
                        "request {} diverged from the fault-free stream at {threads} threads",
                        o.id
                    );
                    if o.outcome == Outcome::Completed {
                        assert_eq!(
                            &o.tokens, want,
                            "survivor {} not bit-identical at {threads} threads",
                            o.id
                        );
                    }
                }
                // Inadmissible in the reference too: it must never have
                // produced a token under faults either.
                None => assert!(o.tokens.is_empty(), "request {} has no reference", o.id),
            }
        }
        per_thread.push(
            rep.outcomes
                .iter()
                .map(|o| (o.id, o.outcome, o.tokens.clone()))
                .collect(),
        );
        last = Some(rep);
    }
    assert_eq!(per_thread[0], per_thread[1], "outcomes diverged 1 vs 2 threads");
    assert_eq!(per_thread[0], per_thread[2], "outcomes diverged 1 vs 4 threads");
    last.unwrap()
}

#[test]
fn pool_exhaustion_preempts_requeues_and_recovers() {
    let mut tr = lifecycle_trace(6);
    // A prompt long enough that its chunked prefill straddles the
    // pressure window's onset (round 0 prefills 128 of 150 rows): the
    // round-1 preflight must preempt it.
    tr[0].input_tokens = 150;
    let plan = FaultPlan::parse("pressure@1:12x6").unwrap();
    let rep = assert_lifecycle_gates(&tr, 12, &plan, rounds_lc());
    assert!(
        rep.summary.preemptions >= 1,
        "the pressure window must preempt the in-flight request"
    );
    assert_eq!(
        rep.summary.completed,
        tr.len(),
        "every request recovers once pressure lifts"
    );
    assert!(
        rep.outcomes
            .iter()
            .any(|o| o.preemptions > 0 && o.outcome == Outcome::Completed),
        "a preempted request must requeue and complete"
    );
}

#[test]
fn cancel_mid_chunked_prefill_frees_the_slot_and_spares_survivors() {
    let mut tr = lifecycle_trace(5);
    tr[0].input_tokens = 150; // three 64-token chunks: cancels mid-prefill
    let plan = FaultPlan::parse("cancel@1:0").unwrap();
    let rep = assert_lifecycle_gates(&tr, 0, &plan, rounds_lc());
    let o0 = &rep.outcomes[0];
    assert_eq!(o0.outcome, Outcome::Cancelled);
    assert!(o0.reason.contains("mid-prefill"), "{}", o0.reason);
    assert!(o0.tokens.is_empty(), "cancelled before its first token");
    assert_eq!(rep.summary.completed, tr.len() - 1);
}

#[test]
fn deadline_expiry_mid_decode_keeps_a_clean_prefix() {
    let mut tr = lifecycle_trace(5);
    tr[0].input_tokens = 40; // prefill completes in the admission round
    tr[0].output_tokens = 10;
    tr[0].deadline_s = 4.0; // rounds: dies partway through decode
    let rep = assert_lifecycle_gates(&tr, 0, &FaultPlan::none(), rounds_lc());
    let o0 = &rep.outcomes[0];
    assert_eq!(o0.outcome, Outcome::DeadlineExceeded);
    assert!(o0.reason.contains("mid-decode"), "{}", o0.reason);
    assert!(
        !o0.tokens.is_empty() && o0.tokens.len() < 10,
        "expired mid-stream, got {} tokens",
        o0.tokens.len()
    );
    assert!(o0.metrics.is_some(), "it produced tokens, so it has metrics");
    assert_eq!(rep.summary.completed, tr.len() - 1);
}

#[test]
fn worker_panic_fails_one_request_and_spares_the_batch() {
    let tr = lifecycle_trace(6);
    let plan = FaultPlan::parse("panic@3").unwrap();
    let rep = assert_lifecycle_gates(&tr, 0, &plan, rounds_lc());
    assert_eq!(rep.summary.failed, 1, "exactly the poisoned request fails");
    assert_eq!(rep.summary.completed, tr.len() - 1);
    let f = rep
        .outcomes
        .iter()
        .find(|o| o.outcome == Outcome::Failed)
        .unwrap();
    assert!(f.reason.contains("worker panic"), "{}", f.reason);
}

#[test]
fn admission_rejects_impossible_requests_with_precise_reasons() {
    let mut tr = lifecycle_trace(4);
    tr[0].input_tokens = 30;
    tr[1].input_tokens = 2000; // exceeds the 1024-token context window
    tr[2].input_tokens = 150; // needs 3 KV pages; the cap is 2
    tr[3].input_tokens = 40;
    let rep = assert_lifecycle_gates(&tr, 2, &FaultPlan::none(), rounds_lc());
    let o1 = &rep.outcomes[1];
    assert_eq!(o1.outcome, Outcome::Rejected);
    assert!(o1.reason.contains("exceeds context window"), "{}", o1.reason);
    assert!(o1.retry_after_s.is_infinite(), "never-fits: do not retry");
    let o2 = &rep.outcomes[2];
    assert_eq!(o2.outcome, Outcome::Rejected);
    assert!(o2.reason.contains("can never fit"), "{}", o2.reason);
    assert_eq!(rep.summary.completed, 2);
}

#[test]
fn generated_fault_plans_preserve_every_invariant() {
    let tr = lifecycle_trace(8);
    for seed in [1u64, 2, 3] {
        let plan = FaultPlan::generate(seed, 16);
        assert!(!plan.is_empty(), "seeded plans schedule events");
        let rep = assert_lifecycle_gates(&tr, 16, &plan, rounds_lc());
        assert_eq!(rep.summary.total(), tr.len(), "seed {seed}");
    }
}

// ---------------------------------------------------------------------
// Live serving: token streams, watchdog supervision, graceful drain,
// and deterministic backoff resubmission.
// ---------------------------------------------------------------------

fn live_sched() -> SchedulerConfig {
    SchedulerConfig {
        prefill_chunk_tokens: 64,
        prefill_round_tokens: 128,
        ..Default::default()
    }
}

#[test]
fn mid_stream_cancel_closes_the_token_channel_with_the_terminal() {
    let mut tr = lifecycle_trace(5);
    tr[0].input_tokens = 40; // prefill completes in the admission round
    tr[0].output_tokens = 10;
    tr[0].deadline_s = f64::INFINITY; // only the injected cancel may kill it
    tr[0].cancel_s = f64::INFINITY;
    let plan = FaultPlan::parse("cancel@4:0").unwrap();
    let mut b = EngineBackend::new(
        EngineModel::tiny(),
        4,
        1024,
        Parallelism::with_threads(2),
    );
    let vocab = b.model.vocab;
    let mut hub = StreamHub::new(64);
    let rx = hub.open(0, 64);
    let rep = run_lifecycle_ext(
        &mut b,
        Ingress::Saturating(&tr),
        live_sched(),
        rounds_lc(),
        &plan,
        vocab,
        &mut hub,
        None,
    )
    .unwrap();
    let o0 = rep.outcomes.iter().find(|o| o.id == 0).unwrap();
    assert_eq!(o0.outcome, Outcome::Cancelled);
    assert!(
        !o0.tokens.is_empty() && o0.tokens.len() < 10,
        "cancelled mid-stream, got {} tokens",
        o0.tokens.len()
    );
    // The consumer's channel carries exactly the emitted tokens, then
    // the terminal event — a client can always tell how the stream died.
    let evs: Vec<StreamEvent> = rx.try_iter().collect();
    let toks: Vec<u32> = evs
        .iter()
        .filter_map(|e| match e {
            StreamEvent::Token(t) => Some(*t),
            StreamEvent::Done { .. } => None,
        })
        .collect();
    assert_eq!(toks, o0.tokens, "stream tokens must match the outcome's");
    assert_eq!(
        evs.last(),
        Some(&StreamEvent::Done {
            outcome: Outcome::Cancelled,
            reason: o0.reason.clone()
        }),
        "the last stream event is the terminal"
    );
    let (alloc, free) = b.kv_pages();
    assert_eq!(alloc, free + b.prefix_stats().parked_pages);
}

#[test]
fn watchdog_kills_a_stalled_launch_and_survivors_stay_bit_identical() {
    let tr = lifecycle_trace(6);
    // stall@3: grid item 0 of round 3's launch stops heartbeating. The
    // lifecycle auto-starts a supervisor for stall plans; the kill is
    // attributed like a worker panic, so the full gate suite (terminal
    // accounting, no leaks, survivor bit-identity at 1/2/4 threads)
    // must hold with the watchdog in the loop.
    let plan = FaultPlan::parse("stall@3").unwrap();
    let rep = assert_lifecycle_gates(&tr, 0, &plan, rounds_lc());
    assert!(
        rep.stats.watchdog_kills >= 1,
        "the auto-supervisor must kill the stalled launch"
    );
    assert_eq!(rep.summary.failed, 1, "exactly the stalled request fails");
    assert_eq!(rep.summary.completed, tr.len() - 1);
    let f = rep
        .outcomes
        .iter()
        .find(|o| o.outcome == Outcome::Failed)
        .unwrap();
    assert!(f.reason.contains("stalled"), "{}", f.reason);
}

#[test]
fn live_ingress_drains_under_pressure_without_leaking_pages() {
    let tr = lifecycle_trace(8);
    let plan = FaultPlan::parse("pressure@2:8x6").unwrap();
    let mut b = EngineBackend::new(
        EngineModel::tiny(),
        4,
        1024,
        Parallelism::with_threads(2),
    );
    b.set_page_cap(16);
    let vocab = b.model.vocab;
    let mut hub = StreamHub::new(64);
    let mut rxs = Vec::new();
    let subs: Vec<_> = tr
        .iter()
        .map(|r| {
            let (tx, rx) = std::sync::mpsc::sync_channel::<StreamEvent>(64);
            rxs.push(rx);
            (r.clone(), Some(tx))
        })
        .collect();
    let (ingress, handle) = spawn_ingress(subs, 1e-4, 4);
    let lc = LifecycleConfig {
        queue_cap: 4,
        resubmit_max: 2,
        ..Default::default()
    };
    let rep = run_lifecycle_ext(
        &mut b,
        Ingress::Live(ingress),
        live_sched(),
        lc,
        &plan,
        vocab,
        &mut hub,
        None,
    )
    .unwrap();
    assert_eq!(
        handle.join().unwrap(),
        tr.len(),
        "the ingress thread submits the whole trace before disconnecting"
    );
    assert_eq!(
        rep.summary.total(),
        tr.len(),
        "every live submission reaches exactly one terminal"
    );
    for rx in rxs {
        let evs: Vec<StreamEvent> = rx.try_iter().collect();
        assert!(
            matches!(evs.last(), Some(StreamEvent::Done { .. })),
            "every stream ends with its terminal event, got {evs:?}"
        );
    }
    let (alloc, free) = b.kv_pages();
    assert_eq!(
        alloc,
        free + b.prefix_stats().parked_pages,
        "pages leaked after drain"
    );
    b.clear_prefix_cache();
    let (alloc, free) = b.kv_pages();
    assert_eq!(alloc, free, "pages leaked after cache clear");
}

#[test]
fn open_loop_backoff_is_deterministic_across_threads() {
    // All ten requests arrive at round 0 against a 3-deep queue: the
    // overflow must re-enter through seeded exponential backoff, and the
    // whole schedule — requeue count, round count, every outcome and
    // token — must be bit-identical at 1, 2, and 4 worker threads.
    let tr = lifecycle_trace(10);
    let mut runs = Vec::new();
    for threads in [1usize, 2, 4] {
        let mut b = EngineBackend::new(
            EngineModel::tiny(),
            4,
            1024,
            Parallelism::with_threads(threads),
        );
        let vocab = b.model.vocab;
        let lc = LifecycleConfig {
            clock: ClockMode::Rounds,
            queue_cap: 3,
            resubmit_max: 3,
            ..Default::default()
        };
        let mut hub = StreamHub::disabled();
        let rep = run_lifecycle_ext(
            &mut b,
            Ingress::OpenLoop { trace: &tr, time_scale: 0.0 },
            live_sched(),
            lc,
            &FaultPlan::none(),
            vocab,
            &mut hub,
            None,
        )
        .unwrap();
        assert_eq!(rep.summary.total(), tr.len());
        assert!(
            rep.stats.backoff_requeues >= 1,
            "queue overflow must requeue through backoff"
        );
        let (alloc, free) = b.kv_pages();
        assert_eq!(alloc, free + b.prefix_stats().parked_pages);
        runs.push((
            rep.stats.backoff_requeues,
            rep.stats.rounds,
            rep.outcomes
                .iter()
                .map(|o| (o.id, o.outcome, o.tokens.clone()))
                .collect::<Vec<_>>(),
        ));
    }
    assert_eq!(runs[0], runs[1], "backoff schedule diverged 1 vs 2 threads");
    assert_eq!(runs[0], runs[2], "backoff schedule diverged 1 vs 4 threads");
}

#[test]
fn graph_builder_panics_are_informative() {
    use flashlight::ir::GraphBuilder;
    let caught = std::panic::catch_unwind(|| {
        let mut b = GraphBuilder::new("bad");
        let x = b.input("x", &[4, 4]);
        let y = b.input("y", &[4, 5]);
        b.add(x, y); // incompatible non-broadcastable shapes
    });
    assert!(caught.is_err());
}

#[test]
fn executor_rejects_missing_and_misshapen_inputs() {
    use flashlight::exec::{eval, Tensor};
    use flashlight::ir::GraphBuilder;
    let mut b = GraphBuilder::new("t");
    let x = b.input("x", &[2, 2]);
    let y = b.neg(x);
    let g = b.finish(&[y]);
    // missing input
    let r = std::panic::catch_unwind(|| eval(&g, &Default::default()));
    assert!(r.is_err());
    // misshapen input
    let mut inputs = std::collections::HashMap::new();
    inputs.insert("x".to_string(), Tensor::zeros(&[3, 3]));
    let r = std::panic::catch_unwind(|| eval(&g, &inputs));
    assert!(r.is_err());
}
