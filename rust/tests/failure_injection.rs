//! Failure-injection tests: the system must fail loudly and precisely,
//! never silently compute garbage.

#[cfg(feature = "pjrt")]
use flashlight::runtime::Engine;
use flashlight::runtime::{Manifest, TensorMeta};
use flashlight::serve::{run_trace, Backend, SchedulerConfig};
use flashlight::tracegen::{generate, Request, TraceConfig};

#[test]
fn manifest_load_fails_cleanly_on_missing_dir() {
    let err = Manifest::load(std::path::Path::new("/definitely/not/here"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("manifest"), "{err}");
}

#[test]
fn manifest_rejects_malformed_lines() {
    let dir = std::path::Path::new("/tmp/flashlight_bad_manifest");
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "artifact broken broken.hlo.txt in notashape out f32:4\n",
    )
    .unwrap();
    assert!(Manifest::load(dir).is_err());
}

#[test]
fn tensor_meta_rejects_garbage() {
    assert!(TensorMeta::parse("f32").is_err());
    assert!(TensorMeta::parse("f32:4xBANANA").is_err());
    assert!(TensorMeta::parse("f32:1x2x3").is_ok());
}

#[cfg(feature = "pjrt")]
#[test]
fn engine_reports_unknown_artifact_and_arity_mismatch() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut engine = Engine::new("artifacts").unwrap();
    let err = match engine.run("no_such_artifact", &[]) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected error"),
    };
    assert!(err.contains("unknown artifact"), "{err}");
    // wrong input arity must be rejected before execution
    let err = match engine.run("attn_causal_fused", &[]) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected error"),
    };
    assert!(err.contains("expected"), "{err}");
}

#[cfg(feature = "pjrt")]
#[test]
fn weight_blob_length_is_validated() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // Truncated blob in a scratch dir with a doctored manifest.
    let dir = std::path::Path::new("/tmp/flashlight_trunc_weights");
    std::fs::create_dir_all(dir).unwrap();
    let manifest = std::fs::read_to_string("artifacts/manifest.txt").unwrap();
    std::fs::write(dir.join("manifest.txt"), &manifest).unwrap();
    // copy one real artifact file so Engine::new parses
    let blob = std::fs::read("artifacts/llama_weights.bin").unwrap();
    std::fs::write(dir.join("llama_weights.bin"), &blob[..blob.len() / 2]).unwrap();
    let engine = Engine::new(dir).unwrap();
    let err = match engine.load_weights("llama") {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected error"),
    };
    assert!(err.contains("too short"), "{err}");
}

/// Backend that always reports a fixed-size context window.
struct TinyContextBackend;

impl Backend for TinyContextBackend {
    fn n_slots(&self) -> usize {
        2
    }
    fn max_context(&self) -> usize {
        64
    }
    fn prefill(
        &mut self,
        _s: usize,
        _req: &Request,
        t: &[u32],
    ) -> anyhow::Result<(f64, u32)> {
        assert!(t.len() <= 64);
        Ok((1e-4, 0))
    }
    fn decode(&mut self, a: &[usize]) -> anyhow::Result<(f64, Vec<u32>)> {
        Ok((1e-4, vec![0; a.len()]))
    }
    fn release(&mut self, _s: usize) {}
    fn is_virtual_time(&self) -> bool {
        true
    }
}

#[test]
fn coordinator_rejects_requests_exceeding_context() {
    let trace = vec![Request {
        id: 0,
        arrival_s: 0.0,
        input_tokens: 100, // > 64-token window
        output_tokens: 8,
        conversation: 0,
        turn: 0,
    }];
    let mut b = TinyContextBackend;
    let err = run_trace(&mut b, &trace, SchedulerConfig::default(), 512)
        .unwrap_err()
        .to_string();
    assert!(err.contains("exceeds context"), "{err}");
}

#[test]
fn coordinator_survives_empty_and_single_token_requests() {
    let mut trace = generate(&TraceConfig {
        n_requests: 8,
        max_input: 32,
        max_output: 2,
        ..Default::default()
    });
    // degenerate: 1 input token, 1 output token
    trace[0].input_tokens = 1;
    trace[0].output_tokens = 1;
    let mut b = TinyContextBackend;
    let done = run_trace(&mut b, &trace, SchedulerConfig::default(), 512).unwrap();
    assert_eq!(done.len(), 8);
    assert!(done[0].itls.is_empty()); // single-token: no inter-token gaps
}

#[test]
fn graph_builder_panics_are_informative() {
    use flashlight::ir::GraphBuilder;
    let caught = std::panic::catch_unwind(|| {
        let mut b = GraphBuilder::new("bad");
        let x = b.input("x", &[4, 4]);
        let y = b.input("y", &[4, 5]);
        b.add(x, y); // incompatible non-broadcastable shapes
    });
    assert!(caught.is_err());
}

#[test]
fn executor_rejects_missing_and_misshapen_inputs() {
    use flashlight::exec::{eval, Tensor};
    use flashlight::ir::GraphBuilder;
    let mut b = GraphBuilder::new("t");
    let x = b.input("x", &[2, 2]);
    let y = b.neg(x);
    let g = b.finish(&[y]);
    // missing input
    let r = std::panic::catch_unwind(|| eval(&g, &Default::default()));
    assert!(r.is_err());
    // misshapen input
    let mut inputs = std::collections::HashMap::new();
    inputs.insert("x".to_string(), Tensor::zeros(&[3, 3]));
    let r = std::panic::catch_unwind(|| eval(&g, &inputs));
    assert!(r.is_err());
}
