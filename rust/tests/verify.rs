//! Static plan verifier: clean-pass property over every built-in
//! variant at odd shapes, plus adversarial graphs/plans/masks crafted
//! so each of the four check classes demonstrably catches its
//! violation, and the PlanCache amortization gate (steady-state decode
//! does zero verify work).

use std::collections::HashMap;

use flashlight::analysis::{
    resolve_verify, set_verify_override, verify_block_mask, verify_calls_on_this_thread,
    CheckClass, VerifyMode,
};
use flashlight::exec::Tensor;
use flashlight::fusion::{
    classify_block_mask, plan, FusionMode, GroupKind, KernelGroup, Pipeline, Plan, PlanCache,
    PlanKey, RewriteEvent, Rule, TileClass,
};
use flashlight::ir::GraphBuilder;
use flashlight::sketch::analyze;
use flashlight::variants::{
    build, build_serving, paper_variants, serving_variants, AttnShape, Variant,
};

fn odd_shape(seq: usize) -> AttnShape {
    AttnShape {
        batch: 1,
        rows: 1,
        heads_q: 2,
        heads_kv: 1,
        seq,
        head_dim: 16,
    }
}

fn render(diags: &[flashlight::analysis::Diagnostic]) -> String {
    diags
        .iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join("\n")
}

// ---------------------------------------------------------------------
// Clean pass: every built-in variant x odd shapes x fusion modes
// ---------------------------------------------------------------------

#[test]
fn every_builtin_variant_verifies_clean_at_odd_shapes() {
    for v in paper_variants() {
        // Shrink the windows so the masks have teeth at tiny seq.
        let v = match v {
            Variant::SlidingWindow { .. } => Variant::SlidingWindow { window: 5 },
            Variant::PrefixLm { .. } => Variant::PrefixLm { prefix: 7 },
            other => other,
        };
        for seq in [17usize, 23, 48] {
            let g = build(v, &odd_shape(seq));
            for mode in [FusionMode::Eager, FusionMode::TorchCompile, FusionMode::Flashlight] {
                let p = plan(&g, mode);
                if let Err(diags) = p.verify(&g) {
                    panic!(
                        "{} seq={seq} {mode:?}: {} diagnostic(s):\n{}",
                        v.name(),
                        diags.len(),
                        render(&diags)
                    );
                }
            }
        }
    }
    for v in serving_variants() {
        for kv in [48usize, 65] {
            let shape = odd_shape(kv);
            for q_len in [1usize, 7] {
                let g = build_serving(v, &shape, q_len);
                let p = plan(&g, FusionMode::Flashlight);
                if let Err(diags) = p.verify(&g) {
                    panic!(
                        "{} serve kv={kv} q={q_len}: {} diagnostic(s):\n{}",
                        v.name(),
                        diags.len(),
                        render(&diags)
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Check 1: shape/broadcast re-inference
// ---------------------------------------------------------------------

#[test]
fn mutated_shape_is_caught_by_reinference() {
    let mut b = GraphBuilder::new("adversarial_shapes");
    let x = b.input("x", &[4, 8]);
    let y0 = b.input("y", &[4, 8]);
    let y = b.add(x, y0);
    let mut g = b.finish(&[y]);
    let p = plan(&g, FusionMode::Eager);
    assert!(p.verify(&g).is_ok(), "untampered graph must verify clean");
    // Corrupt the stored shape after planning — as a buggy rewrite that
    // forgot to re-infer would.
    g.nodes[y.0 as usize].shape = vec![4, 9];
    let diags = p.verify(&g).unwrap_err();
    assert!(
        diags
            .iter()
            .any(|d| d.check == CheckClass::ShapeInference && d.node == Some(y)),
        "expected a shape-inference diagnostic at the corrupted node:\n{}",
        render(&diags)
    );
}

// ---------------------------------------------------------------------
// Check 2: race freedom (overlapping grid write regions)
// ---------------------------------------------------------------------

#[test]
fn overlapping_group_write_sets_are_caught() {
    let g = build(Variant::Vanilla, &odd_shape(48));
    let mut p = plan(&g, FusionMode::Flashlight);
    assert!(p.verify(&g).is_ok());
    // Forge a second kernel group that writes a node the pipeline
    // already owns: two launches racing on one output buffer.
    let stolen = p.groups[0].nodes[0];
    p.groups.push(KernelGroup {
        nodes: vec![stolen],
        kind: GroupKind::Elementwise,
    });
    let diags = p.verify(&g).unwrap_err();
    assert!(
        diags
            .iter()
            .any(|d| d.check == CheckClass::RaceFreedom && d.message.contains("both write")),
        "expected an overlapping-write-set diagnostic:\n{}",
        render(&diags)
    );
}

// ---------------------------------------------------------------------
// Check 3: float determinism
// ---------------------------------------------------------------------

#[test]
fn swapped_softmax_roles_break_the_determinism_contract() {
    let g = build(Variant::Vanilla, &odd_shape(48));
    let mut p = plan(&g, FusionMode::Flashlight);
    assert!(p.verify(&g).is_ok());
    let mut swapped = false;
    for grp in &mut p.groups {
        if let GroupKind::Pipeline(pipe) = &mut grp.kind {
            if let Some(roles) = &mut pipe.softmax {
                std::mem::swap(&mut roles.max, &mut roles.sum);
                swapped = true;
            }
        }
    }
    assert!(swapped, "vanilla flashlight plan must contain an online-softmax pipeline");
    let diags = p.verify(&g).unwrap_err();
    assert!(
        diags.iter().any(|d| d.check == CheckClass::Determinism),
        "expected a float-determinism diagnostic for swapped max/sum roles:\n{}",
        render(&diags)
    );
}

#[test]
fn hand_built_pipeline_with_reordered_reduction_is_flagged() {
    // A plain (non-online) normalization fused into a tiled pipeline:
    // sum over k runs *before* tiling re-blocks the k loop, so fusing it
    // reorders a non-associative f32 reduction with no contract.
    let mut b = GraphBuilder::new("reordered_reduction");
    let q = b.input("q", &[1, 1, 8, 4]);
    let k = b.input("k", &[1, 1, 16, 4]);
    let v = b.input("v", &[1, 1, 16, 4]);
    let s = b.matmul_nt(q, k); // [1,1,8,16]
    let w = b.sum_reduce(s, 3); // [1,1,8,1]
    let wb = b.broadcast(w, &[1, 1, 8, 16]);
    let sn = b.div(s, wb);
    let o = b.matmul(sn, v); // [1,1,8,4]
    let g = b.finish(&[o]);
    let an = analyze(&g);
    let q_class = an.axes[s.0 as usize][2];
    let kv_class = an.axes[s.0 as usize][3];
    let members = vec![s, w, wb, sn, o];
    let mut assignment = vec![usize::MAX; g.nodes.len()];
    for m in &members {
        assignment[m.0 as usize] = 0;
    }
    let p = Plan {
        mode: FusionMode::Flashlight,
        groups: vec![KernelGroup {
            nodes: members,
            kind: GroupKind::Pipeline(Pipeline {
                m1: s,
                score_root: sn,
                softmax: None,
                m2: o,
                out: o,
                q_class,
                kv_class,
                mask: None,
            }),
        }],
        assignment,
        log: vec![RewriteEvent {
            rule: Rule::AlgebraicOnline,
            at: w,
        }],
    };
    let diags = p.verify(&g).unwrap_err();
    assert!(
        diags
            .iter()
            .any(|d| d.check == CheckClass::Determinism && d.node == Some(w)),
        "expected a determinism diagnostic at the fused sum reduction:\n{}",
        render(&diags)
    );
    // The trail event claiming an online-softmax rewrite at the sum is
    // unaccounted too (there are no softmax roles to bless it).
    assert!(
        diags
            .iter()
            .filter(|d| d.check == CheckClass::Determinism)
            .count()
            >= 2,
        "expected both the member scan and the trail walk to fire:\n{}",
        render(&diags)
    );
}

// ---------------------------------------------------------------------
// Check 4: mask-skip soundness
// ---------------------------------------------------------------------

fn masked_pipeline(g: &flashlight::ir::Graph, p: &Plan) -> Pipeline {
    p.groups
        .iter()
        .find_map(|grp| match &grp.kind {
            GroupKind::Pipeline(pipe) if pipe.mask.is_some() => Some(pipe.clone()),
            _ => None,
        })
        .unwrap_or_else(|| panic!("{}: plan has no masked pipeline", g.name))
}

#[test]
fn undemoted_dead_row_empty_tile_is_caught() {
    let seq = 16usize;
    let shape = AttnShape {
        batch: 1,
        rows: 1,
        heads_q: 2,
        heads_kv: 1,
        seq,
        head_dim: 8,
    };
    let g = build(Variant::DocumentMask, &shape);
    let p = plan(&g, FusionMode::Flashlight);
    let pipe = masked_pipeline(&g, &p);
    let info = pipe.mask.as_ref().unwrap();
    let score_shape = g.node(pipe.score_root).shape.clone();
    let rank = score_shape.len();
    let (q_ax, kv_ax) = (rank - 2, rank - 1);
    // Two-document halves: block-diagonal mask, off-diagonal tiles Empty.
    let halves: Vec<f32> = (0..seq).map(|i| (i * 2 / seq) as f32).collect();
    let mut live = HashMap::new();
    live.insert(
        "doc_q".to_string(),
        Tensor::from_vec(&[1, 1, 1, seq, 1], halves.clone()),
    );
    live.insert(
        "doc_k".to_string(),
        Tensor::from_vec(&[1, 1, 1, 1, seq], halves.clone()),
    );
    let bm = classify_block_mask(&g, info, &score_shape, q_ax, kv_ax, 4, 4, &live)
        .expect("document mask is classifiable with doc inputs supplied");
    assert!(bm.skipped_tiles() > 0, "block-diagonal mask must skip tiles");
    assert!(
        verify_block_mask(&g, info, &bm, &score_shape, q_ax, kv_ax, &live).is_empty(),
        "classes re-derived from the same inputs must verify clean"
    );
    // Adversarial inputs: the first q-tile's rows get a doc id matching
    // no key at all — those rows are fully dead, so the Empty tiles in
    // that q-tile may no longer be skipped (dead-row demotion rule).
    let mut dead = halves.clone();
    for r in dead.iter_mut().take(4) {
        *r = 777.0;
    }
    let mut adv = HashMap::new();
    adv.insert(
        "doc_q".to_string(),
        Tensor::from_vec(&[1, 1, 1, seq, 1], dead),
    );
    adv.insert(
        "doc_k".to_string(),
        Tensor::from_vec(&[1, 1, 1, 1, seq], halves),
    );
    let diags = verify_block_mask(&g, info, &bm, &score_shape, q_ax, kv_ax, &adv);
    assert!(
        diags.iter().any(|d| d.message.contains("undemoted dead-row")),
        "expected the dead-row demotion violation:\n{}",
        render(&diags)
    );
    assert!(diags.iter().all(|d| d.check == CheckClass::MaskSkip));
}

#[test]
fn forged_tile_classes_are_caught() {
    let shape = AttnShape {
        batch: 1,
        rows: 1,
        heads_q: 2,
        heads_kv: 1,
        seq: 32,
        head_dim: 8,
    };
    let g = build(Variant::Causal, &shape);
    let p = plan(&g, FusionMode::Flashlight);
    let pipe = masked_pipeline(&g, &p);
    let info = pipe.mask.as_ref().unwrap();
    assert!(info.is_input_free(), "causal mask is an input-free index mask");
    let score_shape = g.node(pipe.score_root).shape.clone();
    let rank = score_shape.len();
    let (q_ax, kv_ax) = (rank - 2, rank - 1);
    let none = HashMap::new();
    let bm = classify_block_mask(&g, info, &score_shape, q_ax, kv_ax, 8, 8, &none)
        .expect("causal mask is classifiable");
    assert!(
        verify_block_mask(&g, info, &bm, &score_shape, q_ax, kv_ax, &none).is_empty(),
        "honest causal classification must verify clean"
    );
    // Forge 1: claim the fully-dead upper-right corner tile Full — the
    // executor would elide the mask over dead positions.
    let mut forged = bm.clone();
    forged.override_class(0, 0, forged.n_k_tiles - 1, TileClass::Full);
    let diags = verify_block_mask(&g, info, &forged, &score_shape, q_ax, kv_ax, &none);
    assert!(
        diags.iter().any(|d| d.message.contains("Full tile")),
        "expected the unsound mask-elision diagnostic:\n{}",
        render(&diags)
    );
    // Forge 2: claim the fully-live lower-left tile Empty — the skip
    // would silently drop live attention weight.
    let mut forged = bm.clone();
    forged.override_class(0, forged.n_q_tiles - 1, 0, TileClass::Empty);
    let diags = verify_block_mask(&g, info, &forged, &score_shape, q_ax, kv_ax, &none);
    assert!(
        diags.iter().any(|d| d.message.contains("Empty tile")),
        "expected the unsound skip diagnostic:\n{}",
        render(&diags)
    );
}

// ---------------------------------------------------------------------
// Amortization: verification runs once per shape bucket, on the miss
// path only (mirrors the analyze_call_count gate).
// ---------------------------------------------------------------------

#[test]
fn plan_cache_verifies_once_per_shape_bucket() {
    set_verify_override(Some(VerifyMode::Strict));
    let before = verify_calls_on_this_thread();
    let mut cache = PlanCache::with_block_k(8, 64);
    let shape = AttnShape {
        batch: 1,
        rows: 1,
        heads_q: 2,
        heads_kv: 1,
        seq: 128,
        head_dim: 16,
    };
    let key = PlanKey {
        tag: "verify-test",
        variant: Variant::Causal.name(),
        heads_q: 2,
        heads_kv: 1,
        head_dim: 16,
        q_len: 1,
        kv_len: 128,
    };
    let _ = cache.get_or_build(key.clone(), || build_serving(Variant::Causal, &shape, 1));
    assert_eq!(
        verify_calls_on_this_thread(),
        before + 1,
        "one miss = exactly one verification"
    );
    for _ in 0..100 {
        let _ = cache.get_or_build(key.clone(), || unreachable!("cache hit must not rebuild"));
    }
    assert_eq!(
        verify_calls_on_this_thread(),
        before + 1,
        "steady-state hits must do zero verify work"
    );
    set_verify_override(None);
}

// ---------------------------------------------------------------------
// Mode resolution
// ---------------------------------------------------------------------

#[test]
fn verify_mode_resolution() {
    assert_eq!(resolve_verify(Some("strict")), VerifyMode::Strict);
    assert_eq!(resolve_verify(Some("0")), VerifyMode::Off);
    assert_eq!(resolve_verify(Some("off")), VerifyMode::Off);
    assert_eq!(resolve_verify(Some("1")), VerifyMode::Warn);
    let unset_default = if cfg!(debug_assertions) {
        VerifyMode::Warn
    } else {
        VerifyMode::Off
    };
    assert_eq!(resolve_verify(None), unset_default);
}
