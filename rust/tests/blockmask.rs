//! Property tests for the block-sparse tile planner.
//!
//! Two contracts (see `rust/src/fusion/blockmask.rs`):
//!
//! 1. **Classification is exact**: for every index-mask variant, the
//!    planner's per-(q-tile, k-tile) `Full/Partial/Empty` classes match
//!    a brute-force evaluation of the variant's keep predicate over odd
//!    shapes and ragged tails.
//! 2. **Skipping is invisible**: sparse execution (Empty tiles skipped,
//!    Full tiles' mask ops elided) is bit-identical to the dense
//!    `FLASHLIGHT_BLOCKMASK=0` path — outputs AND traffic counters — at
//!    1, 2, and 3 threads, while actually skipping work
//!    (`tiles_skipped > 0`, fewer FLOPs).
//!
//! Plus the runtime data-dependent path: `Variant::Rectified`'s
//! threshold mask must prune tiles from the *data* (no static class
//! grid exists) and still match the unpruned reference.

use std::collections::HashMap;

use flashlight::exec::{execute_plan, execute_plan_par, Counters, Parallelism, Tensor};
use flashlight::fusion::{
    classify_block_mask, extract_mask, plan, set_blockmask_override, FusionMode, MaskInfo,
    MaskKind, TileClass, TileConfig,
};
use flashlight::ir::{Graph, NodeId, Op};
use flashlight::variants::{build, AttnShape, Variant};

fn shape(seq: usize) -> AttnShape {
    AttnShape {
        batch: 1,
        rows: 1,
        heads_q: 2,
        heads_kv: 2,
        seq,
        head_dim: 8,
    }
}

/// Deterministic inputs; document ids are `j * 3 / n` (three ragged
/// documents), matching the id layout the doc-mask brute force assumes.
fn inputs_for(g: &Graph, seed: u64) -> HashMap<String, Tensor> {
    let mut m = HashMap::new();
    for (i, &id) in g.inputs.iter().enumerate() {
        let node = g.node(id);
        let Op::Input { name } = &node.op else { unreachable!() };
        let t = if name.starts_with("doc") {
            let n: usize = node.shape.iter().product();
            Tensor::from_vec(&node.shape, (0..n).map(|j| (j * 3 / n) as f32).collect())
        } else {
            Tensor::synthetic(&node.shape, seed + i as u64)
        };
        m.insert(name.clone(), t);
    }
    m
}

/// The unique maskable `Where` at a variant graph's score root.
fn mask_root(g: &Graph) -> (NodeId, MaskInfo) {
    for id in g.ids() {
        if let Some(info) = extract_mask(g, id) {
            return (id, info);
        }
    }
    panic!("graph has no maskable score root");
}

/// The variant's keep predicate, reimplemented independently of the IR.
fn brute_keep(v: &Variant, qi: usize, ki: usize, doc: &[usize]) -> bool {
    match v {
        Variant::Causal => ki <= qi,
        Variant::SlidingWindow { window } => ki <= qi && qi - ki <= *window,
        Variant::PrefixLm { prefix } => ki <= qi || ki < *prefix,
        Variant::DocumentMask => doc[qi] == doc[ki],
        other => panic!("not an index-mask variant: {other:?}"),
    }
}

/// Index-mask variants exercised throughout, sized for `seq`.
fn index_variants(seq: usize) -> Vec<Variant> {
    vec![
        Variant::Causal,
        Variant::SlidingWindow { window: seq / 4 },
        Variant::PrefixLm { prefix: seq / 3 },
        Variant::DocumentMask,
    ]
}

/// Contract 1: planner classification == brute-force predicate scan,
/// over prime/odd sequence lengths (ragged tail tiles) and asymmetric
/// block shapes, including the fully-dead-row demotion rule.
#[test]
fn classification_matches_brute_force_over_odd_shapes() {
    for seq in [17usize, 23, 48] {
        for (bq, bk) in [(8usize, 8usize), (16, 8), (8, 16)] {
            for v in index_variants(seq) {
                let s = shape(seq);
                let g = build(v, &s);
                let inputs = inputs_for(&g, 7);
                let (root, info) = mask_root(&g);
                assert!(
                    matches!(info.kind, MaskKind::Index { .. }),
                    "{} must extract as an index mask",
                    v.name()
                );
                let score_shape = g.node(root).shape.clone();
                let rank = score_shape.len();
                let bm = classify_block_mask(
                    &g,
                    &info,
                    &score_shape,
                    rank - 2,
                    rank - 1,
                    bq,
                    bk,
                    &inputs,
                )
                .expect("index mask must classify");
                // batch == 1: at most one dep combination.
                assert_eq!(bm.n_deps(), 1, "{}", v.name());

                let doc: Vec<usize> = (0..seq).map(|j| j * 3 / seq).collect();
                let keep = |qi: usize, ki: usize| brute_keep(&v, qi, ki, &doc);
                let (bq_c, bk_c) = (bq.min(seq), bk.min(seq));
                for qt in 0..bm.n_q_tiles {
                    let q0 = qt * bq_c;
                    let cq = bq_c.min(seq - q0);
                    let dead_row =
                        (q0..q0 + cq).any(|qi| (0..seq).all(|ki| !keep(qi, ki)));
                    for kt in 0..bm.n_k_tiles {
                        let k0 = kt * bk_c;
                        let ck = bk_c.min(seq - k0);
                        let kept = (q0..q0 + cq)
                            .flat_map(|qi| (k0..k0 + ck).map(move |ki| (qi, ki)))
                            .filter(|&(qi, ki)| keep(qi, ki))
                            .count();
                        let want = if kept == cq * ck {
                            TileClass::Full
                        } else if kept == 0 && !dead_row {
                            TileClass::Empty
                        } else {
                            TileClass::Partial
                        };
                        assert_eq!(
                            bm.class(0, qt, kt),
                            want,
                            "{} seq={seq} bq={bq} bk={bk} tile ({qt},{kt})",
                            v.name()
                        );
                    }
                }
            }
        }
    }
}

/// Run one graph dense (override off) then sparse (override on),
/// asserting bitwise-equal outputs at 1/2/3 threads and returning
/// (dense counters, sparse counters).
fn dense_vs_sparse(
    g: &Graph,
    inputs: &HashMap<String, Tensor>,
    tile: TileConfig,
    label: &str,
) -> (Counters, Counters) {
    let p = plan(g, FusionMode::Flashlight);
    set_blockmask_override(Some(false));
    let (dense_out, dense_c) = execute_plan(g, &p, inputs, tile);
    set_blockmask_override(Some(true));
    let (sparse_out, sparse_c) = execute_plan(g, &p, inputs, tile);
    for threads in [2usize, 3] {
        let par = Parallelism::with_threads(threads);
        let (o, c) = execute_plan_par(g, &p, inputs, tile, &par);
        assert_eq!(o, sparse_out, "{label}: sparse unstable at threads={threads}");
        assert_eq!(c, sparse_c, "{label}: counters unstable at threads={threads}");
    }
    set_blockmask_override(None);
    assert_eq!(dense_out.len(), sparse_out.len(), "{label}");
    for (i, (d, s)) in dense_out.iter().zip(&sparse_out).enumerate() {
        assert_eq!(d.shape, s.shape, "{label} out[{i}]");
        assert!(
            d.data == s.data,
            "{label} out[{i}]: sparse not bit-identical to dense"
        );
    }
    (dense_c, sparse_c)
}

/// Contract 2: every index-mask variant executes bit-identically with
/// the block-mask layer on, while provably skipping tiles and FLOPs.
/// Ragged tails (seq 44 vs block 16) ride along.
#[test]
fn sparse_execution_is_bit_identical_to_dense() {
    for seq in [32usize, 44] {
        for v in index_variants(seq) {
            let s = shape(seq);
            let g = build(v, &s);
            let inputs = inputs_for(&g, 11);
            let tile = TileConfig {
                block_q: 16,
                block_k: 8,
                ..Default::default()
            };
            let label = format!("{} seq={seq}", v.name());
            let (dense_c, sparse_c) = dense_vs_sparse(&g, &inputs, tile, &label);
            assert!(sparse_c.tiles_skipped > 0, "{label}: no tiles skipped");
            assert!(sparse_c.tiles_visited > 0, "{label}: nothing visited?");
            assert!(sparse_c.flops < dense_c.flops, "{label}: no FLOPs saved");
            assert!(sparse_c.flops_avoided > 0, "{label}");
            assert!(sparse_c.bytes_skipped > 0, "{label}");
            // Traffic may only shrink; writes are mask-independent.
            assert!(sparse_c.l2_read <= dense_c.l2_read, "{label}");
            assert!(sparse_c.hbm_read <= dense_c.hbm_read, "{label}");
            assert_eq!(sparse_c.hbm_write, dense_c.hbm_write, "{label}");
            // The dense run never consults the block-mask machinery.
            assert_eq!(dense_c.tiles_skipped, 0, "{label}");
            assert_eq!(dense_c.flops_avoided, 0, "{label}");
        }
    }
}

/// An unmasked variant must be untouched by the layer (no mask, no
/// skips, identical FLOPs); a masked variant with a non-trivial score
/// subgraph (Softcap's tanh) exercises Full-tile elision — the `Where`
/// and fill are dropped but the softcapped value must still be
/// computed bit-identically.
#[test]
fn no_mask_is_a_no_op_and_full_tile_elision_is_exact() {
    let tile = TileConfig {
        block_q: 8,
        block_k: 8,
        ..Default::default()
    };
    let s = shape(32);

    let g = build(Variant::Vanilla, &s);
    let inputs = inputs_for(&g, 5);
    let (dense_c, sparse_c) = dense_vs_sparse(&g, &inputs, tile, "vanilla");
    assert_eq!(sparse_c.tiles_skipped, 0, "vanilla has nothing to skip");
    assert_eq!(dense_c.flops, sparse_c.flops, "vanilla must be untouched");

    // Softcap is causally masked: below-diagonal tiles are Full and
    // elide the mask, above-diagonal tiles are Empty and skip.
    let g = build(Variant::Softcap { cap: 20.0 }, &s);
    let inputs = inputs_for(&g, 5);
    let (dense_c, sparse_c) = dense_vs_sparse(&g, &inputs, tile, "softcap");
    assert!(sparse_c.tiles_skipped > 0, "causal softcap must skip");
    assert!(sparse_c.flops < dense_c.flops);
}

/// Runtime data-dependent block mask: `Rectified`'s threshold predicate
/// cannot be classified statically (no `BlockMask` exists), yet the
/// executor prunes tiles from the score data at runtime. Inputs are
/// crafted so the k-range splits into a provably-live head (scores
/// >> tau) and a provably-dead tail (scores 0 < tau): pruning must
/// trigger, and the result must match the unpruned reference exactly
/// (a fully sub-threshold tile is an exact no-op in the dense path
/// too, so even bit-identity holds).
#[test]
fn rectified_threshold_prunes_at_runtime() {
    let seq = 32usize;
    let (bq, bk) = (8usize, 8usize);
    let s = shape(seq);
    let g = build(Variant::Rectified { tau: 0.05 }, &s);
    let mut inputs = inputs_for(&g, 13);

    // q strictly positive so q.k^T over the crafted K is controlled.
    let q = inputs.get_mut("q").expect("rectified graph has a q input");
    q.data.iter_mut().for_each(|x| *x = x.abs() + 0.5);
    // K rows: first k-block all-ones (scores well above tau -> every
    // row live after block 0), last k-block all-zeros (scores exactly
    // 0 < tau after scaling -> dead, prunable).
    let k = inputs.get_mut("k").expect("rectified graph has a k input");
    let d = s.head_dim;
    for (j, x) in k.data.iter_mut().enumerate() {
        let pos = (j / d) % seq;
        if pos < bk {
            *x = 1.0;
        } else if pos >= seq - bk {
            *x = 0.0;
        }
    }

    // Static classification must refuse a threshold mask...
    let (root, info) = mask_root(&g);
    assert!(matches!(info.kind, MaskKind::Threshold { .. }));
    let score_shape = g.node(root).shape.clone();
    let rank = score_shape.len();
    assert!(
        classify_block_mask(&g, &info, &score_shape, rank - 2, rank - 1, bq, bk, &inputs)
            .is_none(),
        "threshold masks have no static class grid"
    );

    // ...so any skipped tile below is decided at runtime, from data.
    let tile = TileConfig {
        block_q: bq,
        block_k: bk,
        ..Default::default()
    };
    let (dense_c, sparse_c) = dense_vs_sparse(&g, &inputs, tile, "rectified");
    assert!(
        sparse_c.tiles_skipped > 0,
        "crafted dead k-tail must be pruned at runtime"
    );
    assert_eq!(dense_c.tiles_skipped, 0);
    assert!(sparse_c.flops < dense_c.flops);
}

/// The kill switch semantics behind the overrides: `0`/`off` disable.
#[test]
fn kill_switch_parses() {
    use flashlight::fusion::resolve_blockmask;
    assert!(resolve_blockmask(None));
    assert!(resolve_blockmask(Some("1")));
    assert!(!resolve_blockmask(Some("0")));
    assert!(!resolve_blockmask(Some("off")));
}
