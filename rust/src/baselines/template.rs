//! Executable FlexAttention substrate — the paper's Listing 2 / §2.2 as
//! a real system, not just a cost model.
//!
//! FlexAttention's programming model (Eq. 4):
//!
//! ```text
//! FlexAttention(Q, K, V, score_mod) = softmax(score_mod(QKᵀ/√d)) V
//! ```
//!
//! * `score_mod(score, b, h, q, kv)` — element-wise score rewrite.
//! * `mask_mod(b, h, q, kv) -> bool` — the special case: index-only
//!   (it "only depends on the shape of Q and K"), inspected *ahead of
//!   time* by [`create_block_mask`] into a sparse [`BlockMask`] that
//!   classifies each (q-block, kv-block) tile as Full / Partial / Empty.
//!   The templatized kernel skips Empty blocks, applies the mask only on
//!   Partial blocks, and runs the fast dense path on Full blocks.
//!
//! The API is *structurally* restricted exactly like the original:
//! `mask_mod` receives indices only, so data-dependent masks (e.g. the
//! `rectified` variant) are inexpressible — the generality gap Flashlight
//! closes (§3.8).

use std::collections::HashMap;

use crate::exec::{Counters, Tensor};
use crate::fusion::OnlineRowState;

/// Element-wise score modification: (score, b, h, q, kv) -> score.
pub type ScoreMod<'a> = &'a dyn Fn(f32, usize, usize, usize, usize) -> f32;

/// Index-only mask: (b, h, q, kv) -> keep? (the paper's `mask_mod`).
pub type MaskMod<'a> = &'a dyn Fn(usize, usize, usize, usize) -> bool;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockClass {
    Empty,
    Partial,
    Full,
}

/// The sparse block-mask representation `create_block_mask` builds
/// (stored "in device memory" — its bytes are charged to the kernel's
/// traffic when executing).
#[derive(Debug, Clone)]
pub struct BlockMask {
    pub block: usize,
    pub nq: usize,
    pub nkv: usize,
    /// Row-major (q-block, kv-block) classification.
    pub classes: Vec<BlockClass>,
    /// Work spent building it (the inspection pass the paper shows
    /// dominating FlexAttention end-to-end when not amortized).
    pub creation: Counters,
}

impl BlockMask {
    pub fn class(&self, qb: usize, kb: usize) -> BlockClass {
        self.classes[qb * self.nkv + kb]
    }

    /// Fraction of blocks that must be computed (Full + Partial).
    pub fn compute_fraction(&self) -> f64 {
        let kept = self
            .classes
            .iter()
            .filter(|c| !matches!(c, BlockClass::Empty))
            .count();
        kept as f64 / self.classes.len() as f64
    }

    pub fn counts(&self) -> (usize, usize, usize) {
        let mut f = 0;
        let mut p = 0;
        let mut e = 0;
        for c in &self.classes {
            match c {
                BlockClass::Full => f += 1,
                BlockClass::Partial => p += 1,
                BlockClass::Empty => e += 1,
            }
        }
        (f, p, e)
    }

    /// Device bytes the kernel must fetch to consult the mask.
    pub fn device_bytes(&self) -> u64 {
        (self.classes.len() as u64) * 4 // kv-indices/kv-num tables
    }
}

/// Inspect `mask_mod` densely over the (S, S) index grid and classify
/// each block — the expensive pass `create_block_mask` runs (§2.2/§3.8).
pub fn create_block_mask(mask: MaskMod, s_q: usize, s_kv: usize, block: usize) -> BlockMask {
    let nq = s_q.div_ceil(block);
    let nkv = s_kv.div_ceil(block);
    let mut classes = Vec::with_capacity(nq * nkv);
    let mut creation = Counters::default();
    for qb in 0..nq {
        for kb in 0..nkv {
            let (q0, q1) = (qb * block, (qb * block + block).min(s_q));
            let (k0, k1) = (kb * block, (kb * block + block).min(s_kv));
            let mut kept = 0usize;
            let total = (q1 - q0) * (k1 - k0);
            for q in q0..q1 {
                for kv in k0..k1 {
                    if mask(0, 0, q, kv) {
                        kept += 1;
                    }
                }
            }
            creation.flops += total as u64; // one mask_mod eval per point
            classes.push(if kept == 0 {
                BlockClass::Empty
            } else if kept == total {
                BlockClass::Full
            } else {
                BlockClass::Partial
            });
        }
    }
    // dense bool mask materialized + block tables written, host synced
    creation.hbm_write += (s_q * s_kv) as u64 + 4 * (nq * nkv) as u64;
    creation.launches += 6;
    BlockMask {
        block,
        nq,
        nkv,
        classes,
        creation,
    }
}

/// LRU-ish cache for block masks keyed on (mask identity, shape) — the
/// `create_block_mask_cached` pattern of Listing 2.
#[derive(Default)]
pub struct MaskCache {
    map: HashMap<(usize, usize, usize), BlockMask>,
    pub hits: usize,
    pub misses: usize,
}

impl MaskCache {
    pub fn get_or_build(
        &mut self,
        mask_id: usize,
        mask: MaskMod,
        s_q: usize,
        s_kv: usize,
        block: usize,
    ) -> &BlockMask {
        let key = (mask_id, s_q, s_kv);
        if self.map.contains_key(&key) {
            self.hits += 1;
        } else {
            self.misses += 1;
            let bm = create_block_mask(mask, s_q, s_kv, block);
            self.map.insert(key, bm);
        }
        self.map.get(&key).unwrap()
    }
}

/// The templatized kernel: tiled attention that consults the block mask
/// (skip Empty, mask Partial, fast-path Full) and applies `score_mod`
/// element-wise. Returns the output plus the work/traffic counters of
/// the execution (Empty blocks cost nothing — the skipping the paper
/// credits for Flex's kernel-time wins on mask variants).
pub fn flex_attention(
    q: &Tensor, // (B, H, S, D)
    k: &Tensor,
    v: &Tensor,
    score_mod: Option<ScoreMod>,
    block_mask: Option<(&BlockMask, MaskMod)>,
    sm_scale: f32,
) -> (Tensor, Counters) {
    let (b, h, s, d) = (q.shape[0], q.shape[1], q.shape[2], q.shape[3]);
    assert_eq!(k.shape, q.shape, "template supports MHA q/k/v same shape");
    let block = block_mask.map(|(m, _)| m.block).unwrap_or(64.min(s));
    let nq = s.div_ceil(block);
    let nkv = s.div_ceil(block);
    let mut out = Tensor::zeros(&q.shape);
    let mut c = Counters {
        launches: 1,
        ..Default::default()
    };
    c.read_elems(q.numel());
    if let Some((m, _)) = block_mask {
        c.hbm_read += m.device_bytes(); // fetch the mask tables
    }

    let mut scores = vec![0f32; block];
    for bi in 0..b {
        for hi in 0..h {
            let base = (bi * h + hi) * s * d;
            for qb in 0..nq {
                let q0 = qb * block;
                let q1 = (q0 + block).min(s);
                let mut rows: Vec<OnlineRowState> =
                    (q0..q1).map(|_| OnlineRowState::new(d)).collect();
                for kb in 0..nkv {
                    let class = block_mask
                        .map(|(m, _)| m.class(qb, kb))
                        .unwrap_or(BlockClass::Full);
                    if class == BlockClass::Empty {
                        continue; // skipped: no compute, no kv traffic
                    }
                    let k0 = kb * block;
                    let k1 = (k0 + block).min(s);
                    c.read_elems(2 * (k1 - k0) * d); // k + v tiles
                    for (r, qi) in (q0..q1).enumerate() {
                        let q_row = &q.data[base + qi * d..base + (qi + 1) * d];
                        scores.clear();
                        for kv in k0..k1 {
                            let k_row = &k.data[base + kv * d..base + (kv + 1) * d];
                            let mut sc: f32 = q_row
                                .iter()
                                .zip(k_row)
                                .map(|(x, y)| x * y)
                                .sum::<f32>()
                                * sm_scale;
                            if let Some(f) = score_mod {
                                sc = f(sc, bi, hi, qi, kv);
                            }
                            if class == BlockClass::Partial {
                                // re-evaluate mask_mod on partial blocks
                                // only — the template's key optimization
                                // (Full blocks skip it entirely).
                                let (_, mask) = block_mask.unwrap();
                                if !mask(bi, hi, qi, kv) {
                                    sc = f32::NEG_INFINITY;
                                }
                                c.flops += 1;
                            }
                            scores.push(sc);
                        }
                        c.flops += (2 * (k1 - k0) * d + 4 * (k1 - k0)) as u64;
                        let v_tile = &v.data[base + k0 * d..base + k1 * d];
                        rows[r].update(&scores, v_tile);
                        c.flops += (2 * (k1 - k0) * d) as u64;
                    }
                }
                for (r, qi) in (q0..q1).enumerate() {
                    let o = rows[r].clone().finish();
                    out.data[base + qi * d..base + (qi + 1) * d].copy_from_slice(&o);
                }
                c.write_elems((q1 - q0) * d);
            }
        }
    }
    (out, c)
}

/// Mask + score-mod helpers for the paper's variants, written against
/// the template API exactly like Listing 2 writes sliding-window.
pub mod mods {
    /// `causal_mask(b, h, q, kv) = kv <= q`
    pub fn causal(_b: usize, _h: usize, q: usize, kv: usize) -> bool {
        kv <= q
    }

    pub fn sliding_window(window: usize) -> impl Fn(usize, usize, usize, usize) -> bool {
        move |_b, _h, q, kv| kv <= q && q - kv <= window
    }

    pub fn prefix_lm(prefix: usize) -> impl Fn(usize, usize, usize, usize) -> bool {
        move |_b, _h, q, kv| kv <= q || kv < prefix
    }

    /// Document mask over a captured doc-id table (index-only: the ids
    /// are fixed at mask-construction time, like FlexAttention closures
    /// over tensors).
    pub fn document(doc: Vec<usize>) -> impl Fn(usize, usize, usize, usize) -> bool {
        move |_b, _h, q, kv| doc[q] == doc[kv]
    }

    /// ALiBi as a `score_mod` (Listing-2-style element-wise rewrite).
    pub fn alibi(num_heads: usize) -> impl Fn(f32, usize, usize, usize, usize) -> f32 {
        move |s, _b, h, q, kv| {
            let slope = (2.0f32).powf(-8.0 * (h as f32 + 1.0) / num_heads as f32);
            if kv <= q {
                s - slope * (q - kv) as f32
            } else {
                f32::NEG_INFINITY
            }
        }
    }

    pub fn softcap(cap: f32) -> impl Fn(f32, usize, usize, usize, usize) -> f32 {
        move |s, _b, _h, q, kv| {
            if kv <= q {
                cap * (s / cap).tanh()
            } else {
                f32::NEG_INFINITY
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::eval;
    use crate::variants::{build, AttnShape, Variant};

    fn qkv(s: usize, d: usize, h: usize) -> (Tensor, Tensor, Tensor) {
        (
            Tensor::synthetic(&[1, h, s, d], 1),
            Tensor::synthetic(&[1, h, s, d], 2),
            Tensor::synthetic(&[1, h, s, d], 3),
        )
    }

    /// Reference via the compiler's own variant graphs (MHA: the 5-D
    /// layout is [1, H, 1, S, D] with group=1).
    fn reference(variant: Variant, s: usize, d: usize, h: usize) -> Tensor {
        let shape = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: h,
            heads_kv: h,
            seq: s,
            head_dim: d,
        };
        let g = build(variant, &shape);
        let mut inputs = std::collections::HashMap::new();
        let (q, k, v) = qkv(s, d, h);
        // 5-D [1, H, 1, S, D] reshape of the same data
        inputs.insert("q".into(), Tensor::from_vec(&[1, h, 1, s, d], q.data));
        inputs.insert("k".into(), Tensor::from_vec(&[1, h, 1, s, d], k.data));
        inputs.insert("v".into(), Tensor::from_vec(&[1, h, 1, s, d], v.data));
        let (outs, _) = eval(&g, &inputs);
        Tensor::from_vec(&[1, h, s, d], outs[0].data.clone())
    }

    #[test]
    fn block_mask_classification_causal() {
        let bm = create_block_mask(&mods::causal, 256, 256, 64);
        let (f, p, e) = bm.counts();
        // 4x4 blocks: diagonal partial, lower-left full, upper-right empty
        assert_eq!(p, 4);
        assert_eq!(f, 6);
        assert_eq!(e, 6);
        assert!((bm.compute_fraction() - 10.0 / 16.0).abs() < 1e-9);
    }

    #[test]
    fn template_matches_reference_causal_and_window() {
        let (s, d, h) = (64usize, 16usize, 2usize);
        let (q, k, v) = qkv(s, d, h);
        let scale = 1.0 / (d as f32).sqrt();

        let bm = create_block_mask(&mods::causal, s, s, 16);
        let (out, c) = flex_attention(&q, &k, &v, None, Some((&bm, &mods::causal)), scale);
        let want = reference(Variant::Causal, s, d, h);
        assert!(
            out.allclose(&want, 1e-5),
            "causal diverges by {}",
            out.max_abs_diff(&want)
        );
        assert!(c.flops > 0);

        let win = mods::sliding_window(8);
        let bm = create_block_mask(&win, s, s, 16);
        let (out, _) = flex_attention(&q, &k, &v, None, Some((&bm, &win)), scale);
        let want = reference(Variant::SlidingWindow { window: 8 }, s, d, h);
        assert!(
            out.allclose(&want, 1e-5),
            "window diverges by {}",
            out.max_abs_diff(&want)
        );
    }

    #[test]
    fn template_matches_reference_score_mods() {
        let (s, d, h) = (32usize, 8usize, 4usize);
        let (q, k, v) = qkv(s, d, h);
        let scale = 1.0 / (d as f32).sqrt();
        let alibi = mods::alibi(h);
        let (out, _) = flex_attention(&q, &k, &v, Some(&alibi), None, scale);
        let want = reference(Variant::Alibi, s, d, h);
        assert!(
            out.allclose(&want, 1e-5),
            "alibi diverges by {}",
            out.max_abs_diff(&want)
        );
        let sc = mods::softcap(15.0);
        let (out, _) = flex_attention(&q, &k, &v, Some(&sc), None, scale);
        let want = reference(Variant::Softcap { cap: 15.0 }, s, d, h);
        assert!(
            out.allclose(&want, 1e-5),
            "softcap diverges by {}",
            out.max_abs_diff(&want)
        );
    }

    #[test]
    fn empty_blocks_are_skipped_proportionally_to_density() {
        let (s, d, h) = (128usize, 8usize, 1usize);
        let (q, k, v) = qkv(s, d, h);
        let win = mods::sliding_window(8);
        let bm = create_block_mask(&win, s, s, 16);
        let (_, c_sparse) = flex_attention(&q, &k, &v, None, Some((&bm, &win)), 1.0);
        let (_, c_dense) = flex_attention(&q, &k, &v, None, None, 1.0);
        let ratio = c_sparse.flops as f64 / c_dense.flops as f64;
        let frac = bm.compute_fraction();
        assert!(
            (ratio - frac).abs() < 0.1,
            "work ratio {ratio} vs block fraction {frac}"
        );
        assert!(c_sparse.hbm_read < c_dense.hbm_read);
    }

    #[test]
    fn measured_block_density_validates_analytic_model() {
        // The cost model's Variant::density must agree with the real
        // inspection at block granularity (within block quantization).
        let cases: Vec<(Variant, Box<dyn Fn(usize, usize, usize, usize) -> bool>)> = vec![
            (Variant::Causal, Box::new(mods::causal)),
            (
                Variant::SlidingWindow { window: 256 },
                Box::new(mods::sliding_window(256)),
            ),
            (
                Variant::PrefixLm { prefix: 256 },
                Box::new(mods::prefix_lm(256)),
            ),
        ];
        for (variant, mask) in cases {
            let s = 2048;
            let bm = create_block_mask(&*mask, s, s, 128);
            let measured = bm.compute_fraction();
            let analytic = variant.density(s);
            assert!(
                (measured - analytic).abs() < 0.08,
                "{}: block fraction {measured:.3} vs analytic {analytic:.3}",
                variant.name()
            );
        }
    }

    #[test]
    fn mask_cache_amortizes_same_shapes() {
        let mut cache = MaskCache::default();
        for _ in 0..5 {
            cache.get_or_build(1, &mods::causal, 256, 256, 64);
        }
        cache.get_or_build(1, &mods::causal, 512, 512, 64); // new shape
        assert_eq!(cache.misses, 2);
        assert_eq!(cache.hits, 4);
    }

    #[test]
    fn creation_work_scales_with_s_squared() {
        let a = create_block_mask(&mods::causal, 512, 512, 128).creation;
        let b = create_block_mask(&mods::causal, 2048, 2048, 128).creation;
        let ratio = b.flops as f64 / a.flops as f64;
        assert!((15.0..17.0).contains(&ratio), "S^2 scaling: {ratio}");
    }
}
