//! Baseline system models: FlexAttention, FlashInfer, torch.compile and
//! eager PyTorch (paper §4.1 "Systems").
//!
//! Flashlight and torch.compile estimates are *derived from their actual
//! compiler plans* (this crate's planner + counters). FlexAttention and
//! FlashInfer are modeled on top of the same workload counters with the
//! mechanisms the paper describes:
//!
//! * FlexAttention (templated Triton): `score_mod` variants run the full
//!   dense pipeline but the templatized kernel carries compute/memory
//!   instructions for full/partial/empty block handling (paper: Flashlight
//!   is up to 1.48x faster *because* its kernel is simpler). `mask_mod`
//!   variants skip empty blocks (kernel faster than Flashlight's dense
//!   kernel) but pay `create_block_mask`: an inspection kernel plus host
//!   sync, amortizable only via an LRU cache, and the kernel still fetches
//!   the block mask from device memory.
//! * FlashInfer (JIT CUDA): evaluates sparsity *inline* from scalar
//!   parameters (`causal`, `window_left`) — no mask materialization, no
//!   inspection — with the best-tuned dense pipeline; its ALiBi path
//!   either computes the bias element-wise or streams precomputed slopes
//!   from global memory, paying a per-block read penalty (§4.2).

pub mod template;

use crate::cost::{kernel_time, Efficiency, GpuSpec};
use crate::exec::Counters;
use crate::fusion::{plan, FusionMode, TileConfig};
use crate::variants::{build, AttnShape, Variant};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum System {
    Flashlight,
    FlexAttention { mask_cached: bool },
    FlashInfer,
    TorchCompile,
    Eager,
}

impl System {
    pub fn label(&self) -> &'static str {
        match self {
            System::Flashlight => "flashlight",
            System::FlexAttention { mask_cached: true } => "flexattention(cached)",
            System::FlexAttention { mask_cached: false } => "flexattention",
            System::FlashInfer => "flashinfer",
            System::TorchCompile => "torch.compile",
            System::Eager => "eager",
        }
    }
}

/// Kernel-quality factors (fraction of peak / of bandwidth attained).
pub const EFF_FLASHLIGHT: Efficiency = Efficiency::new(0.55, 0.85);
pub const EFF_FLEX_TEMPLATE: Efficiency = Efficiency::new(0.40, 0.75);
pub const EFF_FLEX_MASKED: Efficiency = Efficiency::new(0.50, 0.80);
pub const EFF_FLASHINFER: Efficiency = Efficiency::new(0.72, 0.90);
pub const EFF_INDUCTOR: Efficiency = Efficiency::new(0.70, 0.85);
/// FlashInfer's ALiBi penalty: per-block global reads of the slope
/// buffer / element-wise bias computation (§4.2).
pub const FLASHINFER_ALIBI_PENALTY: f64 = 1.9;

/// FlexAttention block size for block-mask construction.
pub const FLEX_BLOCK: usize = 128;

#[derive(Debug, Clone, Copy)]
pub struct Estimate {
    /// Attention kernel execution time (s).
    pub kernel_s: f64,
    /// Preparation overhead per call (block-mask creation, plan()).
    pub prep_s: f64,
}

impl Estimate {
    pub fn total(&self) -> f64 {
        self.kernel_s + self.prep_s
    }
}

/// Dense fused-kernel counters for the variant at this shape, from the
/// Flashlight plan (the ground truth the baselines are scaled from).
pub fn fused_counters(variant: Variant, shape: &AttnShape, tile: TileConfig) -> Counters {
    let g = build(variant, shape);
    let p = plan(&g, FusionMode::Flashlight);
    p.counters(&g, tile)
}

/// Scale counters by a visible-block density (kept-block compute and kv
/// traffic only; q/output traffic is unaffected).
fn sparsify(c: &Counters, density: f64) -> Counters {
    let mut out = *c;
    out.flops = (c.flops as f64 * density) as u64;
    // roughly: kv reads dominate pipeline reads; scale reads by density
    out.hbm_read = (c.hbm_read as f64 * density) as u64;
    out.l2_read = (c.l2_read as f64 * density) as u64;
    out
}

/// Block-mask creation cost (`create_block_mask`): evaluates `mask_mod`
/// densely over the full (S, S) index grid (a vmapped Python callable —
/// very low achieved efficiency), reduces it per 128x128 block, writes
/// the sparse block tables, and syncs with the host. This is the cost
/// the paper shows dominating FlexAttention end-to-end when the mask is
/// not amortized by a cache (§4.2, Figs 2/3).
pub fn mask_creation_time(spec: &GpuSpec, s: usize) -> f64 {
    let points = (s * s) as u64;
    let blocks = (s.div_ceil(FLEX_BLOCK) * s.div_ceil(FLEX_BLOCK)) as u64;
    let c = Counters {
        hbm_read: points / 8,
        l2_read: 0,
        hbm_write: points + 8 * blocks, // bool mask + block tables
        flops: 64 * points,             // vmapped mask_mod evaluation
        launches: 6,                    // the multi-kernel inspection path
        peak_workspace: points,
        ..Counters::default()
    };
    spec.mask_host_s + kernel_time(spec, &c, Efficiency::new(0.015, 0.5))
}

/// Estimate one forward attention call for `system` on `variant`.
/// Returns None when the system cannot express the variant (paper §3.8:
/// DiffAttn / Evoformer / data-dependent variants are outside the
/// FlexAttention template and FlashInfer's API).
pub fn estimate_attention(
    system: System,
    variant: Variant,
    shape: &AttnShape,
    spec: &GpuSpec,
    tile: TileConfig,
) -> Option<Estimate> {
    let s = shape.seq;
    match system {
        System::Flashlight => {
            // Dense fused kernel — Flashlight does not exploit block
            // sparsity (§3.8, left to future work).
            let c = fused_counters(variant, shape, tile);
            Some(Estimate {
                kernel_s: kernel_time(spec, &c, EFF_FLASHLIGHT),
                prep_s: 0.0,
            })
        }
        System::TorchCompile | System::Eager => {
            let g = build(variant, shape);
            let mode = if system == System::TorchCompile {
                FusionMode::TorchCompile
            } else {
                FusionMode::Eager
            };
            let c = plan(&g, mode).counters(&g, tile);
            Some(Estimate {
                kernel_s: kernel_time(spec, &c, EFF_INDUCTOR),
                prep_s: 0.0,
            })
        }
        System::FlexAttention { mask_cached } => {
            if !variant.flex_supported() {
                return None;
            }
            let dense = fused_counters(variant, shape, tile);
            if variant.is_mask_variant() {
                // Sparse-block kernel + block-mask fetch traffic.
                let mut c = sparsify(&dense, variant.density(s));
                let blocks =
                    (s.div_ceil(FLEX_BLOCK) * s.div_ceil(FLEX_BLOCK)) as u64;
                c.hbm_read += 8 * blocks * shape.batch as u64;
                let kernel_s = kernel_time(spec, &c, EFF_FLEX_MASKED);
                let prep_s = if mask_cached {
                    0.0
                } else {
                    mask_creation_time(spec, s)
                };
                Some(Estimate { kernel_s, prep_s })
            } else {
                // score_mod path: dense with template overhead.
                Some(Estimate {
                    kernel_s: kernel_time(spec, &dense, EFF_FLEX_TEMPLATE),
                    prep_s: 0.0,
                })
            }
        }
        System::FlashInfer => {
            if !variant.flex_supported() {
                return None;
            }
            let dense = fused_counters(variant, shape, tile);
            let c = if variant.is_mask_variant() {
                sparsify(&dense, variant.density(s))
            } else {
                dense
            };
            let mut kernel_s = kernel_time(spec, &c, EFF_FLASHINFER);
            if matches!(variant, Variant::Alibi) {
                kernel_s *= FLASHINFER_ALIBI_PENALTY;
            }
            Some(Estimate {
                kernel_s,
                prep_s: 12e-6, // plan(): host-side parameter setup
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::h100;

    fn est(sys: System, v: Variant, b: usize, s: usize) -> Option<Estimate> {
        let shape = AttnShape::mha(b, s);
        estimate_attention(sys, v, &shape, &h100(), TileConfig::default())
    }

    #[test]
    fn flashlight_beats_flex_on_score_mod_variants() {
        for v in [
            Variant::Vanilla,
            Variant::Alibi,
            Variant::Softcap { cap: 20.0 },
        ] {
            let fl = est(System::Flashlight, v, 4, 4096).unwrap();
            let fx = est(System::FlexAttention { mask_cached: true }, v, 4, 4096)
                .unwrap();
            let speedup = fx.total() / fl.total();
            assert!(
                speedup > 1.0 && speedup < 1.6,
                "{}: speedup {speedup} out of the paper's band",
                v.name()
            );
        }
    }

    #[test]
    fn flex_kernel_beats_flashlight_on_mask_variants_but_loses_end_to_end() {
        // Paper §4.2: "FlexAttention's Kernel execution is always faster
        // than Flashlight's ... However, FlexAttention's Block-Mask
        // [creation] time is much slower" — Flashlight wins end-to-end
        // across the token-budget sweep (B*S = 16k tokens).
        let v = Variant::Causal;
        for (b, s) in [(32usize, 512usize), (16, 1024), (4, 4096), (1, 16384)] {
            let fl = est(System::Flashlight, v, b, s).unwrap();
            let fx = est(System::FlexAttention { mask_cached: false }, v, b, s)
                .unwrap();
            assert!(
                fx.kernel_s < fl.kernel_s,
                "B={b} S={s}: flex sparse kernel should win"
            );
            assert!(
                fx.total() > fl.total(),
                "B={b} S={s}: mask creation should dominate ({:.0}us vs {:.0}us)",
                fx.total() * 1e6,
                fl.total() * 1e6
            );
        }
        // With a warm mask cache (the serving case, Fig 5) the sparse
        // kernel wins end-to-end — that is why Flex wins Causal serving.
        let fl = est(System::Flashlight, v, 4, 4096).unwrap();
        let fxc = est(System::FlexAttention { mask_cached: true }, v, 4, 4096)
            .unwrap();
        assert!(fxc.total() < fl.total());
    }

    #[test]
    fn flashinfer_fastest_except_alibi() {
        for v in crate::variants::paper_variants() {
            let fi = est(System::FlashInfer, v, 4, 4096).unwrap();
            let fl = est(System::Flashlight, v, 4, 4096).unwrap();
            if matches!(v, Variant::Alibi) {
                assert!(fi.total() > fl.total(), "alibi: flashinfer should lose");
            } else {
                assert!(
                    fi.total() < fl.total(),
                    "{}: flashinfer should win",
                    v.name()
                );
            }
        }
    }

    #[test]
    fn torch_compile_slowest_everywhere() {
        for v in crate::variants::paper_variants() {
            let tc = est(System::TorchCompile, v, 4, 4096).unwrap();
            let fl = est(System::Flashlight, v, 4, 4096).unwrap();
            assert!(
                tc.total() > 2.0 * fl.total(),
                "{}: torch.compile only {}x slower",
                v.name(),
                tc.total() / fl.total()
            );
        }
    }

    #[test]
    fn unsupported_variants_return_none_for_flex_and_flashinfer() {
        let v = Variant::DiffAttn { lambda: 0.5 };
        assert!(est(System::FlexAttention { mask_cached: true }, v, 1, 512).is_none());
        assert!(est(System::FlashInfer, v, 1, 512).is_none());
        assert!(est(System::Flashlight, v, 1, 512).is_some());
        assert!(est(System::TorchCompile, v, 1, 512).is_some());
    }

    #[test]
    fn mask_creation_grows_with_seqlen() {
        let spec = h100();
        assert!(mask_creation_time(&spec, 16384) > mask_creation_time(&spec, 512));
        // but is dominated by the fixed host cost at short seqlens
        let t = mask_creation_time(&spec, 512);
        assert!(t > spec.mask_host_s && t < 2.0 * spec.mask_host_s);
    }

    #[test]
    fn gqa_reduces_traffic_not_flops() {
        // GQA shares kv heads: same attention flops, 8x less kv data.
        // When the kernel is compute-bound the runtimes tie; the traffic
        // advantage must show in the counters.
        let mha = AttnShape::mha(4, 4096);
        let gqa = AttnShape::gqa(4, 4096);
        let cm = fused_counters(Variant::Causal, &mha, TileConfig::default());
        let cg = fused_counters(Variant::Causal, &gqa, TileConfig::default());
        assert_eq!(cm.flops, cg.flops);
        assert!(cg.hbm_read < cm.hbm_read);
        let tm = estimate_attention(
            System::Flashlight,
            Variant::Causal,
            &mha,
            &h100(),
            TileConfig::default(),
        )
        .unwrap();
        let tg = estimate_attention(
            System::Flashlight,
            Variant::Causal,
            &gqa,
            &h100(),
            TileConfig::default(),
        )
        .unwrap();
        assert!(tg.total() <= tm.total());
    }
}
