//! Logical grid dimensions (paper §3.6) and the `blockreduction`
//! autotuning heuristic (§3.7).
//!
//! TorchInductor couples logical tiling dimensions to the physical GPU
//! grid, whose Y/Z extents cap at 65,535 — forcing either a shared tile
//! size (flattening) or a size limit (multi-grid). Flashlight instead
//! defines a *logical* multi-dimensional grid of tiles with independent
//! per-dimension tile sizes, unrolls it into a single physical dimension,
//! and recovers the logical tile coordinates in-kernel with an inverse
//! affine map. The L2-cache swizzle groups blocks into GROUP_M strips.

/// One logical tiled dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TiledDim {
    pub size: usize,
    pub tile: usize,
}

impl TiledDim {
    pub fn n_tiles(&self) -> usize {
        self.size.div_ceil(self.tile)
    }
}

/// A logical multi-dimensional grid of tiles, mapped to one physical
/// grid dimension (CUDA X / `tl.program_id(0)`).
#[derive(Debug, Clone)]
pub struct LogicalGrid {
    pub dims: Vec<TiledDim>,
}

/// CUDA physical grid limits the paper cites: X up to 2^31-1, Y/Z 65,535.
pub const CUDA_MAX_X: usize = (1 << 31) - 1;
pub const CUDA_MAX_YZ: usize = 65_535;

impl LogicalGrid {
    pub fn new(dims: Vec<TiledDim>) -> Self {
        LogicalGrid { dims }
    }

    /// Total number of physical blocks after unrolling.
    pub fn n_blocks(&self) -> usize {
        self.dims.iter().map(|d| d.n_tiles()).product()
    }

    /// Would a naive multi-grid mapping (one logical dim per physical
    /// dim) exceed the hardware's Y/Z limits? (the dilemma of §3.6)
    pub fn multi_grid_mapping_fails(&self) -> bool {
        self.dims.len() > 1
            && self.dims[..self.dims.len() - 1]
                .iter()
                .any(|d| d.n_tiles() > CUDA_MAX_YZ)
    }

    /// Linearize logical tile coordinates to a physical block id
    /// (row-major over the logical grid).
    pub fn linearize(&self, coords: &[usize]) -> usize {
        debug_assert_eq!(coords.len(), self.dims.len());
        let mut id = 0;
        for (c, d) in coords.iter().zip(&self.dims) {
            debug_assert!(*c < d.n_tiles());
            id = id * d.n_tiles() + c;
        }
        id
    }

    /// The in-kernel inverse affine map: physical block id -> logical
    /// tile coordinates.
    pub fn delinearize(&self, mut id: usize) -> Vec<usize> {
        let mut coords = vec![0usize; self.dims.len()];
        for (i, d) in self.dims.iter().enumerate().rev() {
            coords[i] = id % d.n_tiles();
            id /= d.n_tiles();
        }
        coords
    }

    /// Element range covered by tile coordinate `c` of dim `i`.
    pub fn tile_range(&self, i: usize, c: usize) -> (usize, usize) {
        let d = self.dims[i];
        let start = c * d.tile;
        (start, d.tile.min(d.size - start))
    }

    /// Per-block scheduling weights in physical block order: `f` maps a
    /// block id to its work size (e.g. live k-elements under a block
    /// mask). Consumed by the weighted sharding of
    /// [`crate::exec::parallel_map_with_weights`], which cuts topology
    /// shards by cumulative weight so skewed grids still balance.
    pub fn block_weights(&self, f: impl Fn(usize) -> u64) -> Vec<u64> {
        (0..self.n_blocks()).map(f).collect()
    }
}

/// L2-cache swizzle (§3.7): for a 2-D tiled iteration (m_tiles x
/// n_tiles), group blocks into strips of `group_m` rows and serpentine
/// within each strip so adjacent block ids touch adjacent tiles —
/// generalizing Triton's matmul-tutorial swizzle.
pub fn swizzle_2d(m_tiles: usize, n_tiles: usize, group_m: usize, pid: usize) -> (usize, usize) {
    let group_m = group_m.max(1);
    let width = group_m * n_tiles;
    let group_id = pid / width;
    let first_m = group_id * group_m;
    let group_size = group_m.min(m_tiles - first_m);
    let pid_m = first_m + (pid % group_size);
    let pid_n = (pid % width) / group_size;
    (pid_m, pid_n)
}

/// One candidate kernel launch configuration (the paper's
/// `blockreduction` heuristic tunes (XBLOCK, RBLOCK, warps, stages)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaunchConfig {
    pub xblock: usize,
    pub rblock: usize,
    pub num_warps: usize,
    pub num_stages: usize,
}

/// The default `blockreduction` search space; `aggressive` widens it
/// with smaller blocks for low-parallelism workloads (§3.7).
pub fn blockreduction_space(aggressive: bool) -> Vec<LaunchConfig> {
    let xs: &[usize] = if aggressive {
        &[16, 32, 64, 128, 256]
    } else {
        &[64, 128, 256]
    };
    let rs: &[usize] = if aggressive {
        &[16, 32, 64, 128]
    } else {
        &[32, 64]
    };
    let mut out = vec![];
    for &x in xs {
        for &r in rs {
            for &w in &[4usize, 8] {
                for &st in &[2usize, 3] {
                    out.push(LaunchConfig {
                        xblock: x,
                        rblock: r,
                        num_warps: w,
                        num_stages: st,
                    });
                }
            }
        }
    }
    out
}

/// Pick the best launch config by the provided cost function. Scheduler
/// hints (from the blocking analysis) override the search space.
pub fn autotune(
    space: &[LaunchConfig],
    hint: Option<LaunchConfig>,
    mut cost: impl FnMut(LaunchConfig) -> f64,
) -> LaunchConfig {
    if let Some(h) = hint {
        return h;
    }
    *space
        .iter()
        .min_by(|a, b| cost(**a).partial_cmp(&cost(**b)).unwrap())
        .expect("non-empty search space")
}

/// VMEM/SRAM footprint (bytes) of a flash tile: q tile + k/v tiles +
/// score tile + accumulator, fp32. Used both by the autotuner constraint
/// and the DESIGN.md §Perf VMEM estimates for the Pallas kernel.
pub fn flash_tile_footprint(bq: usize, bk: usize, d: usize) -> usize {
    4 * (bq * d + 2 * bk * d + bq * bk + bq * d + 2 * bq)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linearize_delinearize_roundtrip() {
        let g = LogicalGrid::new(vec![
            TiledDim { size: 100, tile: 32 },
            TiledDim { size: 7, tile: 2 },
            TiledDim { size: 64, tile: 64 },
        ]);
        assert_eq!(g.n_blocks(), 4 * 4 * 1);
        for id in 0..g.n_blocks() {
            let c = g.delinearize(id);
            assert_eq!(g.linearize(&c), id);
        }
    }

    #[test]
    fn tile_ranges_cover_dim_exactly() {
        let g = LogicalGrid::new(vec![TiledDim { size: 100, tile: 32 }]);
        let mut covered = 0;
        for c in 0..g.dims[0].n_tiles() {
            let (start, len) = g.tile_range(0, c);
            assert_eq!(start, covered);
            covered += len;
        }
        assert_eq!(covered, 100);
    }

    #[test]
    fn block_weights_cover_all_blocks_in_order() {
        let g = LogicalGrid::new(vec![
            TiledDim { size: 4, tile: 1 },
            TiledDim { size: 100, tile: 32 },
        ]);
        let w = g.block_weights(|b| (b as u64) + 1);
        assert_eq!(w.len(), g.n_blocks());
        assert_eq!(w[0], 1);
        assert_eq!(*w.last().unwrap(), g.n_blocks() as u64);
    }

    #[test]
    fn eliminated_dim_has_one_tile() {
        // §3.5: B_P >= |P| collapses the loop.
        let d = TiledDim { size: 64, tile: 128 };
        assert_eq!(d.n_tiles(), 1);
    }

    #[test]
    fn multi_grid_limit_detection() {
        let big = LogicalGrid::new(vec![
            TiledDim {
                size: 70_000 * 16,
                tile: 16,
            },
            TiledDim { size: 64, tile: 16 },
        ]);
        assert!(big.multi_grid_mapping_fails());
        // but the logical unroll handles it fine
        assert!(big.n_blocks() > CUDA_MAX_YZ);
        let c = big.delinearize(big.n_blocks() - 1);
        assert_eq!(big.linearize(&c), big.n_blocks() - 1);
    }

    #[test]
    fn swizzle_is_a_permutation() {
        let (m, n, gm) = (7, 5, 3);
        let mut seen = std::collections::HashSet::new();
        for pid in 0..m * n {
            let (pm, pn) = swizzle_2d(m, n, gm, pid);
            assert!(pm < m && pn < n, "({pm},{pn})");
            assert!(seen.insert((pm, pn)), "duplicate ({pm},{pn})");
        }
        assert_eq!(seen.len(), m * n);
    }

    #[test]
    fn swizzle_improves_m_locality() {
        // within a strip, consecutive pids share pid_n ranges and walk
        // pid_m first: first group_m pids all have pid_n == 0.
        for pid in 0..3 {
            let (_, pn) = swizzle_2d(8, 8, 3, pid);
            assert_eq!(pn, 0);
        }
    }

    #[test]
    fn autotune_picks_min_cost_and_respects_hint() {
        let space = blockreduction_space(false);
        let best = autotune(&space, None, |c| {
            ((c.xblock as i64 - 128).abs() + (c.rblock as i64 - 64).abs()) as f64
        });
        assert_eq!(best.xblock, 128);
        assert_eq!(best.rblock, 64);
        let hint = LaunchConfig {
            xblock: 16,
            rblock: 16,
            num_warps: 4,
            num_stages: 2,
        };
        assert_eq!(autotune(&space, Some(hint), |_| 0.0), hint);
    }

    #[test]
    fn aggressive_space_is_wider() {
        assert!(blockreduction_space(true).len() > blockreduction_space(false).len());
    }
}
