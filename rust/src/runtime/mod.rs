//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the PJRT CPU client.
//!
//! This is the only place the rust side touches XLA; Python never runs on
//! the request path. Interchange is HLO *text* (see aot.py — serialized
//! protos from jax >= 0.5 are rejected by xla_extension 0.5.1).
//!
//! The XLA-touching half (the [`Engine`], `selftest`) is gated behind
//! the `pjrt` cargo feature because the `xla` crate cannot be built in
//! the offline image; manifest/weights parsing stays always-on.

use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

#[cfg(feature = "pjrt")]
use anyhow::bail;
use anyhow::{Context, Result};

/// Tensor metadata from the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorMeta {
    pub dtype: String, // "f32" | "i32"
    pub shape: Vec<usize>,
}

impl TensorMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn parse(s: &str) -> Result<Self> {
        let (dtype, dims) = s.split_once(':').context("dtype:shape")?;
        let shape = if dims == "0" || dims.is_empty() {
            vec![]
        } else {
            dims.split('x')
                .map(|d| d.parse::<usize>().context("dim"))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorMeta {
            dtype: dtype.to_string(),
            shape,
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    pub meta: HashMap<String, String>,
}

#[derive(Debug, Clone)]
pub struct WeightsMeta {
    pub file: String,
    pub tensors: Vec<(String, Vec<usize>)>,
}

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: HashMap<String, ArtifactMeta>,
    pub weights: HashMap<String, WeightsMeta>,
    pub configs: HashMap<String, HashMap<String, String>>,
}

impl Manifest {
    /// Parse `manifest.txt` (line-based; see aot.py::finish).
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading {}/manifest.txt (run `make artifacts`)", dir.display()))?;
        let mut m = Manifest::default();
        for line in text.lines() {
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("artifact") => {
                    let name = parts.next().context("artifact name")?.to_string();
                    let file = parts.next().context("artifact file")?.to_string();
                    let rest: Vec<&str> = parts.collect();
                    let in_pos = rest.iter().position(|t| *t == "in").context("in")?;
                    let out_pos = rest.iter().position(|t| *t == "out").context("out")?;
                    let meta_pos = rest.iter().position(|t| *t == "meta").unwrap_or(rest.len());
                    let inputs = rest[in_pos + 1..out_pos]
                        .iter()
                        .map(|s| TensorMeta::parse(s))
                        .collect::<Result<Vec<_>>>()?;
                    let outputs = rest[out_pos + 1..meta_pos]
                        .iter()
                        .map(|s| TensorMeta::parse(s))
                        .collect::<Result<Vec<_>>>()?;
                    let mut meta = HashMap::new();
                    for kv in rest.iter().skip(meta_pos + 1) {
                        if let Some((k, v)) = kv.split_once('=') {
                            meta.insert(k.to_string(), v.to_string());
                        }
                    }
                    m.artifacts.insert(
                        name.clone(),
                        ArtifactMeta {
                            name,
                            file,
                            inputs,
                            outputs,
                            meta,
                        },
                    );
                }
                Some("weights") => {
                    let family = parts.next().context("weights family")?.to_string();
                    let file = parts.next().context("weights file")?.to_string();
                    let tensors = parts
                        .map(|t| -> Result<(String, Vec<usize>)> {
                            let (name, dims) = t.rsplit_once(':').context("w shape")?;
                            let shape = dims
                                .split('x')
                                .map(|d| d.parse::<usize>().context("dim"))
                                .collect::<Result<Vec<_>>>()?;
                            Ok((name.to_string(), shape))
                        })
                        .collect::<Result<Vec<_>>>()?;
                    m.weights.insert(family, WeightsMeta { file, tensors });
                }
                Some("config") => {
                    let family = parts.next().context("config family")?.to_string();
                    let mut cfg = HashMap::new();
                    for kv in parts {
                        if let Some((k, v)) = kv.split_once('=') {
                            cfg.insert(k.to_string(), v.to_string());
                        }
                    }
                    m.configs.insert(family, cfg);
                }
                _ => {}
            }
        }
        Ok(m)
    }
}

/// A weight blob loaded from `<family>_weights.bin`, split per tensor.
pub struct Weights {
    pub tensors: Vec<(String, Vec<usize>, Vec<f32>)>,
}

impl Weights {
    #[cfg(feature = "pjrt")]
    pub fn literals(&self) -> Vec<xla::Literal> {
        self.tensors
            .iter()
            .map(|(_, shape, data)| {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims).expect("reshape")
            })
            .collect()
    }
}

/// The PJRT engine: lazily compiles artifacts and executes them.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Engine {
            client,
            dir,
            manifest,
            compiled: HashMap::new(),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.manifest
            .artifacts
            .get(name)
            .with_context(|| format!("unknown artifact {name}"))
    }

    /// Compile (and cache) an artifact.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let meta = self.artifact(name)?.clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with the given input literals. Outputs are the
    /// flattened tuple elements (aot.py lowers with return_tuple=True).
    pub fn run(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.compile(name)?;
        let meta = self.artifact(name)?;
        if inputs.len() != meta.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            );
        }
        let exe = &self.compiled[name];
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Load a weight family from its binary blob.
    pub fn load_weights(&self, family: &str) -> Result<Weights> {
        let meta = self
            .manifest
            .weights
            .get(family)
            .with_context(|| format!("unknown weights {family}"))?;
        let bytes = std::fs::read(self.dir.join(&meta.file))?;
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let mut tensors = vec![];
        let mut off = 0usize;
        for (name, shape) in &meta.tensors {
            let n: usize = shape.iter().product();
            if off + n > floats.len() {
                bail!("weight blob too short for {name}");
            }
            tensors.push((name.clone(), shape.clone(), floats[off..off + n].to_vec()));
            off += n;
        }
        if off != floats.len() {
            bail!("weight blob has {} trailing floats", floats.len() - off);
        }
        Ok(Weights { tensors })
    }

    /// Build a deterministic synthetic literal for an input slot
    /// (matching `Tensor::synthetic` on the pure-rust side).
    pub fn synthetic_input(meta: &TensorMeta, seed: u64) -> xla::Literal {
        let n = meta.numel();
        let dims: Vec<i64> = meta.shape.iter().map(|&d| d as i64).collect();
        if meta.dtype == "i32" {
            // token ids / doc ids / positions: small sorted-ish ints
            let data: Vec<i32> = (0..n).map(|i| ((i * 3) / n.max(1)) as i32).collect();
            xla::Literal::vec1(&data).reshape(&dims).expect("reshape")
        } else {
            let s = seed as f64;
            let data: Vec<f32> = (0..n)
                .map(|i| ((s + i as f64 * 0.7).sin() * 0.5) as f32)
                .collect();
            xla::Literal::vec1(&data).reshape(&dims).expect("reshape")
        }
    }
}

/// Integration self-test: for every `<name>_fused` / `<name>_naive`
/// artifact pair, execute both on identical synthetic inputs and check
/// the outputs agree — the fused Pallas kernel vs the materializing jnp
/// reference, end-to-end through HLO text -> PJRT.
#[cfg(feature = "pjrt")]
pub fn selftest(dir: &str) -> Result<()> {
    let mut engine = Engine::new(dir)?;
    let names: Vec<String> = engine
        .manifest
        .artifacts
        .keys()
        .filter(|n| n.contains("_fused"))
        .cloned()
        .collect();
    let mut checked = 0;
    let mut names = names;
    names.sort();
    for fused in names {
        let naive = fused.replace("_fused", "_naive");
        if !engine.manifest.artifacts.contains_key(&naive) {
            continue;
        }
        let meta = engine.artifact(&fused)?.clone();
        let needs_weights = fused.starts_with("llama") || fused.starts_with("evoformer");
        let mut inputs: Vec<xla::Literal> = vec![];
        if needs_weights {
            let family = if fused.starts_with("llama") {
                "llama"
            } else {
                "evoformer"
            };
            let w = engine.load_weights(family)?;
            inputs.extend(w.literals());
        }
        for (i, im) in meta.inputs.iter().enumerate().skip(inputs.len()) {
            inputs.push(Engine::synthetic_input(im, 42 + i as u64));
        }
        let out_f = engine.run(&fused, &inputs)?;
        let out_n = engine.run(&naive, &inputs)?;
        anyhow::ensure!(out_f.len() == out_n.len(), "{fused}: output arity");
        for (a, b) in out_f.iter().zip(&out_n) {
            let va: Vec<f32> = a.to_vec()?;
            let vb: Vec<f32> = b.to_vec()?;
            let err = va
                .iter()
                .zip(&vb)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            anyhow::ensure!(
                err < 2e-3,
                "{fused} vs {naive}: max abs diff {err}"
            );
        }
        println!("  OK {fused} == {naive}");
        checked += 1;
    }
    anyhow::ensure!(checked >= 10, "only {checked} artifact pairs checked");
    println!("selftest: {checked} fused/naive artifact pairs agree");
    Ok(())
}

/// Without the `pjrt` feature there is no XLA client to run artifacts
/// on; fail loudly instead of silently passing.
#[cfg(not(feature = "pjrt"))]
pub fn selftest(_dir: &str) -> Result<()> {
    anyhow::bail!(
        "flashlight was built without the `pjrt` feature: add the `xla` \
         dependency to Cargo.toml (see the [features] note there) and \
         rebuild with --features pjrt to run selftest"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_meta_parses() {
        let t = TensorMeta::parse("f32:1x4x128x64").unwrap();
        assert_eq!(t.dtype, "f32");
        assert_eq!(t.shape, vec![1, 4, 128, 64]);
        assert_eq!(t.numel(), 1 * 4 * 128 * 64);
        let s = TensorMeta::parse("i32:8").unwrap();
        assert_eq!(s.shape, vec![8]);
    }

    #[test]
    fn manifest_parses_when_artifacts_exist() {
        let dir = Path::new("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert!(m.artifacts.contains_key("attn_vanilla_fused"));
        assert!(m.weights.contains_key("llama"));
        let llama = &m.configs["llama"];
        assert_eq!(llama["n_layers"], "4");
        let a = &m.artifacts["attn_vanilla_fused"];
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.meta["variant"], "vanilla");
    }
}
