//! Serving metrics: TTFT, ITL, token throughput (paper Fig 5) — plus
//! the fault-tolerant lifecycle's terminal-state accounting (exactly
//! one [`Outcome`] per request, latency summaries split by outcome,
//! goodput).

#[derive(Debug, Clone, Default)]
pub struct RequestMetrics {
    pub id: usize,
    pub arrival_s: f64,
    pub first_token_s: f64,
    pub done_s: f64,
    pub input_tokens: usize,
    pub output_tokens: usize,
    /// Inter-token latencies (seconds between consecutive tokens).
    pub itls: Vec<f64>,
}

impl RequestMetrics {
    pub fn ttft(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub n_requests: usize,
    pub ttft_mean_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub itl_mean_s: f64,
    pub itl_p50_s: f64,
    pub itl_p99_s: f64,
    /// Generated tokens per second over the whole run.
    pub tokens_per_s: f64,
    pub makespan_s: f64,
}

fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[i.min(sorted.len() - 1)]
}

pub fn summarize(reqs: &[RequestMetrics]) -> Summary {
    let mut ttfts: Vec<f64> = reqs.iter().map(|r| r.ttft()).collect();
    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut itls: Vec<f64> = reqs.iter().flat_map(|r| r.itls.iter().copied()).collect();
    itls.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let makespan = reqs
        .iter()
        .map(|r| r.done_s)
        .fold(0.0f64, f64::max)
        .max(1e-12);
    let out_tokens: usize = reqs.iter().map(|r| r.output_tokens).sum();
    let mean = |v: &[f64]| {
        if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    };
    Summary {
        n_requests: reqs.len(),
        ttft_mean_s: mean(&ttfts),
        ttft_p50_s: pct(&ttfts, 0.5),
        ttft_p99_s: pct(&ttfts, 0.99),
        itl_mean_s: mean(&itls),
        itl_p50_s: pct(&itls, 0.5),
        itl_p99_s: pct(&itls, 0.99),
        tokens_per_s: out_tokens as f64 / makespan,
        makespan_s: makespan,
    }
}

/// The terminal state of one request under the fault-tolerant
/// lifecycle. Every admitted-or-rejected request ends in *exactly one*
/// of these — the chaos harness's core invariant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Outcome {
    /// All requested tokens generated.
    Completed,
    /// Refused at the ingress (queue overflow or can-never-fit); the
    /// client may retry after the hinted backoff.
    Rejected,
    /// Client cancelled before completion.
    Cancelled,
    /// Deadline (SLO budget) expired before completion.
    DeadlineExceeded,
    /// An engine fault (attributed worker panic) killed the request.
    Failed,
}

impl Outcome {
    pub fn name(&self) -> &'static str {
        match self {
            Outcome::Completed => "completed",
            Outcome::Rejected => "rejected",
            Outcome::Cancelled => "cancelled",
            Outcome::DeadlineExceeded => "deadline_exceeded",
            Outcome::Failed => "failed",
        }
    }
}

/// One request's full lifecycle record: its terminal state, the token
/// stream it actually emitted (partial for non-completed requests —
/// preempted-and-resumed requests re-emit from their restart point,
/// so the stream is the *final* attempt's), and timing metrics where
/// a first token was ever produced.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub id: usize,
    pub outcome: Outcome,
    /// Human-readable cause for non-completed terminals.
    pub reason: String,
    /// Backoff hint attached to `Rejected` terminals (seconds).
    pub retry_after_s: f64,
    /// Tokens emitted by the final attempt, in emission order.
    pub tokens: Vec<u32>,
    /// Times this request was preempted (parked + requeued).
    pub preemptions: u32,
    /// Timing metrics; `None` when no first token was ever emitted.
    pub metrics: Option<RequestMetrics>,
}

/// Aggregate lifecycle accounting over a run.
#[derive(Debug, Clone, Default)]
pub struct LifecycleSummary {
    pub completed: usize,
    pub rejected: usize,
    pub cancelled: usize,
    pub deadline_exceeded: usize,
    pub failed: usize,
    /// Preemption events across all requests.
    pub preemptions: u64,
    /// Latency summary over completed requests only.
    pub completed_summary: Option<Summary>,
    /// Tokens emitted by requests that went on to complete, divided by
    /// the run's makespan: throughput that *counted* (preempted work
    /// that was re-done, and tokens of requests that later died, are
    /// excluded).
    pub goodput_tokens_per_s: f64,
}

impl LifecycleSummary {
    pub fn total(&self) -> usize {
        self.completed + self.rejected + self.cancelled + self.deadline_exceeded + self.failed
    }
}

/// Fold per-request outcomes into the run-level accounting.
pub fn summarize_outcomes(outcomes: &[RequestOutcome]) -> LifecycleSummary {
    let mut s = LifecycleSummary::default();
    let mut completed_metrics = Vec::new();
    let mut good_tokens = 0usize;
    let mut makespan = 0f64;
    for o in outcomes {
        match o.outcome {
            Outcome::Completed => s.completed += 1,
            Outcome::Rejected => s.rejected += 1,
            Outcome::Cancelled => s.cancelled += 1,
            Outcome::DeadlineExceeded => s.deadline_exceeded += 1,
            Outcome::Failed => s.failed += 1,
        }
        s.preemptions += u64::from(o.preemptions);
        if let Some(m) = &o.metrics {
            makespan = makespan.max(m.done_s);
            if o.outcome == Outcome::Completed {
                good_tokens += o.tokens.len();
                completed_metrics.push(m.clone());
            }
        }
    }
    if !completed_metrics.is_empty() {
        s.completed_summary = Some(summarize(&completed_metrics));
    }
    s.goodput_tokens_per_s = good_tokens as f64 / makespan.max(1e-12);
    s
}

/// One point on a goodput-vs-offered-load curve: a full lifecycle run
/// at a fixed offered load, reduced to the numbers the serve bench
/// records per load point.
#[derive(Debug, Clone, Copy)]
pub struct LoadPoint {
    /// Offered load for the run (requests per second — or per round
    /// under `ClockMode::Rounds`).
    pub offered_rps: f64,
    pub completed: usize,
    /// Requests that ended in any non-completed terminal.
    pub shed: usize,
    pub goodput_tokens_per_s: f64,
    /// Fraction of *all* submitted requests that completed within
    /// `slo_ttft_s` of submission (non-completed requests count as
    /// misses), so attainment degrades honestly as load sheds work.
    pub slo_attainment: f64,
}

/// Reduce one run's outcomes to a [`LoadPoint`] at `offered_rps`,
/// judging SLO attainment by TTFT against `slo_ttft_s` (pass
/// `f64::INFINITY` to make attainment = completion rate).
pub fn load_point(outcomes: &[RequestOutcome], offered_rps: f64, slo_ttft_s: f64) -> LoadPoint {
    let s = summarize_outcomes(outcomes);
    let within = outcomes
        .iter()
        .filter(|o| {
            o.outcome == Outcome::Completed
                && o.metrics.as_ref().is_some_and(|m| m.ttft() <= slo_ttft_s)
        })
        .count();
    LoadPoint {
        offered_rps,
        completed: s.completed,
        shed: s.total() - s.completed,
        goodput_tokens_per_s: s.goodput_tokens_per_s,
        slo_attainment: if outcomes.is_empty() {
            0.0
        } else {
            within as f64 / outcomes.len() as f64
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_math() {
        let reqs = vec![
            RequestMetrics {
                id: 0,
                arrival_s: 0.0,
                first_token_s: 0.1,
                done_s: 0.5,
                input_tokens: 10,
                output_tokens: 5,
                itls: vec![0.1; 4],
            },
            RequestMetrics {
                id: 1,
                arrival_s: 0.2,
                first_token_s: 0.5,
                done_s: 1.0,
                input_tokens: 10,
                output_tokens: 5,
                itls: vec![0.125; 4],
            },
        ];
        let s = summarize(&reqs);
        assert_eq!(s.n_requests, 2);
        assert!((s.ttft_mean_s - 0.2).abs() < 1e-12);
        assert!((s.tokens_per_s - 10.0).abs() < 1e-9);
        assert!((s.itl_mean_s - 0.1125).abs() < 1e-12);
    }

    #[test]
    fn outcome_accounting_counts_each_terminal_once() {
        let m = |done_s: f64| RequestMetrics {
            id: 0,
            arrival_s: 0.0,
            first_token_s: 0.1,
            done_s,
            input_tokens: 4,
            output_tokens: 3,
            itls: vec![0.1, 0.1],
        };
        let o = |id, outcome, tokens: usize, metrics| RequestOutcome {
            id,
            outcome,
            reason: String::new(),
            retry_after_s: 0.0,
            tokens: vec![7; tokens],
            preemptions: u32::from(id == 1),
            metrics,
        };
        let outcomes = vec![
            o(0, Outcome::Completed, 3, Some(m(1.0))),
            o(1, Outcome::Completed, 3, Some(m(2.0))),
            o(2, Outcome::Rejected, 0, None),
            o(3, Outcome::Cancelled, 1, Some(m(0.5))),
            o(4, Outcome::DeadlineExceeded, 2, Some(m(0.8))),
            o(5, Outcome::Failed, 1, Some(m(0.9))),
        ];
        let s = summarize_outcomes(&outcomes);
        assert_eq!(
            (s.completed, s.rejected, s.cancelled, s.deadline_exceeded, s.failed),
            (2, 1, 1, 1, 1)
        );
        assert_eq!(s.total(), outcomes.len());
        assert_eq!(s.preemptions, 1);
        // Goodput counts only completed requests' tokens over the
        // makespan: 6 tokens / 2.0 s.
        assert!((s.goodput_tokens_per_s - 3.0).abs() < 1e-9);
        assert_eq!(s.completed_summary.unwrap().n_requests, 2);

        // The load-point reduction: TTFT here is 0.1 for every request
        // with metrics, so a 0.2s SLO admits both completions (2 of 6
        // requests), and a tighter-than-TTFT SLO admits none.
        let lp = load_point(&outcomes, 4.0, 0.2);
        assert_eq!(lp.offered_rps, 4.0);
        assert_eq!((lp.completed, lp.shed), (2, 4));
        assert!((lp.slo_attainment - 2.0 / 6.0).abs() < 1e-12);
        let tight = load_point(&outcomes, 4.0, 0.05);
        assert_eq!(tight.slo_attainment, 0.0);
        assert!((load_point(&outcomes, 4.0, f64::INFINITY).slo_attainment
            - 2.0 / 6.0)
            .abs()
            < 1e-12);
    }
}
