//! Serving metrics: TTFT, ITL, token throughput (paper Fig 5).

#[derive(Debug, Clone, Default)]
pub struct RequestMetrics {
    pub id: usize,
    pub arrival_s: f64,
    pub first_token_s: f64,
    pub done_s: f64,
    pub input_tokens: usize,
    pub output_tokens: usize,
    /// Inter-token latencies (seconds between consecutive tokens).
    pub itls: Vec<f64>,
}

impl RequestMetrics {
    pub fn ttft(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub n_requests: usize,
    pub ttft_mean_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub itl_mean_s: f64,
    pub itl_p50_s: f64,
    pub itl_p99_s: f64,
    /// Generated tokens per second over the whole run.
    pub tokens_per_s: f64,
    pub makespan_s: f64,
}

fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[i.min(sorted.len() - 1)]
}

pub fn summarize(reqs: &[RequestMetrics]) -> Summary {
    let mut ttfts: Vec<f64> = reqs.iter().map(|r| r.ttft()).collect();
    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut itls: Vec<f64> = reqs.iter().flat_map(|r| r.itls.iter().copied()).collect();
    itls.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let makespan = reqs
        .iter()
        .map(|r| r.done_s)
        .fold(0.0f64, f64::max)
        .max(1e-12);
    let out_tokens: usize = reqs.iter().map(|r| r.output_tokens).sum();
    let mean = |v: &[f64]| {
        if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    };
    Summary {
        n_requests: reqs.len(),
        ttft_mean_s: mean(&ttfts),
        ttft_p50_s: pct(&ttfts, 0.5),
        ttft_p99_s: pct(&ttfts, 0.99),
        itl_mean_s: mean(&itls),
        itl_p50_s: pct(&itls, 0.5),
        itl_p99_s: pct(&itls, 0.99),
        tokens_per_s: out_tokens as f64 / makespan,
        makespan_s: makespan,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_math() {
        let reqs = vec![
            RequestMetrics {
                id: 0,
                arrival_s: 0.0,
                first_token_s: 0.1,
                done_s: 0.5,
                input_tokens: 10,
                output_tokens: 5,
                itls: vec![0.1; 4],
            },
            RequestMetrics {
                id: 1,
                arrival_s: 0.2,
                first_token_s: 0.5,
                done_s: 1.0,
                input_tokens: 10,
                output_tokens: 5,
                itls: vec![0.125; 4],
            },
        ];
        let s = summarize(&reqs);
        assert_eq!(s.n_requests, 2);
        assert!((s.ttft_mean_s - 0.2).abs() < 1e-12);
        assert!((s.tokens_per_s - 10.0).abs() < 1e-9);
        assert!((s.itl_mean_s - 0.1125).abs() < 1e-12);
    }
}
