//! Watchdog supervision for the live serving loop.
//!
//! A [`Supervisor`] owns one background watchdog thread that monitors
//! two liveness signals:
//!
//! * the **runtime heartbeat** ([`crate::exec::runtime::heartbeat`]) —
//!   a monotone counter every completed work item ticks; and
//! * the **round beat** ([`Supervisor::beat`]) — ticked by the
//!   lifecycle round loop once per round, so a healthy-but-idle server
//!   (no launches in flight) still reads as alive.
//!
//! While a launch is in flight
//! ([`crate::exec::runtime::launches_in_flight`] `> 0`) and the
//! combined signal has not moved for a full **stall budget**, the
//! watchdog concludes the launch is stuck and calls
//! [`crate::exec::runtime::kill_stalled_launch`]. The stalled item
//! panics at its cooperative stall point, the panic is attributed
//! (`AttributedPanic` → `BatchPanic`), the owning request's slot is
//! Failed by the lifecycle, and the surviving batch re-executes
//! bit-identically — the same isolation path a worker panic takes.
//!
//! The stall budget comes from the caller (tests use a few tens of
//! milliseconds); CLI entry points read `FLASHLIGHT_STALL_MS` via
//! [`stall_budget_from_env`]. Library code never reads the
//! environment.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::exec::runtime;

/// Environment variable the CLI reads the watchdog stall budget from
/// (milliseconds; `0` disables supervision).
pub const STALL_MS_ENV: &str = "FLASHLIGHT_STALL_MS";

/// Default stall budget for CLI entry points: generous enough that a
/// slow-but-progressing launch on a loaded box is never killed (every
/// completed tile ticks the heartbeat, resetting the clock), short
/// enough that an injected stall resolves quickly.
pub const DEFAULT_STALL_MS: u64 = 500;

/// Watchdog stall budget from `FLASHLIGHT_STALL_MS` (CLI entry points
/// only). Unset → [`DEFAULT_STALL_MS`]; `0` is a *valid* value
/// (disables supervision). Anything set but not a non-negative integer
/// is **rejected with a warning** rather than silently falling back
/// (the `FLASHLIGHT_THREADS` fix, applied here): a typo'd budget would
/// otherwise quietly change when stalled launches get killed.
pub fn stall_budget_from_env() -> u64 {
    stall_budget_from_env_value(std::env::var(STALL_MS_ENV).ok().as_deref())
}

/// Testable core of [`stall_budget_from_env`].
pub fn stall_budget_from_env_value(env: Option<&str>) -> u64 {
    match env {
        None => DEFAULT_STALL_MS,
        Some(s) => match s.trim().parse::<u64>() {
            Ok(ms) => ms,
            Err(_) => {
                eprintln!(
                    "flashlight: ignoring invalid {STALL_MS_ENV}={s:?} \
                     (want milliseconds as an integer >= 0, 0 = no watchdog); \
                     using the default of {DEFAULT_STALL_MS}"
                );
                DEFAULT_STALL_MS
            }
        },
    }
}

struct Shared {
    stop: AtomicBool,
    /// Round-loop liveness ticks, added to the runtime heartbeat.
    round_beats: AtomicU64,
    /// Stalled launches the watchdog has killed.
    kills: AtomicU64,
}

/// A running watchdog. Dropping it (or calling [`Supervisor::stop`])
/// stops the thread; the supervisor never outlives the scope that
/// started it.
pub struct Supervisor {
    shared: Arc<Shared>,
    handle: Option<JoinHandle<()>>,
}

impl Supervisor {
    /// Start a watchdog with the given stall budget in milliseconds.
    /// A budget of `0` starts a no-op supervisor (never kills).
    pub fn start(stall_ms: u64) -> Self {
        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            round_beats: AtomicU64::new(0),
            kills: AtomicU64::new(0),
        });
        let handle = if stall_ms == 0 {
            None
        } else {
            let sh = shared.clone();
            Some(
                std::thread::Builder::new()
                    .name("flashlight-watchdog".to_string())
                    .spawn(move || watchdog_loop(&sh, stall_ms))
                    .expect("spawn flashlight watchdog"),
            )
        };
        Supervisor {
            shared,
            handle,
        }
    }

    /// Round-loop liveness tick: call once per lifecycle round. Resets
    /// the watchdog's stall clock even when no launch completed items
    /// that round (e.g. an empty admission round).
    pub fn beat(&self) {
        self.shared.round_beats.fetch_add(1, Ordering::Relaxed);
    }

    /// Stalled launches killed so far.
    pub fn kills(&self) -> u64 {
        self.shared.kills.load(Ordering::Relaxed)
    }

    /// Stop the watchdog thread and return the total kill count.
    pub fn stop(mut self) -> u64 {
        self.halt();
        self.kills()
    }

    fn halt(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        self.halt();
    }
}

fn watchdog_loop(sh: &Shared, stall_ms: u64) {
    // Poll several times per budget so a kill lands within ~1.25x the
    // budget of the actual stall onset.
    let poll = Duration::from_millis((stall_ms / 8).max(1));
    let budget = Duration::from_millis(stall_ms);
    let mut last_signal = runtime::heartbeat() + sh.round_beats.load(Ordering::Relaxed);
    let mut stalled_for = Duration::ZERO;
    while !sh.stop.load(Ordering::SeqCst) {
        std::thread::sleep(poll);
        let signal = runtime::heartbeat() + sh.round_beats.load(Ordering::Relaxed);
        if signal != last_signal || runtime::launches_in_flight() == 0 {
            // Progress (or nothing running): reset the stall clock.
            last_signal = signal;
            stalled_for = Duration::ZERO;
            continue;
        }
        stalled_for += poll;
        if stalled_for >= budget {
            runtime::kill_stalled_launch();
            sh.kills.fetch_add(1, Ordering::Relaxed);
            stalled_for = Duration::ZERO;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::runtime::{clear_injected_stall, inject_stall_next_launch};
    use crate::exec::{parallel_map_with, Parallelism};

    #[test]
    fn watchdog_kills_an_injected_stall_and_spares_healthy_launches() {
        let sup = Supervisor::start(30);
        // Healthy launches complete untouched.
        let ok = parallel_map_with(&Parallelism::with_threads(2), 16, || (), |_, i| i + 1);
        assert_eq!(ok, (1..=16).collect::<Vec<_>>());
        // A stalled launch is killed and attributed.
        inject_stall_next_launch(2);
        let res = std::panic::catch_unwind(|| {
            parallel_map_with(&Parallelism::with_threads(2), 8, || (), |_, i| i)
        });
        let payload = res.expect_err("watchdog must kill the stalled launch");
        assert_eq!(crate::exec::runtime::panic_item(payload.as_ref()), Some(2));
        assert!(crate::exec::runtime::panic_message(payload.as_ref())
            .contains("launch stalled"));
        assert!(sup.kills() >= 1);
        // The pool survives; subsequent launches are clean.
        let ok = parallel_map_with(&Parallelism::with_threads(2), 8, || (), |_, i| i);
        assert_eq!(ok, (0..8).collect::<Vec<_>>());
        clear_injected_stall();
        let kills = sup.stop();
        assert!(kills >= 1);
    }

    #[test]
    fn stall_budget_env_accepts_zero_but_rejects_garbage() {
        assert_eq!(stall_budget_from_env_value(None), DEFAULT_STALL_MS);
        assert_eq!(stall_budget_from_env_value(Some("250")), 250);
        assert_eq!(stall_budget_from_env_value(Some(" 1000 ")), 1000);
        // 0 is a deliberate "no watchdog", not an error.
        assert_eq!(stall_budget_from_env_value(Some("0")), 0);
        // Garbage is rejected (loudly), never treated as 0/disabled: a
        // typo must not silently turn the watchdog off.
        for bad in ["-1", "fast", "", "0.5s", "500ms"] {
            assert_eq!(
                stall_budget_from_env_value(Some(bad)),
                DEFAULT_STALL_MS,
                "{bad:?} must fall back to the default"
            );
        }
    }

    #[test]
    fn zero_budget_supervisor_is_a_no_op() {
        let sup = Supervisor::start(0);
        sup.beat();
        assert_eq!(sup.kills(), 0);
        assert_eq!(sup.stop(), 0);
    }
}
