//! Simulated serving backend: LLaMa-3.2-1B shapes on the GPU cost model
//! with a virtual clock — the substrate for reproducing Fig 5's vLLM
//! experiment (DESIGN.md §2).
//!
//! Per-iteration times are composed from (a) the attention-kernel
//! estimates of [`crate::baselines`] under the chosen system
//! (Flashlight or FlexAttention, with FlexAttention's LRU block-mask
//! cache modeled per tensor shape, exactly the amortization the paper
//! discusses), and (b) GEMM/weight-streaming costs of the rest of the
//! transformer.

use std::collections::HashSet;

use crate::baselines::{estimate_attention, mask_creation_time, System};
use crate::cost::{kernel_time, Efficiency, GpuSpec};
use crate::exec::Counters;
use crate::fusion::TileConfig;
use crate::variants::{AttnShape, Variant};

use crate::tracegen::Request;

use super::engine::Backend;

/// LLaMa-3.2-1B architecture (paper §4.4 serves this model in vLLM).
#[derive(Debug, Clone, Copy)]
pub struct ModelShape {
    pub d_model: usize,
    pub layers: usize,
    pub heads_q: usize,
    pub heads_kv: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub vocab: usize,
}

pub fn llama_3_2_1b() -> ModelShape {
    ModelShape {
        d_model: 2048,
        layers: 16,
        heads_q: 32,
        heads_kv: 8,
        head_dim: 64,
        ffn: 8192,
        vocab: 128_256,
    }
}

impl ModelShape {
    /// Parameter count (embeddings tied, LLaMa-3.2 style).
    pub fn params(&self) -> u64 {
        let d = self.d_model as u64;
        let attn = d * d + 2 * d * (self.heads_kv * self.head_dim) as u64 + d * d;
        let mlp = 3 * d * self.ffn as u64;
        (self.vocab as u64) * d + self.layers as u64 * (attn + mlp)
    }

    /// Non-attention GEMM flops for a forward over `tokens` tokens.
    pub fn gemm_flops(&self, tokens: usize) -> u64 {
        let t = tokens as u64;
        let d = self.d_model as u64;
        let kv = (self.heads_kv * self.head_dim) as u64;
        let per_layer = 2 * t * (d * d + 2 * d * kv + d * d) + 2 * t * 3 * d * self.ffn as u64;
        self.layers as u64 * per_layer + 2 * t * d * self.vocab as u64
    }
}

pub struct SimBackend {
    pub spec: GpuSpec,
    pub model: ModelShape,
    pub system: System,
    pub variant: Variant,
    n_slots: usize,
    max_context: usize,
    /// Context length per slot (tokens currently in the KV cache).
    ctx: Vec<usize>,
    /// FlexAttention's LRU mask cache, keyed by prefill length (the
    /// "same tensor shapes" amortization of §4.4).
    mask_cache: HashSet<usize>,
    /// Mooncake-style prefix caching: retained KV length per
    /// conversation (trading KV-cache storage for prefill computation —
    /// the trace source's core idea). Off by default to match the
    /// paper's vLLM setup.
    pub prefix_caching: bool,
    prefix_cache: std::collections::HashMap<usize, usize>,
    tile: TileConfig,
    /// Weight bytes streamed per forward (bf16).
    weight_bytes: u64,
}

impl SimBackend {
    pub fn new(spec: GpuSpec, system: System, variant: Variant) -> Self {
        let model = llama_3_2_1b();
        let weight_bytes = model.params() * 2;
        SimBackend {
            spec,
            model,
            system,
            variant,
            n_slots: 32,
            max_context: 8192,
            ctx: vec![0; 32],
            mask_cache: HashSet::new(),
            prefix_caching: false,
            prefix_cache: std::collections::HashMap::new(),
            tile: TileConfig::default(),
            weight_bytes,
        }
    }

    fn attn_shape(&self, s: usize) -> AttnShape {
        AttnShape {
            batch: 1,
            rows: 1,
            heads_q: self.model.heads_q,
            heads_kv: self.model.heads_kv,
            seq: s.max(16),
            head_dim: self.model.head_dim,
        }
    }

    /// Dense GEMM + weight streaming time for a forward of `tokens`.
    fn backbone_time(&self, tokens: usize) -> f64 {
        let c = Counters {
            hbm_read: self.weight_bytes + (tokens * self.model.d_model * 2) as u64,
            l2_read: 0,
            hbm_write: (tokens * self.model.d_model * 2) as u64,
            flops: self.model.gemm_flops(tokens),
            launches: (self.model.layers * 6) as u64,
            ..Counters::default()
        };
        kernel_time(&self.spec, &c, Efficiency::new(0.70, 0.85))
    }

    /// Attention time for one prefill of length `s` across all layers,
    /// including FlexAttention's mask-cache dynamics.
    fn prefill_attention_time(&mut self, s: usize) -> f64 {
        let shape = self.attn_shape(s);
        // Within one forward the mask is created once and reused across
        // layers; across requests it is cached per shape.
        let est = estimate_attention(
            match self.system {
                System::FlexAttention { .. } => System::FlexAttention { mask_cached: true },
                other => other,
            },
            self.variant,
            &shape,
            &self.spec,
            self.tile,
        )
        .expect("serving variant must be supported");
        let mut t = est.total() * self.model.layers as f64;
        // Mask shapes are bucketed (compiled kernels pad sequence
        // lengths), so the LRU cache warms up after a few requests per
        // bucket — the amortization that makes Flex win Causal in Fig 5.
        let bucket = s.div_ceil(128) * 128;
        if matches!(self.system, System::FlexAttention { .. })
            && self.variant.is_mask_variant()
            && self.mask_cache.insert(bucket)
        {
            t += mask_creation_time(&self.spec, bucket); // cold bucket
        }
        t
    }

    /// Decode attention: q_len = 1 per slot; KV-cache streaming bound.
    fn decode_attention_time(&self, active: &[usize]) -> f64 {
        let kv_bytes: u64 = active
            .iter()
            .map(|&slot| {
                (self.model.layers
                    * 2
                    * self.model.heads_kv
                    * self.model.head_dim
                    * self.ctx[slot]
                    * 2) as u64
            })
            .sum();
        let c = Counters {
            hbm_read: kv_bytes,
            l2_read: 0,
            hbm_write: 0,
            flops: 2 * kv_bytes, // one MAC per streamed kv element
            launches: self.model.layers as u64,
            ..Counters::default()
        };
        kernel_time(&self.spec, &c, Efficiency::new(0.5, 0.8))
    }
}

impl Backend for SimBackend {
    fn n_slots(&self) -> usize {
        self.n_slots
    }

    fn max_context(&self) -> usize {
        self.max_context
    }

    fn prefill(
        &mut self,
        slot: usize,
        req: &Request,
        tokens: &[u32],
    ) -> anyhow::Result<(f64, u32)> {
        let s = tokens.len();
        self.ctx[slot] = s;
        // Prefix-cache hit: only the new suffix needs prefilling (the
        // cached prefix's KV blocks are reused from storage).
        let new_tokens = if self.prefix_caching {
            let cached = self
                .prefix_cache
                .get(&req.conversation)
                .copied()
                .unwrap_or(0)
                .min(s);
            self.prefix_cache
                .insert(req.conversation, s + req.output_tokens);
            s - cached
        } else {
            s
        };
        let t = if new_tokens == 0 {
            // pure cache hit: one cheap KV-fetch pass
            self.backbone_time(1)
        } else {
            self.backbone_time(new_tokens) + self.prefill_attention_time(new_tokens)
        };
        // The generated token is arbitrary in simulation.
        Ok((t, (s as u32).wrapping_mul(2654435761) % 512))
    }

    fn decode(&mut self, active: &[usize]) -> anyhow::Result<(f64, Vec<u32>)> {
        let t = self.backbone_time(active.len()) + self.decode_attention_time(active);
        let toks = active
            .iter()
            .map(|&slot| {
                self.ctx[slot] += 1;
                (self.ctx[slot] as u32).wrapping_mul(2246822519) % 512
            })
            .collect();
        Ok((t, toks))
    }

    fn release(&mut self, slot: usize) {
        self.ctx[slot] = 0;
    }

    fn is_virtual_time(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::h100;

    #[test]
    fn model_params_close_to_1_2b() {
        let p = llama_3_2_1b().params();
        assert!(
            (1.0e9..1.5e9).contains(&(p as f64)),
            "param count {p} not ~1.2B"
        );
    }

    fn dummy_req(conversation: usize, input: usize) -> Request {
        Request {
            id: 0,
            arrival_s: 0.0,
            input_tokens: input,
            output_tokens: 16,
            conversation,
            turn: 0,
            ..Request::default()
        }
    }

    #[test]
    fn decode_itl_is_sub_10ms() {
        let mut b = SimBackend::new(h100(), System::Flashlight, Variant::Causal);
        let toks: Vec<u32> = (0..256).collect();
        b.prefill(0, &dummy_req(0, 256), &toks).unwrap();
        b.prefill(1, &dummy_req(1, 256), &toks).unwrap();
        let (t, out) = b.decode(&[0, 1]).unwrap();
        assert_eq!(out.len(), 2);
        assert!(t > 0.0 && t < 10e-3, "ITL {t}");
    }

    #[test]
    fn flex_mask_cache_amortizes_across_requests() {
        let mut b = SimBackend::new(
            h100(),
            System::FlexAttention { mask_cached: false },
            Variant::Causal,
        );
        let t_cold = b.prefill_attention_time(1024);
        let t_warm = b.prefill_attention_time(1024);
        assert!(t_cold > t_warm, "first shape must pay mask creation");
        let t_new_shape = b.prefill_attention_time(2048);
        assert!(t_new_shape > b.prefill_attention_time(2048));
    }

    #[test]
    fn prefix_caching_cuts_continuation_prefill_cost() {
        let mut b = SimBackend::new(h100(), System::Flashlight, Variant::Causal);
        b.prefix_caching = true;
        let req0 = dummy_req(7, 1024);
        let toks: Vec<u32> = (0..1024).collect();
        let (t_cold, _) = b.prefill(0, &req0, &toks).unwrap();
        // second turn: same conversation, longer prompt (history + new)
        let req1 = Request {
            input_tokens: 1100,
            turn: 1,
            ..req0.clone()
        };
        let toks2: Vec<u32> = (0..1100).collect();
        let (t_warm, _) = b.prefill(1, &req1, &toks2).unwrap();
        assert!(
            t_warm < t_cold * 0.5,
            "cached continuation should be much cheaper: {t_warm} vs {t_cold}"
        );
        // a different conversation pays full price
        let req2 = Request {
            conversation: 99,
            ..req0.clone()
        };
        let (t_other, _) = b.prefill(2, &req2, &toks).unwrap();
        assert!((t_other - t_cold).abs() < t_cold * 0.05);
    }

    #[test]
    fn softcap_prefill_faster_under_flashlight_causal_under_flex() {
        // The paper's Fig 5 result in one assertion.
        let spec = h100();
        let t = |sys: System, v: Variant| {
            let mut b = SimBackend::new(spec, sys, v);
            // warm the mask cache like a running server
            b.prefill_attention_time(1024);
            b.prefill_attention_time(1024)
        };
        let flex = System::FlexAttention { mask_cached: false };
        assert!(
            t(System::Flashlight, Variant::Softcap { cap: 20.0 })
                < t(flex, Variant::Softcap { cap: 20.0 }),
            "flashlight must win softcap"
        );
        assert!(
            t(flex, Variant::Causal) < t(System::Flashlight, Variant::Causal),
            "flex (warm cache) must win causal"
        );
    }
}
