//! One serving shard: a self-contained engine instance.
//!
//! A [`Shard`] owns everything a single engine needs to serve requests
//! — a [`PlanRunner`]-backed [`EngineBackend`] (its own plan cache and
//! slot-paged KV pool), the queue of requests the router assigned to
//! it, and its lifecycle health. Shards share *nothing*: a shard's
//! plan cache is rebuilt per instance from the same deterministic
//! autotune cost model (see `fusion::cache`), its KV pool is private,
//! and its prefix cache is shard-local (which is why the router keeps
//! conversations sticky). That isolation is the fault domain: a
//! `kill@R:shard=S` fault destroys one shard's state and nothing else.
//!
//! Execution is **wave-based**: the router routes a batch of requests
//! onto shards, every shard runs one [`run_lifecycle`] pass over its
//! queue ([`Shard::run_wave`]), and requests a killed shard never
//! finished come back to the router for re-sharding onto the
//! survivors in the next wave. Between waves a surviving shard keeps
//! its backend — parked conversation prefixes survive, so re-routed
//! multi-turn conversations adopt partial prefixes where the page pool
//! survived and re-prefill where it died with the shard.
//!
//! Shards run their waves sequentially on the shared worker pool
//! (one process stands in for N instances); because every shard's
//! stream is bit-identical at any parallelism, this is
//! indistinguishable from truly concurrent instances.

use std::collections::HashSet;

use crate::exec::topology::{proportional_split, Topology};
use crate::exec::PlanRunner;
use crate::tracegen::Request;

use super::engine::SchedulerConfig;
use super::engine_backend::EngineBackend;
use super::faults::FaultPlan;
use super::lifecycle::{run_lifecycle, LifecycleConfig, LifecycleReport};

/// Pin `n_shards` instances to topology domains, proportional to each
/// domain's worker weight (largest remainder, deterministic): on a
/// `numa:8,8` box, 4 shards land 2+2; on `flat:N` everything is domain
/// 0. Returns one domain index per shard. The pin is advisory (this
/// runtime has no thread-affinity syscalls) but it is carried through
/// health rows and bench output so placement is observable.
pub fn shard_domains(topo: &Topology, n_shards: usize) -> Vec<usize> {
    let counts = proportional_split(topo.weights(), n_shards);
    let mut domains = Vec::with_capacity(n_shards);
    for (domain, &count) in counts.iter().enumerate() {
        for _ in 0..count {
            domains.push(domain);
        }
    }
    domains
}

/// A point-in-time health row for one shard, as the router reports it.
#[derive(Debug, Clone)]
pub struct ShardHealth {
    pub id: usize,
    /// Topology domain the instance is pinned to.
    pub domain: usize,
    /// `false` once a kill fault took the instance down.
    pub alive: bool,
    /// Lifecycle rounds executed across all waves.
    pub rounds: u64,
    /// Stalled launches the shard's watchdog killed.
    pub watchdog_kills: u64,
    /// Requests the router assigned to this shard (all waves).
    pub assigned: usize,
    /// Terminal states this shard produced.
    pub terminals: usize,
    /// KV pages ever allocated from this shard's pool.
    pub pages_allocated: usize,
    /// KV pages back on this shard's free list.
    pub pages_free: usize,
    /// KV pages held by this shard's parked conversation prefixes.
    pub pages_parked: usize,
    /// Runner + domain label, e.g. `cpu:4t@numa0`.
    pub runner: String,
}

impl ShardHealth {
    /// The shard-local no-leak invariant: every page ever allocated is
    /// either free or parked behind a prefix. Only meaningful for
    /// surviving shards — a killed shard's pool died mid-flight.
    pub fn leak_free(&self) -> bool {
        self.pages_allocated == self.pages_free + self.pages_parked
    }
}

/// One engine instance plus its routing state. See the module docs.
pub struct Shard {
    pub id: usize,
    /// Topology domain this instance is pinned to (advisory).
    pub domain: usize,
    pub backend: EngineBackend,
    /// Requests routed here for the next wave, in arrival order.
    pub queue: Vec<Request>,
    /// Round a `kill@R:shard=S` fault dooms this instance at
    /// (0 = healthy). Consumed by the next wave.
    pub kill_at: u64,
    pub alive: bool,
    rounds: u64,
    watchdog_kills: u64,
    assigned_total: usize,
    terminals: usize,
}

impl Shard {
    pub fn new(id: usize, domain: usize, backend: EngineBackend) -> Self {
        Shard {
            id,
            domain,
            backend,
            queue: Vec::new(),
            kill_at: 0,
            alive: true,
            rounds: 0,
            watchdog_kills: 0,
            assigned_total: 0,
            terminals: 0,
        }
    }

    /// Run one lifecycle wave over this shard's queue. Returns the
    /// wave's report plus the requests that never reached a terminal
    /// state — non-empty only when a pending kill halted the instance
    /// mid-wave, in which case the shard is marked dead and the router
    /// must re-shard the leftovers onto survivors.
    ///
    /// A kill round the wave never reached (the shard drained first)
    /// is a no-op: the instance shut down cleanly before the fault
    /// landed. Either way the kill is consumed — a dead shard is not
    /// re-killed, and a survivor does not halt in a later wave.
    pub fn run_wave(
        &mut self,
        sched: SchedulerConfig,
        lc: LifecycleConfig,
        faults: &FaultPlan,
        vocab: usize,
    ) -> anyhow::Result<(LifecycleReport, Vec<Request>)> {
        let wave = std::mem::take(&mut self.queue);
        let lc = LifecycleConfig {
            halt_at_round: self.kill_at,
            ..lc
        };
        self.kill_at = 0;
        let rep = run_lifecycle(&mut self.backend, &wave, sched, lc, faults, vocab)?;
        self.rounds += rep.stats.rounds;
        self.watchdog_kills += rep.stats.watchdog_kills;
        self.assigned_total += wave.len();
        self.terminals += rep.outcomes.len();
        // The lifecycle guarantees a terminal per request unless it was
        // halted, so leftovers are exactly the kill's in-flight victims
        // (plus whatever was still queued behind them).
        let done: HashSet<usize> = rep.outcomes.iter().map(|o| o.id).collect();
        let unfinished: Vec<Request> =
            wave.into_iter().filter(|r| !done.contains(&r.id)).collect();
        if !unfinished.is_empty() {
            self.alive = false;
        }
        Ok((rep, unfinished))
    }

    /// Outstanding work estimate for the router's load balancing:
    /// total tokens (prompt + completion) queued on this shard.
    pub fn queued_cost(&self) -> usize {
        self.queue
            .iter()
            .map(|r| r.input_tokens + r.output_tokens)
            .sum()
    }

    pub fn health(&self) -> ShardHealth {
        let (pages_allocated, pages_free) = self.backend.kv_pages();
        ShardHealth {
            id: self.id,
            domain: self.domain,
            alive: self.alive,
            rounds: self.rounds,
            watchdog_kills: self.watchdog_kills,
            assigned: self.assigned_total,
            terminals: self.terminals,
            pages_allocated,
            pages_free,
            pages_parked: self.backend.prefix_stats().parked_pages,
            runner: format!("{}@dom{}", self.backend.runner().describe(), self.domain),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Parallelism;
    use crate::serve::engine_backend::EngineModel;
    use crate::serve::lifecycle::ClockMode;

    fn backend() -> EngineBackend {
        EngineBackend::new(
            EngineModel::tiny(),
            4,
            512,
            Parallelism::with_threads(1),
        )
    }

    #[test]
    fn domains_split_proportionally_and_cover_every_shard() {
        let topo = Topology::from_domains(vec![8, 8], "test");
        assert_eq!(shard_domains(&topo, 4), vec![0, 0, 1, 1]);
        assert_eq!(shard_domains(&topo, 3), vec![0, 0, 1]);
        let flat = Topology::flat(4);
        assert_eq!(shard_domains(&flat, 2), vec![0, 0]);
        let skew = Topology::from_domains(vec![12, 4], "test");
        assert_eq!(shard_domains(&skew, 4), vec![0, 0, 0, 1]);
    }

    #[test]
    fn healthy_wave_terminates_everything_and_stays_alive() {
        let trace = crate::serve::engine_trace(6);
        let mut s = Shard::new(0, 0, backend());
        let vocab = s.backend.model.vocab;
        s.queue = trace.clone();
        let lc = LifecycleConfig {
            clock: ClockMode::Rounds,
            ..Default::default()
        };
        let (rep, unfinished) = s
            .run_wave(
                SchedulerConfig::default(),
                lc,
                &FaultPlan::none(),
                vocab,
            )
            .unwrap();
        assert!(unfinished.is_empty());
        assert!(s.alive);
        assert_eq!(rep.outcomes.len(), trace.len());
        let h = s.health();
        assert!(h.leak_free(), "healthy shard must not leak pages");
        assert_eq!((h.assigned, h.terminals), (trace.len(), trace.len()));
    }

    #[test]
    fn killed_wave_returns_the_unfinished_remainder_exactly_once() {
        let trace = crate::serve::engine_trace(8);
        let mut s = Shard::new(1, 0, backend());
        let vocab = s.backend.model.vocab;
        s.queue = trace.clone();
        s.kill_at = 2;
        let lc = LifecycleConfig {
            clock: ClockMode::Rounds,
            ..Default::default()
        };
        let (rep, unfinished) = s
            .run_wave(
                SchedulerConfig::default(),
                lc,
                &FaultPlan::none(),
                vocab,
            )
            .unwrap();
        assert!(!s.alive, "a kill that strands work must mark the shard dead");
        assert!(!unfinished.is_empty());
        assert_eq!(s.kill_at, 0, "the kill is consumed by the wave");
        // Terminal + unfinished ids partition the wave exactly.
        let mut ids: Vec<usize> = rep
            .outcomes
            .iter()
            .map(|o| o.id)
            .chain(unfinished.iter().map(|r| r.id))
            .collect();
        ids.sort_unstable();
        let mut want: Vec<usize> = trace.iter().map(|r| r.id).collect();
        want.sort_unstable();
        assert_eq!(ids, want);
    }
}
