//! The serving coordinator: a vLLM-style continuous batcher with
//! prefill-priority scheduling, slot-based KV-cache management, and a
//! discrete-event clock that works for both the virtual-time simulated
//! backend (Fig 5) and the real PJRT backend (wall time).

use std::collections::VecDeque;

use crate::tracegen::{Request, Rng};

use super::metrics::RequestMetrics;

/// A serving backend: owns the model + KV state per slot.
pub trait Backend {
    fn n_slots(&self) -> usize;
    fn max_context(&self) -> usize;
    /// Called once by [`run_trace`] before any work: backends that
    /// execute real plans pick up [`SchedulerConfig::parallelism`] here.
    fn configure(&mut self, _cfg: &SchedulerConfig) {}
    /// Run a prefill for `tokens` in `slot`; returns (elapsed seconds,
    /// first generated token). The request is passed for conversation
    /// identity (prefix-cache reuse across turns).
    fn prefill(&mut self, slot: usize, req: &Request, tokens: &[u32])
        -> anyhow::Result<(f64, u32)>;
    /// Run one batched decode step over `active` slots; returns
    /// (elapsed seconds, one generated token per active slot).
    fn decode(&mut self, active: &[usize]) -> anyhow::Result<(f64, Vec<u32>)>;
    /// Free a slot's KV state.
    fn release(&mut self, slot: usize);
    /// Virtual-time backends advance the clock by their returned times;
    /// wall-time backends (PJRT) also do, but arrivals are compressed.
    fn is_virtual_time(&self) -> bool;
    /// Admission control: can this request *ever* complete on this
    /// backend? Checked once per request before any resources are
    /// committed; `Err` carries a precise human-readable reason. The
    /// default enforces the context window; backends with bounded KV
    /// pools also reject requests whose worst-case lifetime page need
    /// exceeds the pool (the silent over-admission fix).
    fn admit_check(&self, req: &Request) -> Result<(), String> {
        if req.input_tokens + req.output_tokens > self.max_context() {
            return Err(format!(
                "request {}: {} prompt + {} output tokens exceeds context window {}",
                req.id,
                req.input_tokens,
                req.output_tokens,
                self.max_context()
            ));
        }
        Ok(())
    }

    // --- chunked prefill (vLLM-style), optional ---------------------
    //
    // Backends that can split prompt prefill into page-granule chunks
    // implement the three methods below; the scheduler then drives
    // *mixed rounds* where prefill chunks and decode steps batch into
    // the same engine launches. The default implementations keep
    // whole-prompt backends (sim, PJRT) on the legacy path.

    /// Does this backend implement `begin_prefill` / `mixed_step`?
    fn supports_chunked_prefill(&self) -> bool {
        false
    }
    /// Stage a prompt for incremental (chunked) prefill into `slot`.
    /// No engine work happens yet; [`Backend::mixed_step`] advances it.
    fn begin_prefill(
        &mut self,
        _slot: usize,
        _req: &Request,
        _tokens: &[u32],
    ) -> anyhow::Result<()> {
        anyhow::bail!("this backend does not support chunked prefill")
    }
    /// Remaining prefill work for a staged slot, in q-row units (a
    /// prompt row counts once per layer it still has to traverse).
    /// 0 when nothing is staged.
    fn staged_rows(&self, _slot: usize) -> usize {
        0
    }
    /// One mixed scheduling round: advance each staged prefill in
    /// `prefill` by up to its `(slot, row_allowance)` and run one decode
    /// step over `active`, with prefill chunks and decode steps batched
    /// into the same engine launches. Returns (elapsed seconds, prefills
    /// that completed this round as `(slot, first_token)`, one decode
    /// token per active slot).
    fn mixed_step(
        &mut self,
        _prefill: &[(usize, usize)],
        _active: &[usize],
    ) -> anyhow::Result<(f64, Vec<(usize, u32)>, Vec<u32>)> {
        anyhow::bail!("this backend does not support chunked prefill")
    }
}

struct Active {
    req: Request,
    slot: usize,
    generated: usize,
    last_token_s: f64,
    metrics: RequestMetrics,
}

/// Scheduling policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Max prefills admitted per scheduling step (vLLM default: prefill
    /// priority, one at a time keeps TTFT fair under load).
    pub max_prefills_per_step: usize,
    /// Host-side execution parallelism, handed to the backend via
    /// [`Backend::configure`]. The engine backend
    /// ([`crate::serve::EngineBackend`]) schedules every active slot's
    /// grid blocks over a worker pool of this many threads; the
    /// simulated backend models a fully parallel device and the PJRT
    /// backend delegates threading to XLA, so both ignore it.
    pub parallelism: crate::exec::Parallelism,
    /// Chunked prefill: split prompt prefill into chunks of this many
    /// q rows (must be a KV-page-granule multiple), issued as engine
    /// jobs that batch with decode steps in the same scheduling round.
    /// 0 disables chunking (whole-prompt prefill, legacy path). Only
    /// honored when [`Backend::supports_chunked_prefill`] is true.
    pub prefill_chunk_tokens: usize,
    /// Per-round prefill budget for the chunked path: at most this many
    /// row-layer units advance per mixed round across all staged
    /// prefills (0 = unbounded). One unit is one prompt row attended at
    /// one layer — a full row costs `layers` units, so at L=1 this is a
    /// plain token budget. Bounds per-round prefill work — and
    /// therefore decode ITL jitter — under long prompts.
    pub prefill_round_tokens: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_prefills_per_step: 1,
            parallelism: crate::exec::Parallelism::sequential(),
            prefill_chunk_tokens: 0,
            prefill_round_tokens: 0,
        }
    }
}

/// Synthesize a deterministic prompt for a request (the trace carries
/// lengths, not text). The stream is seeded by the *conversation* only,
/// so a follow-up turn's (longer) prompt literally extends the previous
/// turn's prompt — the property Mooncake-style prefix caching relies on
/// (turn t+1 re-sends the turn-t history verbatim plus a new message).
pub fn prompt_tokens(req: &Request, vocab: usize) -> Vec<u32> {
    let mut rng = Rng::new(0x9E3779B9 ^ (req.conversation as u64) << 17);
    (0..req.input_tokens)
        .map(|_| (rng.next_u64() % vocab as u64) as u32)
        .collect()
}

/// Run the trace to completion. Returns per-request metrics.
///
/// With `cfg.prefill_chunk_tokens > 0` and a backend that supports it,
/// the chunk-scheduled loop runs instead: prompts prefill incrementally,
/// chunks batching with decode steps in the same engine rounds.
pub fn run_trace(
    backend: &mut dyn Backend,
    trace: &[Request],
    cfg: SchedulerConfig,
    vocab: usize,
) -> anyhow::Result<Vec<RequestMetrics>> {
    backend.configure(&cfg);
    if cfg.prefill_chunk_tokens > 0 && backend.supports_chunked_prefill() {
        return run_trace_chunked(backend, trace, cfg, vocab);
    }
    let n_slots = backend.n_slots();
    let mut clock = 0.0f64;
    let mut pending: VecDeque<Request> = trace.to_vec().into();
    let mut waiting: VecDeque<Request> = VecDeque::new();
    let mut slots: Vec<Option<Active>> = (0..n_slots).map(|_| None).collect();
    let mut done: Vec<RequestMetrics> = Vec::with_capacity(trace.len());
    let compress_arrivals = !backend.is_virtual_time();

    loop {
        // Admit arrivals.
        while let Some(r) = pending.front() {
            let arrived = compress_arrivals || r.arrival_s <= clock;
            if arrived {
                waiting.push_back(pending.pop_front().unwrap());
            } else {
                break;
            }
        }

        let free: Vec<usize> = slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect();

        // Prefill priority (vLLM-style): admit new requests first.
        let mut prefilled = 0;
        for slot in free {
            if prefilled >= cfg.max_prefills_per_step || waiting.is_empty() {
                break;
            }
            let req = waiting.pop_front().unwrap();
            if let Err(why) = backend.admit_check(&req) {
                anyhow::bail!("inadmissible request: {why}");
            }
            let tokens = prompt_tokens(&req, vocab);
            let (dt, _tok) = backend.prefill(slot, &req, &tokens)?;
            clock += dt;
            let arrival = if compress_arrivals { clock - dt } else { req.arrival_s };
            let metrics = RequestMetrics {
                id: req.id,
                arrival_s: arrival,
                first_token_s: clock,
                done_s: clock,
                input_tokens: req.input_tokens,
                output_tokens: req.output_tokens,
                itls: vec![],
            };
            if req.output_tokens <= 1 {
                // Single-token request: complete at prefill, no decode.
                let mut m = metrics;
                m.done_s = clock;
                backend.release(slot);
                done.push(m);
            } else {
                slots[slot] = Some(Active {
                    slot,
                    generated: 1,
                    last_token_s: clock,
                    metrics,
                    req,
                });
            }
            prefilled += 1;
        }

        // One batched decode step over all active slots.
        let active: Vec<usize> = slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_some())
            .map(|(i, _)| i)
            .collect();
        if !active.is_empty() {
            let (dt, _toks) = backend.decode(&active)?;
            clock += dt;
            for &si in &active {
                let a = slots[si].as_mut().unwrap();
                a.metrics.itls.push(clock - a.last_token_s);
                a.last_token_s = clock;
                a.generated += 1;
                if a.generated >= a.req.output_tokens.max(1) {
                    let mut fin = slots[si].take().unwrap();
                    fin.metrics.done_s = clock;
                    backend.release(fin.slot);
                    done.push(fin.metrics);
                }
            }
        } else if waiting.is_empty() {
            match pending.front() {
                Some(r) => clock = clock.max(r.arrival_s), // idle until next arrival
                None => break,
            }
        }
    }

    done.sort_by_key(|m| m.id);
    Ok(done)
}

/// The chunk-scheduled serving loop: staged prefills advance by a
/// per-round token budget while active slots decode, and the backend
/// batches both kinds of work into the same engine rounds
/// ([`Backend::mixed_step`]). TTFT is paid incrementally — a long prompt
/// no longer stalls every decoding request for its whole prefill.
fn run_trace_chunked(
    backend: &mut dyn Backend,
    trace: &[Request],
    cfg: SchedulerConfig,
    vocab: usize,
) -> anyhow::Result<Vec<RequestMetrics>> {
    let n_slots = backend.n_slots();
    let mut clock = 0.0f64;
    let mut pending: VecDeque<Request> = trace.to_vec().into();
    let mut waiting: VecDeque<Request> = VecDeque::new();
    // A slot is either decoding (`slots`), mid-prefill (`prefilling`,
    // with FIFO admission order in `prefill_order`), or free.
    let mut slots: Vec<Option<Active>> = (0..n_slots).map(|_| None).collect();
    let mut prefilling: Vec<Option<(Request, f64)>> = (0..n_slots).map(|_| None).collect();
    let mut prefill_order: Vec<usize> = Vec::new();
    let mut done: Vec<RequestMetrics> = Vec::with_capacity(trace.len());
    let compress_arrivals = !backend.is_virtual_time();

    loop {
        // Admit arrivals.
        while let Some(r) = pending.front() {
            if compress_arrivals || r.arrival_s <= clock {
                waiting.push_back(pending.pop_front().unwrap());
            } else {
                break;
            }
        }

        // Stage new prefills into free slots (prefill priority).
        let mut admitted = 0;
        for si in 0..n_slots {
            if admitted >= cfg.max_prefills_per_step || waiting.is_empty() {
                break;
            }
            if slots[si].is_some() || prefilling[si].is_some() {
                continue;
            }
            let req = waiting.pop_front().unwrap();
            if let Err(why) = backend.admit_check(&req) {
                anyhow::bail!("inadmissible request: {why}");
            }
            let tokens = prompt_tokens(&req, vocab);
            backend.begin_prefill(si, &req, &tokens)?;
            let arrival = if compress_arrivals { clock } else { req.arrival_s };
            prefilling[si] = Some((req, arrival));
            prefill_order.push(si);
            admitted += 1;
        }

        // Allocate the round's prefill budget FIFO over staged slots.
        let mut budget = if cfg.prefill_round_tokens == 0 {
            usize::MAX
        } else {
            cfg.prefill_round_tokens
        };
        let mut work: Vec<(usize, usize)> = Vec::new();
        for &si in &prefill_order {
            if budget == 0 {
                break;
            }
            let rows = backend.staged_rows(si).min(budget);
            if rows > 0 {
                work.push((si, rows));
                budget -= rows;
            }
        }

        let active: Vec<usize> = slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_some())
            .map(|(i, _)| i)
            .collect();

        if work.is_empty() && active.is_empty() {
            match pending.front() {
                Some(r) => clock = clock.max(r.arrival_s), // idle until next arrival
                None if waiting.is_empty() => break,
                None => continue,
            }
            continue;
        }

        // One mixed round: prefill chunks + the batched decode step.
        let (dt, finished, _toks) = backend.mixed_step(&work, &active)?;
        clock += dt;

        for &si in &active {
            let a = slots[si].as_mut().unwrap();
            a.metrics.itls.push(clock - a.last_token_s);
            a.last_token_s = clock;
            a.generated += 1;
            if a.generated >= a.req.output_tokens.max(1) {
                let mut fin = slots[si].take().unwrap();
                fin.metrics.done_s = clock;
                backend.release(fin.slot);
                done.push(fin.metrics);
            }
        }

        for (si, _tok) in finished {
            prefill_order.retain(|&s| s != si);
            let (req, arrival) = prefilling[si].take().expect("finished an unstaged slot");
            let metrics = RequestMetrics {
                id: req.id,
                arrival_s: arrival,
                first_token_s: clock,
                done_s: clock,
                input_tokens: req.input_tokens,
                output_tokens: req.output_tokens,
                itls: vec![],
            };
            if req.output_tokens <= 1 {
                backend.release(si);
                done.push(metrics);
            } else {
                slots[si] = Some(Active {
                    slot: si,
                    generated: 1,
                    last_token_s: clock,
                    metrics,
                    req,
                });
            }
        }
    }

    done.sort_by_key(|m| m.id);
    Ok(done)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracegen::{generate, TraceConfig};

    /// Deterministic toy backend for scheduler invariants.
    struct ToyBackend {
        slots: usize,
        busy: Vec<bool>,
        prefills: usize,
        decodes: usize,
    }

    impl Backend for ToyBackend {
        fn n_slots(&self) -> usize {
            self.slots
        }
        fn max_context(&self) -> usize {
            4096
        }
        fn prefill(
            &mut self,
            slot: usize,
            _req: &Request,
            tokens: &[u32],
        ) -> anyhow::Result<(f64, u32)> {
            assert!(!self.busy[slot], "slot aliasing: {slot} already busy");
            self.busy[slot] = true;
            self.prefills += 1;
            Ok((1e-3 * tokens.len() as f64 / 100.0, 1))
        }
        fn decode(&mut self, active: &[usize]) -> anyhow::Result<(f64, Vec<u32>)> {
            for &s in active {
                assert!(self.busy[s], "decoding a free slot");
            }
            self.decodes += 1;
            Ok((1e-3, vec![2; active.len()]))
        }
        fn release(&mut self, slot: usize) {
            assert!(self.busy[slot]);
            self.busy[slot] = false;
        }
        fn is_virtual_time(&self) -> bool {
            true
        }
    }

    #[test]
    fn all_requests_complete_with_correct_token_counts() {
        let trace = generate(&TraceConfig {
            n_requests: 64,
            ..Default::default()
        });
        let mut b = ToyBackend {
            slots: 4,
            busy: vec![false; 4],
            prefills: 0,
            decodes: 0,
        };
        let done = run_trace(&mut b, &trace, SchedulerConfig::default(), 512).unwrap();
        assert_eq!(done.len(), 64);
        assert_eq!(b.prefills, 64);
        for (m, r) in done.iter().zip(&trace) {
            assert_eq!(m.id, r.id);
            // generated = output_tokens; itls = output_tokens - 1
            assert_eq!(m.itls.len(), r.output_tokens.max(1) - 1);
            assert!(m.first_token_s >= m.arrival_s, "TTFT must be non-negative");
            assert!(m.done_s >= m.first_token_s);
        }
    }

    #[test]
    fn fifo_order_of_first_tokens() {
        // With prefill priority and a FIFO waiting queue, first tokens
        // are emitted in arrival order.
        let trace = generate(&TraceConfig {
            n_requests: 32,
            rate: 1000.0, // all arrive ~simultaneously: pure queueing
            ..Default::default()
        });
        let mut b = ToyBackend {
            slots: 2,
            busy: vec![false; 2],
            prefills: 0,
            decodes: 0,
        };
        let done = run_trace(&mut b, &trace, SchedulerConfig::default(), 512).unwrap();
        let mut by_id = done.clone();
        by_id.sort_by_key(|m| m.id);
        for w in by_id.windows(2) {
            assert!(
                w[0].first_token_s <= w[1].first_token_s + 1e-12,
                "FIFO violated"
            );
        }
    }

    #[test]
    fn prompt_tokens_deterministic_and_in_vocab() {
        let trace = generate(&TraceConfig::default());
        for r in trace.iter().take(10) {
            let a = prompt_tokens(r, 512);
            let b = prompt_tokens(r, 512);
            assert_eq!(a, b);
            assert_eq!(a.len(), r.input_tokens);
            assert!(a.iter().all(|&t| t < 512));
        }
    }
}
