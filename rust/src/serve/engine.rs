//! The serving coordinator: a vLLM-style continuous batcher with
//! prefill-priority scheduling, slot-based KV-cache management, and a
//! discrete-event clock that works for both the virtual-time simulated
//! backend (Fig 5) and the real PJRT backend (wall time).

use std::collections::VecDeque;

use crate::tracegen::{Request, Rng};

use super::metrics::RequestMetrics;

/// A serving backend: owns the model + KV state per slot.
pub trait Backend {
    fn n_slots(&self) -> usize;
    fn max_context(&self) -> usize;
    /// Called once by [`run_trace`] before any work: backends that
    /// execute real plans pick up [`SchedulerConfig::parallelism`] here.
    fn configure(&mut self, _cfg: &SchedulerConfig) {}
    /// Run a prefill for `tokens` in `slot`; returns (elapsed seconds,
    /// first generated token). The request is passed for conversation
    /// identity (prefix-cache reuse across turns).
    fn prefill(&mut self, slot: usize, req: &Request, tokens: &[u32])
        -> anyhow::Result<(f64, u32)>;
    /// Run one batched decode step over `active` slots; returns
    /// (elapsed seconds, one generated token per active slot).
    fn decode(&mut self, active: &[usize]) -> anyhow::Result<(f64, Vec<u32>)>;
    /// Free a slot's KV state.
    fn release(&mut self, slot: usize);
    /// Virtual-time backends advance the clock by their returned times;
    /// wall-time backends (PJRT) also do, but arrivals are compressed.
    fn is_virtual_time(&self) -> bool;
}

struct Active {
    req: Request,
    slot: usize,
    generated: usize,
    last_token_s: f64,
    metrics: RequestMetrics,
}

/// Scheduling policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Max prefills admitted per scheduling step (vLLM default: prefill
    /// priority, one at a time keeps TTFT fair under load).
    pub max_prefills_per_step: usize,
    /// Host-side execution parallelism, handed to the backend via
    /// [`Backend::configure`]. The engine backend
    /// ([`crate::serve::EngineBackend`]) schedules every active slot's
    /// grid blocks over a worker pool of this many threads; the
    /// simulated backend models a fully parallel device and the PJRT
    /// backend delegates threading to XLA, so both ignore it.
    pub parallelism: crate::exec::Parallelism,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_prefills_per_step: 1,
            parallelism: crate::exec::Parallelism::sequential(),
        }
    }
}

/// Synthesize a deterministic prompt for a request (the trace carries
/// lengths, not text).
pub fn prompt_tokens(req: &Request, vocab: usize) -> Vec<u32> {
    let mut rng = Rng::new(0x9E3779B9 ^ (req.conversation as u64) << 17 ^ req.turn as u64);
    (0..req.input_tokens)
        .map(|_| (rng.next_u64() % vocab as u64) as u32)
        .collect()
}

/// Run the trace to completion. Returns per-request metrics.
pub fn run_trace(
    backend: &mut dyn Backend,
    trace: &[Request],
    cfg: SchedulerConfig,
    vocab: usize,
) -> anyhow::Result<Vec<RequestMetrics>> {
    backend.configure(&cfg);
    let n_slots = backend.n_slots();
    let mut clock = 0.0f64;
    let mut pending: VecDeque<Request> = trace.to_vec().into();
    let mut waiting: VecDeque<Request> = VecDeque::new();
    let mut slots: Vec<Option<Active>> = (0..n_slots).map(|_| None).collect();
    let mut done: Vec<RequestMetrics> = Vec::with_capacity(trace.len());
    let compress_arrivals = !backend.is_virtual_time();

    loop {
        // Admit arrivals.
        while let Some(r) = pending.front() {
            let arrived = compress_arrivals || r.arrival_s <= clock;
            if arrived {
                waiting.push_back(pending.pop_front().unwrap());
            } else {
                break;
            }
        }

        let free: Vec<usize> = slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect();

        // Prefill priority (vLLM-style): admit new requests first.
        let mut prefilled = 0;
        for slot in free {
            if prefilled >= cfg.max_prefills_per_step || waiting.is_empty() {
                break;
            }
            let req = waiting.pop_front().unwrap();
            if req.input_tokens + req.output_tokens > backend.max_context() {
                anyhow::bail!("request {} exceeds context window", req.id);
            }
            let tokens = prompt_tokens(&req, vocab);
            let (dt, _tok) = backend.prefill(slot, &req, &tokens)?;
            clock += dt;
            let arrival = if compress_arrivals { clock - dt } else { req.arrival_s };
            let metrics = RequestMetrics {
                id: req.id,
                arrival_s: arrival,
                first_token_s: clock,
                done_s: clock,
                input_tokens: req.input_tokens,
                output_tokens: req.output_tokens,
                itls: vec![],
            };
            if req.output_tokens <= 1 {
                // Single-token request: complete at prefill, no decode.
                let mut m = metrics;
                m.done_s = clock;
                backend.release(slot);
                done.push(m);
            } else {
                slots[slot] = Some(Active {
                    slot,
                    generated: 1,
                    last_token_s: clock,
                    metrics,
                    req,
                });
            }
            prefilled += 1;
        }

        // One batched decode step over all active slots.
        let active: Vec<usize> = slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_some())
            .map(|(i, _)| i)
            .collect();
        if !active.is_empty() {
            let (dt, _toks) = backend.decode(&active)?;
            clock += dt;
            for &si in &active {
                let a = slots[si].as_mut().unwrap();
                a.metrics.itls.push(clock - a.last_token_s);
                a.last_token_s = clock;
                a.generated += 1;
                if a.generated >= a.req.output_tokens.max(1) {
                    let mut fin = slots[si].take().unwrap();
                    fin.metrics.done_s = clock;
                    backend.release(fin.slot);
                    done.push(fin.metrics);
                }
            }
        } else if waiting.is_empty() {
            match pending.front() {
                Some(r) => clock = clock.max(r.arrival_s), // idle until next arrival
                None => break,
            }
        }
    }

    done.sort_by_key(|m| m.id);
    Ok(done)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracegen::{generate, TraceConfig};

    /// Deterministic toy backend for scheduler invariants.
    struct ToyBackend {
        slots: usize,
        busy: Vec<bool>,
        prefills: usize,
        decodes: usize,
    }

    impl Backend for ToyBackend {
        fn n_slots(&self) -> usize {
            self.slots
        }
        fn max_context(&self) -> usize {
            4096
        }
        fn prefill(
            &mut self,
            slot: usize,
            _req: &Request,
            tokens: &[u32],
        ) -> anyhow::Result<(f64, u32)> {
            assert!(!self.busy[slot], "slot aliasing: {slot} already busy");
            self.busy[slot] = true;
            self.prefills += 1;
            Ok((1e-3 * tokens.len() as f64 / 100.0, 1))
        }
        fn decode(&mut self, active: &[usize]) -> anyhow::Result<(f64, Vec<u32>)> {
            for &s in active {
                assert!(self.busy[s], "decoding a free slot");
            }
            self.decodes += 1;
            Ok((1e-3, vec![2; active.len()]))
        }
        fn release(&mut self, slot: usize) {
            assert!(self.busy[slot]);
            self.busy[slot] = false;
        }
        fn is_virtual_time(&self) -> bool {
            true
        }
    }

    #[test]
    fn all_requests_complete_with_correct_token_counts() {
        let trace = generate(&TraceConfig {
            n_requests: 64,
            ..Default::default()
        });
        let mut b = ToyBackend {
            slots: 4,
            busy: vec![false; 4],
            prefills: 0,
            decodes: 0,
        };
        let done = run_trace(&mut b, &trace, SchedulerConfig::default(), 512).unwrap();
        assert_eq!(done.len(), 64);
        assert_eq!(b.prefills, 64);
        for (m, r) in done.iter().zip(&trace) {
            assert_eq!(m.id, r.id);
            // generated = output_tokens; itls = output_tokens - 1
            assert_eq!(m.itls.len(), r.output_tokens.max(1) - 1);
            assert!(m.first_token_s >= m.arrival_s, "TTFT must be non-negative");
            assert!(m.done_s >= m.first_token_s);
        }
    }

    #[test]
    fn fifo_order_of_first_tokens() {
        // With prefill priority and a FIFO waiting queue, first tokens
        // are emitted in arrival order.
        let trace = generate(&TraceConfig {
            n_requests: 32,
            rate: 1000.0, // all arrive ~simultaneously: pure queueing
            ..Default::default()
        });
        let mut b = ToyBackend {
            slots: 2,
            busy: vec![false; 2],
            prefills: 0,
            decodes: 0,
        };
        let done = run_trace(&mut b, &trace, SchedulerConfig::default(), 512).unwrap();
        let mut by_id = done.clone();
        by_id.sort_by_key(|m| m.id);
        for w in by_id.windows(2) {
            assert!(
                w[0].first_token_s <= w[1].first_token_s + 1e-12,
                "FIFO violated"
            );
        }
    }

    #[test]
    fn prompt_tokens_deterministic_and_in_vocab() {
        let trace = generate(&TraceConfig::default());
        for r in trace.iter().take(10) {
            let a = prompt_tokens(r, 512);
            let b = prompt_tokens(r, 512);
            assert_eq!(a, b);
            assert_eq!(a.len(), r.input_tokens);
            assert!(a.iter().all(|&t| t < 512));
        }
    }
}
