//! Deterministic fault injection for the serving lifecycle.
//!
//! A [`FaultPlan`] is a *schedule* of adverse events keyed by lifecycle
//! round number — page-pool pressure windows, worker panics, client
//! cancels, deadline storms. The lifecycle runner consults the plan at
//! the top of every round, so a given (trace, scheduler config, plan)
//! triple replays bit-identically: the chaos harness asserts that every
//! request still reaches exactly one terminal state, that no KV pages
//! leak, and that the survivors' token streams match the fault-free
//! run bit for bit.
//!
//! Plans come from three places:
//!
//! * [`FaultPlan::parse`] — a compact spec string, e.g.
//!   `pressure@3:2x4;panic@5;cancel@7:2;storm@9:2`;
//! * [`FaultPlan::generate`] — a seeded random schedule (`seed=42` in
//!   spec form), for chaos sweeps;
//! * [`FaultPlan::from_env`] — either of the above via the
//!   `FLASHLIGHT_FAULTS` environment variable (CLI entry points only;
//!   library code never reads the environment).

use crate::tracegen::Rng;

/// Environment variable the CLI reads fault specs from.
pub const FAULTS_ENV: &str = "FLASHLIGHT_FAULTS";

/// One scheduled adverse event. `round` is the lifecycle round the
/// event fires at (pressure events span `[round, round + rounds)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Withhold `pages` KV pages from availability for `rounds`
    /// consecutive rounds — simulated pool exhaustion the scheduler
    /// must degrade around (evict prefixes, preempt, throttle).
    PagePressure {
        round: u64,
        pages: usize,
        rounds: u64,
    },
    /// Poison grid item `item` of the round's first engine launch: the
    /// worker panics, the runtime attributes it, and exactly one
    /// request must fail while the pool and the rest of the batch
    /// continue.
    WorkerPanic { round: u64, item: usize },
    /// Client cancel of request `id` at the top of the round.
    Cancel { round: u64, id: usize },
    /// Deadline storm: every `every`-th in-flight request's deadline
    /// collapses to "now" at the top of the round.
    DeadlineStorm { round: u64, every: usize },
    /// Stall grid item `item` of the round's first engine launch: the
    /// worker stops making progress (heartbeats cease) until the
    /// supervisor's watchdog kills the launch, which attributes the
    /// stall like a panic — exactly one request fails and the
    /// surviving batch re-executes bit-identically.
    StalledLaunch { round: u64, item: usize },
    /// Kill engine shard `shard` outright at the top of round `round`:
    /// its page pool, plan cache, and parked prefixes are gone; every
    /// request in flight on it must be attributed and re-sharded onto
    /// the survivors. A *router-level* event — the per-shard lifecycle
    /// never sees it ([`FaultPlan::events_at`] filters it out, like
    /// pressure windows); [`crate::serve::run_sharded`] consumes it via
    /// [`FaultPlan::shard_kills`].
    ShardKill { round: u64, shard: usize },
}

impl Fault {
    /// The round this event first applies to.
    pub fn round(&self) -> u64 {
        match *self {
            Fault::PagePressure { round, .. }
            | Fault::WorkerPanic { round, .. }
            | Fault::Cancel { round, .. }
            | Fault::DeadlineStorm { round, .. }
            | Fault::StalledLaunch { round, .. }
            | Fault::ShardKill { round, .. } => round,
        }
    }
}

impl std::fmt::Display for Fault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Fault::PagePressure {
                round,
                pages,
                rounds,
            } => write!(f, "pressure@{round}:{pages}x{rounds}"),
            Fault::WorkerPanic { round, item } => write!(f, "panic@{round}:{item}"),
            Fault::Cancel { round, id } => write!(f, "cancel@{round}:{id}"),
            Fault::DeadlineStorm { round, every } => write!(f, "storm@{round}:{every}"),
            Fault::StalledLaunch { round, item } => write!(f, "stall@{round}:{item}"),
            Fault::ShardKill { round, shard } => write!(f, "kill@{round}:shard={shard}"),
        }
    }
}

/// A deterministic schedule of [`Fault`] events, sorted by round.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub events: Vec<Fault>,
}

/// Round-trips through [`FaultPlan::parse`]: the display form of any
/// plan (including generated ones) is itself a valid spec.
impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                write!(f, ";")?;
            }
            write!(f, "{e}")?;
        }
        Ok(())
    }
}

impl FaultPlan {
    /// The empty plan: a fault-free run.
    pub fn none() -> Self {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse a spec string: either `seed=N[@ROUNDS]` (a generated
    /// schedule over a `ROUNDS`-round horizon, default 64) or a
    /// `;`-separated event list:
    ///
    /// * `pressure@R:PxD` — withhold `P` pages for `D` rounds from `R`
    /// * `panic@R[:I]`    — poison grid item `I` (default 0) at `R`
    /// * `cancel@R:ID`    — cancel request `ID` at round `R`
    /// * `storm@R[:H]`    — collapse every `H`-th (default every)
    ///   in-flight deadline at round `R`
    /// * `stall@R[:I]`    — stall grid item `I` (default 0) at `R`
    ///   until the watchdog kills the launch
    /// * `kill@R:shard=S` — kill engine shard `S` at round `R`
    ///   (sharded serving only; the router fails it over)
    ///
    /// The empty string parses to the empty plan.
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Ok(FaultPlan::none());
        }
        if let Some(rest) = spec.strip_prefix("seed=") {
            let (seed, rounds) = match rest.split_once('@') {
                Some((s, r)) => (
                    s.parse::<u64>()
                        .map_err(|e| anyhow::anyhow!("bad fault seed {s:?}: {e}"))?,
                    r.parse::<u64>()
                        .map_err(|e| anyhow::anyhow!("bad fault horizon {r:?}: {e}"))?,
                ),
                None => (
                    rest.parse::<u64>()
                        .map_err(|e| anyhow::anyhow!("bad fault seed {rest:?}: {e}"))?,
                    64,
                ),
            };
            return Ok(FaultPlan::generate(seed, rounds));
        }
        let mut events = Vec::new();
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (kind, at) = part
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("fault event {part:?} missing '@round'"))?;
            let (round_s, args) = match at.split_once(':') {
                Some((r, a)) => (r, Some(a)),
                None => (at, None),
            };
            let round: u64 = round_s
                .parse()
                .map_err(|e| anyhow::anyhow!("bad round in {part:?}: {e}"))?;
            let ev = match kind {
                "pressure" => {
                    let a = args
                        .ok_or_else(|| anyhow::anyhow!("pressure needs ':PAGESxROUNDS' ({part:?})"))?;
                    let (p, d) = a
                        .split_once('x')
                        .ok_or_else(|| anyhow::anyhow!("pressure needs 'PAGESxROUNDS' ({part:?})"))?;
                    Fault::PagePressure {
                        round,
                        pages: p
                            .parse()
                            .map_err(|e| anyhow::anyhow!("bad pages in {part:?}: {e}"))?,
                        rounds: d
                            .parse()
                            .map_err(|e| anyhow::anyhow!("bad duration in {part:?}: {e}"))?,
                    }
                }
                "panic" => Fault::WorkerPanic {
                    round,
                    item: match args {
                        Some(a) => a
                            .parse()
                            .map_err(|e| anyhow::anyhow!("bad item in {part:?}: {e}"))?,
                        None => 0,
                    },
                },
                "cancel" => Fault::Cancel {
                    round,
                    id: args
                        .ok_or_else(|| anyhow::anyhow!("cancel needs ':REQUEST_ID' ({part:?})"))?
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad request id in {part:?}: {e}"))?,
                },
                "storm" => Fault::DeadlineStorm {
                    round,
                    every: match args {
                        Some(a) => a
                            .parse::<usize>()
                            .map_err(|e| anyhow::anyhow!("bad stride in {part:?}: {e}"))?
                            .max(1),
                        None => 1,
                    },
                },
                "stall" => Fault::StalledLaunch {
                    round,
                    item: match args {
                        Some(a) => a
                            .parse()
                            .map_err(|e| anyhow::anyhow!("bad item in {part:?}: {e}"))?,
                        None => 0,
                    },
                },
                "kill" => Fault::ShardKill {
                    round,
                    shard: args
                        .ok_or_else(|| anyhow::anyhow!("kill needs ':shard=S' ({part:?})"))?
                        .strip_prefix("shard=")
                        .ok_or_else(|| anyhow::anyhow!("kill needs ':shard=S' ({part:?})"))?
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad shard in {part:?}: {e}"))?,
                },
                other => anyhow::bail!("unknown fault kind {other:?} in {part:?}"),
            };
            events.push(ev);
        }
        let mut plan = FaultPlan { events };
        plan.events.sort_by_key(|e| e.round());
        Ok(plan)
    }

    /// Read a plan from `FLASHLIGHT_FAULTS` (unset or empty = no
    /// faults). CLI entry points only — library code takes plans as
    /// values.
    pub fn from_env() -> anyhow::Result<Self> {
        match std::env::var(FAULTS_ENV) {
            Ok(spec) => FaultPlan::parse(&spec),
            Err(_) => Ok(FaultPlan::none()),
        }
    }

    /// A seeded random schedule over a `rounds`-round horizon: a
    /// handful of pressure windows, panics, cancels, and storms whose
    /// placement is a pure function of `seed` — the chaos harness runs
    /// the same plan twice and asserts byte-identical outcomes.
    pub fn generate(seed: u64, rounds: u64) -> Self {
        let horizon = rounds.max(1);
        let mut rng = Rng::new(seed | 1);
        let n = 3 + (rng.next_u64() % 4) as usize;
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            let round = rng.next_u64() % horizon;
            events.push(match rng.next_u64() % 5 {
                0 => Fault::PagePressure {
                    round,
                    pages: 1 + (rng.next_u64() % 4) as usize,
                    rounds: 1 + rng.next_u64() % 6,
                },
                1 => Fault::WorkerPanic {
                    round,
                    item: (rng.next_u64() % 8) as usize,
                },
                2 => Fault::Cancel {
                    round,
                    id: (rng.next_u64() % 16) as usize,
                },
                3 => Fault::DeadlineStorm {
                    round,
                    every: 1 + (rng.next_u64() % 3) as usize,
                },
                _ => Fault::StalledLaunch {
                    round,
                    item: (rng.next_u64() % 8) as usize,
                },
            });
        }
        events.sort_by_key(|e| e.round());
        FaultPlan { events }
    }

    /// A seeded schedule for *sharded* chaos: the [`FaultPlan::generate`]
    /// event mix plus one or two [`Fault::ShardKill`] events targeting
    /// shards `< n_shards`, placed in the middle half of the horizon so
    /// the kill lands while requests are genuinely in flight. A separate
    /// generator (rather than a sixth kind inside `generate`) so every
    /// existing seeded single-instance plan replays byte-identically.
    pub fn generate_sharded(seed: u64, rounds: u64, n_shards: usize) -> Self {
        let mut plan = FaultPlan::generate(seed, rounds);
        let horizon = rounds.max(4);
        let mut rng = Rng::new((seed | 1).rotate_left(17) ^ 0x5bd1e995);
        let kills = 1 + (rng.next_u64() % 2) as usize;
        for _ in 0..kills.min(n_shards.saturating_sub(1)) {
            plan.events.push(Fault::ShardKill {
                round: horizon / 4 + rng.next_u64() % (horizon / 2).max(1),
                shard: (rng.next_u64() % n_shards.max(1) as u64) as usize,
            });
        }
        plan.events.sort_by_key(|e| e.round());
        plan
    }

    /// The point events (panic / cancel / storm / stall) firing exactly
    /// at `round`, in plan order. Pressure windows are queried
    /// separately via [`FaultPlan::pressure_at`] because they span
    /// rounds, and shard kills via [`FaultPlan::shard_kills`] because
    /// they are handled by the router, not the per-shard lifecycle.
    pub fn events_at(&self, round: u64) -> impl Iterator<Item = &Fault> {
        self.events.iter().filter(move |e| {
            e.round() == round
                && !matches!(e, Fault::PagePressure { .. } | Fault::ShardKill { .. })
        })
    }

    /// Every scheduled shard kill, as `(round, shard)` in plan order.
    /// Consumed by the sharded router ([`crate::serve::run_sharded`]);
    /// the single-instance lifecycle ignores these events entirely.
    pub fn shard_kills(&self) -> Vec<(u64, usize)> {
        self.events
            .iter()
            .filter_map(|e| match *e {
                Fault::ShardKill { round, shard } => Some((round, shard)),
                _ => None,
            })
            .collect()
    }

    /// Total KV pages withheld at `round`: the sum of all pressure
    /// windows covering it.
    pub fn pressure_at(&self, round: u64) -> usize {
        self.events
            .iter()
            .filter_map(|e| match *e {
                Fault::PagePressure {
                    round: r,
                    pages,
                    rounds,
                } if round >= r && round < r.saturating_add(rounds) => Some(pages),
                _ => None,
            })
            .sum()
    }

    /// Whether the plan contains any [`Fault::StalledLaunch`] event.
    /// The lifecycle auto-starts a watchdog supervisor for such plans
    /// so a stalled launch is always killed rather than blocking the
    /// round loop forever.
    pub fn has_stalls(&self) -> bool {
        self.events
            .iter()
            .any(|e| matches!(e, Fault::StalledLaunch { .. }))
    }

    /// The last round any event in the plan touches (0 for an empty
    /// plan) — runners keep stepping at least this far so late faults
    /// are not silently skipped on short traces.
    pub fn horizon(&self) -> u64 {
        self.events
            .iter()
            .map(|e| match *e {
                Fault::PagePressure { round, rounds, .. } => {
                    round.saturating_add(rounds)
                }
                other => other.round(),
            })
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_event_kind() {
        let plan = FaultPlan::parse(
            "pressure@3:2x4; panic@5:1; cancel@7:2; storm@9:2; stall@11:3; kill@13:shard=1;",
        )
        .unwrap();
        assert_eq!(
            plan.events,
            vec![
                Fault::PagePressure {
                    round: 3,
                    pages: 2,
                    rounds: 4
                },
                Fault::WorkerPanic { round: 5, item: 1 },
                Fault::Cancel { round: 7, id: 2 },
                Fault::DeadlineStorm { round: 9, every: 2 },
                Fault::StalledLaunch { round: 11, item: 3 },
                Fault::ShardKill { round: 13, shard: 1 },
            ]
        );
        // Display form re-parses to the same plan.
        let spec: Vec<String> = plan.events.iter().map(|e| e.to_string()).collect();
        assert_eq!(FaultPlan::parse(&spec.join(";")).unwrap(), plan);
    }

    #[test]
    fn parse_defaults_and_errors() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert_eq!(
            FaultPlan::parse("panic@4").unwrap().events,
            vec![Fault::WorkerPanic { round: 4, item: 0 }]
        );
        assert_eq!(
            FaultPlan::parse("storm@2").unwrap().events,
            vec![Fault::DeadlineStorm { round: 2, every: 1 }]
        );
        assert_eq!(
            FaultPlan::parse("stall@6").unwrap().events,
            vec![Fault::StalledLaunch { round: 6, item: 0 }]
        );
        for bad in [
            "pressure@1",
            "cancel@1",
            "blorp@3",
            "panic",
            "panic@x",
            "stall@x",
            "kill@2",
            "kill@2:1",
            "kill@2:shard=x",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn shard_kills_are_router_level_events() {
        let plan = FaultPlan::parse("kill@4:shard=2;panic@4;kill@9:shard=0").unwrap();
        assert_eq!(plan.shard_kills(), vec![(4, 2), (9, 0)]);
        // The per-shard lifecycle never sees them as point events...
        assert_eq!(
            plan.events_at(4).collect::<Vec<_>>(),
            vec![&Fault::WorkerPanic { round: 4, item: 0 }]
        );
        assert_eq!(plan.events_at(9).count(), 0);
        // ...but they do extend the horizon so short traces still reach
        // the kill round.
        assert_eq!(plan.horizon(), 9);
    }

    #[test]
    fn pressure_windows_span_and_stack() {
        let plan = FaultPlan::parse("pressure@2:3x2;pressure@3:1x3").unwrap();
        assert_eq!(plan.pressure_at(1), 0);
        assert_eq!(plan.pressure_at(2), 3);
        assert_eq!(plan.pressure_at(3), 4); // both windows cover round 3
        assert_eq!(plan.pressure_at(4), 1);
        assert_eq!(plan.pressure_at(5), 1);
        assert_eq!(plan.pressure_at(6), 0);
        assert_eq!(plan.horizon(), 6);
        // Pressure never shows up as a point event.
        assert_eq!(plan.events_at(2).count(), 0);
    }

    #[test]
    fn generated_plans_are_deterministic_and_seed_sensitive() {
        let a = FaultPlan::generate(42, 64);
        let b = FaultPlan::generate(42, 64);
        assert_eq!(a, b, "same seed must replay the same plan");
        assert!(!a.is_empty());
        assert!(a.events.iter().all(|e| e.round() < 64));
        let c = FaultPlan::generate(43, 64);
        assert_ne!(a, c, "different seeds must differ");
        // The seed= spec form reaches the same generator.
        assert_eq!(FaultPlan::parse("seed=42@64").unwrap(), a);
        assert_eq!(FaultPlan::parse("seed=42").unwrap(), a);
    }

    #[test]
    fn sharded_generator_adds_kills_without_touching_the_base_plan() {
        for seed in 0..32u64 {
            let base = FaultPlan::generate(seed, 64);
            let sharded = FaultPlan::generate_sharded(seed, 64, 4);
            let kills = sharded.shard_kills();
            assert!(!kills.is_empty(), "seed {seed} generated no shard kill");
            assert!(kills.len() < 4, "must leave at least one survivor");
            assert!(kills.iter().all(|&(r, s)| r < 64 && s < 4));
            // Removing the kills recovers exactly the base schedule —
            // sharded chaos replays the same single-instance faults.
            let mut stripped = sharded.clone();
            stripped
                .events
                .retain(|e| !matches!(e, Fault::ShardKill { .. }));
            assert_eq!(stripped, base, "seed {seed} perturbed the base plan");
        }
    }

    /// Satellite: the Display↔parse round-trip holds for *generated*
    /// multi-event plans, not only the hand-written cases above. Plans
    /// are drawn from the repo's own deterministic RNG: all six event
    /// kinds with randomized parameters, plus every seeded generator
    /// output.
    #[test]
    fn display_parse_round_trip_property() {
        let mut rng = Rng::new(0xfa_17_5);
        for case in 0..256 {
            let n = 1 + (rng.next_u64() % 8) as usize;
            let mut events = Vec::with_capacity(n);
            for _ in 0..n {
                let round = rng.next_u64() % 100;
                events.push(match rng.next_u64() % 6 {
                    0 => Fault::PagePressure {
                        round,
                        pages: (rng.next_u64() % 100) as usize,
                        rounds: rng.next_u64() % 100,
                    },
                    1 => Fault::WorkerPanic {
                        round,
                        item: (rng.next_u64() % 100) as usize,
                    },
                    2 => Fault::Cancel {
                        round,
                        id: (rng.next_u64() % 1000) as usize,
                    },
                    3 => Fault::DeadlineStorm {
                        round,
                        every: 1 + (rng.next_u64() % 9) as usize,
                    },
                    4 => Fault::StalledLaunch {
                        round,
                        item: (rng.next_u64() % 100) as usize,
                    },
                    _ => Fault::ShardKill {
                        round,
                        shard: (rng.next_u64() % 8) as usize,
                    },
                });
            }
            // parse() sorts by round (stably), so compare against the
            // sorted form — which Display then preserves verbatim.
            events.sort_by_key(|e| e.round());
            let plan = FaultPlan { events };
            let spec = plan.to_string();
            let reparsed = FaultPlan::parse(&spec)
                .unwrap_or_else(|e| panic!("case {case}: {spec:?} failed to parse: {e}"));
            assert_eq!(reparsed, plan, "case {case}: {spec:?} did not round-trip");
        }
        // Seeded generator outputs round-trip too (both generators).
        for seed in 0..64u64 {
            for plan in [
                FaultPlan::generate(seed, 48),
                FaultPlan::generate_sharded(seed, 48, 4),
            ] {
                assert_eq!(
                    FaultPlan::parse(&plan.to_string()).unwrap(),
                    plan,
                    "seed {seed} did not round-trip"
                );
            }
        }
    }
}
