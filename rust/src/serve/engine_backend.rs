//! The engine-backed serving backend: requests execute on the *real*
//! fused tiled engine, not the cost model.
//!
//! Five pieces make a serving round cheap, batched, and realistic:
//!
//! * **Slot-paged KV** ([`super::kv::PagedKv`]) — one refcounted page
//!   pool shared across every (slot, layer) sequence; appends are
//!   in-place, gathers produce the padded bucketed tensors the cached
//!   plans expect, and whole-page prompt prefixes survive a request to
//!   be re-adopted by the conversation's next turn.
//! * **Plan cache** ([`crate::fusion::PlanCache`]) — fusion plans (and
//!   their autotuned tile schedules) are keyed by shape class (variant +
//!   heads + bucketed lengths), so steady-state decode re-plans nothing:
//!   a step is a cache hit returning an `Arc<CachedPlan>` that also
//!   carries the graph analysis the executor needs (zero per-step
//!   `analyze()` / `consumers()` calls). [`EngineBackend::warmup_plans`]
//!   pre-builds the bucket ladder so the first request per bucket does
//!   not pay plan+autotune latency inline. Autotune is pinned to
//!   `block_k ==` page granule — see the bit-identity note below.
//! * **Multi-layer model** ([`EngineModel::layers`]) — a token step
//!   traverses L stacked attention layers (layer 0 reads the token
//!   embeddings; deeper layers project their Q/K/V elementwise from the
//!   residual stream), all layers sharing the one page pool and the one
//!   cached plan per shape class.
//! * **Chunked prefill** — a prompt prefills in page-granule chunks
//!   ([`Backend::begin_prefill`] / [`Backend::mixed_step`]), each chunk
//!   an ordinary engine job, so prefill chunks and decode steps batch
//!   into the *same* grid-scheduling rounds and a long prompt no longer
//!   stalls every decoding request for its whole prefill.
//! * **Cross-request grid scheduling**
//!   ([`crate::exec::execute_plans_batched`]) — every job in a round
//!   (decode steps at their current layer, prefill chunks at theirs)
//!   contributes its `LogicalGrid` blocks as tagged work items to one
//!   shared worker pool, so `SchedulerConfig::parallelism` is filled by
//!   the *batch*, not by any single request's (tiny) grid.
//!
//! ## Bit-identity
//!
//! K/V/q embeddings are pure functions of (token, position), plans are
//! shape-keyed, and the batched executor merges per plan in block order —
//! so the token stream is bitwise identical whether slots decode together
//! or one at a time, at any thread count. Chunked prefill is bitwise
//! identical to one-shot prefill, and a prefix-reusing turn is bitwise
//! identical to a cold re-prefill, because each query row's online-
//! softmax state depends only on the kv *tile boundaries* (pinned: the
//! serving plan cache fixes `block_k` to the page granule, and every
//! bucket is a granule multiple) and on the K/V values themselves (pure
//! per-position functions, identical however the rows were batched into
//! chunks). Asserted by the tests below and gated in the serve bench.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Instant;

use crate::exec::{CpuRunner, Parallelism, PlanJob, PlanRunner, Tensor};
use crate::fusion::{bucket_len, CacheStats, CachedPlan, PlanCache, PlanKey};
use crate::tracegen::{Request, Rng};
use crate::variants::{build_serving, AttnShape, Variant};

use super::engine::{Backend, SchedulerConfig};
use super::kv::{PagedKv, DEFAULT_BLOCK_TOKENS};

/// The tiny attention model the engine backend serves: `layers` stacked
/// attention layers per token step with deterministic token embeddings
/// and cheap-but-real per-layer Q/K/V projections (the repo's scope is
/// the attention path; dense FFNs stay out of it).
#[derive(Debug, Clone, Copy)]
pub struct EngineModel {
    pub variant: Variant,
    /// Attention layers per token step. Layer 0 reads the token
    /// embeddings directly; each deeper layer projects its Q/K/V
    /// elementwise from the residual stream, so the serve bench's
    /// arithmetic intensity scales like a real L-layer model.
    pub layers: usize,
    pub heads_q: usize,
    pub heads_kv: usize,
    pub head_dim: usize,
    pub vocab: usize,
}

impl EngineModel {
    /// Small GQA config: fast enough to serve whole traces in tests.
    pub fn tiny() -> Self {
        EngineModel {
            variant: Variant::Causal,
            layers: 1,
            heads_q: 4,
            heads_kv: 2,
            head_dim: 16,
            vocab: 512,
        }
    }

    /// [`EngineModel::tiny`] with `layers` stacked attention layers.
    pub fn tiny_deep(layers: usize) -> Self {
        EngineModel {
            layers: layers.max(1),
            ..EngineModel::tiny()
        }
    }
}

const K_SALT: u64 = 0x4B56_0001;
const V_SALT: u64 = 0x4B56_0002;
const Q_SALT: u64 = 0x4B56_0003;
const W_SALT: u64 = 0x4B56_0004;

/// Deterministic per-(token, position) embedding in [-0.5, 0.5).
fn embed(salt: u64, token: u32, pos: usize, n: usize) -> Vec<f32> {
    let seed = salt
        ^ ((token as u64) << 20)
        ^ (pos as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut rng = Rng::new(seed | 1);
    (0..n).map(|_| (rng.f64() - 0.5) as f32).collect()
}

/// Deterministic greedy "sampler": folds the attention output bits, so
/// bitwise-identical outputs yield identical tokens (FNV-1a).
fn sample_token(data: &[f32], vocab: usize) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &x in data {
        h ^= x.to_bits();
        h = h.wrapping_mul(0x0100_0193);
    }
    h % vocab.max(1) as u32
}

/// Per-layer projection weights (deterministic, fixed at model build).
/// All three are `[heads_q * head_dim]` vectors applied elementwise:
/// Q keeps the full width, K/V fold the query-head groups down to the
/// kv-head width (a diagonal stand-in for the dense projections — cheap,
/// but the data really flows layer to layer).
struct LayerProj {
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
}

/// Reusable per-slot K/V gather buffers: steady-state decode gathers are
/// allocation-free (buffers round-trip through the input tensors and
/// come back after every launch). `valid_for` identifies the gather the
/// buffers currently hold — successive chunks of one prefill layer read
/// the same immutable appended K/V, so the copy is skipped entirely on
/// a key match (the executor never mutates job inputs).
#[derive(Default)]
struct GatherScratch {
    k: Vec<f32>,
    v: Vec<f32>,
    /// (sequence, cached len, padded bucket) the buffers were filled
    /// for; cleared whenever the slot's cache identity changes.
    valid_for: Option<(usize, usize, usize)>,
}

/// A conversation's parked KV prefix: whole pages per layer, plus the
/// prompt tokens they cache (verified against the next turn's prompt
/// before adoption) and the admission-score inputs — a recency tick and
/// the conversation's observed reuse count at park time.
struct ParkedPrefix {
    tokens: Vec<u32>,
    /// Page lists, one per layer; all the same length.
    pages: Vec<Vec<usize>>,
    tick: u64,
    /// Times this conversation had come back (follow-up turns seen)
    /// when the prefix was parked. A returning conversation is likelier
    /// to return again, so reuse history buys eviction protection.
    reuses: u32,
}

/// Prefix-cache counters, surfaced in serving metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// Prefills that adopted a parked prefix.
    pub hits: u64,
    /// Prompt tokens whose prefill was skipped via adoption.
    pub tokens_reused: u64,
    /// Parked conversations currently held.
    pub entries: usize,
    /// Pages (across all layers) pinned by parked prefixes.
    pub parked_pages: usize,
}

/// In-flight chunked prefill of one slot: the layer-staged state
/// machine. All *new* rows' K/V for the current layer are appended at
/// layer entry (from embeddings at layer 0, from the residual stream
/// `x` above), then the rows are attended chunk by chunk with the
/// runtime `kv_len`/`q_off` scalars; when the cursor wraps, the next
/// layer begins. This ordering serves causal *and* bidirectional
/// variants: a chunk's kernel sees the full (masked) key range exactly
/// as a one-shot prefill would.
struct PrefillState {
    conversation: usize,
    /// Full prompt, including any adopted prefix.
    prompt: Vec<u32>,
    /// Adopted prefix length in tokens (q_off of new row 0).
    base: usize,
    layer: usize,
    /// New rows completed at the current layer.
    cursor: usize,
    /// Residual stream entering the current layer: `[n_new][hq*d]`
    /// (unused at layer 0, where embeddings feed the kernel directly).
    x: Vec<f32>,
    /// Residual stream being produced for the next layer.
    x_next: Vec<f32>,
}

/// Parked metadata of a slot whose prefill completed (needed to park
/// the conversation prefix at release time).
struct SlotMeta {
    conversation: usize,
    prompt: Vec<u32>,
}

/// Who owns a job in one mixed sub-round.
enum Owner {
    /// Index into the round's decode states.
    Dec(usize),
    /// (slot, rows in this chunk).
    Pre(usize, usize),
}

/// Outcome of one fault-aware mixed round ([`EngineBackend::step`]).
/// Unlike the legacy [`Backend::mixed_step`] tuple, a poisoned job
/// (worker panic attributed to one request) does not abort the round:
/// the victim lands in `failed`, everyone else's tokens are emitted
/// exactly as in a healthy round.
#[derive(Debug, Default)]
pub struct StepReport {
    pub elapsed_s: f64,
    /// Prefills that completed this round: (slot, first token), in
    /// completion order.
    pub finished: Vec<(usize, u32)>,
    /// One decode token per *surviving* active slot: (slot, token), in
    /// the caller's `active` order.
    pub tokens: Vec<(usize, u32)>,
    /// Slots whose request died mid-round (a worker panic poisoned
    /// their job). The engine state for the slot is already detached;
    /// the scheduler must `release` it and fail the request.
    pub failed: Vec<(usize, String)>,
}

pub struct EngineBackend {
    pub model: EngineModel,
    n_slots: usize,
    max_context: usize,
    /// One sequence per (slot, layer): sequence `slot * layers + layer`.
    kv: PagedKv,
    last_token: Vec<u32>,
    plans: PlanCache,
    par: Parallelism,
    /// Who executes the fused plans this instance schedules. The CPU
    /// runner today; the [`crate::exec::PlanRunner`] seam is what lets
    /// a future accelerator path slot in per instance without the
    /// scheduler or plan cache changing shape.
    runner: CpuRunner,
    /// Prefill chunk size in q rows (page-granule multiple); 0 = the
    /// whole prompt in one chunk.
    chunk_tokens: usize,
    prefix_caching: bool,
    /// Page budget for parked prefix pages (across all layers).
    prefix_cache_pages: usize,
    /// Admission policy weight: each observed return of a conversation
    /// is worth this many recency ticks in its eviction score, so a
    /// multi-turn conversation outlives a burst of one-shot parks.
    /// 0 degrades to pure page-LRU (the pre-admission-polish policy).
    prefix_reuse_boost: u64,
    /// Follow-up turns observed per conversation (the trace-derived
    /// reuse signal feeding the admission score).
    conv_reuses: HashMap<usize, u32>,
    proj: Vec<LayerProj>,
    staged: Vec<Option<PrefillState>>,
    slot_meta: Vec<Option<SlotMeta>>,
    prefix_cache: HashMap<usize, ParkedPrefix>,
    prefix_tick: u64,
    prefix_hits: u64,
    prefix_tokens_reused: u64,
    /// Mid-prefill releases that parked a partial (whole-page) prefix
    /// instead of freeing it — preemption/kill work a retry reuses.
    partial_parks: u64,
    scratch: Vec<GatherScratch>,
    gather_reallocs: u64,
    log_tokens: bool,
    /// Every emitted token in backend-call order (prefill first tokens,
    /// then decode tokens batch by batch) — the serve bench's
    /// bit-identity gate compares these across thread counts. Only
    /// populated after [`Self::enable_token_log`]; off by default so
    /// long serving runs stay O(1) in generated tokens.
    pub token_log: Vec<u32>,
}

impl EngineBackend {
    pub fn new(model: EngineModel, n_slots: usize, max_context: usize, par: Parallelism) -> Self {
        let model = EngineModel {
            layers: model.layers.max(1),
            ..model
        };
        let w = model.heads_q * model.head_dim;
        let proj = (1..model.layers)
            .map(|l| LayerProj {
                wq: embed(W_SALT, l as u32, 0, w),
                wk: embed(W_SALT, l as u32, 1, w),
                wv: embed(W_SALT, l as u32, 2, w),
            })
            .collect();
        // Pre-size the gather scratch for the largest bucket so
        // steady-state decode performs zero gather allocations.
        let max_gather =
            model.heads_kv * model.head_dim * bucket_len(max_context, DEFAULT_BLOCK_TOKENS);
        let scratch = (0..n_slots)
            .map(|_| GatherScratch {
                k: Vec::with_capacity(max_gather),
                v: Vec::with_capacity(max_gather),
                valid_for: None,
            })
            .collect();
        let buckets = max_context.max(1).div_ceil(DEFAULT_BLOCK_TOKENS);
        let plan_capacity = buckets + buckets * (buckets + 1) / 2 + 8;
        // Pre-spawn the worker pool for this thread count: steady-state
        // serving (and every decode step) then performs zero thread
        // spawns — the runtime's parked workers just wake per launch.
        crate::exec::runtime::warm(&par);
        EngineBackend {
            n_slots,
            max_context,
            kv: PagedKv::new(
                n_slots * model.layers,
                DEFAULT_BLOCK_TOKENS,
                model.heads_kv,
                model.head_dim,
            ),
            last_token: vec![0; n_slots],
            // Autotune pinned to the page granule: the kv tiling must be
            // identical across every bucket for chunked prefill and
            // prefix reuse to stay bit-identical to one-shot prefill.
            // Capacity covers the worst-case warmup for this context
            // window — the decode ladder plus the unchunked prefill
            // triangle (every q_bucket <= kv_bucket pair) — so warming
            // never evicts what it just built.
            plans: PlanCache::with_block_k(plan_capacity, DEFAULT_BLOCK_TOKENS),
            par,
            runner: CpuRunner::new(par),
            chunk_tokens: 0,
            prefix_caching: true,
            prefix_cache_pages: 256,
            prefix_reuse_boost: 8,
            conv_reuses: HashMap::new(),
            proj,
            staged: (0..n_slots).map(|_| None).collect(),
            slot_meta: (0..n_slots).map(|_| None).collect(),
            prefix_cache: HashMap::new(),
            prefix_tick: 0,
            prefix_hits: 0,
            prefix_tokens_reused: 0,
            partial_parks: 0,
            scratch,
            gather_reallocs: 0,
            log_tokens: false,
            token_log: Vec::new(),
            model,
        }
    }

    /// Record every emitted token into [`Self::token_log`] (the serve
    /// bench's bit-identity gate needs the full stream).
    pub fn enable_token_log(&mut self) {
        self.log_tokens = true;
    }

    fn log_token(&mut self, tok: u32) {
        if self.log_tokens {
            self.token_log.push(tok);
        }
    }

    /// Prefill chunk size in q rows; rounded up to the page granule
    /// (0 = whole-prompt chunks).
    pub fn set_chunk_tokens(&mut self, chunk: usize) {
        self.chunk_tokens = if chunk == 0 {
            0
        } else {
            bucket_len(chunk, self.kv.block_tokens())
        };
    }

    /// Enable/disable conversation prefix retention (existing parked
    /// prefixes stay until [`Self::clear_prefix_cache`]).
    pub fn set_prefix_caching(&mut self, on: bool) {
        self.prefix_caching = on;
    }

    /// Release every parked conversation prefix back to the page pool.
    pub fn clear_prefix_cache(&mut self) {
        for (_, p) in self.prefix_cache.drain() {
            for pl in &p.pages {
                self.kv.release_prefix(pl);
            }
        }
        self.conv_reuses.clear();
    }

    /// Plan-cache hit/miss counters (surfaced in serving metrics).
    pub fn cache_stats(&self) -> CacheStats {
        self.plans.stats()
    }

    /// KV page-pool occupancy: (allocated, free).
    pub fn kv_pages(&self) -> (usize, usize) {
        (self.kv.allocated_pages(), self.kv.free_pages())
    }

    /// Prefix-cache counters.
    pub fn prefix_stats(&self) -> PrefixStats {
        PrefixStats {
            hits: self.prefix_hits,
            tokens_reused: self.prefix_tokens_reused,
            entries: self.prefix_cache.len(),
            parked_pages: self.parked_pages(),
        }
    }

    /// Mid-prefill releases that parked a partial prefix (whole pages
    /// every layer had appended) for the request's retry to adopt.
    pub fn partial_parks(&self) -> u64 {
        self.partial_parks
    }

    fn parked_pages(&self) -> usize {
        self.prefix_cache
            .values()
            .map(|p| p.pages.iter().map(Vec::len).sum::<usize>())
            .sum()
    }

    /// How many times a K/V gather had to grow its scratch buffer. The
    /// scratch is pre-sized for the context window, so this stays 0 —
    /// steady-state decode gathers are allocation-free (gated in the
    /// serve bench).
    pub fn gather_reallocs(&self) -> u64 {
        self.gather_reallocs
    }

    /// The execution parallelism in effect (set via [`Backend::configure`]).
    pub fn parallelism(&self) -> Parallelism {
        self.par
    }

    /// The plan runner this instance launches through — the executor
    /// half of the instance (copyable, so schedulers can lift it out
    /// before borrow-heavy loops).
    pub fn runner(&self) -> CpuRunner {
        self.runner
    }

    /// Pre-build (plan + autotune) the serving bucket ladder up to
    /// `max_len` tokens: the decode plan and every prefill shape class
    /// for every KV bucket. With chunking on, prefill needs one q width
    /// (the chunk size) per bucket; with chunking off, a prefix-adopting
    /// turn prefills only its suffix, so every `q_bucket <= kv_bucket`
    /// pair can occur and the whole triangle is warmed. Returns the
    /// number of plans built, so callers can subtract warmup misses from
    /// steady-state stats. Run it at server start — no request then pays
    /// plan+autotune latency inline (gated in `bench serve_engine`).
    pub fn warmup_plans(&mut self, max_len: usize) -> u64 {
        let block = self.kv.block_tokens();
        let chunk = self.chunk_tokens;
        let before = self.plans.stats().misses;
        let top = bucket_len(max_len.clamp(1, self.max_context), block);
        let mut bucket = block;
        while bucket <= top {
            self.plan_entry("decode", 1, bucket);
            if chunk == 0 {
                let mut qb = block;
                while qb <= bucket {
                    self.plan_entry("prefill", qb, bucket);
                    qb += block;
                }
            } else {
                self.plan_entry("prefill", chunk, bucket);
            }
            bucket += block;
        }
        self.plans.stats().misses - before
    }

    /// Sequence index of (slot, layer) in the shared page pool.
    fn seq(&self, slot: usize, layer: usize) -> usize {
        slot * self.model.layers + layer
    }

    /// Elementwise Q projection of a residual-stream row (layer >= 1).
    fn proj_q(&self, layer: usize, x: &[f32]) -> Vec<f32> {
        let w = &self.proj[layer - 1].wq;
        x.iter().zip(w).map(|(a, b)| a * b).collect()
    }

    /// Group-folding K/V projection: `[hq*d] -> [hkv*d]`, each kv head
    /// the weighted sum of its query-head group.
    fn proj_kv(&self, weights: &[f32], x: &[f32]) -> Vec<f32> {
        let (hkv, d) = (self.model.heads_kv, self.model.head_dim);
        let group = self.model.heads_q / hkv;
        let mut out = vec![0f32; hkv * d];
        for h in 0..hkv {
            for g in 0..group {
                let src = (h * group + g) * d;
                for i in 0..d {
                    out[h * d + i] += weights[src + i] * x[src + i];
                }
            }
        }
        out
    }

    fn proj_k(&self, layer: usize, x: &[f32]) -> Vec<f32> {
        self.proj_kv(&self.proj[layer - 1].wk, x)
    }

    fn proj_v(&self, layer: usize, x: &[f32]) -> Vec<f32> {
        self.proj_kv(&self.proj[layer - 1].wv, x)
    }

    /// Fetch (or build + autotune) the plan for one shape class.
    fn plan_entry(&mut self, tag: &'static str, q_len: usize, kv_len: usize) -> Arc<CachedPlan> {
        let m = self.model;
        let key = PlanKey {
            tag,
            variant: m.variant.name(),
            heads_q: m.heads_q,
            heads_kv: m.heads_kv,
            head_dim: m.head_dim,
            q_len,
            kv_len,
        };
        self.plans.get_or_build(key, || {
            let shape = AttnShape {
                batch: 1,
                rows: 1,
                heads_q: m.heads_q,
                heads_kv: m.heads_kv,
                seq: kv_len,
                head_dim: m.head_dim,
            };
            build_serving(m.variant, &shape, q_len)
        })
    }

    /// Assemble the engine inputs for one (slot, layer) job: gathered
    /// padded K/V from the per-slot scratch plus the runtime `kv_len` /
    /// `q_off` scalars. The scratch buffers travel inside the returned
    /// tensors and come home via [`Self::reclaim_scratch`].
    fn attn_inputs(
        &mut self,
        slot: usize,
        layer: usize,
        q: Tensor,
        bucket: usize,
        len: usize,
        q_off: usize,
    ) -> anyhow::Result<HashMap<String, Tensor>> {
        let (hkv, d) = (self.model.heads_kv, self.model.head_dim);
        let seq = self.seq(slot, layer);
        let key = (seq, self.kv.len(seq), bucket);
        let mut kbuf = std::mem::take(&mut self.scratch[slot].k);
        let mut vbuf = std::mem::take(&mut self.scratch[slot].v);
        if self.scratch[slot].valid_for != Some(key) {
            let caps = (kbuf.capacity(), vbuf.capacity());
            if let Err(e) = self.kv.gather(seq, bucket, &mut kbuf, &mut vbuf) {
                // Hand the buffers home so even this (bucketing-bug)
                // path leaks nothing.
                self.scratch[slot].k = kbuf;
                self.scratch[slot].v = vbuf;
                self.scratch[slot].valid_for = None;
                return Err(e.into());
            }
            if kbuf.capacity() != caps.0 || vbuf.capacity() != caps.1 {
                self.gather_reallocs += 1;
            }
            self.scratch[slot].valid_for = Some(key);
        }
        debug_assert_eq!(kbuf.len(), hkv * bucket * d);
        let mut m = HashMap::new();
        m.insert("q".to_string(), q);
        m.insert(
            "k".to_string(),
            Tensor::from_vec(&[1, hkv, 1, bucket, d], kbuf),
        );
        m.insert(
            "v".to_string(),
            Tensor::from_vec(&[1, hkv, 1, bucket, d], vbuf),
        );
        m.insert(
            "kv_len".to_string(),
            Tensor::from_vec(&[1, 1, 1, 1, 1], vec![len as f32]),
        );
        m.insert(
            "q_off".to_string(),
            Tensor::from_vec(&[1, 1, 1, 1, 1], vec![q_off as f32]),
        );
        Ok(m)
    }

    /// Take the K/V buffers back out of a finished job's inputs so the
    /// next gather for this slot reuses them (allocation-free).
    fn reclaim_scratch(&mut self, slot: usize, inputs: &mut HashMap<String, Tensor>) {
        if let Some(t) = inputs.remove("k") {
            self.scratch[slot].k = t.data;
        }
        if let Some(t) = inputs.remove("v") {
            self.scratch[slot].v = t.data;
        }
    }

    /// Set the admission-score weight per observed conversation return
    /// (0 = pure page-LRU). See [`Self::park_slot`].
    pub fn set_prefix_reuse_boost(&mut self, boost: u64) {
        self.prefix_reuse_boost = boost;
    }

    /// Eviction score of a parked prefix: its recency tick plus
    /// [`Self::prefix_reuse_boost`] ticks per observed return of the
    /// conversation (capped so one immortal conversation cannot pin
    /// pages forever). Lowest score is evicted first; the tick
    /// tie-break keeps victim choice deterministic.
    fn admission_score(&self, p: &ParkedPrefix) -> (u64, u64) {
        (p.tick + self.prefix_reuse_boost * u64::from(p.reuses.min(16)), p.tick)
    }

    /// Park a finished slot's conversation prefix (whole pages covering
    /// its prompt) instead of freeing it. Beyond the page budget, the
    /// victim is the parked prefix with the lowest **recency-weighted
    /// reuse score** ([`Self::admission_score`]) — not raw page-LRU, so
    /// a conversation with demonstrated multi-turn reuse survives a
    /// burst of never-returning one-shot parks (gated by the admission
    /// test below: strictly higher adopt hit rate on a multi-turn
    /// trace than LRU).
    fn park_slot(&mut self, slot: usize, meta: SlotMeta) {
        let layers = self.model.layers;
        let block = self.kv.block_tokens();
        let keep = (meta.prompt.len() / block) * block;
        if keep == 0 {
            // Nothing parked: the reuse signal can never be read, so
            // drop the conversation's entry (keeps the map bounded).
            self.conv_reuses.remove(&meta.conversation);
            for l in 0..layers {
                let s = self.seq(slot, l);
                self.kv.release(s);
            }
            return;
        }
        let mut pages = Vec::with_capacity(layers);
        for l in 0..layers {
            let s = self.seq(slot, l);
            pages.push(self.kv.park(s, keep));
        }
        self.prefix_tick += 1;
        let parked = ParkedPrefix {
            tokens: meta.prompt[..keep].to_vec(),
            pages,
            tick: self.prefix_tick,
            reuses: self.conv_reuses.get(&meta.conversation).copied().unwrap_or(0),
        };
        if let Some(old) = self.prefix_cache.insert(meta.conversation, parked) {
            for pl in &old.pages {
                self.kv.release_prefix(pl);
            }
        }
        // Recency-weighted reuse eviction down to the page budget.
        while self.parked_pages() > self.prefix_cache_pages {
            let victim = self
                .prefix_cache
                .iter()
                .min_by_key(|(_, p)| self.admission_score(p))
                .map(|(c, _)| *c);
            let Some(conv) = victim else { break };
            let p = self
                .prefix_cache
                .remove(&conv)
                .expect("victim key was just read from this map");
            self.conv_reuses.remove(&conv);
            for pl in &p.pages {
                self.kv.release_prefix(pl);
            }
        }
    }

    // --- KV capacity surface (the lifecycle scheduler's levers) ------

    /// Cap the KV page pool at `cap` pages (fresh allocations beyond it
    /// fail with [`super::kv::KvError::PoolExhausted`]).
    pub fn set_page_cap(&mut self, cap: usize) {
        self.kv.set_page_cap(cap);
    }

    /// The configured KV page cap (`usize::MAX` = unbounded).
    pub fn page_cap(&self) -> usize {
        self.kv.page_cap()
    }

    /// Withhold `pages` pages from availability (fault injection: page
    /// pressure without touching real occupancy).
    pub fn set_kv_pressure(&mut self, pages: usize) {
        self.kv.set_pressure(pages);
    }

    /// KV pages a fresh allocation could still claim right now.
    pub fn available_kv_pages(&self) -> usize {
        self.kv.available_pages()
    }

    /// Exact fresh pages one decode round of `slot` takes: each layer
    /// appends one token, needing a page only when the sequence sits on
    /// a page boundary (all of a slot's layer sequences advance in
    /// lockstep, so layer 0's length speaks for all).
    pub fn decode_pages_needed(&self, slot: usize) -> usize {
        let pos = self.kv.len(self.seq(slot, 0));
        if pos % self.kv.block_tokens() == 0 {
            self.model.layers
        } else {
            0
        }
    }

    /// Conservative bound on fresh pages continuing `slot`'s staged
    /// prefill can take in one mixed round. A round may cross several
    /// layer boundaries, and each crossing appends every new row to
    /// that layer's sequence — so the bound sums the not-yet-entered
    /// layers' append needs (the current layer's rows were appended at
    /// its entry). 0 when nothing is staged.
    pub fn prefill_pages_bound(&self, slot: usize) -> usize {
        match &self.staged[slot] {
            Some(st) => {
                let n_new = st.prompt.len() - st.base;
                (st.layer + 1..self.model.layers)
                    .map(|l| self.kv.pages_for_append(self.seq(slot, l), n_new))
                    .sum()
            }
            None => 0,
        }
    }

    /// Fresh pages staging a cold `input_tokens`-token prompt needs at
    /// layer 0 (prefix adoption can only lower it). The scheduler
    /// checks this before `begin_prefill`.
    pub fn admit_pages_needed(&self, input_tokens: usize) -> usize {
        input_tokens.max(1).div_ceil(self.kv.block_tokens())
    }

    /// Worst-case pages a request pins over its whole lifetime: every
    /// layer holds prompt + generated tokens, minus the final sampled
    /// token (sampled but never appended). Admission control rejects
    /// requests whose bound exceeds the page cap — they could *never*
    /// complete, however empty the pool.
    pub fn lifetime_pages_bound(&self, input_tokens: usize, output_tokens: usize) -> usize {
        let final_len = input_tokens.max(1) + output_tokens.max(1) - 1;
        self.model.layers * final_len.div_ceil(self.kv.block_tokens())
    }

    /// Degradation-ladder rung 1: evict parked conversation prefixes
    /// (lowest admission score first — the same policy park uses) until
    /// `pages` are available or the cache is empty. Returns the
    /// resulting availability.
    pub fn evict_prefixes_for(&mut self, pages: usize) -> usize {
        while self.kv.available_pages() < pages && !self.prefix_cache.is_empty() {
            let victim = self
                .prefix_cache
                .iter()
                .min_by_key(|(_, p)| self.admission_score(p))
                .map(|(c, _)| *c);
            let Some(conv) = victim else { break };
            let p = self
                .prefix_cache
                .remove(&conv)
                .expect("victim key was just read from this map");
            self.conv_reuses.remove(&conv);
            for pl in &p.pages {
                self.kv.release_prefix(pl);
            }
        }
        self.kv.available_pages()
    }

    /// One fault-aware mixed round: the engine's real scheduling unit.
    /// Numerics and emission order are identical to the legacy
    /// [`Backend::mixed_step`] (which now delegates here), plus two
    /// robustness layers:
    ///
    /// * **KV preflight** — every page the round's decode entries and
    ///   staged prefill layer-crossings could claim is checked against
    ///   availability *before any append*, so capacity failure is a
    ///   clean error with nothing mutated (the lifecycle preempts or
    ///   throttles instead of corrupting slots).
    /// * **Poisoned-job isolation** — a worker panic attributed to one
    ///   job ([`crate::exec::BatchPanic`]) fails only that job's slot:
    ///   the victim is detached and reported in [`StepReport::failed`],
    ///   the surviving jobs re-launch, and their tokens come out
    ///   bit-identical to a healthy round (per-slot state is folded
    ///   only after a launch fully succeeds, and kernels are
    ///   deterministic, so re-execution reproduces the same bits).
    ///   A failed slot must then be `release`d by the caller.
    pub fn step(
        &mut self,
        prefill: &[(usize, usize)],
        active: &[usize],
    ) -> anyhow::Result<StepReport> {
        let t0 = Instant::now();
        let layers = self.model.layers;
        let (hq, hkv, d) = (
            self.model.heads_q,
            self.model.heads_kv,
            self.model.head_dim,
        );
        let w = hq * d;
        let block = self.kv.block_tokens();
        let stride = self.kv.token_stride();
        // Copy the runner out before the borrow-heavy loop (it is the
        // same trick as copying `Parallelism`): launches below go
        // through the `PlanRunner` seam, not a hardwired executor.
        let runner = self.runner;

        // --- KV preflight: fail before any append, not mid-round.
        let mut need = 0usize;
        for &slot in active {
            anyhow::ensure!(
                self.staged[slot].is_none(),
                "decoding a slot {slot} still mid-prefill"
            );
            let seq0 = self.seq(slot, 0);
            anyhow::ensure!(!self.kv.is_empty(seq0), "decoding an unprefilled slot {slot}");
            anyhow::ensure!(self.kv.len(seq0) < self.max_context, "slot {slot} exceeds context");
            need += self.decode_pages_needed(slot);
        }
        for &(slot, budget) in prefill {
            if budget > 0 {
                need += self.prefill_pages_bound(slot);
            }
        }
        let avail = self.kv.available_pages();
        anyhow::ensure!(
            need <= avail,
            "KV preflight: round needs up to {need} fresh pages, {avail} available"
        );

        // Decode init: append the pending token's layer-0 K/V.
        struct DecState {
            slot: usize,
            tok: u32,
            pos: usize,
            x: Vec<f32>,
            layer: usize,
            /// Poisoned by a worker panic this round: no further jobs,
            /// no token. The slot awaits `release`.
            failed: bool,
        }
        let mut dec: Vec<DecState> = Vec::with_capacity(active.len());
        for &slot in active {
            let seq0 = self.seq(slot, 0);
            let tok = self.last_token[slot];
            let pos = self.kv.len(seq0);
            let k = embed(K_SALT, tok, pos, stride);
            let v = embed(V_SALT, tok, pos, stride);
            // Preflighted above — a failure here is an accounting bug,
            // surfaced as an error rather than a panic.
            self.kv.append(seq0, &k, &v)?;
            dec.push(DecState {
                slot,
                tok,
                pos,
                x: Vec::new(),
                layer: 0,
                failed: false,
            });
        }

        let mut allow: Vec<(usize, usize)> = prefill.to_vec();
        let mut completions: Vec<(usize, u32)> = Vec::new();
        let mut failed: Vec<(usize, String)> = Vec::new();

        loop {
            // --- build this sub-round's jobs (decode first, then chunks)
            let mut built: Vec<(Owner, Arc<CachedPlan>, HashMap<String, Tensor>)> = Vec::new();
            for di in 0..dec.len() {
                if dec[di].failed || dec[di].layer >= layers {
                    continue;
                }
                let (slot, layer, pos) = (dec[di].slot, dec[di].layer, dec[di].pos);
                let q_vec = if layer == 0 {
                    embed(Q_SALT, dec[di].tok, pos, w)
                } else {
                    self.proj_q(layer, &dec[di].x)
                };
                let len = pos + 1;
                let bucket = bucket_len(len, block);
                let entry = self.plan_entry("decode", 1, bucket);
                let q = Tensor::from_vec(&[1, hkv, hq / hkv, 1, d], q_vec);
                let inputs = self.attn_inputs(slot, layer, q, bucket, len, len - 1)?;
                built.push((Owner::Dec(di), entry, inputs));
            }
            for ai in 0..allow.len() {
                let (slot, rem) = allow[ai];
                if rem == 0 {
                    continue;
                }
                let Some(st) = self.staged[slot].take() else {
                    continue;
                };
                let n_new = st.prompt.len() - st.base;
                let rows_left = n_new - st.cursor;
                let chunk_cap = if self.chunk_tokens == 0 {
                    n_new
                } else {
                    self.chunk_tokens
                };
                let c = rows_left.min(chunk_cap).min(rem);
                if c == 0 || st.layer >= layers {
                    self.staged[slot] = Some(st);
                    continue;
                }
                // One plan class per (chunk size, kv bucket): real rows
                // zero-padded up to the chunk width, pad outputs ignored.
                let qb = if self.chunk_tokens == 0 {
                    bucket_len(n_new, block)
                } else {
                    self.chunk_tokens
                };
                let total = st.prompt.len();
                let kvb = bucket_len(total, block);
                let mut qdata = vec![0f32; hq * qb * d];
                for i in 0..c {
                    let r = st.cursor + i;
                    let abs = st.base + r;
                    let qrow = if st.layer == 0 {
                        embed(Q_SALT, st.prompt[abs], abs, w)
                    } else {
                        self.proj_q(st.layer, &st.x[r * w..(r + 1) * w])
                    };
                    for h in 0..hq {
                        let dst = (h * qb + i) * d;
                        qdata[dst..dst + d].copy_from_slice(&qrow[h * d..(h + 1) * d]);
                    }
                }
                let entry = self.plan_entry("prefill", qb, kvb);
                let q = Tensor::from_vec(&[1, hkv, hq / hkv, qb, d], qdata);
                let q_off = st.base + st.cursor;
                let layer = st.layer;
                allow[ai].1 = rem - c;
                // Park the state *before* the fallible gather so an
                // error cannot orphan a mid-prefill slot.
                self.staged[slot] = Some(st);
                let inputs = self.attn_inputs(slot, layer, q, kvb, total, q_off)?;
                built.push((Owner::Pre(slot, c), entry, inputs));
            }
            if built.is_empty() {
                break;
            }

            // --- one batched launch over the shared worker pool. A
            //     panic attributed to a single job detaches only that
            //     job's slot; the remaining jobs re-launch from their
            //     (immutable) inputs. Per-slot folds happen strictly
            //     after a fully successful launch, so a retried round
            //     reproduces identical bits for the survivors.
            let results = loop {
                let exec = {
                    let jobs: Vec<PlanJob> = built
                        .iter()
                        .map(|(_, e, inp)| PlanJob::from_cached(e.as_ref(), inp))
                        .collect();
                    catch_unwind(AssertUnwindSafe(|| runner.run_batch(&jobs)))
                };
                let payload = match exec {
                    Ok(r) => break r,
                    Err(p) => p,
                };
                let Some(j) = crate::exec::batch_panic_job(payload.as_ref()) else {
                    anyhow::bail!(
                        "engine round panicked without job attribution: {}",
                        crate::exec::runtime::panic_message(payload.as_ref())
                    );
                };
                let msg = payload
                    .downcast_ref::<crate::exec::BatchPanic>()
                    .map(|b| crate::exec::runtime::panic_message(b.payload.as_ref()))
                    .unwrap_or_else(|| crate::exec::runtime::panic_message(payload.as_ref()));
                let (owner, _entry, mut inputs) = built.remove(j);
                let (slot, what) = match owner {
                    Owner::Dec(di) => {
                        dec[di].failed = true;
                        (dec[di].slot, "decode")
                    }
                    Owner::Pre(slot, _) => {
                        self.staged[slot] = None;
                        (slot, "prefill")
                    }
                };
                self.reclaim_scratch(slot, &mut inputs);
                self.scratch[slot].valid_for = None;
                failed.push((
                    slot,
                    format!("worker panic poisoned {what} for slot {slot}: {msg}"),
                ));
            };

            // --- fold results back into the per-slot state machines
            for ((owner, _entry, mut inputs), (mut outs, _c)) in
                built.into_iter().zip(results)
            {
                match owner {
                    Owner::Dec(di) => {
                        self.reclaim_scratch(dec[di].slot, &mut inputs);
                        if dec[di].layer == 0 {
                            // The results are owned here: move the
                            // output buffer into the residual stream.
                            dec[di].x = outs.swap_remove(0).data;
                        } else {
                            for (a, b) in dec[di].x.iter_mut().zip(&outs[0].data) {
                                *a += b;
                            }
                        }
                        dec[di].layer += 1;
                        let l = dec[di].layer;
                        if l < layers {
                            let k = self.proj_k(l, &dec[di].x);
                            let v = self.proj_v(l, &dec[di].x);
                            let s = self.seq(dec[di].slot, l);
                            self.kv.append(s, &k, &v)?;
                        }
                    }
                    Owner::Pre(slot, c) => {
                        self.reclaim_scratch(slot, &mut inputs);
                        let out = &outs[0];
                        let mut st = self.staged[slot].take().expect("state parked");
                        let n_new = st.prompt.len() - st.base;
                        let qb = out.numel() / w;
                        for i in 0..c {
                            let r = st.cursor + i;
                            let (x, x_next) = (&st.x, &mut st.x_next);
                            let dst = &mut x_next[r * w..(r + 1) * w];
                            for h in 0..hq {
                                let src = (h * qb + i) * d;
                                let seg = &out.data[src..src + d];
                                if st.layer == 0 {
                                    dst[h * d..(h + 1) * d].copy_from_slice(seg);
                                } else {
                                    let base = r * w + h * d;
                                    for j in 0..d {
                                        dst[h * d + j] = x[base + j] + seg[j];
                                    }
                                }
                            }
                        }
                        st.cursor += c;
                        if st.cursor == n_new {
                            st.layer += 1;
                            st.cursor = 0;
                            std::mem::swap(&mut st.x, &mut st.x_next);
                            if st.layer == layers {
                                // Prefill complete: sample the first
                                // token from the final stream's last row.
                                let last = &st.x[(n_new - 1) * w..n_new * w];
                                let tok = sample_token(last, self.model.vocab);
                                self.last_token[slot] = tok;
                                completions.push((slot, tok));
                                self.slot_meta[slot] = Some(SlotMeta {
                                    conversation: st.conversation,
                                    prompt: std::mem::take(&mut st.prompt),
                                });
                            } else {
                                // Enter the next layer: append its K/V
                                // for every new row from the stream.
                                // Covered by the preflight bound above.
                                for r in 0..n_new {
                                    let xr = &st.x[r * w..(r + 1) * w];
                                    let k = self.proj_k(st.layer, xr);
                                    let v = self.proj_v(st.layer, xr);
                                    let s = self.seq(slot, st.layer);
                                    self.kv.append(s, &k, &v)?;
                                }
                                self.staged[slot] = Some(st);
                            }
                        } else {
                            self.staged[slot] = Some(st);
                        }
                    }
                }
            }
        }

        // Emit tokens: prefill completions first (in completion order —
        // the sub-round each finished in, then job order within it),
        // then the decode batch (active order, survivors only). Both
        // orders depend only on the scheduler's call sequence, never on
        // thread timing, so the bit-identity gate holds.
        let mut tokens: Vec<(usize, u32)> = Vec::with_capacity(dec.len());
        for ds in &dec {
            if ds.failed {
                continue;
            }
            let tok = sample_token(&ds.x, self.model.vocab);
            self.last_token[ds.slot] = tok;
            tokens.push((ds.slot, tok));
        }
        for &(_, tok) in &completions {
            self.log_token(tok);
        }
        for &(_, tok) in &tokens {
            self.log_token(tok);
        }
        Ok(StepReport {
            elapsed_s: t0.elapsed().as_secs_f64(),
            finished: completions,
            tokens,
            failed,
        })
    }
}

impl Backend for EngineBackend {
    fn n_slots(&self) -> usize {
        self.n_slots
    }

    fn max_context(&self) -> usize {
        self.max_context
    }

    fn configure(&mut self, cfg: &SchedulerConfig) {
        self.par = cfg.parallelism;
        self.runner = CpuRunner::new(self.par);
        // Thread-count changes re-warm the pool so the serving loop
        // itself never spawns (gated in `bench serve_engine`).
        crate::exec::runtime::warm(&self.par);
        self.set_chunk_tokens(cfg.prefill_chunk_tokens);
    }

    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    /// Admission control: beyond the context-window check, reject a
    /// request whose worst-case lifetime page need exceeds the page
    /// cap — it could *never* complete, however empty the pool, so
    /// failing it at admit time is strictly better than deadlocking
    /// the batch on it later (the silent over-admission fix).
    fn admit_check(&self, req: &Request) -> Result<(), String> {
        let total = req.input_tokens.max(1) + req.output_tokens.max(1);
        if total > self.max_context {
            return Err(format!(
                "request {}: {} prompt + {} output tokens exceeds context window {}",
                req.id, req.input_tokens, req.output_tokens, self.max_context
            ));
        }
        let need = self.lifetime_pages_bound(req.input_tokens, req.output_tokens);
        if need > self.kv.page_cap() {
            return Err(format!(
                "request {}: needs up to {} KV pages over its lifetime, page cap is {} — can never fit",
                req.id, need, self.kv.page_cap()
            ));
        }
        Ok(())
    }

    fn begin_prefill(
        &mut self,
        slot: usize,
        req: &Request,
        tokens: &[u32],
    ) -> anyhow::Result<()> {
        let layers = self.model.layers;
        anyhow::ensure!(
            self.staged[slot].is_none(),
            "prefill into a slot {slot} already mid-prefill"
        );
        for l in 0..layers {
            anyhow::ensure!(
                self.kv.is_empty(self.seq(slot, l)),
                "prefill into a non-empty slot {slot}"
            );
        }
        anyhow::ensure!(
            tokens.len() <= self.max_context,
            "prompt of {} tokens exceeds context window {}",
            tokens.len(),
            self.max_context
        );
        let prompt: Vec<u32> = if tokens.is_empty() {
            vec![0]
        } else {
            tokens.to_vec()
        };
        // Capacity preflight, checked *before* adoption so a rejection
        // leaves no state to undo. Worst case every layer-0 prompt page
        // is fresh (adoption can only lower the need); deeper layers
        // are covered round by round in `step`'s preflight. Defensive —
        // the lifecycle scheduler checks `admit_pages_needed` first.
        let avail = self.kv.available_pages();
        let need = prompt.len().div_ceil(self.kv.block_tokens());
        anyhow::ensure!(
            need <= avail,
            "admission preflight: prompt needs {need} fresh KV pages for layer 0, {avail} available"
        );
        // The slot's cache identity changes: stale gather scratch from a
        // previous occupant (whose freed pages may since have been
        // rewritten) must not be trusted.
        self.scratch[slot].valid_for = None;
        // Admission signal: a conversation seen again is a follow-up
        // turn — evidence its parked prefix earns eviction protection.
        // Only tracked where the signal can ever be read (causal arms
        // with prefix caching on); entries are pruned when the
        // conversation leaves the prefix cache, so the map is bounded
        // by parked entries + in-flight slots, not by trace length.
        if self.prefix_caching && self.model.variant.causal_serving() {
            self.conv_reuses
                .entry(req.conversation)
                .and_modify(|c| *c = c.saturating_add(1))
                .or_insert(0);
        }
        // Prefix adoption: graft the conversation's parked whole-page
        // prefix (verified token-for-token) and prefill only the rest.
        // At least one fresh row is kept so the first token has a query.
        // Only causal serving arms park/adopt (see Variant::causal_serving).
        let block = self.kv.block_tokens();
        let mut base = 0usize;
        if self.prefix_caching && self.model.variant.causal_serving() {
            if let Some(p) = self.prefix_cache.get_mut(&req.conversation) {
                let adopt_pages = p.pages[0].len().min((prompt.len() - 1) / block);
                let adopt = adopt_pages * block;
                if adopt_pages > 0 && p.tokens[..adopt] == prompt[..adopt] {
                    self.prefix_tick += 1;
                    p.tick = self.prefix_tick;
                    let page_lists: Vec<Vec<usize>> = p
                        .pages
                        .iter()
                        .map(|pl| pl[..adopt_pages].to_vec())
                        .collect();
                    for (l, pl) in page_lists.iter().enumerate() {
                        let s = self.seq(slot, l);
                        // Infallible by construction — the slot's seqs
                        // were verified empty above and parked pages
                        // always hold a live refcount — but a violation
                        // surfaces as an error, not a panic.
                        self.kv.adopt(s, pl)?;
                    }
                    base = adopt;
                    self.prefix_hits += 1;
                    self.prefix_tokens_reused += adopt as u64;
                }
            }
        }
        // Enter layer 0: its K/V come straight from the token embeddings.
        let n_new = prompt.len() - base;
        let stride = self.kv.token_stride();
        let seq0 = self.seq(slot, 0);
        for r in 0..n_new {
            let pos = base + r;
            let k = embed(K_SALT, prompt[pos], pos, stride);
            let v = embed(V_SALT, prompt[pos], pos, stride);
            // Cannot exhaust: the preflight above reserved `need`
            // pages, and layer-0 staging consumes at most that many.
            self.kv.append(seq0, &k, &v)?;
        }
        let w = self.model.heads_q * self.model.head_dim;
        self.staged[slot] = Some(PrefillState {
            conversation: req.conversation,
            prompt,
            base,
            layer: 0,
            cursor: 0,
            x: vec![0.0; n_new * w],
            x_next: vec![0.0; n_new * w],
        });
        self.slot_meta[slot] = None;
        Ok(())
    }

    fn staged_rows(&self, slot: usize) -> usize {
        match &self.staged[slot] {
            Some(st) => {
                let n_new = st.prompt.len() - st.base;
                (self.model.layers - st.layer) * n_new - st.cursor
            }
            None => 0,
        }
    }

    /// One mixed round under the legacy strict contract: delegates to
    /// the fault-aware [`EngineBackend::step`] and turns any poisoned
    /// slot into a hard error. Fault tolerance is the lifecycle
    /// runner's job — a caller that cannot handle partial failure must
    /// not silently lose a request.
    fn mixed_step(
        &mut self,
        prefill: &[(usize, usize)],
        active: &[usize],
    ) -> anyhow::Result<(f64, Vec<(usize, u32)>, Vec<u32>)> {
        let rep = self.step(prefill, active)?;
        anyhow::ensure!(
            rep.failed.is_empty(),
            "worker panic poisoned slots {:?}",
            rep.failed
        );
        Ok((
            rep.elapsed_s,
            rep.finished,
            rep.tokens.into_iter().map(|(_, t)| t).collect(),
        ))
    }

    fn prefill(
        &mut self,
        slot: usize,
        req: &Request,
        tokens: &[u32],
    ) -> anyhow::Result<(f64, u32)> {
        let t0 = Instant::now();
        self.begin_prefill(slot, req, tokens)?;
        loop {
            let (_dt, fin, _toks) = self.mixed_step(&[(slot, usize::MAX)], &[])?;
            if let Some(&(s, tok)) = fin.first() {
                debug_assert_eq!(s, slot);
                return Ok((t0.elapsed().as_secs_f64(), tok));
            }
        }
    }

    fn decode(&mut self, active: &[usize]) -> anyhow::Result<(f64, Vec<u32>)> {
        let (dt, fin, toks) = self.mixed_step(&[], active)?;
        debug_assert!(fin.is_empty());
        Ok((dt, toks))
    }

    fn release(&mut self, slot: usize) {
        self.scratch[slot].valid_for = None;
        let parkable = self.prefix_caching && self.model.variant.causal_serving();
        // A mid-prefill release (preemption, cancellation, watchdog
        // kill) still parks the prompt rows that *every* layer has
        // fully appended — whole pages only, truncated to the minimum
        // KV length across layers so the parked page lists stay
        // layer-consistent. The retry regenerates the same prompt
        // (prompt_tokens is conversation-pure), adopts the partial
        // prefix, and prefills only the remainder.
        let partial = match (parkable, &self.staged[slot]) {
            (true, Some(st)) => {
                let min_len = (0..self.model.layers)
                    .map(|l| self.kv.len(self.seq(slot, l)))
                    .min()
                    .unwrap_or(0)
                    .min(st.prompt.len());
                (min_len >= self.kv.block_tokens()).then(|| SlotMeta {
                    conversation: st.conversation,
                    prompt: st.prompt[..min_len].to_vec(),
                })
            }
            _ => None,
        };
        self.staged[slot] = None;
        match (parkable, self.slot_meta[slot].take(), partial) {
            (true, Some(meta), _) => self.park_slot(slot, meta),
            (true, None, Some(meta)) => {
                self.partial_parks += 1;
                self.park_slot(slot, meta);
            }
            _ => {
                for l in 0..self.model.layers {
                    let s = self.seq(slot, l);
                    self.kv.release(s);
                }
            }
        }
        self.last_token[slot] = 0;
    }

    fn is_virtual_time(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::execute_plans_batched;
    use crate::serve::engine::{prompt_tokens, run_trace};
    use crate::tracegen::{generate, TraceConfig};

    fn req(id: usize, input_tokens: usize) -> Request {
        Request {
            id,
            input_tokens,
            output_tokens: 8,
            conversation: id,
            ..Request::default()
        }
    }

    fn backend(par: Parallelism) -> EngineBackend {
        EngineBackend::new(EngineModel::tiny(), 4, 1024, par)
    }

    /// prefill + `steps` decodes of one request in one slot; the stream.
    fn run_one(b: &mut EngineBackend, slot: usize, r: &Request, steps: usize) -> Vec<u32> {
        let toks = prompt_tokens(r, b.model.vocab);
        let (_, first) = b.prefill(slot, r, &toks).unwrap();
        let mut out = vec![first];
        for _ in 0..steps {
            let (_, t) = b.decode(&[slot]).unwrap();
            out.push(t[0]);
        }
        out
    }

    #[test]
    fn batched_decode_is_bitwise_identical_to_sequential_requests() {
        // N slots decoded together must emit exactly the tokens each
        // request produces when served alone — at multiple thread counts
        // (the issue's batched-decode parity gate).
        let prompts = [9usize, 23, 40];
        let steps = 5;
        let solo: Vec<Vec<u32>> = prompts
            .iter()
            .enumerate()
            .map(|(i, &plen)| {
                let mut b = backend(Parallelism::sequential());
                run_one(&mut b, 0, &req(i, plen), steps)
            })
            .collect();
        for threads in [1, 2, 4] {
            let mut b = backend(Parallelism::with_threads(threads));
            let mut outs: Vec<Vec<u32>> = Vec::new();
            for (i, &plen) in prompts.iter().enumerate() {
                let r = req(i, plen);
                let toks = prompt_tokens(&r, b.model.vocab);
                let (_, first) = b.prefill(i, &r, &toks).unwrap();
                outs.push(vec![first]);
            }
            for _ in 0..steps {
                let (_, ts) = b.decode(&[0, 1, 2]).unwrap();
                for (i, t) in ts.iter().enumerate() {
                    outs[i].push(*t);
                }
            }
            assert_eq!(outs, solo, "threads={threads}");
        }
    }

    #[test]
    fn chunked_prefill_is_bit_identical_to_one_shot() {
        // Chunk-scheduled prefill must emit the exact token stream of
        // whole-prompt prefill — for every serving-supported variant,
        // across bucket-crossing prompt lengths, including ragged
        // budget-limited chunks.
        for variant in crate::variants::serving_variants() {
            // a window shorter than the prompts so the mask has teeth
            let variant = match variant {
                Variant::SlidingWindow { .. } => Variant::SlidingWindow { window: 40 },
                v => v,
            };
            let model = EngineModel {
                variant,
                layers: 2,
                ..EngineModel::tiny()
            };
            for plen in [9usize, 64, 100, 150] {
                let r = req(0, plen);
                let mut cold = EngineBackend::new(model, 2, 1024, Parallelism::sequential());
                let want = run_one(&mut cold, 0, &r, 4);

                // chunk = one page, whole budget per round
                let mut chunked =
                    EngineBackend::new(model, 2, 1024, Parallelism::sequential());
                chunked.set_chunk_tokens(64);
                let got = run_one(&mut chunked, 0, &r, 4);
                assert_eq!(got, want, "{} plen={plen} chunked", variant.name());

                // ragged: 7-row allowances through mixed_step directly
                let mut ragged =
                    EngineBackend::new(model, 2, 1024, Parallelism::sequential());
                ragged.set_chunk_tokens(64);
                let toks = prompt_tokens(&r, ragged.model.vocab);
                ragged.begin_prefill(0, &r, &toks).unwrap();
                let first = loop {
                    let (_, fin, _) = ragged.mixed_step(&[(0, 7)], &[]).unwrap();
                    if let Some(&(_, tok)) = fin.first() {
                        break tok;
                    }
                };
                let mut got = vec![first];
                for _ in 0..4 {
                    let (_, t) = ragged.decode(&[0]).unwrap();
                    got.push(t[0]);
                }
                assert_eq!(got, want, "{} plen={plen} ragged", variant.name());
            }
        }
    }

    #[test]
    fn prefix_reuse_matches_a_cold_reprefill() {
        // Turn 2 of a conversation adopts the parked turn-1 prefix; its
        // token stream must be bitwise identical to a cold re-prefill of
        // the full turn-2 prompt — multi-layer, chunked.
        let model = EngineModel::tiny_deep(2);
        let turn1 = Request {
            conversation: 5,
            turn: 0,
            ..req(0, 70)
        };
        let turn2 = Request {
            conversation: 5,
            turn: 1,
            ..req(1, 130)
        };

        let mut warm = EngineBackend::new(model, 2, 1024, Parallelism::sequential());
        warm.set_chunk_tokens(64);
        let _ = run_one(&mut warm, 0, &turn1, 3);
        warm.release(0);
        assert!(warm.prefix_stats().parked_pages > 0, "turn 1 must park pages");
        let got = run_one(&mut warm, 1, &turn2, 3);
        let ps = warm.prefix_stats();
        assert_eq!(ps.hits, 1, "turn 2 must adopt the parked prefix");
        assert_eq!(ps.tokens_reused, 64, "70-token prompt parks one full page");

        let mut cold = EngineBackend::new(model, 2, 1024, Parallelism::sequential());
        cold.set_chunk_tokens(64);
        let want = run_one(&mut cold, 0, &turn2, 3);
        assert_eq!(got, want, "prefix reuse must not change the stream");
    }

    #[test]
    fn multi_layer_l1_matches_single_layer_reference() {
        // The L=1 model must reproduce the plain single-attention-layer
        // path built by hand from the same public pieces (plan cache
        // with granule-pinned autotune, paged KV, batched executor).
        let r = req(3, 33);
        let steps = 4;
        let mut b = backend(Parallelism::sequential());
        assert_eq!(b.model.layers, 1);
        let got = run_one(&mut b, 0, &r, steps);

        // Hand-rolled single-layer serving loop.
        let m = EngineModel::tiny();
        let (hq, hkv, d) = (m.heads_q, m.heads_kv, m.head_dim);
        let mut plans = PlanCache::with_block_k(16, DEFAULT_BLOCK_TOKENS);
        let mut kv = PagedKv::new(1, DEFAULT_BLOCK_TOKENS, hkv, d);
        let stride = kv.token_stride();
        let prompt = prompt_tokens(&r, m.vocab);
        let entry = |plans: &mut PlanCache, tag, q_len: usize, kv_len: usize| {
            plans.get_or_build(
                PlanKey {
                    tag,
                    variant: m.variant.name(),
                    heads_q: hq,
                    heads_kv: hkv,
                    head_dim: d,
                    q_len,
                    kv_len,
                },
                || {
                    build_serving(
                        m.variant,
                        &AttnShape {
                            batch: 1,
                            rows: 1,
                            heads_q: hq,
                            heads_kv: hkv,
                            seq: kv_len,
                            head_dim: d,
                        },
                        q_len,
                    )
                },
            )
        };
        let attn = |kv: &PagedKv, q: Tensor, bucket: usize, len: usize, q_off: usize| {
            let mut kb = Vec::new();
            let mut vb = Vec::new();
            kv.gather(0, bucket, &mut kb, &mut vb).unwrap();
            let mut inp = HashMap::new();
            inp.insert("q".to_string(), q);
            inp.insert("k".to_string(), Tensor::from_vec(&[1, hkv, 1, bucket, d], kb));
            inp.insert("v".to_string(), Tensor::from_vec(&[1, hkv, 1, bucket, d], vb));
            inp.insert(
                "kv_len".to_string(),
                Tensor::from_vec(&[1, 1, 1, 1, 1], vec![len as f32]),
            );
            inp.insert(
                "q_off".to_string(),
                Tensor::from_vec(&[1, 1, 1, 1, 1], vec![q_off as f32]),
            );
            inp
        };
        for (pos, &tok) in prompt.iter().enumerate() {
            kv.append(0, &embed(K_SALT, tok, pos, stride), &embed(V_SALT, tok, pos, stride))
                .unwrap();
        }
        let s = prompt.len();
        let bucket = bucket_len(s, DEFAULT_BLOCK_TOKENS);
        let e = entry(&mut plans, "prefill", bucket, bucket);
        let mut qdata = vec![0f32; hq * bucket * d];
        for (pos, &tok) in prompt.iter().enumerate() {
            let qe = embed(Q_SALT, tok, pos, hq * d);
            for h in 0..hq {
                let dst = (h * bucket + pos) * d;
                qdata[dst..dst + d].copy_from_slice(&qe[h * d..(h + 1) * d]);
            }
        }
        let q = Tensor::from_vec(&[1, hkv, hq / hkv, bucket, d], qdata);
        let inputs = attn(&kv, q, bucket, s, 0);
        let job = PlanJob::from_cached(e.as_ref(), &inputs);
        let (outs, _) = execute_plans_batched(
            std::slice::from_ref(&job),
            &Parallelism::sequential(),
        )
        .pop()
        .unwrap();
        drop(job);
        let out = &outs[0];
        let mut row = Vec::with_capacity(hq * d);
        for h in 0..hq {
            let off = (h * bucket + (s - 1)) * d;
            row.extend_from_slice(&out.data[off..off + d]);
        }
        let mut want = vec![sample_token(&row, m.vocab)];
        let mut last = want[0];
        for _ in 0..steps {
            let pos = kv.len(0);
            kv.append(
                0,
                &embed(K_SALT, last, pos, stride),
                &embed(V_SALT, last, pos, stride),
            )
            .unwrap();
            let len = pos + 1;
            let bucket = bucket_len(len, DEFAULT_BLOCK_TOKENS);
            let e = entry(&mut plans, "decode", 1, bucket);
            let q = Tensor::from_vec(&[1, hkv, hq / hkv, 1, d], embed(Q_SALT, last, pos, hq * d));
            let inputs = attn(&kv, q, bucket, len, len - 1);
            let job = PlanJob::from_cached(e.as_ref(), &inputs);
            let (outs, _) = execute_plans_batched(
                std::slice::from_ref(&job),
                &Parallelism::sequential(),
            )
            .pop()
            .unwrap();
            drop(job);
            last = sample_token(&outs[0].data, m.vocab);
            want.push(last);
        }
        assert_eq!(got, want, "L=1 must match the plain single-layer path");
    }

    #[test]
    fn deeper_models_change_the_stream() {
        // L=4 must actually flow data through the extra layers (if the
        // projections or residual stream were dead, the streams would
        // coincide).
        let r = req(0, 40);
        let mut b1 = EngineBackend::new(EngineModel::tiny_deep(1), 2, 1024, Parallelism::sequential());
        let mut b4 = EngineBackend::new(EngineModel::tiny_deep(4), 2, 1024, Parallelism::sequential());
        assert_ne!(run_one(&mut b1, 0, &r, 5), run_one(&mut b4, 0, &r, 5));
    }

    #[test]
    fn plan_cache_hit_rate_exceeds_90_percent_at_steady_state() {
        let mut b = backend(Parallelism::sequential());
        for (i, plen) in [40usize, 55, 62, 70].into_iter().enumerate() {
            let r = req(i, plen);
            let toks = prompt_tokens(&r, b.model.vocab);
            b.prefill(i, &r, &toks).unwrap();
        }
        for _ in 0..60 {
            b.decode(&[0, 1, 2, 3]).unwrap();
        }
        let s = b.cache_stats();
        assert!(
            s.hit_rate() > 0.9,
            "steady-state decode hit rate {:.3} too low: {s:?}",
            s.hit_rate()
        );
    }

    #[test]
    fn warmup_covers_the_bucket_ladder() {
        // After warm-up, a chunk-scheduled serving run must build zero
        // new plans — and therefore run zero analyze() calls and zero
        // gather reallocations (the two per-step bug gates).
        let mut b = EngineBackend::new(EngineModel::tiny_deep(2), 4, 512, Parallelism::sequential());
        b.set_chunk_tokens(64);
        let warmed = b.warmup_plans(512);
        assert!(warmed >= 2, "warmup must build the ladder ({warmed})");
        let misses0 = b.cache_stats().misses;
        for (i, plen) in [40usize, 70, 130, 200].into_iter().enumerate() {
            let r = req(i, plen);
            let toks = prompt_tokens(&r, b.model.vocab);
            b.begin_prefill(i, &r, &toks).unwrap();
            while b.staged_rows(i) > 0 {
                b.mixed_step(&[(i, 64)], &[]).unwrap();
            }
        }
        for _ in 0..30 {
            b.decode(&[0, 1, 2, 3]).unwrap();
        }
        // Zero new plans after warmup. Because every serving job carries
        // its CachedPlan's precomputed analysis/consumers, zero misses
        // also means zero per-step analyze() calls (the global counter
        // is reported by `bench serve_engine`, which runs isolated).
        assert_eq!(b.cache_stats().misses, misses0, "warmup missed a shape class");
        assert_eq!(b.gather_reallocs(), 0, "decode gathers must be allocation-free");
    }

    #[test]
    fn steady_state_decode_does_zero_verify_work() {
        use crate::analysis::{set_verify_override, verify_calls_on_this_thread, VerifyMode};
        // Static plan verification is amortized through the PlanCache:
        // every plan born at warmup is verified exactly once (strict
        // mode — a diagnostic would panic right here), and the serving
        // steady state never verifies again. Mirrors the zero-analyze /
        // zero-plan-build gates above.
        set_verify_override(Some(VerifyMode::Strict));
        let mut b = EngineBackend::new(EngineModel::tiny_deep(2), 4, 512, Parallelism::sequential());
        b.set_chunk_tokens(64);
        let before = verify_calls_on_this_thread();
        let warmed = b.warmup_plans(512);
        let built = verify_calls_on_this_thread();
        assert_eq!(
            built - before,
            warmed,
            "every plan built at warmup is verified exactly once"
        );
        for (i, plen) in [40usize, 70].into_iter().enumerate() {
            let r = req(i, plen);
            let toks = prompt_tokens(&r, b.model.vocab);
            b.begin_prefill(i, &r, &toks).unwrap();
            while b.staged_rows(i) > 0 {
                b.mixed_step(&[(i, 64)], &[]).unwrap();
            }
        }
        for _ in 0..10 {
            b.decode(&[0, 1]).unwrap();
        }
        assert_eq!(
            verify_calls_on_this_thread(),
            built,
            "steady-state serving must do zero verify work (amortized through PlanCache)"
        );
        set_verify_override(None);
    }

    #[test]
    fn engine_backend_completes_a_generated_trace() {
        let trace = generate(&TraceConfig {
            n_requests: 8,
            rate: 100.0,
            input_mu: 3.0,
            input_sigma: 0.5,
            mean_output: 4.0,
            max_input: 48,
            max_output: 6,
            ..Default::default()
        });
        let mut b = backend(Parallelism::sequential());
        let vocab = b.model.vocab;
        let cfg = SchedulerConfig {
            parallelism: Parallelism::with_threads(2),
            ..Default::default()
        };
        let done = run_trace(&mut b, &trace, cfg, vocab).unwrap();
        assert_eq!(done.len(), trace.len());
        for (m, r) in done.iter().zip(&trace) {
            assert_eq!(m.id, r.id);
            assert_eq!(m.itls.len(), r.output_tokens.max(1) - 1);
        }
        // SchedulerConfig.parallelism reached the backend (satellite:
        // --threads flows end to end through configure()).
        assert_eq!(b.parallelism().num_threads, 2);
        // Page accounting balances: everything not parked is free, and
        // clearing the prefix cache frees the rest.
        let (allocated, free) = b.kv_pages();
        assert_eq!(allocated, free + b.prefix_stats().parked_pages);
        b.clear_prefix_cache();
        let (allocated, free) = b.kv_pages();
        assert_eq!(allocated, free);
    }

    #[test]
    fn chunk_scheduled_trace_completes_with_budget() {
        // The chunked scheduling loop (mixed rounds, budgeted prefill)
        // must complete a multi-layer trace with correct token counts.
        let trace = generate(&TraceConfig {
            n_requests: 10,
            rate: 100.0,
            input_mu: 3.5,
            input_sigma: 0.5,
            mean_output: 4.0,
            max_input: 150,
            max_output: 6,
            ..Default::default()
        });
        let mut b = EngineBackend::new(EngineModel::tiny_deep(2), 4, 1024, Parallelism::sequential());
        let vocab = b.model.vocab;
        let cfg = SchedulerConfig {
            parallelism: Parallelism::with_threads(2),
            prefill_chunk_tokens: 64,
            prefill_round_tokens: 128,
            ..Default::default()
        };
        let done = run_trace(&mut b, &trace, cfg, vocab).unwrap();
        assert_eq!(done.len(), trace.len());
        for (m, r) in done.iter().zip(&trace) {
            assert_eq!(m.id, r.id);
            assert_eq!(m.itls.len(), r.output_tokens.max(1) - 1);
            assert!(m.first_token_s >= m.arrival_s);
        }
    }

    #[test]
    fn kv_pages_are_shared_parked_and_adopted() {
        let mut b = backend(Parallelism::sequential());
        let r = req(0, 100);
        let toks = prompt_tokens(&r, b.model.vocab);
        b.prefill(0, &r, &toks).unwrap();
        let (alloc_after_prefill, _) = b.kv_pages();
        assert_eq!(alloc_after_prefill, 2, "100 tokens = 2 pages of 64");
        // Release parks the whole-page prefix (1 page) and frees the
        // partial tail.
        b.release(0);
        let (_, free) = b.kv_pages();
        assert_eq!(free, 1);
        assert_eq!(b.prefix_stats().parked_pages, 1);
        // The same conversation prefills again: the parked page is
        // adopted, the freed page is reused — no new allocation.
        b.prefill(1, &r, &toks).unwrap();
        let (alloc2, free2) = b.kv_pages();
        assert_eq!(alloc2, 2);
        assert_eq!(free2, 0);
        assert_eq!(b.prefix_stats().hits, 1);
        // With prefix caching off, release frees everything.
        b.set_prefix_caching(false);
        b.release(1);
        b.clear_prefix_cache();
        let (alloc3, free3) = b.kv_pages();
        assert_eq!(alloc3, free3);
    }

    #[test]
    fn mid_prefill_release_parks_partial_prefix_the_retry_adopts() {
        // 160-token prompt, 2 layers, 32-row chunks: count the mixed
        // rounds a full prefill takes, then kill an identical prefill
        // one round short of finishing. Every layer has appended all
        // prompt rows by then, so release parks the whole-page prefix
        // (2 pages x 2 layers) and the retry adopts it — emitting the
        // same first token as an unharmed prefill.
        let mk = || {
            EngineBackend::new(EngineModel::tiny_deep(2), 2, 1024, Parallelism::sequential())
        };
        let r = req(0, 160);
        let full_rounds = {
            let mut b = mk();
            let toks = prompt_tokens(&r, b.model.vocab);
            b.begin_prefill(0, &r, &toks).unwrap();
            let mut n = 0usize;
            loop {
                let (_dt, fin, _toks) = b.mixed_step(&[(0, 32)], &[]).unwrap();
                n += 1;
                if !fin.is_empty() {
                    break (n, fin[0].1);
                }
            }
        };
        let (rounds, tok_fresh) = full_rounds;
        assert!(rounds > 2, "the chunked prefill must span rounds");

        let mut b = mk();
        let toks = prompt_tokens(&r, b.model.vocab);
        b.begin_prefill(0, &r, &toks).unwrap();
        for _ in 0..rounds - 1 {
            b.mixed_step(&[(0, 32)], &[]).unwrap();
        }
        b.release(0); // preemption mid-prefill
        assert_eq!(b.partial_parks(), 1, "mid-prefill release must park");
        let ps = b.prefix_stats();
        assert_eq!(ps.parked_pages, 4, "2 whole pages x 2 layers");
        let (alloc, free) = b.kv_pages();
        assert_eq!(alloc, free + ps.parked_pages, "no leak past the park");

        // The retry adopts the partial prefix and matches bit-for-bit.
        b.begin_prefill(0, &r, &toks).unwrap();
        assert_eq!(b.prefix_stats().hits, 1, "retry must adopt the park");
        assert_eq!(b.prefix_stats().tokens_reused, 128);
        let tok_retry = loop {
            let (_dt, fin, _toks) = b.mixed_step(&[(0, 32)], &[]).unwrap();
            if let Some(&(_, t)) = fin.first() {
                break t;
            }
        };
        assert_eq!(tok_retry, tok_fresh, "adopted retry must be bit-identical");
        b.release(0);
        b.clear_prefix_cache();
        let (alloc, free) = b.kv_pages();
        assert_eq!(alloc, free, "pages leaked after the retry");
    }

    #[test]
    fn prefix_cache_evicts_lru_beyond_the_page_budget() {
        let mut b = backend(Parallelism::sequential());
        b.prefix_cache_pages = 2;
        for conv in 0..3 {
            let r = Request {
                conversation: conv,
                ..req(conv, 70)
            };
            let toks = prompt_tokens(&r, b.model.vocab);
            b.prefill(0, &r, &toks).unwrap();
            b.release(0); // parks 1 page per conversation
        }
        let ps = b.prefix_stats();
        assert_eq!(ps.entries, 2, "third park must evict the LRU conversation");
        assert_eq!(ps.parked_pages, 2);
    }

    #[test]
    fn non_causal_variants_never_park_prefixes() {
        // Vanilla serving attends the whole growing cache, so a cached
        // row's deeper-layer K/V would change under a longer sequence —
        // its prefixes are not reusable and must not be parked.
        let mut b = EngineBackend::new(
            EngineModel {
                variant: Variant::Vanilla,
                ..EngineModel::tiny()
            },
            2,
            1024,
            Parallelism::sequential(),
        );
        let r = req(0, 100);
        let toks = prompt_tokens(&r, b.model.vocab);
        b.prefill(0, &r, &toks).unwrap();
        b.release(0);
        assert_eq!(b.prefix_stats().entries, 0);
        let (alloc, free) = b.kv_pages();
        assert_eq!(alloc, free, "vanilla release must free everything");
    }

    #[test]
    fn reuse_weighted_admission_beats_lru_on_a_multi_turn_trace() {
        // A hot conversation returns every round while pairs of one-shot
        // conversations churn a 2-page prefix budget. Pure page-LRU
        // (boost 0) evicts the hot prefix on every churn burst; the
        // recency-weighted reuse score keeps it parked, so every later
        // turn adopts.
        let run = |boost: u64| {
            let mut b = backend(Parallelism::sequential());
            b.prefix_cache_pages = 2;
            b.set_prefix_reuse_boost(boost);
            let hot = |turn: usize| Request {
                conversation: 7,
                turn,
                ..req(0, 70)
            };
            let r0 = hot(0);
            let t0 = prompt_tokens(&r0, b.model.vocab);
            b.prefill(0, &r0, &t0).unwrap();
            b.release(0); // parks the hot conversation's one full page
            for round in 1..=4usize {
                let r = hot(round);
                let t = prompt_tokens(&r, b.model.vocab);
                b.prefill(0, &r, &t).unwrap();
                b.release(0);
                // Two one-shot conversations churn the budget.
                for k in 0..2 {
                    let one = Request {
                        conversation: 100 + round * 2 + k,
                        ..req(1, 70)
                    };
                    let t1 = prompt_tokens(&one, b.model.vocab);
                    b.prefill(1, &one, &t1).unwrap();
                    b.release(1);
                }
            }
            b.prefix_stats().hits
        };
        let lru_hits = run(0);
        let scored_hits = run(8);
        assert!(
            scored_hits > lru_hits,
            "reuse-weighted admission must beat LRU: {scored_hits} vs {lru_hits}"
        );
        assert_eq!(scored_hits, 4, "every returning turn must adopt under the score");
    }

    #[test]
    fn steady_state_decode_spawns_no_threads() {
        use crate::exec::runtime;
        // `new()` warms the worker pool for the configured parallelism;
        // after a prefill + a few warmup decodes, the decode path must
        // never create an OS thread again (the acceptance gate — spawn
        // attribution is per calling thread, so concurrent tests in
        // this binary cannot perturb the counter).
        let mut b = backend(Parallelism::with_threads(3));
        let r = req(0, 40);
        let toks = prompt_tokens(&r, b.model.vocab);
        b.prefill(0, &r, &toks).unwrap();
        for _ in 0..3 {
            b.decode(&[0]).unwrap();
        }
        let before = runtime::spawns_on_this_thread();
        for _ in 0..20 {
            b.decode(&[0]).unwrap();
        }
        assert_eq!(
            runtime::spawns_on_this_thread(),
            before,
            "steady-state decode must perform zero thread spawns"
        );
    }

    #[test]
    fn tokens_are_deterministic_across_backends() {
        let mk = || {
            let mut b = backend(Parallelism::sequential());
            b.enable_token_log();
            let r = req(7, 33);
            let toks = prompt_tokens(&r, b.model.vocab);
            b.prefill(0, &r, &toks).unwrap();
            for _ in 0..4 {
                b.decode(&[0]).unwrap();
            }
            b.token_log
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn admission_rejects_requests_that_can_never_fit() {
        let mut b = backend(Parallelism::sequential());
        b.set_page_cap(2); // 2 pages x 64 tokens, single layer
        assert!(b.admit_check(&req(0, 40)).is_ok()); // 47 tokens -> 1 page
        let err = b.admit_check(&req(1, 130)).unwrap_err(); // 137 -> 3 pages
        assert!(err.contains("can never fit"), "{err}");
        // The context-window check still fires (and first).
        let err = b.admit_check(&req(2, 2000)).unwrap_err();
        assert!(err.contains("exceeds context window"), "{err}");
    }

    #[test]
    fn kv_preflight_fails_cleanly_at_zero_availability() {
        let mut b = backend(Parallelism::sequential());
        let r = req(0, 64); // exactly one full page
        let toks = prompt_tokens(&r, b.model.vocab);
        b.prefill(0, &r, &toks).unwrap();
        let (alloc0, _) = b.kv_pages();
        // The sequence sits on a page boundary: the next decode needs
        // one fresh page per layer. Cap the pool at its current size
        // and the preflight must fail without appending anything.
        b.set_page_cap(alloc0);
        assert_eq!(b.available_kv_pages(), 0);
        assert_eq!(b.decode_pages_needed(0), 1);
        let err = b.decode(&[0]).unwrap_err().to_string();
        assert!(err.contains("KV preflight"), "{err}");
        let (alloc1, _) = b.kv_pages();
        assert_eq!(alloc1, alloc0, "failed preflight must not allocate");
        // Capacity returns -> the very same decode succeeds.
        b.set_page_cap(alloc0 + 1);
        b.decode(&[0]).unwrap();
    }

    #[test]
    fn a_poisoned_job_fails_one_slot_and_survivors_match_bitwise() {
        use crate::exec::runtime;
        // Reference streams, served together with no faults.
        let prompts = [9usize, 23, 40];
        let mut h = backend(Parallelism::sequential());
        let mut want: Vec<Vec<u32>> = Vec::new();
        for (i, &plen) in prompts.iter().enumerate() {
            let r = req(i, plen);
            let toks = prompt_tokens(&r, h.model.vocab);
            let (_, first) = h.prefill(i, &r, &toks).unwrap();
            want.push(vec![first]);
        }
        for _ in 0..5 {
            let (_, ts) = h.decode(&[0, 1, 2]).unwrap();
            for (i, t) in ts.iter().enumerate() {
                want[i].push(*t);
            }
        }

        for threads in [1, 2, 4] {
            let mut b = backend(Parallelism::with_threads(threads));
            let mut outs: Vec<Vec<u32>> = Vec::new();
            for (i, &plen) in prompts.iter().enumerate() {
                let r = req(i, plen);
                let toks = prompt_tokens(&r, b.model.vocab);
                let (_, first) = b.prefill(i, &r, &toks).unwrap();
                outs.push(vec![first]);
            }
            for stepno in 0..5 {
                if stepno == 2 {
                    // Poison grid item 0 — the first block of the first
                    // job, i.e. slot 0's decode. Only that slot fails.
                    runtime::inject_panic_next_launch(0);
                    let rep = b.step(&[], &[0, 1, 2]).unwrap();
                    assert_eq!(rep.failed.len(), 1, "threads={threads}");
                    assert_eq!(rep.failed[0].0, 0, "threads={threads}");
                    assert!(rep.failed[0].1.contains("worker panic"));
                    assert_eq!(rep.tokens.len(), 2, "threads={threads}");
                    for &(slot, tok) in &rep.tokens {
                        outs[slot].push(tok);
                    }
                    b.release(0);
                } else if stepno > 2 {
                    let (_, ts) = b.decode(&[1, 2]).unwrap();
                    outs[1].push(ts[0]);
                    outs[2].push(ts[1]);
                } else {
                    let (_, ts) = b.decode(&[0, 1, 2]).unwrap();
                    for (i, t) in ts.iter().enumerate() {
                        outs[i].push(*t);
                    }
                }
            }
            runtime::clear_injected_panic();
            // Survivors' streams are bitwise identical to the healthy
            // run; the victim matches up to the fault.
            assert_eq!(outs[1], want[1], "threads={threads}");
            assert_eq!(outs[2], want[2], "threads={threads}");
            assert_eq!(&outs[0][..3], &want[0][..3], "threads={threads}");
            // No pages leak past release + cache clear.
            b.release(1);
            b.release(2);
            b.clear_prefix_cache();
            let (alloc, free) = b.kv_pages();
            assert_eq!(alloc, free, "threads={threads}");
        }
    }
}
