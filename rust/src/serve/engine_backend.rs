//! The engine-backed serving backend: requests execute on the *real*
//! fused tiled engine, not the cost model.
//!
//! Three pieces make a decode step cheap and batched:
//!
//! * **Slot-paged KV** ([`super::kv::PagedKv`]) — one page pool shared
//!   across slots; appends are in-place, gathers produce the padded
//!   bucketed tensors the cached plans expect.
//! * **Plan cache** ([`crate::fusion::PlanCache`]) — fusion plans (and
//!   their autotuned tile schedules) are keyed by shape class (variant +
//!   heads + bucketed lengths), so steady-state decode re-plans nothing:
//!   a step is a cache hit returning an `Arc<CachedPlan>`.
//! * **Cross-request grid scheduling**
//!   ([`crate::exec::execute_plans_batched`]) — every active slot's
//!   decode step contributes its `LogicalGrid` blocks as tagged work
//!   items to one shared worker pool, so `SchedulerConfig::parallelism`
//!   is filled by the *batch*, not by any single request's (tiny) grid.
//!
//! Determinism: K/V/q embeddings are pure functions of (token, position),
//! plans are shape-keyed, and the batched executor merges per plan in
//! block order — so the token stream is bitwise identical whether slots
//! decode together or one at a time, at any thread count (asserted by
//! the tests below and gated in the serve bench).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::exec::{execute_plans_batched, Parallelism, PlanJob, Tensor};
use crate::fusion::{bucket_len, CacheStats, CachedPlan, PlanCache, PlanKey};
use crate::tracegen::{Request, Rng};
use crate::variants::{build_serving, AttnShape, Variant};

use super::engine::{Backend, SchedulerConfig};
use super::kv::{PagedKv, DEFAULT_BLOCK_TOKENS};

/// The tiny attention model the engine backend serves: one attention
/// layer per step with deterministic token embeddings (the repo's scope
/// is the attention path; the transformer backbone stays out of it).
#[derive(Debug, Clone, Copy)]
pub struct EngineModel {
    pub variant: Variant,
    pub heads_q: usize,
    pub heads_kv: usize,
    pub head_dim: usize,
    pub vocab: usize,
}

impl EngineModel {
    /// Small GQA config: fast enough to serve whole traces in tests.
    pub fn tiny() -> Self {
        EngineModel {
            variant: Variant::Causal,
            heads_q: 4,
            heads_kv: 2,
            head_dim: 16,
            vocab: 512,
        }
    }
}

const K_SALT: u64 = 0x4B56_0001;
const V_SALT: u64 = 0x4B56_0002;
const Q_SALT: u64 = 0x4B56_0003;

/// Deterministic per-(token, position) embedding in [-0.5, 0.5).
fn embed(salt: u64, token: u32, pos: usize, n: usize) -> Vec<f32> {
    let seed = salt
        ^ ((token as u64) << 20)
        ^ (pos as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut rng = Rng::new(seed | 1);
    (0..n).map(|_| (rng.f64() - 0.5) as f32).collect()
}

/// Deterministic greedy "sampler": folds the attention output bits, so
/// bitwise-identical outputs yield identical tokens (FNV-1a).
fn sample_token(data: &[f32], vocab: usize) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &x in data {
        h ^= x.to_bits();
        h = h.wrapping_mul(0x0100_0193);
    }
    h % vocab.max(1) as u32
}

pub struct EngineBackend {
    pub model: EngineModel,
    n_slots: usize,
    max_context: usize,
    kv: PagedKv,
    last_token: Vec<u32>,
    plans: PlanCache,
    par: Parallelism,
    log_tokens: bool,
    /// Every emitted token in backend-call order (prefill first tokens,
    /// then decode tokens batch by batch) — the serve bench's
    /// bit-identity gate compares these across thread counts. Only
    /// populated after [`Self::enable_token_log`]; off by default so
    /// long serving runs stay O(1) in generated tokens.
    pub token_log: Vec<u32>,
}

impl EngineBackend {
    pub fn new(model: EngineModel, n_slots: usize, max_context: usize, par: Parallelism) -> Self {
        EngineBackend {
            model,
            n_slots,
            max_context,
            kv: PagedKv::new(
                n_slots,
                DEFAULT_BLOCK_TOKENS,
                model.heads_kv,
                model.head_dim,
            ),
            last_token: vec![0; n_slots],
            plans: PlanCache::new(64),
            par,
            log_tokens: false,
            token_log: Vec::new(),
        }
    }

    /// The serving configuration shared by `serve --backend engine` and
    /// the serve-throughput bench, so the CLI path and the recorded perf
    /// trajectory always measure the same setup.
    pub fn default_server(par: Parallelism) -> Self {
        EngineBackend::new(EngineModel::tiny(), 8, 1024, par)
    }

    /// Record every emitted token into [`Self::token_log`] (the serve
    /// bench's bit-identity gate needs the full stream).
    pub fn enable_token_log(&mut self) {
        self.log_tokens = true;
    }

    fn log_token(&mut self, tok: u32) {
        if self.log_tokens {
            self.token_log.push(tok);
        }
    }

    /// Plan-cache hit/miss counters (surfaced in serving metrics).
    pub fn cache_stats(&self) -> CacheStats {
        self.plans.stats()
    }

    /// KV page-pool occupancy: (allocated, free).
    pub fn kv_pages(&self) -> (usize, usize) {
        (self.kv.allocated_pages(), self.kv.free_pages())
    }

    /// The execution parallelism in effect (set via [`Backend::configure`]).
    pub fn parallelism(&self) -> Parallelism {
        self.par
    }

    /// Fetch (or build + autotune) the plan for one shape class.
    fn plan_entry(&mut self, tag: &'static str, q_len: usize, kv_len: usize) -> Arc<CachedPlan> {
        let m = self.model;
        let key = PlanKey {
            tag,
            variant: m.variant.name(),
            heads_q: m.heads_q,
            heads_kv: m.heads_kv,
            head_dim: m.head_dim,
            q_len,
            kv_len,
        };
        self.plans.get_or_build(key, || {
            let shape = AttnShape {
                batch: 1,
                rows: 1,
                heads_q: m.heads_q,
                heads_kv: m.heads_kv,
                seq: kv_len,
                head_dim: m.head_dim,
            };
            build_serving(m.variant, &shape, q_len)
        })
    }

    /// Assemble the engine inputs for one slot: gathered padded K/V plus
    /// the runtime `kv_len` / `q_off` scalars.
    fn attn_inputs(
        &self,
        slot: usize,
        q: Tensor,
        bucket: usize,
        len: usize,
        q_off: usize,
    ) -> HashMap<String, Tensor> {
        let (hkv, d) = (self.model.heads_kv, self.model.head_dim);
        let mut kbuf = Vec::new();
        let mut vbuf = Vec::new();
        self.kv.gather(slot, bucket, &mut kbuf, &mut vbuf);
        let mut m = HashMap::new();
        m.insert("q".to_string(), q);
        m.insert(
            "k".to_string(),
            Tensor::from_vec(&[1, hkv, 1, bucket, d], kbuf),
        );
        m.insert(
            "v".to_string(),
            Tensor::from_vec(&[1, hkv, 1, bucket, d], vbuf),
        );
        m.insert(
            "kv_len".to_string(),
            Tensor::from_vec(&[1, 1, 1, 1, 1], vec![len as f32]),
        );
        m.insert(
            "q_off".to_string(),
            Tensor::from_vec(&[1, 1, 1, 1, 1], vec![q_off as f32]),
        );
        m
    }
}

impl Backend for EngineBackend {
    fn n_slots(&self) -> usize {
        self.n_slots
    }

    fn max_context(&self) -> usize {
        self.max_context
    }

    fn configure(&mut self, cfg: &SchedulerConfig) {
        self.par = cfg.parallelism;
    }

    fn prefill(
        &mut self,
        slot: usize,
        _req: &Request,
        tokens: &[u32],
    ) -> anyhow::Result<(f64, u32)> {
        let t0 = Instant::now();
        anyhow::ensure!(self.kv.is_empty(slot), "prefill into a non-empty slot {slot}");
        anyhow::ensure!(
            tokens.len() <= self.max_context,
            "prompt of {} tokens exceeds context window {}",
            tokens.len(),
            self.max_context
        );
        let bos = [0u32];
        let toks: &[u32] = if tokens.is_empty() { &bos } else { tokens };
        let (hq, d) = (self.model.heads_q, self.model.head_dim);
        let stride = self.kv.token_stride();
        for (pos, &tok) in toks.iter().enumerate() {
            let k = embed(K_SALT, tok, pos, stride);
            let v = embed(V_SALT, tok, pos, stride);
            self.kv.append(slot, &k, &v);
        }
        let s = toks.len();
        let bucket = bucket_len(s, self.kv.block_tokens());
        let entry = self.plan_entry("prefill", bucket, bucket);
        // q rows: one per prompt token (head-major, zero-padded rows).
        let mut q = vec![0f32; hq * bucket * d];
        for (pos, &tok) in toks.iter().enumerate() {
            let qe = embed(Q_SALT, tok, pos, hq * d); // [hq][d]
            for h in 0..hq {
                let dst = (h * bucket + pos) * d;
                q[dst..dst + d].copy_from_slice(&qe[h * d..(h + 1) * d]);
            }
        }
        let q = Tensor::from_vec(
            &[1, self.model.heads_kv, hq / self.model.heads_kv, bucket, d],
            q,
        );
        let inputs = self.attn_inputs(slot, q, bucket, s, 0);
        let (outs, _c) = entry
            .plan
            .execute(&entry.graph, &inputs, entry.tile, self.par);
        // First token from the last valid q row across all heads.
        let out = &outs[0]; // [1, hkv, g, bucket, d] == [hq][bucket][d]
        let mut row = Vec::with_capacity(hq * d);
        for h in 0..hq {
            let off = (h * bucket + (s - 1)) * d;
            row.extend_from_slice(&out.data[off..off + d]);
        }
        let tok = sample_token(&row, self.model.vocab);
        self.last_token[slot] = tok;
        self.log_token(tok);
        Ok((t0.elapsed().as_secs_f64(), tok))
    }

    fn decode(&mut self, active: &[usize]) -> anyhow::Result<(f64, Vec<u32>)> {
        let t0 = Instant::now();
        let (hq, hkv, d) = (
            self.model.heads_q,
            self.model.heads_kv,
            self.model.head_dim,
        );
        let stride = self.kv.token_stride();
        // Phase 1 (per slot, scheduler thread): append the pending
        // token's K/V, gather padded inputs, fetch the bucketed plan.
        let mut per_slot: Vec<(Arc<CachedPlan>, HashMap<String, Tensor>)> =
            Vec::with_capacity(active.len());
        for &slot in active {
            anyhow::ensure!(!self.kv.is_empty(slot), "decoding an unprefilled slot {slot}");
            let tok = self.last_token[slot];
            let pos = self.kv.len(slot);
            anyhow::ensure!(pos < self.max_context, "slot {slot} exceeds context");
            let k = embed(K_SALT, tok, pos, stride);
            let v = embed(V_SALT, tok, pos, stride);
            self.kv.append(slot, &k, &v);
            let len = pos + 1;
            let bucket = bucket_len(len, self.kv.block_tokens());
            let entry = self.plan_entry("decode", 1, bucket);
            // q for the single new position: [1, hkv, g, 1, d] is the
            // same flat layout as embed's [hq][d].
            let q = Tensor::from_vec(
                &[1, hkv, hq / hkv, 1, d],
                embed(Q_SALT, tok, pos, hq * d),
            );
            let inputs = self.attn_inputs(slot, q, bucket, len, len - 1);
            per_slot.push((entry, inputs));
        }
        // Phase 2: all slots' grid blocks through ONE shared worker pool.
        let jobs: Vec<PlanJob> = per_slot
            .iter()
            .map(|(e, inp)| PlanJob {
                graph: &e.graph,
                plan: &e.plan,
                inputs: inp,
                tile: e.tile,
            })
            .collect();
        let results = execute_plans_batched(&jobs, &self.par);
        drop(jobs);
        let mut toks = Vec::with_capacity(active.len());
        for (i, &slot) in active.iter().enumerate() {
            let out = &results[i].0[0];
            let tok = sample_token(&out.data, self.model.vocab);
            self.last_token[slot] = tok;
            self.log_token(tok);
            toks.push(tok);
        }
        Ok((t0.elapsed().as_secs_f64(), toks))
    }

    fn release(&mut self, slot: usize) {
        self.kv.release(slot);
        self.last_token[slot] = 0;
    }

    fn is_virtual_time(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::{prompt_tokens, run_trace};
    use crate::tracegen::{generate, TraceConfig};

    fn req(id: usize, input_tokens: usize) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            input_tokens,
            output_tokens: 8,
            conversation: id,
            turn: 0,
        }
    }

    fn backend(par: Parallelism) -> EngineBackend {
        EngineBackend::new(EngineModel::tiny(), 4, 1024, par)
    }

    #[test]
    fn batched_decode_is_bitwise_identical_to_sequential_requests() {
        // N slots decoded together must emit exactly the tokens each
        // request produces when served alone — at multiple thread counts
        // (the issue's batched-decode parity gate).
        let prompts = [9usize, 23, 40];
        let steps = 5;
        let solo: Vec<Vec<u32>> = prompts
            .iter()
            .enumerate()
            .map(|(i, &plen)| {
                let mut b = backend(Parallelism::sequential());
                let r = req(i, plen);
                let toks = prompt_tokens(&r, b.model.vocab);
                let (_, first) = b.prefill(0, &r, &toks).unwrap();
                let mut out = vec![first];
                for _ in 0..steps {
                    let (_, t) = b.decode(&[0]).unwrap();
                    out.push(t[0]);
                }
                out
            })
            .collect();
        for threads in [1, 2, 4] {
            let mut b = backend(Parallelism::with_threads(threads));
            let mut outs: Vec<Vec<u32>> = Vec::new();
            for (i, &plen) in prompts.iter().enumerate() {
                let r = req(i, plen);
                let toks = prompt_tokens(&r, b.model.vocab);
                let (_, first) = b.prefill(i, &r, &toks).unwrap();
                outs.push(vec![first]);
            }
            for _ in 0..steps {
                let (_, ts) = b.decode(&[0, 1, 2]).unwrap();
                for (i, t) in ts.iter().enumerate() {
                    outs[i].push(*t);
                }
            }
            assert_eq!(outs, solo, "threads={threads}");
        }
    }

    #[test]
    fn plan_cache_hit_rate_exceeds_90_percent_at_steady_state() {
        let mut b = backend(Parallelism::sequential());
        for (i, plen) in [40usize, 55, 62, 70].into_iter().enumerate() {
            let r = req(i, plen);
            let toks = prompt_tokens(&r, b.model.vocab);
            b.prefill(i, &r, &toks).unwrap();
        }
        for _ in 0..60 {
            b.decode(&[0, 1, 2, 3]).unwrap();
        }
        let s = b.cache_stats();
        assert!(
            s.hit_rate() > 0.9,
            "steady-state decode hit rate {:.3} too low: {s:?}",
            s.hit_rate()
        );
    }

    #[test]
    fn engine_backend_completes_a_generated_trace() {
        let trace = generate(&TraceConfig {
            n_requests: 8,
            rate: 100.0,
            input_mu: 3.0,
            input_sigma: 0.5,
            mean_output: 4.0,
            max_input: 48,
            max_output: 6,
            ..Default::default()
        });
        let mut b = backend(Parallelism::sequential());
        let vocab = b.model.vocab;
        let cfg = SchedulerConfig {
            parallelism: Parallelism::with_threads(2),
            ..Default::default()
        };
        let done = run_trace(&mut b, &trace, cfg, vocab).unwrap();
        assert_eq!(done.len(), trace.len());
        for (m, r) in done.iter().zip(&trace) {
            assert_eq!(m.id, r.id);
            assert_eq!(m.itls.len(), r.output_tokens.max(1) - 1);
        }
        // SchedulerConfig.parallelism reached the backend (satellite:
        // --threads flows end to end through configure()).
        assert_eq!(b.parallelism().num_threads, 2);
        // All slots were released: every page is back on the free list.
        let (allocated, free) = b.kv_pages();
        assert_eq!(allocated, free);
    }

    #[test]
    fn kv_pages_are_shared_and_released() {
        let mut b = backend(Parallelism::sequential());
        let r = req(0, 100);
        let toks = prompt_tokens(&r, b.model.vocab);
        b.prefill(0, &r, &toks).unwrap();
        let (alloc_after_prefill, _) = b.kv_pages();
        assert_eq!(alloc_after_prefill, 2, "100 tokens = 2 pages of 64");
        b.release(0);
        let (_, free) = b.kv_pages();
        assert_eq!(free, 2);
        // A new request reuses the freed pages.
        b.prefill(1, &r, &toks).unwrap();
        let (alloc2, free2) = b.kv_pages();
        assert_eq!(alloc2, 2);
        assert_eq!(free2, 0);
    }

    #[test]
    fn tokens_are_deterministic_across_backends() {
        let mk = || {
            let mut b = backend(Parallelism::sequential());
            b.enable_token_log();
            let r = req(7, 33);
            let toks = prompt_tokens(&r, b.model.vocab);
            b.prefill(0, &r, &toks).unwrap();
            for _ in 0..4 {
                b.decode(&[0]).unwrap();
            }
            b.token_log
        };
        assert_eq!(mk(), mk());
    }
}
