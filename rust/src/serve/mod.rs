//! Serving stack (paper §4.4): vLLM-style coordinator, simulated
//! LLaMa-3.2-1B backend for Fig 5, the engine backend that executes
//! requests on the real fused tiled engine (slot-paged KV + plan cache +
//! cross-request grid scheduling — see `serve/README.md`), and the PJRT
//! backend over the tiny AOT-compiled model.

pub mod engine;
pub mod engine_backend;
pub mod faults;
pub mod kv;
pub mod lifecycle;
pub mod live;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod router;
pub mod shard;
pub mod sim;
pub mod supervisor;

pub use engine::{run_trace, Backend, SchedulerConfig};
pub use engine_backend::{EngineBackend, EngineModel, PrefixStats};
pub use faults::{Fault, FaultPlan, FAULTS_ENV};
pub use kv::{KvError, PagedKv};
pub use lifecycle::{
    run_lifecycle, run_lifecycle_ext, ClockMode, Ingress, LifecycleConfig, LifecycleReport,
    LifecycleStats,
};
pub use live::{
    spawn_ingress, stream_buf_from_env, LiveSubmission, StreamEvent, StreamHub,
    DEFAULT_STREAM_BUF, STREAM_BUF_ENV,
};
pub use metrics::{
    load_point, summarize, summarize_outcomes, LifecycleSummary, LoadPoint, Outcome,
    RequestMetrics, RequestOutcome, Summary,
};
pub use router::{run_sharded, Router, RouterConfig, ShardedReport};
pub use shard::{shard_domains, Shard, ShardHealth};
pub use supervisor::{stall_budget_from_env, Supervisor, DEFAULT_STALL_MS, STALL_MS_ENV};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use sim::{llama_3_2_1b, ModelShape, SimBackend};

use crate::baselines::System;
use crate::bench::harness::Csv;
use crate::cost::GpuSpec;
use crate::tracegen::{generate, TraceConfig};
use crate::variants::Variant;

/// The Fig 5 trace: first 200 requests of a Mooncake-like conversation
/// trace at LLaMa-1B serving scale.
pub fn fig5_trace(n: usize) -> Vec<crate::tracegen::Request> {
    generate(&TraceConfig {
        n_requests: n,
        rate: 120.0, // saturating replay, like the paper's back-to-back 200 requests
        input_mu: 6.3, // ~540 tokens median first turn
        input_sigma: 0.9,
        mean_output: 96.0,
        max_input: 4096,
        max_output: 256,
        ..Default::default()
    })
}

/// Figure 5: TTFT / ITL / token throughput for LLaMa-3.2-1B variants
/// under Flashlight vs FlexAttention on the Mooncake-like trace.
pub fn bench_fig5(spec: &GpuSpec) -> anyhow::Result<()> {
    println!(
        "== Figure 5: Mooncake-like trace, LLaMa-3.2-1B shapes, {} ==",
        spec.name
    );
    let trace = fig5_trace(200);
    let mut csv = Csv::new(
        crate::bench::figures::OUT_DIR,
        "fig5.csv",
        "gpu,variant,system,ttft_mean_ms,ttft_p99_ms,itl_mean_ms,itl_p99_ms,tokens_per_s",
    );
    println!(
        "{:<10} {:<22} {:>10} {:>10} {:>9} {:>9} {:>10}",
        "variant", "system", "TTFT(ms)", "p99", "ITL(ms)", "p99", "tok/s"
    );
    for variant in [
        Variant::Vanilla,
        Variant::Causal,
        Variant::Softcap { cap: 20.0 },
    ] {
        let mut totals = vec![];
        for system in [
            System::Flashlight,
            System::FlexAttention { mask_cached: false },
        ] {
            let mut backend = SimBackend::new(*spec, system, variant);
            let done = run_trace(
                &mut backend,
                &trace,
                SchedulerConfig::default(),
                llama_3_2_1b().vocab,
            )?;
            let s = summarize(&done);
            println!(
                "{:<10} {:<22} {:>10.2} {:>10.2} {:>9.3} {:>9.3} {:>10.1}",
                variant.name(),
                system.label(),
                s.ttft_mean_s * 1e3,
                s.ttft_p99_s * 1e3,
                s.itl_mean_s * 1e3,
                s.itl_p99_s * 1e3,
                s.tokens_per_s
            );
            csv.row(&[
                spec.name.into(),
                variant.name().into(),
                system.label().into(),
                format!("{:.3}", s.ttft_mean_s * 1e3),
                format!("{:.3}", s.ttft_p99_s * 1e3),
                format!("{:.4}", s.itl_mean_s * 1e3),
                format!("{:.4}", s.itl_p99_s * 1e3),
                format!("{:.2}", s.tokens_per_s),
            ]);
            totals.push(s.tokens_per_s);
        }
        let better = if totals[0] >= totals[1] {
            "flashlight"
        } else {
            "flexattention"
        };
        println!("{:<10} -> higher throughput: {}", variant.name(), better);
    }
    let p = csv.finish()?;
    println!("wrote {}", p.display());
    Ok(())
}

/// Mooncake's core trade (storage for computation): serving throughput
/// with vs without conversation prefix caching, Flashlight attention.
pub fn bench_prefix_caching(spec: &GpuSpec) -> anyhow::Result<()> {
    println!("== Mooncake prefix-caching ablation ({}) ==", spec.name);
    let trace = fig5_trace(200);
    for caching in [false, true] {
        let mut backend = SimBackend::new(*spec, System::Flashlight, Variant::Causal);
        backend.prefix_caching = caching;
        let done = run_trace(
            &mut backend,
            &trace,
            SchedulerConfig::default(),
            llama_3_2_1b().vocab,
        )?;
        let s = summarize(&done);
        println!(
            "  prefix_caching={:<5} TTFT mean {:8.2} ms p99 {:8.2} ms | tok/s {:8.1}",
            caching,
            s.ttft_mean_s * 1e3,
            s.ttft_p99_s * 1e3,
            s.tokens_per_s
        );
    }
    Ok(())
}

/// Trace sized for the engine backend: prompt buckets the real tiled
/// executor prefills comfortably on CPU, with a decode-heavy tail.
pub fn engine_trace(n: usize) -> Vec<crate::tracegen::Request> {
    generate(&TraceConfig {
        n_requests: n,
        rate: 50.0,
        input_mu: 4.0, // ~55 tokens median prompt
        input_sigma: 0.6,
        mean_output: 10.0,
        max_input: 192,
        max_output: 24,
        ..Default::default()
    })
}

/// Knobs for `serve --backend engine`. Defaults match the serve
/// bench's chunked *single-layer* cell (layers 1, chunk 64); pass
/// `--layers 4` to reproduce the bench's deep rows.
#[derive(Debug, Clone, Copy)]
pub struct EngineServeOpts {
    /// Attention layers per token step.
    pub layers: usize,
    /// Prefill chunk size in tokens (0 = whole-prompt prefill).
    pub chunk_tokens: usize,
    /// Per-round prefill budget in row-layer units — one unit advances
    /// one prompt row through one layer, so a full row costs `layers`
    /// units (0 = unbounded).
    pub round_tokens: usize,
    /// Default completion deadline applied to requests that carry none
    /// (`--deadline-ms`; 0 = no default deadline).
    pub deadline_ms: u64,
    /// Ingress queue bound (`--queue-cap`; 0 = unbounded, no
    /// rejection).
    pub queue_cap: usize,
    /// KV page-pool cap (`--kv-pages`; 0 = uncapped). Pressure faults
    /// and the preemption ladder only bind against a finite cap.
    pub kv_page_cap: usize,
    /// `--live`: serve through the threaded ingress + per-request token
    /// streams under a watchdog instead of replaying the trace inline
    /// (serve); run the live chaos gates (chaos).
    pub live: bool,
    /// `--shards N`: run N engine instances behind the conversation-
    /// sticky router (see [`router`]) instead of a single backend.
    /// 1 = unsharded (the default). Takes precedence over `--live`.
    pub shards: usize,
}

impl Default for EngineServeOpts {
    fn default() -> Self {
        EngineServeOpts {
            layers: 1,
            chunk_tokens: 64,
            round_tokens: 256,
            deadline_ms: 0,
            queue_cap: 0,
            kv_page_cap: 0,
            live: false,
            shards: 1,
        }
    }
}

/// `flashlight serve` CLI: run the coordinator on a trace with the
/// simulated backend, the real tiled-engine backend, or the PJRT
/// backend (fused vs naive). `par` is handed to backends that execute
/// real plans (see [`SchedulerConfig::parallelism`]); `opts` only
/// applies to the engine backend.
pub fn cli_serve(
    n_requests: usize,
    backend: &str,
    par: crate::exec::Parallelism,
    opts: EngineServeOpts,
) -> anyhow::Result<()> {
    match backend {
        "sim" => {
            let spec = crate::cost::h100();
            bench_fig5(&spec)?;
            let _ = (n_requests, par);
            Ok(())
        }
        "engine" => serve_engine(n_requests, par, opts),
        "pjrt" => serve_pjrt(n_requests, par),
        other => anyhow::bail!("unknown backend {other} (sim|engine|pjrt)"),
    }
}

/// Real tiled-engine serving run under the fault-tolerant lifecycle:
/// chunk-scheduled multi-layer serving on the fused executor with
/// slot-paged KV, conversation prefix reuse, the pre-warmed fusion
/// plan cache, bounded ingress, deadlines, and KV-pressure preemption.
/// Fault injection comes from the `FLASHLIGHT_FAULTS` env var (see
/// [`faults`]).
fn serve_engine(
    n_requests: usize,
    par: crate::exec::Parallelism,
    opts: EngineServeOpts,
) -> anyhow::Result<()> {
    if opts.shards > 1 {
        return serve_engine_sharded(n_requests, par, opts);
    }
    if opts.live {
        return serve_engine_live(n_requests, par, opts);
    }
    let trace = engine_trace(n_requests);
    let mut b = EngineBackend::new(EngineModel::tiny_deep(opts.layers), 8, 1024, par);
    if opts.kv_page_cap > 0 {
        b.set_page_cap(opts.kv_page_cap);
    }
    let vocab = b.model.vocab;
    let cfg = SchedulerConfig {
        parallelism: par,
        prefill_chunk_tokens: opts.chunk_tokens,
        prefill_round_tokens: opts.round_tokens,
        ..Default::default()
    };
    let lc = LifecycleConfig {
        queue_cap: opts.queue_cap,
        default_deadline_s: if opts.deadline_ms == 0 {
            f64::INFINITY
        } else {
            opts.deadline_ms as f64 / 1e3
        },
        clock: ClockMode::Wall,
        ..Default::default()
    };
    let plan = FaultPlan::from_env()?;
    if !plan.is_empty() {
        println!("fault plan ({} events): {plan}", plan.events.len());
    }
    // Plan-cache warmup: build the whole bucket ladder up front so the
    // first request per bucket pays no plan+autotune latency inline.
    b.configure(&cfg);
    let warmed = b.warmup_plans(1024);
    let t0 = std::time::Instant::now();
    let rep = run_lifecycle(&mut b, &trace, cfg, lc, &plan, vocab)?;
    let sum = &rep.summary;
    let s = sum.completed_summary.unwrap_or(Summary {
        n_requests: 0,
        ttft_mean_s: 0.0,
        ttft_p50_s: 0.0,
        ttft_p99_s: 0.0,
        itl_mean_s: 0.0,
        itl_p50_s: 0.0,
        itl_p99_s: 0.0,
        tokens_per_s: 0.0,
        makespan_s: 0.0,
    });
    let cs = b.cache_stats();
    let ps = b.prefix_stats();
    let (pages_alloc, pages_free) = b.kv_pages();
    println!(
        "engine backend: {} reqs in {:.2}s wall | TTFT mean {:.1} ms p99 {:.1} ms | \
         ITL mean {:.2} ms | {:.1} tok/s | {} threads | {} layers | chunk {}",
        s.n_requests,
        t0.elapsed().as_secs_f64(),
        s.ttft_mean_s * 1e3,
        s.ttft_p99_s * 1e3,
        s.itl_mean_s * 1e3,
        s.tokens_per_s,
        b.parallelism().num_threads,
        b.model.layers,
        opts.chunk_tokens,
    );
    println!(
        "lifecycle: {} completed, {} rejected, {} cancelled, {} deadline_exceeded, \
         {} failed | {} preemptions | goodput {:.1} tok/s | {} rounds",
        sum.completed,
        sum.rejected,
        sum.cancelled,
        sum.deadline_exceeded,
        sum.failed,
        sum.preemptions,
        sum.goodput_tokens_per_s,
        rep.stats.rounds,
    );
    println!(
        "plan cache: {} warmed, {} hits / {} misses ({:.1}% hit rate, {} entries) | \
         kv pages: {} allocated, {} free, {} parked",
        warmed,
        cs.hits,
        cs.misses,
        cs.hit_rate() * 100.0,
        cs.entries,
        pages_alloc,
        pages_free,
        ps.parked_pages,
    );
    println!(
        "prefix cache: {} adoptions, {} tokens re-used, {} conversations parked | \
         gather reallocs: {}",
        ps.hits,
        ps.tokens_reused,
        ps.entries,
        b.gather_reallocs(),
    );
    Ok(())
}

/// `flashlight serve --backend engine --live`: the same engine run as
/// [`serve_engine`], but as a *real server* — a dedicated ingress
/// thread paces the trace's arrivals in wall time through a bounded
/// channel, every request streams its tokens to a consumer thread over
/// a bounded per-request channel (slow consumers are cancelled, not
/// buffered without bound), and a watchdog supervises launch liveness
/// (`FLASHLIGHT_STALL_MS`). Dropping the ingress sender drains the
/// server gracefully; the no-leak invariant is checked on exit.
fn serve_engine_live(
    n_requests: usize,
    par: crate::exec::Parallelism,
    opts: EngineServeOpts,
) -> anyhow::Result<()> {
    let trace = engine_trace(n_requests);
    let mut b = EngineBackend::new(EngineModel::tiny_deep(opts.layers), 8, 1024, par);
    if opts.kv_page_cap > 0 {
        b.set_page_cap(opts.kv_page_cap);
    }
    let vocab = b.model.vocab;
    let cfg = SchedulerConfig {
        parallelism: par,
        prefill_chunk_tokens: opts.chunk_tokens,
        prefill_round_tokens: opts.round_tokens,
        ..Default::default()
    };
    let lc = LifecycleConfig {
        queue_cap: opts.queue_cap,
        default_deadline_s: if opts.deadline_ms == 0 {
            f64::INFINITY
        } else {
            opts.deadline_ms as f64 / 1e3
        },
        clock: ClockMode::Wall,
        resubmit_max: 3,
        ..Default::default()
    };
    let plan = FaultPlan::from_env()?;
    if !plan.is_empty() {
        println!("fault plan ({} events): {plan}", plan.events.len());
    }
    b.configure(&cfg);
    let warmed = b.warmup_plans(1024);

    // Per-request bounded token streams; one consumer thread drains
    // them all (a real deployment would hold one socket per client).
    let buf = stream_buf_from_env();
    let mut hub = StreamHub::new(buf * 4);
    let mut subs = Vec::with_capacity(trace.len());
    let mut rxs = Vec::with_capacity(trace.len());
    for r in &trace {
        let (tx, rx) = std::sync::mpsc::sync_channel(buf.max(1));
        rxs.push(rx);
        subs.push((r.clone(), Some(tx)));
    }
    let consumer = std::thread::Builder::new()
        .name("flashlight-consumer".to_string())
        .spawn(move || {
            let mut tokens = 0u64;
            let mut done = 0usize;
            let mut open: Vec<_> = rxs.into_iter().map(Some).collect();
            while open.iter().any(Option::is_some) {
                let mut progressed = false;
                for slot in open.iter_mut() {
                    let mut finished = false;
                    if let Some(rx) = slot.as_ref() {
                        loop {
                            match rx.try_recv() {
                                Ok(StreamEvent::Token(_)) => {
                                    tokens += 1;
                                    progressed = true;
                                }
                                Ok(StreamEvent::Done { .. }) => {
                                    done += 1;
                                    finished = true;
                                    progressed = true;
                                    break;
                                }
                                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                                    finished = true;
                                    break;
                                }
                            }
                        }
                    }
                    if finished {
                        *slot = None;
                    }
                }
                if !progressed {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
            }
            (tokens, done)
        })
        .expect("spawn flashlight consumer");

    let sup = Supervisor::start(stall_budget_from_env());
    let (ingress_rx, ingress) = spawn_ingress(subs, 1.0, 64);
    let t0 = std::time::Instant::now();
    let rep = run_lifecycle_ext(
        &mut b,
        Ingress::Live(ingress_rx),
        cfg,
        lc,
        &plan,
        vocab,
        &mut hub,
        Some(&sup),
    )?;
    let wall = t0.elapsed().as_secs_f64();
    let submitted = ingress.join().expect("ingress thread");
    drop(hub); // close any surviving stream senders before joining
    let (streamed_tokens, streamed_done) = consumer.join().expect("consumer thread");
    let kills = sup.stop();
    let sum = &rep.summary;
    println!(
        "live engine backend: {submitted} submitted over {wall:.2}s wall | \
         {} completed, {} rejected, {} cancelled, {} deadline_exceeded, {} failed | \
         goodput {:.1} tok/s | {} rounds",
        sum.completed,
        sum.rejected,
        sum.cancelled,
        sum.deadline_exceeded,
        sum.failed,
        sum.goodput_tokens_per_s,
        rep.stats.rounds,
    );
    println!(
        "supervision: {kills} watchdog kills | {} backoff requeues | \
         {} slow-consumer cancels | streams: {streamed_tokens} tokens to \
         {streamed_done} consumers | plans warmed: {warmed}",
        rep.stats.backoff_requeues,
        rep.stats.slow_consumer_cancels,
    );
    let (pages_alloc, pages_free) = b.kv_pages();
    let parked = b.prefix_stats().parked_pages;
    println!(
        "drain: kv pages {} allocated, {} free, {} parked (no-leak invariant held)",
        pages_alloc, pages_free, parked,
    );
    Ok(())
}

/// `flashlight chaos`: replay the engine trace under deterministic
/// fault plans and enforce the lifecycle's three invariants, loudly.
///
/// For every plan (parsed from `specs`, e.g. `seed=1` or
/// `pressure@2:4x6;panic@3;cancel@4:1;storm@6:2`):
///
/// 1. **Terminal accounting** — every request ends in exactly one of
///    `completed | rejected | cancelled | deadline_exceeded | failed`.
/// 2. **No leaks** — allocated KV pages return to `free + parked`, and
///    to exactly `free` once the prefix cache is cleared.
/// 3. **Bit-identical survivors** — every request that completes under
///    the fault plan emits the same token stream as the fault-free
///    reference run, even if it was preempted and retried.
///
/// Runs on the deterministic round clock so a failure reproduces
/// anywhere from the (trace, config, plan) triple alone. Any gate
/// violation returns an error (non-zero CLI exit) naming the plan.
pub fn chaos(
    n_requests: usize,
    par: crate::exec::Parallelism,
    opts: EngineServeOpts,
    specs: &[String],
) -> anyhow::Result<()> {
    if opts.shards > 1 {
        return chaos_sharded(n_requests, par, opts, specs);
    }
    if opts.live {
        return chaos_live(n_requests, opts, specs);
    }
    let trace = engine_trace(n_requests);
    // A tight page cap makes pressure windows and the preemption
    // ladder actually bind (the trace's worst request needs ~4 pages
    // per layer; 8 slots would want ~32).
    let cap = if opts.kv_page_cap > 0 {
        opts.kv_page_cap
    } else {
        20 * opts.layers
    };
    let mk = || {
        let mut b = EngineBackend::new(EngineModel::tiny_deep(opts.layers), 8, 1024, par);
        b.set_page_cap(cap);
        b
    };
    let cfg = SchedulerConfig {
        parallelism: par,
        prefill_chunk_tokens: opts.chunk_tokens,
        prefill_round_tokens: opts.round_tokens,
        ..Default::default()
    };
    // The reference run must complete everything, so the chaos clock is
    // deterministic rounds with no deadline default and no queue bound
    // (fault plans inject the adversity themselves).
    let lc = LifecycleConfig {
        clock: ClockMode::Rounds,
        ..Default::default()
    };
    let mut hb = mk();
    let vocab = hb.model.vocab;
    let healthy = run_lifecycle(&mut hb, &trace, cfg, lc, &FaultPlan::none(), vocab)?;
    anyhow::ensure!(
        healthy.summary.completed == trace.len(),
        "fault-free reference run must complete all {} requests (completed {})",
        trace.len(),
        healthy.summary.completed
    );
    let reference: std::collections::HashMap<usize, Vec<u32>> = healthy
        .outcomes
        .into_iter()
        .map(|o| (o.id, o.tokens))
        .collect();
    println!(
        "chaos: {} requests, {} plans, {} threads, {} layers",
        trace.len(),
        specs.len(),
        par.num_threads,
        opts.layers
    );
    for spec in specs {
        let plan = FaultPlan::parse(spec)?;
        let mut b = mk();
        let rep = run_lifecycle(&mut b, &trace, cfg, lc, &plan, vocab)?;
        let sum = &rep.summary;
        anyhow::ensure!(
            sum.total() == trace.len(),
            "plan `{spec}`: terminal accounting broken — {} terminals for {} requests",
            sum.total(),
            trace.len()
        );
        let (alloc, free) = b.kv_pages();
        let parked = b.prefix_stats().parked_pages;
        anyhow::ensure!(
            alloc == free + parked,
            "plan `{spec}`: page leak — {alloc} allocated vs {free} free + {parked} parked"
        );
        b.clear_prefix_cache();
        let (alloc, free) = b.kv_pages();
        anyhow::ensure!(
            alloc == free,
            "plan `{spec}`: page leak after prefix-cache clear — {alloc} allocated, {free} free"
        );
        for o in rep.outcomes.iter().filter(|o| o.outcome == Outcome::Completed) {
            let want = reference.get(&o.id).ok_or_else(|| {
                anyhow::anyhow!("plan `{spec}`: request {} has no fault-free reference", o.id)
            })?;
            anyhow::ensure!(
                &o.tokens == want,
                "plan `{spec}`: request {} diverged from the fault-free run \
                 ({} tokens vs {}, preempted {}x)",
                o.id,
                o.tokens.len(),
                want.len(),
                o.preemptions
            );
        }
        println!(
            "  plan `{spec}` OK: {} completed, {} rejected, {} cancelled, \
             {} deadline_exceeded, {} failed | {} preemptions | {} rounds | \
             goodput {:.1} tok/round | survivors bit-identical, no leaks",
            sum.completed,
            sum.rejected,
            sum.cancelled,
            sum.deadline_exceeded,
            sum.failed,
            sum.preemptions,
            rep.stats.rounds,
            sum.goodput_tokens_per_s,
        );
    }
    println!("chaos: all {} plans passed", specs.len());
    Ok(())
}

/// `flashlight chaos --live`: the live-serving chaos gates.
///
/// **Deterministic half** (`ClockMode::Rounds`, open-loop ingress with
/// every arrival compressed to round 0 so the bounded queue *must*
/// overflow into backoff): each fault plan runs at 1, 2, and 4 threads
/// with per-request token streams attached, and the gates require
///
/// 1. exactly one terminal per request, at every thread count;
/// 2. zero leaked pages (`allocated == free + parked`, and
///    `allocated == free` after the prefix cache clears);
/// 3. the **entire outcome vector** — terminal state and token stream
///    per request, with backoff requeues and (for stall plans)
///    watchdog-killed launches in flight — bit-identical across
///    1/2/4 threads, and completed streams identical to the fault-free
///    reference;
/// 4. every attached stream carries exactly the tokens its outcome
///    recorded, ending in `Done` with the matching terminal;
/// 5. stall plans actually exercise the watchdog (`kills >= 1`) and
///    the run requeues through backoff (`backoff_requeues >= 1`).
///
/// **Wall-clock half**: one real live run — ingress thread, bounded
/// submission channel, graceful drain — gated on terminal accounting
/// and the no-leak invariant.
pub fn chaos_live(
    n_requests: usize,
    opts: EngineServeOpts,
    specs: &[String],
) -> anyhow::Result<()> {
    use std::collections::HashMap;

    let trace = engine_trace(n_requests);
    let cap = if opts.kv_page_cap > 0 {
        opts.kv_page_cap
    } else {
        20 * opts.layers
    };
    let mk = |par: crate::exec::Parallelism| {
        let mut b = EngineBackend::new(EngineModel::tiny_deep(opts.layers), 8, 1024, par);
        b.set_page_cap(cap);
        b
    };
    let cfg_for = |par: crate::exec::Parallelism| SchedulerConfig {
        parallelism: par,
        prefill_chunk_tokens: opts.chunk_tokens,
        prefill_round_tokens: opts.round_tokens,
        ..Default::default()
    };
    // Small queue + compressed arrivals force the backoff path; three
    // retries with exponential windows let everyone land eventually.
    let lc = LifecycleConfig {
        clock: ClockMode::Rounds,
        queue_cap: 4,
        resubmit_max: 3,
        ..Default::default()
    };
    let vocab = EngineModel::tiny().vocab;

    // Fault-free reference (1 thread; determinism across threads is
    // itself a gate below).
    let reference: HashMap<usize, Vec<u32>> = {
        let par = crate::exec::Parallelism::with_threads(1);
        let mut b = mk(par);
        let mut hub = StreamHub::disabled();
        let rep = run_lifecycle_ext(
            &mut b,
            Ingress::OpenLoop { trace: &trace, time_scale: 0.0 },
            cfg_for(par),
            lc,
            &FaultPlan::none(),
            vocab,
            &mut hub,
            None,
        )?;
        rep.outcomes
            .into_iter()
            .filter(|o| o.outcome == Outcome::Completed)
            .map(|o| (o.id, o.tokens))
            .collect()
    };
    anyhow::ensure!(
        !reference.is_empty(),
        "live chaos reference run completed nothing"
    );
    println!(
        "chaos --live: {} requests, {} plans, queue_cap {}, resubmit_max {}, page cap {}",
        trace.len(),
        specs.len(),
        lc.queue_cap,
        lc.resubmit_max,
        cap
    );

    for spec in specs {
        let plan = FaultPlan::parse(spec)?;
        let mut runs: Vec<Vec<(usize, Outcome, Vec<u32>)>> = Vec::new();
        for threads in [1usize, 2, 4] {
            let par = crate::exec::Parallelism::with_threads(threads);
            let mut b = mk(par);
            let mut hub = StreamHub::new(256);
            let rxs: Vec<_> = trace.iter().map(|r| hub.open(r.id, 64)).collect();
            let rep = run_lifecycle_ext(
                &mut b,
                Ingress::OpenLoop { trace: &trace, time_scale: 0.0 },
                cfg_for(par),
                lc,
                &plan,
                vocab,
                &mut hub,
                None,
            )?;
            let sum = &rep.summary;
            anyhow::ensure!(
                sum.total() == trace.len(),
                "plan `{spec}` @{threads}t: {} terminals for {} requests",
                sum.total(),
                trace.len()
            );
            anyhow::ensure!(
                rep.stats.backoff_requeues >= 1,
                "plan `{spec}` @{threads}t: compressed arrivals never hit the backoff path"
            );
            if plan.has_stalls() {
                anyhow::ensure!(
                    rep.stats.watchdog_kills >= 1,
                    "plan `{spec}` @{threads}t: stall plan ran with no watchdog kill"
                );
                anyhow::ensure!(
                    sum.failed >= 1,
                    "plan `{spec}` @{threads}t: a killed stalled launch must fail its request"
                );
            }
            let (alloc, free) = b.kv_pages();
            let parked = b.prefix_stats().parked_pages;
            anyhow::ensure!(
                alloc == free + parked,
                "plan `{spec}` @{threads}t: page leak — {alloc} allocated vs {free} free + {parked} parked"
            );
            b.clear_prefix_cache();
            let (alloc, free) = b.kv_pages();
            anyhow::ensure!(
                alloc == free,
                "plan `{spec}` @{threads}t: page leak after prefix-cache clear"
            );
            // Streams must carry exactly the recorded tokens and end
            // with the matching terminal event.
            for (o, rx) in rep.outcomes.iter().zip(rxs) {
                let events: Vec<StreamEvent> = rx.try_iter().collect();
                let toks: Vec<u32> = events
                    .iter()
                    .filter_map(|e| match e {
                        StreamEvent::Token(t) => Some(*t),
                        StreamEvent::Done { .. } => None,
                    })
                    .collect();
                anyhow::ensure!(
                    toks == o.tokens,
                    "plan `{spec}` @{threads}t: request {} streamed {} tokens but recorded {}",
                    o.id,
                    toks.len(),
                    o.tokens.len()
                );
                anyhow::ensure!(
                    matches!(events.last(), Some(StreamEvent::Done { outcome, .. }) if *outcome == o.outcome),
                    "plan `{spec}` @{threads}t: request {} stream did not end in its terminal",
                    o.id
                );
            }
            for o in rep.outcomes.iter().filter(|o| o.outcome == Outcome::Completed) {
                if let Some(want) = reference.get(&o.id) {
                    anyhow::ensure!(
                        &o.tokens == want,
                        "plan `{spec}` @{threads}t: request {} diverged from the fault-free run",
                        o.id
                    );
                }
            }
            println!(
                "  plan `{spec}` @{threads}t: {} completed, {} rejected, {} failed | \
                 {} backoff requeues, {} watchdog kills, {} preemptions | {} rounds",
                sum.completed,
                sum.rejected,
                sum.failed,
                rep.stats.backoff_requeues,
                rep.stats.watchdog_kills,
                rep.stats.preemptions,
                rep.stats.rounds,
            );
            runs.push(
                rep.outcomes
                    .into_iter()
                    .map(|o| (o.id, o.outcome, o.tokens))
                    .collect(),
            );
        }
        anyhow::ensure!(
            runs[0] == runs[1] && runs[0] == runs[2],
            "plan `{spec}`: outcome vector diverged across 1/2/4 threads"
        );
        println!("  plan `{spec}` OK: bit-identical across 1/2/4 threads, no leaks");
    }

    // Wall-clock half: a real threaded ingress with graceful drain.
    {
        let par = crate::exec::Parallelism::with_threads(2);
        let mut b = mk(par);
        let mut hub = StreamHub::new(256);
        let subs: Vec<_> = trace.iter().map(|r| (r.clone(), None)).collect();
        let (rx, ingress) = spawn_ingress(subs, 1e-4, 8);
        let sup = Supervisor::start(500);
        let rep = run_lifecycle_ext(
            &mut b,
            Ingress::Live(rx),
            cfg_for(par),
            LifecycleConfig {
                clock: ClockMode::Wall,
                queue_cap: 4,
                resubmit_max: 3,
                ..Default::default()
            },
            &FaultPlan::none(),
            vocab,
            &mut hub,
            Some(&sup),
        )?;
        let submitted = ingress.join().expect("ingress thread");
        sup.stop();
        anyhow::ensure!(
            submitted == trace.len() && rep.summary.total() == submitted,
            "live wall run: {} submitted, {} terminals",
            submitted,
            rep.summary.total()
        );
        let (alloc, free) = b.kv_pages();
        let parked = b.prefix_stats().parked_pages;
        anyhow::ensure!(
            alloc == free + parked,
            "live wall run: page leak — {alloc} allocated vs {free} free + {parked} parked"
        );
        println!(
            "  live wall run OK: {} submitted, {} completed, {} rejected | graceful drain, no leaks",
            submitted, rep.summary.completed, rep.summary.rejected,
        );
    }
    println!("chaos --live: all {} plans passed", specs.len());
    Ok(())
}

/// `flashlight serve --backend engine --shards N`: serve the trace
/// over N self-contained engine instances behind the conversation-
/// sticky router, each pinned to a topology domain, with per-shard
/// health reported on exit. Fault plans (including `kill@R:shard=S`)
/// come from `FLASHLIGHT_FAULTS`.
fn serve_engine_sharded(
    n_requests: usize,
    par: crate::exec::Parallelism,
    opts: EngineServeOpts,
) -> anyhow::Result<()> {
    let trace = engine_trace(n_requests);
    let vocab = EngineModel::tiny().vocab;
    let cfg = SchedulerConfig {
        parallelism: par,
        prefill_chunk_tokens: opts.chunk_tokens,
        prefill_round_tokens: opts.round_tokens,
        ..Default::default()
    };
    let lc = LifecycleConfig {
        queue_cap: opts.queue_cap,
        default_deadline_s: if opts.deadline_ms == 0 {
            f64::INFINITY
        } else {
            opts.deadline_ms as f64 / 1e3
        },
        clock: ClockMode::Wall,
        ..Default::default()
    };
    let plan = FaultPlan::from_env()?;
    if !plan.is_empty() {
        println!("fault plan ({} events): {plan}", plan.events.len());
    }
    let mk = |_i: usize| {
        let mut b = EngineBackend::new(EngineModel::tiny_deep(opts.layers), 8, 1024, par);
        if opts.kv_page_cap > 0 {
            b.set_page_cap(opts.kv_page_cap);
        }
        b
    };
    let t0 = std::time::Instant::now();
    let rep = run_sharded(
        &trace,
        cfg,
        lc,
        &plan,
        vocab,
        opts.shards,
        RouterConfig::default(),
        mk,
    )?;
    let sum = &rep.summary;
    println!(
        "sharded engine: {} reqs over {} shards in {:.2}s wall | topology {} | \
         {} steals, {} failovers{}",
        trace.len(),
        opts.shards,
        t0.elapsed().as_secs_f64(),
        rep.topology,
        rep.steals,
        rep.failovers,
        if rep.killed.is_empty() {
            String::new()
        } else {
            format!(" | killed shards {:?}", rep.killed)
        },
    );
    println!(
        "lifecycle: {} completed, {} rejected, {} cancelled, {} deadline_exceeded, \
         {} failed | {} preemptions | goodput {:.1} tok/s",
        sum.completed,
        sum.rejected,
        sum.cancelled,
        sum.deadline_exceeded,
        sum.failed,
        sum.preemptions,
        sum.goodput_tokens_per_s,
    );
    print_shard_table(&rep.shards);
    Ok(())
}

fn print_shard_table(shards: &[ShardHealth]) {
    println!(
        "{:<7} {:<12} {:<6} {:>9} {:>10} {:>7} {:>22}",
        "shard", "runner", "alive", "assigned", "terminals", "rounds", "pages a/f/parked"
    );
    for h in shards {
        println!(
            "{:<7} {:<12} {:<6} {:>9} {:>10} {:>7} {:>22}",
            h.id,
            h.runner,
            if h.alive { "yes" } else { "KILLED" },
            h.assigned,
            h.terminals,
            h.rounds,
            format!("{}/{}/{}", h.pages_allocated, h.pages_free, h.pages_parked),
        );
    }
}

/// `flashlight chaos --shards N`: the sharded-serving gates.
///
/// **Determinism gate** (fault-free): the same trace sharded 1, 2,
/// and 4 ways (plus `--shards N` if different), each at 1, 2, and 4
/// threads per shard, must complete every request with per-request
/// token streams bit-identical to the unsharded single-thread
/// reference — sharding and parallelism are invisible in the output.
///
/// **Failover gate** (per fault plan): under a plan with
/// `kill@R:shard=S` events (spec form `seed=N[@R]` generates one via
/// [`FaultPlan::generate_sharded`]), every admitted request reaches
/// exactly one terminal state, completed survivors' streams match the
/// fault-free reference bit-for-bit, and every *surviving* shard's
/// page pool satisfies `allocated == free + parked`.
pub fn chaos_sharded(
    n_requests: usize,
    par: crate::exec::Parallelism,
    opts: EngineServeOpts,
    specs: &[String],
) -> anyhow::Result<()> {
    use std::collections::HashMap;

    let n_shards = opts.shards.max(2);
    let trace = engine_trace(n_requests);
    let cap = if opts.kv_page_cap > 0 {
        opts.kv_page_cap
    } else {
        20 * opts.layers
    };
    let vocab = EngineModel::tiny().vocab;
    let cfg_for = |p: crate::exec::Parallelism| SchedulerConfig {
        parallelism: p,
        prefill_chunk_tokens: opts.chunk_tokens,
        prefill_round_tokens: opts.round_tokens,
        ..Default::default()
    };
    // Deterministic rounds, unbounded queue, no deadlines: every
    // request must complete in the fault-free shardings, which is what
    // makes the bit-identity gate total.
    let lc = LifecycleConfig {
        clock: ClockMode::Rounds,
        ..Default::default()
    };
    let mk = |p: crate::exec::Parallelism| {
        move |_i: usize| {
            let mut b =
                EngineBackend::new(EngineModel::tiny_deep(opts.layers), 8, 1024, p);
            b.set_page_cap(cap);
            b
        }
    };

    let one_thread = crate::exec::Parallelism::with_threads(1);
    let reference: HashMap<usize, Vec<u32>> = {
        let rep = run_sharded(
            &trace,
            cfg_for(one_thread),
            lc,
            &FaultPlan::none(),
            vocab,
            1,
            RouterConfig::default(),
            mk(one_thread),
        )?;
        anyhow::ensure!(
            rep.summary.completed == trace.len(),
            "unsharded fault-free reference must complete all {} requests (completed {})",
            trace.len(),
            rep.summary.completed
        );
        rep.outcomes.into_iter().map(|o| (o.id, o.tokens)).collect()
    };
    println!(
        "chaos --shards: {} requests, {} shards, {} plans, page cap {}/shard",
        trace.len(),
        n_shards,
        specs.len(),
        cap
    );

    // Determinism gate: sharding and per-shard threads are invisible.
    let mut shard_counts = vec![1usize, 2, 4];
    if !shard_counts.contains(&n_shards) {
        shard_counts.push(n_shards);
    }
    for threads in [1usize, 2, 4] {
        let p = crate::exec::Parallelism::with_threads(threads);
        for &ns in &shard_counts {
            let rep = run_sharded(
                &trace,
                cfg_for(p),
                lc,
                &FaultPlan::none(),
                vocab,
                ns,
                RouterConfig::default(),
                mk(p),
            )?;
            anyhow::ensure!(
                rep.summary.completed == trace.len(),
                "@{ns} shards x {threads}t: completed {} of {}",
                rep.summary.completed,
                trace.len()
            );
            for o in &rep.outcomes {
                anyhow::ensure!(
                    Some(&o.tokens) == reference.get(&o.id),
                    "@{ns} shards x {threads}t: request {} diverged from the \
                     unsharded reference",
                    o.id
                );
            }
            for h in &rep.shards {
                anyhow::ensure!(
                    h.leak_free(),
                    "@{ns} shards x {threads}t: shard {} leaked pages",
                    h.id
                );
            }
            println!(
                "  determinism @{ns} shards x {threads}t OK ({} steals, topology {})",
                rep.steals, rep.topology
            );
        }
    }

    // Failover gate, per plan.
    for spec in specs {
        let plan = if let Some(rest) = spec.strip_prefix("seed=") {
            let (seed, rounds) = match rest.split_once('@') {
                Some((s, r)) => (s.parse::<u64>()?, r.parse::<u64>()?),
                None => (rest.parse::<u64>()?, 64),
            };
            FaultPlan::generate_sharded(seed, rounds, n_shards)
        } else {
            FaultPlan::parse(spec)?
        };
        let rep = run_sharded(
            &trace,
            cfg_for(par),
            lc,
            &plan,
            vocab,
            n_shards,
            RouterConfig::default(),
            mk(par),
        )?;
        anyhow::ensure!(
            rep.outcomes.len() == trace.len(),
            "plan `{spec}`: terminal accounting broken — {} terminals for {} requests",
            rep.outcomes.len(),
            trace.len()
        );
        for o in rep.outcomes.iter().filter(|o| o.outcome == Outcome::Completed) {
            let want = reference.get(&o.id).ok_or_else(|| {
                anyhow::anyhow!("plan `{spec}`: request {} has no reference", o.id)
            })?;
            anyhow::ensure!(
                &o.tokens == want,
                "plan `{spec}`: request {} diverged from the fault-free reference \
                 ({} tokens vs {}, {} failovers in run)",
                o.id,
                o.tokens.len(),
                want.len(),
                rep.failovers
            );
        }
        for h in rep.shards.iter().filter(|h| h.alive) {
            anyhow::ensure!(
                h.leak_free(),
                "plan `{spec}`: surviving shard {} leaked pages \
                 ({} allocated vs {} free + {} parked)",
                h.id,
                h.pages_allocated,
                h.pages_free,
                h.pages_parked
            );
        }
        if !plan.shard_kills().is_empty() && rep.killed.is_empty() {
            println!(
                "  plan `{spec}` note: kill landed after its shard drained (no-op)"
            );
        }
        println!(
            "  plan `{spec}` OK: {} completed, {} failed | killed {:?}, \
             {} failovers, {} steals | survivors bit-identical, no leaks",
            rep.summary.completed,
            rep.summary.failed,
            rep.killed,
            rep.failovers,
            rep.steals,
        );
        print_shard_table(&rep.shards);
    }
    println!("chaos --shards: all gates passed");
    Ok(())
}

/// Real PJRT serving run (fused vs naive attention).
#[cfg(feature = "pjrt")]
fn serve_pjrt(n_requests: usize, par: crate::exec::Parallelism) -> anyhow::Result<()> {
    // Small-scale trace that fits the tiny model's 256-token prefill
    // bucket and 512-token context.
    let trace = generate(&TraceConfig {
        n_requests,
        rate: 50.0,
        input_mu: 4.2,
        input_sigma: 0.7,
        mean_output: 12.0,
        max_input: 240,
        max_output: 24,
        ..Default::default()
    });
    let cfg = SchedulerConfig {
        parallelism: par,
        ..Default::default()
    };
    for fused in [true, false] {
        let tag = if fused { "fused(flashlight)" } else { "naive(torch.compile)" };
        let mut b = PjrtBackend::new("artifacts", "causal", fused)?;
        let vocab = b.vocab();
        let t0 = std::time::Instant::now();
        let done = run_trace(&mut b, &trace, cfg, vocab)?;
        let s = summarize(&done);
        println!(
            "pjrt {tag}: {} reqs in {:.2}s wall | TTFT mean {:.1} ms p99 {:.1} ms | ITL mean {:.2} ms | {:.1} tok/s",
            s.n_requests,
            t0.elapsed().as_secs_f64(),
            s.ttft_mean_s * 1e3,
            s.ttft_p99_s * 1e3,
            s.itl_mean_s * 1e3,
            s.tokens_per_s
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn serve_pjrt(_n_requests: usize, _par: crate::exec::Parallelism) -> anyhow::Result<()> {
    anyhow::bail!(
        "flashlight was built without the `pjrt` feature: add the `xla` \
         dependency to Cargo.toml (see the [features] note there) and \
         rebuild with --features pjrt"
    )
}
