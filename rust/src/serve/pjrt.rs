//! Real serving backend: the tiny LLaMa-style model AOT-compiled from
//! JAX (L2) with the fused Pallas attention kernel (L1), executed via
//! PJRT (the end-to-end deliverable: all three layers compose, Python
//! never runs on the request path).

use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::Engine;

use super::engine::Backend;

pub struct PjrtBackend {
    engine: Engine,
    weights: Vec<xla::Literal>,
    pub variant: String,
    pub fused: bool,
    vocab: usize,
    n_layers: usize,
    n_kv: usize,
    head_dim: usize,
    max_seq: usize,
    batch: usize,
    buckets: Vec<usize>,
    k_cache: Vec<f32>,
    v_cache: Vec<f32>,
    pos: Vec<usize>,
    last_token: Vec<u32>,
    active: Vec<bool>,
}

impl PjrtBackend {
    pub fn new(dir: &str, variant: &str, fused: bool) -> Result<Self> {
        let mut engine = Engine::new(dir)?;
        let cfg = engine
            .manifest
            .configs
            .get("llama")
            .context("llama config in manifest")?
            .clone();
        let get = |k: &str| -> Result<usize> {
            cfg.get(k)
                .with_context(|| format!("config key {k}"))?
                .parse::<usize>()
                .context("int")
        };
        let (vocab, n_layers, n_kv, head_dim, max_seq, batch) = (
            get("vocab")?,
            get("n_layers")?,
            get("n_kv_heads")?,
            get("head_dim")?,
            get("max_seq")?,
            get("decode_batch")?,
        );
        let buckets: Vec<usize> = cfg
            .get("prefill_buckets")
            .context("prefill_buckets")?
            .split('/')
            .map(|s| {
                s.parse()
                    .map_err(|e| anyhow::anyhow!("prefill_buckets entry `{s}`: {e}"))
            })
            .collect::<anyhow::Result<_>>()?;
        let weights = engine.load_weights("llama")?.literals();
        // Precompile every executable this backend can hit, so XLA JIT
        // time never lands inside serving metrics (the paper likewise
        // measures after a warmup replay).
        let tag = if fused { "fused" } else { "naive" };
        for b in &buckets {
            engine.compile(&format!("llama_prefill_{variant}_{tag}_s{b}"))?;
        }
        engine.compile(&format!("llama_decode_b{batch}"))?;
        let cache_len = n_layers * batch * n_kv * max_seq * head_dim;
        Ok(PjrtBackend {
            engine,
            weights,
            variant: variant.to_string(),
            fused,
            vocab,
            n_layers,
            n_kv,
            head_dim,
            max_seq,
            batch,
            buckets,
            k_cache: vec![0.0; cache_len],
            v_cache: vec![0.0; cache_len],
            pos: vec![0; batch],
            last_token: vec![0; batch],
            active: vec![false; batch],
        })
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    fn bucket_for(&self, len: usize) -> Result<usize> {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= len)
            .with_context(|| format!("prompt of {len} tokens exceeds largest bucket"))
    }

    /// Copy a prefill cache (L, Hkv, S_b, Dh) into slot `slot` of the
    /// batched decode cache (L, B, Hkv, Smax, Dh), positions [0, len).
    fn scatter_cache(dst: &mut [f32], src: &[f32], dims: (usize, usize, usize, usize, usize),
                     bucket: usize, slot: usize, len: usize) {
        let (l, b, hkv, smax, dh) = dims;
        debug_assert_eq!(dst.len(), l * b * hkv * smax * dh);
        debug_assert_eq!(src.len(), l * hkv * bucket * dh);
        for li in 0..l {
            for h in 0..hkv {
                for s in 0..len {
                    let s_off = ((li * hkv + h) * bucket + s) * dh;
                    let d_off = (((li * b + slot) * hkv + h) * smax + s) * dh;
                    dst[d_off..d_off + dh].copy_from_slice(&src[s_off..s_off + dh]);
                }
            }
        }
    }

    fn argmax(logits: &[f32]) -> u32 {
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        best as u32
    }
}

impl Backend for PjrtBackend {
    fn n_slots(&self) -> usize {
        self.batch
    }

    fn max_context(&self) -> usize {
        self.max_seq
    }

    fn prefill(
        &mut self,
        slot: usize,
        _req: &crate::tracegen::Request,
        tokens: &[u32],
    ) -> Result<(f64, u32)> {
        let t0 = Instant::now();
        let len = tokens.len();
        let bucket = self.bucket_for(len)?;
        let tag = if self.fused { "fused" } else { "naive" };
        let name = format!("llama_prefill_{}_{}_s{}", self.variant, tag, bucket);
        // Right-pad the prompt to the bucket length.
        let mut padded: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        padded.resize(bucket, 0);
        let tok_lit = xla::Literal::vec1(&padded)
            .reshape(&[1, bucket as i64])
            .context("tokens reshape")?;
        let mut inputs = self.weights.clone();
        inputs.push(tok_lit);
        let outs = self.engine.run(&name, &inputs)?;
        anyhow::ensure!(outs.len() == 3, "prefill outputs");
        let logits: Vec<f32> = outs[0].to_vec()?; // (1, bucket, V)
        let kc: Vec<f32> = outs[1].to_vec()?;
        let vc: Vec<f32> = outs[2].to_vec()?;
        let dims = (self.n_layers, self.batch, self.n_kv, self.max_seq, self.head_dim);
        Self::scatter_cache(&mut self.k_cache, &kc, dims, bucket, slot, len);
        Self::scatter_cache(&mut self.v_cache, &vc, dims, bucket, slot, len);
        // Logits of the *real* last token (prompt is padded).
        let row = &logits[(len - 1) * self.vocab..len * self.vocab];
        let tok = Self::argmax(row);
        self.pos[slot] = len;
        self.last_token[slot] = tok;
        self.active[slot] = true;
        Ok((t0.elapsed().as_secs_f64(), tok))
    }

    fn decode(&mut self, active: &[usize]) -> Result<(f64, Vec<u32>)> {
        let t0 = Instant::now();
        let toks: Vec<i32> = (0..self.batch)
            .map(|i| self.last_token[i] as i32)
            .collect();
        let pos: Vec<i32> = (0..self.batch)
            .map(|i| if self.active[i] { self.pos[i] as i32 } else { 0 })
            .collect();
        let cache_dims: Vec<i64> = vec![
            self.n_layers as i64,
            self.batch as i64,
            self.n_kv as i64,
            self.max_seq as i64,
            self.head_dim as i64,
        ];
        let mut inputs = self.weights.clone();
        inputs.push(xla::Literal::vec1(&toks));
        inputs.push(xla::Literal::vec1(&pos));
        inputs.push(xla::Literal::vec1(&self.k_cache).reshape(&cache_dims)?);
        inputs.push(xla::Literal::vec1(&self.v_cache).reshape(&cache_dims)?);
        let name = format!("llama_decode_b{}", self.batch);
        let outs = self.engine.run(&name, &inputs)?;
        anyhow::ensure!(outs.len() == 3, "decode outputs");
        let logits: Vec<f32> = outs[0].to_vec()?; // (B, V)
        self.k_cache = outs[1].to_vec()?;
        self.v_cache = outs[2].to_vec()?;
        let mut emitted = Vec::with_capacity(active.len());
        for &slot in active {
            let row = &logits[slot * self.vocab..(slot + 1) * self.vocab];
            let tok = Self::argmax(row);
            self.pos[slot] += 1;
            self.last_token[slot] = tok;
            emitted.push(tok);
        }
        Ok((t0.elapsed().as_secs_f64(), emitted))
    }

    fn release(&mut self, slot: usize) {
        self.active[slot] = false;
        self.pos[slot] = 0;
        self.last_token[slot] = 0;
    }

    fn is_virtual_time(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn scatter_cache_places_rows() {
        let dims = (1usize, 2usize, 1usize, 4usize, 2usize); // L,B,Hkv,Smax,Dh
        let mut dst = vec![0.0f32; 1 * 2 * 1 * 4 * 2];
        // bucket=2, len=2 source: (L=1, Hkv=1, S=2, Dh=2)
        let src = vec![1.0, 2.0, 3.0, 4.0];
        super::PjrtBackend::scatter_cache(&mut dst, &src, dims, 2, 1, 2);
        // slot 1 occupies the second half of the B axis
        assert_eq!(&dst[8..12], &[1.0, 2.0, 3.0, 4.0]);
        assert!(dst[..8].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(super::PjrtBackend::argmax(&[0.1, 0.9, 0.3]), 1);
    }
}
