//! Sharded multi-instance serving: a deterministic request router over
//! N engine [`Shard`]s, with shard fault domains and failover.
//!
//! ## Routing
//!
//! Requests are sharded by **conversation**: a conversation's first
//! request picks its shard, every later turn follows it there
//! (sticky), because the prefix cache — and therefore prefix adoption
//! — is shard-local. The primary placement is `conversation mod
//! n_shards`; when the primary has backed up past twice the
//! least-loaded shard's outstanding work (estimated in prompt +
//! completion tokens, plus a slack floor), admission **work-steals**
//! the conversation to the least-loaded shard instead (lowest id
//! breaks ties). Everything is integer arithmetic over the trace in
//! arrival order, so a placement is a pure function of (trace,
//! config) — reproducible anywhere.
//!
//! ## Failover
//!
//! A `kill@R:shard=S` fault (see [`super::faults`]) dooms shard `S`:
//! its lifecycle halts at round `R` as if the instance died — no
//! drain, no terminals for whatever it still held. The router then
//! *attributes* the loss (assigned minus terminals is exactly the
//! in-flight + queued remainder), re-shards those requests over the
//! survivors in arrival order, and runs a failover wave. Surviving
//! shards keep their backends between waves, so re-routed multi-turn
//! conversations adopt parked partial prefixes where the page pool
//! survived; conversations that lived on the dead shard re-prefill
//! from scratch. Every admitted request reaches **exactly one**
//! terminal state, and because token streams are bit-identical at any
//! placement, survivors match the fault-free reference exactly.

use std::collections::{BTreeMap, HashMap};

use crate::tracegen::Request;

use super::engine::SchedulerConfig;
use super::engine_backend::EngineBackend;
use super::faults::FaultPlan;
use super::lifecycle::LifecycleConfig;
use super::metrics::{summarize_outcomes, LifecycleSummary, RequestOutcome};
use super::shard::{shard_domains, Shard, ShardHealth};

/// Router tuning.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Work-stealing threshold slack: steal a new conversation away
    /// from its primary shard only when the primary's outstanding
    /// token estimate exceeds `2 * least_loaded + slack`. The slack
    /// keeps tiny imbalances (a single short request) from defeating
    /// modulo placement.
    pub steal_slack_tokens: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            steal_slack_tokens: 64,
        }
    }
}

/// Deterministic conversation-sticky router state.
pub struct Router {
    cfg: RouterConfig,
    /// conversation -> shard home.
    placement: HashMap<usize, usize>,
    /// Estimated tokens assigned per shard (all waves).
    loads: Vec<usize>,
    /// Conversations admission stole away from their primary shard.
    pub steals: u64,
}

impl Router {
    pub fn new(n_shards: usize, cfg: RouterConfig) -> Self {
        Router {
            cfg,
            placement: HashMap::new(),
            loads: vec![0; n_shards],
            steals: 0,
        }
    }

    /// Assign `reqs` (in arrival order) onto the `eligible` shards.
    /// Returns one queue per shard (ineligible shards get empty
    /// queues). Sticky homes that are no longer eligible (the shard
    /// died) are re-placed as if the conversation were new.
    pub fn assign(&mut self, reqs: &[Request], eligible: &[usize]) -> Vec<Vec<Request>> {
        assert!(!eligible.is_empty(), "router needs at least one eligible shard");
        let mut queues = vec![Vec::new(); self.loads.len()];
        for r in reqs {
            let s = self.place(r, eligible);
            self.loads[s] += r.input_tokens + r.output_tokens;
            queues[s].push(r.clone());
        }
        queues
    }

    fn place(&mut self, r: &Request, eligible: &[usize]) -> usize {
        if let Some(&home) = self.placement.get(&r.conversation) {
            if eligible.contains(&home) {
                return home;
            }
        }
        let primary = eligible[r.conversation % eligible.len()];
        let least = *eligible
            .iter()
            .min_by_key(|&&s| (self.loads[s], s))
            .expect("eligible is non-empty");
        let shard = if self.loads[primary]
            > 2 * self.loads[least] + self.cfg.steal_slack_tokens
        {
            self.steals += 1;
            least
        } else {
            primary
        };
        self.placement.insert(r.conversation, shard);
        shard
    }
}

/// Everything a sharded run produced, merged back together.
#[derive(Debug, Clone)]
pub struct ShardedReport {
    /// One terminal record per admitted request, sorted by id —
    /// regardless of which shard (or how many, after failover)
    /// touched it.
    pub outcomes: Vec<RequestOutcome>,
    pub summary: LifecycleSummary,
    /// Final health row per shard, including the dead ones.
    pub shards: Vec<ShardHealth>,
    /// Conversations stolen from their primary at admission.
    pub steals: u64,
    /// Requests re-sharded onto survivors after kills.
    pub failovers: u64,
    /// Shards that kill faults actually took down (a kill landing
    /// after a shard drained is a no-op and does not appear here).
    pub killed: Vec<usize>,
    /// Topology pin, e.g. `numa:8,8 -> [0, 0, 1, 1]`.
    pub topology: String,
}

/// Run `trace` over `n_shards` engine instances. See the module docs
/// for the routing and failover semantics. `make_backend(i)` builds
/// shard `i`'s private engine (callers pick model depth, page caps,
/// and per-shard parallelism there).
///
/// Non-kill fault events are applied to every shard's wave
/// identically (each instance experiences the same adverse schedule);
/// kill events are router-level and consumed here. The failover wave
/// runs fault-free: the plan's schedule already fired in wave one,
/// and replaying it against resubmitted work would double-apply it.
pub fn run_sharded(
    trace: &[Request],
    sched: SchedulerConfig,
    lc: LifecycleConfig,
    faults: &FaultPlan,
    vocab: usize,
    n_shards: usize,
    router_cfg: RouterConfig,
    mut make_backend: impl FnMut(usize) -> EngineBackend,
) -> anyhow::Result<ShardedReport> {
    anyhow::ensure!(n_shards >= 1, "need at least one shard");
    let topo = crate::exec::runtime::topology();
    let domains = shard_domains(&topo, n_shards);

    // Kill schedule: earliest kill per shard wins; later kills of the
    // same shard are no-ops (it is already dead). `kill@0` halts at
    // round 1 — the lifecycle treats 0 as "never".
    let mut kill_at: BTreeMap<usize, u64> = BTreeMap::new();
    for (round, shard) in faults.shard_kills() {
        anyhow::ensure!(
            shard < n_shards,
            "kill@{round}:shard={shard} targets a shard that does not exist \
             (running {n_shards})"
        );
        let r = round.max(1);
        kill_at
            .entry(shard)
            .and_modify(|cur| *cur = (*cur).min(r))
            .or_insert(r);
    }
    anyhow::ensure!(
        kill_at.len() < n_shards,
        "fault plan kills all {n_shards} shards; at least one must survive"
    );

    let mut shards: Vec<Shard> = (0..n_shards)
        .map(|i| {
            let mut sh = Shard::new(i, domains[i], make_backend(i));
            if let Some(&r) = kill_at.get(&i) {
                sh.kill_at = r;
            }
            sh
        })
        .collect();

    let mut router = Router::new(n_shards, router_cfg);
    let all: Vec<usize> = (0..n_shards).collect();
    for (sh, queue) in shards.iter_mut().zip(router.assign(trace, &all)) {
        sh.queue = queue;
    }

    let mut outcomes: BTreeMap<usize, RequestOutcome> = BTreeMap::new();
    let record = |outcomes: &mut BTreeMap<usize, RequestOutcome>,
                      rep_outcomes: Vec<RequestOutcome>|
     -> anyhow::Result<()> {
        for o in rep_outcomes {
            let id = o.id;
            anyhow::ensure!(
                outcomes.insert(id, o).is_none(),
                "request {id} reached two terminal states"
            );
        }
        Ok(())
    };

    // Wave 1: every shard runs its queue; doomed shards halt at their
    // kill round and hand their unfinished remainder back.
    let mut stranded: Vec<Request> = Vec::new();
    for sh in shards.iter_mut() {
        let (rep, unfinished) = sh.run_wave(sched, lc, faults, vocab)?;
        record(&mut outcomes, rep.outcomes)?;
        stranded.extend(unfinished);
    }

    let killed: Vec<usize> = shards.iter().filter(|s| !s.alive).map(|s| s.id).collect();
    let failovers = stranded.len() as u64;
    if !stranded.is_empty() {
        let survivors: Vec<usize> =
            shards.iter().filter(|s| s.alive).map(|s| s.id).collect();
        anyhow::ensure!(
            !survivors.is_empty(),
            "every shard died with work in flight; nothing to fail over to"
        );
        // Re-shard in arrival order (ids are monotone in arrival) so
        // the failover placement is as deterministic as admission.
        stranded.sort_by_key(|r| r.id);
        for (sh, queue) in shards.iter_mut().zip(router.assign(&stranded, &survivors)) {
            if !queue.is_empty() {
                sh.queue = queue;
            }
        }
        for sh in shards.iter_mut() {
            if !sh.alive || sh.queue.is_empty() {
                continue;
            }
            let (rep, unfinished) =
                sh.run_wave(sched, lc, &FaultPlan::none(), vocab)?;
            anyhow::ensure!(
                unfinished.is_empty(),
                "failover wave stranded work on surviving shard {}",
                sh.id
            );
            record(&mut outcomes, rep.outcomes)?;
        }
    }

    anyhow::ensure!(
        outcomes.len() == trace.len(),
        "sharded run lost requests: {} terminals for {} admitted",
        outcomes.len(),
        trace.len()
    );
    let outcomes: Vec<RequestOutcome> = outcomes.into_values().collect();
    let summary = summarize_outcomes(&outcomes);
    Ok(ShardedReport {
        summary,
        outcomes,
        shards: shards.iter().map(Shard::health).collect(),
        steals: router.steals,
        failovers,
        killed,
        topology: format!("{} -> {:?}", topo.describe(), domains),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Parallelism;
    use crate::serve::engine_backend::{EngineBackend, EngineModel};
    use crate::serve::lifecycle::ClockMode;
    use crate::serve::metrics::Outcome;

    fn req(id: usize, conversation: usize, cost: usize) -> Request {
        Request {
            id,
            conversation,
            input_tokens: cost,
            output_tokens: cost / 4,
            ..Default::default()
        }
    }

    #[test]
    fn routing_is_conversation_sticky_and_deterministic() {
        let trace: Vec<Request> =
            (0..24).map(|i| req(i, i % 7, 32 + (i % 5) * 16)).collect();
        let mut a = Router::new(4, RouterConfig::default());
        let mut b = Router::new(4, RouterConfig::default());
        let all = vec![0, 1, 2, 3];
        let qa = a.assign(&trace, &all);
        let qb = b.assign(&trace, &all);
        let ids = |qs: &[Vec<Request>]| -> Vec<Vec<usize>> {
            qs.iter()
                .map(|q| q.iter().map(|r| r.id).collect())
                .collect()
        };
        assert_eq!(ids(&qa), ids(&qb), "identical inputs must route identically");
        // Sticky: every conversation lands on exactly one shard.
        let mut home: HashMap<usize, usize> = HashMap::new();
        for (s, q) in qa.iter().enumerate() {
            for r in q {
                assert_eq!(
                    *home.entry(r.conversation).or_insert(s),
                    s,
                    "conversation {} split across shards",
                    r.conversation
                );
            }
        }
    }

    #[test]
    fn admission_steals_from_a_backed_up_primary() {
        // Every conversation hashes to shard 0; once it backs up past
        // the threshold, new conversations spill to the idle shard.
        let trace: Vec<Request> = (0..8).map(|i| req(i, i * 2, 256)).collect();
        let mut r = Router::new(2, RouterConfig {
            steal_slack_tokens: 64,
        });
        let q = r.assign(&trace, &[0, 1]);
        assert!(r.steals >= 1, "backed-up primary must shed work");
        assert!(
            !q[1].is_empty(),
            "stolen conversations must land on the idle shard"
        );
        // Re-offered turns of a stolen conversation follow it.
        let follow = r.assign(&[req(100, trace[q[1][0].id].conversation, 8)], &[0, 1]);
        assert!(follow[0].is_empty() && !follow[1].is_empty());
    }

    #[test]
    fn dead_homes_are_replaced_only_for_survivors() {
        let mut r = Router::new(2, RouterConfig::default());
        let first = r.assign(&[req(0, 5, 64)], &[0, 1]);
        let home = if first[1].is_empty() { 0 } else { 1 };
        let survivor = 1 - home;
        let re = r.assign(&[req(1, 5, 64)], &[survivor]);
        assert!(!re[survivor].is_empty(), "failover must re-place the conversation");
        // And stickiness now points at the survivor.
        let again = r.assign(&[req(2, 5, 64)], &[0, 1]);
        assert!(!again[survivor].is_empty());
    }

    fn mk_backend(par_threads: usize) -> impl FnMut(usize) -> EngineBackend {
        move |_i| {
            EngineBackend::new(
                EngineModel::tiny(),
                4,
                512,
                Parallelism::with_threads(par_threads),
            )
        }
    }

    fn rounds_lc() -> LifecycleConfig {
        LifecycleConfig {
            clock: ClockMode::Rounds,
            ..Default::default()
        }
    }

    /// The determinism gate in miniature: the same trace sharded
    /// 1/2/4 ways completes everything with bit-identical per-request
    /// token streams.
    #[test]
    fn sharding_is_invisible_in_the_token_streams() {
        let trace = crate::serve::engine_trace(10);
        let mut streams: Vec<Vec<(usize, Vec<u32>)>> = Vec::new();
        for n_shards in [1usize, 2, 4] {
            let rep = run_sharded(
                &trace,
                SchedulerConfig::default(),
                rounds_lc(),
                &FaultPlan::none(),
                EngineModel::tiny().vocab,
                n_shards,
                RouterConfig::default(),
                mk_backend(1),
            )
            .unwrap();
            assert_eq!(rep.summary.completed, trace.len());
            assert!(rep.shards.iter().all(|h| h.alive && h.leak_free()));
            streams.push(
                rep.outcomes
                    .into_iter()
                    .map(|o| (o.id, o.tokens))
                    .collect(),
            );
        }
        assert_eq!(streams[0], streams[1], "2-way sharding changed a stream");
        assert_eq!(streams[0], streams[2], "4-way sharding changed a stream");
    }

    /// The failover gate in miniature: kill a shard mid-trace; every
    /// request still reaches exactly one terminal, survivors match
    /// the fault-free reference, and surviving pools do not leak.
    #[test]
    fn shard_kill_fails_over_with_exact_terminal_accounting() {
        let trace = crate::serve::engine_trace(12);
        let vocab = EngineModel::tiny().vocab;
        let reference = run_sharded(
            &trace,
            SchedulerConfig::default(),
            rounds_lc(),
            &FaultPlan::none(),
            vocab,
            2,
            RouterConfig::default(),
            mk_backend(1),
        )
        .unwrap();
        let plan = FaultPlan::parse("kill@2:shard=0").unwrap();
        let rep = run_sharded(
            &trace,
            SchedulerConfig::default(),
            rounds_lc(),
            &plan,
            vocab,
            2,
            RouterConfig::default(),
            mk_backend(1),
        )
        .unwrap();
        assert_eq!(rep.killed, vec![0], "the kill must land mid-trace");
        assert!(rep.failovers >= 1);
        assert_eq!(rep.outcomes.len(), trace.len());
        assert_eq!(
            rep.summary.completed,
            trace.len(),
            "failover must finish the dead shard's work"
        );
        let want: HashMap<usize, Vec<u32>> = reference
            .outcomes
            .into_iter()
            .map(|o| (o.id, o.tokens))
            .collect();
        for o in &rep.outcomes {
            assert_eq!(o.outcome, Outcome::Completed);
            assert_eq!(
                &o.tokens, &want[&o.id],
                "request {} diverged after failover",
                o.id
            );
        }
        for h in rep.shards.iter().filter(|h| h.alive) {
            assert!(h.leak_free(), "surviving shard {} leaked pages", h.id);
        }
    }

    #[test]
    fn killing_every_shard_is_rejected_loudly() {
        let trace = crate::serve::engine_trace(4);
        let plan = FaultPlan::parse("kill@1:shard=0;kill@2:shard=1").unwrap();
        let err = run_sharded(
            &trace,
            SchedulerConfig::default(),
            rounds_lc(),
            &plan,
            EngineModel::tiny().vocab,
            2,
            RouterConfig::default(),
            mk_backend(1),
        )
        .unwrap_err();
        assert!(err.to_string().contains("at least one must survive"));
    }
}
