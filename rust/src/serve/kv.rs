//! Sequence-paged KV tensors: one refcounted page pool shared across all
//! serving sequences.
//!
//! FlashInfer-style paged KV (arXiv 2501.01005): a sequence's K/V cache
//! is a list of fixed-size *pages* ([`PagedKv::block_tokens`] tokens
//! each) drawn from a pool shared by every sequence. Decode steps append
//! one token's K/V in place — a new page is taken from the free list
//! only at block boundaries, so steady-state appends never reallocate
//! and releasing a request returns its pages for immediate reuse. Page
//! size doubles as the plan-cache bucket granule
//! ([`crate::fusion::bucket_len`]): a gathered KV tensor is always a
//! whole number of pages, which is exactly the padded shape the cached
//! serving plans expect.
//!
//! A *sequence* is one (slot, layer) cache: the multi-layer engine
//! backend maps slot `s`, layer `l` onto sequence `s * layers + l`, all
//! drawing from this single pool.
//!
//! **Prefix reuse (Mooncake-style):** pages carry reference counts so a
//! conversation's prompt prefix can outlive its request. Which parked
//! prefixes survive the page budget is the *caller's* admission policy —
//! the engine backend evicts by a recency-weighted reuse score
//! (conversation last-seen tick + observed follow-up turns), not raw
//! page-LRU, so multi-turn conversations outlive one-shot churn; this
//! module only provides the refcounted park/adopt/release mechanics.
//! [`Self::park`]
//! detaches a whole-page prefix from a finished sequence (the partial
//! tail page — which mixes prompt and generated tokens — is freed, never
//! shared); [`Self::adopt`] grafts a parked prefix into a fresh sequence
//! by bumping refcounts, so a follow-up turn skips re-prefilling the
//! shared history. Shared pages are always *full* and therefore
//! immutable: appends only ever write pages this sequence allocated
//! itself (asserted), so copy-on-write is never needed.
//!
//! Layout: within a page, token-major `[token][head][d]` (an append is
//! one contiguous write); gathers produce the engine's head-major
//! `[head][token][d]` layout with zero fill for the padded tail.

/// Default page size in tokens — also the serving bucket granule.
pub const DEFAULT_BLOCK_TOKENS: usize = 64;

/// Typed KV-pool failures. Exhaustion is an *expected* runtime state the
/// lifecycle scheduler reacts to (preempt → requeue → throttle), so it
/// must be a value, not a panic; the invariant violations are programming
/// errors surfaced as errors so a serving process degrades instead of
/// aborting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KvError {
    /// The pool cannot supply another page: every page up to `cap` is
    /// live (or held back by injected `pressure`).
    PoolExhausted {
        seq: usize,
        in_use: usize,
        cap: usize,
        pressure: usize,
    },
    /// [`PagedKv::adopt`] into a sequence that still owns pages.
    AdoptNonEmpty { seq: usize },
    /// [`PagedKv::adopt`] of a page with no live references (the prefix
    /// was already evicted).
    AdoptFreedPage { page: usize },
    /// [`PagedKv::gather`] with a padded length below the cached length —
    /// a stale bucket would silently drop the newest tokens.
    GatherTruncates {
        seq: usize,
        padded_len: usize,
        len: usize,
    },
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::PoolExhausted {
                seq,
                in_use,
                cap,
                pressure,
            } => write!(
                f,
                "kv pool exhausted appending to seq {seq}: {in_use} pages in use, cap {cap}, external pressure {pressure}"
            ),
            KvError::AdoptNonEmpty { seq } => {
                write!(f, "adopt into non-empty seq {seq}")
            }
            KvError::AdoptFreedPage { page } => {
                write!(f, "adopting freed page {page}")
            }
            KvError::GatherTruncates {
                seq,
                padded_len,
                len,
            } => write!(
                f,
                "gather of seq {seq} with padded_len {padded_len} < cached len {len} would drop tokens"
            ),
        }
    }
}

impl std::error::Error for KvError {}

struct Page {
    k: Vec<f32>,
    v: Vec<f32>,
    /// Live references: one per sequence holding the page plus one per
    /// parked prefix. 0 = on the free list.
    rc: u32,
}

struct SeqKv {
    pages: Vec<usize>,
    len: usize,
}

pub struct PagedKv {
    block_tokens: usize,
    heads: usize,
    head_dim: usize,
    pages: Vec<Page>,
    free: Vec<usize>,
    seqs: Vec<SeqKv>,
    /// Hard cap on pool size in pages (`usize::MAX` = grow on demand,
    /// the legacy behavior). With a finite cap, [`Self::append`] returns
    /// [`KvError::PoolExhausted`] instead of allocating past it.
    page_cap: usize,
    /// Pages held hostage by fault injection: subtracted from
    /// [`Self::available_pages`] without touching real bookkeeping, so a
    /// chaos plan can simulate exhaustion deterministically.
    pressure: usize,
}

impl PagedKv {
    pub fn new(n_seqs: usize, block_tokens: usize, heads: usize, head_dim: usize) -> Self {
        PagedKv {
            block_tokens: block_tokens.max(1),
            heads,
            head_dim,
            pages: Vec::new(),
            free: Vec::new(),
            seqs: (0..n_seqs)
                .map(|_| SeqKv {
                    pages: Vec::new(),
                    len: 0,
                })
                .collect(),
            page_cap: usize::MAX,
            pressure: 0,
        }
    }

    /// Cap the pool at `cap` pages. Shrinking below the current
    /// allocation does not free anything — it only forbids growth and
    /// makes [`Self::available_pages`] report the tighter budget.
    pub fn set_page_cap(&mut self, cap: usize) {
        self.page_cap = cap.max(1);
    }

    pub fn page_cap(&self) -> usize {
        self.page_cap
    }

    /// Fault injection: pretend `pages` pages are unavailable.
    pub fn set_pressure(&mut self, pages: usize) {
        self.pressure = pages;
    }

    pub fn pressure(&self) -> usize {
        self.pressure
    }

    /// Pages an append could take right now: the free list plus headroom
    /// below the cap, minus injected pressure.
    pub fn available_pages(&self) -> usize {
        let headroom = self.page_cap.saturating_sub(self.pages.len());
        self.free
            .len()
            .saturating_add(headroom)
            .saturating_sub(self.pressure)
    }

    /// New pages appending `extra_tokens` more tokens to `seq` would
    /// take (0 if they all land in the current partial tail page).
    pub fn pages_for_append(&self, seq: usize, extra_tokens: usize) -> usize {
        let sl = &self.seqs[seq];
        (sl.len + extra_tokens)
            .div_ceil(self.block_tokens)
            .saturating_sub(sl.pages.len())
    }

    /// Tokens per page (the serving bucket granule).
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Per-token K/V vector length (`heads * head_dim`).
    pub fn token_stride(&self) -> usize {
        self.heads * self.head_dim
    }

    /// Tokens currently cached for `seq`.
    pub fn len(&self, seq: usize) -> usize {
        self.seqs[seq].len
    }

    pub fn is_empty(&self, seq: usize) -> bool {
        self.seqs[seq].len == 0
    }

    /// Pages ever allocated (the pool's high-water mark).
    pub fn allocated_pages(&self) -> usize {
        self.pages.len()
    }

    /// Pages currently on the free list.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Append one token's K/V (`[head][d]` layout, `token_stride()`
    /// floats each) to `seq`. Amortized allocation-free: a page is
    /// taken from the free list (or freshly allocated) only every
    /// `block_tokens` appends. Only pages owned exclusively by this
    /// sequence are ever written (adopted prefix pages are full, so the
    /// write cursor never lands inside one). At a block boundary with no
    /// page available ([`Self::available_pages`] = 0) this returns
    /// [`KvError::PoolExhausted`] *before* mutating anything, so the
    /// scheduler can preempt and retry.
    pub fn append(&mut self, seq: usize, k: &[f32], v: &[f32]) -> Result<(), KvError> {
        let stride = self.token_stride();
        debug_assert_eq!(k.len(), stride);
        debug_assert_eq!(v.len(), stride);
        let len = self.seqs[seq].len;
        if len % self.block_tokens == 0 {
            if self.available_pages() == 0 {
                return Err(KvError::PoolExhausted {
                    seq,
                    in_use: self.pages.len() - self.free.len(),
                    cap: self.page_cap,
                    pressure: self.pressure,
                });
            }
            let cap = self.block_tokens * stride;
            let pi = match self.free.pop() {
                Some(pi) => pi,
                None => {
                    self.pages.push(Page {
                        k: vec![0.0; cap],
                        v: vec![0.0; cap],
                        rc: 0,
                    });
                    self.pages.len() - 1
                }
            };
            debug_assert_eq!(self.pages[pi].rc, 0, "free page with live references");
            self.pages[pi].rc = 1;
            self.seqs[seq].pages.push(pi);
        }
        // Invariant, not an error path: the branch above pushed a page
        // whenever the cursor sat on a block boundary, so a tail page
        // always exists here.
        let pi = *self.seqs[seq].pages.last().expect("page just ensured");
        debug_assert_eq!(
            self.pages[pi].rc, 1,
            "appending into a shared page would corrupt other readers"
        );
        let off = (len % self.block_tokens) * stride;
        self.pages[pi].k[off..off + stride].copy_from_slice(k);
        self.pages[pi].v[off..off + stride].copy_from_slice(v);
        self.seqs[seq].len = len + 1;
        Ok(())
    }

    /// Gather `seq`'s cache into head-major `[head][padded_len][d]`
    /// buffers (the engine's KV input layout), zero-filling positions
    /// `>= len(seq)`. `padded_len` must be a bucketed length `>= len`:
    /// a stale bucket (computed before an append) would silently drop
    /// the newest tokens, so it is a typed error, not a debug assert.
    pub fn gather(
        &self,
        seq: usize,
        padded_len: usize,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    ) -> Result<(), KvError> {
        let d = self.head_dim;
        let stride = self.token_stride();
        let sl = &self.seqs[seq];
        if padded_len < sl.len {
            return Err(KvError::GatherTruncates {
                seq,
                padded_len,
                len: sl.len,
            });
        }
        let len = sl.len;
        k_out.clear();
        v_out.clear();
        k_out.reserve(self.heads * padded_len * d);
        v_out.reserve(self.heads * padded_len * d);
        for h in 0..self.heads {
            for t in 0..len {
                let page = &self.pages[sl.pages[t / self.block_tokens]];
                let off = (t % self.block_tokens) * stride + h * d;
                k_out.extend_from_slice(&page.k[off..off + d]);
                v_out.extend_from_slice(&page.v[off..off + d]);
            }
            k_out.resize(k_out.len() + (padded_len - len) * d, 0.0);
            v_out.resize(v_out.len() + (padded_len - len) * d, 0.0);
        }
        Ok(())
    }

    fn unref(&mut self, pi: usize) {
        let page = &mut self.pages[pi];
        debug_assert!(page.rc > 0, "double release of page {pi}");
        page.rc -= 1;
        if page.rc == 0 {
            self.free.push(pi);
        }
    }

    /// Drop a sequence's reference to its pages (freeing unshared ones)
    /// and reset it to empty.
    pub fn release(&mut self, seq: usize) {
        let pages = std::mem::take(&mut self.seqs[seq].pages);
        for pi in pages {
            self.unref(pi);
        }
        self.seqs[seq].len = 0;
    }

    /// Detach a whole-page prefix covering at most `keep_tokens` tokens
    /// from `seq`, returning the kept page list (the sequence's
    /// reference on those pages transfers to the returned prefix — drop
    /// it later with [`Self::release_prefix`]). Everything past the
    /// prefix — including the partial tail page — is released, and the
    /// sequence is reset to empty.
    pub fn park(&mut self, seq: usize, keep_tokens: usize) -> Vec<usize> {
        let keep_pages = keep_tokens.min(self.seqs[seq].len) / self.block_tokens;
        let mut pages = std::mem::take(&mut self.seqs[seq].pages);
        for pi in pages.drain(keep_pages.min(pages.len())..) {
            self.unref(pi);
        }
        self.seqs[seq].len = 0;
        pages
    }

    /// Graft a parked prefix into an empty sequence: every page gains a
    /// reference, and the sequence continues appending *after* the
    /// prefix (the prefix pages are full, so the next append opens a
    /// fresh page — shared pages are never written). Validates the whole
    /// prefix *before* bumping any refcount, so a failed adopt leaves
    /// the pool untouched.
    pub fn adopt(&mut self, seq: usize, pages: &[usize]) -> Result<(), KvError> {
        if !self.seqs[seq].pages.is_empty() {
            return Err(KvError::AdoptNonEmpty { seq });
        }
        if let Some(&pi) = pages.iter().find(|&&pi| self.pages[pi].rc == 0) {
            return Err(KvError::AdoptFreedPage { page: pi });
        }
        for &pi in pages {
            self.pages[pi].rc += 1;
        }
        self.seqs[seq].pages = pages.to_vec();
        self.seqs[seq].len = pages.len() * self.block_tokens;
        Ok(())
    }

    /// Drop a parked prefix's references (LRU eviction / replacement).
    pub fn release_prefix(&mut self, pages: &[usize]) {
        for &pi in pages {
            self.unref(pi);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn token_vec(seed: f32, stride: usize) -> Vec<f32> {
        (0..stride).map(|i| seed + i as f32).collect()
    }

    #[test]
    fn append_and_gather_round_trip_with_zero_padding() {
        let (heads, d) = (2, 4);
        let mut kv = PagedKv::new(2, 4, heads, d);
        let stride = kv.token_stride();
        for t in 0..6 {
            let k = token_vec(100.0 + t as f32, stride);
            let v = token_vec(200.0 + t as f32, stride);
            kv.append(0, &k, &v).unwrap();
        }
        assert_eq!(kv.len(0), 6);
        let mut kb = Vec::new();
        let mut vb = Vec::new();
        kv.gather(0, 8, &mut kb, &mut vb).unwrap();
        assert_eq!(kb.len(), heads * 8 * d);
        // head-major layout: [h][t][d]; token t of head h came from
        // token_vec(100 + t)[h*d..]
        for h in 0..heads {
            for t in 0..8 {
                let got = &kb[(h * 8 + t) * d..(h * 8 + t + 1) * d];
                if t < 6 {
                    let want: Vec<f32> =
                        (0..d).map(|i| 100.0 + t as f32 + (h * d + i) as f32).collect();
                    assert_eq!(got, &want[..], "h={h} t={t}");
                } else {
                    assert!(got.iter().all(|&x| x == 0.0), "padding must be zero");
                }
            }
        }
        assert_eq!(vb[(0 * 8 + 3) * d], 203.0);
    }

    #[test]
    fn pages_grow_in_block_increments() {
        let mut kv = PagedKv::new(1, 4, 1, 2);
        let stride = kv.token_stride();
        assert_eq!(kv.allocated_pages(), 0);
        for t in 0..4 {
            kv.append(0, &token_vec(t as f32, stride), &token_vec(t as f32, stride))
                .unwrap();
        }
        assert_eq!(kv.allocated_pages(), 1, "4 tokens fit one 4-token page");
        kv.append(0, &token_vec(9.0, stride), &token_vec(9.0, stride))
            .unwrap();
        assert_eq!(kv.allocated_pages(), 2, "5th token opens a second page");
    }

    #[test]
    fn released_pages_are_reused_across_seqs() {
        let mut kv = PagedKv::new(2, 2, 1, 2);
        let stride = kv.token_stride();
        for _ in 0..4 {
            kv.append(0, &token_vec(1.0, stride), &token_vec(1.0, stride))
                .unwrap();
        }
        assert_eq!(kv.allocated_pages(), 2);
        kv.release(0);
        assert_eq!(kv.len(0), 0);
        assert_eq!(kv.free_pages(), 2);
        // Seq 1 reuses the freed pages: no new allocation.
        for _ in 0..4 {
            kv.append(1, &token_vec(2.0, stride), &token_vec(2.0, stride))
                .unwrap();
        }
        assert_eq!(kv.allocated_pages(), 2, "pool must reuse freed pages");
        assert_eq!(kv.free_pages(), 0);
        // And the reused pages carry the new values, not the old ones.
        let (mut kb, mut vb) = (Vec::new(), Vec::new());
        kv.gather(1, 4, &mut kb, &mut vb).unwrap();
        assert!(kb.iter().take(4 * 2).all(|&x| x >= 2.0));
    }

    #[test]
    fn gather_reuses_caller_buffers() {
        let mut kv = PagedKv::new(1, 4, 1, 2);
        let stride = kv.token_stride();
        kv.append(0, &token_vec(1.0, stride), &token_vec(1.0, stride))
            .unwrap();
        let mut kb = Vec::with_capacity(64);
        let mut vb = Vec::with_capacity(64);
        let cap = kb.capacity();
        kv.gather(0, 4, &mut kb, &mut vb).unwrap();
        assert_eq!(kb.capacity(), cap, "gather must not grow a large buffer");
        assert_eq!(kb.len(), 4 * 2);
    }

    #[test]
    fn park_keeps_whole_pages_and_frees_the_tail() {
        // 2-token pages; 5 appended tokens = 3 pages (last partial).
        let mut kv = PagedKv::new(1, 2, 1, 2);
        let stride = kv.token_stride();
        for t in 0..5 {
            kv.append(0, &token_vec(t as f32, stride), &token_vec(t as f32, stride))
                .unwrap();
        }
        assert_eq!(kv.allocated_pages(), 3);
        // Park a 5-token prefix: only 2 full pages (4 tokens) survive.
        let prefix = kv.park(0, 5);
        assert_eq!(prefix.len(), 2);
        assert_eq!(kv.len(0), 0);
        assert_eq!(kv.free_pages(), 1, "partial tail page must be freed");
        kv.release_prefix(&prefix);
        assert_eq!(kv.free_pages(), 3);
    }

    #[test]
    fn adopted_prefix_is_shared_until_all_refs_drop() {
        let mut kv = PagedKv::new(2, 2, 1, 2);
        let stride = kv.token_stride();
        for t in 0..4 {
            kv.append(0, &token_vec(t as f32, stride), &token_vec(t as f32, stride))
                .unwrap();
        }
        let prefix = kv.park(0, 4); // 2 full pages
        assert_eq!(prefix.len(), 2);
        // Adopt into seq 1 and extend it.
        kv.adopt(1, &prefix).unwrap();
        assert_eq!(kv.len(1), 4);
        kv.append(1, &token_vec(9.0, stride), &token_vec(9.0, stride))
            .unwrap();
        assert_eq!(kv.len(1), 5);
        // Releasing the sequence keeps the parked prefix alive...
        kv.release(1);
        let (mut kb, mut vb) = (Vec::new(), Vec::new());
        kv.adopt(1, &prefix).unwrap();
        kv.gather(1, 4, &mut kb, &mut vb).unwrap();
        assert_eq!(kb[0], 0.0); // token 0 still intact
        assert_eq!(kb[2 * 2], 2.0); // token 2 (page 1) intact
        kv.release(1);
        // ...and dropping the prefix frees everything.
        kv.release_prefix(&prefix);
        assert_eq!(kv.free_pages(), kv.allocated_pages());
    }

    #[test]
    fn append_after_adoption_opens_a_fresh_page() {
        let mut kv = PagedKv::new(2, 2, 1, 2);
        let stride = kv.token_stride();
        for t in 0..2 {
            kv.append(0, &token_vec(t as f32, stride), &token_vec(t as f32, stride))
                .unwrap();
        }
        let prefix = kv.park(0, 2);
        kv.adopt(0, &prefix).unwrap();
        let before = kv.allocated_pages();
        kv.append(0, &token_vec(7.0, stride), &token_vec(7.0, stride))
            .unwrap();
        // The shared page is full, so the append must not touch it.
        assert!(kv.allocated_pages() > before || kv.free_pages() == 0);
        let (mut kb, mut vb) = (Vec::new(), Vec::new());
        kv.gather(0, 4, &mut kb, &mut vb).unwrap();
        assert_eq!(kb[2 * 2], 7.0);
        kv.release(0);
        kv.release_prefix(&prefix);
        assert_eq!(kv.free_pages(), kv.allocated_pages());
    }

    #[test]
    fn capped_pool_exhausts_cleanly_and_recovers_after_release() {
        // 2-token pages, cap 2 pages => at most 4 cached tokens.
        let mut kv = PagedKv::new(2, 2, 1, 2);
        kv.set_page_cap(2);
        let stride = kv.token_stride();
        for t in 0..4 {
            kv.append(0, &token_vec(t as f32, stride), &token_vec(t as f32, stride))
                .unwrap();
        }
        assert_eq!(kv.available_pages(), 0);
        let err = kv
            .append(0, &token_vec(9.0, stride), &token_vec(9.0, stride))
            .unwrap_err();
        assert_eq!(
            err,
            KvError::PoolExhausted {
                seq: 0,
                in_use: 2,
                cap: 2,
                pressure: 0
            }
        );
        // The failed append must not have mutated anything.
        assert_eq!(kv.len(0), 4);
        assert_eq!(kv.allocated_pages(), 2);
        // Releasing frees capacity and the append succeeds on seq 1.
        kv.release(0);
        assert_eq!(kv.available_pages(), 2);
        kv.append(1, &token_vec(5.0, stride), &token_vec(5.0, stride))
            .unwrap();
        assert_eq!(kv.len(1), 1);
    }

    #[test]
    fn mid_page_appends_survive_exhaustion() {
        // Appends into a partial tail page need no new page, so they
        // must succeed even with zero availability.
        let mut kv = PagedKv::new(1, 4, 1, 2);
        kv.set_page_cap(1);
        let stride = kv.token_stride();
        kv.append(0, &token_vec(0.0, stride), &token_vec(0.0, stride))
            .unwrap();
        assert_eq!(kv.available_pages(), 0);
        for t in 1..4 {
            kv.append(0, &token_vec(t as f32, stride), &token_vec(t as f32, stride))
                .unwrap();
        }
        assert!(kv
            .append(0, &token_vec(4.0, stride), &token_vec(4.0, stride))
            .is_err());
    }

    #[test]
    fn pressure_simulates_exhaustion_and_lifts() {
        let mut kv = PagedKv::new(1, 2, 1, 2);
        kv.set_page_cap(4);
        assert_eq!(kv.available_pages(), 4);
        kv.set_pressure(3);
        assert_eq!(kv.available_pages(), 1);
        let stride = kv.token_stride();
        for t in 0..2 {
            kv.append(0, &token_vec(t as f32, stride), &token_vec(t as f32, stride))
                .unwrap();
        }
        let err = kv
            .append(0, &token_vec(9.0, stride), &token_vec(9.0, stride))
            .unwrap_err();
        assert!(matches!(err, KvError::PoolExhausted { pressure: 3, .. }));
        kv.set_pressure(0);
        kv.append(0, &token_vec(9.0, stride), &token_vec(9.0, stride))
            .unwrap();
        assert_eq!(kv.len(0), 3);
    }

    #[test]
    fn pages_for_append_counts_block_crossings() {
        let mut kv = PagedKv::new(1, 4, 1, 2);
        let stride = kv.token_stride();
        assert_eq!(kv.pages_for_append(0, 1), 1);
        assert_eq!(kv.pages_for_append(0, 4), 1);
        assert_eq!(kv.pages_for_append(0, 5), 2);
        for t in 0..3 {
            kv.append(0, &token_vec(t as f32, stride), &token_vec(t as f32, stride))
                .unwrap();
        }
        assert_eq!(kv.pages_for_append(0, 1), 0, "fits the tail page");
        assert_eq!(kv.pages_for_append(0, 2), 1);
    }

    #[test]
    fn adopt_and_gather_report_typed_errors() {
        let mut kv = PagedKv::new(2, 2, 1, 2);
        let stride = kv.token_stride();
        for t in 0..4 {
            kv.append(0, &token_vec(t as f32, stride), &token_vec(t as f32, stride))
                .unwrap();
        }
        let prefix = kv.park(0, 4);
        kv.append(1, &token_vec(8.0, stride), &token_vec(8.0, stride))
            .unwrap();
        assert_eq!(
            kv.adopt(1, &prefix).unwrap_err(),
            KvError::AdoptNonEmpty { seq: 1 }
        );
        assert_eq!(
            kv.gather(1, 0, &mut Vec::new(), &mut Vec::new()).unwrap_err(),
            KvError::GatherTruncates {
                seq: 1,
                padded_len: 0,
                len: 1
            }
        );
        // Evict the prefix, then adopting it must fail without touching
        // refcounts.
        kv.release_prefix(&prefix);
        let free_before = kv.free_pages();
        assert!(matches!(
            kv.adopt(0, &prefix).unwrap_err(),
            KvError::AdoptFreedPage { .. }
        ));
        assert_eq!(kv.free_pages(), free_before);
        assert!(kv.is_empty(0));
    }
}
