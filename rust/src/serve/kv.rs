//! Slot-paged KV tensors: one page pool shared across all serving slots.
//!
//! FlashInfer-style paged KV (arXiv 2501.01005): a slot's K/V cache is a
//! list of fixed-size *pages* ([`PagedKv::block_tokens`] tokens each)
//! drawn from a pool shared by every slot. Decode steps append one
//! token's K/V in place — a new page is taken from the free list only at
//! block boundaries, so steady-state appends never reallocate and
//! releasing a request returns its pages for immediate reuse by any
//! other slot. Page size doubles as the plan-cache bucket granule
//! ([`crate::fusion::bucket_len`]): a gathered KV tensor is always a
//! whole number of pages, which is exactly the padded shape the cached
//! serving plans expect.
//!
//! Layout: within a page, token-major `[token][head][d]` (an append is
//! one contiguous write); gathers produce the engine's head-major
//! `[head][token][d]` layout with zero fill for the padded tail.

/// Default page size in tokens — also the serving bucket granule.
pub const DEFAULT_BLOCK_TOKENS: usize = 64;

struct Page {
    k: Vec<f32>,
    v: Vec<f32>,
}

struct SlotKv {
    pages: Vec<usize>,
    len: usize,
}

pub struct PagedKv {
    block_tokens: usize,
    heads: usize,
    head_dim: usize,
    pages: Vec<Page>,
    free: Vec<usize>,
    slots: Vec<SlotKv>,
}

impl PagedKv {
    pub fn new(n_slots: usize, block_tokens: usize, heads: usize, head_dim: usize) -> Self {
        PagedKv {
            block_tokens: block_tokens.max(1),
            heads,
            head_dim,
            pages: Vec::new(),
            free: Vec::new(),
            slots: (0..n_slots)
                .map(|_| SlotKv {
                    pages: Vec::new(),
                    len: 0,
                })
                .collect(),
        }
    }

    /// Tokens per page (the serving bucket granule).
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Per-token K/V vector length (`heads * head_dim`).
    pub fn token_stride(&self) -> usize {
        self.heads * self.head_dim
    }

    /// Tokens currently cached for `slot`.
    pub fn len(&self, slot: usize) -> usize {
        self.slots[slot].len
    }

    pub fn is_empty(&self, slot: usize) -> bool {
        self.slots[slot].len == 0
    }

    /// Pages ever allocated (the pool's high-water mark).
    pub fn allocated_pages(&self) -> usize {
        self.pages.len()
    }

    /// Pages currently on the free list.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Append one token's K/V (`[head][d]` layout, `token_stride()`
    /// floats each) to `slot`. Amortized allocation-free: a page is
    /// taken from the free list (or freshly allocated) only every
    /// `block_tokens` appends.
    pub fn append(&mut self, slot: usize, k: &[f32], v: &[f32]) {
        let stride = self.token_stride();
        debug_assert_eq!(k.len(), stride);
        debug_assert_eq!(v.len(), stride);
        let len = self.slots[slot].len;
        if len % self.block_tokens == 0 {
            let cap = self.block_tokens * stride;
            let pi = self.free.pop().unwrap_or_else(|| {
                self.pages.push(Page {
                    k: vec![0.0; cap],
                    v: vec![0.0; cap],
                });
                self.pages.len() - 1
            });
            self.slots[slot].pages.push(pi);
        }
        let pi = *self.slots[slot].pages.last().expect("page just ensured");
        let off = (len % self.block_tokens) * stride;
        self.pages[pi].k[off..off + stride].copy_from_slice(k);
        self.pages[pi].v[off..off + stride].copy_from_slice(v);
        self.slots[slot].len = len + 1;
    }

    /// Gather `slot`'s cache into head-major `[head][padded_len][d]`
    /// buffers (the engine's KV input layout), zero-filling positions
    /// `>= len(slot)`. `padded_len` must be a bucketed length `>= len`.
    pub fn gather(
        &self,
        slot: usize,
        padded_len: usize,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    ) {
        let d = self.head_dim;
        let stride = self.token_stride();
        let sl = &self.slots[slot];
        // A stale bucket (computed before an append) would silently drop
        // the newest tokens; fail fast instead.
        debug_assert!(
            padded_len >= sl.len,
            "gather with padded_len {padded_len} < cached len {}",
            sl.len
        );
        let len = sl.len.min(padded_len);
        k_out.clear();
        v_out.clear();
        k_out.reserve(self.heads * padded_len * d);
        v_out.reserve(self.heads * padded_len * d);
        for h in 0..self.heads {
            for t in 0..len {
                let page = &self.pages[sl.pages[t / self.block_tokens]];
                let off = (t % self.block_tokens) * stride + h * d;
                k_out.extend_from_slice(&page.k[off..off + d]);
                v_out.extend_from_slice(&page.v[off..off + d]);
            }
            k_out.resize(k_out.len() + (padded_len - len) * d, 0.0);
            v_out.resize(v_out.len() + (padded_len - len) * d, 0.0);
        }
    }

    /// Free a slot's pages back to the shared pool.
    pub fn release(&mut self, slot: usize) {
        let pages = std::mem::take(&mut self.slots[slot].pages);
        self.free.extend(pages);
        self.slots[slot].len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn token_vec(seed: f32, stride: usize) -> Vec<f32> {
        (0..stride).map(|i| seed + i as f32).collect()
    }

    #[test]
    fn append_and_gather_round_trip_with_zero_padding() {
        let (heads, d) = (2, 4);
        let mut kv = PagedKv::new(2, 4, heads, d);
        let stride = kv.token_stride();
        for t in 0..6 {
            let k = token_vec(100.0 + t as f32, stride);
            let v = token_vec(200.0 + t as f32, stride);
            kv.append(0, &k, &v);
        }
        assert_eq!(kv.len(0), 6);
        let mut kb = Vec::new();
        let mut vb = Vec::new();
        kv.gather(0, 8, &mut kb, &mut vb);
        assert_eq!(kb.len(), heads * 8 * d);
        // head-major layout: [h][t][d]; token t of head h came from
        // token_vec(100 + t)[h*d..]
        for h in 0..heads {
            for t in 0..8 {
                let got = &kb[(h * 8 + t) * d..(h * 8 + t + 1) * d];
                if t < 6 {
                    let want: Vec<f32> =
                        (0..d).map(|i| 100.0 + t as f32 + (h * d + i) as f32).collect();
                    assert_eq!(got, &want[..], "h={h} t={t}");
                } else {
                    assert!(got.iter().all(|&x| x == 0.0), "padding must be zero");
                }
            }
        }
        assert_eq!(vb[(0 * 8 + 3) * d], 203.0);
    }

    #[test]
    fn pages_grow_in_block_increments() {
        let mut kv = PagedKv::new(1, 4, 1, 2);
        let stride = kv.token_stride();
        assert_eq!(kv.allocated_pages(), 0);
        for t in 0..4 {
            kv.append(0, &token_vec(t as f32, stride), &token_vec(t as f32, stride));
        }
        assert_eq!(kv.allocated_pages(), 1, "4 tokens fit one 4-token page");
        kv.append(0, &token_vec(9.0, stride), &token_vec(9.0, stride));
        assert_eq!(kv.allocated_pages(), 2, "5th token opens a second page");
    }

    #[test]
    fn released_pages_are_reused_across_slots() {
        let mut kv = PagedKv::new(2, 2, 1, 2);
        let stride = kv.token_stride();
        for _ in 0..4 {
            kv.append(0, &token_vec(1.0, stride), &token_vec(1.0, stride));
        }
        assert_eq!(kv.allocated_pages(), 2);
        kv.release(0);
        assert_eq!(kv.len(0), 0);
        assert_eq!(kv.free_pages(), 2);
        // Slot 1 reuses the freed pages: no new allocation.
        for _ in 0..4 {
            kv.append(1, &token_vec(2.0, stride), &token_vec(2.0, stride));
        }
        assert_eq!(kv.allocated_pages(), 2, "pool must reuse freed pages");
        assert_eq!(kv.free_pages(), 0);
        // And the reused pages carry the new values, not the old ones.
        let (mut kb, mut vb) = (Vec::new(), Vec::new());
        kv.gather(1, 4, &mut kb, &mut vb);
        assert!(kb.iter().take(4 * 2).all(|&x| x >= 2.0));
    }

    #[test]
    fn gather_reuses_caller_buffers() {
        let mut kv = PagedKv::new(1, 4, 1, 2);
        let stride = kv.token_stride();
        kv.append(0, &token_vec(1.0, stride), &token_vec(1.0, stride));
        let mut kb = Vec::with_capacity(64);
        let mut vb = Vec::with_capacity(64);
        let cap = kb.capacity();
        kv.gather(0, 4, &mut kb, &mut vb);
        assert_eq!(kb.capacity(), cap, "gather must not grow a large buffer");
        assert_eq!(kb.len(), 4 * 2);
    }
}
