//! Live-serving plumbing: per-request token streams and the threaded
//! ingress that turns the lifecycle's replay loop into a real server.
//!
//! Three pieces (modeled on tgimagik's router `infer.rs` split between
//! an ingress queue and per-request response channels):
//!
//! * [`LiveSubmission`] — what a client hands the server: the request
//!   plus an optional per-token stream sender. Submissions travel over
//!   a **bounded** MPSC channel, so a flooding client blocks in `send`
//!   (backpressure) instead of growing server memory. Dropping the
//!   sender is the drain signal: the lifecycle stops admitting, finishes
//!   in-flight work, and exits with the no-leak invariant intact.
//! * [`StreamHub`] — the server side of every open token stream. Each
//!   emitted token is `try_send`-ed to the request's bounded channel;
//!   tokens a slow consumer can't take queue in a per-request backlog
//!   (flushed ahead of later tokens). A backlog past `max_backlog`, or
//!   a dropped receiver, marks the consumer gone — the lifecycle then
//!   cancels the request (`slow consumer` / mid-stream disconnect) and
//!   frees its pages. The round loop never blocks on a client.
//! * [`spawn_ingress`] — a detached thread that paces a trace's
//!   arrivals in wall time and submits each request through the bounded
//!   channel, then disconnects (graceful drain).
//!
//! Every event a consumer sees ends with [`StreamEvent::Done`] carrying
//! the request's terminal [`Outcome`], so a client can always
//! distinguish "stream over" from "server died".

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::tracegen::Request;

use super::metrics::Outcome;

/// Environment variable the CLI reads the per-request stream channel
/// capacity from (tokens buffered in the channel itself; the hub
/// backlogs up to 4x more before declaring the consumer slow).
pub const STREAM_BUF_ENV: &str = "FLASHLIGHT_STREAM_BUF";

/// Default per-request stream capacity: larger than any single
/// response in the engine trace, so a consumer that reads at all never
/// loses tokens.
pub const DEFAULT_STREAM_BUF: usize = 32;

/// Stream channel capacity from `FLASHLIGHT_STREAM_BUF` (CLI entry
/// points only). Unset → [`DEFAULT_STREAM_BUF`]; anything set but not
/// an integer ≥ 1 is **rejected with a warning** rather than silently
/// falling back (the `FLASHLIGHT_THREADS` fix, applied here): a typo'd
/// capacity would otherwise quietly change the slow-consumer policy.
pub fn stream_buf_from_env() -> usize {
    stream_buf_from_env_value(std::env::var(STREAM_BUF_ENV).ok().as_deref())
}

/// Testable core of [`stream_buf_from_env`].
pub fn stream_buf_from_env_value(env: Option<&str>) -> usize {
    match env {
        None => DEFAULT_STREAM_BUF,
        Some(s) => match s.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                eprintln!(
                    "flashlight: ignoring invalid {STREAM_BUF_ENV}={s:?} \
                     (want an integer >= 1); using the default of {DEFAULT_STREAM_BUF}"
                );
                DEFAULT_STREAM_BUF
            }
        },
    }
}

/// One event on a per-request token stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StreamEvent {
    /// The next generated token.
    Token(u32),
    /// The stream's terminal event — always the last one delivered.
    Done { outcome: Outcome, reason: String },
}

/// What a client submits to the live server: the request plus an
/// optional sender for its token stream (None = fire-and-forget; the
/// outcome is still recorded in the lifecycle report).
pub struct LiveSubmission {
    pub req: Request,
    pub stream: Option<SyncSender<StreamEvent>>,
}

struct Sink {
    tx: SyncSender<StreamEvent>,
    /// Tokens the bounded channel couldn't take yet, oldest first.
    backlog: VecDeque<StreamEvent>,
}

/// The server side of all open token streams. Not a channel itself —
/// a registry the round loop pushes into between engine steps, so
/// stream delivery never blocks a launch.
pub struct StreamHub {
    enabled: bool,
    /// Backlogged events past which a consumer is declared slow and its
    /// stream dropped (the request is then cancelled by the lifecycle).
    max_backlog: usize,
    sinks: HashMap<usize, Sink>,
    slow_drops: u64,
    disconnects: u64,
}

impl StreamHub {
    /// A hub with the given slow-consumer backlog bound (events queued
    /// *beyond* each stream channel's own capacity).
    pub fn new(max_backlog: usize) -> Self {
        StreamHub {
            enabled: true,
            max_backlog,
            sinks: HashMap::new(),
            slow_drops: 0,
            disconnects: 0,
        }
    }

    /// The no-op hub for replay runs with no streaming consumers:
    /// `push_token` always succeeds, `finish` does nothing.
    pub fn disabled() -> Self {
        StreamHub {
            enabled: false,
            max_backlog: 0,
            sinks: HashMap::new(),
            slow_drops: 0,
            disconnects: 0,
        }
    }

    /// Register a consumer-supplied sender for request `id`.
    pub fn attach(&mut self, id: usize, tx: SyncSender<StreamEvent>) {
        if self.enabled {
            self.sinks.insert(id, Sink { tx, backlog: VecDeque::new() });
        }
    }

    /// Create a bounded stream for request `id` and return the consumer
    /// end (test / in-process convenience).
    pub fn open(&mut self, id: usize, capacity: usize) -> Receiver<StreamEvent> {
        let (tx, rx) = sync_channel(capacity.max(1));
        self.attach(id, tx);
        rx
    }

    /// Streams currently open.
    pub fn open_streams(&self) -> usize {
        self.sinks.len()
    }

    /// Consumers dropped for exceeding the backlog bound.
    pub fn slow_drops(&self) -> u64 {
        self.slow_drops
    }

    /// Consumers that disconnected (dropped their receiver) mid-stream.
    pub fn disconnects(&self) -> u64 {
        self.disconnects
    }

    /// Deliver one token to request `id`'s stream. Returns `false` when
    /// the consumer is gone — disconnected, or so far behind that its
    /// backlog passed the bound — in which case the sink is dropped and
    /// the caller should cancel the request. Requests with no stream
    /// registered always return `true`.
    pub fn push_token(&mut self, id: usize, tok: u32) -> bool {
        if !self.enabled {
            return true;
        }
        let Some(sink) = self.sinks.get_mut(&id) else {
            return true;
        };
        sink.backlog.push_back(StreamEvent::Token(tok));
        let gone = loop {
            let Some(ev) = sink.backlog.pop_front() else {
                break false;
            };
            match sink.tx.try_send(ev) {
                Ok(()) => {}
                Err(TrySendError::Full(ev)) => {
                    sink.backlog.push_front(ev);
                    break sink.backlog.len() > self.max_backlog;
                }
                Err(TrySendError::Disconnected(_)) => break true,
            }
        };
        if gone {
            let sink = self.sinks.remove(&id).unwrap();
            if sink.backlog.len() > self.max_backlog {
                self.slow_drops += 1;
            } else {
                self.disconnects += 1;
            }
            return false;
        }
        true
    }

    /// Close request `id`'s stream with its terminal event, flushing
    /// any backlog first (best-effort: a consumer that keeps reading
    /// sees every token and then `Done`; one that stopped reading may
    /// miss trailing events but its channel still disconnects).
    pub fn finish(&mut self, id: usize, outcome: Outcome, reason: &str) {
        if !self.enabled {
            return;
        }
        let Some(mut sink) = self.sinks.remove(&id) else {
            return;
        };
        sink.backlog.push_back(StreamEvent::Done {
            outcome,
            reason: reason.to_string(),
        });
        while let Some(ev) = sink.backlog.pop_front() {
            if sink.tx.try_send(ev).is_err() {
                break;
            }
        }
    }
}

/// Spawn the ingress thread: submit each `(request, stream)` pair at
/// `arrival_s * time_scale` seconds of wall time after spawn, through a
/// bounded channel of `channel_cap` submissions (a full channel blocks
/// the ingress — backpressure — rather than growing memory). The thread
/// drops its sender when the trace is exhausted; the lifecycle sees the
/// disconnect and drains gracefully.
pub fn spawn_ingress(
    trace: Vec<(Request, Option<SyncSender<StreamEvent>>)>,
    time_scale: f64,
    channel_cap: usize,
) -> (Receiver<LiveSubmission>, JoinHandle<usize>) {
    let (tx, rx) = sync_channel(channel_cap.max(1));
    let handle = std::thread::Builder::new()
        .name("flashlight-ingress".to_string())
        .spawn(move || {
            let start = Instant::now();
            let mut sent = 0usize;
            for (req, stream) in trace {
                let due = Duration::from_secs_f64((req.arrival_s * time_scale).max(0.0));
                if let Some(wait) = due.checked_sub(start.elapsed()) {
                    std::thread::sleep(wait);
                }
                if tx.send(LiveSubmission { req, stream }).is_err() {
                    break; // server went away; stop submitting
                }
                sent += 1;
            }
            sent
        })
        .expect("spawn flashlight ingress");
    (rx, handle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hub_delivers_tokens_then_done_in_order() {
        let mut hub = StreamHub::new(8);
        let rx = hub.open(7, 4);
        for t in [10u32, 11, 12] {
            assert!(hub.push_token(7, t));
        }
        hub.finish(7, Outcome::Completed, "");
        let got: Vec<StreamEvent> = rx.try_iter().collect();
        assert_eq!(
            got,
            vec![
                StreamEvent::Token(10),
                StreamEvent::Token(11),
                StreamEvent::Token(12),
                StreamEvent::Done { outcome: Outcome::Completed, reason: String::new() },
            ]
        );
        assert_eq!(hub.open_streams(), 0);
    }

    #[test]
    fn slow_consumer_backlogs_then_drops() {
        // Channel holds 1 event, hub backlogs up to 2 more: the 4th
        // undelivered token exceeds the bound and drops the consumer.
        let mut hub = StreamHub::new(2);
        let rx = hub.open(3, 1);
        assert!(hub.push_token(3, 0)); // -> channel
        assert!(hub.push_token(3, 1)); // backlog: 1
        assert!(hub.push_token(3, 2)); // backlog: 2 (== bound, still ok)
        assert!(!hub.push_token(3, 3), "backlog past the bound must drop");
        assert_eq!(hub.slow_drops(), 1);
        assert_eq!(hub.open_streams(), 0);
        // finish() after the drop is a no-op.
        hub.finish(3, Outcome::Cancelled, "slow");
        // The consumer still sees what the channel took.
        assert_eq!(rx.try_iter().count(), 1);
    }

    #[test]
    fn disconnected_consumer_reports_gone() {
        let mut hub = StreamHub::new(8);
        let rx = hub.open(1, 4);
        assert!(hub.push_token(1, 5));
        drop(rx);
        assert!(!hub.push_token(1, 6), "dropped receiver must report gone");
        assert_eq!(hub.disconnects(), 1);
    }

    #[test]
    fn a_consumer_that_drains_mid_push_recovers_its_backlog() {
        let mut hub = StreamHub::new(8);
        let rx = hub.open(2, 1);
        assert!(hub.push_token(2, 0));
        assert!(hub.push_token(2, 1)); // backlogged
        assert_eq!(rx.recv().unwrap(), StreamEvent::Token(0));
        // Next push flushes the backlog first, keeping order.
        assert!(hub.push_token(2, 2));
        assert_eq!(rx.recv().unwrap(), StreamEvent::Token(1));
        hub.finish(2, Outcome::Completed, "");
        assert_eq!(rx.recv().unwrap(), StreamEvent::Token(2));
        assert_eq!(
            rx.recv().unwrap(),
            StreamEvent::Done { outcome: Outcome::Completed, reason: String::new() }
        );
    }

    #[test]
    fn stream_buf_env_rejects_zero_and_garbage() {
        assert_eq!(stream_buf_from_env_value(None), DEFAULT_STREAM_BUF);
        assert_eq!(stream_buf_from_env_value(Some("8")), 8);
        assert_eq!(stream_buf_from_env_value(Some(" 64 ")), 64);
        // Invalid values are rejected (loudly), never treated as "tiny
        // buffer" or "unset": a zero-capacity stream channel cannot
        // exist and garbage is always a typo.
        for bad in ["0", "-3", "lots", "", "4.5"] {
            assert_eq!(
                stream_buf_from_env_value(Some(bad)),
                DEFAULT_STREAM_BUF,
                "{bad:?} must fall back to the default"
            );
        }
    }

    #[test]
    fn ingress_thread_paces_submits_and_disconnects() {
        let reqs: Vec<(Request, Option<SyncSender<StreamEvent>>)> = (0..5)
            .map(|i| {
                let mut r = Request::default();
                r.id = i;
                r.arrival_s = i as f64 * 1e-3;
                (r, None)
            })
            .collect();
        let (rx, handle) = spawn_ingress(reqs, 1.0, 2);
        let mut got = Vec::new();
        while let Ok(sub) = rx.recv() {
            got.push(sub.req.id);
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        assert_eq!(handle.join().unwrap(), 5);
    }
}
