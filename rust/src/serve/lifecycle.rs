//! The fault-tolerant request lifecycle: a serving loop in which every
//! request ends in **exactly one** terminal state
//! ([`Outcome`]: `Completed | Rejected | Cancelled | DeadlineExceeded |
//! Failed`) no matter what faults fire, no KV pages or slots leak, and
//! the surviving requests' token streams are bit-identical to a
//! fault-free run.
//!
//! Where [`super::engine::run_trace`] assumes a fixed, well-behaved
//! schedule (any anomaly aborts the whole run), this runner degrades:
//!
//! 1. **Bounded ingress** — clients submitting past `queue_cap` get an
//!    explicit `Rejected { retry_after }` instead of unbounded queue
//!    growth (saturating replay: the whole trace submits as fast as
//!    the queue drains).
//! 2. **Admission control** — requests that could *never* complete
//!    (context window, worst-case lifetime KV pages vs the page cap)
//!    are rejected up front with a precise reason
//!    ([`Backend::admit_check`]).
//! 3. **Deadlines & cancellation** — per-request SLO budgets and
//!    cancel times (trace-driven or fault-injected) are swept between
//!    engine rounds; a dead request's pages and slot free immediately,
//!    even mid-prefill.
//! 4. **KV-pressure degradation ladder** — when the next round's page
//!    preflight cannot be satisfied: first evict parked conversation
//!    prefixes, then *preempt* the lowest-priority in-flight request
//!    (release its slot, requeue it at the front; completed-prefill
//!    victims park their prefix so the retry adopts it), and finally
//!    throttle admission until pressure lifts. Nothing panics on an
//!    exhausted pool.
//! 5. **Worker-panic isolation** — an attributed panic inside a
//!    batched launch ([`EngineBackend::step`]) fails only the poisoned
//!    request; the pool and the rest of the batch continue.
//!
//! Faults come from a [`FaultPlan`] consulted at the top of every
//! round, so a (trace, config, plan) triple replays deterministically —
//! the chaos harness's whole premise.

use std::collections::{HashMap, HashSet, VecDeque};

use crate::tracegen::Request;

use super::engine::{prompt_tokens, Backend, SchedulerConfig};
use super::engine_backend::EngineBackend;
use super::faults::{Fault, FaultPlan};
use super::metrics::{
    summarize_outcomes, LifecycleSummary, Outcome, RequestMetrics, RequestOutcome,
};

/// How deadlines and cancel budgets are measured.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockMode {
    /// Engine-reported elapsed seconds (real serving). Deadline
    /// terminals depend on machine speed — use `Rounds` for
    /// deterministic tests.
    Wall,
    /// One clock unit per scheduling round: `deadline_s`/`cancel_s`
    /// budgets count rounds, bit-for-bit reproducible anywhere.
    Rounds,
}

/// Lifecycle policy knobs, layered on top of [`SchedulerConfig`].
#[derive(Debug, Clone, Copy)]
pub struct LifecycleConfig {
    /// Ingress queue bound; submissions past it are rejected with a
    /// backoff hint. 0 = unbounded (no rejection rung).
    pub queue_cap: usize,
    /// Deadline budget applied to requests that carry none
    /// (`Request::deadline_s = INFINITY`). INFINITY = no default.
    pub default_deadline_s: f64,
    pub clock: ClockMode,
    /// Consecutive rounds the runner may sit unable to admit or step
    /// anything (e.g. a pressure window with an empty batch) before it
    /// drains the queue as `Rejected` instead of livelocking.
    pub max_stall_rounds: u32,
}

impl Default for LifecycleConfig {
    fn default() -> Self {
        LifecycleConfig {
            queue_cap: 0,
            default_deadline_s: f64::INFINITY,
            clock: ClockMode::Wall,
            max_stall_rounds: 64,
        }
    }
}

/// Run-level lifecycle counters (beyond per-request outcomes).
#[derive(Debug, Clone, Copy, Default)]
pub struct LifecycleStats {
    pub rounds: u64,
    /// In-flight requests preempted (released + requeued) for pages.
    pub preemptions: u64,
    /// Rounds admission was throttled for lack of pages.
    pub throttled_rounds: u64,
    pub rejected_queue_full: u64,
    pub rejected_inadmissible: u64,
}

/// Everything a lifecycle run produced.
#[derive(Debug, Clone)]
pub struct LifecycleReport {
    /// One terminal record per trace request, sorted by id.
    pub outcomes: Vec<RequestOutcome>,
    pub summary: LifecycleSummary,
    pub stats: LifecycleStats,
}

/// A submitted-but-not-yet-running request, with its lifecycle budgets
/// made absolute at submission time.
struct Queued {
    req: Request,
    submitted_s: f64,
    deadline_at: f64,
    cancel_at: f64,
    preemptions: u32,
}

/// A request occupying a slot (mid-prefill or decoding).
struct InFlight {
    q: Queued,
    admitted_round: u64,
    prefilling: bool,
    tokens: Vec<u32>,
    first_token_s: f64,
    last_token_s: f64,
    itls: Vec<f64>,
}

fn record(outcomes: &mut HashMap<usize, RequestOutcome>, o: RequestOutcome) {
    let id = o.id;
    let prev = outcomes.insert(id, o);
    debug_assert!(
        prev.is_none(),
        "request {id} reached two terminal states"
    );
}

fn terminal(q: &Queued, outcome: Outcome, reason: String, retry_after_s: f64) -> RequestOutcome {
    RequestOutcome {
        id: q.req.id,
        outcome,
        reason,
        retry_after_s,
        tokens: Vec::new(),
        preemptions: q.preemptions,
        metrics: None,
    }
}

impl InFlight {
    fn into_terminal(self, outcome: Outcome, reason: String, now: f64) -> RequestOutcome {
        let metrics = self.first_token_s.is_finite().then(|| RequestMetrics {
            id: self.q.req.id,
            arrival_s: self.q.submitted_s,
            first_token_s: self.first_token_s,
            done_s: now,
            input_tokens: self.q.req.input_tokens,
            output_tokens: self.tokens.len(),
            itls: self.itls.clone(),
        });
        RequestOutcome {
            id: self.q.req.id,
            outcome,
            reason,
            retry_after_s: 0.0,
            tokens: self.tokens,
            preemptions: self.q.preemptions,
            metrics,
        }
    }
}

/// Drive `trace` through `backend` under the fault-tolerant lifecycle.
/// See the module docs for the state machine; `faults` may be
/// [`FaultPlan::none`] for a healthy run.
pub fn run_lifecycle(
    backend: &mut EngineBackend,
    trace: &[Request],
    sched: SchedulerConfig,
    lc: LifecycleConfig,
    faults: &FaultPlan,
    vocab: usize,
) -> anyhow::Result<LifecycleReport> {
    backend.configure(&sched);
    let n_slots = backend.n_slots();
    let mut pending: VecDeque<Request> = trace.to_vec().into();
    let mut queue: VecDeque<Queued> = VecDeque::new();
    let mut slots: Vec<Option<InFlight>> = (0..n_slots).map(|_| None).collect();
    let mut prefill_order: Vec<usize> = Vec::new();
    let mut outcomes: HashMap<usize, RequestOutcome> = HashMap::new();
    let mut cancelled_ids: HashSet<usize> = HashSet::new();
    let mut stats = LifecycleStats::default();
    let mut clock = 0.0f64;
    let mut round: u64 = 0;
    let mut stall = 0u32;
    let mut last_dt = 1e-3f64;

    loop {
        if pending.is_empty() && queue.is_empty() && slots.iter().all(Option::is_none) {
            break;
        }
        stats.rounds = round + 1;

        // 1. Fault-plan pressure for this round (0 lifts it).
        backend.set_kv_pressure(faults.pressure_at(round));

        // 2. Point faults: cancels persist (a client cancel also kills
        //    a not-yet-submitted request), storms and panics fire now.
        for ev in faults.events_at(round) {
            match *ev {
                Fault::Cancel { id, .. } => {
                    cancelled_ids.insert(id);
                }
                Fault::DeadlineStorm { every, .. } => {
                    let mut j = 0usize;
                    for s in slots.iter_mut().flatten() {
                        if j % every == 0 {
                            s.q.deadline_at = s.q.deadline_at.min(clock);
                        }
                        j += 1;
                    }
                }
                Fault::WorkerPanic { item, .. } => {
                    crate::exec::runtime::inject_panic_next_launch(item);
                }
                Fault::PagePressure { .. } => {}
            }
        }

        // 3. Bounded ingress (saturating replay: every not-yet-
        //    submitted client submits now; past the cap they get an
        //    explicit rejection with a backoff hint).
        while let Some(r) = pending.pop_front() {
            if lc.queue_cap > 0 && queue.len() >= lc.queue_cap {
                stats.rejected_queue_full += 1;
                let retry = (queue.len() as f64) * last_dt.max(1e-3);
                let q = Queued {
                    req: r,
                    submitted_s: clock,
                    deadline_at: f64::INFINITY,
                    cancel_at: f64::INFINITY,
                    preemptions: 0,
                };
                record(
                    &mut outcomes,
                    terminal(
                        &q,
                        Outcome::Rejected,
                        format!("ingress queue full ({} queued)", queue.len()),
                        retry,
                    ),
                );
                continue;
            }
            let deadline_budget = if r.deadline_s.is_finite() {
                r.deadline_s
            } else {
                lc.default_deadline_s
            };
            queue.push_back(Queued {
                deadline_at: clock + deadline_budget,
                cancel_at: clock + r.cancel_s,
                submitted_s: clock,
                preemptions: 0,
                req: r,
            });
        }

        // 4. Sweeps: cancelled / past-deadline requests terminate now,
        //    queued or in-flight alike; an in-flight death frees its
        //    pages and slot immediately, even mid-prefill.
        let mut keep = VecDeque::with_capacity(queue.len());
        for q in queue.drain(..) {
            if cancelled_ids.contains(&q.req.id) || clock >= q.cancel_at {
                record(
                    &mut outcomes,
                    terminal(&q, Outcome::Cancelled, "cancelled while queued".into(), 0.0),
                );
            } else if clock >= q.deadline_at {
                record(
                    &mut outcomes,
                    terminal(
                        &q,
                        Outcome::DeadlineExceeded,
                        "deadline expired while queued".into(),
                        0.0,
                    ),
                );
            } else {
                keep.push_back(q);
            }
        }
        queue = keep;
        for slot in 0..n_slots {
            let Some(fl) = &slots[slot] else { continue };
            let cancel = cancelled_ids.contains(&fl.q.req.id) || clock >= fl.q.cancel_at;
            let deadline = clock >= fl.q.deadline_at;
            if cancel || deadline {
                let fl = slots[slot].take().unwrap();
                let phase = if fl.prefilling { "prefill" } else { "decode" };
                backend.release(slot);
                prefill_order.retain(|&s| s != slot);
                let (outcome, why) = if cancel {
                    (Outcome::Cancelled, format!("cancelled mid-{phase}"))
                } else {
                    (Outcome::DeadlineExceeded, format!("deadline expired mid-{phase}"))
                };
                record(&mut outcomes, fl.into_terminal(outcome, why, clock));
            }
        }

        // 5. Admission: free slots pull from the queue head. Requests
        //    that can never complete are rejected; if the prompt's
        //    pages aren't available even after evicting parked
        //    prefixes, admission throttles (the request waits).
        let mut free: VecDeque<usize> = (0..n_slots).filter(|&i| slots[i].is_none()).collect();
        let mut admitted = 0usize;
        while admitted < sched.max_prefills_per_step && !free.is_empty() {
            let Some(q) = queue.pop_front() else { break };
            if let Err(why) = backend.admit_check(&q.req) {
                stats.rejected_inadmissible += 1;
                record(
                    &mut outcomes,
                    terminal(&q, Outcome::Rejected, why, f64::INFINITY),
                );
                continue;
            }
            let need = backend.admit_pages_needed(q.req.input_tokens);
            if need > backend.available_kv_pages() && backend.evict_prefixes_for(need) < need {
                stats.throttled_rounds += 1;
                queue.push_front(q);
                break;
            }
            let slot = free.pop_front().unwrap();
            let tokens = prompt_tokens(&q.req, vocab);
            backend.begin_prefill(slot, &q.req, &tokens)?;
            prefill_order.push(slot);
            slots[slot] = Some(InFlight {
                q,
                admitted_round: round,
                prefilling: true,
                tokens: Vec::new(),
                first_token_s: f64::NAN,
                last_token_s: clock,
                itls: Vec::new(),
            });
            admitted += 1;
        }

        // 6. Build the round's work and walk the degradation ladder
        //    until its page preflight fits: evict parked prefixes,
        //    then preempt the lowest-priority / latest-admitted
        //    in-flight request (requeued at the front; a completed
        //    prefill parks so the retry adopts it).
        let mut budget = if sched.prefill_round_tokens == 0 {
            usize::MAX
        } else {
            sched.prefill_round_tokens
        };
        let mut work: Vec<(usize, usize)> = Vec::new();
        for &si in &prefill_order {
            if budget == 0 {
                break;
            }
            let rows = backend.staged_rows(si).min(budget);
            if rows > 0 {
                work.push((si, rows));
                budget -= rows;
            }
        }
        let mut active: Vec<usize> = (0..n_slots)
            .filter(|&i| slots[i].as_ref().is_some_and(|fl| !fl.prefilling))
            .collect();
        loop {
            let need: usize = active
                .iter()
                .map(|&s| backend.decode_pages_needed(s))
                .sum::<usize>()
                + work
                    .iter()
                    .map(|&(s, _)| backend.prefill_pages_bound(s))
                    .sum::<usize>();
            if need <= backend.available_kv_pages() || backend.evict_prefixes_for(need) >= need {
                break;
            }
            let victim = slots
                .iter()
                .enumerate()
                .filter_map(|(i, s)| {
                    s.as_ref()
                        .map(|fl| (i, fl.q.req.priority, fl.admitted_round))
                })
                .min_by_key(|&(_, pri, adm)| (pri, std::cmp::Reverse(adm)))
                .map(|(i, ..)| i);
            let Some(v) = victim else { break };
            let mut fl = slots[v].take().unwrap();
            backend.release(v);
            active.retain(|&s| s != v);
            work.retain(|&(s, _)| s != v);
            prefill_order.retain(|&s| s != v);
            // The retry restarts cleanly: its stream is regenerated
            // from the prompt, so a preempted-then-completed request
            // still matches the fault-free run bit for bit.
            fl.q.preemptions += 1;
            stats.preemptions += 1;
            queue.push_front(fl.q);
        }

        // 7. One engine round (if there is anything to run).
        if work.is_empty() && active.is_empty() {
            if !queue.is_empty() || !pending.is_empty() {
                stall += 1;
                if stall > lc.max_stall_rounds {
                    // Livelock guard: pressure (or ping-pong) has kept
                    // the engine idle too long — shed the queue rather
                    // than spin forever. Every request still gets a
                    // terminal state.
                    for q in queue.drain(..) {
                        stats.rejected_queue_full += 1;
                        record(
                            &mut outcomes,
                            terminal(
                                &q,
                                Outcome::Rejected,
                                format!(
                                    "admission stalled for {} rounds (KV pressure)",
                                    lc.max_stall_rounds
                                ),
                                last_dt.max(1e-3) * 16.0,
                            ),
                        );
                    }
                    for r in pending.drain(..) {
                        let q = Queued {
                            req: r,
                            submitted_s: clock,
                            deadline_at: f64::INFINITY,
                            cancel_at: f64::INFINITY,
                            preemptions: 0,
                        };
                        stats.rejected_queue_full += 1;
                        record(
                            &mut outcomes,
                            terminal(
                                &q,
                                Outcome::Rejected,
                                "server stalled before submission".into(),
                                last_dt.max(1e-3) * 16.0,
                            ),
                        );
                    }
                }
            }
        } else {
            stall = 0;
            let rep = backend.step(&work, &active)?;
            last_dt = rep.elapsed_s.max(1e-9);
            if lc.clock == ClockMode::Wall {
                clock += rep.elapsed_s;
            }
            let now = if lc.clock == ClockMode::Rounds {
                (round + 1) as f64
            } else {
                clock
            };

            for (slot, tok) in rep.finished {
                prefill_order.retain(|&s| s != slot);
                let fl = slots[slot].as_mut().expect("finished an empty slot");
                fl.prefilling = false;
                fl.first_token_s = now;
                fl.last_token_s = now;
                fl.tokens.push(tok);
                if fl.q.req.output_tokens <= 1 {
                    let fl = slots[slot].take().unwrap();
                    backend.release(slot);
                    record(&mut outcomes, fl.into_terminal(Outcome::Completed, String::new(), now));
                }
            }
            for (slot, tok) in rep.tokens {
                let fl = slots[slot].as_mut().expect("token for an empty slot");
                fl.itls.push(now - fl.last_token_s);
                fl.last_token_s = now;
                fl.tokens.push(tok);
                if fl.tokens.len() >= fl.q.req.output_tokens.max(1) {
                    let fl = slots[slot].take().unwrap();
                    backend.release(slot);
                    record(&mut outcomes, fl.into_terminal(Outcome::Completed, String::new(), now));
                }
            }
            for (slot, reason) in rep.failed {
                prefill_order.retain(|&s| s != slot);
                let fl = slots[slot].take().expect("failure on an empty slot");
                backend.release(slot);
                record(&mut outcomes, fl.into_terminal(Outcome::Failed, reason, now));
            }
        }

        round += 1;
        if lc.clock == ClockMode::Rounds {
            clock = round as f64;
        }
    }

    // Leave the backend clean for the next run: no synthetic pressure,
    // no armed faults.
    backend.set_kv_pressure(0);
    crate::exec::runtime::clear_injected_panic();

    anyhow::ensure!(
        outcomes.len() == trace.len(),
        "terminal-state invariant violated: {} outcomes for {} requests",
        outcomes.len(),
        trace.len()
    );
    let mut outcomes: Vec<RequestOutcome> = outcomes.into_values().collect();
    outcomes.sort_by_key(|o| o.id);
    let summary = summarize_outcomes(&outcomes);
    Ok(LifecycleReport {
        summary,
        stats,
        outcomes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Parallelism;
    use crate::serve::engine_backend::EngineModel;
    use crate::tracegen::{generate, TraceConfig};

    fn trace(n: usize) -> Vec<Request> {
        generate(&TraceConfig {
            n_requests: n,
            rate: 100.0,
            input_mu: 3.5,
            input_sigma: 0.5,
            mean_output: 5.0,
            max_input: 100,
            max_output: 8,
            ..Default::default()
        })
    }

    fn backend(threads: usize) -> EngineBackend {
        EngineBackend::new(
            EngineModel::tiny(),
            4,
            1024,
            Parallelism::with_threads(threads),
        )
    }

    fn sched() -> SchedulerConfig {
        SchedulerConfig {
            prefill_chunk_tokens: 64,
            prefill_round_tokens: 128,
            ..Default::default()
        }
    }

    fn assert_no_leak(b: &mut EngineBackend) {
        let (alloc, free) = b.kv_pages();
        assert_eq!(
            alloc,
            free + b.prefix_stats().parked_pages,
            "pages leaked beyond the parked prefixes"
        );
        b.clear_prefix_cache();
        let (alloc, free) = b.kv_pages();
        assert_eq!(alloc, free, "pages leaked after cache clear");
    }

    #[test]
    fn healthy_lifecycle_completes_everything_bit_identically_across_threads() {
        let tr = trace(10);
        let mut streams: Vec<Vec<Vec<u32>>> = Vec::new();
        for threads in [1, 2, 4] {
            let mut b = backend(threads);
            let vocab = b.model.vocab;
            let rep = run_lifecycle(
                &mut b,
                &tr,
                sched(),
                LifecycleConfig {
                    clock: ClockMode::Rounds,
                    ..Default::default()
                },
                &FaultPlan::none(),
                vocab,
            )
            .unwrap();
            assert_eq!(rep.summary.completed, tr.len(), "threads={threads}");
            assert_eq!(rep.summary.total(), tr.len());
            for (o, r) in rep.outcomes.iter().zip(&tr) {
                assert_eq!(o.id, r.id);
                assert_eq!(o.outcome, Outcome::Completed);
                assert_eq!(o.tokens.len(), r.output_tokens.max(1), "req {}", r.id);
            }
            assert!(rep.summary.goodput_tokens_per_s > 0.0);
            streams.push(rep.outcomes.into_iter().map(|o| o.tokens).collect());
            assert_no_leak(&mut b);
        }
        assert_eq!(streams[0], streams[1], "threads must not change tokens");
        assert_eq!(streams[0], streams[2], "threads must not change tokens");
    }

    #[test]
    fn bounded_ingress_rejects_overflow_with_backoff() {
        let tr = trace(8);
        let mut b = backend(1);
        let vocab = b.model.vocab;
        let rep = run_lifecycle(
            &mut b,
            &tr,
            sched(),
            LifecycleConfig {
                queue_cap: 2,
                clock: ClockMode::Rounds,
                ..Default::default()
            },
            &FaultPlan::none(),
            vocab,
        )
        .unwrap();
        assert_eq!(rep.summary.total(), tr.len());
        assert!(rep.summary.rejected > 0, "overflow must reject");
        assert_eq!(rep.summary.completed + rep.summary.rejected, tr.len());
        for o in rep.outcomes.iter().filter(|o| o.outcome == Outcome::Rejected) {
            assert!(o.retry_after_s > 0.0, "rejection must carry a backoff hint");
            assert!(o.reason.contains("queue full"), "{}", o.reason);
        }
        assert_eq!(rep.stats.rejected_queue_full as usize, rep.summary.rejected);
        assert_no_leak(&mut b);
    }

    #[test]
    fn default_deadline_expires_slow_requests_deterministically() {
        let tr = trace(8);
        let run = |threads: usize| {
            let mut b = backend(threads);
            let vocab = b.model.vocab;
            let rep = run_lifecycle(
                &mut b,
                &tr,
                sched(),
                LifecycleConfig {
                    default_deadline_s: 6.0, // rounds
                    clock: ClockMode::Rounds,
                    ..Default::default()
                },
                &FaultPlan::none(),
                vocab,
            )
            .unwrap();
            assert_eq!(rep.summary.total(), tr.len());
            assert!(
                rep.summary.deadline_exceeded > 0,
                "a 6-round budget must expire some of 8 queued requests"
            );
            assert_no_leak(&mut b);
            rep.outcomes
                .iter()
                .map(|o| (o.outcome, o.tokens.clone()))
                .collect::<Vec<_>>()
        };
        // Rounds-mode deadlines are thread-count independent.
        assert_eq!(run(1), run(2));
    }
}
