//! The fault-tolerant request lifecycle: a serving loop in which every
//! request ends in **exactly one** terminal state
//! ([`Outcome`]: `Completed | Rejected | Cancelled | DeadlineExceeded |
//! Failed`) no matter what faults fire, no KV pages or slots leak, and
//! the surviving requests' token streams are bit-identical to a
//! fault-free run.
//!
//! Where [`super::engine::run_trace`] assumes a fixed, well-behaved
//! schedule (any anomaly aborts the whole run), this runner degrades:
//!
//! 1. **Bounded ingress** — clients submitting past `queue_cap` get an
//!    explicit `Rejected { retry_after }`, or — with
//!    [`LifecycleConfig::resubmit_max`] > 0 — re-enter through seeded
//!    exponential backoff with jitter that *honors* the computed
//!    `retry_after` instead of hammering the full queue every round.
//! 2. **Admission control** — requests that could *never* complete
//!    (context window, worst-case lifetime KV pages vs the page cap)
//!    are rejected up front with a precise reason
//!    ([`Backend::admit_check`]). Admission is **priority-aware with
//!    aging**: the queue entry with the highest
//!    `priority + waited_rounds / aging_rounds` admits first (FIFO
//!    within a class, so uniform-priority traces behave exactly as
//!    before), and aging guarantees low-priority requests cannot
//!    starve.
//! 3. **Deadlines & cancellation** — per-request SLO budgets and
//!    cancel times (trace-driven or fault-injected) are swept between
//!    engine rounds; a dead request's pages and slot free immediately,
//!    even mid-prefill. A streaming consumer that disconnects or falls
//!    past its backlog bound cancels its request the same way (the
//!    slow-consumer policy — see [`super::live::StreamHub`]).
//! 4. **KV-pressure degradation ladder** — when the next round's page
//!    preflight cannot be satisfied: first evict parked conversation
//!    prefixes, then *preempt* the lowest-priority in-flight request
//!    (release its slot, requeue it; victims park their whole-page
//!    prefill rows so the retry adopts them), and finally throttle
//!    admission until pressure lifts. Nothing panics on an exhausted
//!    pool.
//! 5. **Worker-panic and stall isolation** — an attributed panic
//!    inside a batched launch ([`EngineBackend::step`]) fails only the
//!    poisoned request; a launch that stops heartbeating past the
//!    watchdog's stall budget ([`super::supervisor::Supervisor`]) is
//!    killed, attributed, and failed the same way. The pool and the
//!    rest of the batch continue, re-executed bit-identically.
//!
//! The loop is fed by an [`Ingress`]: the legacy saturating replay, an
//! open-loop arrival schedule (requests submit when the clock reaches
//! their arrival time, whether or not the server has capacity), or a
//! **live** bounded MPSC channel fed by real threads
//! ([`super::live::spawn_ingress`]) — channel disconnect is the
//! graceful-drain signal: stop admitting, finish in-flight work, and
//! exit with the no-leak invariant (`allocated == free + parked`)
//! checked on the way out.
//!
//! Faults come from a [`FaultPlan`] consulted at the top of every
//! round, so a (trace, config, plan) triple replays deterministically —
//! the chaos harness's whole premise. Backoff jitter draws from its own
//! seeded RNG in submission order, so `ClockMode::Rounds` chaos runs
//! stay bit-reproducible at any thread count even with requeues in
//! flight.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

use crate::tracegen::{Request, Rng};

use super::engine::{prompt_tokens, Backend, SchedulerConfig};
use super::engine_backend::EngineBackend;
use super::faults::{Fault, FaultPlan};
use super::live::{LiveSubmission, StreamHub};
use super::metrics::{
    summarize_outcomes, LifecycleSummary, Outcome, RequestMetrics, RequestOutcome,
};
use super::supervisor::Supervisor;

/// How deadlines and cancel budgets are measured.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockMode {
    /// Engine-reported elapsed seconds (real serving). Deadline
    /// terminals depend on machine speed — use `Rounds` for
    /// deterministic tests.
    Wall,
    /// One clock unit per scheduling round: `deadline_s`/`cancel_s`
    /// budgets count rounds, bit-for-bit reproducible anywhere.
    Rounds,
}

/// Stall budget for the supervisor [`run_lifecycle_ext`] auto-starts
/// when a fault plan contains stall events but the caller passed no
/// supervisor of its own — stall plans are self-supervising, so a
/// generated chaos plan can never hang a run.
const AUTO_STALL_MS: u64 = 150;

/// Lifecycle policy knobs, layered on top of [`SchedulerConfig`].
#[derive(Debug, Clone, Copy)]
pub struct LifecycleConfig {
    /// Ingress queue bound; submissions past it are rejected with a
    /// backoff hint. 0 = unbounded (no rejection rung).
    pub queue_cap: usize,
    /// Deadline budget applied to requests that carry none
    /// (`Request::deadline_s = INFINITY`). INFINITY = no default.
    pub default_deadline_s: f64,
    pub clock: ClockMode,
    /// Consecutive rounds the runner may sit unable to admit or step
    /// anything (e.g. a pressure window with an empty batch) before it
    /// drains the queue as `Rejected` instead of livelocking.
    pub max_stall_rounds: u32,
    /// Times a queue-full rejection re-enters through exponential
    /// backoff before it becomes terminal. 0 = legacy single-shot
    /// rejection (the default: replay benchmarks count every overflow).
    pub resubmit_max: u32,
    /// Seed for the backoff jitter stream (deterministic; consumed in
    /// submission order on the single round-loop thread).
    pub backoff_seed: u64,
    /// Rounds of queue wait per +1 effective admission priority
    /// (aging). 0 disables aging (pure priority, starvation possible).
    pub aging_rounds: u64,
    /// Crash simulation: halt the loop at the top of this round as if
    /// the instance died — no drain, no terminal states for whatever is
    /// queued or in flight, no exit invariants. 0 = never (the normal
    /// case). Only the sharded router sets this (for the shard a
    /// `kill@R:shard=S` fault dooms); it then attributes the halted
    /// instance's unfinished requests and re-shards them onto the
    /// survivors.
    pub halt_at_round: u64,
}

impl Default for LifecycleConfig {
    fn default() -> Self {
        LifecycleConfig {
            queue_cap: 0,
            default_deadline_s: f64::INFINITY,
            clock: ClockMode::Wall,
            max_stall_rounds: 64,
            resubmit_max: 0,
            backoff_seed: 0x0b0f,
            aging_rounds: 4,
            halt_at_round: 0,
        }
    }
}

/// Effective admission key of a queued request: the slot scan picks the
/// **maximum** `(class, Reverse(seq))` — highest `priority + aging`
/// class first, FIFO (oldest submission sequence) within a class.
///
/// Aging gives a hard starvation bound: a request gains one effective
/// class per `aging_rounds` waited, so after at most
/// `aging_rounds × priority_levels` rounds its class meets the top
/// class and the FIFO tie-break (older seq wins) makes it the unique
/// maximum over every later arrival — property-tested below against a
/// sustained top-priority flood.
pub fn admission_key(
    priority: u8,
    submitted_round: u64,
    now_round: u64,
    aging_rounds: u64,
    seq: u64,
) -> (u64, std::cmp::Reverse<u64>) {
    let waited = now_round.saturating_sub(submitted_round);
    let aged = if aging_rounds > 0 { waited / aging_rounds } else { 0 };
    (u64::from(priority) + aged, std::cmp::Reverse(seq))
}

/// Run-level lifecycle counters (beyond per-request outcomes).
#[derive(Debug, Clone, Copy, Default)]
pub struct LifecycleStats {
    pub rounds: u64,
    /// In-flight requests preempted (released + requeued) for pages.
    pub preemptions: u64,
    /// Rounds admission was throttled for lack of pages.
    pub throttled_rounds: u64,
    pub rejected_queue_full: u64,
    pub rejected_inadmissible: u64,
    /// Queue-full submissions that re-entered through backoff instead
    /// of terminating.
    pub backoff_requeues: u64,
    /// Stalled launches the watchdog killed during this run.
    pub watchdog_kills: u64,
    /// Requests cancelled because their stream consumer disconnected
    /// or fell past the backlog bound.
    pub slow_consumer_cancels: u64,
}

/// Everything a lifecycle run produced.
#[derive(Debug, Clone)]
pub struct LifecycleReport {
    /// One terminal record per submitted request, sorted by id.
    pub outcomes: Vec<RequestOutcome>,
    pub summary: LifecycleSummary,
    pub stats: LifecycleStats,
}

/// Where the lifecycle's requests come from.
pub enum Ingress<'a> {
    /// Legacy replay: the whole trace is offered as fast as the queue
    /// drains (every not-yet-submitted client submits every round).
    Saturating(&'a [Request]),
    /// Open-loop replay: each request submits when the lifecycle clock
    /// reaches `arrival_s * time_scale` — arrivals do not wait for
    /// server capacity, which is what makes goodput-under-load curves
    /// honest. Under `ClockMode::Rounds` the scaled arrival time is in
    /// rounds (deterministic).
    OpenLoop {
        trace: &'a [Request],
        time_scale: f64,
    },
    /// Live serving: submissions arrive over a bounded channel from
    /// other threads (see [`super::live::spawn_ingress`]). Sender
    /// disconnect = graceful drain.
    Live(Receiver<LiveSubmission>),
}

/// A submitted-but-not-yet-running request, with its lifecycle budgets
/// made absolute at submission time.
struct Queued {
    req: Request,
    submitted_s: f64,
    deadline_at: f64,
    cancel_at: f64,
    preemptions: u32,
    /// Monotone submission sequence — FIFO tie-break within a priority
    /// class (preserved across preemption requeues).
    seq: u64,
    /// Round the request entered the queue (aging reference point).
    submitted_round: u64,
}

/// A request waiting out its backoff window before resubmission.
struct BackoffEntry {
    req: Request,
    attempts: u32,
    not_before: f64,
}

/// A request occupying a slot (mid-prefill or decoding).
struct InFlight {
    q: Queued,
    admitted_round: u64,
    prefilling: bool,
    tokens: Vec<u32>,
    first_token_s: f64,
    last_token_s: f64,
    itls: Vec<f64>,
}

fn record(outcomes: &mut HashMap<usize, RequestOutcome>, hub: &mut StreamHub, o: RequestOutcome) {
    hub.finish(o.id, o.outcome, &o.reason);
    let id = o.id;
    let prev = outcomes.insert(id, o);
    debug_assert!(
        prev.is_none(),
        "request {id} reached two terminal states"
    );
}

fn terminal(q: &Queued, outcome: Outcome, reason: String, retry_after_s: f64) -> RequestOutcome {
    RequestOutcome {
        id: q.req.id,
        outcome,
        reason,
        retry_after_s,
        tokens: Vec::new(),
        preemptions: q.preemptions,
        metrics: None,
    }
}

impl InFlight {
    fn into_terminal(self, outcome: Outcome, reason: String, now: f64) -> RequestOutcome {
        let metrics = self.first_token_s.is_finite().then(|| RequestMetrics {
            id: self.q.req.id,
            arrival_s: self.q.submitted_s,
            first_token_s: self.first_token_s,
            done_s: now,
            input_tokens: self.q.req.input_tokens,
            output_tokens: self.tokens.len(),
            itls: self.itls.clone(),
        });
        RequestOutcome {
            id: self.q.req.id,
            outcome,
            reason,
            retry_after_s: 0.0,
            tokens: self.tokens,
            preemptions: self.q.preemptions,
            metrics,
        }
    }
}

/// Drive `trace` through `backend` under the fault-tolerant lifecycle
/// (legacy saturating replay, no streaming, no external supervisor).
/// See the module docs for the state machine; `faults` may be
/// [`FaultPlan::none`] for a healthy run.
pub fn run_lifecycle(
    backend: &mut EngineBackend,
    trace: &[Request],
    sched: SchedulerConfig,
    lc: LifecycleConfig,
    faults: &FaultPlan,
    vocab: usize,
) -> anyhow::Result<LifecycleReport> {
    let mut hub = StreamHub::disabled();
    run_lifecycle_ext(
        backend,
        Ingress::Saturating(trace),
        sched,
        lc,
        faults,
        vocab,
        &mut hub,
        None,
    )
}

/// The full lifecycle entry point: any [`Ingress`], per-request token
/// streaming through `hub`, and optional watchdog supervision. When
/// `supervisor` is `None` but the fault plan schedules stall events,
/// a private supervisor is auto-started so stall plans can never hang
/// the loop.
#[allow(clippy::too_many_arguments)]
pub fn run_lifecycle_ext(
    backend: &mut EngineBackend,
    ingress: Ingress<'_>,
    sched: SchedulerConfig,
    lc: LifecycleConfig,
    faults: &FaultPlan,
    vocab: usize,
    hub: &mut StreamHub,
    supervisor: Option<&Supervisor>,
) -> anyhow::Result<LifecycleReport> {
    backend.configure(&sched);
    let n_slots = backend.n_slots();

    // Ingress state. Replay modes know their terminal count up front;
    // live mode counts what it receives.
    let (mut replay, open_scale, live_rx): (VecDeque<Request>, Option<f64>, Option<Receiver<LiveSubmission>>) =
        match ingress {
            Ingress::Saturating(tr) => (tr.to_vec().into(), None, None),
            Ingress::OpenLoop { trace, time_scale } => {
                (trace.to_vec().into(), Some(time_scale), None)
            }
            Ingress::Live(rx) => (VecDeque::new(), None, Some(rx)),
        };
    let mut live_open = live_rx.is_some();
    let mut expected: usize = replay.len();

    let auto_sup = if supervisor.is_none() && faults.has_stalls() {
        Some(Supervisor::start(AUTO_STALL_MS))
    } else {
        None
    };
    let sup: Option<&Supervisor> = supervisor.or(auto_sup.as_ref());
    let kills0 = sup.map_or(0, Supervisor::kills);

    let mut queue: VecDeque<Queued> = VecDeque::new();
    let mut backoff: Vec<BackoffEntry> = Vec::new();
    let mut brng = Rng::new(lc.backoff_seed | 1);
    let mut slots: Vec<Option<InFlight>> = (0..n_slots).map(|_| None).collect();
    let mut prefill_order: Vec<usize> = Vec::new();
    let mut outcomes: HashMap<usize, RequestOutcome> = HashMap::new();
    let mut cancelled_ids: HashSet<usize> = HashSet::new();
    let mut stats = LifecycleStats::default();
    let mut clock = 0.0f64;
    let mut round: u64 = 0;
    let mut stall = 0u32;
    let mut last_dt = 1e-3f64;
    let mut next_seq: u64 = 0;

    let mut halted = false;
    loop {
        let ingress_done = replay.is_empty() && !live_open;
        if ingress_done
            && backoff.is_empty()
            && queue.is_empty()
            && slots.iter().all(Option::is_none)
        {
            break;
        }
        // Crash simulation (shard kill): the instance dies at the top
        // of this round — nothing queued or in flight reaches a
        // terminal here; the sharded router attributes and re-shards
        // the unfinished work.
        if lc.halt_at_round > 0 && round >= lc.halt_at_round {
            halted = true;
            break;
        }
        stats.rounds = round + 1;
        if let Some(s) = sup {
            s.beat();
        }

        // 1. Fault-plan pressure for this round (0 lifts it).
        backend.set_kv_pressure(faults.pressure_at(round));

        // 2. Point faults: cancels persist (a client cancel also kills
        //    a not-yet-submitted request), storms, panics, and stalls
        //    fire now.
        for ev in faults.events_at(round) {
            match *ev {
                Fault::Cancel { id, .. } => {
                    cancelled_ids.insert(id);
                }
                Fault::DeadlineStorm { every, .. } => {
                    let mut j = 0usize;
                    for s in slots.iter_mut().flatten() {
                        if j % every == 0 {
                            s.q.deadline_at = s.q.deadline_at.min(clock);
                        }
                        j += 1;
                    }
                }
                Fault::WorkerPanic { item, .. } => {
                    crate::exec::runtime::inject_panic_next_launch(item);
                }
                Fault::StalledLaunch { item, .. } => {
                    crate::exec::runtime::inject_stall_next_launch(item);
                }
                Fault::PagePressure { .. } => {}
            }
        }

        // 3. Ingress. Matured backoff entries re-offer FIRST (their
        //    retry_after has been honored; oldest deadline first), then
        //    this round's arrivals.
        let mut offers: Vec<(Request, u32)> = Vec::new();
        if !backoff.is_empty() {
            let (mut matured, rest): (Vec<BackoffEntry>, Vec<BackoffEntry>) = backoff
                .drain(..)
                .partition(|e| e.not_before <= clock);
            backoff = rest;
            matured.sort_by(|a, b| {
                a.not_before
                    .partial_cmp(&b.not_before)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.req.id.cmp(&b.req.id))
            });
            offers.extend(matured.into_iter().map(|e| (e.req, e.attempts)));
        }
        match (&live_rx, open_scale) {
            (Some(rx), _) => {
                if live_open {
                    loop {
                        match rx.try_recv() {
                            Ok(sub) => {
                                expected += 1;
                                if let Some(tx) = sub.stream {
                                    hub.attach(sub.req.id, tx);
                                }
                                offers.push((sub.req, 0));
                            }
                            Err(TryRecvError::Empty) => break,
                            Err(TryRecvError::Disconnected) => {
                                live_open = false;
                                break;
                            }
                        }
                    }
                    // Idle server: park briefly on the channel instead
                    // of spinning; the wait still counts as wall time.
                    if live_open
                        && offers.is_empty()
                        && queue.is_empty()
                        && backoff.is_empty()
                        && slots.iter().all(Option::is_none)
                    {
                        let t0 = Instant::now();
                        match rx.recv_timeout(Duration::from_millis(1)) {
                            Ok(sub) => {
                                expected += 1;
                                if let Some(tx) = sub.stream {
                                    hub.attach(sub.req.id, tx);
                                }
                                offers.push((sub.req, 0));
                            }
                            Err(RecvTimeoutError::Timeout) => {}
                            Err(RecvTimeoutError::Disconnected) => live_open = false,
                        }
                        if lc.clock == ClockMode::Wall {
                            clock += t0.elapsed().as_secs_f64();
                        }
                    }
                }
            }
            (None, Some(scale)) => {
                while replay
                    .front()
                    .is_some_and(|r| r.arrival_s * scale <= clock)
                {
                    offers.push((replay.pop_front().unwrap(), 0));
                }
            }
            (None, None) => {
                while let Some(r) = replay.pop_front() {
                    offers.push((r, 0));
                }
            }
        }

        // Bounded admission of the offers: past the cap, a submission
        // either re-enters through exponential backoff with jitter
        // (honoring its retry hint — the full queue is NOT re-offered
        // every round) or, once its attempts are spent, terminates as
        // Rejected with the hint attached.
        for (r, attempts) in offers {
            if lc.queue_cap > 0 && queue.len() >= lc.queue_cap {
                if lc.resubmit_max > attempts {
                    let unit = match lc.clock {
                        ClockMode::Rounds => 1.0,
                        ClockMode::Wall => last_dt.max(1e-3),
                    };
                    let jitter = 1.0 + brng.f64(); // [1, 2)
                    let delay = unit
                        * (queue.len().max(1) as f64)
                        * (1u64 << attempts.min(16)) as f64
                        * jitter;
                    stats.backoff_requeues += 1;
                    backoff.push(BackoffEntry {
                        req: r,
                        attempts: attempts + 1,
                        not_before: clock + delay,
                    });
                    continue;
                }
                stats.rejected_queue_full += 1;
                let retry = (queue.len() as f64) * last_dt.max(1e-3);
                let q = Queued {
                    req: r,
                    submitted_s: clock,
                    deadline_at: f64::INFINITY,
                    cancel_at: f64::INFINITY,
                    preemptions: 0,
                    seq: next_seq,
                    submitted_round: round,
                };
                next_seq += 1;
                record(
                    &mut outcomes,
                    hub,
                    terminal(
                        &q,
                        Outcome::Rejected,
                        if attempts == 0 {
                            format!("ingress queue full ({} queued)", queue.len())
                        } else {
                            format!(
                                "ingress queue full ({} queued) after {attempts} backoff retries",
                                queue.len()
                            )
                        },
                        retry,
                    ),
                );
                continue;
            }
            let deadline_budget = if r.deadline_s.is_finite() {
                r.deadline_s
            } else {
                lc.default_deadline_s
            };
            queue.push_back(Queued {
                deadline_at: clock + deadline_budget,
                cancel_at: clock + r.cancel_s,
                submitted_s: clock,
                preemptions: 0,
                seq: next_seq,
                submitted_round: round,
                req: r,
            });
            next_seq += 1;
        }

        // 4. Sweeps: cancelled / past-deadline requests terminate now,
        //    queued or in-flight alike; an in-flight death frees its
        //    pages and slot immediately, even mid-prefill.
        let mut keep = VecDeque::with_capacity(queue.len());
        for q in queue.drain(..) {
            if cancelled_ids.contains(&q.req.id) || clock >= q.cancel_at {
                record(
                    &mut outcomes,
                    hub,
                    terminal(&q, Outcome::Cancelled, "cancelled while queued".into(), 0.0),
                );
            } else if clock >= q.deadline_at {
                record(
                    &mut outcomes,
                    hub,
                    terminal(
                        &q,
                        Outcome::DeadlineExceeded,
                        "deadline expired while queued".into(),
                        0.0,
                    ),
                );
            } else {
                keep.push_back(q);
            }
        }
        queue = keep;
        for slot in 0..n_slots {
            let Some(fl) = &slots[slot] else { continue };
            let cancel = cancelled_ids.contains(&fl.q.req.id) || clock >= fl.q.cancel_at;
            let deadline = clock >= fl.q.deadline_at;
            if cancel || deadline {
                let fl = slots[slot].take().unwrap();
                let phase = if fl.prefilling { "prefill" } else { "decode" };
                backend.release(slot);
                prefill_order.retain(|&s| s != slot);
                let (outcome, why) = if cancel {
                    (Outcome::Cancelled, format!("cancelled mid-{phase}"))
                } else {
                    (Outcome::DeadlineExceeded, format!("deadline expired mid-{phase}"))
                };
                record(&mut outcomes, hub, fl.into_terminal(outcome, why, clock));
            }
        }

        // 5. Admission: free slots pull the highest effective-priority
        //    queue entry (priority + aging, FIFO within a class).
        //    Requests that can never complete are rejected; if the
        //    winner's pages aren't available even after evicting parked
        //    prefixes, admission throttles (everyone waits — a smaller
        //    lower-priority request must not starve the winner).
        let mut free: VecDeque<usize> = (0..n_slots).filter(|&i| slots[i].is_none()).collect();
        let mut admitted = 0usize;
        while admitted < sched.max_prefills_per_step && !free.is_empty() && !queue.is_empty() {
            let bi = {
                let mut best: Option<(usize, (u64, std::cmp::Reverse<u64>))> = None;
                for (i, q) in queue.iter().enumerate() {
                    let key = admission_key(
                        q.req.priority,
                        q.submitted_round,
                        round,
                        lc.aging_rounds,
                        q.seq,
                    );
                    if best.as_ref().map_or(true, |&(_, bk)| key > bk) {
                        best = Some((i, key));
                    }
                }
                let Some((i, _)) = best else { break };
                i
            };
            if let Err(why) = backend.admit_check(&queue[bi].req) {
                let q = queue.remove(bi).unwrap();
                stats.rejected_inadmissible += 1;
                record(
                    &mut outcomes,
                    hub,
                    terminal(&q, Outcome::Rejected, why, f64::INFINITY),
                );
                continue;
            }
            let need = backend.admit_pages_needed(queue[bi].req.input_tokens);
            if need > backend.available_kv_pages() && backend.evict_prefixes_for(need) < need {
                stats.throttled_rounds += 1;
                break;
            }
            let q = queue.remove(bi).unwrap();
            let slot = free.pop_front().unwrap();
            let tokens = prompt_tokens(&q.req, vocab);
            backend.begin_prefill(slot, &q.req, &tokens)?;
            prefill_order.push(slot);
            slots[slot] = Some(InFlight {
                q,
                admitted_round: round,
                prefilling: true,
                tokens: Vec::new(),
                first_token_s: f64::NAN,
                last_token_s: clock,
                itls: Vec::new(),
            });
            admitted += 1;
        }

        // 6. Build the round's work and walk the degradation ladder
        //    until its page preflight fits: evict parked prefixes,
        //    then preempt the lowest-priority / latest-admitted
        //    in-flight request (requeued with its original sequence, so
        //    it re-admits ahead of its class; a prefill parks its
        //    whole-page rows so the retry adopts them).
        let mut budget = if sched.prefill_round_tokens == 0 {
            usize::MAX
        } else {
            sched.prefill_round_tokens
        };
        let mut work: Vec<(usize, usize)> = Vec::new();
        for &si in &prefill_order {
            if budget == 0 {
                break;
            }
            let rows = backend.staged_rows(si).min(budget);
            if rows > 0 {
                work.push((si, rows));
                budget -= rows;
            }
        }
        let mut active: Vec<usize> = (0..n_slots)
            .filter(|&i| slots[i].as_ref().is_some_and(|fl| !fl.prefilling))
            .collect();
        loop {
            let need: usize = active
                .iter()
                .map(|&s| backend.decode_pages_needed(s))
                .sum::<usize>()
                + work
                    .iter()
                    .map(|&(s, _)| backend.prefill_pages_bound(s))
                    .sum::<usize>();
            if need <= backend.available_kv_pages() || backend.evict_prefixes_for(need) >= need {
                break;
            }
            let victim = slots
                .iter()
                .enumerate()
                .filter_map(|(i, s)| {
                    s.as_ref()
                        .map(|fl| (i, fl.q.req.priority, fl.admitted_round))
                })
                .min_by_key(|&(_, pri, adm)| (pri, std::cmp::Reverse(adm)))
                .map(|(i, ..)| i);
            let Some(v) = victim else { break };
            let mut fl = slots[v].take().unwrap();
            backend.release(v);
            active.retain(|&s| s != v);
            work.retain(|&(s, _)| s != v);
            prefill_order.retain(|&s| s != v);
            // The retry restarts cleanly: its stream is regenerated
            // from the prompt, so a preempted-then-completed request
            // still matches the fault-free run bit for bit.
            fl.q.preemptions += 1;
            stats.preemptions += 1;
            queue.push_front(fl.q);
        }

        // Idle wall clock: with nothing runnable and nothing queued,
        // jump to the next scheduled event (open-loop arrival or
        // backoff maturity) instead of spinning on a frozen clock.
        if work.is_empty() && active.is_empty() && queue.is_empty() && lc.clock == ClockMode::Wall
        {
            let mut next = f64::INFINITY;
            if let (Some(scale), Some(r)) = (open_scale, replay.front()) {
                next = next.min(r.arrival_s * scale);
            }
            for e in &backoff {
                next = next.min(e.not_before);
            }
            if next.is_finite() && next > clock {
                clock = next;
            }
        }

        // 7. One engine round (if there is anything to run).
        if work.is_empty() && active.is_empty() {
            if !queue.is_empty() {
                stall += 1;
                if stall > lc.max_stall_rounds {
                    // Livelock guard: pressure (or ping-pong) has kept
                    // the engine idle too long — shed the queue rather
                    // than spin forever. Every request still gets a
                    // terminal state.
                    for q in queue.drain(..) {
                        stats.rejected_queue_full += 1;
                        record(
                            &mut outcomes,
                            hub,
                            terminal(
                                &q,
                                Outcome::Rejected,
                                format!(
                                    "admission stalled for {} rounds (KV pressure)",
                                    lc.max_stall_rounds
                                ),
                                last_dt.max(1e-3) * 16.0,
                            ),
                        );
                    }
                }
            }
        } else {
            stall = 0;
            let rep = backend.step(&work, &active)?;
            last_dt = rep.elapsed_s.max(1e-9);
            if lc.clock == ClockMode::Wall {
                clock += rep.elapsed_s;
            }
            let now = if lc.clock == ClockMode::Rounds {
                (round + 1) as f64
            } else {
                clock
            };

            // Consumers whose stream went away (disconnect or slow past
            // the backlog bound) — their requests cancel after the fold.
            let mut gone_streams: HashSet<usize> = HashSet::new();
            for (slot, tok) in rep.finished {
                prefill_order.retain(|&s| s != slot);
                let fl = slots[slot].as_mut().expect("finished an empty slot");
                fl.prefilling = false;
                fl.first_token_s = now;
                fl.last_token_s = now;
                fl.tokens.push(tok);
                if !hub.push_token(fl.q.req.id, tok) {
                    gone_streams.insert(fl.q.req.id);
                }
                if fl.q.req.output_tokens <= 1 {
                    let fl = slots[slot].take().unwrap();
                    backend.release(slot);
                    record(
                        &mut outcomes,
                        hub,
                        fl.into_terminal(Outcome::Completed, String::new(), now),
                    );
                }
            }
            for (slot, tok) in rep.tokens {
                let fl = slots[slot].as_mut().expect("token for an empty slot");
                fl.itls.push(now - fl.last_token_s);
                fl.last_token_s = now;
                fl.tokens.push(tok);
                if !hub.push_token(fl.q.req.id, tok) {
                    gone_streams.insert(fl.q.req.id);
                }
                if fl.tokens.len() >= fl.q.req.output_tokens.max(1) {
                    let fl = slots[slot].take().unwrap();
                    backend.release(slot);
                    record(
                        &mut outcomes,
                        hub,
                        fl.into_terminal(Outcome::Completed, String::new(), now),
                    );
                }
            }
            for (slot, reason) in rep.failed {
                prefill_order.retain(|&s| s != slot);
                let fl = slots[slot].take().expect("failure on an empty slot");
                backend.release(slot);
                record(&mut outcomes, hub, fl.into_terminal(Outcome::Failed, reason, now));
            }
            // Slow-consumer policy: a request whose stream is gone (and
            // which didn't already reach a terminal above) cancels now,
            // freeing its pages — the engine never generates for a
            // client that stopped listening.
            if !gone_streams.is_empty() {
                for slot in 0..n_slots {
                    let Some(fl) = &slots[slot] else { continue };
                    if !gone_streams.contains(&fl.q.req.id) {
                        continue;
                    }
                    let fl = slots[slot].take().unwrap();
                    backend.release(slot);
                    prefill_order.retain(|&s| s != slot);
                    stats.slow_consumer_cancels += 1;
                    record(
                        &mut outcomes,
                        hub,
                        fl.into_terminal(
                            Outcome::Cancelled,
                            "client token stream closed (slow consumer or disconnect)".into(),
                            now,
                        ),
                    );
                }
            }
        }

        round += 1;
        if lc.clock == ClockMode::Rounds {
            clock = round as f64;
        }
    }

    // Graceful drain is complete: leave the backend clean for the next
    // run (no synthetic pressure, no armed faults) and enforce the
    // no-leak invariant — every page is either free or parked under a
    // conversation prefix. Fault-arming state is process-global, so it
    // is cleared even on a simulated crash.
    backend.set_kv_pressure(0);
    crate::exec::runtime::clear_injected_panic();
    crate::exec::runtime::clear_injected_stall();
    stats.watchdog_kills = sup.map_or(0, Supervisor::kills).saturating_sub(kills0);
    drop(auto_sup);

    if !halted {
        let (alloc, free_pages) = backend.kv_pages();
        let parked = backend.prefix_stats().parked_pages;
        anyhow::ensure!(
            alloc == free_pages + parked,
            "no-leak invariant violated on drain: {alloc} allocated vs {free_pages} free + {parked} parked"
        );
        anyhow::ensure!(
            outcomes.len() == expected,
            "terminal-state invariant violated: {} outcomes for {} submitted requests",
            outcomes.len(),
            expected
        );
    }
    let mut outcomes: Vec<RequestOutcome> = outcomes.into_values().collect();
    outcomes.sort_by_key(|o| o.id);
    let summary = summarize_outcomes(&outcomes);
    Ok(LifecycleReport {
        summary,
        stats,
        outcomes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Parallelism;
    use crate::serve::engine_backend::EngineModel;
    use crate::tracegen::{generate, TraceConfig};

    fn trace(n: usize) -> Vec<Request> {
        generate(&TraceConfig {
            n_requests: n,
            rate: 100.0,
            input_mu: 3.5,
            input_sigma: 0.5,
            mean_output: 5.0,
            max_input: 100,
            max_output: 8,
            ..Default::default()
        })
    }

    fn backend(threads: usize) -> EngineBackend {
        EngineBackend::new(
            EngineModel::tiny(),
            4,
            1024,
            Parallelism::with_threads(threads),
        )
    }

    fn sched() -> SchedulerConfig {
        SchedulerConfig {
            prefill_chunk_tokens: 64,
            prefill_round_tokens: 128,
            ..Default::default()
        }
    }

    fn assert_no_leak(b: &mut EngineBackend) {
        let (alloc, free) = b.kv_pages();
        assert_eq!(
            alloc,
            free + b.prefix_stats().parked_pages,
            "pages leaked beyond the parked prefixes"
        );
        b.clear_prefix_cache();
        let (alloc, free) = b.kv_pages();
        assert_eq!(alloc, free, "pages leaked after cache clear");
    }

    #[test]
    fn healthy_lifecycle_completes_everything_bit_identically_across_threads() {
        let tr = trace(10);
        let mut streams: Vec<Vec<Vec<u32>>> = Vec::new();
        for threads in [1, 2, 4] {
            let mut b = backend(threads);
            let vocab = b.model.vocab;
            let rep = run_lifecycle(
                &mut b,
                &tr,
                sched(),
                LifecycleConfig {
                    clock: ClockMode::Rounds,
                    ..Default::default()
                },
                &FaultPlan::none(),
                vocab,
            )
            .unwrap();
            assert_eq!(rep.summary.completed, tr.len(), "threads={threads}");
            assert_eq!(rep.summary.total(), tr.len());
            for (o, r) in rep.outcomes.iter().zip(&tr) {
                assert_eq!(o.id, r.id);
                assert_eq!(o.outcome, Outcome::Completed);
                assert_eq!(o.tokens.len(), r.output_tokens.max(1), "req {}", r.id);
            }
            assert!(rep.summary.goodput_tokens_per_s > 0.0);
            streams.push(rep.outcomes.into_iter().map(|o| o.tokens).collect());
            assert_no_leak(&mut b);
        }
        assert_eq!(streams[0], streams[1], "threads must not change tokens");
        assert_eq!(streams[0], streams[2], "threads must not change tokens");
    }

    #[test]
    fn bounded_ingress_rejects_overflow_with_backoff() {
        let tr = trace(8);
        let mut b = backend(1);
        let vocab = b.model.vocab;
        let rep = run_lifecycle(
            &mut b,
            &tr,
            sched(),
            LifecycleConfig {
                queue_cap: 2,
                clock: ClockMode::Rounds,
                ..Default::default()
            },
            &FaultPlan::none(),
            vocab,
        )
        .unwrap();
        assert_eq!(rep.summary.total(), tr.len());
        assert!(rep.summary.rejected > 0, "overflow must reject");
        assert_eq!(rep.summary.completed + rep.summary.rejected, tr.len());
        for o in rep.outcomes.iter().filter(|o| o.outcome == Outcome::Rejected) {
            assert!(o.retry_after_s > 0.0, "rejection must carry a backoff hint");
            assert!(o.reason.contains("queue full"), "{}", o.reason);
        }
        assert_eq!(rep.stats.rejected_queue_full as usize, rep.summary.rejected);
        assert_eq!(rep.stats.backoff_requeues, 0, "resubmit_max=0 is single-shot");
        assert_no_leak(&mut b);
    }

    #[test]
    fn backoff_resubmission_honors_retry_after_and_recovers_overflow() {
        let tr = trace(8);
        let run = |resubmit_max: u32| {
            let mut b = backend(1);
            let vocab = b.model.vocab;
            let rep = run_lifecycle(
                &mut b,
                &tr,
                sched(),
                LifecycleConfig {
                    queue_cap: 2,
                    resubmit_max,
                    clock: ClockMode::Rounds,
                    ..Default::default()
                },
                &FaultPlan::none(),
                vocab,
            )
            .unwrap();
            assert_eq!(rep.summary.total(), tr.len());
            assert_no_leak(&mut b);
            rep
        };
        let single = run(0);
        let retried = run(4);
        assert!(retried.stats.backoff_requeues > 0, "backoff must engage");
        // Each overflowed request waits out its window instead of being
        // re-offered every round: requeues are bounded by attempts.
        assert!(
            retried.stats.backoff_requeues <= tr.len() as u64 * 4,
            "full queue must not be hammered every round ({} requeues)",
            retried.stats.backoff_requeues
        );
        // Honoring retry_after converts rejections into completions.
        assert!(
            retried.summary.completed > single.summary.completed,
            "backoff must recover overflow ({} vs {})",
            retried.summary.completed,
            single.summary.completed
        );
        for o in retried
            .outcomes
            .iter()
            .filter(|o| o.outcome == Outcome::Rejected)
        {
            assert!(
                o.reason.contains("backoff retries"),
                "terminal rejection must only happen after retries: {}",
                o.reason
            );
        }
        // Deterministic: the jitter stream is seeded.
        let again = run(4);
        assert_eq!(
            retried
                .outcomes
                .iter()
                .map(|o| (o.id, o.outcome, o.tokens.clone()))
                .collect::<Vec<_>>(),
            again
                .outcomes
                .iter()
                .map(|o| (o.id, o.outcome, o.tokens.clone()))
                .collect::<Vec<_>>()
        );
    }

    #[test]
    fn priority_admission_orders_by_priority_and_ages_out_starvation() {
        // Three same-arrival requests, priorities 0/1/2, one slot: the
        // highest priority must reach its first token first, the lowest
        // last — and still complete (aging forbids starvation).
        let mut tr = trace(3);
        for (i, r) in tr.iter_mut().enumerate() {
            r.priority = i as u8; // ids 0,1,2 -> priorities 0,1,2
            r.arrival_s = 0.0;
        }
        let mut b = EngineBackend::new(
            EngineModel::tiny(),
            1,
            1024,
            Parallelism::with_threads(1),
        );
        let vocab = b.model.vocab;
        let rep = run_lifecycle(
            &mut b,
            &tr,
            sched(),
            LifecycleConfig {
                clock: ClockMode::Rounds,
                aging_rounds: 1000, // effectively pure priority here
                ..Default::default()
            },
            &FaultPlan::none(),
            vocab,
        )
        .unwrap();
        assert_eq!(rep.summary.completed, 3, "aging must prevent starvation");
        let ttft = |id: usize| {
            rep.outcomes[id]
                .metrics
                .as_ref()
                .expect("completed request has metrics")
                .first_token_s
        };
        assert!(ttft(2) < ttft(1), "priority 2 admits before 1");
        assert!(ttft(1) < ttft(0), "priority 1 admits before 0");
        assert_no_leak(&mut b);
    }

    #[test]
    fn default_deadline_expires_slow_requests_deterministically() {
        let tr = trace(8);
        let run = |threads: usize| {
            let mut b = backend(threads);
            let vocab = b.model.vocab;
            let rep = run_lifecycle(
                &mut b,
                &tr,
                sched(),
                LifecycleConfig {
                    default_deadline_s: 6.0, // rounds
                    clock: ClockMode::Rounds,
                    ..Default::default()
                },
                &FaultPlan::none(),
                vocab,
            )
            .unwrap();
            assert_eq!(rep.summary.total(), tr.len());
            assert!(
                rep.summary.deadline_exceeded > 0,
                "a 6-round budget must expire some of 8 queued requests"
            );
            assert_no_leak(&mut b);
            rep.outcomes
                .iter()
                .map(|o| (o.outcome, o.tokens.clone()))
                .collect::<Vec<_>>()
        };
        // Rounds-mode deadlines are thread-count independent.
        assert_eq!(run(1), run(2));
    }

    #[test]
    fn open_loop_ingress_completes_and_matches_across_threads() {
        let tr = trace(8);
        let run = |threads: usize| {
            let mut b = backend(threads);
            let vocab = b.model.vocab;
            let mut hub = StreamHub::disabled();
            let rep = run_lifecycle_ext(
                &mut b,
                // Spread arrivals over the first ~12 rounds.
                Ingress::OpenLoop {
                    trace: &tr,
                    time_scale: 12.0 / tr.last().unwrap().arrival_s.max(1e-9),
                },
                sched(),
                LifecycleConfig {
                    clock: ClockMode::Rounds,
                    ..Default::default()
                },
                &FaultPlan::none(),
                vocab,
                &mut hub,
                None,
            )
            .unwrap();
            assert_eq!(rep.summary.completed, tr.len());
            assert_no_leak(&mut b);
            rep.outcomes
                .into_iter()
                .map(|o| (o.id, o.tokens))
                .collect::<Vec<_>>()
        };
        let one = run(1);
        assert_eq!(one, run(2));
        assert_eq!(one, run(4));
    }

    #[test]
    fn streaming_delivers_every_token_and_cancels_slow_consumers() {
        use crate::serve::live::StreamEvent;
        let tr = trace(6);
        let mut b = backend(1);
        let vocab = b.model.vocab;
        let mut hub = StreamHub::new(0); // zero backlog tolerance
        // Request 0 gets a 1-slot channel nobody reads (slow consumer);
        // the others get roomy channels read after the run.
        let mut rxs = Vec::new();
        for r in &tr {
            let cap = if r.id == 0 { 1 } else { 64 };
            rxs.push(hub.open(r.id, cap));
        }
        let rep = run_lifecycle_ext(
            &mut b,
            Ingress::Saturating(&tr),
            sched(),
            LifecycleConfig {
                clock: ClockMode::Rounds,
                ..Default::default()
            },
            &FaultPlan::none(),
            vocab,
            &mut hub,
            None,
        )
        .unwrap();
        assert_eq!(rep.summary.total(), tr.len());
        let slow = &rep.outcomes[0];
        if tr[0].output_tokens > 2 {
            assert_eq!(slow.outcome, Outcome::Cancelled, "{}", slow.reason);
            assert!(slow.reason.contains("stream"), "{}", slow.reason);
            assert!(rep.stats.slow_consumer_cancels >= 1);
        }
        for (o, rx) in rep.outcomes.iter().zip(rxs).skip(1) {
            let events: Vec<StreamEvent> = rx.try_iter().collect();
            let toks: Vec<u32> = events
                .iter()
                .filter_map(|e| match e {
                    StreamEvent::Token(t) => Some(*t),
                    StreamEvent::Done { .. } => None,
                })
                .collect();
            assert_eq!(toks, o.tokens, "stream must carry the outcome's tokens");
            assert!(
                matches!(events.last(), Some(StreamEvent::Done { outcome, .. }) if *outcome == o.outcome),
                "stream must end with the terminal outcome"
            );
        }
        assert_no_leak(&mut b);
    }

    #[test]
    fn live_ingress_serves_submissions_and_drains_gracefully() {
        use crate::serve::live::spawn_ingress;
        let tr = trace(6);
        let mut b = backend(2);
        let vocab = b.model.vocab;
        let mut hub = StreamHub::new(256);
        let subs = tr.iter().map(|r| (r.clone(), None)).collect();
        // Compress arrivals hard so the test is fast; the channel bound
        // of 2 exercises ingress backpressure.
        let (rx, handle) = spawn_ingress(subs, 1e-3, 2);
        let rep = run_lifecycle_ext(
            &mut b,
            Ingress::Live(rx),
            sched(),
            LifecycleConfig::default(), // Wall clock: a real server
            &FaultPlan::none(),
            vocab,
            &mut hub,
            None,
        )
        .unwrap();
        assert_eq!(handle.join().unwrap(), tr.len());
        assert_eq!(rep.summary.total(), tr.len(), "every submission terminal");
        assert_eq!(rep.summary.completed, tr.len());
        assert_no_leak(&mut b);
    }

    #[test]
    fn halt_at_round_crashes_mid_trace_without_draining() {
        // Crash simulation: the loop stops dead at the halt round. The
        // run returns (no error, no exit invariants) with only the
        // requests that finished *before* the crash — what the sharded
        // router needs to attribute the rest.
        let tr = trace(8);
        let mut full = backend(1);
        let vocab = full.model.vocab;
        let lc = LifecycleConfig {
            clock: ClockMode::Rounds,
            ..Default::default()
        };
        let complete =
            run_lifecycle(&mut full, &tr, sched(), lc, &FaultPlan::none(), vocab).unwrap();
        assert_eq!(complete.summary.completed, tr.len());
        let mut b = backend(1);
        let halted = run_lifecycle(
            &mut b,
            &tr,
            sched(),
            LifecycleConfig {
                halt_at_round: 3,
                ..lc
            },
            &FaultPlan::none(),
            vocab,
        )
        .unwrap();
        assert!(
            halted.outcomes.len() < tr.len(),
            "a round-3 crash must strand some of 8 requests"
        );
        // Whatever did finish before the crash matches the healthy run
        // bit for bit (the crash happens *between* rounds).
        for o in &halted.outcomes {
            assert_eq!(o.outcome, Outcome::Completed);
            assert_eq!(o.tokens, complete.outcomes[o.id].tokens, "req {}", o.id);
        }
    }

    /// Satellite: the aging starvation bound. A queued request of any
    /// priority class, under a sustained flood of fresh top-priority
    /// arrivals with one admission per round, must admit within
    /// `aging_rounds × priority_levels` rounds of submission: after
    /// `aging_rounds × (top − p)` rounds its effective class reaches
    /// the top class, where the FIFO tie-break (oldest seq first)
    /// makes it beat every newer flood entry.
    #[test]
    fn aging_bounds_starvation_under_priority_flood() {
        struct Q {
            priority: u8,
            submitted_round: u64,
            seq: u64,
        }
        for (aging_rounds, levels) in [(4u64, 4u8), (1, 8), (6, 2), (4, 1)] {
            let bound = aging_rounds * u64::from(levels);
            let top = levels - 1;
            for victim_priority in 0..levels {
                // The victim is queued at round 0, the flood starts the
                // same round and never lets up.
                let mut queue = vec![Q {
                    priority: victim_priority,
                    submitted_round: 0,
                    seq: 0,
                }];
                let mut seq = 1u64;
                let mut admitted_at: Option<u64> = None;
                for round in 0..=bound {
                    queue.push(Q {
                        priority: top,
                        submitted_round: round,
                        seq,
                    });
                    seq += 1;
                    // One admission per round: scan for the max key
                    // exactly the way the lifecycle's admission loop
                    // does.
                    let bi = queue
                        .iter()
                        .enumerate()
                        .max_by_key(|(_, q)| {
                            admission_key(
                                q.priority,
                                q.submitted_round,
                                round,
                                aging_rounds,
                                q.seq,
                            )
                        })
                        .map(|(i, _)| i)
                        .unwrap();
                    let q = queue.remove(bi);
                    if q.seq == 0 {
                        admitted_at = Some(round);
                        break;
                    }
                }
                let waited = admitted_at.unwrap_or_else(|| {
                    panic!(
                        "aging={aging_rounds} levels={levels}: priority-{victim_priority} \
                         victim starved past {bound} rounds"
                    )
                });
                assert!(
                    waited <= bound,
                    "aging={aging_rounds} levels={levels}: priority-{victim_priority} \
                     victim waited {waited} > {bound}"
                );
                // The bound is tight: the victim admits exactly when its
                // aged class first reaches the top class.
                assert_eq!(
                    waited,
                    aging_rounds * u64::from(top - victim_priority),
                    "aging={aging_rounds} levels={levels} victim={victim_priority}"
                );
            }
        }
    }
}
