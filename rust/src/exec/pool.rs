//! Scratch-buffer pool for the tiled executor's inner loops.
//!
//! The k-tile loop of a flash pipeline touches a handful of tile-sized
//! buffers per iteration (gathered operand tiles, pointwise temps, the
//! PV accumulator). Allocating fresh `Vec<f32>`s for each of them — as
//! the original executor did via `Tensor::zeros` — puts the allocator on
//! the hot path. The pool keeps retired buffers (with their capacity)
//! and hands them back for the next tile, so steady-state execution of a
//! pipeline performs no heap allocation in the k loop.
//!
//! Each worker thread of the parallel engine owns its own pool; nothing
//! here is synchronized.

use crate::exec::tensor::Tensor;

/// Retired buffers kept for reuse. Bounded so pathological plans cannot
/// hold unbounded memory captive. Sized so a whole block's memo
/// teardown (score chain × k-tiles) fits without dropping buffers.
const MAX_POOLED: usize = 256;

#[derive(Debug, Default)]
pub struct TilePool {
    free: Vec<Vec<f32>>,
}

impl TilePool {
    pub fn new() -> Self {
        TilePool { free: Vec::new() }
    }

    /// An empty buffer with capacity for at least `n` elements. The
    /// caller fills it with `extend`/`push` (no redundant zero-fill).
    ///
    /// Best-fit: the smallest retired buffer whose capacity already
    /// covers `n` (a linear scan over the bounded free list beats a
    /// realloc); otherwise the largest buffer, so the regrow is minimal.
    /// The pool mixes scalar-sized and tile-sized retirements, so a
    /// size-blind LIFO pop would routinely reallocate.
    pub fn take(&mut self, n: usize) -> Vec<f32> {
        let mut best: Option<usize> = None;
        let mut largest: Option<usize> = None;
        for (i, b) in self.free.iter().enumerate() {
            let cap = b.capacity();
            if cap >= n && best.map_or(true, |j| cap < self.free[j].capacity()) {
                best = Some(i);
            }
            if largest.map_or(true, |j| cap > self.free[j].capacity()) {
                largest = Some(i);
            }
        }
        match best.or(largest) {
            Some(i) => {
                let mut buf = self.free.swap_remove(i);
                buf.clear();
                buf.reserve(n);
                buf
            }
            None => Vec::with_capacity(n),
        }
    }

    /// A zero-filled buffer of length `n` (for accumulators).
    pub fn take_zeroed(&mut self, n: usize) -> Vec<f32> {
        let mut buf = self.take(n);
        buf.resize(n, 0.0);
        buf
    }

    /// Return a buffer's storage to the pool.
    pub fn put(&mut self, buf: Vec<f32>) {
        if self.free.len() < MAX_POOLED && buf.capacity() > 0 {
            self.free.push(buf);
        }
    }

    /// Retire a whole tensor, keeping its storage.
    pub fn recycle(&mut self, t: Tensor) {
        self.put(t.data);
    }

    /// Retire a shared (copy-on-write) tensor: reclaims the storage only
    /// when this was the last reference — the executor's memo may still
    /// hold the same allocation.
    pub fn recycle_shared(&mut self, t: std::rc::Rc<Tensor>) {
        if let Ok(t) = std::rc::Rc::try_unwrap(t) {
            self.put(t.data);
        }
    }

    /// A copy of `t` backed by pooled storage (the executor's memo keeps
    /// copies of tile values; this keeps those copies allocation-free).
    pub fn duplicate(&mut self, t: &Tensor) -> Tensor {
        let mut buf = self.take(t.data.len());
        buf.extend_from_slice(&t.data);
        Tensor::from_vec(&t.shape, buf)
    }

    /// Number of buffers currently pooled (for tests/diagnostics).
    pub fn idle_buffers(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_reuses_recycled_capacity() {
        let mut pool = TilePool::new();
        let mut a = pool.take(128);
        a.resize(128, 1.0);
        let cap = a.capacity();
        let ptr = a.as_ptr();
        pool.put(a);
        assert_eq!(pool.idle_buffers(), 1);
        let b = pool.take(64);
        assert!(b.is_empty());
        assert_eq!(b.capacity(), cap);
        assert_eq!(b.as_ptr(), ptr, "storage must be reused");
        assert_eq!(pool.idle_buffers(), 0);
    }

    #[test]
    fn take_zeroed_clears_stale_contents() {
        let mut pool = TilePool::new();
        let mut a = pool.take(8);
        a.extend_from_slice(&[9.0; 8]);
        pool.put(a);
        let b = pool.take_zeroed(8);
        assert_eq!(b, vec![0.0; 8]);
    }

    #[test]
    fn duplicate_matches_source() {
        let mut pool = TilePool::new();
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let d = pool.duplicate(&t);
        assert_eq!(d, t);
    }

    #[test]
    fn recycle_shared_reclaims_only_last_reference() {
        use std::rc::Rc;
        let mut pool = TilePool::new();
        let t = Rc::new(Tensor::from_vec(&[4], vec![1., 2., 3., 4.]));
        let t2 = t.clone();
        pool.recycle_shared(t2); // a second handle is live: keep the data
        assert_eq!(pool.idle_buffers(), 0);
        pool.recycle_shared(t); // last reference: storage reclaimed
        assert_eq!(pool.idle_buffers(), 1);
    }

    #[test]
    fn pool_is_bounded() {
        let mut pool = TilePool::new();
        for _ in 0..(MAX_POOLED + 10) {
            pool.put(vec![0.0; 4]);
        }
        assert_eq!(pool.idle_buffers(), MAX_POOLED);
    }
}
