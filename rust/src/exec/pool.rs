//! Scratch-buffer pool for the tiled executor's inner loops.
//!
//! The k-tile loop of a flash pipeline touches a handful of tile-sized
//! buffers per iteration (gathered operand tiles, pointwise temps, the
//! PV accumulator). Allocating fresh `Vec<f32>`s for each of them — as
//! the original executor did via `Tensor::zeros` — puts the allocator on
//! the hot path. The pool keeps retired buffers (with their capacity)
//! and hands them back for the next tile, so steady-state execution of a
//! pipeline performs no heap allocation in the k loop.
//!
//! Each worker thread of the parallel engine owns its own pool; nothing
//! here is synchronized.

use std::collections::HashMap;
use std::rc::Rc;

use crate::exec::simd::{self, PackedB};
use crate::exec::tensor::Tensor;

/// Retired buffers kept for reuse. Bounded so pathological plans cannot
/// hold unbounded memory captive. Sized so a whole block's memo
/// teardown (score chain × k-tiles) fits without dropping buffers.
const MAX_POOLED: usize = 256;

/// Packed-panel cache bound: at most this many distinct (plan, node,
/// region) K-tile panels are held per worker before the cache resets.
/// Eviction is pure perf — panels are derived data, so correctness and
/// the bit-identity gates never depend on hits.
const MAX_PANELS: usize = 128;

/// Identity of a packed NT panel: (plan tag, node id, operand region).
/// Worker pools are **persistent** (they live in the runtime's
/// per-thread storage and outlive launches), so the plan tag embeds a
/// process-unique launch id — see `PipelineRun::tag` — and a key can
/// never collide with a later launch's panels. Stale-launch entries
/// linger harmlessly until the [`MAX_PANELS`] bound evicts them.
pub type PanelKey = (u64, u32, Vec<(usize, usize)>);

#[derive(Debug, Default)]
pub struct TilePool {
    free: Vec<Vec<f32>>,
    panels: HashMap<PanelKey, Rc<PackedB>>,
}

impl TilePool {
    pub fn new() -> Self {
        TilePool::default()
    }

    /// The packed panels for NT operand tile `b[n × k]` under `key`,
    /// packing (once) on miss — this is how K tiles are packed once per
    /// k-tile rather than once per (q-tile, k-tile) pair. The caller
    /// still gathers (and touch-logs) the raw tile exactly as before,
    /// so HBM/L2 counters are byte-identical with the cache cold or
    /// warm, at any thread count.
    pub fn packed_nt_panel(&mut self, key: PanelKey, b: &[f32], n: usize, k: usize) -> Rc<PackedB> {
        if let Some(p) = self.panels.get(&key) {
            if p.n == n && p.k == k {
                return p.clone();
            }
        }
        if self.panels.len() >= MAX_PANELS {
            self.clear_panels();
        }
        let nr = simd::panel_width(simd::level());
        let buf = self.take((n + nr - 1) / nr * k * nr);
        let p = Rc::new(PackedB::pack_with(simd::level(), b, n, k, buf));
        self.panels.insert(key, p.clone());
        p
    }

    /// Drop all cached panels, retiring sole-owned storage into the
    /// free list.
    pub fn clear_panels(&mut self) {
        for (_, p) in self.panels.drain() {
            if let Ok(p) = Rc::try_unwrap(p) {
                self.free_put(p.data);
            }
        }
    }

    /// Number of cached panels (tests/diagnostics).
    pub fn cached_panels(&self) -> usize {
        self.panels.len()
    }

    fn free_put(&mut self, buf: Vec<f32>) {
        if self.free.len() < MAX_POOLED && buf.capacity() > 0 {
            self.free.push(buf);
        }
    }

    /// An empty buffer with capacity for at least `n` elements. The
    /// caller fills it with `extend`/`push` (no redundant zero-fill).
    ///
    /// Best-fit: the smallest retired buffer whose capacity already
    /// covers `n` (a linear scan over the bounded free list beats a
    /// realloc); otherwise the largest buffer, so the regrow is minimal.
    /// The pool mixes scalar-sized and tile-sized retirements, so a
    /// size-blind LIFO pop would routinely reallocate.
    pub fn take(&mut self, n: usize) -> Vec<f32> {
        let mut best: Option<usize> = None;
        let mut largest: Option<usize> = None;
        for (i, b) in self.free.iter().enumerate() {
            let cap = b.capacity();
            if cap >= n && best.map_or(true, |j| cap < self.free[j].capacity()) {
                best = Some(i);
            }
            if largest.map_or(true, |j| cap > self.free[j].capacity()) {
                largest = Some(i);
            }
        }
        match best.or(largest) {
            Some(i) => {
                let mut buf = self.free.swap_remove(i);
                buf.clear();
                buf.reserve(n);
                buf
            }
            None => Vec::with_capacity(n),
        }
    }

    /// A zero-filled buffer of length `n` (for accumulators).
    pub fn take_zeroed(&mut self, n: usize) -> Vec<f32> {
        let mut buf = self.take(n);
        buf.resize(n, 0.0);
        buf
    }

    /// Return a buffer's storage to the pool.
    pub fn put(&mut self, buf: Vec<f32>) {
        self.free_put(buf);
    }

    /// Retire a whole tensor, keeping its storage.
    pub fn recycle(&mut self, t: Tensor) {
        self.put(t.data);
    }

    /// Retire a shared (copy-on-write) tensor: reclaims the storage only
    /// when this was the last reference — the executor's memo may still
    /// hold the same allocation.
    pub fn recycle_shared(&mut self, t: std::rc::Rc<Tensor>) {
        if let Ok(t) = std::rc::Rc::try_unwrap(t) {
            self.put(t.data);
        }
    }

    /// A copy of `t` backed by pooled storage (the executor's memo keeps
    /// copies of tile values; this keeps those copies allocation-free).
    pub fn duplicate(&mut self, t: &Tensor) -> Tensor {
        let mut buf = self.take(t.data.len());
        buf.extend_from_slice(&t.data);
        Tensor::from_vec(&t.shape, buf)
    }

    /// Number of buffers currently pooled (for tests/diagnostics).
    pub fn idle_buffers(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_reuses_recycled_capacity() {
        let mut pool = TilePool::new();
        let mut a = pool.take(128);
        a.resize(128, 1.0);
        let cap = a.capacity();
        let ptr = a.as_ptr();
        pool.put(a);
        assert_eq!(pool.idle_buffers(), 1);
        let b = pool.take(64);
        assert!(b.is_empty());
        assert_eq!(b.capacity(), cap);
        assert_eq!(b.as_ptr(), ptr, "storage must be reused");
        assert_eq!(pool.idle_buffers(), 0);
    }

    #[test]
    fn take_zeroed_clears_stale_contents() {
        let mut pool = TilePool::new();
        let mut a = pool.take(8);
        a.extend_from_slice(&[9.0; 8]);
        pool.put(a);
        let b = pool.take_zeroed(8);
        assert_eq!(b, vec![0.0; 8]);
    }

    #[test]
    fn duplicate_matches_source() {
        let mut pool = TilePool::new();
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let d = pool.duplicate(&t);
        assert_eq!(d, t);
    }

    #[test]
    fn recycle_shared_reclaims_only_last_reference() {
        use std::rc::Rc;
        let mut pool = TilePool::new();
        let t = Rc::new(Tensor::from_vec(&[4], vec![1., 2., 3., 4.]));
        let t2 = t.clone();
        pool.recycle_shared(t2); // a second handle is live: keep the data
        assert_eq!(pool.idle_buffers(), 0);
        pool.recycle_shared(t); // last reference: storage reclaimed
        assert_eq!(pool.idle_buffers(), 1);
    }

    #[test]
    fn pool_is_bounded() {
        let mut pool = TilePool::new();
        for _ in 0..(MAX_POOLED + 10) {
            pool.put(vec![0.0; 4]);
        }
        assert_eq!(pool.idle_buffers(), MAX_POOLED);
    }

    #[test]
    fn panel_cache_packs_once_per_key() {
        let mut pool = TilePool::new();
        let (n, k) = (6, 4);
        let b: Vec<f32> = (0..n * k).map(|i| i as f32).collect();
        let key: PanelKey = (0, 42, vec![(0, n), (0, k)]);
        let p1 = pool.packed_nt_panel(key.clone(), &b, n, k);
        let p2 = pool.packed_nt_panel(key, &b, n, k);
        assert!(Rc::ptr_eq(&p1, &p2), "second lookup must hit the cache");
        assert_eq!(pool.cached_panels(), 1);
        // a different q-tile's key for the same node misses
        let p3 = pool.packed_nt_panel((0, 42, vec![(1, n), (0, k)]), &b, n, k);
        assert!(!Rc::ptr_eq(&p1, &p3));
        assert_eq!(pool.cached_panels(), 2);
        drop((p1, p2, p3));
        pool.clear_panels();
        assert_eq!(pool.cached_panels(), 0);
        assert!(pool.idle_buffers() >= 1, "panel storage retires to the free list");
    }

    #[test]
    fn panel_cache_is_bounded() {
        let mut pool = TilePool::new();
        let b = vec![1.0f32; 8];
        for i in 0..(MAX_PANELS + 5) {
            let _ = pool.packed_nt_panel((0, i as u32, vec![]), &b, 2, 4);
        }
        assert!(pool.cached_panels() <= MAX_PANELS + 1);
    }
}
