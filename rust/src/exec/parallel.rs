//! Thread scheduling for the data-parallel execution engine.
//!
//! The tiled executor's launch grid — one program instance per
//! (batch, head, q-tile) block of [`crate::grid::LogicalGrid`] — is
//! embarrassingly parallel: blocks share only read-only state. This
//! module distributes block ids over a scoped thread pool with a shared
//! atomic cursor (dynamic load balancing: causal/windowed variants give
//! q-tiles very different amounts of unmasked work), then returns the
//! results **in block order** so the caller's merge is deterministic and
//! bit-identical to a sequential run.
//!
//! Workers claim the cursor in small chunks ([`CLAIM_CHUNK`] blocks per
//! CAS) to cut contention on fine-grained grids — one `fetch_add` per
//! block made the cursor line the hottest word in the process on
//! many-core hosts. The final `workers · CLAIM_CHUNK` items degrade to
//! single-block claims so the tail stays load-balanced; either way each
//! index is claimed exactly once and results are reassembled in index
//! order, so the deterministic block-order merge is untouched.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Blocks handed out per cursor claim away from the tail.
const CLAIM_CHUNK: usize = 4;

/// How many OS threads the execution engine may use. `num_threads == 1`
/// is the exact sequential path (no threads are spawned).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    pub num_threads: usize,
}

impl Parallelism {
    /// Single-threaded execution (the default: bit-stable with the
    /// pre-parallel engine, and what unit tests compare against).
    pub fn sequential() -> Self {
        Parallelism { num_threads: 1 }
    }

    /// One thread per available hardware thread.
    pub fn available() -> Self {
        Parallelism {
            num_threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        }
    }

    /// Exactly `n` threads (clamped to at least 1).
    pub fn with_threads(n: usize) -> Self {
        Parallelism {
            num_threads: n.max(1),
        }
    }

    /// `FLASHLIGHT_THREADS=N` override, else all available cores.
    pub fn from_env() -> Self {
        match std::env::var("FLASHLIGHT_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
        {
            Some(n) => Self::with_threads(n),
            None => Self::available(),
        }
    }

    pub fn is_parallel(&self) -> bool {
        self.num_threads > 1
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Self::sequential()
    }
}

/// Map `f` over `0..n`, giving each worker thread its own scratch state
/// built by `init` (reused across all items that worker claims — this is
/// how the engine keeps per-thread tile pools warm). Items are claimed
/// dynamically from a shared cursor; the returned Vec is in item order
/// regardless of which thread computed what.
///
/// Worker panics propagate to the caller.
pub fn parallel_map_with<S, T, I, F>(par: &Parallelism, n: usize, init: I, f: F) -> Vec<T>
where
    S: Send,
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let workers = par.num_threads.min(n).max(1);
    if workers == 1 {
        let mut state = init();
        return (0..n).map(|i| f(&mut state, i)).collect();
    }

    let cursor = AtomicUsize::new(0);
    // Chunked claims degrade to one block each inside the tail window,
    // so no worker sits on a multi-block claim while others idle.
    let tail_start = n.saturating_sub(workers * CLAIM_CHUNK);
    let mut shards: Vec<Vec<(usize, T)>> = Vec::with_capacity(workers);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            handles.push(scope.spawn(|| {
                let mut state = init();
                let mut local: Vec<(usize, T)> = Vec::new();
                loop {
                    let start = cursor.load(Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    // Clamp chunks at the tail boundary so the last
                    // `workers * CLAIM_CHUNK` items go out one by one.
                    let take = if start < tail_start {
                        CLAIM_CHUNK.min(tail_start - start)
                    } else {
                        1
                    };
                    if cursor
                        .compare_exchange_weak(
                            start,
                            start + take,
                            Ordering::Relaxed,
                            Ordering::Relaxed,
                        )
                        .is_err()
                    {
                        continue; // lost the race (or spurious) — retry
                    }
                    for i in start..start + take {
                        local.push((i, f(&mut state, i)));
                    }
                }
                local
            }));
        }
        for h in handles {
            match h.join() {
                Ok(shard) => shards.push(shard),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });

    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, v) in shards.into_iter().flatten() {
        debug_assert!(out[i].is_none(), "item {i} computed twice");
        out[i] = Some(v);
    }
    out.into_iter()
        .map(|o| o.expect("work item never claimed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_and_parallel_agree_in_order() {
        let f = |_s: &mut (), i: usize| i * i;
        let seq = parallel_map_with(&Parallelism::sequential(), 100, || (), f);
        for threads in [2, 3, 8, 64] {
            let par = parallel_map_with(&Parallelism::with_threads(threads), 100, || (), f);
            assert_eq!(seq, par, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single_item_grids() {
        let par = Parallelism::with_threads(4);
        let none: Vec<usize> = parallel_map_with(&par, 0, || (), |_, i| i);
        assert!(none.is_empty());
        let one = parallel_map_with(&par, 1, || (), |_, i| i + 7);
        assert_eq!(one, vec![7]);
    }

    #[test]
    fn per_worker_state_is_reused_not_shared() {
        // Each worker counts the items it processed in its own state;
        // the per-item result records the worker-local ordinal, which
        // must never exceed the item count.
        let n = 64;
        let out = parallel_map_with(
            &Parallelism::with_threads(4),
            n,
            || 0usize,
            |count, _i| {
                *count += 1;
                *count
            },
        );
        assert_eq!(out.len(), n);
        assert!(out.iter().all(|&c| c >= 1 && c <= n));
        // sequential: one state sees every item
        let seq = parallel_map_with(&Parallelism::sequential(), n, || 0usize, |c, _| {
            *c += 1;
            *c
        });
        assert_eq!(seq, (1..=n).collect::<Vec<_>>());
    }

    #[test]
    fn parallelism_constructors_clamp() {
        assert_eq!(Parallelism::with_threads(0).num_threads, 1);
        assert!(Parallelism::available().num_threads >= 1);
        assert!(!Parallelism::sequential().is_parallel());
        assert!(Parallelism::with_threads(2).is_parallel());
        assert_eq!(Parallelism::default(), Parallelism::sequential());
    }
}
