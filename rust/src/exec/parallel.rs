//! Thread-count configuration and the engine's map primitive.
//!
//! The tiled executor's launch grid — one program instance per
//! (batch, head, q-tile) block of [`crate::grid::LogicalGrid`] — is
//! embarrassingly parallel: blocks share only read-only state.
//! [`parallel_map_with`] distributes block ids over the **persistent
//! topology-aware worker runtime** ([`crate::exec::runtime`]): a
//! process-lifetime pool whose workers park between launches, claim
//! per-domain grid shards in chunked CAS steps (single-block claims
//! inside each shard's tail window), and steal hierarchically —
//! within-domain first, cross-domain when a shard runs dry. Results
//! come back **in item order**, so the caller's merge is deterministic
//! and bit-identical to a sequential run at any thread count under any
//! topology.
//!
//! Earlier revisions spawned a fresh scoped thread pool per launch;
//! that cost dominated small launches (a serving decode sub-round is a
//! few hundred microseconds), so the scheduler now only ever spawns a
//! worker the first time a thread count is requested — steady-state
//! serving performs zero thread spawns (gated in `bench serve_engine`).

use crate::exec::runtime;

/// How many OS threads the execution engine may use. `num_threads == 1`
/// is the exact sequential path (the worker pool is never touched).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    pub num_threads: usize,
}

impl Parallelism {
    /// Single-threaded execution (the default: bit-stable with the
    /// pre-parallel engine, and what unit tests compare against).
    pub fn sequential() -> Self {
        Parallelism { num_threads: 1 }
    }

    /// One thread per available hardware thread.
    pub fn available() -> Self {
        Parallelism {
            num_threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        }
    }

    /// Exactly `n` threads (clamped to at least 1).
    pub fn with_threads(n: usize) -> Self {
        Parallelism {
            num_threads: n.max(1),
        }
    }

    /// `FLASHLIGHT_THREADS=N` override (N >= 1), else all available
    /// cores. `0` and unparseable values are **rejected with a
    /// warning** rather than silently clamped to one thread — a typo'd
    /// `FLASHLIGHT_THREADS=0` used to quietly serialize the whole
    /// engine. See `exec/README.md` for the variable reference.
    pub fn from_env() -> Self {
        Self::from_env_value(std::env::var("FLASHLIGHT_THREADS").ok().as_deref())
    }

    /// [`Parallelism::from_env`] on an explicit value (unit-testable
    /// without touching the process environment).
    pub fn from_env_value(env: Option<&str>) -> Self {
        match env {
            None => Self::available(),
            Some(s) => match s.trim().parse::<usize>() {
                Ok(n) if n >= 1 => Self::with_threads(n),
                _ => {
                    eprintln!(
                        "flashlight: ignoring invalid FLASHLIGHT_THREADS={s:?} \
                         (want an integer >= 1); using all {} cores",
                        Self::available().num_threads
                    );
                    Self::available()
                }
            },
        }
    }

    pub fn is_parallel(&self) -> bool {
        self.num_threads > 1
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Self::sequential()
    }
}

/// Map `f` over `0..n` on the persistent worker runtime, giving each
/// worker thread its own scratch state of type `S` (built by `init` the
/// first time a thread needs one, then **reused across items, launches,
/// and serving steps** — this is how the engine keeps per-thread tile
/// pools and packed-panel caches warm between calls). Items are claimed
/// dynamically from per-domain shard cursors with hierarchical
/// stealing; the returned Vec is in item order regardless of which
/// thread computed what.
///
/// Worker panics propagate to the caller; the pool survives them.
///
/// Nesting: a `parallel_map_with` issued from *inside* another map's
/// closure does not re-enter the (non-reentrant) launch protocol — it
/// runs sequentially on the calling worker with its own scratch.
/// Correct, just serial; the engine never nests launches on purpose.
pub fn parallel_map_with<S, T, I, F>(par: &Parallelism, n: usize, init: I, f: F) -> Vec<T>
where
    S: 'static,
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    runtime::map_with(par, n, init, f)
}

/// [`parallel_map_with`] with optional per-item scheduling weights:
/// `Some(weights)` (one entry per item) cuts the per-domain shards by
/// cumulative weight instead of item count, so launches whose items do
/// very different amounts of work — block-sparse attention grids under
/// a sliding-window mask, say — still balance across topology domains.
/// Weighting changes shard boundaries only; results stay index-ordered
/// and bit-identical to the unweighted (and sequential) path.
pub fn parallel_map_with_weights<S, T, I, F>(
    par: &Parallelism,
    n: usize,
    weights: Option<&[u64]>,
    init: I,
    f: F,
) -> Vec<T>
where
    S: 'static,
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    runtime::map_with_weights(par, n, weights, init, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_and_parallel_agree_in_order() {
        let f = |_s: &mut (), i: usize| i * i;
        let seq = parallel_map_with(&Parallelism::sequential(), 100, || (), f);
        for threads in [2, 3, 8, 64] {
            let par = parallel_map_with(&Parallelism::with_threads(threads), 100, || (), f);
            assert_eq!(seq, par, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single_item_grids() {
        let par = Parallelism::with_threads(4);
        let none: Vec<usize> = parallel_map_with(&par, 0, || (), |_, i| i);
        assert!(none.is_empty());
        let one = parallel_map_with(&par, 1, || (), |_, i| i + 7);
        assert_eq!(one, vec![7]);
    }

    #[test]
    fn per_worker_state_is_reused_not_shared() {
        // Each worker counts the items it processed in its own scratch;
        // the per-item result records the worker-local ordinal, which
        // must never exceed the total number of items ever run through
        // this scratch type (scratch persists across the two launches
        // below — unique local types keep other tests out of the slot).
        struct ParCount(usize);
        let n = 64;
        let out = parallel_map_with(
            &Parallelism::with_threads(4),
            n,
            || ParCount(0),
            |c, _i| {
                c.0 += 1;
                c.0
            },
        );
        assert_eq!(out.len(), n);
        assert!(out.iter().all(|&c| c >= 1 && c <= 2 * n));
        // sequential: one persistent state sees every item, in order
        struct SeqCount(usize);
        let seq = parallel_map_with(&Parallelism::sequential(), n, || SeqCount(0), |c, _| {
            c.0 += 1;
            c.0
        });
        assert_eq!(seq, (1..=n).collect::<Vec<_>>());
        // ...and a second sequential launch continues where it left off
        // (the persistence contract serving relies on).
        let again =
            parallel_map_with(&Parallelism::sequential(), 1, || SeqCount(0), |c, _| c.0);
        assert_eq!(again, vec![n]);
    }

    #[test]
    fn parallelism_constructors_clamp() {
        assert_eq!(Parallelism::with_threads(0).num_threads, 1);
        assert!(Parallelism::available().num_threads >= 1);
        assert!(!Parallelism::sequential().is_parallel());
        assert!(Parallelism::with_threads(2).is_parallel());
        assert_eq!(Parallelism::default(), Parallelism::sequential());
    }

    #[test]
    fn from_env_rejects_zero_and_garbage() {
        let all = Parallelism::available();
        assert_eq!(Parallelism::from_env_value(None), all);
        assert_eq!(Parallelism::from_env_value(Some("3")).num_threads, 3);
        assert_eq!(Parallelism::from_env_value(Some(" 2 ")).num_threads, 2);
        // 0 used to silently become 1 thread; now it is rejected.
        assert_eq!(Parallelism::from_env_value(Some("0")), all);
        assert_eq!(Parallelism::from_env_value(Some("")), all);
        assert_eq!(Parallelism::from_env_value(Some("lots")), all);
        assert_eq!(Parallelism::from_env_value(Some("-4")), all);
        assert_eq!(Parallelism::from_env_value(Some("2.5")), all);
    }
}
