//! Persistent, topology-aware worker runtime for the execution engine.
//!
//! The original scheduler ([`crate::exec::parallel`]) spawned a fresh
//! scoped thread pool for **every** launch. For big one-shot grids the
//! spawn cost amortizes, but the serving engine launches per decode
//! sub-round — a few hundred microseconds of work — so thread creation
//! and teardown dominated small-batch decode latency (the scheduler tax
//! FlashInfer's serving measurements call out). This module replaces it
//! with a process-lifetime pool:
//!
//! * **Persistent workers.** Helper threads spawn once (counted by
//!   [`thread_spawns`] / [`spawns_on_this_thread`]; the serve bench
//!   gates the steady state at zero) and park between launches on an
//!   epoch doorbell — a `Mutex<Epoch>` + `Condvar` pair, the portable
//!   spelling of a futex wait: workers sleep until the epoch advances,
//!   the launcher bumps it and notifies. `Parallelism::num_threads == 1`
//!   never touches the pool (the exact sequential path).
//! * **Persistent scratch.** Each worker thread keeps its launch
//!   scratch (the tiled executor's `WorkerScratch`: tile pool, packed-
//!   panel cache, online-softmax rows) in thread-local storage keyed by
//!   scratch type, so pooled buffers and panel capacity survive across
//!   launches and across serving steps instead of being rebuilt per
//!   call. The caller participates as worker 0 and keeps its own
//!   scratch the same way (so single-threaded serving also reuses its
//!   pool).
//! * **Topology-aware sharding + hierarchical stealing.** Each launch
//!   range-partitions its `0..n` index space into per-domain shards
//!   (see [`crate::exec::topology`]), proportional to the workers
//!   assigned to each domain. A worker claims from its home shard's
//!   cursor first (chunked CAS claims, degrading to single-block claims
//!   inside the shard's tail window) and steals from sibling domains in
//!   ring order only when a shard runs dry. A drained cursor never
//!   refills, so one ring pass visits every item exactly once.
//!
//! **Determinism.** Scheduling never touches results: every item is
//! claimed exactly once, each claim runs the same closure a sequential
//! run would, and results are written into an index-ordered output
//! vector — so the caller's merge (and therefore outputs *and*
//! `Counters`) is bit-identical to sequential under any topology, any
//! steal schedule, and any thread count. Property-tested in
//! `rust/tests/runtime_sched.rs` under adversarial topologies and
//! forced-steal schedules.
//!
//! **Safety protocol.** A launch borrows the caller's closure and
//! output buffer. The borrow is erased to a raw `dyn Fn` pointer for
//! the workers, which is sound because the launcher (a) pre-registers
//! the participant count, and (b) blocks until every participant has
//! checked out — no worker can touch the job after `launch` returns.
//! Worker panics are caught, forwarded, and re-raised on the caller;
//! the pool itself stays usable (locks are poison-tolerant).
//!
//! Launches are serialized process-wide (one launch owns the pool at a
//! time); nested launches from inside a worker closure are not
//! supported — the engine never nests them.

use std::any::Any;
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError, RwLock};

use crate::exec::parallel::Parallelism;
use crate::exec::topology::{proportional_split, Topology};

/// Blocks handed out per cursor claim away from a shard's tail.
pub(crate) const CLAIM_CHUNK: usize = 4;

// ---------------------------------------------------------------------
// Instrumentation
// ---------------------------------------------------------------------

static THREAD_SPAWNS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Pool-growth events attributable to launches from *this* thread
    /// (the launcher performs the spawns). Unlike the global counter,
    /// this is immune to concurrent launches from other threads, so
    /// steady-state gates ("zero spawns after warmup") are exact even
    /// under a parallel test harness.
    static LOCAL_SPAWNS: Cell<u64> = const { Cell::new(0) };
}

/// OS threads the runtime has ever spawned, process-wide.
pub fn thread_spawns() -> u64 {
    THREAD_SPAWNS.load(Ordering::Relaxed)
}

/// Worker spawns caused by launches issued from the calling thread.
/// The serve bench and the engine-backend tests gate this at zero
/// after warmup: steady-state decode must never create threads.
pub fn spawns_on_this_thread() -> u64 {
    LOCAL_SPAWNS.with(|c| c.get())
}

thread_local! {
    /// True while this thread is executing launch work (as launcher or
    /// pooled worker). A nested map issued from inside a launch runs
    /// sequentially on the calling worker instead of re-entering the
    /// (non-reentrant) launch protocol — correct, just serial.
    static IN_LAUNCH: Cell<bool> = const { Cell::new(false) };
}

fn in_launch() -> bool {
    IN_LAUNCH.with(|c| c.get())
}

// ---------------------------------------------------------------------
// Liveness instrumentation (the supervisor's watchdog protocol)
// ---------------------------------------------------------------------

/// Monotone per-item completion counter: every work item that finishes
/// under [`map_with_topology`] ticks it once. A watchdog that sees
/// launches in flight but no heartbeat progress for a full stall budget
/// concludes the remaining item(s) are stuck.
static HEARTBEAT: AtomicU64 = AtomicU64::new(0);

/// Launches currently executing (entered `map_with_topology`, not yet
/// returned). Guard-decremented so panics unwind it correctly.
static LAUNCHES_IN_FLIGHT: AtomicUsize = AtomicUsize::new(0);

/// Bumped by [`kill_stalled_launch`]. Cooperative stall points (the
/// injected [`Fault::StalledLaunch`](crate::serve::Fault) wait loop)
/// poll it and panic — attributed to their work item — when it moves.
static STALL_KILL_EPOCH: AtomicU64 = AtomicU64::new(0);

/// Total work items completed, process-wide. Monotone; only progress
/// (deltas) is meaningful.
pub fn heartbeat() -> u64 {
    HEARTBEAT.load(Ordering::Relaxed)
}

/// Launches currently inside the runtime (0 = quiescent).
pub fn launches_in_flight() -> usize {
    LAUNCHES_IN_FLIGHT.load(Ordering::SeqCst)
}

/// Kill any launch currently blocked on a cooperative stall point: the
/// stalled item panics with an attributed payload, the launch unwinds
/// through the normal panic protocol ([`AttributedPanic`] →
/// `BatchPanic`), and the pool stays usable. Items that are merely slow
/// (still heartbeating) are unaffected — only code that explicitly
/// polls the stall-kill epoch reacts.
pub fn kill_stalled_launch() {
    STALL_KILL_EPOCH.fetch_add(1, Ordering::SeqCst);
}

struct LaunchGuard;

impl LaunchGuard {
    fn enter() -> Self {
        LAUNCHES_IN_FLIGHT.fetch_add(1, Ordering::SeqCst);
        LaunchGuard
    }
}

impl Drop for LaunchGuard {
    fn drop(&mut self) {
        LAUNCHES_IN_FLIGHT.fetch_sub(1, Ordering::SeqCst);
    }
}

// ---------------------------------------------------------------------
// Scoped panic attribution + deterministic fault injection
// ---------------------------------------------------------------------

/// A panic payload wrapped with the index of the work item that raised
/// it. Every item executed by [`map_with_topology`] runs under its own
/// `catch_unwind`; a panic is re-raised wrapped in this struct, so a
/// caller catching the launch panic can map it back to the exact plan /
/// request the item belonged to and fail *only* that unit of work. The
/// wrapper travels as the panic payload itself — no global slot — so
/// attribution is race-free even with concurrent launches from parallel
/// test threads.
pub struct AttributedPanic {
    /// Index (into the launch's `0..n` item space) that panicked.
    pub item: usize,
    /// The original panic payload.
    pub payload: Box<dyn Any + Send>,
}

/// Extract the attributed work-item index from a caught launch panic.
pub fn panic_item(payload: &(dyn Any + Send)) -> Option<usize> {
    payload.downcast_ref::<AttributedPanic>().map(|a| a.item)
}

/// Best-effort human-readable message from a panic payload, unwrapping
/// the [`AttributedPanic`] layer if present.
pub fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(a) = payload.downcast_ref::<AttributedPanic>() {
        return panic_message(a.payload.as_ref());
    }
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        return (*s).to_string();
    }
    if let Some(s) = payload.downcast_ref::<String>() {
        return s.clone();
    }
    "non-string panic payload".to_string()
}

thread_local! {
    /// Deterministic fault injection (serve/faults): when armed, the
    /// next map launched from this thread panics while executing the
    /// given work item (clamped to the launch size). Thread-local and
    /// one-shot, so a chaos plan poisons exactly the launch it schedules
    /// and can never leak into a concurrently running test.
    static INJECT_PANIC: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Arm the fault injector: the next launch issued from this thread
/// panics at work item `item.min(n - 1)`.
pub fn inject_panic_next_launch(item: usize) {
    INJECT_PANIC.with(|c| c.set(Some(item)));
}

/// Disarm a pending injected fault (end-of-run hygiene so an unfired
/// injection cannot poison an unrelated later launch on this thread).
pub fn clear_injected_panic() {
    INJECT_PANIC.with(|c| c.take());
}

thread_local! {
    /// Deterministic stall injection: when armed, the next map launched
    /// from this thread parks the given work item (clamped to the
    /// launch size) at a cooperative stall point — no heartbeat, no
    /// completion — until [`kill_stalled_launch`] fires (or a hard cap
    /// expires so an unsupervised test cannot hang forever). One-shot
    /// and thread-local like [`INJECT_PANIC`].
    static INJECT_STALL: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Arm the stall injector: the next launch issued from this thread
/// stalls at work item `item.min(n - 1)` until the watchdog kills it.
pub fn inject_stall_next_launch(item: usize) {
    INJECT_STALL.with(|c| c.set(Some(item)));
}

/// Disarm a pending injected stall (end-of-run hygiene).
pub fn clear_injected_stall() {
    INJECT_STALL.with(|c| c.take());
}

/// Hard cap on an injected stall with no watchdog: panic anyway so a
/// misconfigured test fails loudly instead of hanging.
const STALL_HARD_CAP: std::time::Duration = std::time::Duration::from_secs(5);

/// Park at the cooperative stall point until the stall-kill epoch moves
/// (watchdog) or the hard cap expires. Always panics.
fn stall_until_killed() -> ! {
    let epoch0 = STALL_KILL_EPOCH.load(Ordering::SeqCst);
    let start = std::time::Instant::now();
    loop {
        std::thread::sleep(std::time::Duration::from_millis(1));
        if STALL_KILL_EPOCH.load(Ordering::SeqCst) != epoch0 {
            panic!("launch stalled: killed by watchdog after exceeding its stall budget");
        }
        if start.elapsed() >= STALL_HARD_CAP {
            panic!("launch stalled: hard cap expired with no watchdog running");
        }
    }
}

/// Run one work item under attribution: any panic (organic or injected)
/// is re-raised wrapped in [`AttributedPanic`] carrying the item index.
/// Completed items tick the process heartbeat (watchdog liveness).
fn run_attributed<S, T, F>(
    f: &F,
    s: &mut S,
    i: usize,
    poison: Option<usize>,
    stall: Option<usize>,
) -> T
where
    F: Fn(&mut S, usize) -> T,
{
    match catch_unwind(AssertUnwindSafe(|| {
        if poison == Some(i) {
            panic!("injected worker fault");
        }
        if stall == Some(i) {
            stall_until_killed();
        }
        let v = f(s, i);
        HEARTBEAT.fetch_add(1, Ordering::Relaxed);
        v
    })) {
        Ok(v) => v,
        Err(payload) => {
            // Don't double-wrap (a nested map already attributed it to
            // its own item space; the outer item is the useful one for
            // the outer caller, so re-wrap with ours).
            let payload = match payload.downcast::<AttributedPanic>() {
                Ok(inner) => inner.payload,
                Err(other) => other,
            };
            std::panic::resume_unwind(Box::new(AttributedPanic { item: i, payload }))
        }
    }
}

static LAUNCH_TAGS: AtomicU64 = AtomicU64::new(0);

/// A process-unique launch tag. The tiled executor scopes its workers'
/// packed-panel cache keys with this, so a panel packed for one launch
/// can never be served to a later launch that happens to reuse the same
/// (plan-index, node, region) key against different data — the
/// correctness condition that lets worker pools outlive launches.
pub fn fresh_launch_tag() -> u64 {
    LAUNCH_TAGS.fetch_add(1, Ordering::Relaxed) + 1
}

// ---------------------------------------------------------------------
// Topology handle (swappable so tests can force adversarial layouts;
// correctness never depends on it — only shard shapes do).
// ---------------------------------------------------------------------

static TOPOLOGY: OnceLock<RwLock<Arc<Topology>>> = OnceLock::new();

fn topo_cell() -> &'static RwLock<Arc<Topology>> {
    TOPOLOGY.get_or_init(|| RwLock::new(Arc::new(Topology::detect())))
}

/// The topology the runtime currently shards launches with.
pub fn topology() -> Arc<Topology> {
    topo_cell()
        .read()
        .unwrap_or_else(PoisonError::into_inner)
        .clone()
}

/// Replace the scheduling topology (tests, tooling). Takes effect for
/// subsequent launches; never affects results, only shard layout.
pub fn set_topology(t: Topology) {
    *topo_cell().write().unwrap_or_else(PoisonError::into_inner) = Arc::new(t);
}

// ---------------------------------------------------------------------
// The worker pool
// ---------------------------------------------------------------------

/// The job a launch publishes to its participants: a lifetime-erased
/// `Fn(worker_ordinal)` plus the participant count for this epoch.
#[derive(Clone, Copy)]
struct Job {
    task: *const (dyn Fn(usize) + Sync),
    participants: usize,
}
// The launcher guarantees the pointee outlives every participant's use.
unsafe impl Send for Job {}

struct PoolState {
    epoch: u64,
    job: Option<Job>,
    /// Helper threads spawned so far (their ordinals are 1..=threads).
    threads: usize,
    /// Participants still inside the current epoch's job.
    active: usize,
}

struct Pool {
    state: Mutex<PoolState>,
    /// Wakes parked workers when the epoch advances.
    doorbell: Condvar,
    /// Wakes the launcher when the last participant checks out.
    done: Condvar,
    /// Serializes launches (one launch owns the pool at a time).
    launch_lock: Mutex<()>,
    /// Panic payloads collected from workers during the current launch.
    panics: Mutex<Vec<Box<dyn Any + Send>>>,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        state: Mutex::new(PoolState {
            epoch: 0,
            job: None,
            threads: 0,
            active: 0,
        }),
        doorbell: Condvar::new(),
        done: Condvar::new(),
        launch_lock: Mutex::new(()),
        panics: Mutex::new(Vec::new()),
    })
}

fn lock_state(p: &Pool) -> MutexGuard<'_, PoolState> {
    p.state.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Grow the pool to at least `helpers` parked worker threads. Spawns
/// are counted globally and against the calling thread.
fn grow(p: &'static Pool, st: &mut PoolState, helpers: usize) {
    while st.threads < helpers {
        let ordinal = st.threads + 1;
        std::thread::Builder::new()
            .name(format!("flashlight-worker-{ordinal}"))
            .spawn(move || worker_loop(p, ordinal))
            .expect("spawn flashlight worker");
        st.threads += 1;
        THREAD_SPAWNS.fetch_add(1, Ordering::Relaxed);
        LOCAL_SPAWNS.with(|c| c.set(c.get() + 1));
    }
}

/// Pre-spawn the helper threads `par` will need so later launches (the
/// serving decode path) perform zero thread spawns. Idempotent.
pub fn warm(par: &Parallelism) {
    let helpers = par.num_threads.saturating_sub(1);
    if helpers == 0 {
        return;
    }
    let p = pool();
    let _g = p.launch_lock.lock().unwrap_or_else(PoisonError::into_inner);
    let mut st = lock_state(p);
    grow(p, &mut st, helpers);
}

/// Helper threads parked right now (diagnostics / bench JSON).
pub fn pooled_workers() -> usize {
    lock_state(pool()).threads
}

fn worker_loop(p: &'static Pool, ordinal: usize) {
    let mut seen = 0u64;
    loop {
        // Park on the doorbell until a new epoch includes us.
        let job = {
            let mut st = lock_state(p);
            loop {
                if st.epoch != seen {
                    seen = st.epoch;
                    if let Some(j) = st.job {
                        if ordinal <= j.participants {
                            break j;
                        }
                    }
                }
                st = p.doorbell.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        };
        // Run our share of the launch; panics are forwarded, not fatal.
        let task = unsafe { &*job.task };
        IN_LAUNCH.with(|c| c.set(true));
        let result = catch_unwind(AssertUnwindSafe(|| task(ordinal)));
        IN_LAUNCH.with(|c| c.set(false));
        if let Err(payload) = result {
            p.panics
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .push(payload);
        }
        let mut st = lock_state(p);
        st.active -= 1;
        if st.active == 0 {
            p.done.notify_all();
        }
    }
}

/// Run `task(ordinal)` once on each of `helpers + 1` workers: ordinals
/// `1..=helpers` on pooled threads, ordinal `0` on the calling thread.
/// Returns only after every participant has finished (or panicked —
/// panics are re-raised here after the pool is quiescent).
fn launch(helpers: usize, task: &(dyn Fn(usize) + Sync)) {
    let p = pool();
    let _guard = p.launch_lock.lock().unwrap_or_else(PoisonError::into_inner);
    {
        let mut st = lock_state(p);
        grow(p, &mut st, helpers);
        st.epoch += 1;
        // Erase the borrow; sound because this frame outlives the job
        // (we block on `active == 0` below before returning).
        let task: *const (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(task) };
        st.job = Some(Job {
            task,
            participants: helpers,
        });
        st.active = helpers;
        p.doorbell.notify_all();
    }
    IN_LAUNCH.with(|c| c.set(true));
    let caller_result = catch_unwind(AssertUnwindSafe(|| task(0)));
    IN_LAUNCH.with(|c| c.set(false));
    {
        let mut st = lock_state(p);
        while st.active > 0 {
            st = p.done.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        st.job = None;
    }
    let mut panics: Vec<Box<dyn Any + Send>> = p
        .panics
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .drain(..)
        .collect();
    if let Err(payload) = caller_result {
        panics.insert(0, payload);
    }
    if let Some(first) = panics.into_iter().next() {
        drop(_guard);
        std::panic::resume_unwind(first);
    }
}

// ---------------------------------------------------------------------
// Per-thread persistent scratch
// ---------------------------------------------------------------------

thread_local! {
    /// Launch scratch by scratch type. Worker threads are persistent,
    /// so a `WorkerScratch` (tile pool + panel cache) placed here
    /// survives across launches; distinct scratch types (tests, other
    /// callers) coexist without evicting each other.
    static SCRATCH: RefCell<HashMap<std::any::TypeId, Box<dyn Any>>> =
        RefCell::new(HashMap::new());
}

fn with_scratch<S: 'static, R>(init: impl Fn() -> S, body: impl FnOnce(&mut S) -> R) -> R {
    let key = std::any::TypeId::of::<S>();
    // Take the slot *out* of the map (releasing the RefCell borrow)
    // while the body runs: a reentrant map on the same thread then
    // builds itself a fresh scratch instead of hitting a borrow panic.
    // The outer scratch is restored afterwards (an inner same-type
    // scratch is simply replaced — persistence is a perf property).
    let mut slot: Box<S> = SCRATCH
        .with(|cell| cell.borrow_mut().remove(&key))
        .and_then(|b| b.downcast::<S>().ok())
        .unwrap_or_else(|| Box::new(init()));
    let out = body(&mut slot);
    SCRATCH.with(|cell| cell.borrow_mut().insert(key, slot as Box<dyn Any>));
    out
}

// ---------------------------------------------------------------------
// Sharded claiming + hierarchical stealing
// ---------------------------------------------------------------------

/// One per-domain shard of a launch's index space.
struct Shard {
    start: usize,
    end: usize,
    /// Absolute index past which claims degrade to single blocks.
    tail_start: usize,
    cursor: AtomicUsize,
}

impl Shard {
    /// Claim the next chunk: `CLAIM_CHUNK` blocks away from the tail,
    /// one block inside it. `None` once the shard is dry (permanent —
    /// cursors never retreat).
    fn claim(&self) -> Option<(usize, usize)> {
        loop {
            let cur = self.cursor.load(Ordering::Relaxed);
            if cur >= self.end {
                return None;
            }
            let take = if cur < self.tail_start {
                CLAIM_CHUNK.min(self.tail_start - cur)
            } else {
                1
            };
            if self
                .cursor
                .compare_exchange_weak(cur, cur + take, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return Some((cur, take));
            }
        }
    }
}

/// Shard `0..n` across domains proportionally to each domain's worker
/// count. Contiguous, disjoint, covering; empty for 0-worker domains.
fn build_shards(workers_per_domain: &[usize], n: usize) -> Vec<Shard> {
    let sizes = proportional_split(workers_per_domain, n);
    let mut shards = Vec::with_capacity(sizes.len());
    let mut start = 0usize;
    for (d, &len) in sizes.iter().enumerate() {
        let end = start + len;
        // Tail window sized to the domain's own workers: the final
        // `workers * CLAIM_CHUNK` items go out one at a time so no
        // worker sits on a multi-block claim while siblings idle.
        let tail = end.saturating_sub(workers_per_domain[d] * CLAIM_CHUNK).max(start);
        shards.push(Shard {
            start,
            end,
            tail_start: tail,
            cursor: AtomicUsize::new(start),
        });
        start = end;
    }
    debug_assert_eq!(start, n);
    shards
}

/// Shard `0..weights.len()` across domains proportionally to each
/// domain's share of the *total weight* rather than the item count: cut
/// points fall where the cumulative weight crosses each domain's
/// worker-proportional target, so block-sparse launches (highly
/// non-uniform per-item cost) still hand every domain a comparable
/// amount of work. Contiguous, disjoint, covering; degenerate weights
/// fall back to uniform sharding.
fn build_shards_weighted(workers_per_domain: &[usize], weights: &[u64]) -> Vec<Shard> {
    let n = weights.len();
    let total: u128 = weights.iter().map(|&w| w as u128).sum();
    let w_workers: usize = workers_per_domain.iter().sum();
    if total == 0 || w_workers == 0 {
        return build_shards(workers_per_domain, n);
    }
    let mut shards = Vec::with_capacity(workers_per_domain.len());
    let mut start = 0usize;
    let mut cum: u128 = 0;
    let mut acc_workers = 0usize;
    for (d, &wk) in workers_per_domain.iter().enumerate() {
        acc_workers += wk;
        let end = if d + 1 == workers_per_domain.len() {
            // Last domain takes the remainder, guaranteeing coverage.
            n
        } else {
            let target = total * acc_workers as u128 / w_workers as u128;
            let mut end = start;
            while end < n && cum < target {
                cum += weights[end] as u128;
                end += 1;
            }
            end
        };
        let tail = end.saturating_sub(wk * CLAIM_CHUNK).max(start);
        shards.push(Shard {
            start,
            end,
            tail_start: tail,
            cursor: AtomicUsize::new(start),
        });
        start = end;
    }
    debug_assert_eq!(start, n);
    shards
}

/// Drain every shard from `home` outward in ring order, running `run`
/// on each claimed index. Own-domain claims come first; cross-domain
/// stealing only begins once a shard is dry, and dry shards stay dry,
/// so a single ring pass claims every index exactly once overall.
fn drive(shards: &[Shard], home: usize, mut run: impl FnMut(usize)) {
    let nd = shards.len();
    for k in 0..nd {
        let shard = &shards[(home + k) % nd];
        while let Some((start, take)) = shard.claim() {
            for i in start..start + take {
                run(i);
            }
        }
    }
}

// ---------------------------------------------------------------------
// The mapping entry points
// ---------------------------------------------------------------------

/// Pointer wrapper so the output buffer can be written from workers
/// (disjoint indices — each claimed exactly once).
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Map `f` over `0..n` on the persistent pool, sharded by `topo`.
///
/// Per-worker scratch of type `S` persists in each worker thread across
/// launches (`init` only runs when a thread has never held an `S`).
/// Results return in index order regardless of which worker computed
/// what, so a caller's merge is deterministic and bit-identical to the
/// `num_threads == 1` sequential path, which runs entirely on the
/// calling thread (using its own persistent scratch) and never touches
/// the pool.
pub fn map_with_topology<S, T, I, F>(
    topo: &Topology,
    par: &Parallelism,
    n: usize,
    init: I,
    f: F,
) -> Vec<T>
where
    S: 'static,
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    map_with_topology_inner(topo, par, n, None, init, f)
}

/// [`map_with_topology`] with optional per-item scheduling weights:
/// when `weights` is `Some` and covers every item, domain shards are
/// cut by cumulative weight instead of item count (see
/// [`build_shards_weighted`]). Results are index-ordered either way, so
/// weighting affects load balance only — never outputs or merge order.
fn map_with_topology_inner<S, T, I, F>(
    topo: &Topology,
    par: &Parallelism,
    n: usize,
    weights: Option<&[u64]>,
    init: I,
    f: F,
) -> Vec<T>
where
    S: 'static,
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    // One-shot injected faults for this launch, clamped so they always
    // land on a real item regardless of launch size. Taken here (not
    // per worker) so each injection is consumed exactly once.
    let poison = INJECT_PANIC.with(|c| c.take()).map(|p| p.min(n - 1));
    let stall = INJECT_STALL.with(|c| c.take()).map(|p| p.min(n - 1));
    let _in_flight = LaunchGuard::enter();
    // A map issued from inside a launch (nested use) runs sequentially
    // on this worker — the launch protocol is not reentrant.
    let workers = if in_launch() {
        1
    } else {
        par.num_threads.min(n).max(1)
    };
    if workers == 1 {
        return with_scratch(&init, |s| {
            (0..n)
                .map(|i| run_attributed(&f, s, i, poison, stall))
                .collect()
        });
    }

    let per_domain = topo.assign_workers(workers);
    let shards = match weights {
        Some(w) if w.len() == n => build_shards_weighted(&per_domain, w),
        _ => build_shards(&per_domain, n),
    };
    // Worker ordinal -> home domain (contiguous ranges per domain).
    let mut home = Vec::with_capacity(workers);
    for (d, &c) in per_domain.iter().enumerate() {
        home.extend(std::iter::repeat(d).take(c));
    }
    debug_assert_eq!(home.len(), workers);

    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let out_ptr = SendPtr(out.as_mut_ptr());
    let task = |ordinal: usize| {
        with_scratch(&init, |s| {
            drive(&shards, home[ordinal], |i| {
                let v = run_attributed(&f, s, i, poison, stall);
                // Each index is claimed exactly once; the slot is None.
                unsafe { out_ptr.0.add(i).write(Some(v)) };
            });
        });
    };
    launch(workers - 1, &task);
    out.into_iter()
        .enumerate()
        .map(|(i, o)| o.unwrap_or_else(|| panic!("work item {i} never claimed")))
        .collect()
}

/// [`map_with_topology`] under the process topology ([`topology()`]).
pub fn map_with<S, T, I, F>(par: &Parallelism, n: usize, init: I, f: F) -> Vec<T>
where
    S: 'static,
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    map_with_topology(topology().as_ref(), par, n, init, f)
}

/// [`map_with`] with optional per-item scheduling weights (weighted
/// domain sharding under the process topology).
pub fn map_with_weights<S, T, I, F>(
    par: &Parallelism,
    n: usize,
    weights: Option<&[u64]>,
    init: I,
    f: F,
) -> Vec<T>
where
    S: 'static,
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    map_with_topology_inner(topology().as_ref(), par, n, weights, init, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_cover_and_partition() {
        for (wpd, n) in [
            (vec![2usize, 2], 100usize),
            (vec![1, 7], 13),
            (vec![3], 5),
            (vec![1, 0, 2], 9),
            (vec![4, 4], 3),
        ] {
            let shards = build_shards(&wpd, n);
            let mut covered = 0usize;
            for s in &shards {
                assert_eq!(s.start, covered, "{wpd:?} n={n}");
                assert!(s.start <= s.tail_start && s.tail_start <= s.end);
                covered = s.end;
            }
            assert_eq!(covered, n, "{wpd:?} n={n}");
        }
    }

    #[test]
    fn weighted_shards_cover_and_balance_by_weight() {
        // Skewed weights: the first half of the items carry almost all
        // the work; an even worker split must give the first domain far
        // fewer items than the second.
        let weights: Vec<u64> = (0..100).map(|i| if i < 50 { 99 } else { 1 }).collect();
        let shards = build_shards_weighted(&[2, 2], &weights);
        let mut covered = 0usize;
        for s in &shards {
            assert_eq!(s.start, covered);
            assert!(s.start <= s.tail_start && s.tail_start <= s.end);
            covered = s.end;
        }
        assert_eq!(covered, 100);
        // ~half the total weight sits in the first ~25 items.
        assert!(shards[0].end < 35, "weighted cut at {}", shards[0].end);

        // Degenerate weights fall back to uniform sharding.
        let zero = build_shards_weighted(&[2, 2], &vec![0u64; 10]);
        assert_eq!(zero.len(), 2);
        assert_eq!(zero.last().unwrap().end, 10);
        let uniform = build_shards_weighted(&[1, 1], &vec![7u64; 8]);
        assert_eq!(uniform[0].end, 4);
    }

    #[test]
    fn claims_are_exactly_once_and_chunked() {
        let shards = build_shards(&[2], 23);
        let mut seen = vec![0usize; 23];
        let mut singles_at_tail = 0;
        while let Some((start, take)) = shards[0].claim() {
            assert!(take == 1 || take == CLAIM_CHUNK || start + take == shards[0].tail_start);
            if start >= shards[0].tail_start {
                assert_eq!(take, 1, "tail claims must be single blocks");
                singles_at_tail += 1;
            }
            for i in start..start + take {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
        assert_eq!(singles_at_tail, 2 * CLAIM_CHUNK);
    }

    #[test]
    fn map_matches_sequential_under_funny_topologies() {
        let f = |_: &mut (), i: usize| (i as f32).sin() * 3.0 + i as f32;
        let seq: Vec<f32> = (0..97).map(|i| f(&mut (), i)).collect();
        for topo in [
            Topology::flat(8),
            Topology::from_domains(vec![1, 1], "env"),
            Topology::from_domains(vec![1, 63], "env"),
            Topology::from_domains(vec![1; 8], "env"),
        ] {
            for threads in [1usize, 2, 4, 7] {
                let got = map_with_topology(
                    &topo,
                    &Parallelism::with_threads(threads),
                    97,
                    || (),
                    f,
                );
                let bits_eq = seq
                    .iter()
                    .zip(&got)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(bits_eq, "topo={topo:?} threads={threads}");
            }
        }
    }

    #[test]
    fn worker_panics_propagate_and_pool_survives() {
        let res = std::panic::catch_unwind(|| {
            map_with(&Parallelism::with_threads(4), 32, || (), |_, i| {
                if i == 17 {
                    panic!("boom");
                }
                i
            })
        });
        assert!(res.is_err(), "panic must propagate to the caller");
        // Pool still serves launches afterwards.
        let ok = map_with(&Parallelism::with_threads(4), 16, || (), |_, i| i * 2);
        assert_eq!(ok, (0..16).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn panics_carry_item_attribution_at_any_width() {
        for threads in [1usize, 4] {
            let res = std::panic::catch_unwind(|| {
                map_with(&Parallelism::with_threads(threads), 32, || (), |_, i| {
                    if i == 17 {
                        panic!("boom at 17");
                    }
                    i
                })
            });
            let payload = res.expect_err("panic must propagate");
            assert_eq!(
                panic_item(payload.as_ref()),
                Some(17),
                "threads={threads}"
            );
            assert_eq!(panic_message(payload.as_ref()), "boom at 17");
        }
    }

    #[test]
    fn injected_fault_fires_once_then_disarms() {
        inject_panic_next_launch(1000); // clamped to n - 1
        let res = std::panic::catch_unwind(|| {
            map_with(&Parallelism::with_threads(2), 8, || (), |_, i| i)
        });
        let payload = res.expect_err("injected fault must fire");
        assert_eq!(panic_item(payload.as_ref()), Some(7));
        assert_eq!(panic_message(payload.as_ref()), "injected worker fault");
        // One-shot: the next launch is clean.
        let ok = map_with(&Parallelism::with_threads(2), 8, || (), |_, i| i);
        assert_eq!(ok, (0..8).collect::<Vec<_>>());
        // clear_injected_panic disarms a never-fired injection.
        inject_panic_next_launch(0);
        clear_injected_panic();
        let ok = map_with(&Parallelism::with_threads(2), 4, || (), |_, i| i);
        assert_eq!(ok, (0..4).collect::<Vec<_>>());
    }

    #[test]
    fn injected_stall_is_killed_attributed_and_disarms() {
        use std::sync::atomic::AtomicBool;
        // A side watchdog: once a launch is in flight, give it a short
        // stall budget and kill it. (The real supervisor watches the
        // heartbeat too; for a 4-item launch with one stalled item the
        // kill is what matters.)
        let stop = Arc::new(AtomicBool::new(false));
        let killer = {
            let stop = stop.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    if launches_in_flight() > 0 {
                        std::thread::sleep(std::time::Duration::from_millis(20));
                        kill_stalled_launch();
                    }
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
            })
        };
        inject_stall_next_launch(1000); // clamped to n - 1
        let res = std::panic::catch_unwind(|| {
            map_with(&Parallelism::with_threads(2), 4, || (), |_, i| i)
        });
        let payload = res.expect_err("stalled launch must be killed");
        assert_eq!(panic_item(payload.as_ref()), Some(3));
        assert!(
            panic_message(payload.as_ref()).contains("launch stalled"),
            "got {:?}",
            panic_message(payload.as_ref())
        );
        // One-shot: the next launch is clean and completes items.
        let hb0 = heartbeat();
        let ok = map_with(&Parallelism::with_threads(2), 8, || (), |_, i| i);
        assert_eq!(ok, (0..8).collect::<Vec<_>>());
        assert!(heartbeat() >= hb0 + 8, "completed items must tick the heartbeat");
        // (No launches_in_flight() == 0 assert: the counter is global
        // and the test harness runs other launches concurrently.)
        // clear_injected_stall disarms a never-fired injection.
        inject_stall_next_launch(0);
        clear_injected_stall();
        let ok = map_with(&Parallelism::with_threads(2), 4, || (), |_, i| i);
        assert_eq!(ok, (0..4).collect::<Vec<_>>());
        stop.store(true, Ordering::SeqCst);
        killer.join().unwrap();
    }

    #[test]
    fn warm_prespawns_and_counts() {
        struct WarmProbe;
        let before = spawns_on_this_thread();
        warm(&Parallelism::with_threads(3));
        let after_warm = spawns_on_this_thread();
        assert!(pooled_workers() >= 2);
        // A post-warm launch at the same width spawns nothing.
        let _ = map_with(
            &Parallelism::with_threads(3),
            64,
            || WarmProbe,
            |_, i| i,
        );
        assert_eq!(spawns_on_this_thread(), after_warm);
        // warm() itself attributed its spawns to this thread (0 if an
        // earlier test on this thread already warmed this far).
        assert!(after_warm >= before);
    }

    #[test]
    fn sequential_path_keeps_caller_scratch_across_calls() {
        // Unique local type: no other test can touch this slot.
        struct Persist(u64);
        let one = map_with(&Parallelism::sequential(), 4, || Persist(0), |s, i| {
            s.0 += 1 + i as u64;
            s.0
        });
        assert_eq!(one, vec![1, 3, 6, 10]);
        // Second launch on the same thread: the scratch carried over.
        let two = map_with(&Parallelism::sequential(), 1, || Persist(0), |s, _| s.0);
        assert_eq!(two, vec![10], "caller scratch must persist across launches");
    }

    #[test]
    fn nested_maps_degrade_to_sequential_without_deadlock() {
        // A map inside a map (same or different scratch type) must not
        // deadlock on the launch protocol or panic on the scratch
        // RefCell — it runs serially on the calling worker.
        struct NestOuter;
        let out = map_with(
            &Parallelism::with_threads(4),
            8,
            || NestOuter,
            |_, i| {
                let inner =
                    map_with(&Parallelism::with_threads(4), 4, || (), |_, j| j * 10);
                inner[i % 4] + i
            },
        );
        assert_eq!(out, (0..8).map(|i| (i % 4) * 10 + i).collect::<Vec<_>>());
    }

    #[test]
    fn launch_tags_are_unique() {
        let a = fresh_launch_tag();
        let b = fresh_launch_tag();
        assert_ne!(a, b);
        assert!(b > 0);
    }
}
