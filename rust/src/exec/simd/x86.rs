//! AVX2 + FMA3 tier (8-lane f32, fused multiply-add).
//!
//! Lane-for-lane mirror of `scalar.rs` — see the bit-exactness contract
//! in the module docs. The NT microkernel is an 8-row × 2-vector
//! (8 × 16) register-blocked accumulator tile over packed B panels; the
//! NN kernel streams contiguous B rows 16 columns at a time with the
//! exact-zero skip; reductions keep one striped YMM accumulator and
//! finish through the shared scalar tree.
//!
//! # Safety
//!
//! Every function is `unsafe fn` + `#[target_feature(enable =
//! "avx2,fma")]`: callers (the dispatcher in `mod.rs`) must only reach
//! this module after `detect()` has confirmed both features.

#![allow(clippy::missing_safety_doc, clippy::too_many_arguments)]

use core::arch::x86_64::*;

use super::{hsum8_tree, mx, PackedB, KC};

const NR: usize = 16; // panel width: two YMM vectors
const MR: usize = 8; // accumulator tile rows

/// `C[m×n] = A[m×k] · B[n×k]ᵀ` over packed panels (`bp.nr == 16`).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn gemm_nt_packed(a: &[f32], bp: &PackedB, c: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(bp.nr, NR);
    debug_assert!(a.len() >= m * k && c.len() >= m * n);
    let panels = (n + NR - 1) / NR;
    for jp in 0..panels {
        let jbase = jp * NR;
        let cols = NR.min(n - jbase);
        let pb = bp.data.as_ptr().add(jp * k * NR);
        let mut i = 0;
        while i + MR <= m {
            nt_block8(a.as_ptr().add(i * k), k, pb, c, i, jbase, n, cols);
            i += MR;
        }
        if i < m {
            nt_block_rows(a.as_ptr().add(i * k), m - i, k, pb, c, i, jbase, n, cols);
        }
    }
}

/// Fixed 8-row block: 16 YMM accumulators, broadcast-A FMA per k step.
#[target_feature(enable = "avx2,fma")]
unsafe fn nt_block8(
    a: *const f32,
    k: usize,
    pb: *const f32,
    c: &mut [f32],
    i0: usize,
    jbase: usize,
    ldc: usize,
    cols: usize,
) {
    let mut acc0 = [_mm256_setzero_ps(); MR];
    let mut acc1 = [_mm256_setzero_ps(); MR];
    for p in 0..k {
        let b0 = _mm256_loadu_ps(pb.add(p * NR));
        let b1 = _mm256_loadu_ps(pb.add(p * NR + 8));
        for r in 0..MR {
            let av = _mm256_set1_ps(*a.add(r * k + p));
            acc0[r] = _mm256_fmadd_ps(av, b0, acc0[r]);
            acc1[r] = _mm256_fmadd_ps(av, b1, acc1[r]);
        }
    }
    store_block(&acc0, &acc1, MR, c, i0, jbase, ldc, cols);
}

/// Tail block (1..8 rows), runtime row count.
#[target_feature(enable = "avx2,fma")]
unsafe fn nt_block_rows(
    a: *const f32,
    mr: usize,
    k: usize,
    pb: *const f32,
    c: &mut [f32],
    i0: usize,
    jbase: usize,
    ldc: usize,
    cols: usize,
) {
    debug_assert!(mr < MR);
    let mut acc0 = [_mm256_setzero_ps(); MR];
    let mut acc1 = [_mm256_setzero_ps(); MR];
    for p in 0..k {
        let b0 = _mm256_loadu_ps(pb.add(p * NR));
        let b1 = _mm256_loadu_ps(pb.add(p * NR + 8));
        for r in 0..mr {
            let av = _mm256_set1_ps(*a.add(r * k + p));
            acc0[r] = _mm256_fmadd_ps(av, b0, acc0[r]);
            acc1[r] = _mm256_fmadd_ps(av, b1, acc1[r]);
        }
    }
    store_block(&acc0, &acc1, mr, c, i0, jbase, ldc, cols);
}

#[target_feature(enable = "avx2,fma")]
unsafe fn store_block(
    acc0: &[__m256; MR],
    acc1: &[__m256; MR],
    rows: usize,
    c: &mut [f32],
    i0: usize,
    jbase: usize,
    ldc: usize,
    cols: usize,
) {
    for r in 0..rows {
        let off = (i0 + r) * ldc + jbase;
        if cols == NR {
            _mm256_storeu_ps(c.as_mut_ptr().add(off), acc0[r]);
            _mm256_storeu_ps(c.as_mut_ptr().add(off + 8), acc1[r]);
        } else {
            let mut buf = [0.0f32; NR];
            _mm256_storeu_ps(buf.as_mut_ptr(), acc0[r]);
            _mm256_storeu_ps(buf.as_mut_ptr().add(8), acc1[r]);
            c[off..off + cols].copy_from_slice(&buf[..cols]);
        }
    }
}

/// Striped-8 dot (the m = 1 NT decode form): vector FMA over full
/// chunks, scalar lanes for the tail, shared tree combine.
#[target_feature(enable = "avx2,fma")]
unsafe fn dot8(a: *const f32, b: *const f32, k: usize) -> f32 {
    let mut acc = _mm256_setzero_ps();
    let mut i = 0;
    while i + 8 <= k {
        acc = _mm256_fmadd_ps(_mm256_loadu_ps(a.add(i)), _mm256_loadu_ps(b.add(i)), acc);
        i += 8;
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    for l in 0..k - i {
        lanes[l] = (*a.add(i + l)).mul_add(*b.add(i + l), lanes[l]);
    }
    hsum8_tree(&lanes)
}

/// `c[j] = a · b[j]` (m = 1 NT).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn nt_row(a: &[f32], b: &[f32], c: &mut [f32], n: usize, k: usize) {
    debug_assert!(a.len() >= k && b.len() >= n * k && c.len() >= n);
    for j in 0..n {
        c[j] = dot8(a.as_ptr(), b.as_ptr().add(j * k), k);
    }
}

/// `C[m×n] += A[m×k] · B[k×n]` — contiguous B rows, [`KC`]-panel
/// contraction blocking, exact-zero skip.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn gemm_nn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && c.len() >= m * n);
    let mut p0 = 0;
    while p0 < k {
        let pc = KC.min(k - p0);
        for i in 0..m {
            let a_row = a.as_ptr().add(i * k + p0);
            let c_row = c.as_mut_ptr().add(i * n);
            let mut j = 0;
            while j + 16 <= n {
                let mut acc0 = _mm256_loadu_ps(c_row.add(j));
                let mut acc1 = _mm256_loadu_ps(c_row.add(j + 8));
                for p in 0..pc {
                    let av = *a_row.add(p);
                    if av == 0.0 {
                        continue;
                    }
                    let avv = _mm256_set1_ps(av);
                    let brow = b.as_ptr().add((p0 + p) * n + j);
                    acc0 = _mm256_fmadd_ps(avv, _mm256_loadu_ps(brow), acc0);
                    acc1 = _mm256_fmadd_ps(avv, _mm256_loadu_ps(brow.add(8)), acc1);
                }
                _mm256_storeu_ps(c_row.add(j), acc0);
                _mm256_storeu_ps(c_row.add(j + 8), acc1);
                j += 16;
            }
            while j + 8 <= n {
                let mut acc = _mm256_loadu_ps(c_row.add(j));
                for p in 0..pc {
                    let av = *a_row.add(p);
                    if av == 0.0 {
                        continue;
                    }
                    let avv = _mm256_set1_ps(av);
                    acc = _mm256_fmadd_ps(
                        avv,
                        _mm256_loadu_ps(b.as_ptr().add((p0 + p) * n + j)),
                        acc,
                    );
                }
                _mm256_storeu_ps(c_row.add(j), acc);
                j += 8;
            }
            while j < n {
                let mut acc = *c_row.add(j);
                for p in 0..pc {
                    let av = *a_row.add(p);
                    if av != 0.0 {
                        acc = av.mul_add(*b.as_ptr().add((p0 + p) * n + j), acc);
                    }
                }
                *c_row.add(j) = acc;
                j += 1;
            }
        }
        p0 += pc;
    }
}

/// Eight lanes of the shared exp kernel (see `exp_f32` for the
/// per-lane reference this mirrors operation-for-operation).
#[target_feature(enable = "avx2,fma")]
unsafe fn exp8(x: __m256) -> __m256 {
    let lo = _mm256_set1_ps(super::EXP_LO);
    let hi = _mm256_set1_ps(super::EXP_HI);
    let xc = _mm256_min_ps(_mm256_max_ps(x, lo), hi);
    let magic = _mm256_set1_ps(super::EXP_MAGIC);
    let n = _mm256_sub_ps(
        _mm256_fmadd_ps(xc, _mm256_set1_ps(super::LOG2E), magic),
        magic,
    );
    let r = _mm256_fmadd_ps(n, _mm256_set1_ps(-super::LN2_HI), xc);
    let r = _mm256_fmadd_ps(n, _mm256_set1_ps(-super::LN2_LO), r);
    let z = _mm256_mul_ps(r, r);
    let mut y = _mm256_set1_ps(super::EXP_P0);
    y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(super::EXP_P1));
    y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(super::EXP_P2));
    y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(super::EXP_P3));
    y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(super::EXP_P4));
    y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(super::EXP_P5));
    let y = _mm256_add_ps(_mm256_fmadd_ps(y, z, r), _mm256_set1_ps(1.0));
    let ni = _mm256_cvtps_epi32(n);
    let bits = _mm256_slli_epi32::<23>(_mm256_add_epi32(ni, _mm256_set1_epi32(127)));
    let out = _mm256_mul_ps(y, _mm256_castsi256_ps(bits));
    // x < EXP_LO ⇒ exactly 0.0 (the -1e30 mask sentinel path).
    let under = _mm256_cmp_ps::<_CMP_LT_OQ>(x, lo);
    _mm256_andnot_ps(under, out)
}

/// `dst[i] = exp(src[i] + shift)`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn vexp_shift(dst: &mut [f32], src: &[f32], shift: f32) {
    let n = src.len();
    let sh = _mm256_set1_ps(shift);
    let mut i = 0;
    while i + 8 <= n {
        let x = _mm256_add_ps(_mm256_loadu_ps(src.as_ptr().add(i)), sh);
        _mm256_storeu_ps(dst.as_mut_ptr().add(i), exp8(x));
        i += 8;
    }
    if i < n {
        let mut xb = [0.0f32; 8];
        xb[..n - i].copy_from_slice(&src[i..]);
        let x = _mm256_add_ps(_mm256_loadu_ps(xb.as_ptr()), sh);
        let mut eb = [0.0f32; 8];
        _mm256_storeu_ps(eb.as_mut_ptr(), exp8(x));
        dst[i..].copy_from_slice(&eb[..n - i]);
    }
}

/// `dst[i] = 1 / (1 + exp(-src[i]))`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn vsigmoid(dst: &mut [f32], src: &[f32]) {
    let n = src.len();
    let one = _mm256_set1_ps(1.0);
    let sign = _mm256_set1_ps(-0.0);
    let mut i = 0;
    while i + 8 <= n {
        let x = _mm256_loadu_ps(src.as_ptr().add(i));
        let e = exp8(_mm256_xor_ps(x, sign));
        _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_div_ps(one, _mm256_add_ps(one, e)));
        i += 8;
    }
    if i < n {
        let mut xb = [0.0f32; 8];
        xb[..n - i].copy_from_slice(&src[i..]);
        let e = exp8(_mm256_xor_ps(_mm256_loadu_ps(xb.as_ptr()), sign));
        let mut ob = [0.0f32; 8];
        _mm256_storeu_ps(ob.as_mut_ptr(), _mm256_div_ps(one, _mm256_add_ps(one, e)));
        dst[i..].copy_from_slice(&ob[..n - i]);
    }
}

/// Striped-8 sum, shared tree combine.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn row_sum(x: &[f32]) -> f32 {
    let n = x.len();
    let mut acc = _mm256_setzero_ps();
    let mut i = 0;
    while i + 8 <= n {
        acc = _mm256_add_ps(acc, _mm256_loadu_ps(x.as_ptr().add(i)));
        i += 8;
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    for l in 0..n - i {
        lanes[l] += x[i + l];
    }
    hsum8_tree(&lanes)
}

/// Striped-8 max (`maxps` matches the scalar `mx` bitwise).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn row_max(x: &[f32]) -> f32 {
    let n = x.len();
    let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
    let mut i = 0;
    while i + 8 <= n {
        acc = _mm256_max_ps(acc, _mm256_loadu_ps(x.as_ptr().add(i)));
        i += 8;
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    for l in 0..n - i {
        lanes[l] = mx(lanes[l], x[i + l]);
    }
    super::hmax8_tree(&lanes)
}

/// `acc[i] *= alpha`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn scale(acc: &mut [f32], alpha: f32) {
    let n = acc.len();
    let av = _mm256_set1_ps(alpha);
    let mut i = 0;
    while i + 8 <= n {
        let p = acc.as_mut_ptr().add(i);
        _mm256_storeu_ps(p, _mm256_mul_ps(_mm256_loadu_ps(p), av));
        i += 8;
    }
    for v in &mut acc[i..] {
        *v *= alpha;
    }
}

/// `acc[i] = fma(p, v[i], acc[i])`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn axpy(acc: &mut [f32], p: f32, v: &[f32]) {
    let n = acc.len();
    let pv = _mm256_set1_ps(p);
    let mut i = 0;
    while i + 8 <= n {
        let ap = acc.as_mut_ptr().add(i);
        _mm256_storeu_ps(
            ap,
            _mm256_fmadd_ps(pv, _mm256_loadu_ps(v.as_ptr().add(i)), _mm256_loadu_ps(ap)),
        );
        i += 8;
    }
    for (av, &vv) in acc[i..].iter_mut().zip(&v[i..]) {
        *av = p.mul_add(vv, *av);
    }
}

/// `dst[i] += src[i]`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn vadd_assign(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    let mut i = 0;
    while i + 8 <= n {
        let dp = dst.as_mut_ptr().add(i);
        _mm256_storeu_ps(
            dp,
            _mm256_add_ps(_mm256_loadu_ps(dp), _mm256_loadu_ps(src.as_ptr().add(i))),
        );
        i += 8;
    }
    for (d, &s) in dst[i..].iter_mut().zip(&src[i..]) {
        *d += s;
    }
}

/// `dst[i] = max(dst[i], src[i])`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn vmax_assign(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    let mut i = 0;
    while i + 8 <= n {
        let dp = dst.as_mut_ptr().add(i);
        _mm256_storeu_ps(
            dp,
            _mm256_max_ps(_mm256_loadu_ps(dp), _mm256_loadu_ps(src.as_ptr().add(i))),
        );
        i += 8;
    }
    for (d, &s) in dst[i..].iter_mut().zip(&src[i..]) {
        *d = mx(*d, s);
    }
}
