//! AVX-512 tier (16-lane f32, fused multiply-add, masked tails).
//!
//! Lane-for-lane mirror of `scalar.rs` — see the bit-exactness contract
//! in the module docs. What the wider ISA buys over the AVX2 tier:
//!
//! * the NT microkernel is an 8-row × 2-vector (8 × 32) register-
//!   blocked accumulator tile over packed B panels (`nr == 32`), with
//!   **masked stores** for partial panels instead of the AVX2 tier's
//!   bounce-buffer copy;
//! * the NN kernel streams contiguous B rows 32 columns at a time, and
//!   the ragged column tail is a masked load/FMA/store — no 8-wide or
//!   scalar special-case loops remain;
//! * element-wise kernels (`exp`, `sigmoid`, scale, axpy, folds) run
//!   16 lanes per step with a masked tail — the scalar tail loops of
//!   the AVX2 tier are gone entirely;
//! * reductions **keep the 8-lane striped accumulator** mandated by the
//!   bit-exactness contract (a 16-lane accumulator would change the
//!   combine association), but the striped tail is a merge-masked YMM
//!   op (`AVX-512VL`) rather than a scalar loop.
//!
//! Per-lane operations are bitwise those of the scalar tier: FMA where
//! it spells `f32::mul_add`, `max` with x86 `maxps` semantics, and the
//! shared `exp` constants — so `to_bits` equality with every other tier
//! holds by construction (`rust/tests/simd_kernels.rs`).
//!
//! # Safety
//!
//! Every function is `unsafe fn` + `#[target_feature(enable =
//! "avx512f,avx512vl")]`: callers (the dispatcher in `mod.rs`) must
//! only reach this module after `detect()` has confirmed both features.
//! The module itself is additionally gated on `cfg(flashlight_avx512)`
//! (build.rs probes the toolchain; the intrinsics are stable since
//! rustc 1.89).

#![allow(clippy::missing_safety_doc, clippy::too_many_arguments)]

use core::arch::x86_64::*;

use super::{hsum8_tree, PackedB, KC};

const NR: usize = 32; // panel width: two ZMM vectors
const MR: usize = 8; // accumulator tile rows

/// All-ones-below-`lanes` 16-bit lane mask (`lanes` in 1..=16).
#[inline(always)]
fn lane_mask16(lanes: usize) -> __mmask16 {
    debug_assert!(lanes >= 1 && lanes <= 16);
    if lanes >= 16 {
        0xFFFF
    } else {
        ((1u32 << lanes) - 1) as __mmask16
    }
}

/// 8-bit lane mask for the striped-YMM tails (`lanes` in 1..=8).
#[inline(always)]
fn lane_mask8(lanes: usize) -> __mmask8 {
    debug_assert!(lanes >= 1 && lanes <= 8);
    if lanes >= 8 {
        0xFF
    } else {
        ((1u16 << lanes) - 1) as __mmask8
    }
}

/// `C[m×n] = A[m×k] · B[n×k]ᵀ` over packed panels (`bp.nr == 32`).
#[target_feature(enable = "avx512f,avx512vl")]
pub unsafe fn gemm_nt_packed(a: &[f32], bp: &PackedB, c: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(bp.nr, NR);
    debug_assert!(a.len() >= m * k && c.len() >= m * n);
    let panels = (n + NR - 1) / NR;
    for jp in 0..panels {
        let jbase = jp * NR;
        let cols = NR.min(n - jbase);
        let pb = bp.data.as_ptr().add(jp * k * NR);
        let mut i = 0;
        while i + MR <= m {
            nt_block(a.as_ptr().add(i * k), MR, k, pb, c, i, jbase, n, cols);
            i += MR;
        }
        if i < m {
            nt_block(a.as_ptr().add(i * k), m - i, k, pb, c, i, jbase, n, cols);
        }
    }
}

/// `mr`-row block (mr ≤ 8): 2·mr ZMM accumulators, broadcast-A FMA per
/// k step, masked stores on partial panels (no bounce buffer).
#[target_feature(enable = "avx512f,avx512vl")]
unsafe fn nt_block(
    a: *const f32,
    mr: usize,
    k: usize,
    pb: *const f32,
    c: &mut [f32],
    i0: usize,
    jbase: usize,
    ldc: usize,
    cols: usize,
) {
    debug_assert!(mr <= MR);
    let mut acc0 = [_mm512_setzero_ps(); MR];
    let mut acc1 = [_mm512_setzero_ps(); MR];
    for p in 0..k {
        // Panels are zero-padded to NR columns: loads are always full.
        let b0 = _mm512_loadu_ps(pb.add(p * NR));
        let b1 = _mm512_loadu_ps(pb.add(p * NR + 16));
        for r in 0..mr {
            let av = _mm512_set1_ps(*a.add(r * k + p));
            acc0[r] = _mm512_fmadd_ps(av, b0, acc0[r]);
            acc1[r] = _mm512_fmadd_ps(av, b1, acc1[r]);
        }
    }
    if cols == NR {
        for r in 0..mr {
            let off = (i0 + r) * ldc + jbase;
            _mm512_storeu_ps(c.as_mut_ptr().add(off), acc0[r]);
            _mm512_storeu_ps(c.as_mut_ptr().add(off + 16), acc1[r]);
        }
    } else {
        let m0 = lane_mask16(cols.min(16));
        let m1 = if cols > 16 { lane_mask16(cols - 16) } else { 0 };
        for r in 0..mr {
            let off = (i0 + r) * ldc + jbase;
            _mm512_mask_storeu_ps(c.as_mut_ptr().add(off), m0, acc0[r]);
            if m1 != 0 {
                _mm512_mask_storeu_ps(c.as_mut_ptr().add(off + 16), m1, acc1[r]);
            }
        }
    }
}

/// Striped-8 dot (the m = 1 NT decode form): one YMM FMA accumulator —
/// the 8-lane striping is part of the cross-tier reduction contract —
/// with a merge-masked FMA for the tail instead of scalar lanes.
#[target_feature(enable = "avx512f,avx512vl")]
unsafe fn dot8(a: *const f32, b: *const f32, k: usize) -> f32 {
    let mut acc = _mm256_setzero_ps();
    let mut i = 0;
    while i + 8 <= k {
        acc = _mm256_fmadd_ps(_mm256_loadu_ps(a.add(i)), _mm256_loadu_ps(b.add(i)), acc);
        i += 8;
    }
    if i < k {
        let m = lane_mask8(k - i);
        let av = _mm256_maskz_loadu_ps(m, a.add(i));
        let bv = _mm256_maskz_loadu_ps(m, b.add(i));
        // Masked-out lanes pass `acc` through untouched.
        acc = _mm256_mask3_fmadd_ps(av, bv, acc, m);
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    hsum8_tree(&lanes)
}

/// `c[j] = a · b[j]` (m = 1 NT).
#[target_feature(enable = "avx512f,avx512vl")]
pub unsafe fn nt_row(a: &[f32], b: &[f32], c: &mut [f32], n: usize, k: usize) {
    debug_assert!(a.len() >= k && b.len() >= n * k && c.len() >= n);
    for j in 0..n {
        c[j] = dot8(a.as_ptr(), b.as_ptr().add(j * k), k);
    }
}

/// `C[m×n] += A[m×k] · B[k×n]` — contiguous B rows, [`KC`]-panel
/// contraction blocking, exact-zero skip, masked ragged tail.
#[target_feature(enable = "avx512f,avx512vl")]
pub unsafe fn gemm_nn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && c.len() >= m * n);
    let mut p0 = 0;
    while p0 < k {
        let pc = KC.min(k - p0);
        for i in 0..m {
            let a_row = a.as_ptr().add(i * k + p0);
            let c_row = c.as_mut_ptr().add(i * n);
            let mut j = 0;
            while j + 32 <= n {
                let mut acc0 = _mm512_loadu_ps(c_row.add(j));
                let mut acc1 = _mm512_loadu_ps(c_row.add(j + 16));
                for p in 0..pc {
                    let av = *a_row.add(p);
                    if av == 0.0 {
                        continue;
                    }
                    let avv = _mm512_set1_ps(av);
                    let brow = b.as_ptr().add((p0 + p) * n + j);
                    acc0 = _mm512_fmadd_ps(avv, _mm512_loadu_ps(brow), acc0);
                    acc1 = _mm512_fmadd_ps(avv, _mm512_loadu_ps(brow.add(16)), acc1);
                }
                _mm512_storeu_ps(c_row.add(j), acc0);
                _mm512_storeu_ps(c_row.add(j + 16), acc1);
                j += 32;
            }
            while j < n {
                // Masked tail: up to two 16-lane segments, no scalar loop.
                let rem = (n - j).min(16);
                let mask = lane_mask16(rem);
                let mut acc = _mm512_maskz_loadu_ps(mask, c_row.add(j));
                for p in 0..pc {
                    let av = *a_row.add(p);
                    if av == 0.0 {
                        continue;
                    }
                    let bv = _mm512_maskz_loadu_ps(mask, b.as_ptr().add((p0 + p) * n + j));
                    acc = _mm512_fmadd_ps(_mm512_set1_ps(av), bv, acc);
                }
                _mm512_mask_storeu_ps(c_row.add(j), mask, acc);
                j += rem;
            }
        }
        p0 += pc;
    }
}

/// Sixteen lanes of the shared exp kernel (see `exp_f32` for the
/// per-lane reference this mirrors operation-for-operation).
#[target_feature(enable = "avx512f,avx512vl")]
unsafe fn exp16(x: __m512) -> __m512 {
    let lo = _mm512_set1_ps(super::EXP_LO);
    let hi = _mm512_set1_ps(super::EXP_HI);
    let xc = _mm512_min_ps(_mm512_max_ps(x, lo), hi);
    let magic = _mm512_set1_ps(super::EXP_MAGIC);
    let n = _mm512_sub_ps(
        _mm512_fmadd_ps(xc, _mm512_set1_ps(super::LOG2E), magic),
        magic,
    );
    let r = _mm512_fmadd_ps(n, _mm512_set1_ps(-super::LN2_HI), xc);
    let r = _mm512_fmadd_ps(n, _mm512_set1_ps(-super::LN2_LO), r);
    let z = _mm512_mul_ps(r, r);
    let mut y = _mm512_set1_ps(super::EXP_P0);
    y = _mm512_fmadd_ps(y, r, _mm512_set1_ps(super::EXP_P1));
    y = _mm512_fmadd_ps(y, r, _mm512_set1_ps(super::EXP_P2));
    y = _mm512_fmadd_ps(y, r, _mm512_set1_ps(super::EXP_P3));
    y = _mm512_fmadd_ps(y, r, _mm512_set1_ps(super::EXP_P4));
    y = _mm512_fmadd_ps(y, r, _mm512_set1_ps(super::EXP_P5));
    let y = _mm512_add_ps(_mm512_fmadd_ps(y, z, r), _mm512_set1_ps(1.0));
    let ni = _mm512_cvtps_epi32(n);
    let bits = _mm512_slli_epi32::<23>(_mm512_add_epi32(ni, _mm512_set1_epi32(127)));
    let out = _mm512_mul_ps(y, _mm512_castsi512_ps(bits));
    // x < EXP_LO ⇒ exactly 0.0 (the -1e30 mask sentinel path).
    let keep = _mm512_cmp_ps_mask::<_CMP_NLT_UQ>(x, lo);
    _mm512_maskz_mov_ps(keep, out)
}

/// IEEE negate (sign-bit flip) without AVX-512DQ's `xor_ps`.
#[target_feature(enable = "avx512f,avx512vl")]
unsafe fn neg16(x: __m512) -> __m512 {
    _mm512_castsi512_ps(_mm512_xor_si512(
        _mm512_castps_si512(x),
        _mm512_set1_epi32(i32::MIN),
    ))
}

/// `dst[i] = exp(src[i] + shift)`.
#[target_feature(enable = "avx512f,avx512vl")]
pub unsafe fn vexp_shift(dst: &mut [f32], src: &[f32], shift: f32) {
    let n = src.len();
    let sh = _mm512_set1_ps(shift);
    let mut i = 0;
    while i + 16 <= n {
        let x = _mm512_add_ps(_mm512_loadu_ps(src.as_ptr().add(i)), sh);
        _mm512_storeu_ps(dst.as_mut_ptr().add(i), exp16(x));
        i += 16;
    }
    if i < n {
        let m = lane_mask16(n - i);
        let x = _mm512_add_ps(_mm512_maskz_loadu_ps(m, src.as_ptr().add(i)), sh);
        _mm512_mask_storeu_ps(dst.as_mut_ptr().add(i), m, exp16(x));
    }
}

/// `dst[i] = 1 / (1 + exp(-src[i]))`.
#[target_feature(enable = "avx512f,avx512vl")]
pub unsafe fn vsigmoid(dst: &mut [f32], src: &[f32]) {
    let n = src.len();
    let one = _mm512_set1_ps(1.0);
    let mut i = 0;
    while i + 16 <= n {
        let x = _mm512_loadu_ps(src.as_ptr().add(i));
        let e = exp16(neg16(x));
        _mm512_storeu_ps(
            dst.as_mut_ptr().add(i),
            _mm512_div_ps(one, _mm512_add_ps(one, e)),
        );
        i += 16;
    }
    if i < n {
        let m = lane_mask16(n - i);
        let x = _mm512_maskz_loadu_ps(m, src.as_ptr().add(i));
        let e = exp16(neg16(x));
        _mm512_mask_storeu_ps(
            dst.as_mut_ptr().add(i),
            m,
            _mm512_div_ps(one, _mm512_add_ps(one, e)),
        );
    }
}

/// Striped-8 sum (8-lane stripe is the cross-tier contract; the tail
/// is a merge-masked add instead of scalar lanes), shared tree combine.
#[target_feature(enable = "avx512f,avx512vl")]
pub unsafe fn row_sum(x: &[f32]) -> f32 {
    let n = x.len();
    let mut acc = _mm256_setzero_ps();
    let mut i = 0;
    while i + 8 <= n {
        acc = _mm256_add_ps(acc, _mm256_loadu_ps(x.as_ptr().add(i)));
        i += 8;
    }
    if i < n {
        let m = lane_mask8(n - i);
        acc = _mm256_mask_add_ps(acc, m, acc, _mm256_maskz_loadu_ps(m, x.as_ptr().add(i)));
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    hsum8_tree(&lanes)
}

/// Striped-8 max (`maxps` matches the scalar `mx` bitwise).
#[target_feature(enable = "avx512f,avx512vl")]
pub unsafe fn row_max(x: &[f32]) -> f32 {
    let n = x.len();
    let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
    let mut i = 0;
    while i + 8 <= n {
        acc = _mm256_max_ps(acc, _mm256_loadu_ps(x.as_ptr().add(i)));
        i += 8;
    }
    if i < n {
        let m = lane_mask8(n - i);
        acc = _mm256_mask_max_ps(acc, m, acc, _mm256_maskz_loadu_ps(m, x.as_ptr().add(i)));
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    super::hmax8_tree(&lanes)
}

/// `acc[i] *= alpha`.
#[target_feature(enable = "avx512f,avx512vl")]
pub unsafe fn scale(acc: &mut [f32], alpha: f32) {
    let n = acc.len();
    let av = _mm512_set1_ps(alpha);
    let mut i = 0;
    while i + 16 <= n {
        let p = acc.as_mut_ptr().add(i);
        _mm512_storeu_ps(p, _mm512_mul_ps(_mm512_loadu_ps(p), av));
        i += 16;
    }
    if i < n {
        let m = lane_mask16(n - i);
        let p = acc.as_mut_ptr().add(i);
        _mm512_mask_storeu_ps(p, m, _mm512_mul_ps(_mm512_maskz_loadu_ps(m, p), av));
    }
}

/// `acc[i] = fma(p, v[i], acc[i])`.
#[target_feature(enable = "avx512f,avx512vl")]
pub unsafe fn axpy(acc: &mut [f32], p: f32, v: &[f32]) {
    let n = acc.len();
    let pv = _mm512_set1_ps(p);
    let mut i = 0;
    while i + 16 <= n {
        let ap = acc.as_mut_ptr().add(i);
        _mm512_storeu_ps(
            ap,
            _mm512_fmadd_ps(pv, _mm512_loadu_ps(v.as_ptr().add(i)), _mm512_loadu_ps(ap)),
        );
        i += 16;
    }
    if i < n {
        let m = lane_mask16(n - i);
        let ap = acc.as_mut_ptr().add(i);
        let vv = _mm512_maskz_loadu_ps(m, v.as_ptr().add(i));
        let av = _mm512_maskz_loadu_ps(m, ap);
        _mm512_mask_storeu_ps(ap, m, _mm512_fmadd_ps(pv, vv, av));
    }
}

/// `dst[i] += src[i]`.
#[target_feature(enable = "avx512f,avx512vl")]
pub unsafe fn vadd_assign(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    let mut i = 0;
    while i + 16 <= n {
        let dp = dst.as_mut_ptr().add(i);
        _mm512_storeu_ps(
            dp,
            _mm512_add_ps(_mm512_loadu_ps(dp), _mm512_loadu_ps(src.as_ptr().add(i))),
        );
        i += 16;
    }
    if i < n {
        let m = lane_mask16(n - i);
        let dp = dst.as_mut_ptr().add(i);
        let sv = _mm512_maskz_loadu_ps(m, src.as_ptr().add(i));
        _mm512_mask_storeu_ps(dp, m, _mm512_add_ps(_mm512_maskz_loadu_ps(m, dp), sv));
    }
}

/// `dst[i] = max(dst[i], src[i])`.
#[target_feature(enable = "avx512f,avx512vl")]
pub unsafe fn vmax_assign(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    let mut i = 0;
    while i + 16 <= n {
        let dp = dst.as_mut_ptr().add(i);
        _mm512_storeu_ps(
            dp,
            _mm512_max_ps(_mm512_loadu_ps(dp), _mm512_loadu_ps(src.as_ptr().add(i))),
        );
        i += 16;
    }
    if i < n {
        let m = lane_mask16(n - i);
        let dp = dst.as_mut_ptr().add(i);
        let sv = _mm512_maskz_loadu_ps(m, src.as_ptr().add(i));
        _mm512_mask_storeu_ps(dp, m, _mm512_max_ps(_mm512_maskz_loadu_ps(m, dp), sv));
    }
}
