//! aarch64 NEON tier (4-lane f32, paired to honor the 8-lane striping
//! contract).
//!
//! Lane-for-lane mirror of `scalar.rs`: element-wise kernels run the
//! same IEEE ops per lane (`vfmaq` where the scalar tier uses
//! `mul_add`), reductions keep a `float32x4` *pair* so the striping and
//! the shared `hsum8_tree`/`hmax8_tree` combine match the scalar and
//! AVX2 tiers exactly, and max is spelled `vbsl(vcgt(a, b), a, b)` so
//! it matches the scalar `a > b ? a : b` (NEON's own `vmax` differs on
//! the sign of zero).
//!
//! NEON is part of the aarch64 baseline ABI, so these are safe `fn`s
//! with internal `unsafe` blocks around the intrinsics; the module is
//! only compiled on aarch64.

use core::arch::aarch64::*;

use super::{hmax8_tree, hsum8_tree, mx, PackedB, KC};

const NR: usize = 8; // panel width: two q-vectors
const MR: usize = 8; // accumulator tile rows

#[inline(always)]
unsafe fn vmax_mirror(a: float32x4_t, b: float32x4_t) -> float32x4_t {
    // a > b ? a : b — bitwise the scalar `mx` for every input class.
    vbslq_f32(vcgtq_f32(a, b), a, b)
}

#[inline(always)]
unsafe fn vmin_mirror(a: float32x4_t, b: float32x4_t) -> float32x4_t {
    // a < b ? a : b — mirrors the scalar clamp upper bound.
    vbslq_f32(vcltq_f32(a, b), a, b)
}

/// `C[m×n] = A[m×k] · B[n×k]ᵀ` over packed panels (`bp.nr == 8`).
pub fn gemm_nt_packed(a: &[f32], bp: &PackedB, c: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(bp.nr, NR);
    debug_assert!(a.len() >= m * k && c.len() >= m * n);
    let panels = (n + NR - 1) / NR;
    unsafe {
        for jp in 0..panels {
            let jbase = jp * NR;
            let cols = NR.min(n - jbase);
            let pb = bp.data.as_ptr().add(jp * k * NR);
            let mut i = 0;
            while i + MR <= m {
                nt_block(a.as_ptr().add(i * k), MR, k, pb, c, i, jbase, n, cols);
                i += MR;
            }
            if i < m {
                nt_block(a.as_ptr().add(i * k), m - i, k, pb, c, i, jbase, n, cols);
            }
        }
    }
}

/// `mr`-row block (mr ≤ 8): 2·mr q-register accumulators, broadcast-A
/// FMA per k step.
#[allow(clippy::too_many_arguments)]
unsafe fn nt_block(
    a: *const f32,
    mr: usize,
    k: usize,
    pb: *const f32,
    c: &mut [f32],
    i0: usize,
    jbase: usize,
    ldc: usize,
    cols: usize,
) {
    let zero = vdupq_n_f32(0.0);
    let mut acc0 = [zero; MR];
    let mut acc1 = [zero; MR];
    for p in 0..k {
        let b0 = vld1q_f32(pb.add(p * NR));
        let b1 = vld1q_f32(pb.add(p * NR + 4));
        for r in 0..mr {
            let av = vdupq_n_f32(*a.add(r * k + p));
            acc0[r] = vfmaq_f32(acc0[r], av, b0);
            acc1[r] = vfmaq_f32(acc1[r], av, b1);
        }
    }
    for r in 0..mr {
        let off = (i0 + r) * ldc + jbase;
        if cols == NR {
            vst1q_f32(c.as_mut_ptr().add(off), acc0[r]);
            vst1q_f32(c.as_mut_ptr().add(off + 4), acc1[r]);
        } else {
            let mut buf = [0.0f32; NR];
            vst1q_f32(buf.as_mut_ptr(), acc0[r]);
            vst1q_f32(buf.as_mut_ptr().add(4), acc1[r]);
            c[off..off + cols].copy_from_slice(&buf[..cols]);
        }
    }
}

/// Striped-8 dot as a q-vector pair (m = 1 NT decode form).
unsafe fn dot8(a: *const f32, b: *const f32, k: usize) -> f32 {
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut i = 0;
    while i + 8 <= k {
        acc0 = vfmaq_f32(acc0, vld1q_f32(a.add(i)), vld1q_f32(b.add(i)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(a.add(i + 4)), vld1q_f32(b.add(i + 4)));
        i += 8;
    }
    let mut lanes = [0.0f32; 8];
    vst1q_f32(lanes.as_mut_ptr(), acc0);
    vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
    for l in 0..k - i {
        lanes[l] = (*a.add(i + l)).mul_add(*b.add(i + l), lanes[l]);
    }
    hsum8_tree(&lanes)
}

/// `c[j] = a · b[j]` (m = 1 NT).
pub fn nt_row(a: &[f32], b: &[f32], c: &mut [f32], n: usize, k: usize) {
    debug_assert!(a.len() >= k && b.len() >= n * k && c.len() >= n);
    unsafe {
        for j in 0..n {
            c[j] = dot8(a.as_ptr(), b.as_ptr().add(j * k), k);
        }
    }
}

/// `C[m×n] += A[m×k] · B[k×n]` — contiguous B rows, [`KC`]-panel
/// contraction blocking, exact-zero skip.
pub fn gemm_nn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && c.len() >= m * n);
    unsafe {
        let mut p0 = 0;
        while p0 < k {
            let pc = KC.min(k - p0);
            for i in 0..m {
                let a_row = a.as_ptr().add(i * k + p0);
                let c_row = c.as_mut_ptr().add(i * n);
                let mut j = 0;
                while j + 8 <= n {
                    let mut acc0 = vld1q_f32(c_row.add(j));
                    let mut acc1 = vld1q_f32(c_row.add(j + 4));
                    for p in 0..pc {
                        let av = *a_row.add(p);
                        if av == 0.0 {
                            continue;
                        }
                        let avv = vdupq_n_f32(av);
                        let brow = b.as_ptr().add((p0 + p) * n + j);
                        acc0 = vfmaq_f32(acc0, avv, vld1q_f32(brow));
                        acc1 = vfmaq_f32(acc1, avv, vld1q_f32(brow.add(4)));
                    }
                    vst1q_f32(c_row.add(j), acc0);
                    vst1q_f32(c_row.add(j + 4), acc1);
                    j += 8;
                }
                while j + 4 <= n {
                    let mut acc = vld1q_f32(c_row.add(j));
                    for p in 0..pc {
                        let av = *a_row.add(p);
                        if av == 0.0 {
                            continue;
                        }
                        acc = vfmaq_f32(
                            acc,
                            vdupq_n_f32(av),
                            vld1q_f32(b.as_ptr().add((p0 + p) * n + j)),
                        );
                    }
                    vst1q_f32(c_row.add(j), acc);
                    j += 4;
                }
                while j < n {
                    let mut acc = *c_row.add(j);
                    for p in 0..pc {
                        let av = *a_row.add(p);
                        if av != 0.0 {
                            acc = av.mul_add(*b.as_ptr().add((p0 + p) * n + j), acc);
                        }
                    }
                    *c_row.add(j) = acc;
                    j += 1;
                }
            }
            p0 += pc;
        }
    }
}

/// Four lanes of the shared exp kernel (see `exp_f32`).
unsafe fn exp4(x: float32x4_t) -> float32x4_t {
    let lo = vdupq_n_f32(super::EXP_LO);
    let hi = vdupq_n_f32(super::EXP_HI);
    let xc = vmin_mirror(vmax_mirror(x, lo), hi);
    let magic = vdupq_n_f32(super::EXP_MAGIC);
    let n = vsubq_f32(vfmaq_f32(magic, xc, vdupq_n_f32(super::LOG2E)), magic);
    let r = vfmaq_f32(xc, n, vdupq_n_f32(-super::LN2_HI));
    let r = vfmaq_f32(r, n, vdupq_n_f32(-super::LN2_LO));
    let z = vmulq_f32(r, r);
    let mut y = vdupq_n_f32(super::EXP_P0);
    y = vfmaq_f32(vdupq_n_f32(super::EXP_P1), y, r);
    y = vfmaq_f32(vdupq_n_f32(super::EXP_P2), y, r);
    y = vfmaq_f32(vdupq_n_f32(super::EXP_P3), y, r);
    y = vfmaq_f32(vdupq_n_f32(super::EXP_P4), y, r);
    y = vfmaq_f32(vdupq_n_f32(super::EXP_P5), y, r);
    let y = vaddq_f32(vfmaq_f32(r, y, z), vdupq_n_f32(1.0));
    let ni = vcvtq_s32_f32(n);
    let bits = vshlq_n_s32::<23>(vaddq_s32(ni, vdupq_n_s32(127)));
    let out = vmulq_f32(y, vreinterpretq_f32_s32(bits));
    let under = vcltq_f32(x, lo);
    vbslq_f32(under, vdupq_n_f32(0.0), out)
}

/// `dst[i] = exp(src[i] + shift)`.
pub fn vexp_shift(dst: &mut [f32], src: &[f32], shift: f32) {
    let n = src.len();
    unsafe {
        let sh = vdupq_n_f32(shift);
        let mut i = 0;
        while i + 4 <= n {
            let x = vaddq_f32(vld1q_f32(src.as_ptr().add(i)), sh);
            vst1q_f32(dst.as_mut_ptr().add(i), exp4(x));
            i += 4;
        }
        if i < n {
            let mut xb = [0.0f32; 4];
            xb[..n - i].copy_from_slice(&src[i..]);
            let x = vaddq_f32(vld1q_f32(xb.as_ptr()), sh);
            let mut eb = [0.0f32; 4];
            vst1q_f32(eb.as_mut_ptr(), exp4(x));
            dst[i..].copy_from_slice(&eb[..n - i]);
        }
    }
}

/// `dst[i] = 1 / (1 + exp(-src[i]))`.
pub fn vsigmoid(dst: &mut [f32], src: &[f32]) {
    let n = src.len();
    unsafe {
        let one = vdupq_n_f32(1.0);
        let mut i = 0;
        while i + 4 <= n {
            let x = vld1q_f32(src.as_ptr().add(i));
            let e = exp4(vnegq_f32(x));
            vst1q_f32(dst.as_mut_ptr().add(i), vdivq_f32(one, vaddq_f32(one, e)));
            i += 4;
        }
        if i < n {
            let mut xb = [0.0f32; 4];
            xb[..n - i].copy_from_slice(&src[i..]);
            let e = exp4(vnegq_f32(vld1q_f32(xb.as_ptr())));
            let mut ob = [0.0f32; 4];
            vst1q_f32(ob.as_mut_ptr(), vdivq_f32(one, vaddq_f32(one, e)));
            dst[i..].copy_from_slice(&ob[..n - i]);
        }
    }
}

/// Striped-8 sum as a q-vector pair, shared tree combine.
pub fn row_sum(x: &[f32]) -> f32 {
    let n = x.len();
    unsafe {
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 8 <= n {
            acc0 = vaddq_f32(acc0, vld1q_f32(x.as_ptr().add(i)));
            acc1 = vaddq_f32(acc1, vld1q_f32(x.as_ptr().add(i + 4)));
            i += 8;
        }
        let mut lanes = [0.0f32; 8];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        for l in 0..n - i {
            lanes[l] += x[i + l];
        }
        hsum8_tree(&lanes)
    }
}

/// Striped-8 max as a q-vector pair, shared tree combine.
pub fn row_max(x: &[f32]) -> f32 {
    let n = x.len();
    unsafe {
        let mut acc0 = vdupq_n_f32(f32::NEG_INFINITY);
        let mut acc1 = vdupq_n_f32(f32::NEG_INFINITY);
        let mut i = 0;
        while i + 8 <= n {
            acc0 = vmax_mirror(acc0, vld1q_f32(x.as_ptr().add(i)));
            acc1 = vmax_mirror(acc1, vld1q_f32(x.as_ptr().add(i + 4)));
            i += 8;
        }
        let mut lanes = [f32::NEG_INFINITY; 8];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        for l in 0..n - i {
            lanes[l] = mx(lanes[l], x[i + l]);
        }
        hmax8_tree(&lanes)
    }
}

/// `acc[i] *= alpha`.
pub fn scale(acc: &mut [f32], alpha: f32) {
    let n = acc.len();
    unsafe {
        let av = vdupq_n_f32(alpha);
        let mut i = 0;
        while i + 4 <= n {
            let p = acc.as_mut_ptr().add(i);
            vst1q_f32(p, vmulq_f32(vld1q_f32(p), av));
            i += 4;
        }
        for v in &mut acc[i..] {
            *v *= alpha;
        }
    }
}

/// `acc[i] = fma(p, v[i], acc[i])`.
pub fn axpy(acc: &mut [f32], p: f32, v: &[f32]) {
    let n = acc.len();
    unsafe {
        let pv = vdupq_n_f32(p);
        let mut i = 0;
        while i + 4 <= n {
            let ap = acc.as_mut_ptr().add(i);
            vst1q_f32(ap, vfmaq_f32(vld1q_f32(ap), pv, vld1q_f32(v.as_ptr().add(i))));
            i += 4;
        }
        for (av, &vv) in acc[i..].iter_mut().zip(&v[i..]) {
            *av = p.mul_add(vv, *av);
        }
    }
}

/// `dst[i] += src[i]`.
pub fn vadd_assign(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    unsafe {
        let mut i = 0;
        while i + 4 <= n {
            let dp = dst.as_mut_ptr().add(i);
            vst1q_f32(dp, vaddq_f32(vld1q_f32(dp), vld1q_f32(src.as_ptr().add(i))));
            i += 4;
        }
        for (d, &s) in dst[i..].iter_mut().zip(&src[i..]) {
            *d += s;
        }
    }
}

/// `dst[i] = max(dst[i], src[i])`.
pub fn vmax_assign(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    unsafe {
        let mut i = 0;
        while i + 4 <= n {
            let dp = dst.as_mut_ptr().add(i);
            vst1q_f32(dp, vmax_mirror(vld1q_f32(dp), vld1q_f32(src.as_ptr().add(i))));
            i += 4;
        }
        for (d, &s) in dst[i..].iter_mut().zip(&src[i..]) {
            *d = mx(*d, s);
        }
    }
}
