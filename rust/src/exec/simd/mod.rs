//! SIMD kernel tier: runtime-dispatched vector microkernels for the
//! engine's three hot paths — register-blocked GEMM (NT over packed B
//! panels, NN over contiguous rows), the online-softmax `exp`/rescale,
//! and row reductions (max / striped sum).
//!
//! ## Dispatch
//!
//! [`level()`] resolves the tier once per process: AVX-512 (F+VL,
//! 16-lane with masked tails — requires a toolchain with stable
//! AVX-512 intrinsics, probed by build.rs) on hosts that report it,
//! else AVX2+FMA on x86_64 hosts that report both features, NEON on
//! aarch64 (baseline there), scalar everywhere else.
//! `FLASHLIGHT_SIMD=0` (also `off` / `scalar`) is the kill switch and
//! `FLASHLIGHT_SIMD=avx2` caps an AVX-512 host at the AVX2 tier; only
//! downgrades are honored because forcing an ISA the host lacks would
//! be unsound. Callers that need an explicit tier (benches, property
//! tests) use the `*_with` entry points.
//!
//! ## The bit-exactness contract
//!
//! Scalar and vector tiers produce **bit-identical** results (property
//! tests in `rust/tests/simd_kernels.rs` assert `to_bits` equality, not
//! tolerance). That holds by construction:
//!
//! * element-wise kernels (`exp`, `sigmoid`, scale, axpy) perform the
//!   same IEEE ops per lane — the scalar tier uses `f32::mul_add`
//!   (fused, single rounding) wherever a vector tier issues an FMA;
//! * GEMM output elements are single sequential FMA chains over the
//!   contraction index, so neither the panel layout nor the register
//!   blocking changes the association;
//! * reductions are pinned to a fixed **8-lane striped** accumulation
//!   (`lane[i % 8]`) with the shared [`hsum8_tree`] / [`hmax8_tree`]
//!   combine, implemented as one YMM register on AVX2 and on AVX-512
//!   (which merge-masks the ragged tail instead of looping scalar
//!   lanes — 16-lane accumulation would change the association), a
//!   `float32x4` pair on NEON, and an `[f32; 8]` array in the scalar
//!   tier;
//! * the m = 1 NT form (serving decode) instead vectorizes the dot
//!   product along k with the same striped-8 scheme — a static split on
//!   shape, so every tier takes it for exactly the same calls.
//!
//! Caveats (documented, not defended): NaN propagation and the sign of
//! zero follow the ISA's `max`/blend semantics (attention graphs
//! produce neither), `exp` overflows to `+inf` slightly early (above
//! ~88.38 rather than 88.72), and the default round-to-nearest mode is
//! assumed.
//!
//! Adding a tier for a new ISA: implement the kernel set in a new
//! `exec/simd/<isa>.rs` mirroring `scalar.rs` lane-for-lane (see
//! `exec/README.md` for the checklist), add a [`SimdLevel`] variant,
//! and wire the `*_with` match arms + [`detect`].

pub mod scalar;

#[cfg(target_arch = "aarch64")]
pub mod neon;
#[cfg(target_arch = "x86_64")]
pub mod x86;
// Gated on the build.rs toolchain probe: the AVX-512 intrinsics are
// stable since rustc 1.89; older toolchains top out at the AVX2 tier.
#[cfg(all(target_arch = "x86_64", flashlight_avx512))]
pub mod x86_512;

use std::sync::OnceLock;

/// Contraction-panel height for the NN kernel: KC rows of B are kept
/// hot across all m rows of A (KC=128, n=64 → 32 KiB, L1-sized). Pure
/// cache blocking — the per-element FMA chains are association-blind to
/// it, so it never affects bits.
pub const KC: usize = 128;

/// A resolved kernel tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable fallback; also the semantic reference the vector tiers
    /// are property-tested against.
    Scalar,
    /// x86_64 with AVX2 + FMA3 (8-lane f32).
    Avx2Fma,
    /// x86_64 with AVX-512F + VL (16-lane f32, masked tails). Only
    /// dispatched when the toolchain compiled the tier
    /// (`cfg(flashlight_avx512)`, see build.rs) *and* the host reports
    /// both features; otherwise the variant exists but never resolves.
    Avx512,
    /// aarch64 NEON (4-lane f32, paired to emulate the 8-lane contract).
    Neon,
}

impl SimdLevel {
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2Fma => "avx2+fma",
            SimdLevel::Avx512 => "avx512",
            SimdLevel::Neon => "neon",
        }
    }

    /// Whether the NT kernels of this tier read packed B panels (the
    /// scalar tier reads the row-major operand directly).
    pub fn uses_panels(self) -> bool {
        !matches!(self, SimdLevel::Scalar)
    }
}

/// Best tier the host supports (ignores the env kill switch).
#[allow(unreachable_code)]
pub fn detect() -> SimdLevel {
    #[cfg(all(target_arch = "x86_64", flashlight_avx512))]
    {
        if std::is_x86_feature_detected!("avx512f") && std::is_x86_feature_detected!("avx512vl")
        {
            return SimdLevel::Avx512;
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma") {
            return SimdLevel::Avx2Fma;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is part of the aarch64 baseline ABI.
        return SimdLevel::Neon;
    }
    SimdLevel::Scalar
}

/// Resolve a `FLASHLIGHT_SIMD` override: `0` / `off` / `scalar` force
/// the scalar tier, `avx2` caps an AVX-512 host at the AVX2+FMA tier
/// (downgrades only — forcing an ISA the host lacks would be unsound),
/// anything else (or unset) auto-detects.
pub fn resolve(env: Option<&str>) -> SimdLevel {
    match env.map(str::trim) {
        Some("0") | Some("off") | Some("scalar") => SimdLevel::Scalar,
        Some("avx2") => match detect() {
            SimdLevel::Avx512 => SimdLevel::Avx2Fma,
            other => other,
        },
        _ => detect(),
    }
}

static LEVEL: OnceLock<SimdLevel> = OnceLock::new();

/// Process-wide dispatch tier, resolved once at first use.
pub fn level() -> SimdLevel {
    *LEVEL.get_or_init(|| resolve(std::env::var("FLASHLIGHT_SIMD").ok().as_deref()))
}

// ---------------------------------------------------------------------
// Shared exp kernel (Cephes-style expf). Every tier runs exactly these
// operations per lane; `exp_f32` *is* the single-lane instance.
// ---------------------------------------------------------------------

/// Above this the one-step 2^n scaling overflows: result is `+inf`.
pub(crate) const EXP_HI: f32 = 88.722_84;
/// Below this the result underflows: pinned to exactly `0.0` (so the
/// `-1e30` mask sentinel and `-inf` both softmax to zero weight).
pub(crate) const EXP_LO: f32 = -87.336_55;
pub(crate) const LOG2E: f32 = 1.442_695;
pub(crate) const LN2_HI: f32 = 0.693_359_4;
pub(crate) const LN2_LO: f32 = -2.121_944_4e-4;
pub(crate) const EXP_P0: f32 = 1.987_569_1e-4;
pub(crate) const EXP_P1: f32 = 1.398_199_9e-3;
pub(crate) const EXP_P2: f32 = 8.333_452e-3;
pub(crate) const EXP_P3: f32 = 4.166_579_6e-2;
pub(crate) const EXP_P4: f32 = 1.666_666_5e-1;
pub(crate) const EXP_P5: f32 = 5.000_000_1e-1;
/// 1.5 · 2²³: add-then-subtract forces round-to-nearest-even, the
/// branch-free `rint` every tier shares (magic-number rounding).
pub(crate) const EXP_MAGIC: f32 = 12_582_912.0;

/// `a > b ? a : b` — the max every tier implements (x86 `maxps`
/// semantics: returns `b` on equal-or-unordered).
#[inline(always)]
pub(crate) fn mx(a: f32, b: f32) -> f32 {
    if a > b {
        a
    } else {
        b
    }
}

/// One lane of the shared `exp` kernel; bit-identical to every vector
/// tier's per-lane computation. ~2 ulp over the finite range; exactly
/// `0.0` below [`EXP_LO`], `+inf` above ~88.38, `exp(0) == 1.0`.
#[inline]
pub fn exp_f32(x: f32) -> f32 {
    // Clamp mirrors vmax(x, lo) then vmin(·, hi).
    let t = if x > EXP_LO { x } else { EXP_LO };
    let xc = if t < EXP_HI { t } else { EXP_HI };
    let n = xc.mul_add(LOG2E, EXP_MAGIC) - EXP_MAGIC;
    let r = n.mul_add(-LN2_HI, xc);
    let r = n.mul_add(-LN2_LO, r);
    let z = r * r;
    let mut y = EXP_P0;
    y = y.mul_add(r, EXP_P1);
    y = y.mul_add(r, EXP_P2);
    y = y.mul_add(r, EXP_P3);
    y = y.mul_add(r, EXP_P4);
    y = y.mul_add(r, EXP_P5);
    let y = y.mul_add(z, r) + 1.0;
    // n ∈ [-126, 128] ⇒ biased exponent ∈ [1, 255]; 255 is +inf.
    let bits = (((n as i32) + 127) as u32) << 23;
    let out = y * f32::from_bits(bits);
    if x < EXP_LO {
        0.0
    } else {
        out
    }
}

/// One lane of the shared logistic kernel: `1 / (1 + exp(-x))`.
#[inline]
pub fn sigmoid_f32(x: f32) -> f32 {
    1.0 / (1.0 + exp_f32(-x))
}

/// The fixed reduction tree over the 8 striped lanes (matches the
/// AVX2 128-bit-halves + movehl horizontal add).
#[inline(always)]
pub fn hsum8_tree(l: &[f32; 8]) -> f32 {
    let b0 = l[0] + l[4];
    let b1 = l[1] + l[5];
    let b2 = l[2] + l[6];
    let b3 = l[3] + l[7];
    (b0 + b2) + (b1 + b3)
}

/// The same tree under max.
#[inline(always)]
pub fn hmax8_tree(l: &[f32; 8]) -> f32 {
    let b0 = mx(l[0], l[4]);
    let b1 = mx(l[1], l[5]);
    let b2 = mx(l[2], l[6]);
    let b3 = mx(l[3], l[7]);
    mx(mx(b0, b2), mx(b1, b3))
}

// ---------------------------------------------------------------------
// Packed B panels for the NT (QKᵀ) microkernel.
// ---------------------------------------------------------------------

/// Panel width (output columns per packed panel) of a tier's NT
/// microkernel: two vectors wide on the vector tiers.
pub fn panel_width(l: SimdLevel) -> usize {
    match l {
        SimdLevel::Avx512 => 32,
        SimdLevel::Avx2Fma => 16,
        SimdLevel::Neon | SimdLevel::Scalar => 8,
    }
}

/// The NT operand `B[n × k]` (row-major, k contiguous — the QKᵀ form's
/// K tile) repacked so the microkernel loads contiguous vectors:
/// `packed[jp][p][jj] = b[(jp·nr + jj)·k + p]`, panels zero-padded to
/// `nr` columns. Pure data movement — never affects bits.
#[derive(Debug)]
pub struct PackedB {
    pub data: Vec<f32>,
    pub n: usize,
    pub k: usize,
    pub nr: usize,
}

impl PackedB {
    /// Pack `b` for tier `l`, reusing `buf`'s storage.
    pub fn pack_with(l: SimdLevel, b: &[f32], n: usize, k: usize, buf: Vec<f32>) -> PackedB {
        let nr = panel_width(l);
        let mut data = buf;
        pack_nt(b, n, k, nr, &mut data);
        PackedB { data, n, k, nr }
    }

    /// Bytes the packed panels occupy (diagnostics / cache bounds).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// Panel-pack `b[n × k]` into `out` at width `nr` (see [`PackedB`]).
pub fn pack_nt(b: &[f32], n: usize, k: usize, nr: usize, out: &mut Vec<f32>) {
    debug_assert!(b.len() >= n * k);
    let panels = (n + nr - 1) / nr.max(1);
    out.clear();
    out.resize(panels * k * nr, 0.0);
    for jp in 0..panels {
        let base = jp * k * nr;
        let cols = nr.min(n - jp * nr);
        for jj in 0..cols {
            let row = &b[(jp * nr + jj) * k..(jp * nr + jj + 1) * k];
            for (p, &v) in row.iter().enumerate() {
                out[base + p * nr + jj] = v;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Dispatched kernel entry points. The `*_with` forms take an explicit
// tier (benches, property tests); the short forms use `level()`.
// ---------------------------------------------------------------------

/// `C[m×n] = A[m×k] · B[n×k]ᵀ` (the QKᵀ form). Overwrites `c`.
pub fn gemm_nt_with(l: SimdLevel, a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert!(a.len() >= m * k && b.len() >= n * k && c.len() >= m * n);
    match l {
        SimdLevel::Scalar => scalar::gemm_nt(a, b, c, m, n, k),
        _ => {
            if m == 1 {
                nt_row_with(l, &a[..k], b, c, n, k);
                return;
            }
            PACK_SCRATCH.with(|cell| {
                let mut slot = cell.borrow_mut();
                let bp = PackedB::pack_with(l, b, n, k, std::mem::take(&mut *slot));
                gemm_nt_packed_with(l, a, &bp, c, m, n, k);
                *slot = bp.data;
            });
        }
    }
}

std::thread_local! {
    /// Per-thread pack buffer for the unpacked [`gemm_nt_with`] entry
    /// (callers that amortize packing use [`PackedB`] + the
    /// `TilePool` panel cache instead).
    static PACK_SCRATCH: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// NT over a caller-packed panel set (the tiled executor's panel-cache
/// path). Bit-identical to [`gemm_nt_with`] at every tier.
pub fn gemm_nt_packed_with(
    l: SimdLevel,
    a: &[f32],
    bp: &PackedB,
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
) {
    debug_assert!(bp.n == n && bp.k == k);
    debug_assert!(a.len() >= m * k && c.len() >= m * n);
    if m == 1 {
        // The decode form never packs; read the panels back out so the
        // striped-dot semantics stay shape-only. Cold path: callers gate
        // the panel cache on m ≥ 2.
        return scalar::nt_row_packed(&a[..k], bp, c, n, k);
    }
    // A panel packed for a different tier width still executes
    // correctly (the layout is bit-neutral): read it back scalar-wise.
    if bp.nr != panel_width(l) {
        return scalar::gemm_nt_packed(a, bp, c, m, n, k);
    }
    match l {
        SimdLevel::Scalar => scalar::gemm_nt_packed(a, bp, c, m, n, k),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2Fma => unsafe { x86::gemm_nt_packed(a, bp, c, m, n, k) },
        #[cfg(all(target_arch = "x86_64", flashlight_avx512))]
        SimdLevel::Avx512 => unsafe { x86_512::gemm_nt_packed(a, bp, c, m, n, k) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => neon::gemm_nt_packed(a, bp, c, m, n, k),
        #[allow(unreachable_patterns)]
        _ => scalar::gemm_nt_packed(a, bp, c, m, n, k),
    }
}

/// The m = 1 NT form (one query row — serving decode): `c[j] = a · bⱼ`,
/// a striped-8 dot along k per output column.
fn nt_row_with(l: SimdLevel, a: &[f32], b: &[f32], c: &mut [f32], n: usize, k: usize) {
    match l {
        SimdLevel::Scalar => scalar::nt_row(a, b, c, n, k),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2Fma => unsafe { x86::nt_row(a, b, c, n, k) },
        #[cfg(all(target_arch = "x86_64", flashlight_avx512))]
        SimdLevel::Avx512 => unsafe { x86_512::nt_row(a, b, c, n, k) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => neon::nt_row(a, b, c, n, k),
        #[allow(unreachable_patterns)]
        _ => scalar::nt_row(a, b, c, n, k),
    }
}

/// `C[m×n] += A[m×k] · B[k×n]` (the PV / epilogue form). Accumulates
/// into `c`; rows of `B` are already contiguous so no packing is
/// needed. Exact-zero A entries (masked scores) skip their row step in
/// every tier (bit-neutral for finite B).
pub fn gemm_nn_with(l: SimdLevel, a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && c.len() >= m * n);
    match l {
        SimdLevel::Scalar => scalar::gemm_nn(a, b, c, m, n, k),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2Fma => unsafe { x86::gemm_nn(a, b, c, m, n, k) },
        #[cfg(all(target_arch = "x86_64", flashlight_avx512))]
        SimdLevel::Avx512 => unsafe { x86_512::gemm_nn(a, b, c, m, n, k) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => neon::gemm_nn(a, b, c, m, n, k),
        #[allow(unreachable_patterns)]
        _ => scalar::gemm_nn(a, b, c, m, n, k),
    }
}

/// `dst[i] = exp(src[i] + shift)` — the online-softmax probability
/// kernel (`shift = -m_new`) and, at `shift = 0`, the `PwOp::Exp` loop.
pub fn vexp_shift_with(l: SimdLevel, dst: &mut [f32], src: &[f32], shift: f32) {
    debug_assert_eq!(dst.len(), src.len());
    match l {
        SimdLevel::Scalar => scalar::vexp_shift(dst, src, shift),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2Fma => unsafe { x86::vexp_shift(dst, src, shift) },
        #[cfg(all(target_arch = "x86_64", flashlight_avx512))]
        SimdLevel::Avx512 => unsafe { x86_512::vexp_shift(dst, src, shift) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => neon::vexp_shift(dst, src, shift),
        #[allow(unreachable_patterns)]
        _ => scalar::vexp_shift(dst, src, shift),
    }
}

/// `dst[i] = 1 / (1 + exp(-src[i]))`.
pub fn vsigmoid_with(l: SimdLevel, dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    match l {
        SimdLevel::Scalar => scalar::vsigmoid(dst, src),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2Fma => unsafe { x86::vsigmoid(dst, src) },
        #[cfg(all(target_arch = "x86_64", flashlight_avx512))]
        SimdLevel::Avx512 => unsafe { x86_512::vsigmoid(dst, src) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => neon::vsigmoid(dst, src),
        #[allow(unreachable_patterns)]
        _ => scalar::vsigmoid(dst, src),
    }
}

/// Striped-8 sum of `x` with the [`hsum8_tree`] combine.
pub fn row_sum_with(l: SimdLevel, x: &[f32]) -> f32 {
    match l {
        SimdLevel::Scalar => scalar::row_sum(x),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2Fma => unsafe { x86::row_sum(x) },
        #[cfg(all(target_arch = "x86_64", flashlight_avx512))]
        SimdLevel::Avx512 => unsafe { x86_512::row_sum(x) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => neon::row_sum(x),
        #[allow(unreachable_patterns)]
        _ => scalar::row_sum(x),
    }
}

/// Striped-8 max of `x` (identity [`f32::NEG_INFINITY`] for empty).
pub fn row_max_with(l: SimdLevel, x: &[f32]) -> f32 {
    match l {
        SimdLevel::Scalar => scalar::row_max(x),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2Fma => unsafe { x86::row_max(x) },
        #[cfg(all(target_arch = "x86_64", flashlight_avx512))]
        SimdLevel::Avx512 => unsafe { x86_512::row_max(x) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => neon::row_max(x),
        #[allow(unreachable_patterns)]
        _ => scalar::row_max(x),
    }
}

/// `acc[i] *= alpha` — the online-softmax rescale.
pub fn scale_with(l: SimdLevel, acc: &mut [f32], alpha: f32) {
    match l {
        SimdLevel::Scalar => scalar::scale(acc, alpha),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2Fma => unsafe { x86::scale(acc, alpha) },
        #[cfg(all(target_arch = "x86_64", flashlight_avx512))]
        SimdLevel::Avx512 => unsafe { x86_512::scale(acc, alpha) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => neon::scale(acc, alpha),
        #[allow(unreachable_patterns)]
        _ => scalar::scale(acc, alpha),
    }
}

/// `acc[i] = fma(p, v[i], acc[i])` — the online-softmax PV row fold.
pub fn axpy_with(l: SimdLevel, acc: &mut [f32], p: f32, v: &[f32]) {
    debug_assert_eq!(acc.len(), v.len());
    match l {
        SimdLevel::Scalar => scalar::axpy(acc, p, v),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2Fma => unsafe { x86::axpy(acc, p, v) },
        #[cfg(all(target_arch = "x86_64", flashlight_avx512))]
        SimdLevel::Avx512 => unsafe { x86_512::axpy(acc, p, v) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => neon::axpy(acc, p, v),
        #[allow(unreachable_patterns)]
        _ => scalar::axpy(acc, p, v),
    }
}

/// `dst[i] += src[i]` — the inner>1 Sum reduce row fold.
pub fn vadd_assign_with(l: SimdLevel, dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    match l {
        SimdLevel::Scalar => scalar::vadd_assign(dst, src),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2Fma => unsafe { x86::vadd_assign(dst, src) },
        #[cfg(all(target_arch = "x86_64", flashlight_avx512))]
        SimdLevel::Avx512 => unsafe { x86_512::vadd_assign(dst, src) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => neon::vadd_assign(dst, src),
        #[allow(unreachable_patterns)]
        _ => scalar::vadd_assign(dst, src),
    }
}

/// `dst[i] = max(dst[i], src[i])` — the inner>1 Max reduce row fold.
pub fn vmax_assign_with(l: SimdLevel, dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    match l {
        SimdLevel::Scalar => scalar::vmax_assign(dst, src),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2Fma => unsafe { x86::vmax_assign(dst, src) },
        #[cfg(all(target_arch = "x86_64", flashlight_avx512))]
        SimdLevel::Avx512 => unsafe { x86_512::vmax_assign(dst, src) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => neon::vmax_assign(dst, src),
        #[allow(unreachable_patterns)]
        _ => scalar::vmax_assign(dst, src),
    }
}

// ---- level()-dispatched conveniences --------------------------------

pub fn gemm_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    gemm_nt_with(level(), a, b, c, m, n, k)
}

pub fn gemm_nt_packed(a: &[f32], bp: &PackedB, c: &mut [f32], m: usize, n: usize, k: usize) {
    gemm_nt_packed_with(level(), a, bp, c, m, n, k)
}

pub fn gemm_nn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    gemm_nn_with(level(), a, b, c, m, n, k)
}

pub fn vexp_shift(dst: &mut [f32], src: &[f32], shift: f32) {
    vexp_shift_with(level(), dst, src, shift)
}

/// Append `exp(src)` to `dst` (pooled-buffer call shape of the
/// executors' pointwise fast paths). The zero-fill `resize` is the
/// price of handing the kernels a safe initialized slice; the kernel
/// then overwrites every element (one extra L1-resident write pass).
pub fn vexp_append(dst: &mut Vec<f32>, src: &[f32]) {
    let start = dst.len();
    dst.resize(start + src.len(), 0.0);
    vexp_shift_with(level(), &mut dst[start..], src, 0.0);
}

/// Append `sigmoid(src)` to `dst`.
pub fn vsigmoid_append(dst: &mut Vec<f32>, src: &[f32]) {
    let start = dst.len();
    dst.resize(start + src.len(), 0.0);
    vsigmoid_with(level(), &mut dst[start..], src);
}

pub fn row_sum(x: &[f32]) -> f32 {
    row_sum_with(level(), x)
}

pub fn row_max(x: &[f32]) -> f32 {
    row_max_with(level(), x)
}

pub fn scale(acc: &mut [f32], alpha: f32) {
    scale_with(level(), acc, alpha)
}

pub fn axpy(acc: &mut [f32], p: f32, v: &[f32]) {
    axpy_with(level(), acc, p, v)
}

pub fn vadd_assign(dst: &mut [f32], src: &[f32]) {
    vadd_assign_with(level(), dst, src)
}

pub fn vmax_assign(dst: &mut [f32], src: &[f32]) {
    vmax_assign_with(level(), dst, src)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_switch_parses() {
        assert_eq!(resolve(Some("0")), SimdLevel::Scalar);
        assert_eq!(resolve(Some("off")), SimdLevel::Scalar);
        assert_eq!(resolve(Some("scalar")), SimdLevel::Scalar);
        assert_eq!(resolve(Some(" 0 ")), SimdLevel::Scalar);
        assert_eq!(resolve(None), detect());
        assert_eq!(resolve(Some("1")), detect());
        // avx2 is a downgrade cap: it only ever steps AVX-512 down.
        let capped = resolve(Some("avx2"));
        if detect() == SimdLevel::Avx512 {
            assert_eq!(capped, SimdLevel::Avx2Fma);
        } else {
            assert_eq!(capped, detect());
        }
        // level() is either the kill switch or auto-detect, never an
        // unsupported tier.
        assert!(level() == SimdLevel::Scalar || level() == detect());
    }

    #[test]
    fn exp_pins_the_boundaries() {
        assert_eq!(exp_f32(0.0), 1.0);
        assert_eq!(exp_f32(f32::NEG_INFINITY), 0.0);
        assert_eq!(exp_f32(-1e30), 0.0); // the NEG_INF mask sentinel
        assert_eq!(exp_f32(-100.0), 0.0);
        assert_eq!(exp_f32(1e30), f32::INFINITY);
        assert_eq!(exp_f32(f32::INFINITY), f32::INFINITY);
        assert!(exp_f32(1.0) > 2.718 && exp_f32(1.0) < 2.7183);
    }

    #[test]
    fn exp_tracks_f64_reference() {
        for i in -2000..=2000 {
            let x = i as f32 * 0.01; // [-20, 20]
            let got = exp_f32(x) as f64;
            let want = (x as f64).exp();
            let rel = ((got - want) / want).abs();
            assert!(rel < 4e-7, "exp({x}): {got} vs {want} (rel {rel})");
        }
    }

    #[test]
    fn sigmoid_saturates_cleanly() {
        assert_eq!(sigmoid_f32(0.0), 0.5);
        assert_eq!(sigmoid_f32(1e30), 1.0);
        assert_eq!(sigmoid_f32(-1e30), 0.0);
        let s = sigmoid_f32(2.0);
        assert!((s - 0.880797).abs() < 1e-5);
    }

    #[test]
    fn packing_round_trips() {
        let (n, k, nr) = (5, 3, 4);
        let b: Vec<f32> = (0..n * k).map(|i| i as f32).collect();
        let mut out = Vec::new();
        pack_nt(&b, n, k, nr, &mut out);
        assert_eq!(out.len(), 2 * k * nr); // two panels, zero-padded
        for j in 0..n {
            for p in 0..k {
                let (jp, jj) = (j / nr, j % nr);
                assert_eq!(out[jp * k * nr + p * nr + jj], b[j * k + p]);
            }
        }
        // padding is exactly zero
        assert_eq!(out[k * nr + 0 * nr + 1], 0.0);
    }
}
