//! Scalar tier: the portable fallback and the semantic reference.
//!
//! Every function here spells out, lane by lane, exactly the IEEE
//! operations the vector tiers perform — `f32::mul_add` where they
//! issue an FMA, `[f32; 8]` striped accumulators where they keep a
//! vector register, the shared `hsum8_tree`/`hmax8_tree` combine where
//! they reduce horizontally. The property tests in
//! `rust/tests/simd_kernels.rs` assert `to_bits` equality against this
//! module, so any semantic drift in a vector tier is caught as a bit
//! mismatch, not a tolerance failure.
//!
//! Known cost of the contract: on targets whose *baseline* ISA lacks a
//! hardware FMA (plain `cargo build` for x86_64 without
//! `-C target-cpu`), `f32::mul_add` lowers to a correctly-rounded
//! libm `fmaf` call, so this tier trades throughput for bit-parity
//! with the vector tiers. Hosts pinned to the scalar tier that care
//! about speed should build with `RUSTFLAGS="-C target-cpu=native"`
//! (keeps `mul_add` a single instruction wherever the CPU has FMA);
//! the `FLASHLIGHT_SIMD=0` CI pass and the microbench's "scalar GF/s"
//! column both run this code and inherit the cost.

use super::{exp_f32, hmax8_tree, hsum8_tree, mx, sigmoid_f32, PackedB, KC};

/// Striped-8 dot product along `k` (the m = 1 NT decode form).
#[inline]
pub(crate) fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut lanes = [0.0f32; 8];
    let mut i = 0;
    while i + 8 <= n {
        for l in 0..8 {
            lanes[l] = a[i + l].mul_add(b[i + l], lanes[l]);
        }
        i += 8;
    }
    for l in 0..n - i {
        lanes[l] = a[i + l].mul_add(b[i + l], lanes[l]);
    }
    hsum8_tree(&lanes)
}

/// `c[j] = a · b[j]` over `n` output columns (m = 1 NT).
pub fn nt_row(a: &[f32], b: &[f32], c: &mut [f32], n: usize, k: usize) {
    for j in 0..n {
        c[j] = dot8(a, &b[j * k..j * k + k]);
    }
}

/// [`nt_row`] reading a packed panel set (cold backstop shared by all
/// tiers for m = 1 calls that arrive pre-packed). Same chains as
/// [`nt_row`] — the panel layout never affects bits.
pub fn nt_row_packed(a: &[f32], bp: &PackedB, c: &mut [f32], n: usize, k: usize) {
    let nr = bp.nr;
    for j in 0..n {
        let base = (j / nr) * k * nr + (j % nr);
        let mut lanes = [0.0f32; 8];
        let mut p = 0;
        while p + 8 <= k {
            for l in 0..8 {
                lanes[l] = a[p + l].mul_add(bp.data[base + (p + l) * nr], lanes[l]);
            }
            p += 8;
        }
        for l in 0..k - p {
            lanes[l] = a[p + l].mul_add(bp.data[base + (p + l) * nr], lanes[l]);
        }
        c[j] = hsum8_tree(&lanes);
    }
}

/// `C[m×n] = A[m×k] · B[n×k]ᵀ`. Each output element is one sequential
/// FMA chain over `p` — the association every tier shares.
pub fn gemm_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    if m == 1 {
        return nt_row(&a[..k], b, c, n, k);
    }
    for i in 0..m {
        let a_row = &a[i * k..i * k + k];
        let c_row = &mut c[i * n..i * n + n];
        for j in 0..n {
            let b_row = &b[j * k..j * k + k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc = a_row[p].mul_add(b_row[p], acc);
            }
            c_row[j] = acc;
        }
    }
}

/// [`gemm_nt`] over a packed panel set (m ≥ 2; the m = 1 case is routed
/// to [`nt_row_packed`] by the dispatcher).
pub fn gemm_nt_packed(a: &[f32], bp: &PackedB, c: &mut [f32], m: usize, n: usize, k: usize) {
    let nr = bp.nr;
    for i in 0..m {
        let a_row = &a[i * k..i * k + k];
        let c_row = &mut c[i * n..i * n + n];
        for j in 0..n {
            let base = (j / nr) * k * nr + (j % nr);
            let mut acc = 0.0f32;
            for p in 0..k {
                acc = a_row[p].mul_add(bp.data[base + p * nr], acc);
            }
            c_row[j] = acc;
        }
    }
}

/// `C[m×n] += A[m×k] · B[k×n]`, contraction blocked into [`KC`]-row
/// panels of `B`. Exact-zero A entries skip their row step (bit-neutral
/// for finite B: `fma(0, b, acc) == acc`).
pub fn gemm_nn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    let mut p0 = 0;
    while p0 < k {
        let pc = KC.min(k - p0);
        for i in 0..m {
            let a_row = &a[i * k + p0..i * k + p0 + pc];
            let c_row = &mut c[i * n..i * n + n];
            for (p, &av) in a_row.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let b_row = &b[(p0 + p) * n..(p0 + p) * n + n];
                for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                    *cv = av.mul_add(bv, *cv);
                }
            }
        }
        p0 += pc;
    }
}

/// `dst[i] = exp(src[i] + shift)`.
pub fn vexp_shift(dst: &mut [f32], src: &[f32], shift: f32) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = exp_f32(s + shift);
    }
}

/// `dst[i] = sigmoid(src[i])`.
pub fn vsigmoid(dst: &mut [f32], src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = sigmoid_f32(s);
    }
}

/// Striped-8 sum with the shared tree combine.
pub fn row_sum(x: &[f32]) -> f32 {
    let n = x.len();
    let mut lanes = [0.0f32; 8];
    let mut i = 0;
    while i + 8 <= n {
        for l in 0..8 {
            lanes[l] += x[i + l];
        }
        i += 8;
    }
    for l in 0..n - i {
        lanes[l] += x[i + l];
    }
    hsum8_tree(&lanes)
}

/// Striped-8 max with the shared tree combine (`-inf` identity).
pub fn row_max(x: &[f32]) -> f32 {
    let n = x.len();
    let mut lanes = [f32::NEG_INFINITY; 8];
    let mut i = 0;
    while i + 8 <= n {
        for l in 0..8 {
            lanes[l] = mx(lanes[l], x[i + l]);
        }
        i += 8;
    }
    for l in 0..n - i {
        lanes[l] = mx(lanes[l], x[i + l]);
    }
    hmax8_tree(&lanes)
}

/// `acc[i] *= alpha`.
pub fn scale(acc: &mut [f32], alpha: f32) {
    for v in acc.iter_mut() {
        *v *= alpha;
    }
}

/// `acc[i] = fma(p, v[i], acc[i])`.
pub fn axpy(acc: &mut [f32], p: f32, v: &[f32]) {
    for (av, &vv) in acc.iter_mut().zip(v) {
        *av = p.mul_add(vv, *av);
    }
}

/// `dst[i] += src[i]`.
pub fn vadd_assign(dst: &mut [f32], src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// `dst[i] = max(dst[i], src[i])` (x86 `maxps` operand order).
pub fn vmax_assign(dst: &mut [f32], src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = mx(*d, s);
    }
}
