//! Dense row-major f32 tensors for the simulated device.

use crate::ir::Shape;

pub const NEG_INF: f32 = -1e30;

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Shape,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Deterministic pseudo-random fill, reproducible across languages:
    /// `x[i] = sin(seed + i * 0.7) * 0.5` computed in f64.
    pub fn synthetic(shape: &[usize], seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let s = seed as f64;
        let data = (0..n)
            .map(|i| ((s + i as f64 * 0.7).sin() * 0.5) as f32)
            .collect();
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        strides_of(&self.shape)
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[flat_index(&self.shape, idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let i = flat_index(&self.shape, idx);
        self.data[i] = v;
    }

    /// Read with size-1 broadcasting against a (possibly larger) index.
    pub fn at_broadcast(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut flat = 0;
        let mut stride = 1;
        for ax in (0..self.shape.len()).rev() {
            if self.shape[ax] != 1 {
                flat += idx[ax] * stride;
            }
            stride *= self.shape[ax];
        }
        self.data[flat]
    }

    /// Materialize a broadcast of `self` (size-1 dims stretch) to
    /// `shape`, using axis-recursive row copies/fills instead of
    /// per-element index arithmetic.
    pub fn broadcast_to(&self, shape: &[usize]) -> Tensor {
        debug_assert_eq!(shape.len(), self.shape.len());
        let mut out = Tensor::zeros(shape);
        let src_strides: Vec<usize> = {
            let s = self.strides();
            self.shape
                .iter()
                .zip(&s)
                .map(|(&d, &st)| if d == 1 { 0 } else { st })
                .collect()
        };
        let dst_strides = out.strides();
        fn rec(
            src: &[f32],
            dst: &mut [f32],
            shape: &[usize],
            ss: &[usize],
            ds: &[usize],
            ax: usize,
            so: usize,
            dof: usize,
        ) {
            if ax + 1 == shape.len() {
                let n = shape[ax];
                if ss[ax] == 0 {
                    let v = src[so];
                    dst[dof..dof + n].fill(v);
                } else {
                    dst[dof..dof + n].copy_from_slice(&src[so..so + n]);
                }
                return;
            }
            for i in 0..shape[ax] {
                rec(src, dst, shape, ss, ds, ax + 1, so + i * ss[ax], dof + i * ds[ax]);
            }
        }
        if shape.is_empty() {
            out.data[0] = self.data[0];
        } else {
            rec(
                &self.data,
                &mut out.data,
                shape,
                &src_strides,
                &dst_strides,
                0,
                0,
                0,
            );
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    pub fn allclose(&self, other: &Tensor, atol: f32) -> bool {
        self.shape == other.shape && self.max_abs_diff(other) <= atol
    }
}

pub fn strides_of(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * shape[i + 1];
    }
    s
}

pub fn flat_index(shape: &[usize], idx: &[usize]) -> usize {
    debug_assert_eq!(shape.len(), idx.len());
    let mut flat = 0;
    let mut stride = 1;
    for ax in (0..shape.len()).rev() {
        debug_assert!(idx[ax] < shape[ax], "index {idx:?} oob for {shape:?}");
        flat += idx[ax] * stride;
        stride *= shape[ax];
    }
    flat
}

/// Visit each row (last-axis run) of a region shaped `lens` in
/// row-major order, passing the leading multi-index (all axes but the
/// last) to `f`. The shared odometer behind the executors'
/// row-contiguous gather/scatter/slice walks.
pub fn for_each_row(lens: &[usize], mut f: impl FnMut(&[usize])) {
    let rank = lens.len();
    if rank == 0 || lens.iter().any(|&l| l == 0) {
        return;
    }
    let mut idx = vec![0usize; rank - 1];
    loop {
        f(&idx);
        // odometer increment over the leading axes
        let mut ax = rank - 1;
        loop {
            if ax == 0 {
                return;
            }
            ax -= 1;
            idx[ax] += 1;
            if idx[ax] < lens[ax] {
                break;
            }
            idx[ax] = 0;
        }
    }
}

/// Iterate all multi-indices of `shape` (row-major order).
pub fn for_each_index(shape: &[usize], mut f: impl FnMut(&[usize])) {
    let rank = shape.len();
    if shape.iter().any(|&s| s == 0) {
        return;
    }
    let mut idx = vec![0usize; rank];
    loop {
        f(&idx);
        // increment
        let mut ax = rank;
        loop {
            if ax == 0 {
                return;
            }
            ax -= 1;
            idx[ax] += 1;
            if idx[ax] < shape[ax] {
                break;
            }
            idx[ax] = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_and_indexing() {
        let t = Tensor::from_vec(&[2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.strides(), vec![3, 1]);
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.at(&[0, 1]), 1.0);
    }

    #[test]
    fn broadcast_read() {
        let t = Tensor::from_vec(&[2, 1], vec![7., 9.]);
        assert_eq!(t.at_broadcast(&[1, 5]), 9.0);
        assert_eq!(t.at_broadcast(&[0, 3]), 7.0);
    }

    #[test]
    fn for_each_visits_all_in_row_major() {
        let mut seen = vec![];
        for_each_index(&[2, 2], |i| seen.push((i[0], i[1])));
        assert_eq!(seen, vec![(0, 0), (0, 1), (1, 0), (1, 1)]);
    }

    #[test]
    fn for_each_row_walks_leading_indices() {
        let mut rows = vec![];
        for_each_row(&[2, 3, 5], |i| rows.push((i[0], i[1])));
        assert_eq!(rows, vec![(0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (1, 2)]);
        // rank 1: a single row with an empty leading index
        let mut count = 0;
        for_each_row(&[7], |i| {
            assert!(i.is_empty());
            count += 1;
        });
        assert_eq!(count, 1);
        // zero-sized and rank-0 regions visit nothing
        for_each_row(&[2, 0, 3], |_| panic!("no rows"));
        for_each_row(&[], |_| panic!("no rows"));
    }

    #[test]
    fn synthetic_is_deterministic() {
        let a = Tensor::synthetic(&[8], 3);
        let b = Tensor::synthetic(&[8], 3);
        assert_eq!(a, b);
        assert!(a.data.iter().all(|x| x.abs() <= 0.5));
    }
}
