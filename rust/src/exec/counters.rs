//! Execution counters: the ground truth behind the GPU cost model.
//!
//! Both executors count the global-memory (HBM) traffic they generate,
//! the floating-point work, and the number of kernel launches. Fusion's
//! entire benefit shows up here: the fused executor never writes
//! intermediates to HBM, the eager/reference executor writes and re-reads
//! every one of them.

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Counters {
    /// Bytes read from simulated HBM (compulsory: first touch of data).
    pub hbm_read: u64,
    /// Bytes re-read within one kernel that hit the L2 cache instead of
    /// HBM (e.g. K/V tiles re-read once per q-tile in a flash pipeline —
    /// the reuse the GROUP_M swizzle of §3.7 exists to capture).
    pub l2_read: u64,
    /// Bytes written to simulated HBM.
    pub hbm_write: u64,
    /// Scalar fused-multiply-add-equivalent flops (1 mul+add = 2 flops).
    pub flops: u64,
    /// Kernel launches.
    pub launches: u64,
    /// Peak extra workspace bytes alive at once (materialized
    /// intermediates for eager; tile buffers for fused).
    pub peak_workspace: u64,
    /// Score k-tiles the tiled executor actually processed.
    pub tiles_visited: u64,
    /// Score k-tiles skipped by the block-sparse layer (statically
    /// `Empty` tiles, or threshold-pruned tiles at runtime).
    pub tiles_skipped: u64,
    /// Flops the dense path would have spent on skipped tiles (QK^T,
    /// softmax update, and PV work that never ran). Not part of
    /// `flops`, which counts work actually performed.
    pub flops_avoided: u64,
    /// Bytes of K/V tile gathers elided by skipped tiles — the HBM/L2
    /// traffic delta vs the dense run.
    pub bytes_skipped: u64,
}

impl Counters {
    /// HBM traffic only (L2 hits excluded).
    pub fn total_traffic(&self) -> u64 {
        self.hbm_read + self.hbm_write
    }

    /// All data movement including L2-resident re-reads.
    pub fn total_with_l2(&self) -> u64 {
        self.hbm_read + self.hbm_write + self.l2_read
    }

    pub fn add(&mut self, other: &Counters) {
        self.hbm_read += other.hbm_read;
        self.l2_read += other.l2_read;
        self.hbm_write += other.hbm_write;
        self.flops += other.flops;
        self.launches += other.launches;
        self.peak_workspace = self.peak_workspace.max(other.peak_workspace);
        self.tiles_visited += other.tiles_visited;
        self.tiles_skipped += other.tiles_skipped;
        self.flops_avoided += other.flops_avoided;
        self.bytes_skipped += other.bytes_skipped;
    }

    pub fn read_elems(&mut self, n: usize) {
        self.hbm_read += 4 * n as u64;
    }

    pub fn l2_elems(&mut self, n: usize) {
        self.l2_read += 4 * n as u64;
    }

    pub fn write_elems(&mut self, n: usize) {
        self.hbm_write += 4 * n as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_accumulates_and_max_workspace() {
        let mut a = Counters {
            hbm_read: 10,
            l2_read: 7,
            hbm_write: 5,
            flops: 100,
            launches: 1,
            peak_workspace: 64,
            tiles_visited: 6,
            tiles_skipped: 2,
            flops_avoided: 40,
            bytes_skipped: 16,
        };
        let b = Counters {
            hbm_read: 1,
            l2_read: 3,
            hbm_write: 2,
            flops: 3,
            launches: 4,
            peak_workspace: 32,
            tiles_visited: 1,
            tiles_skipped: 3,
            flops_avoided: 5,
            bytes_skipped: 8,
        };
        a.add(&b);
        assert_eq!(a.hbm_read, 11);
        assert_eq!(a.l2_read, 10);
        assert_eq!(a.launches, 5);
        assert_eq!(a.peak_workspace, 64);
        assert_eq!(a.total_traffic(), 18);
        assert_eq!(a.total_with_l2(), 28);
        assert_eq!(a.tiles_visited, 7);
        assert_eq!(a.tiles_skipped, 5);
        assert_eq!(a.flops_avoided, 45);
        assert_eq!(a.bytes_skipped, 24);
    }
}
