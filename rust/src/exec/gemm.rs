//! f32 GEMM entry points for both executors — thin wrappers over the
//! runtime-dispatched SIMD kernel tier ([`crate::exec::simd`]).
//!
//! * NT (`C = A · Bᵀ`, both operands row-major over k, the QKᵀ form):
//!   register-blocked microkernels over **packed B panels** (8 rows ×
//!   two vectors of accumulators on the vector tiers). The m = 1 form
//!   (serving decode) skips packing and runs a striped dot along k.
//!   Callers that revisit the same B tile (the tiled executor's k-loop
//!   across q-tiles) amortize packing through the
//!   [`TilePool`](crate::exec::pool::TilePool) panel cache and call
//!   [`gemm_nt_packed`]; the plain entry packs per call into a
//!   per-thread scratch.
//! * NN (`C += A · B`, the PV form): B rows are already contiguous, so
//!   the kernel streams them two vectors at a time under [`KC`]-row
//!   contraction panels, preserving the exact-zero skip for masked
//!   attention scores.
//!
//! Every tier produces bit-identical results (the per-element FMA
//! chains are fixed; see `exec/simd/mod.rs`), so dispatch never affects
//! the engine's determinism gates.

use crate::exec::simd;
pub use crate::exec::simd::{PackedB, KC};
use crate::exec::tensor::Tensor;

/// `C[m×n] = A[m×k] · B[n×k]ᵀ` — the QKᵀ form (both operands row-major
/// with k contiguous). Overwrites `c`.
pub fn gemm_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert!(a.len() >= m * k && b.len() >= n * k && c.len() >= m * n);
    simd::gemm_nt(a, b, c, m, n, k)
}

/// [`gemm_nt`] over a pre-packed B (the tiled executor's panel-cache
/// path — K/V tiles are packed once per k-tile, not per q-tile).
pub fn gemm_nt_packed(a: &[f32], bp: &PackedB, c: &mut [f32], m: usize, n: usize, k: usize) {
    simd::gemm_nt_packed(a, bp, c, m, n, k)
}

/// `C[m×n] += A[m×k] · B[k×n]` — the PV form. Accumulates into `c`
/// (callers pass a zeroed or carried accumulator).
pub fn gemm_nn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && c.len() >= m * n);
    simd::gemm_nn(a, b, c, m, n, k)
}

/// Batched matmul with size-1 batch-dim broadcasting (the IR `Matmul`
/// semantics shared by both executors). `shape` is the output shape;
/// `out` must be zero-filled and of `shape`'s size.
pub fn batched_matmul(
    a: &Tensor,
    b: &Tensor,
    transpose_rhs: bool,
    shape: &[usize],
    out: &mut [f32],
) {
    let rank = shape.len();
    let m = shape[rank - 2];
    let n = shape[rank - 1];
    let k = a.shape[rank - 1];
    let batch_shape = &shape[..rank - 2];
    let batch: usize = batch_shape.iter().product();
    debug_assert_eq!(out.len(), batch * m * n);
    for bi in 0..batch {
        // Per-axis broadcast mapping of the batch index (size-1 dims of
        // either operand map to 0), as in `Tensor::at_broadcast`.
        let (mut ab, mut bb) = (0usize, 0usize);
        let (mut astride, mut bstride) = (1usize, 1usize);
        let mut rem = bi;
        for ax in (0..batch_shape.len()).rev() {
            let ix = rem % batch_shape[ax];
            rem /= batch_shape[ax];
            if a.shape[ax] != 1 {
                ab += ix * astride;
            }
            if b.shape[ax] != 1 {
                bb += ix * bstride;
            }
            astride *= a.shape[ax];
            bstride *= b.shape[ax];
        }
        let a_off = ab * m * k;
        let b_off = bb * k * n; // n·k elements per batch either way
        let a_mat = &a.data[a_off..a_off + m * k];
        let c_mat = &mut out[bi * m * n..(bi + 1) * m * n];
        if transpose_rhs {
            gemm_nt(a_mat, &b.data[b_off..b_off + n * k], c_mat, m, n, k);
        } else {
            gemm_nn(a_mat, &b.data[b_off..b_off + k * n], c_mat, m, n, k);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[j * k + p];
                }
            }
        }
        c
    }

    fn naive_nn(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    fn fill(n: usize, seed: u64) -> Vec<f32> {
        (0..n)
            .map(|i| (((seed as f64) + i as f64 * 0.7).sin() * 0.5) as f32)
            .collect()
    }

    #[test]
    fn nt_matches_naive_over_odd_shapes() {
        for (m, n, k) in [(1, 1, 1), (3, 5, 7), (8, 8, 8), (5, 9, 130), (17, 4, 33), (1, 9, 40)] {
            let a = fill(m * k, 1);
            let b = fill(n * k, 2);
            let mut c = vec![0.0; m * n];
            gemm_nt(&a, &b, &mut c, m, n, k);
            let want = naive_nt(&a, &b, m, n, k);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() <= 1e-4, "{m}x{n}x{k}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn nt_packed_matches_unpacked_bitwise() {
        use crate::exec::simd::{self, PackedB};
        for (m, n, k) in [(2, 3, 5), (8, 16, 64), (9, 17, 33)] {
            let a = fill(m * k, 7);
            let b = fill(n * k, 8);
            let mut c1 = vec![0.0; m * n];
            gemm_nt(&a, &b, &mut c1, m, n, k);
            let bp = PackedB::pack_with(simd::level(), &b, n, k, Vec::new());
            let mut c2 = vec![0.0; m * n];
            gemm_nt_packed(&a, &bp, &mut c2, m, n, k);
            for (x, y) in c1.iter().zip(&c2) {
                assert_eq!(x.to_bits(), y.to_bits(), "{m}x{n}x{k}");
            }
        }
    }

    #[test]
    fn nn_matches_naive_over_odd_shapes() {
        for (m, n, k) in [(1, 1, 1), (3, 5, 7), (8, 8, 8), (4, 6, 300), (17, 4, 129)] {
            let a = fill(m * k, 3);
            let b = fill(k * n, 4);
            let mut c = vec![0.0; m * n];
            gemm_nn(&a, &b, &mut c, m, n, k);
            let want = naive_nn(&a, &b, m, n, k);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() <= 1e-4, "{m}x{n}x{k}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn nn_zero_rows_skip_but_stay_exact() {
        let (m, n, k) = (2, 8, 64);
        let mut a = fill(m * k, 5);
        for p in (0..k).step_by(2) {
            a[p] = 0.0; // half the first row masked
        }
        let b = fill(k * n, 6);
        let mut c = vec![0.0; m * n];
        gemm_nn(&a, &b, &mut c, m, n, k);
        let want = naive_nn(&a, &b, m, n, k);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() <= 1e-4);
        }
    }

    #[test]
    fn batched_matmul_broadcasts_size_one_batch_dims() {
        // a: [2,1,3] nt b: [1,1,3] -> out [2,1,1] (the GQA pattern)
        let a = Tensor::from_vec(&[2, 1, 3], vec![1., 1., 1., 2., 2., 2.]);
        let b = Tensor::from_vec(&[1, 1, 3], vec![1., 2., 3.]);
        let mut out = vec![0.0; 2];
        batched_matmul(&a, &b, true, &[2, 1, 1], &mut out);
        assert_eq!(out, vec![6., 12.]);
    }
}
