//! Cache-blocked, autovectorizer-friendly f32 GEMM microkernels.
//!
//! Both executors' matmuls land here. The kernels are written against
//! contiguous slices with zipped iterators so LLVM can elide bounds
//! checks and vectorize, and they break the serial FP dependency chains
//! the naive loops had:
//!
//! * NT (`C = A · Bᵀ`, both operands row-major over k): 4-wide register
//!   blocking over output columns (each `A` row is re-used across four
//!   `B` rows from registers) and a 4-accumulator dot for the tail.
//! * NN (`C += A · B`): the contraction is blocked into panels of
//!   [`KC`] rows of `B` so the streamed panel stays cache-resident
//!   across all `m` output rows; two contraction steps are fused per
//!   pass over the output row to halve its load/store traffic. Zero
//!   `A` entries (masked-out attention scores) skip their panel rows,
//!   preserving the sparse shortcut of the original executor.

use crate::exec::tensor::Tensor;

/// Contraction-panel height for the NN kernel: KC · n floats of B are
/// kept hot across all m rows of A (KC=128, n=64 → 32 KiB, L1-sized).
pub const KC: usize = 128;

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let mut ai = a.chunks_exact(4);
    let mut bi = b.chunks_exact(4);
    for (a4, b4) in (&mut ai).zip(&mut bi) {
        acc[0] += a4[0] * b4[0];
        acc[1] += a4[1] * b4[1];
        acc[2] += a4[2] * b4[2];
        acc[3] += a4[3] * b4[3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (x, y) in ai.remainder().iter().zip(bi.remainder()) {
        s += x * y;
    }
    s
}

/// `C[m×n] = A[m×k] · B[n×k]ᵀ` — the QKᵀ form (both operands row-major
/// with k contiguous). Overwrites `c`.
pub fn gemm_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert!(a.len() >= m * k && b.len() >= n * k && c.len() >= m * n);
    for (i, a_row) in a.chunks_exact(k).take(m).enumerate() {
        let c_row = &mut c[i * n..(i + 1) * n];
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &b[j * k..(j + 1) * k];
            let b1 = &b[(j + 1) * k..(j + 2) * k];
            let b2 = &b[(j + 2) * k..(j + 3) * k];
            let b3 = &b[(j + 3) * k..(j + 4) * k];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for ((((&av, &v0), &v1), &v2), &v3) in
                a_row.iter().zip(b0).zip(b1).zip(b2).zip(b3)
            {
                s0 += av * v0;
                s1 += av * v1;
                s2 += av * v2;
                s3 += av * v3;
            }
            c_row[j] = s0;
            c_row[j + 1] = s1;
            c_row[j + 2] = s2;
            c_row[j + 3] = s3;
            j += 4;
        }
        while j < n {
            c_row[j] = dot(a_row, &b[j * k..(j + 1) * k]);
            j += 1;
        }
    }
}

/// `C[m×n] += A[m×k] · B[k×n]` — the PV form. Accumulates into `c`
/// (callers pass a zeroed or carried accumulator).
pub fn gemm_nn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && c.len() >= m * n);
    let mut p0 = 0;
    while p0 < k {
        let pc = KC.min(k - p0);
        let b_panel = &b[p0 * n..(p0 + pc) * n];
        for i in 0..m {
            let a_row = &a[i * k + p0..i * k + p0 + pc];
            let c_row = &mut c[i * n..(i + 1) * n];
            let mut p = 0;
            while p + 2 <= pc {
                let (a0, a1) = (a_row[p], a_row[p + 1]);
                if a0 != 0.0 || a1 != 0.0 {
                    let b0 = &b_panel[p * n..(p + 1) * n];
                    let b1 = &b_panel[(p + 1) * n..(p + 2) * n];
                    for ((cv, &v0), &v1) in c_row.iter_mut().zip(b0).zip(b1) {
                        *cv += a0 * v0 + a1 * v1;
                    }
                }
                p += 2;
            }
            if p < pc {
                let a0 = a_row[p];
                if a0 != 0.0 {
                    let b0 = &b_panel[p * n..(p + 1) * n];
                    for (cv, &v0) in c_row.iter_mut().zip(b0) {
                        *cv += a0 * v0;
                    }
                }
            }
        }
        p0 += pc;
    }
}

/// Batched matmul with size-1 batch-dim broadcasting (the IR `Matmul`
/// semantics shared by both executors). `shape` is the output shape;
/// `out` must be zero-filled and of `shape`'s size.
pub fn batched_matmul(
    a: &Tensor,
    b: &Tensor,
    transpose_rhs: bool,
    shape: &[usize],
    out: &mut [f32],
) {
    let rank = shape.len();
    let m = shape[rank - 2];
    let n = shape[rank - 1];
    let k = a.shape[rank - 1];
    let batch_shape = &shape[..rank - 2];
    let batch: usize = batch_shape.iter().product();
    debug_assert_eq!(out.len(), batch * m * n);
    for bi in 0..batch {
        // Per-axis broadcast mapping of the batch index (size-1 dims of
        // either operand map to 0), as in `Tensor::at_broadcast`.
        let (mut ab, mut bb) = (0usize, 0usize);
        let (mut astride, mut bstride) = (1usize, 1usize);
        let mut rem = bi;
        for ax in (0..batch_shape.len()).rev() {
            let ix = rem % batch_shape[ax];
            rem /= batch_shape[ax];
            if a.shape[ax] != 1 {
                ab += ix * astride;
            }
            if b.shape[ax] != 1 {
                bb += ix * bstride;
            }
            astride *= a.shape[ax];
            bstride *= b.shape[ax];
        }
        let a_off = ab * m * k;
        let b_off = bb * k * n; // n·k elements per batch either way
        let a_mat = &a.data[a_off..a_off + m * k];
        let c_mat = &mut out[bi * m * n..(bi + 1) * m * n];
        if transpose_rhs {
            gemm_nt(a_mat, &b.data[b_off..b_off + n * k], c_mat, m, n, k);
        } else {
            gemm_nn(a_mat, &b.data[b_off..b_off + k * n], c_mat, m, n, k);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[j * k + p];
                }
            }
        }
        c
    }

    fn naive_nn(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    fn fill(n: usize, seed: u64) -> Vec<f32> {
        (0..n)
            .map(|i| (((seed as f64) + i as f64 * 0.7).sin() * 0.5) as f32)
            .collect()
    }

    #[test]
    fn nt_matches_naive_over_odd_shapes() {
        for (m, n, k) in [(1, 1, 1), (3, 5, 7), (8, 8, 8), (5, 9, 130), (17, 4, 33)] {
            let a = fill(m * k, 1);
            let b = fill(n * k, 2);
            let mut c = vec![0.0; m * n];
            gemm_nt(&a, &b, &mut c, m, n, k);
            let want = naive_nt(&a, &b, m, n, k);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() <= 1e-4, "{m}x{n}x{k}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn nn_matches_naive_over_odd_shapes() {
        for (m, n, k) in [(1, 1, 1), (3, 5, 7), (8, 8, 8), (4, 6, 300), (17, 4, 129)] {
            let a = fill(m * k, 3);
            let b = fill(k * n, 4);
            let mut c = vec![0.0; m * n];
            gemm_nn(&a, &b, &mut c, m, n, k);
            let want = naive_nn(&a, &b, m, n, k);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() <= 1e-4, "{m}x{n}x{k}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn nn_zero_rows_skip_but_stay_exact() {
        let (m, n, k) = (2, 8, 64);
        let mut a = fill(m * k, 5);
        for p in (0..k).step_by(2) {
            a[p] = 0.0; // half the first row masked
        }
        let b = fill(k * n, 6);
        let mut c = vec![0.0; m * n];
        gemm_nn(&a, &b, &mut c, m, n, k);
        let want = naive_nn(&a, &b, m, n, k);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() <= 1e-4);
        }
    }

    #[test]
    fn batched_matmul_broadcasts_size_one_batch_dims() {
        // a: [2,1,3] nt b: [1,1,3] -> out [2,1,1] (the GQA pattern)
        let a = Tensor::from_vec(&[2, 1, 3], vec![1., 1., 1., 2., 2., 2.]);
        let b = Tensor::from_vec(&[1, 1, 3], vec![1., 2., 3.]);
        let mut out = vec![0.0; 2];
        batched_matmul(&a, &b, true, &[2, 1, 1], &mut out);
        assert_eq!(out, vec![6., 12.]);
    }
}
