//! Reference (eager) executor: node-by-node, materializing every
//! intermediate — the semantics eager PyTorch gives the paper's Listing 1.
//!
//! It is both the numerical oracle for the fused tiled executor and the
//! traffic baseline: every node is one kernel launch that reads its
//! operands from HBM and writes its result back.

use std::collections::HashMap;

use crate::exec::counters::Counters;
use crate::exec::simd;
use crate::exec::tensor::{for_each_row, Tensor};
use crate::ir::{CmpOp, Graph, NodeId, Op, PwOp, ReduceOp};

/// Append iota values along `axis` of a tensor with dims `lens` to
/// `data` (an empty buffer), starting the axis at `start`. Only
/// `idx[axis]` matters, so the fill runs in (outer, value, inner) runs.
/// Shared by the eager executor and both tiled paths so there is one
/// implementation to keep bit-stable.
pub(crate) fn iota_fill(data: &mut Vec<f32>, lens: &[usize], axis: usize, start: usize) {
    let n: usize = lens.iter().product();
    let inner: usize = lens[axis + 1..].iter().product();
    let count = lens[axis];
    let outer: usize = lens[..axis].iter().product();
    if n > 0 {
        for _ in 0..outer.max(1) {
            for j in 0..count {
                data.resize(data.len() + inner, (start + j) as f32);
            }
        }
    }
    debug_assert_eq!(data.len(), n);
}

/// Generic pointwise element loop: gather each operand's element `i`,
/// apply `op`, push. The slow-path kernel shared by the eager executor
/// and both tiled paths (their fast paths special-case 1/2-operand ops)
/// so a semantics change — operand arity, NaN policy — lands everywhere
/// at once. `T` is anything that derefs to a tensor (`&Tensor`, `Rc`).
pub(crate) fn pointwise_fill<T>(data: &mut Vec<f32>, op: PwOp, operands: &[T], n: usize)
where
    T: std::ops::Deref<Target = Tensor>,
{
    let mut args = [0f32; 3];
    for i in 0..n {
        for (j, t) in operands.iter().enumerate() {
            args[j] = t.data[i];
        }
        data.push(eval_pw(op, &args[..operands.len()]));
    }
}

/// Row-contiguous reduction of `src` along `axis` into `out`, which the
/// caller pre-fills with the reduce identity. The combine order is the
/// bit-stability contract shared by the eager and fused executors: both
/// call this one implementation, so fused-vs-eager parity can never
/// drift. When the reduced axis is innermost, rows fold through the
/// SIMD tier's striped-8 reduction (`simd::row_sum` / `simd::row_max`);
/// otherwise the inner dimension folds element-wise row by row — both
/// bit-identical at every dispatch level.
pub(crate) fn reduce_rows_into(src: &Tensor, axis: usize, op: ReduceOp, out: &mut [f32]) {
    let inner: usize = src.shape[axis + 1..].iter().product();
    let count = src.shape[axis];
    let outer: usize = src.shape[..axis].iter().product();
    if inner == 1 {
        for o in 0..outer {
            let row = &src.data[o * count..(o + 1) * count];
            let reduced = match op {
                ReduceOp::Sum => simd::row_sum(row),
                ReduceOp::Max => simd::row_max(row),
            };
            out[o] = op.combine(out[o], reduced);
        }
    } else {
        for o in 0..outer {
            let dst = &mut out[o * inner..(o + 1) * inner];
            for j in 0..count {
                let s_off = (o * count + j) * inner;
                let row = &src.data[s_off..s_off + inner];
                match op {
                    ReduceOp::Sum => simd::vadd_assign(dst, row),
                    ReduceOp::Max => simd::vmax_assign(dst, row),
                }
            }
        }
    }
}

pub fn eval_pw(op: PwOp, args: &[f32]) -> f32 {
    match op {
        PwOp::Add => args[0] + args[1],
        PwOp::Sub => args[0] - args[1],
        PwOp::Mul => args[0] * args[1],
        PwOp::Div => args[0] / args[1],
        PwOp::Neg => -args[0],
        // exp/sigmoid land on the shared SIMD-tier kernel (one
        // polynomial for every executor and dispatch level, so parity
        // between eager, fused, scalar, and vector paths is bitwise).
        PwOp::Exp => simd::exp_f32(args[0]),
        PwOp::Exp2 => args[0].exp2(),
        PwOp::Tanh => args[0].tanh(),
        PwOp::Sigmoid => simd::sigmoid_f32(args[0]),
        PwOp::Recip => 1.0 / args[0],
        PwOp::Sqrt => args[0].sqrt(),
        PwOp::Rsqrt => 1.0 / args[0].sqrt(),
        PwOp::Abs => args[0].abs(),
        PwOp::Maximum => args[0].max(args[1]),
        PwOp::Minimum => args[0].min(args[1]),
        PwOp::Where => {
            if args[0] != 0.0 {
                args[1]
            } else {
                args[2]
            }
        }
        PwOp::Cmp(c) => {
            let (a, b) = (args[0], args[1]);
            let t = match c {
                CmpOp::Le => a <= b,
                CmpOp::Lt => a < b,
                CmpOp::Ge => a >= b,
                CmpOp::Gt => a > b,
                CmpOp::Eq => a == b,
                CmpOp::Ne => a != b,
                CmpOp::And => a != 0.0 && b != 0.0,
                CmpOp::Or => a != 0.0 || b != 0.0,
            };
            if t {
                1.0
            } else {
                0.0
            }
        }
        PwOp::MulAdd => args[0] * args[1] + args[2],
        PwOp::MulScalar(s) => args[0] * s,
        PwOp::AddScalar(s) => args[0] + s,
    }
}

/// Evaluate one node given its operand tensors.
pub fn eval_node(node_op: &Op, shape: &[usize], operands: &[&Tensor]) -> Tensor {
    match node_op {
        Op::Input { .. } => panic!("inputs are provided, not evaluated"),
        Op::Const { value } => Tensor::full(shape, *value),
        Op::Iota { axis } => {
            let mut data = Vec::with_capacity(shape.iter().product());
            iota_fill(&mut data, shape, *axis, 0);
            Tensor::from_vec(shape, data)
        }
        Op::Pointwise { op, .. } => {
            let n: usize = shape.iter().product();
            let mut data = Vec::with_capacity(n);
            // Unary exp/sigmoid take the vectorized slice kernel
            // (bit-identical to the per-element generic loop).
            match (operands.len(), *op) {
                (1, PwOp::Exp) => simd::vexp_append(&mut data, &operands[0].data),
                (1, PwOp::Sigmoid) => simd::vsigmoid_append(&mut data, &operands[0].data),
                // Uniform-condition select degenerates to a copy of one
                // branch — bit-identical to the element loop (`Where` is
                // `if c != 0.0 { a } else { b }` per element), and the
                // eager-side analogue of the tiled executor's Full/Empty
                // tile elision: masked score tensors are uniform over
                // large mask-aligned spans.
                (3, PwOp::Where) => {
                    let cond = &operands[0].data;
                    if cond.iter().all(|&c| c != 0.0) {
                        data.extend_from_slice(&operands[1].data);
                    } else if cond.iter().all(|&c| c == 0.0) {
                        data.extend_from_slice(&operands[2].data);
                    } else {
                        pointwise_fill(&mut data, *op, operands, n);
                    }
                }
                _ => pointwise_fill(&mut data, *op, operands, n),
            }
            Tensor::from_vec(shape, data)
        }
        Op::Broadcast { .. } => operands[0].broadcast_to(shape),
        Op::Reduce { op, axis, .. } => {
            let src = operands[0];
            let mut out = Tensor::full(shape, op.identity());
            reduce_rows_into(src, *axis, *op, &mut out.data);
            out
        }
        Op::Matmul { transpose_rhs, .. } => {
            // Cache-blocked microkernels in `exec::gemm` (NT and NN
            // forms) — shared with the tiled executor's tile matmuls.
            let mut out = Tensor::zeros(shape);
            crate::exec::gemm::batched_matmul(
                operands[0],
                operands[1],
                *transpose_rhs,
                shape,
                &mut out.data,
            );
            out
        }
        Op::Slice { axis, start, .. } => {
            // Row-wise copies: every output row (the contiguous last
            // axis) is contiguous in the source too — including when
            // the sliced axis *is* the last axis (the row then starts
            // `start` elements in). One copy_from_slice per row.
            let src = operands[0];
            let mut out = Tensor::zeros(shape);
            let rank = shape.len();
            if rank > 0 {
                let row = shape[rank - 1];
                let src_strides = src.strides();
                let mut dof = 0usize;
                for_each_row(shape, |idx| {
                    let mut soff = if *axis == rank - 1 { *start } else { 0 };
                    for ax in 0..rank - 1 {
                        let j = idx[ax] + if ax == *axis { *start } else { 0 };
                        soff += j * src_strides[ax];
                    }
                    out.data[dof..dof + row]
                        .copy_from_slice(&src.data[soff..soff + row]);
                    dof += row;
                });
            }
            out
        }
    }
}

/// Flop cost of evaluating one node (FMA = 2).
pub fn node_flops(g: &Graph, id: NodeId) -> u64 {
    let node = g.node(id);
    match &node.op {
        Op::Matmul { lhs, .. } => {
            let k = g.node(*lhs).shape.last().copied().unwrap_or(1);
            (2 * g.numel(id) * k) as u64
        }
        Op::Reduce { input, .. } => g.numel(*input) as u64,
        Op::Pointwise { .. } => g.numel(id) as u64,
        _ => 0,
    }
}

/// Evaluate the whole graph eagerly. Returns output tensors + counters.
pub fn eval(g: &Graph, inputs: &HashMap<String, Tensor>) -> (Vec<Tensor>, Counters) {
    let mut values: Vec<Option<Tensor>> = vec![None; g.nodes.len()];
    let mut c = Counters::default();
    let mut live_bytes: u64 = 0;
    for id in g.ids() {
        let node = g.node(id);
        if let Op::Input { name } = &node.op {
            let t = inputs
                .get(name)
                .unwrap_or_else(|| panic!("missing input {name}"))
                .clone();
            assert_eq!(t.shape, node.shape, "input {name} shape");
            values[id.0 as usize] = Some(t);
            continue;
        }
        let operand_ids = node.op.input_ids();
        let operands: Vec<&Tensor> = operand_ids
            .iter()
            .map(|i| values[i.0 as usize].as_ref().expect("topo order"))
            .collect();
        // Traffic: one kernel per node — read operands, write result.
        for &oid in &operand_ids {
            c.read_elems(g.numel(oid));
        }
        c.write_elems(g.numel(id));
        c.flops += node_flops(g, id);
        c.launches += 1;
        let out = eval_node(&node.op, &node.shape, &operands);
        live_bytes += 4 * out.numel() as u64;
        c.peak_workspace = c.peak_workspace.max(live_bytes);
        values[id.0 as usize] = Some(out);
    }
    let outs = g
        .outputs
        .iter()
        .map(|o| values[o.0 as usize].clone().expect("output"))
        .collect();
    (outs, c)
}

/// Analytic eager counters (no data): identical to what [`eval`] reports.
pub fn eager_counters(g: &Graph) -> Counters {
    let mut c = Counters::default();
    let mut live: u64 = 0;
    for id in g.ids() {
        let node = g.node(id);
        if matches!(node.op, Op::Input { .. }) {
            continue;
        }
        for oid in node.op.input_ids() {
            c.read_elems(g.numel(oid));
        }
        c.write_elems(g.numel(id));
        c.flops += node_flops(g, id);
        c.launches += 1;
        live += 4 * g.numel(id) as u64;
        c.peak_workspace = c.peak_workspace.max(live);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    #[test]
    fn softmax_numerics() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 4]);
        let s = b.softmax(x, 1);
        let g = b.finish(&[s]);
        let mut inp = HashMap::new();
        inp.insert(
            "x".to_string(),
            Tensor::from_vec(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]),
        );
        let (outs, _) = eval(&g, &inp);
        let sum: f32 = outs[0].data.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        // softmax of [1,2,3,4]: last element e^0 / sum(e^-3..e^0)
        let expect = 1.0 / (1.0 + (-1.0f32).exp() + (-2.0f32).exp() + (-3.0f32).exp());
        assert!((outs[0].data[3] - expect).abs() < 1e-6);
    }

    #[test]
    fn matmul_nt_matches_manual() {
        let mut b = GraphBuilder::new("t");
        let q = b.input("q", &[1, 2, 3]);
        let k = b.input("k", &[1, 2, 3]);
        let s = b.matmul_nt(q, k);
        let g = b.finish(&[s]);
        let mut inp = HashMap::new();
        inp.insert(
            "q".to_string(),
            Tensor::from_vec(&[1, 2, 3], vec![1., 0., 0., 0., 1., 0.]),
        );
        inp.insert(
            "k".to_string(),
            Tensor::from_vec(&[1, 2, 3], vec![1., 2., 3., 4., 5., 6.]),
        );
        let (outs, _) = eval(&g, &inp);
        assert_eq!(outs[0].data, vec![1., 4., 2., 5.]);
    }

    #[test]
    fn eval_counters_match_analytic() {
        let mut b = GraphBuilder::new("t");
        let q = b.input("q", &[2, 8, 4]);
        let k = b.input("k", &[2, 8, 4]);
        let v = b.input("v", &[2, 8, 4]);
        let s = b.matmul_nt(q, k);
        let w = b.softmax(s, 2);
        let o = b.matmul(w, v);
        let g = b.finish(&[o]);
        let mut inp = HashMap::new();
        inp.insert("q".into(), Tensor::synthetic(&[2, 8, 4], 1));
        inp.insert("k".into(), Tensor::synthetic(&[2, 8, 4], 2));
        inp.insert("v".into(), Tensor::synthetic(&[2, 8, 4], 3));
        let (_, c1) = eval(&g, &inp);
        let c2 = eager_counters(&g);
        assert_eq!(c1, c2);
    }

    #[test]
    fn iota_and_cmp_build_causal_mask() {
        let mut b = GraphBuilder::new("t");
        let qi = b.iota(&[3, 3], 0);
        let ki = b.iota(&[3, 3], 1);
        let keep = b.cmp(crate::ir::CmpOp::Le, ki, qi);
        let g = b.finish(&[keep]);
        let (outs, _) = eval(&g, &HashMap::new());
        assert_eq!(outs[0].data, vec![1., 0., 0., 1., 1., 0., 1., 1., 1.]);
    }

    #[test]
    fn where_uniform_cond_fast_path_is_bitwise() {
        let a = Tensor::from_vec(&[4], vec![1.5, -2.0, 3.25, 0.0]);
        let b = Tensor::from_vec(&[4], vec![-9.0, 0.5, 7.0, -1e30]);
        for cond in [
            vec![1.0f32, 1.0, 1.0, 1.0],
            vec![0.0, 0.0, 0.0, 0.0],
            vec![1.0, 0.0, 0.0, 1.0],
        ] {
            let c = Tensor::from_vec(&[4], cond);
            let op = Op::Pointwise {
                op: PwOp::Where,
                inputs: vec![],
            };
            let got = eval_node(&op, &[4], &[&c, &a, &b]);
            let mut want = Vec::new();
            pointwise_fill(&mut want, PwOp::Where, &[&c, &a, &b], 4);
            assert_eq!(got.data, want);
        }
    }

    #[test]
    fn gqa_matmul_broadcasts_kv_batch() {
        // lhs batch 4, rhs batch 2 (broadcast cyclically is NOT what we
        // want; we want block repeat — verify the modulo behaviour used
        // by variants: kv head h maps to h % hkv after head reordering).
        let mut b = GraphBuilder::new("t");
        let a = b.input("a", &[2, 1, 3]);
        let k = b.input("k", &[1, 1, 3]);
        let s = b.matmul_nt(a, k);
        let g = b.finish(&[s]);
        let mut inp = HashMap::new();
        inp.insert(
            "a".into(),
            Tensor::from_vec(&[2, 1, 3], vec![1., 1., 1., 2., 2., 2.]),
        );
        inp.insert("k".into(), Tensor::from_vec(&[1, 1, 3], vec![1., 2., 3.]));
        let (outs, _) = eval(&g, &inp);
        assert_eq!(outs[0].data, vec![6., 12.]);
    }
}
