//! Cache/NUMA topology detection for the persistent worker runtime.
//!
//! The scheduler in [`crate::exec::runtime`] range-partitions each
//! launch grid into per-domain shards so workers claim blocks that are
//! near their cache first and steal across domains only when their own
//! shard runs dry. A *domain* is a set of hardware threads that share a
//! last-level cache (or a NUMA node) — work scheduled within one domain
//! reuses packed panels and gathered tiles out of the shared cache
//! instead of bouncing lines across the interconnect.
//!
//! Detection order:
//!
//! 1. `FLASHLIGHT_TOPO` override — `flat` (one domain), `DxW`
//!    (`D` domains of `W` hardware threads, e.g. `2x8`), or a comma
//!    list of per-domain thread counts (e.g. `8,8,4`). Invalid specs
//!    warn once and fall back to detection. This is how tests exercise
//!    adversarial topologies and how exotic hosts (heterogeneous
//!    clusters, containers with misleading sysfs) pin the layout.
//! 2. Linux sysfs — NUMA nodes (`/sys/devices/system/node/node*/
//!    cpulist`) when there is more than one; otherwise L3 domains
//!    (`cpu*/cache/index3/shared_cpu_list` grouping, the
//!    multi-CCX/chiplet case).
//! 3. Flat fallback — one domain spanning every available thread.
//!
//! Topology only ever affects *scheduling*: shard boundaries and steal
//! order. Outputs and [`crate::exec::Counters`] are bit-identical under
//! every topology because the runtime merges results in index order
//! (property-tested in `rust/tests/runtime_sched.rs`).
//!
//! Note on pinning: the runtime does not call `sched_setaffinity` —
//! std exposes no affinity API and the offline build image carries no
//! `libc` crate — so domain assignment is advisory (the OS scheduler
//! keeps parked threads where they last ran, which in practice holds
//! workers inside their domain between launches).

use std::collections::BTreeMap;

/// Hardware-thread grouping used to shard launch grids. `domains[d]`
/// is the relative weight (hardware thread count) of domain `d`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    domains: Vec<usize>,
    /// Where this layout came from (diagnostics / bench JSON).
    source: &'static str,
}

impl Topology {
    /// A single domain of `threads` hardware threads (the no-locality
    /// layout; also the `FLASHLIGHT_TOPO=flat` override).
    pub fn flat(threads: usize) -> Self {
        Topology {
            domains: vec![threads.max(1)],
            source: "flat",
        }
    }

    /// A topology from explicit per-domain thread counts.
    pub fn from_domains(domains: Vec<usize>, source: &'static str) -> Self {
        let domains: Vec<usize> = domains.into_iter().filter(|&c| c > 0).collect();
        if domains.is_empty() {
            return Topology::flat(available_threads());
        }
        Topology { domains, source }
    }

    /// Parse a `FLASHLIGHT_TOPO` spec: `flat`, `DxW`, or `c0,c1,...`.
    pub fn parse_spec(spec: &str) -> Result<Self, String> {
        let s = spec.trim();
        if s.eq_ignore_ascii_case("flat") {
            return Ok(Topology::flat(available_threads()));
        }
        if let Some((d, w)) = s.split_once(['x', 'X']) {
            let d: usize = d.trim().parse().map_err(|_| format!("bad domain count in {spec:?}"))?;
            let w: usize = w.trim().parse().map_err(|_| format!("bad domain width in {spec:?}"))?;
            if d == 0 || w == 0 {
                return Err(format!("zero extent in {spec:?}"));
            }
            return Ok(Topology::from_domains(vec![w; d], "env"));
        }
        let counts: Result<Vec<usize>, _> = s.split(',').map(|c| c.trim().parse::<usize>()).collect();
        match counts {
            Ok(c) if !c.is_empty() && c.iter().all(|&x| x > 0) => {
                Ok(Topology::from_domains(c, "env"))
            }
            _ => Err(format!("unparseable FLASHLIGHT_TOPO {spec:?} (want flat, DxW, or c0,c1,...)")),
        }
    }

    /// Resolve the host topology: env override, then sysfs, then flat.
    pub fn detect() -> Self {
        if let Ok(spec) = std::env::var("FLASHLIGHT_TOPO") {
            match Topology::parse_spec(&spec) {
                Ok(t) => return t,
                Err(e) => eprintln!("flashlight: ignoring {e}; auto-detecting topology"),
            }
        }
        if let Some(t) = detect_sysfs() {
            return t;
        }
        Topology::flat(available_threads())
    }

    pub fn n_domains(&self) -> usize {
        self.domains.len()
    }

    /// Per-domain hardware-thread weights.
    pub fn weights(&self) -> &[usize] {
        &self.domains
    }

    pub fn source(&self) -> &'static str {
        self.source
    }

    /// Compact description for logs/bench JSON, e.g. `numa:8,8`.
    pub fn describe(&self) -> String {
        let counts: Vec<String> = self.domains.iter().map(|c| c.to_string()).collect();
        format!("{}:{}", self.source, counts.join(","))
    }

    /// Distribute `k` workers over the domains proportionally to their
    /// weights (largest-remainder rounding, ties to the lower domain
    /// index). Always sums to `k`; domains may receive zero workers
    /// when `k < n_domains()`.
    pub fn assign_workers(&self, k: usize) -> Vec<usize> {
        proportional_split(&self.domains, k)
    }
}

/// Largest-remainder proportional split of `total` units over `weights`.
/// Deterministic: floors first, then hands remainders to the largest
/// fractional parts (ties broken by lower index). Sums to `total`.
pub fn proportional_split(weights: &[usize], total: usize) -> Vec<usize> {
    let w_sum: usize = weights.iter().sum();
    if w_sum == 0 || weights.is_empty() {
        let mut out = vec![0; weights.len().max(1)];
        out[0] = total;
        return out;
    }
    let mut out = Vec::with_capacity(weights.len());
    let mut rems: Vec<(usize, usize)> = Vec::with_capacity(weights.len()); // (remainder, idx)
    let mut assigned = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        let num = total * w;
        out.push(num / w_sum);
        assigned += num / w_sum;
        rems.push((num % w_sum, i));
    }
    // Largest remainder first; ties to the lower index.
    rems.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    for &(_, i) in rems.iter().take(total - assigned) {
        out[i] += 1;
    }
    out
}

fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Count the entries of a sysfs cpulist like `0-3,8-11`.
fn cpulist_len(list: &str) -> usize {
    let mut n = 0usize;
    for part in list.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.split_once('-') {
            Some((a, b)) => {
                if let (Ok(a), Ok(b)) = (a.parse::<usize>(), b.parse::<usize>()) {
                    n += b.saturating_sub(a) + 1;
                }
            }
            None => {
                if part.parse::<usize>().is_ok() {
                    n += 1;
                }
            }
        }
    }
    n
}

/// Linux sysfs detection: NUMA nodes first, then L3 sharing groups.
fn detect_sysfs() -> Option<Topology> {
    // NUMA nodes with their cpu counts.
    if let Ok(rd) = std::fs::read_dir("/sys/devices/system/node") {
        let mut nodes: BTreeMap<usize, usize> = BTreeMap::new();
        for e in rd.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy().into_owned();
            if let Some(idx) = name.strip_prefix("node").and_then(|s| s.parse::<usize>().ok()) {
                if let Ok(list) = std::fs::read_to_string(e.path().join("cpulist")) {
                    let n = cpulist_len(&list);
                    if n > 0 {
                        nodes.insert(idx, n);
                    }
                }
            }
        }
        if nodes.len() > 1 {
            return Some(Topology::from_domains(nodes.into_values().collect(), "numa"));
        }
    }
    // Single node: group hardware threads by their shared L3.
    if let Ok(rd) = std::fs::read_dir("/sys/devices/system/cpu") {
        let mut l3: BTreeMap<String, usize> = BTreeMap::new();
        let mut cpus = 0usize;
        for e in rd.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy().into_owned();
            let is_cpu = name
                .strip_prefix("cpu")
                .is_some_and(|s| !s.is_empty() && s.chars().all(|c| c.is_ascii_digit()));
            if !is_cpu {
                continue;
            }
            cpus += 1;
            if let Ok(list) = std::fs::read_to_string(e.path().join("cache/index3/shared_cpu_list")) {
                *l3.entry(list.trim().to_string()).or_insert(0) += 1;
            }
        }
        if l3.len() > 1 && l3.values().sum::<usize>() == cpus {
            return Some(Topology::from_domains(l3.into_values().collect(), "l3"));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing_covers_all_forms() {
        assert_eq!(Topology::parse_spec("flat").unwrap().n_domains(), 1);
        let t = Topology::parse_spec("2x8").unwrap();
        assert_eq!(t.weights(), &[8, 8]);
        let t = Topology::parse_spec("8, 8, 4").unwrap();
        assert_eq!(t.weights(), &[8, 8, 4]);
        assert!(Topology::parse_spec("").is_err());
        assert!(Topology::parse_spec("0x4").is_err());
        assert!(Topology::parse_spec("a,b").is_err());
        assert!(Topology::parse_spec("4,0,4").is_err());
    }

    #[test]
    fn proportional_split_sums_and_balances() {
        assert_eq!(proportional_split(&[1, 1], 4), vec![2, 2]);
        assert_eq!(proportional_split(&[8, 8, 4], 5), vec![2, 2, 1]);
        // fewer units than domains: lower indexes win ties
        assert_eq!(proportional_split(&[1, 1, 1, 1], 2).iter().sum::<usize>(), 2);
        assert_eq!(proportional_split(&[1, 7], 8), vec![1, 7]);
        assert_eq!(proportional_split(&[3], 10), vec![10]);
        assert_eq!(proportional_split(&[0, 0], 3)[0], 3, "zero weights fall to domain 0");
        for (w, k) in [(vec![5usize, 3, 9], 7usize), (vec![2, 2], 1), (vec![1, 63], 4)] {
            assert_eq!(proportional_split(&w, k).iter().sum::<usize>(), k, "{w:?} {k}");
        }
    }

    #[test]
    fn cpulist_parsing() {
        assert_eq!(cpulist_len("0-3,8-11"), 8);
        assert_eq!(cpulist_len("0"), 1);
        assert_eq!(cpulist_len("0-15"), 16);
        assert_eq!(cpulist_len(""), 0);
        assert_eq!(cpulist_len("2,4,6"), 3);
    }

    #[test]
    fn detect_always_yields_a_usable_topology() {
        let t = Topology::detect();
        assert!(t.n_domains() >= 1);
        assert!(t.weights().iter().all(|&c| c > 0));
        assert!(!t.describe().is_empty());
    }
}
