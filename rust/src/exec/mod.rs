//! Executors for the simulated device: the eager reference interpreter
//! (numerical oracle + traffic baseline) and the fused tiled executor
//! (runs the flashlight-compiled kernel groups tile-by-tile with the
//! online-softmax rewrite, counting HBM traffic it actually generates).
//!
//! The tiled executor is a data-parallel engine: pipeline groups run
//! their (batch, head, q-tile) launch grid across threads
//! ([`Parallelism`]) with per-thread scratch pools ([`TilePool`]), and
//! both executors' matmuls go through the cache-blocked microkernels in
//! [`gemm`]. See `rust/src/exec/README.md` for the architecture.

mod counters;
mod gemm;
mod parallel;
mod pool;
mod reference;
mod tensor;
pub mod tiled;

pub use counters::Counters;
pub use gemm::{batched_matmul, gemm_nn, gemm_nt};
pub use parallel::{parallel_map_with, Parallelism};
pub use pool::TilePool;
pub use reference::{eager_counters, eval, eval_node, eval_pw, node_flops};
pub use tensor::{flat_index, for_each_index, for_each_row, strides_of, Tensor, NEG_INF};
pub use tiled::{execute_plan, execute_plan_par, execute_plans_batched, PlanJob};
