//! Executors for the simulated device: the eager reference interpreter
//! (numerical oracle + traffic baseline) and the fused tiled executor
//! (runs the flashlight-compiled kernel groups tile-by-tile with the
//! online-softmax rewrite, counting HBM traffic it actually generates).

mod counters;
mod reference;
mod tensor;
pub mod tiled;

pub use counters::Counters;
pub use reference::{eager_counters, eval, eval_node, eval_pw, node_flops};
pub use tensor::{flat_index, for_each_index, strides_of, Tensor, NEG_INF};
pub use tiled::execute_plan;
