//! Executors for the simulated device: the eager reference interpreter
//! (numerical oracle + traffic baseline) and the fused tiled executor
//! (runs the flashlight-compiled kernel groups tile-by-tile with the
//! online-softmax rewrite, counting HBM traffic it actually generates).
//!
//! The tiled executor is a data-parallel engine: pipeline groups run
//! their (batch, head, q-tile) launch grid over the persistent
//! topology-aware worker runtime ([`runtime`]: process-lifetime pool,
//! per-domain grid shards, hierarchical work stealing) configured by
//! [`Parallelism`], with per-thread scratch pools ([`TilePool`]), and
//! both executors' numerics land on the runtime-dispatched SIMD kernel
//! tier ([`simd`]: AVX2+FMA / NEON / scalar, `FLASHLIGHT_SIMD=0` kill
//! switch) through the GEMM wrappers in [`gemm`], the shared
//! exp/sigmoid kernels, and the striped row reductions. Scalar and
//! vector tiers are bit-identical by construction, so dispatch never
//! perturbs the determinism gates. See `rust/src/exec/README.md` for
//! the architecture.

mod counters;
mod gemm;
mod parallel;
mod pool;
mod reference;
pub mod runtime;
pub mod simd;
mod tensor;
pub mod tiled;
pub mod topology;

pub use counters::Counters;
pub use gemm::{batched_matmul, gemm_nn, gemm_nt, gemm_nt_packed, PackedB};
pub use parallel::{parallel_map_with, parallel_map_with_weights, Parallelism};
pub use pool::TilePool;
pub use topology::Topology;
pub use reference::{eager_counters, eval, eval_node, eval_pw, node_flops};
pub use simd::SimdLevel;
pub use tensor::{flat_index, for_each_index, for_each_row, strides_of, Tensor, NEG_INF};
pub use tiled::{
    batch_panic_job, execute_plan, execute_plan_par, execute_plans_batched, BatchPanic, CpuRunner,
    PlanJob, PlanRunner,
};
