//! Fused tiled executor: runs a [`Plan`](crate::fusion::Plan) the way the
//! generated Triton kernel would — pipeline groups execute tile-by-tile
//! with the online-softmax rewrite, never materializing the (S, S)
//! intermediates; other groups execute as single kernels through the
//! shared [`TilePool`].
//!
//! The executor counts the HBM traffic it *actually* generates (every
//! `Input`/materialized-tensor tile read and every output tile write), so
//! `plan.counters()`'s analytic model is testable against real execution.
//!
//! ## The parallel engine
//!
//! A pipeline group's iteration space is the launch grid of §3.6: one
//! program instance per (batch…, head…, q-tile) block, modeled by
//! [`LogicalGrid`]. Blocks share only read-only state (graph, inputs,
//! previously materialized values), so a [`PipelineRun`] schedules them
//! over the persistent topology-aware worker runtime
//! ([`crate::exec::runtime`]: parked process-lifetime workers, per-
//! domain grid shards, hierarchical stealing) with per-thread scratch
//! ([`WorkerScratch`]: tile pool + online-softmax row states) that
//! survives across launches — packed panels and pooled tile buffers
//! stay warm between serving steps instead of being rebuilt per call.
//!
//! ## The multi-plan work queue
//!
//! [`execute_plans_batched`] runs *several* plans at once (the serving
//! engine's batched decode: one plan per active request). All plans that
//! are ready at a pipeline group contribute tagged work items
//! `(plan, block)` to **one** shared worker pool, so grid parallelism is
//! cross-request, not per-plan — a single-block decode step no longer
//! strands the other workers. [`execute_plan_par`] is the one-job case.
//!
//! Determinism: each block computes with exactly the code a sequential
//! run uses and *logs* its operand-region fetches instead of counting
//! them; the scheduler thread merges each plan's blocks in grid order,
//! replaying the touch logs against that plan's group-level seen-set.
//! Outputs and [`Counters`] — including the HBM-vs-L2 split, which
//! depends on first-touch order — are therefore bit-identical between
//! sequential, parallel, and batched multi-plan runs (asserted by
//! `rust/tests/parallel_parity.rs`).
//!
//! Memory: per-block tile values live in a copy-on-write memo of shared
//! (`Rc`) tensors — consumers retire their handle into the worker's
//! [`TilePool`], and the storage is reclaimed as soon as the last holder
//! lets go, so no duplicate copies are made for memoization.

use std::collections::{HashMap, HashSet};
use std::rc::Rc;
use std::sync::Arc;

use crate::exec::gemm;
use crate::exec::parallel::{parallel_map_with_weights, Parallelism};
use crate::exec::pool::TilePool;
use crate::exec::reference::{iota_fill, pointwise_fill, reduce_rows_into};
use crate::exec::{eval_node, eval_pw, node_flops, Counters, Tensor, NEG_INF};
use crate::fusion::{
    blockmask_enabled, classify_block_mask, BlockMask, GroupKind, MaskKind, OnlineRowState,
    Pipeline, Plan, TileClass, TileConfig,
};
use crate::grid::{LogicalGrid, TiledDim};
use crate::ir::{Graph, NodeId, Op};
use crate::sketch::{analyze, DimAnalysis};

/// Per-axis (start, len) region of a node's tensor.
type Region = Vec<(usize, usize)>;

/// One logged operand-region fetch: (node, region, elements). Replayed
/// in block order at merge time to attribute HBM vs L2 deterministically.
type Touch = (u32, Region, usize);

/// State shared read-only by every grid block of a pipeline group.
struct PipelineShared<'g> {
    g: &'g Graph,
    inputs: &'g HashMap<String, Tensor>,
    /// Materialized results of earlier groups (and graph inputs by id).
    values: &'g HashMap<NodeId, Tensor>,
}

/// Per-block evaluation context. `pool` (and the caller's row states)
/// live in the worker's [`WorkerScratch`] and persist across the blocks
/// that worker claims, so the k-tile loop is allocation-free at steady
/// state. Tile values are shared `Rc`s: the memo and the consumer hold
/// the same allocation (copy-on-write — no duplicate is ever made), and
/// [`TilePool::recycle_shared`] reclaims storage at the last release.
struct TiledCtx<'g, 'w> {
    sh: &'w PipelineShared<'g>,
    /// Values pinned by the pipeline driver (e.g. the PV accumulator).
    pinned: HashMap<NodeId, Rc<Tensor>>,
    memo: HashMap<(u32, Region), Rc<Tensor>>,
    touches: Vec<Touch>,
    flops: u64,
    pool: &'w mut TilePool,
    /// Plan tag scoping the worker's packed-panel cache within one
    /// batched launch (see [`TilePool::packed_nt_panel`]).
    tag: u64,
}

impl<'g, 'w> TiledCtx<'g, 'w> {
    /// Gather a sub-region of a full tensor into a pooled buffer and log
    /// the fetch (the merge step decides HBM vs L2).
    fn gather(&mut self, id: NodeId, t: &Tensor, region: &Region) -> Tensor {
        let lens: Vec<usize> = region.iter().map(|(_, l)| *l).collect();
        let n: usize = lens.iter().product();
        let rank = lens.len();
        let mut data = self.pool.take(n);
        if rank == 0 {
            data.push(t.data[0]);
        } else {
            // Row-wise copies: the last axis is contiguous in the source,
            // so decompose indices once per row, not once per element.
            let strides = t.strides();
            let row = lens[rank - 1];
            crate::exec::for_each_row(&lens, |idx| {
                let mut soff = region[rank - 1].0; // last-axis start
                for ax in 0..rank - 1 {
                    soff += (region[ax].0 + idx[ax]) * strides[ax];
                }
                data.extend_from_slice(&t.data[soff..soff + row]);
            });
            debug_assert_eq!(data.len(), n);
        }
        self.touches.push((id.0, region.clone(), n));
        Tensor::from_vec(&lens, data)
    }

    /// Evaluate `node` restricted to `region`, recursively. Regions
    /// propagate structurally: each op knows its operands' regions.
    /// Returns a shared handle; the memo keeps a clone of the same `Rc`
    /// (copy-on-write), so repeated requests are free.
    fn eval_region(&mut self, id: NodeId, region: &Region) -> Rc<Tensor> {
        if let Some(t) = self.pinned.get(&id) {
            return t.clone();
        }
        let key = (id.0, region.clone());
        if let Some(t) = self.memo.get(&key) {
            return t.clone();
        }
        // Materialized by an earlier group: read the tile from "HBM".
        let values = self.sh.values;
        if let Some(t) = values.get(&id) {
            let out = Rc::new(self.gather(id, t, region));
            self.memo.insert(key, out.clone());
            return out;
        }
        let g = self.sh.g;
        let node = g.node(id);
        let lens: Vec<usize> = region.iter().map(|(_, l)| *l).collect();
        let out = match &node.op {
            Op::Input { name } => {
                let inputs = self.sh.inputs;
                self.gather(id, &inputs[name], region)
            }
            Op::Const { value } => {
                let n: usize = lens.iter().product();
                let mut data = self.pool.take(n);
                data.resize(n, *value);
                Tensor::from_vec(&lens, data)
            }
            Op::Iota { axis } => {
                let n: usize = lens.iter().product();
                let mut data = self.pool.take(n);
                iota_fill(&mut data, &lens, *axis, region[*axis].0);
                Tensor::from_vec(&lens, data)
            }
            Op::Pointwise { op, inputs } => {
                let ts: Vec<Rc<Tensor>> = inputs
                    .iter()
                    .map(|&i| self.eval_region(i, region))
                    .collect();
                let n: usize = lens.iter().product();
                // Fast paths hoist the op dispatch out of the element
                // loop (the interpreter's hottest code).
                use crate::ir::PwOp;
                let mut data = self.pool.take(n);
                match (ts.len(), *op) {
                    (1, op1) => {
                        let a = &ts[0].data;
                        match op1 {
                            // exp/sigmoid: vectorized shared kernels
                            // (bit-identical to the eager executor's).
                            PwOp::Exp => crate::exec::simd::vexp_append(&mut data, a),
                            PwOp::Tanh => data.extend(a.iter().map(|x| x.tanh())),
                            PwOp::Sigmoid => {
                                crate::exec::simd::vsigmoid_append(&mut data, a)
                            }
                            PwOp::Neg => data.extend(a.iter().map(|x| -x)),
                            PwOp::MulScalar(s) => {
                                data.extend(a.iter().map(|x| x * s))
                            }
                            PwOp::AddScalar(s) => {
                                data.extend(a.iter().map(|x| x + s))
                            }
                            other => {
                                data.extend(a.iter().map(|&x| eval_pw(other, &[x])))
                            }
                        }
                    }
                    (2, op2) => {
                        let (a, b) = (&ts[0].data, &ts[1].data);
                        match op2 {
                            PwOp::Add => {
                                data.extend(a.iter().zip(b).map(|(x, y)| x + y))
                            }
                            PwOp::Sub => {
                                data.extend(a.iter().zip(b).map(|(x, y)| x - y))
                            }
                            PwOp::Mul => {
                                data.extend(a.iter().zip(b).map(|(x, y)| x * y))
                            }
                            PwOp::Div => {
                                data.extend(a.iter().zip(b).map(|(x, y)| x / y))
                            }
                            other => data.extend(
                                a.iter()
                                    .zip(b)
                                    .map(|(&x, &y)| eval_pw(other, &[x, y])),
                            ),
                        }
                    }
                    _ => pointwise_fill(&mut data, *op, &ts, n),
                }
                debug_assert_eq!(data.len(), n);
                let out = Tensor::from_vec(&lens, data);
                for t in ts {
                    self.pool.recycle_shared(t);
                }
                out
            }
            Op::Broadcast { input } => {
                let in_shape = &g.node(*input).shape;
                let op_region: Region = region
                    .iter()
                    .enumerate()
                    .map(|(ax, &(s, l))| if in_shape[ax] == 1 { (0, 1) } else { (s, l) })
                    .collect();
                let src = self.eval_region(*input, &op_region);
                let out = src.broadcast_to(&lens);
                self.pool.recycle_shared(src);
                out
            }
            Op::Slice {
                input,
                axis,
                start,
                ..
            } => {
                let op_region: Region = region
                    .iter()
                    .enumerate()
                    .map(|(ax, &(s, l))| if ax == *axis { (s + start, l) } else { (s, l) })
                    .collect();
                // Shared alias of the inner value: memoize the same Rc
                // under the slice key (copy-on-write, no duplicate).
                let inner = self.eval_region(*input, &op_region);
                self.memo.insert(key, inner.clone());
                return inner;
            }
            Op::Matmul {
                lhs,
                rhs,
                transpose_rhs,
            } => {
                let rank = region.len();
                let k_full = g.node(*lhs).shape[rank - 1];
                let lhs_shape = &g.node(*lhs).shape;
                let rhs_shape = &g.node(*rhs).shape;
                let mut lr: Region = vec![];
                let mut rr: Region = vec![];
                for ax in 0..rank - 2 {
                    let (s, l) = region[ax];
                    lr.push(if lhs_shape[ax] == 1 { (0, 1) } else { (s, l) });
                    rr.push(if rhs_shape[ax] == 1 { (0, 1) } else { (s, l) });
                }
                lr.push(region[rank - 2]);
                lr.push((0, k_full));
                if *transpose_rhs {
                    rr.push(region[rank - 1]);
                    rr.push((0, k_full));
                } else {
                    rr.push((0, k_full));
                    rr.push(region[rank - 1]);
                }
                let lt = self.eval_region(*lhs, &lr);
                let rt = self.eval_region(*rhs, &rr);
                let n: usize = lens.iter().product();
                let mut data = self.pool.take_zeroed(n);
                let (mm, nn) = (lens[rank - 2], lens[rank - 1]);
                if *transpose_rhs
                    && mm >= 2
                    && n == mm * nn
                    && crate::exec::simd::level().uses_panels()
                {
                    // In-pipeline QKᵀ tile (batch dims pinned to 1):
                    // pack the K tile once per (plan, node, k-region)
                    // into the worker's panel cache — amortized across
                    // every q-tile block this worker claims. The tile
                    // gather above already logged the fetch, so HBM/L2
                    // counters are identical with the cache cold or
                    // warm. Bit-neutral: the packed and plain kernels
                    // share per-element FMA chains.
                    let key = (self.tag, rhs.0, rr);
                    let bp = self.pool.packed_nt_panel(key, &rt.data, nn, k_full);
                    gemm::gemm_nt_packed(&lt.data, &bp, &mut data, mm, nn, k_full);
                } else {
                    gemm::batched_matmul(&lt, &rt, *transpose_rhs, &lens, &mut data);
                }
                self.pool.recycle_shared(lt);
                self.pool.recycle_shared(rt);
                Tensor::from_vec(&lens, data)
            }
            Op::Reduce { .. } => {
                panic!("reductions inside pipelines are handled by the driver")
            }
        };
        let out = Rc::new(out);
        self.memo.insert(key, out.clone());
        out
    }
}

/// Block-invariant pipeline geometry, computed once per group.
struct PipeMeta {
    out_shape: Vec<usize>,
    score_shape: Vec<usize>,
    q_ax_out: usize,
    q_ax_s: usize,
    kv_ax_s: usize,
    sk: usize,
    d_out: usize,
    has_sm: bool,
    outer_axes: Vec<usize>,
    bk: usize,
    /// score axis -> outer-coordinate slot pinned per block.
    score_outer_map: Vec<Option<usize>>,
    /// v axis -> outer-coordinate slot pinned per block.
    v_outer_map: Vec<Option<usize>>,
    v_src: NodeId,
    v_shape: Vec<usize>,
    /// m1 contraction extent (flops accounting).
    kdim: usize,
    m2: NodeId,
    m2_rank: usize,
}

/// Per-worker scratch, reused across all blocks a thread claims.
struct WorkerScratch {
    pool: TilePool,
    states: Vec<OnlineRowState>,
}

impl WorkerScratch {
    fn new() -> Self {
        WorkerScratch {
            pool: TilePool::new(),
            states: Vec::new(),
        }
    }
}

/// Result of one grid block, merged deterministically by the caller.
struct BlockOut {
    out_region: Region,
    tile: Tensor,
    touches: Vec<Touch>,
    flops: u64,
    tiles_visited: u64,
    tiles_skipped: u64,
    flops_avoided: u64,
    bytes_skipped: u64,
}

/// Resolved block-mask strategy for one pipeline run.
enum RunMask {
    /// Classified tile classes (index masks): skip `Empty` tiles, elide
    /// the mask/fill ops on `Full` tiles by evaluating `value` directly.
    Static { bm: Arc<BlockMask>, value: NodeId },
    /// Data-dependent threshold (`keep = score >= tau`): a coarse pass
    /// scores each raw tile, and the exact pass is pruned at runtime
    /// when the tile maximum falls below `tau` and every row of the
    /// q-tile is already live (the bitwise no-op condition).
    Dynamic { value: NodeId, tau: f32 },
}

/// Execute one (outer…, q-tile) program instance of a pipeline group.
#[allow(clippy::too_many_arguments)]
fn run_block(
    sh: &PipelineShared,
    pipe: &Pipeline,
    meta: &PipeMeta,
    grid: &LogicalGrid,
    mask: Option<&RunMask>,
    block: usize,
    scratch: &mut WorkerScratch,
    tag: u64,
) -> BlockOut {
    let coords = grid.delinearize(block);
    let q_dim = coords.len() - 1;
    let outer_idx = &coords[..q_dim];
    let (qt, cq) = grid.tile_range(q_dim, coords[q_dim]);

    let WorkerScratch { pool, states } = scratch;
    let mut ctx = TiledCtx {
        sh,
        pinned: HashMap::new(),
        memo: HashMap::new(),
        touches: Vec::new(),
        flops: 0,
        pool,
        tag,
    };

    // Score region template (per kv tile) for this block.
    let mut score_region: Region = meta.score_shape.iter().map(|&s| (0, s)).collect();
    for (ax_s, slot) in meta.score_outer_map.iter().enumerate() {
        if let Some(i) = slot {
            score_region[ax_s] = (outer_idx[*i], 1);
        }
    }
    score_region[meta.q_ax_s] = (qt, cq);

    // Static tile classes for this block's (dep, q-tile) row. `dep_index`
    // reads only the outer (non-q/kv) axes of the region, which are
    // already pinned above.
    let static_mask = match mask {
        Some(RunMask::Static { bm, value }) => {
            Some((bm, *value, bm.dep_index(&score_region), coords[q_dim]))
        }
        _ => None,
    };
    let mut tiles_visited = 0u64;
    let mut tiles_skipped = 0u64;
    let mut flops_avoided = 0u64;
    let mut bytes_skipped = 0u64;

    // Online state per q row (worker-resident, reset per block).
    if meta.has_sm {
        for st in states.iter_mut().take(cq) {
            st.m = f32::NEG_INFINITY;
            st.l = 0.0;
            st.acc.clear();
            st.acc.resize(meta.d_out, 0.0);
        }
        while states.len() < cq {
            states.push(OnlineRowState::new(meta.d_out));
        }
    }
    let mut plain_acc = if meta.has_sm {
        Vec::new()
    } else {
        ctx.pool.take_zeroed(cq * meta.d_out)
    };

    let v_rank = meta.v_shape.len();
    let mut kt = 0;
    while kt < meta.sk {
        let ck = meta.bk.min(meta.sk - kt);

        // Which node yields this k-tile's scores: the full masked score
        // graph by default, the unmasked `value` on provably-Full tiles
        // (Where(keep, v, fill) == v bitwise when keep is 1 everywhere).
        let mut score_node = pipe.score_root;
        if let Some((bm, value, dep, qti)) = &static_mask {
            match bm.class(*dep, *qti, kt / meta.bk) {
                TileClass::Empty => {
                    // Provably all-masked, and no q-row of this tile is
                    // dead everywhere (classification demotes such tiles
                    // to Partial): the dense online-softmax update is a
                    // bitwise no-op here, so skip the tile without
                    // gathering K or V.
                    tiles_skipped += 1;
                    flops_avoided += (2 * cq * ck * meta.d_out
                        + 4 * cq * ck
                        + 2 * cq * ck * meta.kdim) as u64;
                    bytes_skipped += (4 * ck * (meta.kdim + meta.d_out)) as u64;
                    kt += ck;
                    continue;
                }
                TileClass::Full => score_node = *value,
                TileClass::Partial => {}
            }
        }

        let mut sr = score_region.clone();
        sr[meta.kv_ax_s] = (kt, ck);

        // Runtime data-dependent mask: a coarse first pass scores the
        // raw tile; the exact pass is pruned when the tile max is below
        // tau *and* every row already has a live column (fresh rows have
        // m == -inf and always fail the guard, so the first tile of each
        // row is never pruned and the finalize path stays dense-exact).
        if let Some(RunMask::Dynamic { value, tau }) = mask {
            let raw = ctx.eval_region(*value, &sr);
            let tile_max = raw.data.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
            ctx.pool.recycle_shared(raw);
            if tile_max < *tau && states.iter().take(cq).all(|st| st.m > NEG_INF) {
                tiles_skipped += 1;
                flops_avoided += (2 * cq * ck * meta.d_out + 4 * cq * ck) as u64;
                bytes_skipped += (4 * ck * meta.d_out) as u64;
                kt += ck;
                continue;
            }
        }

        let s_tile = ctx.eval_region(score_node, &sr);
        // v tile: [.., ck, d]
        let vr: Region = meta
            .v_shape
            .iter()
            .enumerate()
            .map(|(ax, &s)| {
                if s == 1 {
                    (0, 1)
                } else if ax == v_rank - 2 {
                    // contraction axis of v
                    (kt, ck)
                } else if ax == v_rank - 1 {
                    (0, s)
                } else if let Some(i) = meta.v_outer_map[ax] {
                    // outer batch axis
                    (outer_idx[i], 1)
                } else {
                    (0, s)
                }
            })
            .collect();
        let v_tile = ctx.eval_region(meta.v_src, &vr);
        debug_assert_eq!(v_tile.numel(), ck * meta.d_out);

        // Fold into the online state row by row.
        let s_flat = &s_tile.data; // [.., cq, ck] with leading 1s
        debug_assert_eq!(s_tile.numel(), cq * ck);
        if meta.has_sm {
            for (r, st) in states.iter_mut().take(cq).enumerate() {
                st.update(&s_flat[r * ck..(r + 1) * ck], &v_tile.data);
            }
            ctx.flops += (2 * cq * ck * meta.d_out + 4 * cq * ck) as u64;
        } else {
            // twin-matmul: plain blocked accumulation
            gemm::gemm_nn(s_flat, &v_tile.data, &mut plain_acc, cq, meta.d_out, ck);
            ctx.flops += (2 * cq * ck * meta.d_out) as u64;
        }
        ctx.pool.recycle_shared(s_tile);
        ctx.pool.recycle_shared(v_tile);
        tiles_visited += 1;
        kt += ck;
    }
    // m1 flops for this tile row: q-block x live kv. Under a static mask
    // only visited k elements pay the QK^T cost; dynamic pruning runs the
    // coarse pass over every tile, so it still pays the full row.
    let m1_k = match &static_mask {
        Some((bm, _, dep, qti)) => bm.live_k_elems(*dep, *qti),
        None => meta.sk,
    };
    ctx.flops += (2 * cq * m1_k * meta.kdim) as u64;

    // Finalize the accumulator -> pin as m2's tile value.
    let acc: Vec<f32> = if meta.has_sm {
        let mut acc = ctx.pool.take(cq * meta.d_out);
        for st in states.iter().take(cq) {
            // `OnlineRowState::finish`, without consuming the state.
            let l = if st.l == 0.0 { 1.0 } else { st.l };
            acc.extend(st.acc.iter().map(|a| a / l));
        }
        acc
    } else {
        plain_acc
    };
    // m2's region shape (leading size-1 batch dims preserved).
    let mut m2_lens = vec![1usize; meta.m2_rank];
    m2_lens[meta.m2_rank - 2] = cq;
    m2_lens[meta.m2_rank - 1] = meta.d_out;
    ctx.pinned
        .insert(meta.m2, Rc::new(Tensor::from_vec(&m2_lens, acc)));

    // Evaluate the epilogue at tile granularity.
    let mut out_region: Region = meta.out_shape.iter().map(|&s| (0, s)).collect();
    for (i, &ax_out) in meta.outer_axes.iter().enumerate() {
        out_region[ax_out] = (outer_idx[i], 1);
    }
    out_region[meta.q_ax_out] = (qt, cq);
    let tile_rc = ctx.eval_region(pipe.out, &out_region);
    // Unshare the output tile: drop the memo/pinned aliases first so the
    // unwrap is copy-free.
    ctx.memo.remove(&(pipe.out.0, out_region.clone()));
    ctx.pinned.remove(&meta.m2);
    let tile = Rc::try_unwrap(tile_rc).unwrap_or_else(|rc| (*rc).clone());

    // Retire all per-block buffers into the worker pool. The memo may
    // alias the pinned tensors (slices); drain it first so the last
    // holder reclaims each allocation exactly once.
    let TiledCtx {
        pinned,
        memo,
        touches,
        flops,
        pool: retired,
        ..
    } = ctx;
    for (_, t) in memo {
        retired.recycle_shared(t);
    }
    for (_, t) in pinned {
        retired.recycle_shared(t);
    }

    BlockOut {
        out_region,
        tile,
        touches,
        flops,
        tiles_visited,
        tiles_skipped,
        flops_avoided,
        bytes_skipped,
    }
}

/// Row-contiguous scatter of a tile into the full output tensor.
fn scatter_tile(out: &mut Tensor, region: &Region, tile: &Tensor) {
    let rank = region.len();
    if rank == 0 {
        out.data[0] = tile.data[0];
        return;
    }
    let lens: Vec<usize> = region.iter().map(|(_, l)| *l).collect();
    let strides = out.strides();
    let row = lens[rank - 1];
    let mut soff = 0usize;
    crate::exec::for_each_row(&lens, |idx| {
        let mut dst = region[rank - 1].0;
        for ax in 0..rank - 1 {
            dst += (region[ax].0 + idx[ax]) * strides[ax];
        }
        out.data[dst..dst + row].copy_from_slice(&tile.data[soff..soff + row]);
        soff += row;
    });
    debug_assert_eq!(soff, tile.numel());
}

/// One pipeline group prepared for execution: block-invariant geometry
/// plus read-only shared state. The same struct serves the single-plan
/// path and the batched multi-plan queue — a `PipelineRun` knows how to
/// run any of its grid blocks and how to merge them deterministically.
struct PipelineRun<'a> {
    sh: PipelineShared<'a>,
    pipe: &'a Pipeline,
    meta: PipeMeta,
    grid: LogicalGrid,
    /// Block-sparse strategy for this run (None = dense).
    mask: Option<RunMask>,
    /// Scopes the workers' packed-panel caches to this plan within this
    /// launch: `(process-unique launch tag << 20) | job index`. Worker
    /// pools outlive launches, so the tag must never repeat — a stale
    /// panel under a reused key would silently serve old K-tile data.
    tag: u64,
}

impl<'a> PipelineRun<'a> {
    fn new(
        g: &'a Graph,
        an: &DimAnalysis,
        pipe: &'a Pipeline,
        tile: TileConfig,
        inputs: &'a HashMap<String, Tensor>,
        values: &'a HashMap<NodeId, Tensor>,
        precomputed: Option<&Arc<BlockMask>>,
        tag: u64,
    ) -> Self {
        let out_shape = g.node(pipe.out).shape.clone();
        let out_axes = an.axes[pipe.out.0 as usize].clone();
        let score_shape = g.node(pipe.score_root).shape.clone();
        let score_axes = an.axes[pipe.score_root.0 as usize].clone();
        let rank = out_shape.len();

        // Locate the q axis on the output and the kv axis on the scores.
        // These three are structural preconditions on every Pipeline the
        // planner emits; `analysis::verify` (check 2) re-derives them at
        // plan birth, so a failure here means an unverified hand-built
        // plan reached the executor.
        let q_ax_out = out_axes
            .iter()
            .position(|c| *c == pipe.q_class)
            .expect("pipeline output must carry the q dimension (caught by analysis::verify)");
        let kv_ax_s = score_axes
            .iter()
            .rposition(|c| *c == pipe.kv_class)
            .expect("score node must carry the kv dimension (caught by analysis::verify)");
        let q_ax_s = score_axes[..kv_ax_s]
            .iter()
            .rposition(|c| *c == pipe.q_class)
            .expect("score node must carry the q dimension (caught by analysis::verify)");
        let sq = out_shape[q_ax_out];
        let sk = score_shape[kv_ax_s];
        let d_out = out_shape[rank - 1];

        // Outer iteration space: all output axes except q and the last (d).
        let outer_axes: Vec<usize> = (0..rank)
            .filter(|&ax| ax != q_ax_out && ax != rank - 1)
            .collect();
        let outer_shape: Vec<usize> =
            outer_axes.iter().map(|&ax| out_shape[ax]).collect();

        let bq = tile.block_q.min(sq);
        let bk = tile.block_k.min(sk);

        // v source (the PV matmul rhs) and its per-axis outer mapping.
        let (v_src, v_transposed) = match g.node(pipe.m2).op {
            Op::Matmul {
                rhs, transpose_rhs, ..
            } => (rhs, transpose_rhs),
            _ => unreachable!(),
        };
        assert!(!v_transposed, "PV matmul with transposed V unsupported");
        let v_shape = g.node(v_src).shape.clone();
        let mut v_outer_map: Vec<Option<usize>> = vec![None; v_shape.len()];
        for ax in 0..v_shape.len().saturating_sub(2) {
            if v_shape[ax] == 1 {
                continue;
            }
            let cls = an.axes[v_src.0 as usize][ax];
            for (i, &ax_out) in outer_axes.iter().enumerate() {
                if out_axes[ax_out] == cls {
                    v_outer_map[ax] = Some(i);
                }
            }
        }
        // Map each outer coordinate onto matching score axes.
        let mut score_outer_map: Vec<Option<usize>> = vec![None; score_shape.len()];
        for (i, &ax_out) in outer_axes.iter().enumerate() {
            let cls = out_axes[ax_out];
            for (ax_s, c) in score_axes.iter().enumerate() {
                if *c == cls && score_shape[ax_s] > 1 {
                    score_outer_map[ax_s] = Some(i);
                }
            }
        }
        let kdim = {
            let m1_rank = g.node(pipe.m1).shape.len();
            let Op::Matmul { lhs, .. } = g.node(pipe.m1).op else {
                unreachable!()
            };
            g.node(lhs).shape[m1_rank - 1]
        };

        let meta = PipeMeta {
            out_shape,
            score_shape,
            q_ax_out,
            q_ax_s,
            kv_ax_s,
            sk,
            d_out,
            has_sm: pipe.softmax.is_some(),
            outer_axes,
            bk,
            score_outer_map,
            v_outer_map,
            v_src,
            v_shape,
            kdim,
            m2: pipe.m2,
            m2_rank: g.node(pipe.m2).shape.len(),
        };

        // The launch grid of §3.6, executed for real: outer dims at
        // tile=1, the q dimension tiled by block_q, unrolled to one
        // block-id axis.
        let mut dims: Vec<TiledDim> = outer_shape
            .iter()
            .map(|&s| TiledDim { size: s, tile: 1 })
            .collect();
        dims.push(TiledDim { size: sq, tile: bq });
        let grid = LogicalGrid::new(dims);

        // Resolve the block-sparse strategy. The cached per-plan mask is
        // reused only when its geometry matches the clamped tile config;
        // otherwise (or for input-dependent index masks, e.g. document
        // ids) classification runs here against this launch's inputs.
        let mask = if meta.has_sm && blockmask_enabled() {
            pipe.mask.as_ref().and_then(|info| match &info.kind {
                MaskKind::Threshold { tau } => Some(RunMask::Dynamic {
                    value: info.value,
                    tau: *tau,
                }),
                MaskKind::Index { .. } => precomputed
                    .filter(|m| {
                        m.block_q == bq
                            && m.block_k == bk
                            && m.sq == meta.score_shape[meta.q_ax_s]
                            && m.sk == sk
                    })
                    .cloned()
                    .or_else(|| {
                        classify_block_mask(
                            g,
                            info,
                            &meta.score_shape,
                            meta.q_ax_s,
                            meta.kv_ax_s,
                            bq,
                            bk,
                            inputs,
                        )
                        .map(Arc::new)
                    })
                    .map(|bm| RunMask::Static {
                        bm,
                        value: info.value,
                    }),
            })
        } else {
            None
        };

        PipelineRun {
            sh: PipelineShared { g, inputs, values },
            pipe,
            meta,
            grid,
            mask,
            tag,
        }
    }

    fn n_blocks(&self) -> usize {
        self.grid.n_blocks()
    }

    fn run_block(&self, block: usize, scratch: &mut WorkerScratch) -> BlockOut {
        run_block(
            &self.sh,
            self.pipe,
            &self.meta,
            &self.grid,
            self.mask.as_ref(),
            block,
            scratch,
            self.tag,
        )
    }

    /// True when this run's static mask makes per-block work non-uniform
    /// enough that weighted sharding pays off.
    fn is_skewed(&self) -> bool {
        matches!(&self.mask, Some(RunMask::Static { bm, .. }) if bm.skipped_tiles() > 0)
    }

    /// Scheduling weight of one grid block: rows x live k elements
    /// (the dominant per-block cost). Dense and dynamic runs are
    /// uniform at `cq * sk`. Never zero, so coverage is preserved.
    fn block_weight(&self, block: usize) -> u64 {
        let coords = self.grid.delinearize(block);
        let q_dim = coords.len() - 1;
        let (_, cq) = self.grid.tile_range(q_dim, coords[q_dim]);
        let live_k = match &self.mask {
            Some(RunMask::Static { bm, .. }) => {
                let mut region: Region =
                    self.meta.score_shape.iter().map(|&s| (0, s)).collect();
                for (ax_s, slot) in self.meta.score_outer_map.iter().enumerate() {
                    if let Some(i) = slot {
                        region[ax_s] = (coords[*i], 1);
                    }
                }
                bm.live_k_elems(bm.dep_index(&region), coords[q_dim]).max(1)
            }
            _ => self.meta.sk,
        };
        (cq * live_k) as u64
    }

    /// Deterministic merge in block (= sequential iteration) order, with
    /// a fresh per-kernel seen-set (L2 is not assumed warm across
    /// kernels). Returns the materialized value of `pipe.out`.
    fn merge(&self, blocks: Vec<BlockOut>, counters: &mut Counters) -> Tensor {
        // Debug cross-check of the verifier's race-freedom certificate
        // (`analysis::verify` check 2): the blocks actually produced
        // must write pairwise-disjoint regions that exactly cover the
        // output — the dynamic counterpart of the static proof.
        #[cfg(debug_assertions)]
        {
            let mut written: HashSet<&Region> = HashSet::new();
            let mut elems = 0usize;
            for b in &blocks {
                debug_assert!(
                    written.insert(&b.out_region),
                    "two grid blocks write output region {:?}",
                    b.out_region
                );
                elems += b.out_region.iter().map(|&(_, len)| len).product::<usize>();
            }
            debug_assert_eq!(
                elems,
                self.meta.out_shape.iter().product::<usize>(),
                "grid blocks must cover the output exactly"
            );
        }
        let mut seen: HashSet<(u32, Region)> = HashSet::new();
        let mut out = Tensor::zeros(&self.meta.out_shape);
        for b in blocks {
            for (nid, region, n) in b.touches {
                if seen.insert((nid, region)) {
                    counters.read_elems(n);
                } else {
                    counters.l2_elems(n);
                }
            }
            counters.flops += b.flops;
            counters.tiles_visited += b.tiles_visited;
            counters.tiles_skipped += b.tiles_skipped;
            counters.flops_avoided += b.flops_avoided;
            counters.bytes_skipped += b.bytes_skipped;
            let n = b.tile.numel();
            scatter_tile(&mut out, &b.out_region, &b.tile);
            counters.write_elems(n);
        }
        out
    }
}

/// Evaluate one node into a pooled output buffer (the non-pipeline
/// kernel path). Pointwise / matmul / reduce / generator outputs come
/// from the [`TilePool`]; ops whose reference implementation already
/// allocates exactly once (broadcast views, row-wise slices) fall back
/// to [`eval_node`].
fn eval_node_pooled(
    op: &Op,
    shape: &[usize],
    operands: &[&Tensor],
    pool: &mut TilePool,
) -> Tensor {
    let n: usize = shape.iter().product();
    match op {
        Op::Const { value } => {
            let mut data = pool.take(n);
            data.resize(n, *value);
            Tensor::from_vec(shape, data)
        }
        Op::Iota { axis } => {
            let mut data = pool.take(n);
            iota_fill(&mut data, shape, *axis, 0);
            Tensor::from_vec(shape, data)
        }
        Op::Pointwise { op, .. } => {
            let mut data = pool.take(n);
            use crate::ir::PwOp;
            match (operands.len(), *op) {
                // Unary exp/sigmoid: shared vectorized kernels,
                // bit-identical to the generic per-element loop.
                (1, PwOp::Exp) => crate::exec::simd::vexp_append(&mut data, &operands[0].data),
                (1, PwOp::Sigmoid) => {
                    crate::exec::simd::vsigmoid_append(&mut data, &operands[0].data)
                }
                _ => pointwise_fill(&mut data, *op, operands, n),
            }
            Tensor::from_vec(shape, data)
        }
        Op::Matmul { transpose_rhs, .. } => {
            let mut data = pool.take_zeroed(n);
            gemm::batched_matmul(operands[0], operands[1], *transpose_rhs, shape, &mut data);
            Tensor::from_vec(shape, data)
        }
        Op::Reduce { op, axis, .. } => {
            // The shared row-contiguous reduction (bit-identical combine
            // order with the eager executor) into a pooled output.
            let src = operands[0];
            let mut data = pool.take(n);
            data.resize(n, op.identity());
            reduce_rows_into(src, *axis, *op, &mut data);
            Tensor::from_vec(shape, data)
        }
        _ => eval_node(op, shape, operands),
    }
}

/// Execute one non-pipeline kernel group: evaluate members in order with
/// pooled buffers, retire member tensors as soon as their last in-group
/// consumer has run, count boundary traffic only, and materialize the
/// externally visible nodes into `values`.
#[allow(clippy::too_many_arguments)]
fn run_single_group(
    g: &Graph,
    plan: &Plan,
    gi: usize,
    inputs: &HashMap<String, Tensor>,
    cons: &[Vec<NodeId>],
    outputs: &HashSet<NodeId>,
    values: &mut HashMap<NodeId, Tensor>,
    counters: &mut Counters,
    pool: &mut TilePool,
) {
    let grp = &plan.groups[gi];
    let members: HashSet<NodeId> = grp.nodes.iter().copied().collect();
    // Externally visible members must survive to be materialized.
    let mut external: HashSet<NodeId> = HashSet::new();
    for &n in &grp.nodes {
        if outputs.contains(&n)
            || cons[n.0 as usize]
                .iter()
                .any(|c| plan.assignment[c.0 as usize] != gi)
        {
            external.insert(n);
        }
    }
    // Remaining in-group consumer count per member (per operand
    // occurrence: `consumers()` records duplicates, and so does the
    // decrement loop below).
    let mut uses: HashMap<NodeId, usize> = HashMap::new();
    for &n in &grp.nodes {
        let u = cons[n.0 as usize]
            .iter()
            .filter(|c| members.contains(c))
            .count();
        uses.insert(n, u);
    }

    let mut scratch: HashMap<NodeId, Tensor> = HashMap::new();
    let mut read_seen: HashSet<NodeId> = HashSet::new();
    for &n in &grp.nodes {
        let node = g.node(n);
        let operand_ids = node.op.input_ids();
        // First pass: materialize in-kernel generators and count boundary
        // reads (kept separate so `scratch` isn't mutably borrowed while
        // the evaluation references live).
        for &oid in &operand_ids {
            if scratch.contains_key(&oid) {
                continue;
            }
            if values.contains_key(&oid) {
                if !members.contains(&oid) && read_seen.insert(oid) {
                    counters.read_elems(g.numel(oid));
                }
            } else if matches!(g.node(oid).op, Op::Input { .. }) {
                if read_seen.insert(oid) {
                    counters.read_elems(g.numel(oid));
                }
            } else if matches!(g.node(oid).op, Op::Const { .. } | Op::Iota { .. }) {
                // in-kernel generator (free unless eager)
                let t = eval_node_pooled(&g.node(oid).op, &g.node(oid).shape, &[], pool);
                scratch.insert(oid, t);
            } else {
                // Every non-input, non-generator operand must be
                // materialized by an earlier group — a read-immutability
                // invariant `analysis::verify` (check 2) proves at plan
                // birth, so this is unreachable for verified plans.
                panic!("operand {oid:?} not available (caught by analysis::verify)");
            }
        }
        let operand_refs: Vec<&Tensor> = operand_ids
            .iter()
            .map(|oid| {
                scratch
                    .get(oid)
                    .or_else(|| values.get(oid))
                    .unwrap_or_else(|| {
                        let Op::Input { name } = &g.node(*oid).op else {
                            panic!("operand {oid:?} not available (caught by analysis::verify)")
                        };
                        &inputs[name]
                    })
            })
            .collect();
        let t = eval_node_pooled(&node.op, &node.shape, &operand_refs, pool);
        counters.flops += node_flops(g, n);
        drop(operand_refs);
        scratch.insert(n, t);
        // Retire member operands whose last in-group consumer this was.
        for &oid in &operand_ids {
            if let Some(u) = uses.get_mut(&oid) {
                *u = u.saturating_sub(1);
                if *u == 0 && !external.contains(&oid) {
                    if let Some(dead) = scratch.remove(&oid) {
                        pool.recycle(dead);
                    }
                }
            }
        }
    }
    // Materialize externally-visible nodes; retire everything else
    // (leftover generators, dead group outputs).
    for &n in &grp.nodes {
        if external.contains(&n) {
            counters.write_elems(g.numel(n));
            if let Some(t) = scratch.remove(&n) {
                values.insert(n, t);
            }
        }
    }
    for (_, t) in scratch.drain() {
        pool.recycle(t);
    }
}

/// One executable unit of the multi-plan work queue: a fusion plan with
/// its graph, inputs and tile schedule. Plans are borrowed (the serving
/// layer holds them in `Arc<CachedPlan>`s from the plan cache), so a job
/// is cheap to construct per decode step.
///
/// `analysis` / `consumers` are the graph metadata the executor needs;
/// for cached serving plans they are immutable and computed once at
/// plan-build time ([`crate::fusion::CachedPlan`] carries both) — pass
/// them so steady-state serving rounds perform zero `analyze()` /
/// `consumers()` recomputation. When absent they are derived per call.
pub struct PlanJob<'a> {
    pub graph: &'a Graph,
    pub plan: &'a Plan,
    pub inputs: &'a HashMap<String, Tensor>,
    pub tile: TileConfig,
    pub analysis: Option<&'a DimAnalysis>,
    pub consumers: Option<&'a [Vec<NodeId>]>,
    /// Plan-cache precomputed block masks, one slot per plan group
    /// (`None` entries and absent slices fall back to per-launch
    /// classification inside [`PipelineRun`]).
    pub block_masks: Option<&'a [Option<Arc<BlockMask>>]>,
}

/// Panic payload re-raised by [`execute_plans_batched`] when a worker
/// panics inside a batched launch: `job` is the index into the `jobs`
/// slice whose grid block raised the panic, when the runtime's per-item
/// attribution could identify it (`None` for panics outside the tiled
/// launch, e.g. a single-kernel group on the scheduler thread). The
/// serving backend catches this to fail only the poisoned request and
/// re-run the surviving jobs — the pool itself stays healthy.
pub struct BatchPanic {
    pub job: Option<usize>,
    /// The original panic payload (attribution layers removed).
    pub payload: Box<dyn std::any::Any + Send>,
}

/// Extract the job attribution from a panic caught around
/// [`execute_plans_batched`].
pub fn batch_panic_job(payload: &(dyn std::any::Any + Send)) -> Option<usize> {
    payload.downcast_ref::<BatchPanic>().and_then(|b| b.job)
}

impl<'a> PlanJob<'a> {
    /// A job without precomputed metadata (one-shot execution paths).
    pub fn new(
        graph: &'a Graph,
        plan: &'a Plan,
        inputs: &'a HashMap<String, Tensor>,
        tile: TileConfig,
    ) -> Self {
        PlanJob {
            graph,
            plan,
            inputs,
            tile,
            analysis: None,
            consumers: None,
            block_masks: None,
        }
    }

    /// A job borrowing everything from a cached serving plan — the
    /// allocation- and analysis-free per-step path.
    pub fn from_cached(
        entry: &'a crate::fusion::CachedPlan,
        inputs: &'a HashMap<String, Tensor>,
    ) -> Self {
        PlanJob {
            graph: &entry.graph,
            plan: &entry.plan,
            inputs,
            tile: entry.tile,
            analysis: Some(&entry.analysis),
            consumers: Some(&entry.consumers),
            block_masks: Some(&entry.block_masks),
        }
    }
}

/// Execute several plans as one batch over a **shared** worker pool.
///
/// Per-plan group order is preserved (groups may depend on earlier
/// groups' materialized values), but whenever multiple plans are ready at
/// a pipeline group, *all* their grid blocks become tagged work items
/// `(plan, block)` in a single [`parallel_map_with_weights`] launch — the
/// cross-request grid parallelism the serving engine's batched decode
/// needs, where each individual plan may have too few blocks to fill the
/// machine. Single-kernel groups run on the scheduler thread through a
/// shared [`TilePool`].
///
/// Determinism: each plan's blocks are merged in block order against
/// per-plan seen-sets, so every `(outputs, Counters)` pair is bit-equal
/// to running that plan alone via [`execute_plan`], at any thread count.
pub fn execute_plans_batched(
    jobs: &[PlanJob],
    par: &Parallelism,
) -> Vec<(Vec<Tensor>, Counters)> {
    let n = jobs.len();
    // Graph metadata: borrow what the jobs carry (cached serving plans
    // precompute it), derive the rest once for this call.
    let owned_analyses: Vec<Option<DimAnalysis>> = jobs
        .iter()
        .map(|j| j.analysis.is_none().then(|| analyze(j.graph)))
        .collect();
    let analyses: Vec<&DimAnalysis> = jobs
        .iter()
        .zip(&owned_analyses)
        .map(|(j, o)| {
            j.analysis
                .unwrap_or_else(|| o.as_ref().expect("owned_analyses filled for jobs without one"))
        })
        .collect();
    let owned_cons: Vec<Option<Vec<Vec<NodeId>>>> = jobs
        .iter()
        .map(|j| j.consumers.is_none().then(|| j.graph.consumers()))
        .collect();
    let cons: Vec<&[Vec<NodeId>]> = jobs
        .iter()
        .zip(&owned_cons)
        .map(|(j, o)| {
            j.consumers
                .unwrap_or_else(|| o.as_deref().expect("owned_cons filled for jobs without one"))
        })
        .collect();
    let outputs: Vec<HashSet<NodeId>> = jobs
        .iter()
        .map(|j| j.graph.outputs.iter().copied().collect())
        .collect();
    let mut values: Vec<HashMap<NodeId, Tensor>> = (0..n).map(|_| HashMap::new()).collect();
    let mut counters: Vec<Counters> = vec![Counters::default(); n];
    let mut next_group: Vec<usize> = vec![0; n];
    // Worker scratch lives in the runtime's persistent per-thread
    // storage; panel-cache keys are scoped by a process-unique launch
    // tag so a surviving pool can never serve a stale panel to a later
    // launch that reuses the same (plan index, node, region) key.
    let launch_tag = crate::exec::runtime::fresh_launch_tag();
    // The scheduler thread's single-kernel pool is persistent too
    // (serving calls this function once per decode sub-round; rebuilding
    // the pool per call put the allocator back on the steady-state path).
    std::thread_local! {
        static SCHED_POOL: std::cell::RefCell<TilePool> =
            std::cell::RefCell::new(TilePool::new());
    }
    SCHED_POOL.with(|cell| {
    let sched_pool = &mut *cell.borrow_mut();

    loop {
        // Drain single-kernel groups on the scheduler thread (cheap);
        // each job stops at its next pipeline group.
        for j in 0..n {
            while next_group[j] < jobs[j].plan.groups.len() {
                let grp = &jobs[j].plan.groups[next_group[j]];
                if matches!(grp.kind, GroupKind::Pipeline(_)) {
                    break;
                }
                counters[j].launches += 1;
                run_single_group(
                    jobs[j].graph,
                    jobs[j].plan,
                    next_group[j],
                    jobs[j].inputs,
                    cons[j],
                    &outputs[j],
                    &mut values[j],
                    &mut counters[j],
                    sched_pool,
                );
                next_group[j] += 1;
            }
        }
        let ready: Vec<usize> = (0..n)
            .filter(|&j| next_group[j] < jobs[j].plan.groups.len())
            .collect();
        if ready.is_empty() {
            break;
        }
        // All ready pipeline groups share one launch: tagged work items
        // over the combined grid.
        let merged: Vec<(usize, NodeId, Tensor, Counters)> = {
            let runs: Vec<PipelineRun> = ready
                .iter()
                .map(|&j| {
                    let GroupKind::Pipeline(p) = &jobs[j].plan.groups[next_group[j]].kind
                    else {
                        unreachable!("ready jobs stop at pipeline groups")
                    };
                    PipelineRun::new(
                        jobs[j].graph,
                        analyses[j],
                        p,
                        jobs[j].tile,
                        jobs[j].inputs,
                        &values[j],
                        jobs[j]
                            .block_masks
                            .and_then(|ms| ms.get(next_group[j]))
                            .and_then(|o| o.as_ref()),
                        (launch_tag << 20) | j as u64,
                    )
                })
                .collect();
            let mut offsets = Vec::with_capacity(runs.len() + 1);
            let mut total = 0usize;
            for r in &runs {
                offsets.push(total);
                total += r.n_blocks();
            }
            offsets.push(total);
            // Size work items by live k-tiles so topology shards stay
            // balanced under block-sparse skew (a sliding-window q-tile
            // near the diagonal does a fraction of a dense tile's work).
            // Uniform launches pass no weights and keep the cheap path.
            let weights: Option<Vec<u64>> = runs.iter().any(|r| r.is_skewed()).then(|| {
                (0..total)
                    .map(|item| {
                        let ri = offsets.partition_point(|&o| o <= item) - 1;
                        runs[ri].block_weight(item - offsets[ri])
                    })
                    .collect()
            });
            // A worker panic inside the launch arrives attributed to a
            // work item; translate the item to the owning job and re-
            // raise as a BatchPanic so the serving layer can fail just
            // that request. State is safe to retry: every per-job
            // mutation (values/counters/next_group) happens only after
            // a launch fully succeeds.
            let blocks: Vec<BlockOut> = match std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| {
                    parallel_map_with_weights(
                        par,
                        total,
                        weights.as_deref(),
                        WorkerScratch::new,
                        |ws, item| {
                            let ri = offsets.partition_point(|&o| o <= item) - 1;
                            runs[ri].run_block(item - offsets[ri], ws)
                        },
                    )
                }),
            ) {
                Ok(b) => b,
                Err(payload) => {
                    let job = crate::exec::runtime::panic_item(payload.as_ref())
                        .map(|item| ready[offsets.partition_point(|&o| o <= item) - 1]);
                    let payload = match payload
                        .downcast::<crate::exec::runtime::AttributedPanic>()
                    {
                        Ok(a) => a.payload,
                        Err(other) => other,
                    };
                    std::panic::resume_unwind(Box::new(BatchPanic { job, payload }));
                }
            };
            // Per-plan deterministic merge, in block order.
            let mut out = Vec::with_capacity(runs.len());
            let mut it = blocks.into_iter();
            for (ri, run) in runs.iter().enumerate() {
                let count = offsets[ri + 1] - offsets[ri];
                let bs: Vec<BlockOut> = it.by_ref().take(count).collect();
                let mut c = Counters::default();
                c.launches += 1;
                let t = run.merge(bs, &mut c);
                out.push((ready[ri], run.pipe.out, t, c));
            }
            out
        };
        for (j, node, t, c) in merged {
            values[j].insert(node, t);
            counters[j].add(&c);
            next_group[j] += 1;
        }
    }
    }); // SCHED_POOL

    jobs.iter()
        .enumerate()
        .map(|(j, job)| {
            let outs = job
                .graph
                .outputs
                .iter()
                .map(|o| values[j][o].clone())
                .collect();
            (outs, counters[j])
        })
        .collect()
}

/// Execute the whole plan sequentially (bit-identical to
/// [`execute_plan_par`] at any thread count).
pub fn execute_plan(
    g: &Graph,
    plan: &Plan,
    inputs: &HashMap<String, Tensor>,
    tile: TileConfig,
) -> (Vec<Tensor>, Counters) {
    execute_plan_par(g, plan, inputs, tile, &Parallelism::sequential())
}

/// Execute the whole plan: pipeline groups run tiled + online over their
/// launch grid with `par` worker threads; other groups execute as single
/// kernels. Returns (outputs, counters). This is the one-job case of
/// [`execute_plans_batched`].
pub fn execute_plan_par(
    g: &Graph,
    plan: &Plan,
    inputs: &HashMap<String, Tensor>,
    tile: TileConfig,
    par: &Parallelism,
) -> (Vec<Tensor>, Counters) {
    let job = PlanJob::new(g, plan, inputs, tile);
    execute_plans_batched(std::slice::from_ref(&job), par)
        .pop()
        .expect("one job in, one result out")
}

/// Who runs a fused plan — the executor half of the
/// LaunchPlanExecutor/TraceRunner split. A [`crate::fusion::CachedPlan`]
/// describes *what* a plan computes (graph, schedule, tile, masks —
/// pure data, no execution machinery); a `PlanRunner` is *how* a batch
/// of such plans gets executed. The serving engine holds one runner per
/// instance, which is what makes an engine instance a self-contained
/// unit of (runner + plan cache + paged KV + lifecycle) that a
/// multi-shard router can replicate and kill independently.
///
/// Contract every implementation must honor:
///
/// - **Bit-identity:** `run_batch` returns, per job, the identical
///   `(outputs, Counters)` that [`execute_plan`] would produce for that
///   job alone — at any internal parallelism, on any scheduling
///   topology. This is what makes shard placement invisible in token
///   streams.
/// - **Panic attribution:** a panic inside one job's grid unwinds as a
///   [`BatchPanic`] naming that job where attribution is possible, and
///   leaves the runner reusable (no poisoned shared state) so the
///   caller can fail one request and re-run the survivors.
///
/// The CPU tiers implement it today ([`CpuRunner`]); a PJRT/accelerator
/// path can implement it later without the plan cache or the serving
/// lifecycle changing shape.
pub trait PlanRunner {
    /// Execute `jobs` as one batch, preserving per-job result order.
    fn run_batch(&self, jobs: &[PlanJob]) -> Vec<(Vec<Tensor>, Counters)>;

    /// Short human-readable identity for logs / bench JSON.
    fn describe(&self) -> String;
}

/// The in-process CPU runner: batched grid execution over the
/// persistent topology-aware worker pool via [`execute_plans_batched`].
/// `Copy`, so callers can lift it out of a backend before a
/// borrow-heavy scheduling loop the same way they copy `Parallelism`.
#[derive(Clone, Copy, Debug)]
pub struct CpuRunner {
    pub par: Parallelism,
}

impl CpuRunner {
    pub fn new(par: Parallelism) -> Self {
        CpuRunner { par }
    }
}

impl PlanRunner for CpuRunner {
    fn run_batch(&self, jobs: &[PlanJob]) -> Vec<(Vec<Tensor>, Counters)> {
        execute_plans_batched(jobs, &self.par)
    }

    fn describe(&self) -> String {
        format!("cpu:{}t", self.par.num_threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::eval;
    use crate::fusion::{plan, FusionMode};
    use crate::variants::{build, paper_variants, AttnShape, Variant};

    fn synthetic_inputs(g: &Graph, seed: u64) -> HashMap<String, Tensor> {
        let mut m = HashMap::new();
        for (i, &id) in g.inputs.iter().enumerate() {
            let node = g.node(id);
            let Op::Input { name } = &node.op else { unreachable!() };
            let t = if name.starts_with("doc") {
                let n: usize = node.shape.iter().product();
                Tensor::from_vec(
                    &node.shape,
                    (0..n).map(|j| (j * 3 / n) as f32).collect(),
                )
            } else {
                Tensor::synthetic(&node.shape, seed + i as u64)
            };
            m.insert(name.clone(), t);
        }
        m
    }

    fn check_variant(v: Variant, shape: AttnShape, tile: TileConfig, tol: f32) {
        let g = build(v, &shape);
        let inputs = synthetic_inputs(&g, 11);
        let (want, _) = eval(&g, &inputs);
        let p = plan(&g, FusionMode::Flashlight);
        assert!(p.num_pipelines() >= 1, "{}", v.name());
        let (got, c) = execute_plan(&g, &p, &inputs, tile);
        assert_eq!(got.len(), want.len());
        let err = got[0].max_abs_diff(&want[0]);
        assert!(
            err <= tol,
            "{}: fused/unfused diverge by {err}",
            v.name()
        );
        assert!(c.hbm_read > 0 && c.hbm_write > 0);
    }

    #[test]
    fn fused_execution_matches_reference_all_variants() {
        let shape = AttnShape {
            batch: 2,
            rows: 1,
            heads_q: 2,
            heads_kv: 2,
            seq: 32,
            head_dim: 8,
        };
        let tile = TileConfig {
            block_q: 16,
            block_k: 8,
            l2_capacity: 40 << 20,
        };
        for v in paper_variants() {
            let v = match v {
                Variant::SlidingWindow { .. } => Variant::SlidingWindow { window: 7 },
                Variant::PrefixLm { .. } => Variant::PrefixLm { prefix: 9 },
                other => other,
            };
            check_variant(v, shape, tile, 1e-5);
        }
    }

    #[test]
    fn fused_execution_matches_reference_gqa() {
        let shape = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 4,
            heads_kv: 2,
            seq: 32,
            head_dim: 8,
        };
        check_variant(
            Variant::Causal,
            shape,
            TileConfig {
                block_q: 8,
                block_k: 16,
                l2_capacity: 40 << 20,
            },
            1e-5,
        );
    }

    #[test]
    fn fused_execution_matches_reference_complex_variants() {
        let shape = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 2,
            heads_kv: 2,
            seq: 16,
            head_dim: 8,
        };
        let tile = TileConfig {
            block_q: 8,
            block_k: 8,
            l2_capacity: 40 << 20,
        };
        check_variant(Variant::DiffAttn { lambda: 0.5 }, shape, tile, 1e-5);
        check_variant(Variant::Evoformer, shape, tile, 1e-5);
    }

    #[test]
    fn twin_matmul_pipeline_matches_reference() {
        let mut b = crate::ir::GraphBuilder::new("twin");
        let a = b.input("a", &[64, 16]);
        let bb = b.input("b", &[16, 32]);
        let d = b.input("d", &[32, 8]);
        let c = b.matmul(a, bb);
        let e = b.matmul(c, d);
        let g = b.finish(&[e]);
        let inputs = synthetic_inputs(&g, 5);
        let (want, _) = eval(&g, &inputs);
        let p = plan(&g, FusionMode::Flashlight);
        assert_eq!(p.num_pipelines(), 1);
        let (got, _) = execute_plan(
            &g,
            &p,
            &inputs,
            TileConfig {
                block_q: 16,
                block_k: 8,
                l2_capacity: 40 << 20,
            },
        );
        let err = got[0].max_abs_diff(&want[0]);
        assert!(err < 1e-4, "twin matmul diverges by {err}");
    }

    #[test]
    fn executed_traffic_matches_analytic_counters() {
        let shape = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 2,
            heads_kv: 2,
            seq: 32,
            head_dim: 8,
        };
        let tile = TileConfig {
            block_q: 8,
            block_k: 8,
            l2_capacity: 40 << 20,
        };
        for v in [Variant::Vanilla, Variant::Causal] {
            let g = build(v, &shape);
            let inputs = synthetic_inputs(&g, 3);
            let p = plan(&g, FusionMode::Flashlight);
            let (_, c_exec) = execute_plan(&g, &p, &inputs, tile);
            let c_model = p.counters(&g, tile);
            assert_eq!(
                c_exec.hbm_read, c_model.hbm_read,
                "{}: read mismatch (exec vs model)",
                v.name()
            );
            assert_eq!(c_exec.hbm_write, c_model.hbm_write, "{}", v.name());
            assert_eq!(c_exec.launches, c_model.launches, "{}", v.name());
        }
    }

    #[test]
    fn torch_compile_plan_also_executes_correctly() {
        let shape = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 2,
            heads_kv: 2,
            seq: 16,
            head_dim: 8,
        };
        let g = build(Variant::Causal, &shape);
        let inputs = synthetic_inputs(&g, 9);
        let (want, _) = eval(&g, &inputs);
        let p = plan(&g, FusionMode::TorchCompile);
        let (got, c) = execute_plan(&g, &p, &inputs, TileConfig::default());
        assert!(got[0].allclose(&want[0], 1e-6));
        // inductor-style plan materializes the S^2 intermediates
        let fl = plan(&g, FusionMode::Flashlight);
        let (_, cf) = execute_plan(&g, &fl, &inputs, TileConfig::default());
        assert!(cf.total_traffic() < c.total_traffic());
    }

    #[test]
    fn parallel_execution_is_bit_identical_to_sequential() {
        let shape = AttnShape {
            batch: 2,
            rows: 1,
            heads_q: 4,
            heads_kv: 2,
            seq: 32,
            head_dim: 8,
        };
        let tile = TileConfig {
            block_q: 8,
            block_k: 8,
            l2_capacity: 40 << 20,
        };
        for v in [Variant::Causal, Variant::Alibi, Variant::DiffAttn { lambda: 0.5 }] {
            let g = build(v, &shape);
            let inputs = synthetic_inputs(&g, 17);
            let p = plan(&g, FusionMode::Flashlight);
            let (seq_out, seq_c) = execute_plan(&g, &p, &inputs, tile);
            for threads in [2, 5] {
                let (par_out, par_c) = execute_plan_par(
                    &g,
                    &p,
                    &inputs,
                    tile,
                    &Parallelism::with_threads(threads),
                );
                assert_eq!(seq_out, par_out, "{} threads={threads}", v.name());
                assert_eq!(seq_c, par_c, "{} threads={threads}", v.name());
            }
        }
    }

    #[test]
    fn batched_multi_plan_matches_individual_execution() {
        // Mixed batch: two Flashlight pipelines + one multi-kernel
        // TorchCompile plan, all through the shared work queue. Every
        // job's outputs AND counters must be bit-equal to running that
        // plan alone, at any thread count.
        let shape = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 2,
            heads_kv: 2,
            seq: 32,
            head_dim: 8,
        };
        let tile = TileConfig {
            block_q: 8,
            block_k: 8,
            l2_capacity: 40 << 20,
        };
        let specs = [
            (Variant::Causal, FusionMode::Flashlight),
            (Variant::Causal, FusionMode::TorchCompile),
            (Variant::DiffAttn { lambda: 0.5 }, FusionMode::Flashlight),
        ];
        let graphs: Vec<Graph> = specs.iter().map(|(v, _)| build(*v, &shape)).collect();
        let inputs: Vec<HashMap<String, Tensor>> = graphs
            .iter()
            .enumerate()
            .map(|(i, g)| synthetic_inputs(g, 21 + i as u64))
            .collect();
        let plans: Vec<Plan> = graphs
            .iter()
            .zip(&specs)
            .map(|(g, (_, m))| plan(g, *m))
            .collect();
        let jobs: Vec<PlanJob> = (0..graphs.len())
            .map(|i| PlanJob::new(&graphs[i], &plans[i], &inputs[i], tile))
            .collect();
        for threads in [1, 3] {
            let batched = execute_plans_batched(&jobs, &Parallelism::with_threads(threads));
            assert_eq!(batched.len(), jobs.len());
            for i in 0..graphs.len() {
                let (want, c_want) = execute_plan(&graphs[i], &plans[i], &inputs[i], tile);
                assert_eq!(batched[i].0, want, "job {i} threads={threads}");
                assert_eq!(batched[i].1, c_want, "job {i} threads={threads}");
            }
        }
    }

    #[test]
    fn batched_empty_job_list_is_fine() {
        let out = execute_plans_batched(&[], &Parallelism::with_threads(4));
        assert!(out.is_empty());
    }
}
