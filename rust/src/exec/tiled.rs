//! Fused tiled executor: runs a [`Plan`](crate::fusion::Plan) the way the
//! generated Triton kernel would — pipeline groups execute tile-by-tile
//! with the online-softmax rewrite, never materializing the (S, S)
//! intermediates; other groups execute as single kernels.
//!
//! The executor counts the HBM traffic it *actually* generates (every
//! `Input`/materialized-tensor tile read and every output tile write), so
//! `plan.counters()`'s analytic model is testable against real execution.

use std::collections::HashMap;

use crate::exec::{eval_node, eval_pw, node_flops, Counters, Tensor};
use crate::fusion::{GroupKind, Pipeline, Plan, TileConfig};
use crate::ir::{Graph, NodeId, Op};
use crate::sketch::{analyze, DimAnalysis};

/// Per-axis (start, len) region of a node's tensor.
type Region = Vec<(usize, usize)>;

struct TiledCtx<'a> {
    g: &'a Graph,
    inputs: &'a HashMap<String, Tensor>,
    /// Materialized results of earlier groups (and graph inputs by id).
    values: HashMap<NodeId, Tensor>,
    /// Values pinned by the pipeline driver (e.g. the PV accumulator).
    pinned: HashMap<NodeId, Tensor>,
    memo: HashMap<(u32, Region), Tensor>,
    /// Regions already fetched once within the current kernel: re-reads
    /// hit L2, not HBM (cleared at each kernel-group boundary).
    seen_regions: std::collections::HashSet<(u32, Region)>,
    counters: Counters,
}


impl<'a> TiledCtx<'a> {
    /// Gather a sub-region of a full tensor, counting read traffic: the
    /// first touch of a region is an HBM read, repeats are L2 hits.
    fn gather(&mut self, id: NodeId, t: &Tensor, region: &Region) -> Tensor {
        let lens: Vec<usize> = region.iter().map(|(_, l)| *l).collect();
        let mut out = Tensor::zeros(&lens);
        let n = out.numel();
        let rank = lens.len();
        if rank == 0 {
            out.data[0] = t.data[0];
        } else {
            // Row-wise copies: the last axis is contiguous in the source,
            // so decompose indices once per row, not once per element.
            let strides = t.strides();
            let row = lens[rank - 1];
            let mut idx = vec![0usize; rank - 1];
            let mut dof = 0usize;
            loop {
                let mut soff = region[rank - 1].0; // last-axis start
                for ax in 0..rank - 1 {
                    soff += (region[ax].0 + idx[ax]) * strides[ax];
                }
                out.data[dof..dof + row].copy_from_slice(&t.data[soff..soff + row]);
                dof += row;
                if dof >= n {
                    break;
                }
                // increment leading indices
                let mut ax = rank - 1;
                loop {
                    ax -= 1;
                    idx[ax] += 1;
                    if idx[ax] < lens[ax] {
                        break;
                    }
                    idx[ax] = 0;
                    if ax == 0 {
                        break;
                    }
                }
            }
        }
        if self.seen_regions.insert((id.0, region.clone())) {
            self.counters.read_elems(n);
        } else {
            self.counters.l2_elems(n);
        }
        out
    }

    /// Evaluate `node` restricted to `region`, recursively. Regions
    /// propagate structurally: each op knows its operands' regions.
    fn eval_region(&mut self, id: NodeId, region: &Region) -> Tensor {
        if let Some(t) = self.pinned.get(&id) {
            return t.clone();
        }
        let key = (id.0, region.clone());
        if let Some(t) = self.memo.get(&key) {
            return t.clone();
        }
        // Materialized by an earlier group: read the tile from "HBM".
        if let Some(t) = self.values.get(&id) {
            let t = t.clone();
            let out = self.gather(id, &t, region);
            self.memo.insert(key, out.clone());
            return out;
        }
        let node = self.g.node(id).clone();
        let lens: Vec<usize> = region.iter().map(|(_, l)| *l).collect();
        let out = match &node.op {
            Op::Input { name } => {
                let t = self.inputs[name].clone();
                self.gather(id, &t, region)
            }
            Op::Const { value } => Tensor::full(&lens, *value),
            Op::Iota { axis } => {
                // Only idx[axis] matters: fill in (outer, value, inner)
                // runs instead of decomposing every element index.
                let mut out = Tensor::zeros(&lens);
                let inner: usize = lens[axis + 1..].iter().product();
                let count = lens[*axis];
                let outer: usize = lens[..*axis].iter().product();
                let start = region[*axis].0;
                let mut off = 0;
                for _ in 0..outer.max(1) {
                    for j in 0..count {
                        out.data[off..off + inner].fill((start + j) as f32);
                        off += inner;
                    }
                }
                out
            }
            Op::Pointwise { op, inputs } => {
                let ts: Vec<Tensor> = inputs
                    .iter()
                    .map(|&i| self.eval_region(i, region))
                    .collect();
                let n: usize = lens.iter().product();
                // Fast paths hoist the op dispatch out of the element
                // loop (the interpreter's hottest code).
                use crate::ir::PwOp;
                let data: Vec<f32> = match (ts.len(), *op) {
                    (1, op1) => {
                        let a = &ts[0].data;
                        match op1 {
                            PwOp::Exp => a.iter().map(|x| x.exp()).collect(),
                            PwOp::Tanh => a.iter().map(|x| x.tanh()).collect(),
                            PwOp::Sigmoid => {
                                a.iter().map(|x| 1.0 / (1.0 + (-x).exp())).collect()
                            }
                            PwOp::Neg => a.iter().map(|x| -x).collect(),
                            PwOp::MulScalar(s) => a.iter().map(|x| x * s).collect(),
                            PwOp::AddScalar(s) => a.iter().map(|x| x + s).collect(),
                            other => a.iter().map(|&x| eval_pw(other, &[x])).collect(),
                        }
                    }
                    (2, op2) => {
                        let (a, b) = (&ts[0].data, &ts[1].data);
                        match op2 {
                            PwOp::Add => a.iter().zip(b).map(|(x, y)| x + y).collect(),
                            PwOp::Sub => a.iter().zip(b).map(|(x, y)| x - y).collect(),
                            PwOp::Mul => a.iter().zip(b).map(|(x, y)| x * y).collect(),
                            PwOp::Div => a.iter().zip(b).map(|(x, y)| x / y).collect(),
                            other => a
                                .iter()
                                .zip(b)
                                .map(|(&x, &y)| eval_pw(other, &[x, y]))
                                .collect(),
                        }
                    }
                    _ => {
                        let mut data = Vec::with_capacity(n);
                        let mut args = [0f32; 3];
                        for f in 0..n {
                            for (j, t) in ts.iter().enumerate() {
                                args[j] = t.data[f];
                            }
                            data.push(eval_pw(*op, &args[..ts.len()]));
                        }
                        data
                    }
                };
                debug_assert_eq!(data.len(), n);
                Tensor::from_vec(&lens, data)
            }
            Op::Broadcast { input } => {
                let in_shape = &self.g.node(*input).shape;
                let op_region: Region = region
                    .iter()
                    .enumerate()
                    .map(|(ax, &(s, l))| if in_shape[ax] == 1 { (0, 1) } else { (s, l) })
                    .collect();
                let src = self.eval_region(*input, &op_region);
                src.broadcast_to(&lens)
            }
            Op::Slice {
                input,
                axis,
                start,
                ..
            } => {
                let op_region: Region = region
                    .iter()
                    .enumerate()
                    .map(|(ax, &(s, l))| if ax == *axis { (s + start, l) } else { (s, l) })
                    .collect();
                self.eval_region(*input, &op_region)
            }
            Op::Matmul {
                lhs,
                rhs,
                transpose_rhs,
            } => {
                let rank = region.len();
                let k_full = self.g.node(*lhs).shape[rank - 1];
                let lhs_shape = self.g.node(*lhs).shape.clone();
                let rhs_shape = self.g.node(*rhs).shape.clone();
                let mut lr: Region = vec![];
                let mut rr: Region = vec![];
                for ax in 0..rank - 2 {
                    let (s, l) = region[ax];
                    lr.push(if lhs_shape[ax] == 1 { (0, 1) } else { (s, l) });
                    rr.push(if rhs_shape[ax] == 1 { (0, 1) } else { (s, l) });
                }
                lr.push(region[rank - 2]);
                lr.push((0, k_full));
                if *transpose_rhs {
                    rr.push(region[rank - 1]);
                    rr.push((0, k_full));
                } else {
                    rr.push((0, k_full));
                    rr.push(region[rank - 1]);
                }
                let lt = self.eval_region(*lhs, &lr);
                let rt = self.eval_region(*rhs, &rr);
                eval_node(&node.op, &lens, &[&lt, &rt])
            }
            Op::Reduce { .. } => {
                panic!("reductions inside pipelines are handled by the driver")
            }
        };
        self.memo.insert(key, out.clone());
        out
    }
}

/// Execute a fused pipeline group. Returns the materialized value of
/// `pipe.out`.
fn run_pipeline(
    ctx: &mut TiledCtx,
    an: &DimAnalysis,
    pipe: &Pipeline,
    tile: TileConfig,
) -> Tensor {
    let g = ctx.g;
    let out_shape = g.node(pipe.out).shape.clone();
    let out_axes = an.axes[pipe.out.0 as usize].clone();
    let score_shape = g.node(pipe.score_root).shape.clone();
    let score_axes = an.axes[pipe.score_root.0 as usize].clone();
    let rank = out_shape.len();

    // Locate the q axis on the output and the kv axis on the scores.
    let q_ax_out = out_axes
        .iter()
        .position(|c| *c == pipe.q_class)
        .expect("pipeline output must carry the q dimension");
    let kv_ax_s = score_axes
        .iter()
        .rposition(|c| *c == pipe.kv_class)
        .expect("score node must carry the kv dimension");
    let q_ax_s = score_axes[..kv_ax_s]
        .iter()
        .rposition(|c| *c == pipe.q_class)
        .expect("score node must carry the q dimension");
    let sq = out_shape[q_ax_out];
    let sk = score_shape[kv_ax_s];
    let d_out = out_shape[rank - 1];
    let has_sm = pipe.softmax.is_some();

    // Outer iteration space: all output axes except q and the last (d).
    let outer_axes: Vec<usize> = (0..rank)
        .filter(|&ax| ax != q_ax_out && ax != rank - 1)
        .collect();
    let outer_shape: Vec<usize> = outer_axes.iter().map(|&ax| out_shape[ax]).collect();
    let n_outer: usize = outer_shape.iter().product::<usize>().max(1);

    let mut out = Tensor::zeros(&out_shape);
    let out_strides = out.strides();
    let bq = tile.block_q.min(sq);
    let bk = tile.block_k.min(sk);

    for o in 0..n_outer {
        // Decompose the outer index.
        let mut outer_idx = vec![0usize; outer_axes.len()];
        let mut rem = o;
        for i in (0..outer_axes.len()).rev() {
            outer_idx[i] = rem % outer_shape[i];
            rem /= outer_shape[i];
        }
        let mut qt = 0;
        while qt < sq {
            ctx.memo.clear();
            let cq = bq.min(sq - qt);
            // Score region template (per kv tile).
            let mut score_region: Region = score_shape.iter().map(|&s| (0, s)).collect();
            for (i, &ax_out) in outer_axes.iter().enumerate() {
                // map the outer axis class onto score axes
                let cls = out_axes[ax_out];
                for (ax_s, c) in score_axes.iter().enumerate() {
                    if *c == cls && score_shape[ax_s] > 1 {
                        score_region[ax_s] = (outer_idx[i], 1);
                    }
                }
            }
            score_region[q_ax_s] = (qt, cq);

            // Online state per q row.
            let mut states: Vec<crate::fusion::OnlineRowState> = (0..cq)
                .map(|_| crate::fusion::OnlineRowState::new(d_out))
                .collect();
            let mut plain_acc = vec![0f32; cq * d_out];

            // v region template.
            let (v_src, v_transposed) = match g.node(pipe.m2).op {
                Op::Matmul {
                    rhs, transpose_rhs, ..
                } => (rhs, transpose_rhs),
                _ => unreachable!(),
            };
            assert!(!v_transposed, "PV matmul with transposed V unsupported");
            let v_shape = g.node(v_src).shape.clone();

            let mut kt = 0;
            while kt < sk {
                let ck = bk.min(sk - kt);
                let mut sr = score_region.clone();
                sr[kv_ax_s] = (kt, ck);
                let s_tile = ctx.eval_region(pipe.score_root, &sr);
                // v tile: [.., ck, d]
                let mut vr: Region = v_shape
                    .iter()
                    .enumerate()
                    .map(|(ax, &s)| {
                        if s == 1 {
                            (0, 1)
                        } else if ax == v_shape.len() - 2 {
                            (kt, ck)
                        } else if ax == v_shape.len() - 1 {
                            (0, s)
                        } else {
                            // outer batch axis
                            let cls = an.axes[v_src.0 as usize][ax];
                            let mut r = (0, s);
                            for (i, &ax_out) in outer_axes.iter().enumerate() {
                                if out_axes[ax_out] == cls {
                                    r = (outer_idx[i], 1);
                                }
                            }
                            r
                        }
                    })
                    .collect();
                // contraction axis of v is its second-to-last
                vr[v_shape.len() - 2] = (kt, ck);
                let v_tile = ctx.eval_region(v_src, &vr);
                debug_assert_eq!(v_tile.numel(), ck * d_out);

                // Fold into the online state row by row.
                let s_flat = &s_tile.data; // [.., cq, ck] with leading 1s
                debug_assert_eq!(s_tile.numel(), cq * ck);
                if has_sm {
                    for (r, st) in states.iter_mut().enumerate() {
                        st.update(&s_flat[r * ck..(r + 1) * ck], &v_tile.data);
                    }
                    ctx.counters.flops += (2 * cq * ck * d_out + 4 * cq * ck) as u64;
                } else {
                    // twin-matmul: plain accumulation
                    for r in 0..cq {
                        for j in 0..ck {
                            let s = s_flat[r * ck + j];
                            for dd in 0..d_out {
                                plain_acc[r * d_out + dd] += s * v_tile.data[j * d_out + dd];
                            }
                        }
                    }
                    ctx.counters.flops += (2 * cq * ck * d_out) as u64;
                }
                kt += ck;
            }
            // m1 flops for this tile row (q-block x full kv).
            let k_contraction = g.node(pipe.m1).shape.len();
            let kdim = {
                let Op::Matmul { lhs, .. } = g.node(pipe.m1).op else {
                    unreachable!()
                };
                g.node(lhs).shape[k_contraction - 1]
            };
            ctx.counters.flops += (2 * cq * sk * kdim) as u64;

            // Finalize the accumulator -> pin as m2's tile value.
            let acc: Vec<f32> = if has_sm {
                states
                    .into_iter()
                    .flat_map(|st| st.finish())
                    .collect()
            } else {
                plain_acc
            };
            // m2's region shape (leading size-1 batch dims preserved).
            let m2_shape = g.node(pipe.m2).shape.clone();
            let m2_lens: Vec<usize> = m2_shape
                .iter()
                .enumerate()
                .map(|(ax, &s)| {
                    if ax == m2_shape.len() - 2 {
                        cq
                    } else if ax == m2_shape.len() - 1 {
                        d_out
                    } else if s == 1 {
                        1
                    } else {
                        1 // fixed outer index
                    }
                })
                .collect();
            ctx.pinned
                .insert(pipe.m2, Tensor::from_vec(&m2_lens, acc));

            // Evaluate the epilogue at tile granularity and write out.
            let mut out_region: Region = out_shape.iter().map(|&s| (0, s)).collect();
            for (i, &ax_out) in outer_axes.iter().enumerate() {
                out_region[ax_out] = (outer_idx[i], 1);
            }
            out_region[q_ax_out] = (qt, cq);
            let tile_out = ctx.eval_region(pipe.out, &out_region);
            ctx.pinned.remove(&pipe.m2);
            // scatter into output
            let lens: Vec<usize> = out_region.iter().map(|(_, l)| *l).collect();
            let n = tile_out.numel();
            let mut idx = vec![0usize; rank];
            for flat in 0..n {
                let mut rem = flat;
                let mut dst = 0usize;
                for ax in (0..rank).rev() {
                    idx[ax] = rem % lens[ax] + out_region[ax].0;
                    rem /= lens[ax];
                    dst += idx[ax] * out_strides[ax];
                }
                out.data[dst] = tile_out.data[flat];
            }
            ctx.counters.write_elems(n);
            qt += cq;
        }
    }
    ctx.memo.clear();
    out
}

/// Execute the whole plan: pipeline groups tiled + online, other groups
/// as single materializing kernels. Returns (outputs, counters).
pub fn execute_plan(
    g: &Graph,
    plan: &Plan,
    inputs: &HashMap<String, Tensor>,
    tile: TileConfig,
) -> (Vec<Tensor>, Counters) {
    let an = analyze(g);
    let mut ctx = TiledCtx {
        g,
        inputs,
        values: HashMap::new(),
        pinned: HashMap::new(),
        memo: HashMap::new(),
        seen_regions: std::collections::HashSet::new(),
        counters: Counters::default(),
    };
    let cons = g.consumers();
    let outputs: std::collections::HashSet<NodeId> = g.outputs.iter().copied().collect();

    for (gi, grp) in plan.groups.iter().enumerate() {
        ctx.counters.launches += 1;
        ctx.seen_regions.clear(); // L2 is not assumed warm across kernels
        match &grp.kind {
            GroupKind::Pipeline(p) => {
                let t = run_pipeline(&mut ctx, &an, p, tile);
                ctx.values.insert(p.out, t);
            }
            _ => {
                // Single-kernel group: evaluate members in order using a
                // local scratch; count boundary traffic only.
                let members: std::collections::HashSet<NodeId> =
                    grp.nodes.iter().copied().collect();
                let mut scratch: HashMap<NodeId, Tensor> = HashMap::new();
                let mut read_seen: std::collections::HashSet<NodeId> =
                    std::collections::HashSet::new();
                for &n in &grp.nodes {
                    let node = g.node(n);
                    let operand_ids = node.op.input_ids();
                    let mut operand_tensors: Vec<Tensor> = vec![];
                    for &oid in &operand_ids {
                        let t = if let Some(t) = scratch.get(&oid) {
                            t.clone()
                        } else if let Some(t) = ctx.values.get(&oid) {
                            if !members.contains(&oid) && read_seen.insert(oid) {
                                ctx.counters.read_elems(g.numel(oid));
                            }
                            t.clone()
                        } else if let Op::Input { name } = &g.node(oid).op {
                            if read_seen.insert(oid) {
                                ctx.counters.read_elems(g.numel(oid));
                            }
                            inputs[name].clone()
                        } else if matches!(
                            g.node(oid).op,
                            Op::Const { .. } | Op::Iota { .. }
                        ) {
                            // in-kernel generator (free unless eager)
                            let t = eval_node(&g.node(oid).op, &g.node(oid).shape, &[]);
                            scratch.insert(oid, t.clone());
                            t
                        } else {
                            panic!("operand {oid:?} not available")
                        };
                        operand_tensors.push(t);
                    }
                    let refs: Vec<&Tensor> = operand_tensors.iter().collect();
                    let t = eval_node(&node.op, &node.shape, &refs);
                    ctx.counters.flops += node_flops(g, n);
                    scratch.insert(n, t);
                }
                // Materialize externally-visible nodes.
                for &n in &grp.nodes {
                    let external = outputs.contains(&n)
                        || cons[n.0 as usize]
                            .iter()
                            .any(|c| plan.assignment[c.0 as usize] != gi);
                    if external {
                        ctx.counters.write_elems(g.numel(n));
                        ctx.values.insert(n, scratch[&n].clone());
                    }
                }
            }
        }
    }

    let outs = g
        .outputs
        .iter()
        .map(|o| ctx.values[o].clone())
        .collect();
    (outs, ctx.counters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::eval;
    use crate::fusion::{plan, FusionMode};
    use crate::variants::{build, paper_variants, AttnShape, Variant};

    fn synthetic_inputs(g: &Graph, seed: u64) -> HashMap<String, Tensor> {
        let mut m = HashMap::new();
        for (i, &id) in g.inputs.iter().enumerate() {
            let node = g.node(id);
            let Op::Input { name } = &node.op else { unreachable!() };
            let t = if name.starts_with("doc") {
                let n: usize = node.shape.iter().product();
                Tensor::from_vec(
                    &node.shape,
                    (0..n).map(|j| (j * 3 / n) as f32).collect(),
                )
            } else {
                Tensor::synthetic(&node.shape, seed + i as u64)
            };
            m.insert(name.clone(), t);
        }
        m
    }

    fn check_variant(v: Variant, shape: AttnShape, tile: TileConfig, tol: f32) {
        let g = build(v, &shape);
        let inputs = synthetic_inputs(&g, 11);
        let (want, _) = eval(&g, &inputs);
        let p = plan(&g, FusionMode::Flashlight);
        assert!(p.num_pipelines() >= 1, "{}", v.name());
        let (got, c) = execute_plan(&g, &p, &inputs, tile);
        assert_eq!(got.len(), want.len());
        let err = got[0].max_abs_diff(&want[0]);
        assert!(
            err <= tol,
            "{}: fused/unfused diverge by {err}",
            v.name()
        );
        assert!(c.hbm_read > 0 && c.hbm_write > 0);
    }

    #[test]
    fn fused_execution_matches_reference_all_variants() {
        let shape = AttnShape {
            batch: 2,
            rows: 1,
            heads_q: 2,
            heads_kv: 2,
            seq: 32,
            head_dim: 8,
        };
        let tile = TileConfig {
            block_q: 16,
            block_k: 8,
            l2_capacity: 40 << 20,
        };
        for v in paper_variants() {
            let v = match v {
                Variant::SlidingWindow { .. } => Variant::SlidingWindow { window: 7 },
                Variant::PrefixLm { .. } => Variant::PrefixLm { prefix: 9 },
                other => other,
            };
            check_variant(v, shape, tile, 1e-5);
        }
    }

    #[test]
    fn fused_execution_matches_reference_gqa() {
        let shape = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 4,
            heads_kv: 2,
            seq: 32,
            head_dim: 8,
        };
        check_variant(
            Variant::Causal,
            shape,
            TileConfig {
                block_q: 8,
                block_k: 16,
                l2_capacity: 40 << 20,
            },
            1e-5,
        );
    }

    #[test]
    fn fused_execution_matches_reference_complex_variants() {
        let shape = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 2,
            heads_kv: 2,
            seq: 16,
            head_dim: 8,
        };
        let tile = TileConfig {
            block_q: 8,
            block_k: 8,
            l2_capacity: 40 << 20,
        };
        check_variant(Variant::DiffAttn { lambda: 0.5 }, shape, tile, 1e-5);
        check_variant(Variant::Evoformer, shape, tile, 1e-5);
    }

    #[test]
    fn twin_matmul_pipeline_matches_reference() {
        let mut b = crate::ir::GraphBuilder::new("twin");
        let a = b.input("a", &[64, 16]);
        let bb = b.input("b", &[16, 32]);
        let d = b.input("d", &[32, 8]);
        let c = b.matmul(a, bb);
        let e = b.matmul(c, d);
        let g = b.finish(&[e]);
        let inputs = synthetic_inputs(&g, 5);
        let (want, _) = eval(&g, &inputs);
        let p = plan(&g, FusionMode::Flashlight);
        assert_eq!(p.num_pipelines(), 1);
        let (got, _) = execute_plan(
            &g,
            &p,
            &inputs,
            TileConfig {
                block_q: 16,
                block_k: 8,
                l2_capacity: 40 << 20,
            },
        );
        let err = got[0].max_abs_diff(&want[0]);
        assert!(err < 1e-4, "twin matmul diverges by {err}");
    }

    #[test]
    fn executed_traffic_matches_analytic_counters() {
        let shape = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 2,
            heads_kv: 2,
            seq: 32,
            head_dim: 8,
        };
        let tile = TileConfig {
            block_q: 8,
            block_k: 8,
            l2_capacity: 40 << 20,
        };
        for v in [Variant::Vanilla, Variant::Causal] {
            let g = build(v, &shape);
            let inputs = synthetic_inputs(&g, 3);
            let p = plan(&g, FusionMode::Flashlight);
            let (_, c_exec) = execute_plan(&g, &p, &inputs, tile);
            let c_model = p.counters(&g, tile);
            assert_eq!(
                c_exec.hbm_read, c_model.hbm_read,
                "{}: read mismatch (exec vs model)",
                v.name()
            );
            assert_eq!(c_exec.hbm_write, c_model.hbm_write, "{}", v.name());
            assert_eq!(c_exec.launches, c_model.launches, "{}", v.name());
        }
    }

    #[test]
    fn torch_compile_plan_also_executes_correctly() {
        let shape = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 2,
            heads_kv: 2,
            seq: 16,
            head_dim: 8,
        };
        let g = build(Variant::Causal, &shape);
        let inputs = synthetic_inputs(&g, 9);
        let (want, _) = eval(&g, &inputs);
        let p = plan(&g, FusionMode::TorchCompile);
        let (got, c) = execute_plan(&g, &p, &inputs, TileConfig::default());
        assert!(got[0].allclose(&want[0], 1e-6));
        // inductor-style plan materializes the S^2 intermediates
        let fl = plan(&g, FusionMode::Flashlight);
        let (_, cf) = execute_plan(&g, &fl, &inputs, TileConfig::default());
        assert!(cf.total_traffic() < c.total_traffic());
    }
}
