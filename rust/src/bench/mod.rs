//! Benchmark harness: regenerates every table and figure in the paper's
//! evaluation (§4) — see DESIGN.md §5 for the experiment index.

pub mod ablations;
pub mod figures;
pub mod harness;

pub use harness::{bench_fn, stats_of, Csv, Stats};

use crate::cost::{a100, h100, GpuSpec};

/// Entry point for `flashlight bench <which> [--gpu ...]`.
pub fn run(which: &str, gpu: &GpuSpec) -> anyhow::Result<()> {
    match which {
        "fig2" => figures::fig2_fig3(&h100(), false)?,
        "fig3" => figures::fig2_fig3(&a100(), false)?,
        "fig4" => figures::fig4(&[h100(), a100()])?,
        "fig5" => crate::serve::bench_fig5(gpu)?,
        "fig6" => figures::fig2_fig3(&h100(), true)?,
        "fig7" => figures::fig2_fig3(&a100(), true)?,
        "alphafold" => figures::alphafold(gpu)?,
        "masks" => figures::mask_cost_table(gpu),
        "ablations" => {
            ablations::run(gpu)?;
            crate::serve::bench_prefix_caching(gpu)?;
        }
        "all" => {
            figures::fig2_fig3(&h100(), false)?;
            figures::fig2_fig3(&a100(), false)?;
            figures::fig4(&[h100(), a100()])?;
            crate::serve::bench_fig5(gpu)?;
            figures::fig2_fig3(&h100(), true)?;
            figures::fig2_fig3(&a100(), true)?;
            figures::alphafold(&h100())?;
            figures::mask_cost_table(&h100());
            ablations::run(&h100())?;
            crate::serve::bench_prefix_caching(&h100())?;
        }
        other => anyhow::bail!("unknown figure {other} (fig2..fig7|alphafold|masks|all)"),
    }
    Ok(())
}
