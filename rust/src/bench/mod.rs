//! Benchmark harness: regenerates every table and figure in the paper's
//! evaluation (§4) — see DESIGN.md §5 for the experiment index.

pub mod ablations;
pub mod engine;
pub mod figures;
pub mod harness;
pub mod serve_bench;

pub use harness::{
    bench_fn, bench_median_ms, json_f64, json_str, stats_of, Csv, JsonArray, Stats,
};

use crate::cost::{a100, h100, GpuSpec};

/// Default output path for the parallel-engine perf trajectory.
pub const ENGINE_BENCH_PATH: &str = "BENCH_parallel_engine.json";

/// Default output path for the serve-throughput trajectory.
pub const SERVE_BENCH_PATH: &str = "BENCH_serve_engine.json";

/// Entry point for `flashlight bench <which> [--gpu ...] [--threads N]`.
/// `threads == 0` means all available cores (engine bench only).
pub fn run(which: &str, gpu: &GpuSpec, threads: usize) -> anyhow::Result<()> {
    match which {
        "engine" => engine::run(threads, ENGINE_BENCH_PATH)?,
        "serve_engine" => serve_bench::run(SERVE_BENCH_PATH)?,
        "fig2" => figures::fig2_fig3(&h100(), false)?,
        "fig3" => figures::fig2_fig3(&a100(), false)?,
        "fig4" => figures::fig4(&[h100(), a100()])?,
        "fig5" => crate::serve::bench_fig5(gpu)?,
        "fig6" => figures::fig2_fig3(&h100(), true)?,
        "fig7" => figures::fig2_fig3(&a100(), true)?,
        "alphafold" => figures::alphafold(gpu)?,
        "masks" => figures::mask_cost_table(gpu),
        "ablations" => {
            ablations::run(gpu)?;
            crate::serve::bench_prefix_caching(gpu)?;
        }
        "all" => {
            figures::fig2_fig3(&h100(), false)?;
            figures::fig2_fig3(&a100(), false)?;
            figures::fig4(&[h100(), a100()])?;
            crate::serve::bench_fig5(gpu)?;
            figures::fig2_fig3(&h100(), true)?;
            figures::fig2_fig3(&a100(), true)?;
            figures::alphafold(&h100())?;
            figures::mask_cost_table(&h100());
            ablations::run(&h100())?;
            crate::serve::bench_prefix_caching(&h100())?;
            engine::run(threads, ENGINE_BENCH_PATH)?;
            serve_bench::run(SERVE_BENCH_PATH)?;
        }
        other => {
            anyhow::bail!(
                "unknown figure {other} \
                 (fig2..fig7|alphafold|masks|engine|serve_engine|all)"
            )
        }
    }
    Ok(())
}
