//! Ablation studies for the design choices DESIGN.md calls out:
//! the L2 swizzle/reuse, the tile-size autotuner (`blockreduction`
//! heuristic, §3.7), and the materialization threshold (§3.7).

use crate::baselines::EFF_FLASHLIGHT;
use crate::bench::harness::Csv;
use crate::cost::{kernel_time, GpuSpec};
use crate::fusion::{
    plan, plan_with_threshold, FusionMode, TileConfig,
    FLASHLIGHT_MATERIALIZE_THRESHOLD, INDUCTOR_MATERIALIZE_THRESHOLD,
};
use crate::grid::{autotune, blockreduction_space, LaunchConfig};
use crate::variants::{build, AttnShape, Variant};

/// Pick the best (block_q, block_k) for a variant+shape by modeled
/// kernel time — the `blockreduction` autotuner driving the same cost
/// model the benchmarks use.
pub fn autotune_tile(
    variant: Variant,
    shape: &AttnShape,
    spec: &GpuSpec,
    aggressive: bool,
) -> (TileConfig, f64) {
    let g = build(variant, shape);
    let p = plan(&g, FusionMode::Flashlight);
    let cost = |c: LaunchConfig| {
        let tile = TileConfig {
            block_q: c.xblock,
            block_k: c.rblock,
            l2_capacity: spec.l2_capacity,
        };
        kernel_time(spec, &p.counters(&g, tile), EFF_FLASHLIGHT)
    };
    let best = autotune(&blockreduction_space(aggressive), None, cost);
    let tile = TileConfig {
        block_q: best.xblock,
        block_k: best.rblock,
        l2_capacity: spec.l2_capacity,
    };
    let t = kernel_time(spec, &p.counters(&g, tile), EFF_FLASHLIGHT);
    (tile, t)
}

pub fn run(spec: &GpuSpec) -> anyhow::Result<()> {
    let mut csv = Csv::new(
        super::figures::OUT_DIR,
        "ablations.csv",
        "ablation,config,value_us_or_count",
    );

    // --- A1: L2 reuse (the GROUP_M swizzle's effect) --------------------
    println!("== A1: L2 tile-reuse (swizzle) ablation, causal MHA ({}) ==", spec.name);
    for (b, s) in [(4usize, 4096usize), (1, 16384)] {
        let g = build(Variant::Causal, &AttnShape::mha(b, s));
        let p = plan(&g, FusionMode::Flashlight);
        let with = p.counters(
            &g,
            TileConfig {
                l2_capacity: spec.l2_capacity,
                ..Default::default()
            },
        );
        let without = p.counters(
            &g,
            TileConfig {
                l2_capacity: 0, // rereads spill to HBM: no swizzle reuse
                ..Default::default()
            },
        );
        let t_with = kernel_time(spec, &with, EFF_FLASHLIGHT);
        let t_without = kernel_time(spec, &without, EFF_FLASHLIGHT);
        println!(
            "  B{b} S{s}: with reuse {:8.1} us  without {:8.1} us  ({:.2}x)",
            t_with * 1e6,
            t_without * 1e6,
            t_without / t_with
        );
        csv.row(&[
            "l2_reuse".into(),
            format!("B{b}S{s}_with"),
            format!("{:.2}", t_with * 1e6),
        ]);
        csv.row(&[
            "l2_reuse".into(),
            format!("B{b}S{s}_without"),
            format!("{:.2}", t_without * 1e6),
        ]);
    }

    // --- A2: tile-size autotuning (blockreduction heuristic) ------------
    println!("== A2: blockreduction autotuning, causal MHA B1 S16384 ==");
    let shape = AttnShape::mha(1, 16384);
    let g = build(Variant::Causal, &shape);
    let p = plan(&g, FusionMode::Flashlight);
    for bq in [16usize, 32, 64, 128, 256] {
        let tile = TileConfig {
            block_q: bq,
            block_k: 64,
            l2_capacity: spec.l2_capacity,
        };
        let t = kernel_time(spec, &p.counters(&g, tile), EFF_FLASHLIGHT);
        println!("  block_q {bq:>4}: {:9.1} us", t * 1e6);
        csv.row(&["tile_sweep".into(), format!("bq{bq}"), format!("{:.2}", t * 1e6)]);
    }
    let (best, t_best) = autotune_tile(Variant::Causal, &shape, spec, true);
    println!(
        "  autotuned -> block_q {} block_k {}: {:9.1} us",
        best.block_q,
        best.block_k,
        t_best * 1e6
    );
    csv.row(&[
        "tile_sweep".into(),
        format!("autotuned_bq{}_bk{}", best.block_q, best.block_k),
        format!("{:.2}", t_best * 1e6),
    ]);

    // --- A3: materialization threshold (§3.7) ---------------------------
    println!("== A3: materialization threshold, ALiBi score chain ==");
    let g = build(Variant::Alibi, &AttnShape::mha(4, 4096));
    for (label, thr) in [
        ("inductor", INDUCTOR_MATERIALIZE_THRESHOLD),
        ("flashlight", FLASHLIGHT_MATERIALIZE_THRESHOLD),
        ("tiny(3)", 3usize),
    ] {
        let p = plan_with_threshold(&g, FusionMode::TorchCompile, thr);
        let c = p.counters(&g, TileConfig::default());
        println!(
            "  threshold {label:<12} -> {:>2} kernels, {:>6} MiB traffic",
            p.groups.len(),
            c.total_traffic() >> 20
        );
        csv.row(&[
            "materialize_threshold".into(),
            label.into(),
            format!("{}", p.groups.len()),
        ]);
    }
    let p = csv.finish()?;
    println!("wrote {}", p.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::h100;

    #[test]
    fn l2_reuse_always_helps_or_ties() {
        let spec = h100();
        let g = build(Variant::Causal, &AttnShape::mha(1, 16384));
        let p = plan(&g, FusionMode::Flashlight);
        let with = p.counters(
            &g,
            TileConfig {
                l2_capacity: spec.l2_capacity,
                ..Default::default()
            },
        );
        let without = p.counters(
            &g,
            TileConfig {
                l2_capacity: 0,
                ..Default::default()
            },
        );
        assert!(with.hbm_read < without.hbm_read);
        assert_eq!(with.total_with_l2(), without.total_with_l2());
        assert!(
            kernel_time(&spec, &with, EFF_FLASHLIGHT)
                <= kernel_time(&spec, &without, EFF_FLASHLIGHT)
        );
    }

    #[test]
    fn autotuned_tile_no_worse_than_default() {
        let spec = h100();
        let shape = AttnShape::mha(1, 16384);
        let g = build(Variant::Causal, &shape);
        let p = plan(&g, FusionMode::Flashlight);
        let t_default = kernel_time(
            &spec,
            &p.counters(
                &g,
                TileConfig {
                    l2_capacity: spec.l2_capacity,
                    ..Default::default()
                },
            ),
            EFF_FLASHLIGHT,
        );
        let (_, t_tuned) = autotune_tile(Variant::Causal, &shape, &spec, true);
        assert!(t_tuned <= t_default * 1.0001);
    }

    #[test]
    fn lower_threshold_means_more_kernels() {
        let g = build(Variant::Alibi, &AttnShape::mha(1, 1024));
        let lo = plan_with_threshold(&g, FusionMode::TorchCompile, 3);
        let hi = plan_with_threshold(
            &g,
            FusionMode::TorchCompile,
            FLASHLIGHT_MATERIALIZE_THRESHOLD,
        );
        assert!(
            lo.groups.len() > hi.groups.len(),
            "threshold 3 -> {} kernels vs raised -> {}",
            lo.groups.len(),
            hi.groups.len()
        );
        // the raised threshold also means less boundary traffic
        let cl = lo.counters(&g, TileConfig::default());
        let ch = hi.counters(&g, TileConfig::default());
        assert!(ch.total_traffic() <= cl.total_traffic());
    }
}
