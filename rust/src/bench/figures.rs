//! Paper-figure regeneration (§4): one function per figure/table.
//!
//! Absolute numbers come from the GPU cost model driven by the compiler's
//! own plans and traffic counters (DESIGN.md §2 substitutions); what must
//! match the paper is the *shape*: who wins, by roughly what factor, and
//! where the crossovers fall.

use crate::baselines::{estimate_attention, mask_creation_time, System};
use crate::bench::harness::Csv;
use crate::cost::GpuSpec;
use crate::fusion::TileConfig;
use crate::variants::{AttnShape, Variant};

/// The paper's token budget: batch x seqlen = 16k (§4.1).
pub const TOKEN_BUDGET: usize = 16 * 1024;

/// (batch, seqlen) sweep with B*S = 16k, S from 512 to 16k.
pub fn token_sweep() -> Vec<(usize, usize)> {
    [512usize, 1024, 2048, 4096, 8192, 16384]
        .iter()
        .map(|&s| (TOKEN_BUDGET / s, s))
        .collect()
}

pub const OUT_DIR: &str = "bench_results";

fn fmt_us(t: f64) -> String {
    format!("{:9.1}", t * 1e6)
}

/// Figures 2 (H100) / 3 (A100): FlexAttention-supported variants under
/// Flashlight, FlexAttention (block-mask + kernel split) and FlashInfer,
/// for MHA and GQA. Matches the paper's bar groups; the `fl/flex`
/// column reproduces the speedup annotations on the bars.
pub fn fig2_fig3(spec: &GpuSpec, include_torch_compile: bool) -> anyhow::Result<()> {
    let fig = if spec.name == "H100" { "fig2" } else { "fig3" };
    let fname = if include_torch_compile {
        format!("{}_appendix.csv", fig) // figs 6/7 include torch.compile
    } else {
        format!("{}.csv", fig)
    };
    let mut csv = Csv::new(
        OUT_DIR,
        &fname,
        "gpu,variant,attn,batch,seqlen,system,kernel_us,prep_us,total_us",
    );
    println!(
        "== {} ({}): FlexAttention-supported variants ==",
        if include_torch_compile {
            if spec.name == "H100" { "Figure 6" } else { "Figure 7" }
        } else if spec.name == "H100" {
            "Figure 2"
        } else {
            "Figure 3"
        },
        spec.name
    );
    let tile = TileConfig::default();
    for variant in crate::variants::paper_variants() {
        for (attn, mk) in [
            ("MHA", AttnShape::mha as fn(usize, usize) -> AttnShape),
            ("GQA", AttnShape::gqa as fn(usize, usize) -> AttnShape),
        ] {
            println!("\n-- {} {} --", variant.name(), attn);
            println!(
                "{:<22} {}",
                "system",
                token_sweep()
                    .iter()
                    .map(|(b, s)| format!("B{:<2}xS{:<6}", b, s))
                    .collect::<Vec<_>>()
                    .join(" ")
            );
            let mut systems = vec![
                System::Flashlight,
                System::FlexAttention { mask_cached: false },
                System::FlashInfer,
            ];
            if include_torch_compile {
                systems.push(System::TorchCompile);
            }
            let mut flex_totals = vec![];
            let mut fl_totals = vec![];
            for sys in systems {
                let mut cells = vec![];
                for (b, s) in token_sweep() {
                    let shape = mk(b, s);
                    let est = estimate_attention(sys, variant, &shape, spec, tile)
                        .expect("flex-supported");
                    cells.push(fmt_us(est.total()));
                    if sys == System::Flashlight {
                        fl_totals.push(est.total());
                    }
                    if matches!(sys, System::FlexAttention { .. }) {
                        flex_totals.push(est.total());
                    }
                    csv.row(&[
                        spec.name.into(),
                        variant.name().into(),
                        attn.into(),
                        b.to_string(),
                        s.to_string(),
                        sys.label().into(),
                        format!("{:.2}", est.kernel_s * 1e6),
                        format!("{:.2}", est.prep_s * 1e6),
                        format!("{:.2}", est.total() * 1e6),
                    ]);
                }
                println!("{:<22} {}", sys.label(), cells.join(" "));
            }
            // the paper's on-bar annotation: flashlight speedup over flex
            let ann: Vec<String> = fl_totals
                .iter()
                .zip(&flex_totals)
                .map(|(fl, fx)| format!("{:9.2}", fx / fl))
                .collect();
            println!("{:<22} {}", "speedup fl/flex", ann.join(" "));
        }
    }
    let p = csv.finish()?;
    println!("\nwrote {}", p.display());
    Ok(())
}

/// Figure 4: variants beyond the FlexAttention template — DiffAttn
/// (d=64 and 128) and Evoformer (B 1..32, S=256) — Flashlight vs
/// torch.compile on both GPUs.
pub fn fig4(specs: &[GpuSpec]) -> anyhow::Result<()> {
    let mut csv = Csv::new(
        OUT_DIR,
        "fig4.csv",
        "gpu,variant,config,batch,seqlen,system,total_us,speedup",
    );
    println!("== Figure 4: variants not supported by FlexAttention ==");
    let tile = TileConfig::default();
    for spec in specs {
        // DiffAttn: MHA config, head dims 64 and 128 (§4.1).
        for d in [64usize, 128] {
            println!("\n-- DiffAttn {} d={} --", spec.name, d);
            println!("{:<16} {}", "system", "B,S sweep (us); speedup in last row");
            let mut speeds = vec![];
            for (b, s) in token_sweep() {
                let shape = AttnShape {
                    batch: b,
                    rows: 1,
                    heads_q: 16,
                    heads_kv: 16,
                    seq: s,
                    head_dim: d,
                };
                let v = Variant::DiffAttn { lambda: 0.5 };
                let fl = estimate_attention(System::Flashlight, v, &shape, spec, tile)
                    .unwrap();
                let tc = estimate_attention(System::TorchCompile, v, &shape, spec, tile)
                    .unwrap();
                let speedup = tc.total() / fl.total();
                speeds.push(speedup);
                println!(
                    "  B{:<3} S{:<6} flashlight {} torch.compile {}  ({:.2}x)",
                    b,
                    s,
                    fmt_us(fl.total()),
                    fmt_us(tc.total()),
                    speedup
                );
                for (sys, est) in [("flashlight", fl), ("torch.compile", tc)] {
                    csv.row(&[
                        spec.name.into(),
                        "diff_attn".into(),
                        format!("d{}", d),
                        b.to_string(),
                        s.to_string(),
                        sys.into(),
                        format!("{:.2}", est.total() * 1e6),
                        format!("{:.3}", speedup),
                    ]);
                }
            }
        }
        // Evoformer: B 1..32, S=256, H=4, d in {64,128}, MSA rows = 128.
        for d in [64usize, 128] {
            println!("\n-- Evoformer {} d={} (S=256, rows=128) --", spec.name, d);
            for b in [1usize, 2, 4, 8, 16, 32] {
                let shape = AttnShape::evoformer(b, 128, 256, d);
                let v = Variant::Evoformer;
                let fl = estimate_attention(System::Flashlight, v, &shape, spec, tile)
                    .unwrap();
                let tc = estimate_attention(System::TorchCompile, v, &shape, spec, tile)
                    .unwrap();
                let speedup = tc.total() / fl.total();
                println!(
                    "  B{:<3} flashlight {} torch.compile {}  ({:.2}x)",
                    b,
                    fmt_us(fl.total()),
                    fmt_us(tc.total()),
                    speedup
                );
                for (sys, est) in [("flashlight", fl), ("torch.compile", tc)] {
                    csv.row(&[
                        spec.name.into(),
                        "evoformer".into(),
                        format!("d{}", d),
                        b.to_string(),
                        "256".into(),
                        sys.into(),
                        format!("{:.2}", est.total() * 1e6),
                        format!("{:.3}", speedup),
                    ]);
                }
            }
        }
    }
    let p = csv.finish()?;
    println!("\nwrote {}", p.display());
    Ok(())
}

/// §4.4 AlphaFold end-to-end: a 48-layer Evoformer stack at S=256.
/// Flashlight accelerates the row/column gated self-attention ~5x; the
/// rest of the layer (transitions, outer-product mean, triangle updates)
/// is unchanged, diluting the end-to-end gain to the paper's 6-9%.
pub fn alphafold(spec: &GpuSpec) -> anyhow::Result<()> {
    let mut csv = Csv::new(
        OUT_DIR,
        "alphafold.csv",
        "gpu,batch,pytorch_ms,flashlight_ms,improvement_pct",
    );
    println!("== §4.4 AlphaFold (48-layer Evoformer stack, S=256) ==");
    let tile = TileConfig::default();
    const LAYERS: f64 = 48.0;
    for b in [1usize, 2, 4, 8, 16, 32] {
        // AlphaFold model config: 8 heads, head dim 32 (paper §4.4).
        let shape = AttnShape::evoformer(b, 128, 256, 32);
        let v = Variant::Evoformer;
        let fl = estimate_attention(System::Flashlight, v, &shape, spec, tile).unwrap();
        let tc = estimate_attention(System::TorchCompile, v, &shape, spec, tile).unwrap();
        // Per layer: row + column gated attention (2x the attention
        // block) + the rest of the Evoformer layer. The non-attention
        // share is calibrated so attention is ~20% of the un-compiled
        // layer, matching OpenFold profiles (triangle updates dominate).
        let attn_pt = 2.0 * tc.total();
        let other = 11.5 * attn_pt;
        let pytorch_e2e = LAYERS * (attn_pt + other);
        let flash_e2e = LAYERS * (2.0 * fl.total() + other);
        let gain = 100.0 * (1.0 - flash_e2e / pytorch_e2e);
        println!(
            "  B{:<3} PyTorch {:8.1} ms  +Flashlight {:8.1} ms  (-{:.1}%)",
            b,
            pytorch_e2e * 1e3,
            flash_e2e * 1e3,
            gain
        );
        csv.row(&[
            spec.name.into(),
            b.to_string(),
            format!("{:.2}", pytorch_e2e * 1e3),
            format!("{:.2}", flash_e2e * 1e3),
            format!("{:.2}", gain),
        ]);
    }
    let p = csv.finish()?;
    println!("wrote {}", p.display());
    Ok(())
}

/// §4.2 sanity table: mask-creation cost vs kernel cost across the sweep
/// (the explanation for FlexAttention's end-to-end losses).
pub fn mask_cost_table(spec: &GpuSpec) {
    println!("== block-mask creation vs kernel time ({}) ==", spec.name);
    let tile = TileConfig::default();
    for (b, s) in token_sweep() {
        let shape = AttnShape::mha(b, s);
        let fx = estimate_attention(
            System::FlexAttention { mask_cached: false },
            Variant::Causal,
            &shape,
            spec,
            tile,
        )
        .unwrap();
        println!(
            "  B{:<3} S{:<6} kernel {:9.1} us   mask-creation {:9.1} us ({}x kernel)",
            b,
            s,
            fx.kernel_s * 1e6,
            fx.prep_s * 1e6,
            (fx.prep_s / fx.kernel_s * 10.0).round() / 10.0
        );
        debug_assert!((fx.prep_s - mask_creation_time(spec, s)).abs() < 1e-12);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{a100, h100};

    #[test]
    fn token_sweep_respects_budget() {
        for (b, s) in token_sweep() {
            assert_eq!(b * s, TOKEN_BUDGET);
        }
    }

    #[test]
    fn evoformer_speedup_is_at_least_5x() {
        // Paper Fig 4 / §4.3: "For Evoformer, the speedups are 5x or
        // more on both H100 and A100."
        let tile = TileConfig::default();
        for spec in [h100(), a100()] {
            for b in [1usize, 8, 32] {
                let shape = AttnShape::evoformer(b, 128, 256, 64);
                let fl = estimate_attention(
                    System::Flashlight,
                    Variant::Evoformer,
                    &shape,
                    &spec,
                    tile,
                )
                .unwrap();
                let tc = estimate_attention(
                    System::TorchCompile,
                    Variant::Evoformer,
                    &shape,
                    &spec,
                    tile,
                )
                .unwrap();
                let speedup = tc.total() / fl.total();
                assert!(
                    speedup >= 5.0,
                    "{} B={}: evoformer speedup {:.2} < 5",
                    spec.name,
                    b,
                    speedup
                );
            }
        }
    }

    #[test]
    fn diff_attn_flashlight_always_beats_torch_compile() {
        let tile = TileConfig::default();
        for spec in [h100(), a100()] {
            for (b, s) in token_sweep() {
                let shape = AttnShape {
                    batch: b,
                    rows: 1,
                    heads_q: 16,
                    heads_kv: 16,
                    seq: s,
                    head_dim: 64,
                };
                let v = Variant::DiffAttn { lambda: 0.5 };
                let fl =
                    estimate_attention(System::Flashlight, v, &shape, &spec, tile)
                        .unwrap();
                let tc =
                    estimate_attention(System::TorchCompile, v, &shape, &spec, tile)
                        .unwrap();
                assert!(tc.total() > fl.total(), "{} B{} S{}", spec.name, b, s);
            }
        }
    }

    #[test]
    fn alphafold_improvement_in_paper_band() {
        // 6-9% inference-latency improvement (§4.4). Allow a slightly
        // wider band for the substituted cost model.
        let tile = TileConfig::default();
        for spec in [h100(), a100()] {
            for b in [1usize, 8, 32] {
                let shape = AttnShape::evoformer(b, 128, 256, 32);
                let fl = estimate_attention(
                    System::Flashlight,
                    Variant::Evoformer,
                    &shape,
                    &spec,
                    tile,
                )
                .unwrap();
                let tc = estimate_attention(
                    System::TorchCompile,
                    Variant::Evoformer,
                    &shape,
                    &spec,
                    tile,
                )
                .unwrap();
                let attn_pt = 2.0 * tc.total();
                let other = 11.5 * attn_pt;
                let gain = 100.0 * (attn_pt - 2.0 * fl.total()) / (attn_pt + other);
                assert!(
                    (4.0..14.0).contains(&gain),
                    "{} B{}: alphafold gain {:.1}% out of band",
                    spec.name,
                    b,
                    gain
                );
            }
        }
    }
}
