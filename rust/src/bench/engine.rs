//! Parallel-engine benchmark: wall-clock of the fused tiled executor,
//! sequential vs multi-threaded, for every built-in variant — with a
//! bit-identity check between the two runs baked in.
//!
//! Writes `BENCH_parallel_engine.json` (via `scripts/bench_regress.sh`)
//! so future PRs have a perf trajectory to compare against.

use std::collections::HashMap;

use crate::bench::harness::{bench_median_ms, json_f64, json_str, JsonArray};
use crate::exec::simd::{self, SimdLevel};
use crate::exec::{eval, execute_plan, execute_plan_par, Parallelism, Tensor};
use crate::fusion::{blockmask_enabled, plan, set_blockmask_override, FusionMode, TileConfig};
use crate::ir::{Graph, Op};
use crate::variants::{build, paper_variants, AttnShape, Variant};

fn inputs_for(g: &Graph, seed: u64) -> HashMap<String, Tensor> {
    let mut m = HashMap::new();
    for (i, &id) in g.inputs.iter().enumerate() {
        let node = g.node(id);
        let Op::Input { name } = &node.op else { unreachable!() };
        let t = if name.starts_with("doc") {
            let n: usize = node.shape.iter().product();
            Tensor::from_vec(&node.shape, (0..n).map(|j| (j * 4 / n) as f32).collect())
        } else {
            Tensor::synthetic(&node.shape, seed + i as u64)
        };
        m.insert(name.clone(), t);
    }
    m
}

fn bench_variants(seq: usize) -> Vec<Variant> {
    let mut vs: Vec<Variant> = paper_variants()
        .into_iter()
        .map(|v| match v {
            Variant::SlidingWindow { .. } => Variant::SlidingWindow { window: seq / 4 },
            Variant::PrefixLm { .. } => Variant::PrefixLm { prefix: seq * 3 / 8 },
            other => other,
        })
        .collect();
    vs.push(Variant::DiffAttn { lambda: 0.5 });
    vs.push(Variant::Evoformer);
    vs
}

/// Run the engine bench. `threads == 0` means all available cores.
/// Writes the JSON trajectory to `out_path` and prints a table.
pub fn run(threads: usize, out_path: &str) -> anyhow::Result<()> {
    let shape = AttnShape {
        batch: 2,
        rows: 1,
        heads_q: 8,
        heads_kv: 4,
        seq: 256,
        head_dim: 32,
    };
    let tile = TileConfig {
        block_q: 32,
        block_k: 64,
        ..Default::default()
    };
    run_with(threads, out_path, shape, tile, 2, 5)
}

/// Parameterized form (tests use a scaled-down shape).
pub fn run_with(
    threads: usize,
    out_path: &str,
    shape: AttnShape,
    tile: TileConfig,
    warmup: usize,
    iters: usize,
) -> anyhow::Result<()> {
    // threads == 0: FLASHLIGHT_THREADS env override, else all cores.
    let par = if threads == 0 {
        Parallelism::from_env()
    } else {
        Parallelism::with_threads(threads)
    };
    // Spawn the persistent worker pool up front: the timed runs below
    // measure grid scheduling over parked workers (a launch wakes them
    // through the epoch doorbell), not thread creation.
    crate::exec::runtime::warm(&par);
    println!(
        "== parallel engine: fused executor, sequential vs {} threads ==",
        par.num_threads
    );
    println!(
        "worker runtime: topology {}, SIMD tier {}",
        crate::exec::runtime::topology().describe(),
        simd::level().name()
    );
    println!(
        "{:<16} {:>10} {:>10} {:>8}  {}",
        "variant", "seq(ms)", "par(ms)", "speedup", "bit-identical"
    );
    let mut json = JsonArray::new(out_path);
    let mut worst_speedup = f64::INFINITY;
    let topo = crate::exec::runtime::topology().describe();
    for v in bench_variants(shape.seq) {
        let shape = if matches!(v, Variant::Evoformer) {
            AttnShape { rows: 2, ..shape }
        } else {
            shape
        };
        let g = build(v, &shape);
        let inputs = inputs_for(&g, 7);
        let p = plan(&g, FusionMode::Flashlight);
        anyhow::ensure!(p.num_pipelines() >= 1, "{}: no pipeline", v.name());

        // Correctness + determinism gate before timing anything.
        let (seq_out, seq_c) = execute_plan(&g, &p, &inputs, tile);
        let (par_out, par_c) = execute_plan_par(&g, &p, &inputs, tile, &par);
        let identical = seq_out == par_out && seq_c == par_c;
        anyhow::ensure!(identical, "{}: parallel run diverged", v.name());
        let (want, _) = eval(&g, &inputs);
        let err = seq_out[0].max_abs_diff(&want[0]);
        anyhow::ensure!(err < 1e-3, "{}: fused/eager err {err}", v.name());

        let seq_ms = bench_median_ms(warmup, iters, || {
            let _ = execute_plan(&g, &p, &inputs, tile);
        });
        let par_ms = bench_median_ms(warmup, iters, || {
            let _ = execute_plan_par(&g, &p, &inputs, tile, &par);
        });
        let speedup = seq_ms / par_ms;
        worst_speedup = worst_speedup.min(speedup);
        println!(
            "{:<16} {:>10.3} {:>10.3} {:>8.2}  {}",
            v.name(),
            seq_ms,
            par_ms,
            speedup,
            identical
        );
        json.push_obj(&[
            ("variant", json_str(v.name())),
            ("seq_ms", json_f64(seq_ms)),
            ("par_ms", json_f64(par_ms)),
            ("speedup", json_f64(speedup)),
            ("threads", par.num_threads.to_string()),
            ("topology", json_str(&topo)),
            ("bit_identical", identical.to_string()),
            ("blockmask", blockmask_enabled().to_string()),
            ("seq", shape.seq.to_string()),
            ("batch", shape.batch.to_string()),
            ("heads_q", shape.heads_q.to_string()),
            ("head_dim", shape.head_dim.to_string()),
        ]);
    }
    sparsity_sweep_into(&mut json, &shape, tile)?;
    microbench_into(&mut json, warmup, iters);
    let p = json.finish()?;
    println!(
        "worst speedup {:.2}x over {} threads; wrote {}",
        worst_speedup,
        par.num_threads,
        p.display()
    );
    Ok(())
}

/// Block-sparsity sweep over a (window x seq-len) grid: each cell runs
/// the fused executor dense (block masks forced off) and sparse (forced
/// on) and gates the contract the planner's tile classes promise —
/// outputs bit-identical to the dense run for every index-mask variant,
/// `tiles_skipped > 0`, work and traffic never above dense, and the
/// sparse run itself bit-stable at 1/2/4 threads. The threshold variant
/// (`rectified`, runtime data-dependent mask) is gated on tolerance vs
/// the unpruned run, with inputs crafted so the coarse pass provably
/// prunes its last k-block. Results land in the JSON trajectory.
fn sparsity_sweep_into(
    json: &mut JsonArray,
    base: &AttnShape,
    tile: TileConfig,
) -> anyhow::Result<()> {
    println!("\n== block-sparsity sweep: sparse vs dense, 1/2/4 threads ==");
    println!(
        "{:<16} {:>5} {:>6} {:>9} {:>9} {:>12}",
        "variant", "seq", "window", "visited", "skipped", "flops saved"
    );
    for &seq in &[base.seq / 2, base.seq] {
        let mut cells: Vec<(Variant, usize)> = vec![
            (Variant::DocumentMask, 0),
            (Variant::PrefixLm { prefix: seq / 4 }, 0),
            (Variant::Rectified { tau: 0.05 }, 0),
        ];
        for &w in &[seq / 8, seq / 4] {
            cells.push((Variant::SlidingWindow { window: w }, w));
        }
        for (v, window) in cells {
            let shape = AttnShape { seq, ..*base };
            let g = build(v, &shape);
            let mut inputs = inputs_for(&g, 11);
            let threshold = matches!(v, Variant::Rectified { .. });
            if threshold {
                // Deterministic runtime mask: all-positive q against an
                // all-ones first k-block makes every row live after the
                // first tile; an all-zero last k-block scores exactly 0
                // (< tau), so the coarse pass must prune it.
                if let Some(q) = inputs.get_mut("q") {
                    q.data.iter_mut().for_each(|x| *x = x.abs() + 0.5);
                }
                if let Some(k) = inputs.get_mut("k") {
                    let r = k.shape.len();
                    let d = k.shape[r - 1];
                    let sk = k.shape[r - 2];
                    let bk = tile.block_k.min(sk);
                    for (j, x) in k.data.iter_mut().enumerate() {
                        let s = (j / d) % sk;
                        if s < bk {
                            *x = 1.0;
                        } else if s >= sk - bk {
                            *x = 0.0;
                        }
                    }
                }
            }
            let p = plan(&g, FusionMode::Flashlight);

            set_blockmask_override(Some(false));
            let (dense_out, dense_c) = execute_plan(&g, &p, &inputs, tile);
            set_blockmask_override(Some(true));
            let (sparse_out, sparse_c) = execute_plan(&g, &p, &inputs, tile);
            // The sparse path must be bit-stable across thread counts
            // (outputs *and* counters — skip decisions are data-, not
            // schedule-, dependent).
            let mut thread_stable = true;
            for threads in [2usize, 4] {
                let (o, c) = execute_plan_par(
                    &g,
                    &p,
                    &inputs,
                    tile,
                    &Parallelism::with_threads(threads),
                );
                thread_stable &= o == sparse_out && c == sparse_c;
            }
            set_blockmask_override(None);
            anyhow::ensure!(
                thread_stable,
                "{} seq={seq}: sparse run diverged across thread counts",
                v.name()
            );

            if threshold {
                let err = sparse_out[0].max_abs_diff(&dense_out[0]);
                anyhow::ensure!(
                    err < 1e-5,
                    "{} seq={seq}: pruned run err {err} vs unpruned",
                    v.name()
                );
            } else {
                anyhow::ensure!(
                    sparse_out == dense_out,
                    "{} seq={seq}: sparse outputs not bit-identical to dense",
                    v.name()
                );
            }
            anyhow::ensure!(
                sparse_c.tiles_skipped > 0,
                "{} seq={seq}: expected skipped tiles, visited {} skipped {}",
                v.name(),
                sparse_c.tiles_visited,
                sparse_c.tiles_skipped
            );
            anyhow::ensure!(
                sparse_c.flops < dense_c.flops || threshold,
                "{} seq={seq}: sparse flops {} not below dense {}",
                v.name(),
                sparse_c.flops,
                dense_c.flops
            );
            anyhow::ensure!(
                sparse_c.hbm_read <= dense_c.hbm_read
                    && sparse_c.l2_read <= dense_c.l2_read
                    && sparse_c.hbm_write == dense_c.hbm_write,
                "{} seq={seq}: sparse traffic above dense",
                v.name()
            );

            println!(
                "{:<16} {:>5} {:>6} {:>9} {:>9} {:>12}",
                v.name(),
                seq,
                window,
                sparse_c.tiles_visited,
                sparse_c.tiles_skipped,
                sparse_c.flops_avoided
            );
            json.push_obj(&[
                ("sweep", json_str("blocksparse")),
                ("variant", json_str(v.name())),
                ("seq", seq.to_string()),
                ("window", window.to_string()),
                ("blockmask", "true".to_string()),
                ("tiles_visited", sparse_c.tiles_visited.to_string()),
                ("tiles_skipped", sparse_c.tiles_skipped.to_string()),
                ("flops_avoided", sparse_c.flops_avoided.to_string()),
                ("bytes_skipped", sparse_c.bytes_skipped.to_string()),
                ("dense_flops", dense_c.flops.to_string()),
                ("sparse_flops", sparse_c.flops.to_string()),
                ("dense_l2_read", dense_c.l2_read.to_string()),
                ("sparse_l2_read", sparse_c.l2_read.to_string()),
                ("bit_identical", (!threshold).to_string()),
            ]);
        }
    }
    Ok(())
}

/// GEMM/softmax microkernel microbench: GFLOP/s per kernel, scalar tier
/// vs the dispatched tier, appended to the engine trajectory JSON so
/// kernel PRs have a per-kernel baseline. Pointwise kernels (exp) are
/// counted at one flop per element.
fn microbench_into(json: &mut JsonArray, warmup: usize, iters: usize) {
    let lvl = simd::level();
    println!("\n== microkernels: scalar vs {} ==", lvl.name());
    println!(
        "{:<12} {:>14} {:>14} {:>8}",
        "kernel", "scalar GF/s", "simd GF/s", "speedup"
    );
    let mut push = |kernel: &str, flops: f64, mut run: Box<dyn FnMut(SimdLevel)>| {
        let scalar_ms = bench_median_ms(warmup, iters, || run(SimdLevel::Scalar));
        let simd_ms = bench_median_ms(warmup, iters, || run(lvl));
        let scalar_gfs = flops / (scalar_ms * 1e6);
        let simd_gfs = flops / (simd_ms * 1e6);
        println!(
            "{:<12} {:>14.2} {:>14.2} {:>8.2}",
            kernel,
            scalar_gfs,
            simd_gfs,
            scalar_ms / simd_ms
        );
        json.push_obj(&[
            ("kernel", json_str(kernel)),
            ("level", json_str(lvl.name())),
            ("scalar_gflops", json_f64(scalar_gfs)),
            ("simd_gflops", json_f64(simd_gfs)),
            ("speedup", json_f64(scalar_ms / simd_ms)),
        ]);
    };

    // NT (QKᵀ): one q-tile row block against a kv span.
    let (m, n, k) = (64, 256, 64);
    let a = Tensor::synthetic(&[m, k], 31).data;
    let b = Tensor::synthetic(&[n, k], 32).data;
    let mut c = vec![0.0f32; m * n];
    push(
        "gemm_nt",
        (2 * m * n * k) as f64,
        Box::new(move |l| simd::gemm_nt_with(l, &a, &b, &mut c, m, n, k)),
    );

    // NN (PV): scores x V, accumulator zero-filled per run (the
    // memset is part of the timed body; it is <2% of the flops).
    let (m, n, k) = (64, 64, 256);
    let a = Tensor::synthetic(&[m, k], 33).data;
    let b = Tensor::synthetic(&[k, n], 34).data;
    let mut c = vec![0.0f32; m * n];
    push(
        "gemm_nn",
        (2 * m * n * k) as f64,
        Box::new(move |l| {
            c.iter_mut().for_each(|x| *x = 0.0);
            simd::gemm_nn_with(l, &a, &b, &mut c, m, n, k)
        }),
    );

    // Online-softmax exp over a score tile's worth of elements.
    let n = 16 * 1024;
    let x: Vec<f32> = Tensor::synthetic(&[n], 35).data.iter().map(|v| v * 8.0).collect();
    let mut e = vec![0.0f32; n];
    push(
        "exp",
        n as f64,
        Box::new(move |l| simd::vexp_shift_with(l, &mut e, &x, -0.25)),
    );

    // Row reduction (softmax denominator / running max).
    let x = Tensor::synthetic(&[16 * 1024], 36).data;
    push(
        "row_sum",
        x.len() as f64,
        Box::new(move |l| {
            std::hint::black_box(simd::row_sum_with(l, &x));
        }),
    );

    // PV row fold (acc += p * v) across a tile of rows.
    let rows = 256;
    let d = 64;
    let v = Tensor::synthetic(&[rows * d], 37).data;
    let mut acc = vec![0.0f32; d];
    push(
        "axpy",
        (2 * rows * d) as f64,
        Box::new(move |l| {
            for j in 0..rows {
                simd::axpy_with(l, &mut acc, 0.5, &v[j * d..(j + 1) * d]);
            }
        }),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_bench_runs_and_writes_json() {
        // Tiny smoke run (2 threads, scaled-down shape, 1 iter each).
        let dir = "/tmp/flashlight_engine_bench";
        std::fs::create_dir_all(dir).unwrap();
        let path = format!("{dir}/BENCH_parallel_engine.json");
        let shape = AttnShape {
            batch: 1,
            rows: 1,
            heads_q: 2,
            heads_kv: 2,
            seq: 32,
            head_dim: 8,
        };
        let tile = TileConfig {
            block_q: 8,
            block_k: 8,
            ..Default::default()
        };
        run_with(2, &path, shape, tile, 0, 1).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.contains("\"variant\": \"causal\""));
        assert!(s.contains("\"bit_identical\": true"));
        assert!(s.contains("\"blockmask\""));
        assert!(s.contains("\"sweep\": \"blocksparse\""));
        assert!(s.contains("\"tiles_skipped\""));
    }
}
