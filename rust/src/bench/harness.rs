//! Minimal wall-clock benchmarking harness (criterion is unavailable in
//! this offline environment): warmup + timed iterations + robust stats,
//! mirroring the paper's methodology of 10 warmup + 20 measured runs.

use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub stddev_s: f64,
    pub iters: usize,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean_s * 1e6
    }
}

/// Run `f` with `warmup` unmeasured and `iters` measured iterations
/// (paper §4.1: 10 warmup, 20 measured).
pub fn bench_fn(warmup: usize, iters: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    stats_of(&mut samples)
}

pub fn stats_of(samples: &mut [f64]) -> Stats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len().max(1);
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples
        .iter()
        .map(|s| (s - mean) * (s - mean))
        .sum::<f64>()
        / n as f64;
    Stats {
        mean_s: mean,
        median_s: samples[n / 2],
        min_s: samples.first().copied().unwrap_or(0.0),
        max_s: samples.last().copied().unwrap_or(0.0),
        stddev_s: var.sqrt(),
        iters: n,
    }
}

/// Median wall clock of `f` in milliseconds over `warmup` unmeasured +
/// `iters` measured runs — the timing-loop boilerplate shared by the
/// bench tables (engine bench, serve bench) so call sites don't each
/// re-spell the warmup/measure/convert dance.
pub fn bench_median_ms(warmup: usize, iters: usize, f: impl FnMut()) -> f64 {
    bench_fn(warmup, iters, f).median_s * 1e3
}

/// Simple CSV writer for bench_results/.
pub struct Csv {
    path: std::path::PathBuf,
    rows: Vec<String>,
}

impl Csv {
    pub fn new(dir: &str, name: &str, header: &str) -> Self {
        std::fs::create_dir_all(dir).ok();
        Csv {
            path: std::path::Path::new(dir).join(name),
            rows: vec![header.to_string()],
        }
    }

    pub fn row(&mut self, cols: &[String]) {
        self.rows.push(cols.join(","));
    }

    pub fn finish(self) -> std::io::Result<std::path::PathBuf> {
        std::fs::write(&self.path, self.rows.join("\n") + "\n")?;
        Ok(self.path)
    }
}

/// Minimal JSON array-of-objects writer (serde is unavailable offline).
/// Values are pre-rendered JSON fragments — use [`json_str`]/[`json_f64`].
pub struct JsonArray {
    path: std::path::PathBuf,
    items: Vec<String>,
}

impl JsonArray {
    pub fn new(path: impl Into<std::path::PathBuf>) -> Self {
        JsonArray {
            path: path.into(),
            items: vec![],
        }
    }

    /// Append one object; `fields` are (key, rendered-JSON-value) pairs.
    pub fn push_obj(&mut self, fields: &[(&str, String)]) {
        let body = fields
            .iter()
            .map(|(k, v)| format!("{}: {}", json_str(k), v))
            .collect::<Vec<_>>()
            .join(", ");
        self.items.push(format!("{{{body}}}"));
    }

    pub fn finish(self) -> std::io::Result<std::path::PathBuf> {
        let mut out = String::from("[\n");
        out.push_str(
            &self
                .items
                .iter()
                .map(|i| format!("  {i}"))
                .collect::<Vec<_>>()
                .join(",\n"),
        );
        out.push_str("\n]\n");
        std::fs::write(&self.path, out)?;
        Ok(self.path)
    }
}

/// Render a JSON string literal (quotes + minimal escaping).
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Render a finite f64 as JSON (NaN/inf become null).
pub fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_array_renders_parseable_objects() {
        let mut j = JsonArray::new("/tmp/flashlight_test_json/t.json");
        std::fs::create_dir_all("/tmp/flashlight_test_json").unwrap();
        j.push_obj(&[
            ("name", json_str("causal \"v1\"")),
            ("speedup", json_f64(2.5)),
            ("threads", "8".to_string()),
        ]);
        j.push_obj(&[("name", json_str("alibi")), ("speedup", json_f64(f64::NAN))]);
        let p = j.finish().unwrap();
        let s = std::fs::read_to_string(p).unwrap();
        assert!(s.starts_with("[\n"));
        assert!(s.contains("\"causal \\\"v1\\\"\""));
        assert!(s.contains("\"speedup\": 2.500000"));
        assert!(s.contains("\"speedup\": null"));
        assert!(s.trim_end().ends_with(']'));
    }

    #[test]
    fn stats_are_sane() {
        let mut s = vec![3.0, 1.0, 2.0];
        let st = stats_of(&mut s);
        assert_eq!(st.median_s, 2.0);
        assert_eq!(st.min_s, 1.0);
        assert_eq!(st.max_s, 3.0);
        assert!((st.mean_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn bench_fn_runs_expected_iterations() {
        let mut count = 0;
        let st = bench_fn(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(st.iters, 5);
    }

    #[test]
    fn csv_writes_rows() {
        let mut c = Csv::new("/tmp/flashlight_test_csv", "t.csv", "a,b");
        c.row(&["1".into(), "2".into()]);
        let p = c.finish().unwrap();
        let s = std::fs::read_to_string(p).unwrap();
        assert_eq!(s, "a,b\n1,2\n");
    }
}
