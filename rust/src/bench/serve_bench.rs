//! Serve-throughput bench: the engine backend under the continuous
//! batcher across the serving matrix — chunked prefill on/off and
//! L ∈ {1, 4} attention layers — each at 1 / 2 / all threads with the
//! bit-identity gate baked in (every thread count must emit the
//! identical token stream for its configuration).
//!
//! Also gates the two per-step perf bugs this bench originally
//! surfaced: after plan-cache warmup a chunk-scheduled run builds zero
//! plans (so zero `analyze()` calls reach the executor — the per-run
//! `analyze_calls` field records the global counter delta) and decode
//! gathers perform zero allocations (`gather_reallocs == 0`, enforced).
//!
//! Writes `BENCH_serve_engine.json` (via `scripts/bench_regress.sh`) so
//! the perf trajectory covers the serve side: tokens/s and TTFT
//! p50/p99 per (layers, chunked, threads) cell, plus plan-cache and
//! prefix-cache stats — and, for the live half, a goodput-vs-offered-
//! load curve (Poisson-retimed open-loop arrivals reduced to
//! completed/shed/goodput/SLO-attainment per rate).

use crate::bench::harness::{json_f64, json_str, JsonArray};
use crate::exec::Parallelism;
use crate::serve::{
    engine_trace, load_point, run_lifecycle, run_lifecycle_ext, run_trace, summarize, Backend,
    ClockMode, EngineBackend, EngineModel, FaultPlan, Ingress, LifecycleConfig, Outcome,
    SchedulerConfig, StreamHub,
};
use crate::tracegen::{retime_arrivals, ArrivalModel};

/// Default entry point (`flashlight bench serve_engine`).
pub fn run(out_path: &str) -> anyhow::Result<()> {
    run_with(out_path, 24)
}

/// Parameterized form (tests use a smaller trace).
pub fn run_with(out_path: &str, n_requests: usize) -> anyhow::Result<()> {
    let trace = engine_trace(n_requests);
    let mut threads: Vec<usize> = vec![1, 2, Parallelism::available().num_threads];
    threads.sort_unstable();
    threads.dedup();
    println!(
        "== serve throughput: engine backend, {} requests, chunking x layers matrix ==",
        n_requests
    );
    println!(
        "worker runtime: topology {} (persistent pool; spawn gate on)",
        crate::exec::runtime::topology().describe()
    );
    println!(
        "{:>6} {:>7} {:>7} {:>9} {:>9} {:>9} {:>9} {:>8}  {}",
        "layers", "chunked", "threads", "tok/s", "wall(s)", "TTFT p50", "TTFT p99", "ITL(ms)", "bit-identical"
    );
    let mut json = JsonArray::new(out_path);
    for (layers, chunked) in [(1usize, false), (1, true), (4, false), (4, true)] {
        let mut baseline: Option<Vec<u32>> = None;
        for &t in &threads {
            let par = Parallelism::with_threads(t);
            let mut b = EngineBackend::new(EngineModel::tiny_deep(layers), 8, 1024, par);
            let vocab = b.model.vocab;
            b.enable_token_log(); // the bit-identity gate needs the stream
            let cfg = SchedulerConfig {
                parallelism: par,
                prefill_chunk_tokens: if chunked { 64 } else { 0 },
                prefill_round_tokens: if chunked { 256 } else { 0 },
                ..Default::default()
            };
            // Warmup (satellite gate): pre-build the bucket ladder, then
            // count plans and analyze() calls the run itself adds.
            b.configure(&cfg);
            let warmed = b.warmup_plans(1024);
            let misses0 = b.cache_stats().misses;
            let analyze0 = crate::sketch::analyze_call_count();
            // Backend construction + configure() warmed the worker pool;
            // from here on the serving loop must never spawn a thread.
            let spawns0 = crate::exec::runtime::spawns_on_this_thread();
            let t0 = std::time::Instant::now();
            let done = run_trace(&mut b, &trace, cfg, vocab)?;
            let wall = t0.elapsed().as_secs_f64();
            let run_spawns = crate::exec::runtime::spawns_on_this_thread() - spawns0;
            let analyze_run = crate::sketch::analyze_call_count() - analyze0;
            let s = summarize(&done);
            let cs = b.cache_stats();
            let ps = b.prefix_stats();
            let run_misses = cs.misses - misses0;
            // Bit-identity gate: the scheduler's call sequence is timing
            // independent, so the token stream must match the 1-thread
            // run exactly at every thread count.
            let identical = match &baseline {
                None => {
                    baseline = Some(b.token_log.clone());
                    true
                }
                Some(base) => base == &b.token_log,
            };
            anyhow::ensure!(
                identical,
                "engine serve diverged at {t} threads (layers={layers} chunked={chunked})"
            );
            // Decode-gather allocation gate (satellite): per-slot scratch
            // makes steady-state gathers allocation-free.
            anyhow::ensure!(
                b.gather_reallocs() == 0,
                "decode gathers allocated ({} reallocs)",
                b.gather_reallocs()
            );
            // Plan warmup gate: every serving shape class is in the
            // warmed ladder (chunked: one q width per bucket; unchunked:
            // the full q<=kv triangle, covering prefix-adopted suffix
            // prefills), so the run itself must build zero plans — and
            // therefore trigger zero per-step analyze() calls.
            anyhow::ensure!(
                run_misses == 0,
                "post-warmup run built {run_misses} plans (layers={layers} chunked={chunked})"
            );
            // Persistent-runtime gate (tentpole): every launch of the
            // run — prefill chunks and decode steps alike — reused the
            // parked worker pool. Zero OS threads created.
            anyhow::ensure!(
                run_spawns == 0,
                "serving run spawned {run_spawns} threads after warmup \
                 (layers={layers} chunked={chunked} threads={t})"
            );
            println!(
                "{:>6} {:>7} {:>7} {:>9.1} {:>9.2} {:>9.2} {:>9.2} {:>8.3}  {}",
                layers,
                chunked,
                t,
                s.tokens_per_s,
                wall,
                s.ttft_p50_s * 1e3,
                s.ttft_p99_s * 1e3,
                s.itl_mean_s * 1e3,
                identical
            );
            json.push_obj(&[
                ("layers", layers.to_string()),
                ("chunked", chunked.to_string()),
                ("threads", t.to_string()),
                ("tokens_per_s", json_f64(s.tokens_per_s)),
                ("wall_s", json_f64(wall)),
                ("ttft_mean_ms", json_f64(s.ttft_mean_s * 1e3)),
                ("ttft_p50_ms", json_f64(s.ttft_p50_s * 1e3)),
                ("ttft_p99_ms", json_f64(s.ttft_p99_s * 1e3)),
                ("itl_mean_ms", json_f64(s.itl_mean_s * 1e3)),
                ("bit_identical", identical.to_string()),
                ("plan_cache_hits", cs.hits.to_string()),
                ("plan_cache_misses", cs.misses.to_string()),
                ("plan_cache_hit_rate", json_f64(cs.hit_rate())),
                ("plans_warmed", warmed.to_string()),
                ("post_warmup_plan_misses", run_misses.to_string()),
                ("analyze_calls_during_run", analyze_run.to_string()),
                ("post_warmup_thread_spawns", run_spawns.to_string()),
                ("gather_reallocs", b.gather_reallocs().to_string()),
                ("prefix_hits", ps.hits.to_string()),
                ("prefix_tokens_reused", ps.tokens_reused.to_string()),
                ("requests", n_requests.to_string()),
            ]);
        }
    }
    // Lifecycle cell: the fault-tolerant runner under a fixed
    // deterministic fault plan (pool pressure + a worker panic + a
    // cancel + a deadline storm) on the round clock, at every thread
    // count. Gates: exactly one terminal per request, no page leaks,
    // survivors bit-identical both across thread counts and to the
    // fault-free reference. Records terminal-state counts and goodput
    // so the perf trajectory covers degraded operation too.
    let plan = FaultPlan::parse("pressure@2:6x6;panic@3;cancel@5:1;storm@9:2")?;
    println!(
        "-- lifecycle under faults: plan `{plan}` --\n\
         {:>7} {:>9} {:>8} {:>9} {:>8} {:>6} {:>11} {:>9}",
        "threads", "completed", "rejected", "cancelled", "deadline", "failed", "preemptions", "goodput"
    );
    let mut healthy_ref: Option<Vec<(usize, Vec<u32>)>> = None;
    let mut fault_ref: Option<Vec<(usize, Vec<u32>)>> = None;
    for &t in &threads {
        let par = Parallelism::with_threads(t);
        let cfg = SchedulerConfig {
            parallelism: par,
            prefill_chunk_tokens: 64,
            prefill_round_tokens: 256,
            ..Default::default()
        };
        let lc = LifecycleConfig {
            clock: ClockMode::Rounds,
            ..Default::default()
        };
        // A tight page cap (trace worst case ~4 pages/request, 8
        // slots) makes the pressure window and preemption ladder bind.
        let mut hb = EngineBackend::new(EngineModel::tiny_deep(1), 8, 1024, par);
        hb.set_page_cap(20);
        let vocab = hb.model.vocab;
        let healthy = run_lifecycle(&mut hb, &trace, cfg, lc, &FaultPlan::none(), vocab)?;
        anyhow::ensure!(
            healthy.summary.completed == trace.len(),
            "fault-free lifecycle must complete all requests at {t} threads"
        );
        let mut b = EngineBackend::new(EngineModel::tiny_deep(1), 8, 1024, par);
        b.set_page_cap(20);
        let rep = run_lifecycle(&mut b, &trace, cfg, lc, &plan, vocab)?;
        let sum = &rep.summary;
        anyhow::ensure!(
            sum.total() == trace.len(),
            "lifecycle terminal accounting broken at {t} threads: {} of {}",
            sum.total(),
            trace.len()
        );
        let (alloc, free) = b.kv_pages();
        let parked = b.prefix_stats().parked_pages;
        anyhow::ensure!(
            alloc == free + parked,
            "lifecycle leaked pages at {t} threads: {alloc} allocated vs {free}+{parked}"
        );
        // Survivor streams: identical to the fault-free run and across
        // thread counts (the round clock makes both exact).
        let healthy_tokens: Vec<(usize, Vec<u32>)> = healthy
            .outcomes
            .into_iter()
            .map(|o| (o.id, o.tokens))
            .collect();
        let survivors: Vec<(usize, Vec<u32>)> = rep
            .outcomes
            .iter()
            .filter(|o| o.outcome == Outcome::Completed)
            .map(|o| (o.id, o.tokens.clone()))
            .collect();
        for (id, toks) in &survivors {
            let want = &healthy_tokens[*id].1;
            anyhow::ensure!(
                toks == want,
                "survivor {id} diverged from the fault-free run at {t} threads"
            );
        }
        match &healthy_ref {
            None => healthy_ref = Some(healthy_tokens),
            Some(base) => anyhow::ensure!(
                base == &healthy_tokens,
                "fault-free lifecycle diverged at {t} threads"
            ),
        }
        match &fault_ref {
            None => fault_ref = Some(survivors),
            Some(base) => anyhow::ensure!(
                base == &survivors,
                "faulted lifecycle survivors diverged at {t} threads"
            ),
        }
        println!(
            "{:>7} {:>9} {:>8} {:>9} {:>8} {:>6} {:>11} {:>9.1}",
            t,
            sum.completed,
            sum.rejected,
            sum.cancelled,
            sum.deadline_exceeded,
            sum.failed,
            sum.preemptions,
            sum.goodput_tokens_per_s,
        );
        json.push_obj(&[
            ("cell", json_str("lifecycle_chaos")),
            ("fault_plan", json_str(&plan.to_string())),
            ("threads", t.to_string()),
            ("completed", sum.completed.to_string()),
            ("rejected", sum.rejected.to_string()),
            ("cancelled", sum.cancelled.to_string()),
            ("deadline_exceeded", sum.deadline_exceeded.to_string()),
            ("failed", sum.failed.to_string()),
            ("preemptions", sum.preemptions.to_string()),
            ("goodput_tokens_per_round", json_f64(sum.goodput_tokens_per_s)),
            ("rounds", rep.stats.rounds.to_string()),
            ("throttled_rounds", rep.stats.throttled_rounds.to_string()),
            ("survivors_bit_identical", "true".to_string()),
            ("requests", n_requests.to_string()),
        ]);
    }
    // Goodput-vs-offered-load curve (the live half): retime the same
    // trace with Poisson interarrivals at increasing offered rates and
    // replay it open-loop on the round clock — arrivals do not wait for
    // server capacity, so overload sheds work (bounded queue, backoff
    // resubmission, default deadline) instead of silently stretching
    // the run. Each rate reduces to one completed/shed/goodput/SLO row.
    const SLO_TTFT_ROUNDS: f64 = 48.0;
    println!(
        "-- goodput under offered load (open loop, rounds clock) --\n\
         {:>9} {:>9} {:>6} {:>9} {:>11} {:>9}",
        "rate(r/r)", "completed", "shed", "goodput", "SLO attain", "requeues"
    );
    for rate in [0.25f64, 0.5, 1.0, 2.0] {
        let open = retime_arrivals(&trace, ArrivalModel::Poisson { rate }, 7);
        let par = Parallelism::with_threads(2);
        let cfg = SchedulerConfig {
            parallelism: par,
            prefill_chunk_tokens: 64,
            prefill_round_tokens: 256,
            ..Default::default()
        };
        let lc = LifecycleConfig {
            clock: ClockMode::Rounds,
            queue_cap: 8,
            resubmit_max: 3,
            default_deadline_s: 96.0,
            ..Default::default()
        };
        let mut b = EngineBackend::new(EngineModel::tiny_deep(1), 8, 1024, par);
        b.set_page_cap(20);
        let vocab = b.model.vocab;
        let rep = run_lifecycle_ext(
            &mut b,
            Ingress::OpenLoop { trace: &open, time_scale: 1.0 },
            cfg,
            lc,
            &FaultPlan::none(),
            vocab,
            &mut StreamHub::disabled(),
            None,
        )?;
        anyhow::ensure!(
            rep.summary.total() == open.len(),
            "open-loop terminal accounting broken at rate {rate}"
        );
        let (alloc, free) = b.kv_pages();
        let parked = b.prefix_stats().parked_pages;
        anyhow::ensure!(
            alloc == free + parked,
            "open-loop run leaked pages at rate {rate}: {alloc} vs {free}+{parked}"
        );
        let lp = load_point(&rep.outcomes, rate, SLO_TTFT_ROUNDS);
        println!(
            "{:>9.2} {:>9} {:>6} {:>9.1} {:>11.2} {:>9}",
            lp.offered_rps,
            lp.completed,
            lp.shed,
            lp.goodput_tokens_per_s,
            lp.slo_attainment,
            rep.stats.backoff_requeues,
        );
        json.push_obj(&[
            ("cell", json_str("goodput_load")),
            ("offered_rps", json_f64(lp.offered_rps)),
            ("completed", lp.completed.to_string()),
            ("shed", lp.shed.to_string()),
            ("goodput_tokens_per_round", json_f64(lp.goodput_tokens_per_s)),
            ("slo_attainment", json_f64(lp.slo_attainment)),
            ("slo_ttft_rounds", json_f64(SLO_TTFT_ROUNDS)),
            ("backoff_requeues", rep.stats.backoff_requeues.to_string()),
            ("rounds", rep.stats.rounds.to_string()),
            ("requests", n_requests.to_string()),
        ]);
    }
    // Sharded-serving cells (tentpole): the same trace behind the
    // conversation-sticky router over 1/2/4 engine instances, with the
    // determinism gate (sharding must be invisible in the per-request
    // streams), then a shard-kill cell exercising failover — every
    // request still reaches exactly one terminal, survivors match the
    // unsharded streams, and surviving pools do not leak.
    {
        use crate::serve::{run_sharded, RouterConfig};
        let par = Parallelism::with_threads(2);
        let cfg = SchedulerConfig {
            parallelism: par,
            prefill_chunk_tokens: 64,
            prefill_round_tokens: 256,
            ..Default::default()
        };
        let lc = LifecycleConfig {
            clock: ClockMode::Rounds,
            ..Default::default()
        };
        let vocab = EngineModel::tiny().vocab;
        let mk = || {
            move |_i: usize| {
                let mut b = EngineBackend::new(EngineModel::tiny_deep(1), 8, 1024, par);
                b.set_page_cap(20);
                b
            }
        };
        println!(
            "-- sharded serving (router + fault domains) --\n\
             {:>6} {:>9} {:>8} {:>7} {:>9} {:>9}  {}",
            "shards", "completed", "wall(s)", "steals", "goodput", "rounds", "topology"
        );
        let mut reference: Option<Vec<(usize, Vec<u32>)>> = None;
        for n_shards in [1usize, 2, 4] {
            let t0 = std::time::Instant::now();
            let rep = run_sharded(
                &trace,
                cfg,
                lc,
                &FaultPlan::none(),
                vocab,
                n_shards,
                RouterConfig::default(),
                mk(),
            )?;
            let wall = t0.elapsed().as_secs_f64();
            anyhow::ensure!(
                rep.summary.completed == trace.len(),
                "sharded run @{n_shards} completed {} of {}",
                rep.summary.completed,
                trace.len()
            );
            for h in &rep.shards {
                anyhow::ensure!(h.leak_free(), "shard {} leaked pages", h.id);
            }
            let streams: Vec<(usize, Vec<u32>)> = rep
                .outcomes
                .iter()
                .map(|o| (o.id, o.tokens.clone()))
                .collect();
            match &reference {
                None => reference = Some(streams),
                Some(base) => anyhow::ensure!(
                    base == &streams,
                    "token streams diverged at {n_shards} shards"
                ),
            }
            let rounds: u64 = rep.shards.iter().map(|h| h.rounds).max().unwrap_or(0);
            println!(
                "{:>6} {:>9} {:>8.2} {:>7} {:>9.1} {:>9}  {}",
                n_shards,
                rep.summary.completed,
                wall,
                rep.steals,
                rep.summary.goodput_tokens_per_s,
                rounds,
                rep.topology,
            );
            json.push_obj(&[
                ("cell", json_str("shard_scaling")),
                ("shards", n_shards.to_string()),
                ("completed", rep.summary.completed.to_string()),
                ("wall_s", json_f64(wall)),
                ("steals", rep.steals.to_string()),
                ("goodput_tokens_per_round", json_f64(rep.summary.goodput_tokens_per_s)),
                ("max_shard_rounds", rounds.to_string()),
                ("topology", json_str(&rep.topology)),
                ("bit_identical", "true".to_string()),
                ("requests", n_requests.to_string()),
            ]);
        }
        // Shard-kill failover cell: doom shard 0 mid-trace on a 2-way
        // split and gate exact terminal accounting + survivor identity.
        let plan = FaultPlan::parse("kill@3:shard=0")?;
        let rep = run_sharded(
            &trace,
            cfg,
            lc,
            &plan,
            vocab,
            2,
            RouterConfig::default(),
            mk(),
        )?;
        anyhow::ensure!(
            rep.outcomes.len() == trace.len(),
            "shard-kill run: {} terminals for {} requests",
            rep.outcomes.len(),
            trace.len()
        );
        let want: std::collections::HashMap<usize, &Vec<u32>> = reference
            .as_ref()
            .unwrap()
            .iter()
            .map(|(id, toks)| (*id, toks))
            .collect();
        for o in rep.outcomes.iter().filter(|o| o.outcome == Outcome::Completed) {
            anyhow::ensure!(
                Some(&&o.tokens) == want.get(&o.id),
                "shard-kill survivor {} diverged from the fault-free streams",
                o.id
            );
        }
        for h in rep.shards.iter().filter(|h| h.alive) {
            anyhow::ensure!(h.leak_free(), "surviving shard {} leaked pages", h.id);
        }
        println!(
            "-- shard kill `{plan}`: killed {:?}, {} failovers, {} completed, \
             survivors bit-identical, no survivor leaks --",
            rep.killed,
            rep.failovers,
            rep.summary.completed,
        );
        json.push_obj(&[
            ("cell", json_str("shard_kill")),
            ("fault_plan", json_str(&plan.to_string())),
            ("shards", "2".to_string()),
            ("killed_shards", rep.killed.len().to_string()),
            ("failovers", rep.failovers.to_string()),
            ("completed", rep.summary.completed.to_string()),
            ("failed", rep.summary.failed.to_string()),
            ("survivors_bit_identical", "true".to_string()),
            ("requests", n_requests.to_string()),
        ]);
    }
    let p = json.finish()?;
    println!("wrote {}", p.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_bench_runs_and_writes_json() {
        let dir = "/tmp/flashlight_serve_bench";
        std::fs::create_dir_all(dir).unwrap();
        let path = format!("{dir}/BENCH_serve_engine.json");
        run_with(&path, 4).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.contains("\"tokens_per_s\""));
        assert!(s.contains("\"bit_identical\": true"));
        assert!(s.contains("\"plan_cache_hit_rate\""));
        assert!(s.contains("\"ttft_p99_ms\""));
        assert!(s.contains("\"chunked\": true"));
        assert!(s.contains("\"layers\": 4"));
        assert!(s.contains("\"gather_reallocs\": 0"));
        assert!(s.contains("\"post_warmup_thread_spawns\": 0"));
        // The lifecycle cell records degraded-mode accounting.
        assert!(s.contains("\"cell\": \"lifecycle_chaos\""));
        assert!(s.contains("\"goodput_tokens_per_round\""));
        assert!(s.contains("\"survivors_bit_identical\": true"));
        // The goodput-vs-offered-load curve records one row per rate.
        assert!(s.contains("\"cell\": \"goodput_load\""));
        assert!(s.contains("\"slo_attainment\""));
        assert!(s.contains("\"offered_rps\""));
        // Sharded cells: scaling rows at 1/2/4 shards plus the
        // shard-kill failover row.
        assert!(s.contains("\"cell\": \"shard_scaling\""));
        assert!(s.contains("\"shards\": 4"));
        assert!(s.contains("\"cell\": \"shard_kill\""));
        assert!(s.contains("\"failovers\""));
    }
}
