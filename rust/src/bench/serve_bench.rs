//! Serve-throughput bench: the engine backend under the continuous
//! batcher at 1 / 2 / all threads, with the bit-identity gate baked in
//! (every thread count must emit the identical token stream).
//!
//! Writes `BENCH_serve_engine.json` (via `scripts/bench_regress.sh`) so
//! the perf trajectory covers the serve side: engine-backend tokens/s
//! per thread count plus plan-cache hit rates.

use crate::bench::harness::{json_f64, JsonArray};
use crate::exec::Parallelism;
use crate::serve::{engine_trace, run_trace, summarize, EngineBackend, SchedulerConfig};

/// Default entry point (`flashlight bench serve_engine`).
pub fn run(out_path: &str) -> anyhow::Result<()> {
    run_with(out_path, 24)
}

/// Parameterized form (tests use a smaller trace).
pub fn run_with(out_path: &str, n_requests: usize) -> anyhow::Result<()> {
    let trace = engine_trace(n_requests);
    let mut threads: Vec<usize> = vec![1, 2, Parallelism::available().num_threads];
    threads.sort_unstable();
    threads.dedup();
    println!(
        "== serve throughput: engine backend, {} requests ==",
        n_requests
    );
    println!(
        "{:>7} {:>10} {:>10} {:>9} {:>9}  {}",
        "threads", "tok/s", "wall(s)", "TTFT(ms)", "ITL(ms)", "bit-identical"
    );
    let mut json = JsonArray::new(out_path);
    let mut baseline: Option<Vec<u32>> = None;
    for &t in &threads {
        let par = Parallelism::with_threads(t);
        let mut b = EngineBackend::default_server(par);
        let vocab = b.model.vocab;
        b.enable_token_log(); // the bit-identity gate needs the stream
        let cfg = SchedulerConfig {
            parallelism: par,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let done = run_trace(&mut b, &trace, cfg, vocab)?;
        let wall = t0.elapsed().as_secs_f64();
        let s = summarize(&done);
        let cs = b.cache_stats();
        // Bit-identity gate: the scheduler's call sequence is timing
        // independent, so the token stream must match the 1-thread run
        // exactly at every thread count.
        let identical = match &baseline {
            None => {
                baseline = Some(b.token_log.clone());
                true
            }
            Some(base) => base == &b.token_log,
        };
        anyhow::ensure!(
            identical,
            "engine serve diverged at {t} threads (token stream mismatch)"
        );
        println!(
            "{:>7} {:>10.1} {:>10.2} {:>9.2} {:>9.3}  {}",
            t,
            s.tokens_per_s,
            wall,
            s.ttft_mean_s * 1e3,
            s.itl_mean_s * 1e3,
            identical
        );
        json.push_obj(&[
            ("threads", t.to_string()),
            ("tokens_per_s", json_f64(s.tokens_per_s)),
            ("wall_s", json_f64(wall)),
            ("ttft_mean_ms", json_f64(s.ttft_mean_s * 1e3)),
            ("itl_mean_ms", json_f64(s.itl_mean_s * 1e3)),
            ("bit_identical", identical.to_string()),
            ("plan_cache_hits", cs.hits.to_string()),
            ("plan_cache_misses", cs.misses.to_string()),
            ("plan_cache_hit_rate", json_f64(cs.hit_rate())),
            ("requests", n_requests.to_string()),
        ]);
    }
    let p = json.finish()?;
    println!("wrote {}", p.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_bench_runs_and_writes_json() {
        let dir = "/tmp/flashlight_serve_bench";
        std::fs::create_dir_all(dir).unwrap();
        let path = format!("{dir}/BENCH_serve_engine.json");
        run_with(&path, 4).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.contains("\"tokens_per_s\""));
        assert!(s.contains("\"bit_identical\": true"));
        assert!(s.contains("\"plan_cache_hit_rate\""));
    }
}
