//! The static plan verifier: four machine-checked proofs over
//! `ir::Graph` + `fusion::Plan` + `BlockMask`, run before any kernel
//! does (see `analysis/README.md` for the contract each check
//! certifies).
//!
//! 1. **Shape/broadcast re-inference** — every node's shape is
//!    re-derived from scratch (independently of `GraphBuilder`, which
//!    asserted the same rules at construction) and compared against the
//!    stored shape; rewritten pipelines additionally get their roles
//!    structurally validated.
//! 2. **Write-set/alias analysis** — re-derives the `LogicalGrid`
//!    decomposition exactly as `exec/tiled.rs::PipelineRun::new` will,
//!    and proves every (batch, head, q-tile) work item writes a
//!    disjoint output region while reading only immutable values;
//!    across kernels, proves group write sets are disjoint and reads
//!    come from earlier launches.
//! 3. **Float-determinism lint** — walks the planner's `RewriteEvent`
//!    trail and flags any rewrite that reorders a non-associative f32
//!    reduction outside the blessed online-softmax contract.
//! 4. **Mask-skip soundness** — re-derives `BlockMask` tile classes by
//!    brute-force predicate evaluation (including the dead-row
//!    demotion rule) instead of trusting construction, and checks the
//!    exp-pins-to-zero cutoff on the actual kernel.

use std::collections::HashMap;
use std::sync::Arc;

use crate::exec::{simd, Tensor, NEG_INF};
use crate::fusion::{
    classify_block_mask, eval_index_expr, BlockMask, CachedPlan, GroupKind, MaskInfo, MaskKind,
    Pipeline, Plan, Rule, SoftmaxRoles, TileClass, TileConfig, MAX_ELIM_DIM,
};
use crate::grid::{LogicalGrid, TiledDim};
use crate::ir::{broadcast_shapes, numel, Graph, NodeId, Op, PwOp, ReduceOp, Shape};
use crate::sketch::{analyze, DimAnalysis};

use super::diagnostics::{node_path, Certificate, CheckClass, Diagnostic};

/// Mirrors the mask classifier's own rank cap.
const MAX_RANK: usize = 8;

/// Brute-force budget for mask re-derivation, matching the classifier's
/// `CLASSIFY_CELL_CAP`: anything the classifier was willing to build,
/// the verifier is willing to re-check.
const VERIFY_CELL_CAP: usize = 1 << 26;

impl Plan {
    /// Statically verify this plan against the graph it was derived
    /// from. Returns a [`Certificate`] summarizing everything proved,
    /// or every violation found (the verifier does not stop at the
    /// first). Block masks for input-free index masks are re-classified
    /// internally; callers holding a [`CachedPlan`] should prefer
    /// [`verify_cached`], which reuses the cached analysis and masks.
    pub fn verify(&self, g: &Graph) -> Result<Certificate, Vec<Diagnostic>> {
        verify_with(g, self, TileConfig::default(), None, None)
    }
}

/// Verify a cached plan exactly as the executor will run it: same tile
/// config, same dimension analysis, same memoized block masks.
pub fn verify_cached(entry: &CachedPlan) -> Result<Certificate, Vec<Diagnostic>> {
    verify_with(
        &entry.graph,
        &entry.plan,
        entry.tile,
        Some(&entry.analysis),
        Some(&entry.block_masks),
    )
}

/// Full-control entry point: verify `plan` against `g` under `tile`.
/// `analysis` and `masks` are reused when provided (the `PlanCache`
/// path) and re-derived otherwise.
pub fn verify_with(
    g: &Graph,
    plan: &Plan,
    tile: TileConfig,
    analysis: Option<&DimAnalysis>,
    masks: Option<&[Option<Arc<BlockMask>>]>,
) -> Result<Certificate, Vec<Diagnostic>> {
    super::note_verify_call();
    let owned;
    let an = match analysis {
        Some(a) => a,
        None => {
            owned = analyze(g);
            &owned
        }
    };
    let mut cert = Certificate {
        graph: g.name.clone(),
        ..Certificate::default()
    };
    let mut diags = Vec::new();
    check_shapes(g, plan, &mut cert, &mut diags);
    check_races(g, plan, an, tile, &mut cert, &mut diags);
    check_determinism(g, plan, an, &mut cert, &mut diags);
    check_masks(g, plan, an, tile, masks, &mut cert, &mut diags);
    if diags.is_empty() {
        Ok(cert)
    } else {
        Err(diags)
    }
}

fn in_graph(g: &Graph, id: NodeId) -> bool {
    (id.0 as usize) < g.nodes.len()
}

/// A value derivable without reading any materialized buffer: Const or
/// Iota, possibly wrapped in view ops. Kernels regenerate these
/// in-scratch instead of reading them, so they are race-free reads.
fn generator_only(g: &Graph, id: NodeId) -> bool {
    if !in_graph(g, id) {
        return false;
    }
    match g.node(id).op {
        Op::Const { .. } | Op::Iota { .. } => true,
        Op::Broadcast { input } | Op::Slice { input, .. } => generator_only(g, input),
        _ => false,
    }
}

/// Strip `Broadcast` wrappers (local re-implementation — check 3 and 4
/// deliberately do not share the planner's helper they are auditing).
fn peel(g: &Graph, mut id: NodeId) -> NodeId {
    while in_graph(g, id) {
        match g.node(id).op {
            Op::Broadcast { input } => id = input,
            _ => break,
        }
    }
    id
}

// ---------------------------------------------------------------------
// Check 1: shape/broadcast re-inference
// ---------------------------------------------------------------------

fn check_shapes(g: &Graph, plan: &Plan, cert: &mut Certificate, diags: &mut Vec<Diagnostic>) {
    for id in g.ids() {
        let node = g.node(id);
        let mut ssa_ok = true;
        for src in node.op.input_ids() {
            if src.0 >= id.0 {
                diags.push(
                    Diagnostic::new(
                        CheckClass::ShapeInference,
                        format!(
                            "operand n{} is not defined before its use (graph is not in SSA order)",
                            src.0
                        ),
                    )
                    .with_node(g, &plan.log, id),
                );
                ssa_ok = false;
            }
        }
        if !ssa_ok {
            continue;
        }
        match infer_shape(g, id) {
            Ok(shape) => {
                if shape != node.shape {
                    diags.push(
                        Diagnostic::new(
                            CheckClass::ShapeInference,
                            format!(
                                "re-inferred shape {:?} disagrees with the stored shape {:?}",
                                shape, node.shape
                            ),
                        )
                        .with_node(g, &plan.log, id),
                    );
                }
            }
            Err(msg) => {
                diags.push(
                    Diagnostic::new(CheckClass::ShapeInference, msg).with_node(g, &plan.log, id),
                );
            }
        }
        cert.nodes_checked += 1;
    }
    // Pipeline structural invariants ride with check 1: every role the
    // rewrite introduced must still denote a node of the promised form.
    for grp in &plan.groups {
        let GroupKind::Pipeline(pipe) = &grp.kind else {
            continue;
        };
        let mut roles_ok = true;
        for (role, id) in [
            ("m1", pipe.m1),
            ("score_root", pipe.score_root),
            ("m2", pipe.m2),
            ("out", pipe.out),
        ] {
            if !in_graph(g, id) {
                diags.push(Diagnostic::new(
                    CheckClass::ShapeInference,
                    format!("pipeline role `{role}` names nonexistent node n{}", id.0),
                ));
                roles_ok = false;
            }
        }
        if !roles_ok {
            continue;
        }
        for (role, id) in [("m1", pipe.m1), ("m2", pipe.m2)] {
            if !matches!(g.node(id).op, Op::Matmul { .. }) {
                diags.push(
                    Diagnostic::new(
                        CheckClass::ShapeInference,
                        format!("pipeline role `{role}` is not a matmul"),
                    )
                    .with_node(g, &plan.log, id),
                );
            }
        }
        // §3.5 tiling-aware elimination collapses the output head-dim
        // loop — legal only if one tile covers it.
        if let Some(&d_out) = g.node(pipe.m2).shape.last() {
            if d_out > MAX_ELIM_DIM {
                diags.push(
                    Diagnostic::new(
                        CheckClass::ShapeInference,
                        format!(
                            "tiling-aware elimination requires one tile to cover the output \
                             head dim: {d_out} > MAX_ELIM_DIM ({MAX_ELIM_DIM})"
                        ),
                    )
                    .with_node(g, &plan.log, pipe.m2),
                );
            }
        }
        for (role, id) in [("score_root", pipe.score_root), ("out", pipe.out)] {
            if !grp.nodes.contains(&id) {
                diags.push(
                    Diagnostic::new(
                        CheckClass::ShapeInference,
                        format!(
                            "pipeline role `{role}` (n{}) is not a member of its own kernel group",
                            id.0
                        ),
                    )
                    .with_node(g, &plan.log, id),
                );
            }
        }
    }
}

/// Independently re-derive one node's shape from its operands — the
/// same rules `GraphBuilder` asserts at construction, re-implemented so
/// a graph mutated after building (or built by hand) is caught.
fn infer_shape(g: &Graph, id: NodeId) -> Result<Shape, String> {
    let node = g.node(id);
    match &node.op {
        Op::Input { .. } | Op::Const { .. } => Ok(node.shape.clone()),
        Op::Iota { axis } => {
            if *axis >= node.shape.len() {
                return Err(format!(
                    "iota axis {axis} out of range for rank {}",
                    node.shape.len()
                ));
            }
            Ok(node.shape.clone())
        }
        Op::Pointwise { op, inputs } => {
            if op.arity() != inputs.len() {
                return Err(format!(
                    "{op:?} expects {} operand(s), has {}",
                    op.arity(),
                    inputs.len()
                ));
            }
            let mut shape = g.node(inputs[0]).shape.clone();
            for &x in &inputs[1..] {
                let xs = &g.node(x).shape;
                shape = broadcast_shapes(&shape, xs).ok_or_else(|| {
                    format!("operand shapes {shape:?} and {xs:?} do not broadcast")
                })?;
            }
            Ok(shape)
        }
        Op::Broadcast { input } => {
            let xs = &g.node(*input).shape;
            if xs.len() != node.shape.len() {
                return Err(format!(
                    "broadcast changes rank: {} -> {}",
                    xs.len(),
                    node.shape.len()
                ));
            }
            for (ax, (&a, &b)) in xs.iter().zip(&node.shape).enumerate() {
                if a != b && a != 1 {
                    return Err(format!("broadcast axis {ax}: cannot stretch {a} to {b}"));
                }
            }
            Ok(node.shape.clone())
        }
        Op::Matmul {
            lhs,
            rhs,
            transpose_rhs,
        } => {
            let sa = &g.node(*lhs).shape;
            let sb = &g.node(*rhs).shape;
            if sa.len() != sb.len() {
                return Err(format!(
                    "matmul rank mismatch: lhs {sa:?} vs rhs {sb:?}"
                ));
            }
            let r = sa.len();
            if r < 2 {
                return Err(format!("matmul needs rank >= 2, got {r}"));
            }
            let (m, ka) = (sa[r - 2], sa[r - 1]);
            let (kb, n) = if *transpose_rhs {
                (sb[r - 1], sb[r - 2])
            } else {
                (sb[r - 2], sb[r - 1])
            };
            if ka != kb {
                return Err(format!("matmul contraction mismatch: {ka} vs {kb}"));
            }
            let mut shape = Vec::with_capacity(r);
            for i in 0..r - 2 {
                if sb[i] != sa[i] && sb[i] != 1 {
                    return Err(format!(
                        "matmul batch axis {i}: rhs {} does not broadcast to lhs {}",
                        sb[i], sa[i]
                    ));
                }
                shape.push(sa[i]);
            }
            shape.push(m);
            shape.push(n);
            Ok(shape)
        }
        Op::Reduce { input, axis, .. } => {
            let mut shape = g.node(*input).shape.clone();
            if *axis >= shape.len() {
                return Err(format!(
                    "reduce axis {axis} out of range for rank {}",
                    shape.len()
                ));
            }
            shape[*axis] = 1;
            Ok(shape)
        }
        Op::Slice {
            input,
            axis,
            start,
            len,
        } => {
            let mut shape = g.node(*input).shape.clone();
            if *axis >= shape.len() {
                return Err(format!(
                    "slice axis {axis} out of range for rank {}",
                    shape.len()
                ));
            }
            if start + len > shape[*axis] {
                return Err(format!(
                    "slice {start}..{} out of range for axis extent {}",
                    start + len,
                    shape[*axis]
                ));
            }
            shape[*axis] = *len;
            Ok(shape)
        }
    }
}

// ---------------------------------------------------------------------
// Check 2: write-set/alias analysis over the LogicalGrid decomposition
// ---------------------------------------------------------------------

fn check_races(
    g: &Graph,
    plan: &Plan,
    an: &DimAnalysis,
    tile: TileConfig,
    cert: &mut Certificate,
    diags: &mut Vec<Diagnostic>,
) {
    let n = g.nodes.len();
    // (a) Inter-kernel write sets: each materialized node is written by
    // exactly one kernel group, and the assignment table agrees.
    let mut owner: Vec<Option<usize>> = vec![None; n];
    for (gi, grp) in plan.groups.iter().enumerate() {
        for &m in &grp.nodes {
            if !in_graph(g, m) {
                diags.push(Diagnostic::new(
                    CheckClass::RaceFreedom,
                    format!("kernel group {gi} names nonexistent node n{}", m.0),
                ));
                continue;
            }
            let i = m.0 as usize;
            match owner[i] {
                Some(prev) => diags.push(
                    Diagnostic::new(
                        CheckClass::RaceFreedom,
                        format!(
                            "kernel groups {prev} and {gi} both write n{}: overlapping \
                             write sets",
                            m.0
                        ),
                    )
                    .with_node(g, &plan.log, m),
                ),
                None => {
                    owner[i] = Some(gi);
                    let assigned = plan.assignment.get(i).copied().unwrap_or(usize::MAX);
                    if assigned != gi {
                        diags.push(
                            Diagnostic::new(
                                CheckClass::RaceFreedom,
                                format!(
                                    "assignment table maps n{} to group {assigned} but group \
                                     {gi} claims it",
                                    m.0
                                ),
                            )
                            .with_node(g, &plan.log, m),
                        );
                    }
                }
            }
        }
    }
    // (b) Read immutability: groups launch in index order, so every
    // value a kernel reads must be a graph input, its own in-kernel
    // scratch, or the output of an earlier-launched group.
    for (gi, grp) in plan.groups.iter().enumerate() {
        for &m in &grp.nodes {
            if !in_graph(g, m) {
                continue;
            }
            for src in g.node(m).op.input_ids() {
                if !in_graph(g, src) {
                    continue; // diagnosed by check 1
                }
                if matches!(g.node(src).op, Op::Input { .. }) {
                    continue;
                }
                match owner[src.0 as usize] {
                    Some(gj) if gj <= gi => {}
                    Some(gj) => diags.push(
                        Diagnostic::new(
                            CheckClass::RaceFreedom,
                            format!(
                                "group {gi} reads n{} while later-launched group {gj} \
                                 writes it",
                                src.0
                            ),
                        )
                        .with_node(g, &plan.log, m),
                    ),
                    // Pure generator chains (Const/Iota, possibly viewed)
                    // are re-evaluated inside the kernel that reads them —
                    // immutable by construction, never materialized.
                    None if generator_only(g, src) => {}
                    None => diags.push(
                        Diagnostic::new(
                            CheckClass::RaceFreedom,
                            format!(
                                "group {gi} reads n{}, which no kernel group materializes",
                                src.0
                            ),
                        )
                        .with_node(g, &plan.log, m),
                    ),
                }
            }
        }
        cert.groups_checked += 1;
    }
    for &out in &g.outputs {
        if in_graph(g, out)
            && !matches!(g.node(out).op, Op::Input { .. })
            && owner[out.0 as usize].is_none()
        {
            diags.push(
                Diagnostic::new(
                    CheckClass::RaceFreedom,
                    format!("graph output n{} is not produced by any kernel group", out.0),
                )
                .with_node(g, &plan.log, out),
            );
        }
    }
    // (c) Intra-pipeline grid decomposition: re-derive the LogicalGrid
    // exactly as exec/tiled.rs::PipelineRun::new will, and prove the
    // per-block output regions are pairwise disjoint and exactly cover
    // the output. (K/V tile staging and the online-softmax row state
    // live in the block's own TilePool/WorkerScratch region by
    // construction — never shared — so disjoint output regions plus the
    // read-immutability proof above give race freedom for
    // exec/parallel.rs and exec/runtime.rs. The debug-build touch-log
    // cross-check in `merge` re-verifies this dynamically.)
    for grp in &plan.groups {
        let GroupKind::Pipeline(pipe) = &grp.kind else {
            continue;
        };
        if !in_graph(g, pipe.out) || !in_graph(g, pipe.score_root) || !in_graph(g, pipe.m2) {
            continue; // diagnosed by check 1
        }
        let out_shape = &g.node(pipe.out).shape;
        let out_axes = &an.axes[pipe.out.0 as usize];
        let rank = out_shape.len();
        let Some(q_ax_out) = out_axes.iter().position(|c| *c == pipe.q_class) else {
            diags.push(
                Diagnostic::new(
                    CheckClass::RaceFreedom,
                    "pipeline output does not carry the q dimension: the executor cannot \
                     cut disjoint q-tile regions",
                )
                .with_node(g, &plan.log, pipe.out),
            );
            continue;
        };
        if rank < 2 || q_ax_out == rank - 1 {
            diags.push(
                Diagnostic::new(
                    CheckClass::RaceFreedom,
                    format!(
                        "q axis {q_ax_out} coincides with the kernel's contiguous output \
                         axis (rank {rank}): the grid decomposition is degenerate"
                    ),
                )
                .with_node(g, &plan.log, pipe.out),
            );
            continue;
        }
        let score_axes = &an.axes[pipe.score_root.0 as usize];
        let Some(kv_ax_s) = score_axes.iter().rposition(|c| *c == pipe.kv_class) else {
            diags.push(
                Diagnostic::new(
                    CheckClass::RaceFreedom,
                    "score node does not carry the kv dimension",
                )
                .with_node(g, &plan.log, pipe.score_root),
            );
            continue;
        };
        if score_axes[..kv_ax_s]
            .iter()
            .rposition(|c| *c == pipe.q_class)
            .is_none()
        {
            diags.push(
                Diagnostic::new(
                    CheckClass::RaceFreedom,
                    "score node does not carry the q dimension left of kv",
                )
                .with_node(g, &plan.log, pipe.score_root),
            );
            continue;
        }
        if matches!(
            g.node(pipe.m2).op,
            Op::Matmul {
                transpose_rhs: true,
                ..
            }
        ) {
            diags.push(
                Diagnostic::new(
                    CheckClass::RaceFreedom,
                    "PV matmul with transposed V is unsupported by the tiled engine",
                )
                .with_node(g, &plan.log, pipe.m2),
            );
        }
        let sq = out_shape[q_ax_out];
        if sq == 0 {
            diags.push(
                Diagnostic::new(CheckClass::RaceFreedom, "empty q dimension")
                    .with_node(g, &plan.log, pipe.out),
            );
            continue;
        }
        let bq = tile.block_q.max(1).min(sq);
        let outer_axes: Vec<usize> = (0..rank)
            .filter(|&ax| ax != q_ax_out && ax != rank - 1)
            .collect();
        let mut dims: Vec<TiledDim> = outer_axes
            .iter()
            .map(|&ax| TiledDim {
                size: out_shape[ax],
                tile: 1,
            })
            .collect();
        dims.push(TiledDim { size: sq, tile: bq });
        let grid = LogicalGrid::new(dims);
        // q-tile ranges must partition [0, sq): contiguous, non-empty,
        // exactly covering.
        let q_dim = outer_axes.len();
        let mut covered = 0usize;
        let mut partitioned = true;
        for qt in 0..grid.dims[q_dim].n_tiles() {
            let (start, len) = grid.tile_range(q_dim, qt);
            if start != covered || len == 0 {
                partitioned = false;
                break;
            }
            covered += len;
        }
        if !partitioned || covered != sq {
            diags.push(
                Diagnostic::new(
                    CheckClass::RaceFreedom,
                    format!("q-tiles do not partition the q axis: covered {covered} of {sq} rows"),
                )
                .with_node(g, &plan.log, pipe.out),
            );
            continue;
        }
        // Each block's output region pins every outer axis to a single
        // coordinate and the q axis to that block's own q-tile range, so
        // two distinct blocks differ in a pinned axis => pairwise
        // disjoint. Region volumes summed against the output prove
        // exact coverage (no element written twice or never).
        let outer_elems: usize = outer_axes.iter().map(|&ax| out_shape[ax]).product();
        let region_total = outer_elems
            .saturating_mul(sq)
            .saturating_mul(out_shape[rank - 1]);
        if region_total != numel(out_shape) {
            diags.push(
                Diagnostic::new(
                    CheckClass::RaceFreedom,
                    format!(
                        "grid block regions cover {region_total} elements but the output \
                         has {}",
                        numel(out_shape)
                    ),
                )
                .with_node(g, &plan.log, pipe.out),
            );
            continue;
        }
        cert.blocks_proved_disjoint += grid.n_blocks();
        cert.pipelines_checked += 1;
    }
}

// ---------------------------------------------------------------------
// Check 3: float-determinism lint over the RewriteEvent trail
// ---------------------------------------------------------------------

fn check_determinism(
    g: &Graph,
    plan: &Plan,
    an: &DimAnalysis,
    cert: &mut Certificate,
    diags: &mut Vec<Diagnostic>,
) {
    let pipes: Vec<&Pipeline> = plan
        .groups
        .iter()
        .filter_map(|grp| match &grp.kind {
            GroupKind::Pipeline(p) => Some(p),
            _ => None,
        })
        .collect();
    for grp in &plan.groups {
        let GroupKind::Pipeline(pipe) = &grp.kind else {
            continue;
        };
        if let Some(roles) = &pipe.softmax {
            check_softmax_contract(g, an, plan, pipe, roles, diags);
        }
        // Inside a tiled pipeline the only reductions whose k-chain may
        // be re-blocked are the online-softmax max/sum (the executor
        // keeps each row's combine a single sequential chain over
        // k-tiles; within a tile the SIMD kernels use the fixed
        // striped-8 tree, identical across tiers). Any other fused
        // reduction — or a third matmul contraction — would be
        // reordered with no such contract.
        for &m in &grp.nodes {
            if !in_graph(g, m) {
                continue;
            }
            match &g.node(m).op {
                Op::Reduce { op, .. } => {
                    let blessed = pipe
                        .softmax
                        .as_ref()
                        .map_or(false, |r| r.max == m || r.sum == m);
                    if !blessed {
                        diags.push(
                            Diagnostic::new(
                                CheckClass::Determinism,
                                format!(
                                    "{op:?} reduction fused into a tiled pipeline outside \
                                     the online-softmax contract: tiling would reorder a \
                                     non-associative f32 reduction"
                                ),
                            )
                            .with_node(g, &plan.log, m),
                        );
                    }
                }
                Op::Matmul { .. } => {
                    if m != pipe.m1 && m != pipe.m2 {
                        diags.push(
                            Diagnostic::new(
                                CheckClass::Determinism,
                                "matmul inside a pipeline that is neither the QK nor the PV \
                                 matmul: its contraction chain would be re-blocked",
                            )
                            .with_node(g, &plan.log, m),
                        );
                    }
                }
                _ => {}
            }
        }
    }
    // Every reduction-reordering event in the trail must be located at
    // a pipeline role node that the checks above validated. (Prologue/
    // epilogue/pointwise fusion preserve element-wise evaluation order
    // and cannot reorder a reduction, so any location is fine — the
    // planner even logs prologue events on abandoned pipeline
    // attempts.)
    for e in &plan.log {
        cert.rewrite_events_checked += 1;
        let accounted = match e.rule {
            Rule::UnifiedReductionGemm => pipes.iter().any(|p| p.m1 == e.at),
            Rule::StructuralDemotion => pipes.iter().any(|p| {
                p.m2 == e.at || p.softmax.as_ref().map_or(false, |r| r.max == e.at)
            }),
            Rule::AlgebraicOnline => pipes
                .iter()
                .any(|p| p.softmax.as_ref().map_or(false, |r| r.sum == e.at)),
            Rule::TilingElimination => pipes.iter().any(|p| p.m2 == e.at),
            _ => true,
        };
        if !accounted {
            let d = Diagnostic::new(
                CheckClass::Determinism,
                format!(
                    "rewrite trail claims {:?} at n{} but no pipeline role accounts for \
                     that reordering",
                    e.rule, e.at.0
                ),
            );
            diags.push(if in_graph(g, e.at) {
                d.with_node(g, &plan.log, e.at)
            } else {
                d
            });
        }
    }
}

/// The blessed online-softmax contract (§3.3/3.4): max is a Max
/// reduction over the kv class, sum a Sum reduction of `exp` over the
/// same class, `exp = exp(score - broadcast(max))` (the homomorphism
/// that justifies blockwise rescaling) and `div = exp / broadcast(sum)`
/// (deferred normalization). Anything else is a reordering the
/// bit-exactness contract does not cover.
fn check_softmax_contract(
    g: &Graph,
    an: &DimAnalysis,
    plan: &Plan,
    pipe: &Pipeline,
    roles: &SoftmaxRoles,
    diags: &mut Vec<Diagnostic>,
) {
    for id in [roles.max, roles.exp, roles.sum, roles.div] {
        if !in_graph(g, id) {
            diags.push(Diagnostic::new(
                CheckClass::Determinism,
                format!("softmax role names nonexistent node n{}", id.0),
            ));
            return;
        }
    }
    let (x, am) = match g.node(roles.max).op {
        Op::Reduce {
            op: ReduceOp::Max,
            input,
            axis,
        } => (input, axis),
        _ => {
            diags.push(
                Diagnostic::new(
                    CheckClass::Determinism,
                    "softmax `max` role is not a Max reduction: the online rescale \
                     exp(m - m') is not an identity",
                )
                .with_node(g, &plan.log, roles.max),
            );
            return;
        }
    };
    let (sum_in, as_) = match g.node(roles.sum).op {
        Op::Reduce {
            op: ReduceOp::Sum,
            input,
            axis,
        } => (input, axis),
        _ => {
            diags.push(
                Diagnostic::new(
                    CheckClass::Determinism,
                    "softmax `sum` role is not a Sum reduction",
                )
                .with_node(g, &plan.log, roles.sum),
            );
            return;
        }
    };
    if sum_in != roles.exp {
        diags.push(
            Diagnostic::new(
                CheckClass::Determinism,
                format!(
                    "softmax `sum` must reduce the exp node (reduces n{} instead)",
                    sum_in.0
                ),
            )
            .with_node(g, &plan.log, roles.sum),
        );
        return;
    }
    let cm = an.axes[x.0 as usize].get(am).copied();
    let cs = an.axes[roles.exp.0 as usize].get(as_).copied();
    if cm != cs || cm != Some(pipe.kv_class) {
        diags.push(
            Diagnostic::new(
                CheckClass::Determinism,
                format!(
                    "max and sum must reduce the pipeline's kv dimension \
                     (classes {cm:?} vs {cs:?})"
                ),
            )
            .with_node(g, &plan.log, roles.sum),
        );
    }
    let exp_ok = match &g.node(roles.exp).op {
        Op::Pointwise {
            op: PwOp::Exp,
            inputs,
        } if inputs.len() == 1 && in_graph(g, inputs[0]) => match &g.node(inputs[0]).op {
            Op::Pointwise {
                op: PwOp::Sub,
                inputs: si,
            } if si.len() == 2 => si[0] == x && peel(g, si[1]) == roles.max,
            _ => false,
        },
        _ => false,
    };
    if !exp_ok {
        diags.push(
            Diagnostic::new(
                CheckClass::Determinism,
                "softmax `exp` role is not exp(score - max): blockwise max-rescaling \
                 would change the result",
            )
            .with_node(g, &plan.log, roles.exp),
        );
    }
    let div_ok = match &g.node(roles.div).op {
        Op::Pointwise {
            op: PwOp::Div,
            inputs,
        } if inputs.len() == 2 => inputs[0] == roles.exp && peel(g, inputs[1]) == roles.sum,
        _ => false,
    };
    if !div_ok {
        diags.push(
            Diagnostic::new(
                CheckClass::Determinism,
                "softmax `div` role is not exp / sum: deferred normalization would \
                 change the result",
            )
            .with_node(g, &plan.log, roles.div),
        );
    }
}

// ---------------------------------------------------------------------
// Check 4: mask-skip soundness
// ---------------------------------------------------------------------

fn check_masks(
    g: &Graph,
    plan: &Plan,
    an: &DimAnalysis,
    tile: TileConfig,
    provided: Option<&[Option<Arc<BlockMask>>]>,
    cert: &mut Certificate,
    diags: &mut Vec<Diagnostic>,
) {
    // One numeric fact underwrites every skip: the shared exp kernel
    // pins exp(NEG_INF - m') to exactly 0.0 for any live running max m'
    // (NEG_INF - m' is far below the kernel's underflow cutoff), and
    // exp(0) to exactly 1.0 (so the rescale alpha of an all-sentinel
    // prefix is the identity). Observe it on the actual kernel rather
    // than trusting the constants.
    cert.exp_cutoff_proved = simd::exp_f32(NEG_INF) == 0.0
        && simd::exp_f32(NEG_INF - 100.0) == 0.0
        && simd::exp_f32(NEG_INF + 1e25) == 0.0
        && simd::exp_f32(0.0) == 1.0;
    if !cert.exp_cutoff_proved {
        diags.push(Diagnostic::new(
            CheckClass::MaskSkip,
            "exp kernel does not pin the -1e30 mask sentinel to exactly 0.0 (or exp(0) \
             to 1.0): empty-tile skipping is not bit-identical",
        ));
    }
    for (gi, grp) in plan.groups.iter().enumerate() {
        let GroupKind::Pipeline(pipe) = &grp.kind else {
            continue;
        };
        let Some(info) = &pipe.mask else {
            continue;
        };
        if !in_graph(g, pipe.score_root) || !in_graph(g, info.cond) || !in_graph(g, info.value) {
            continue; // diagnosed by check 1
        }
        if pipe.softmax.is_none() {
            diags.push(
                Diagnostic::new(
                    CheckClass::MaskSkip,
                    "mask on a pipeline without online softmax: a skipped tile would \
                     silently drop sentinel contributions",
                )
                .with_node(g, &plan.log, pipe.score_root),
            );
            continue;
        }
        // Re-derive the fill independently: the score root must be
        // Where(cond, value, -1e30) for the skip algebra to apply.
        match &g.node(pipe.score_root).op {
            Op::Pointwise {
                op: PwOp::Where,
                inputs,
            } if inputs.len() == 3 => {
                if inputs[0] != info.cond || inputs[1] != info.value {
                    diags.push(
                        Diagnostic::new(
                            CheckClass::MaskSkip,
                            "MaskInfo cond/value do not match the score root's Where operands",
                        )
                        .with_node(g, &plan.log, pipe.score_root),
                    );
                }
                let fill = peel(g, inputs[2]);
                let fill_ok = in_graph(g, fill)
                    && matches!(g.node(fill).op, Op::Const { value } if value == NEG_INF);
                if !fill_ok {
                    diags.push(
                        Diagnostic::new(
                            CheckClass::MaskSkip,
                            format!(
                                "mask fill is not the {NEG_INF:e} sentinel: the \
                                 exp-pins-to-zero proof does not apply"
                            ),
                        )
                        .with_node(g, &plan.log, pipe.score_root),
                    );
                }
            }
            _ => {
                diags.push(
                    Diagnostic::new(
                        CheckClass::MaskSkip,
                        "masked pipeline's score root is not a Where(keep, score, fill)",
                    )
                    .with_node(g, &plan.log, pipe.score_root),
                );
                continue;
            }
        }
        match &info.kind {
            MaskKind::Threshold { .. } => {
                // Data-dependent: tiles are pruned at runtime from a
                // coarse score pass, so there is no static class table
                // to certify; the fill re-derivation above is the
                // static part of that contract.
            }
            MaskKind::Index { .. } => {
                let score_shape = &g.node(pipe.score_root).shape;
                let score_axes = &an.axes[pipe.score_root.0 as usize];
                let Some(kv_ax) = score_axes.iter().rposition(|c| *c == pipe.kv_class) else {
                    continue; // diagnosed by check 2
                };
                let Some(q_ax) = score_axes[..kv_ax]
                    .iter()
                    .rposition(|c| *c == pipe.q_class)
                else {
                    continue; // diagnosed by check 2
                };
                let cached = provided.and_then(|v| v.get(gi)).and_then(|o| o.as_deref());
                let owned;
                let bm: Option<&BlockMask> = match cached {
                    Some(bm) => Some(bm),
                    None if info.is_input_free() => {
                        owned = classify_block_mask(
                            g,
                            info,
                            score_shape,
                            q_ax,
                            kv_ax,
                            tile.block_q.min(score_shape[q_ax].max(1)),
                            tile.block_k.min(score_shape[kv_ax].max(1)),
                            &HashMap::new(),
                        );
                        owned.as_ref()
                    }
                    None => None, // input-dependent: classified per launch
                };
                if let Some(bm) = bm {
                    let found =
                        verify_block_mask(g, info, bm, score_shape, q_ax, kv_ax, &HashMap::new());
                    diags.extend(found);
                    cert.mask_cells_checked = cert.mask_cells_checked.saturating_add(
                        bm.n_deps().saturating_mul(bm.sq).saturating_mul(bm.sk),
                    );
                    cert.empty_tiles_proved += bm.skipped_tiles() as u64;
                }
            }
        }
    }
}

/// Independently re-derive a [`BlockMask`]'s skip legality from the
/// mask predicate itself — brute-force evaluation of every (dep, q, k)
/// cell — instead of trusting the classifier's construction. Checks:
/// geometry agrees with the score grid, the dependency axes match an
/// independent varies-walk, `Full` tiles are fully live (mask elision
/// is sound), `Empty` tiles are fully dead (the skip drops nothing),
/// and no `Empty` tile sits in a q-tile with a fully-dead row (the
/// dead-row demotion rule: such tiles must be `Partial` so the dense
/// path's garbage-cancellation arithmetic is reproduced exactly).
pub fn verify_block_mask(
    g: &Graph,
    info: &MaskInfo,
    bm: &BlockMask,
    score_shape: &[usize],
    q_ax: usize,
    kv_ax: usize,
    inputs: &HashMap<String, Tensor>,
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let path = if in_graph(g, info.cond) {
        node_path(g, info.cond)
    } else {
        String::new()
    };
    let mk = |msg: String| Diagnostic {
        check: CheckClass::MaskSkip,
        rule: None,
        node: Some(info.cond),
        path: path.clone(),
        message: msg,
    };
    let MaskKind::Index { input_deps } = &info.kind else {
        diags.push(mk(
            "only index masks carry static tile classes to verify".to_string(),
        ));
        return diags;
    };
    if !input_deps.iter().all(|n| inputs.contains_key(n)) {
        diags.push(mk(format!(
            "mask inputs {input_deps:?} not supplied: cannot re-derive tile classes"
        )));
        return diags;
    }
    let rank = score_shape.len();
    if rank > MAX_RANK || q_ax >= rank || kv_ax >= rank || q_ax == kv_ax {
        diags.push(mk(format!(
            "degenerate mask geometry: rank {rank}, q_ax {q_ax}, kv_ax {kv_ax}"
        )));
        return diags;
    }
    let (sq, sk) = (score_shape[q_ax], score_shape[kv_ax]);
    if bm.sq != sq || bm.sk != sk {
        diags.push(mk(format!(
            "BlockMask geometry {}x{} does not match the score grid {sq}x{sk}",
            bm.sq, bm.sk
        )));
        return diags;
    }
    let (bq, bk) = (bm.block_q, bm.block_k);
    if bq == 0 || bk == 0 || bm.n_q_tiles != sq.div_ceil(bq) || bm.n_k_tiles != sk.div_ceil(bk) {
        diags.push(mk(format!(
            "tile counts ({}, {}) disagree with block sizes ({bq}, {bk})",
            bm.n_q_tiles, bm.n_k_tiles
        )));
        return diags;
    }
    // Independent varies-walk: which score axes (besides q/kv) does the
    // predicate actually depend on?
    let mut varies = [false; MAX_RANK];
    predicate_varies_along(g, info.cond, &mut varies[..rank]);
    let mut dep_axes = Vec::new();
    let mut dep_sizes = Vec::new();
    for (ax, &sz) in score_shape.iter().enumerate() {
        if ax != q_ax && ax != kv_ax && varies[ax] && sz > 1 {
            dep_axes.push(ax);
            dep_sizes.push(sz);
        }
    }
    if dep_axes != bm.dep_axes {
        diags.push(mk(format!(
            "predicate varies along axes {:?} but the mask classified {:?}",
            dep_axes, bm.dep_axes
        )));
        return diags;
    }
    let n_dep = dep_sizes.iter().product::<usize>().max(1);
    if n_dep != bm.n_deps() {
        diags.push(mk(format!(
            "dep combination count {n_dep} disagrees with the mask's {}",
            bm.n_deps()
        )));
        return diags;
    }
    if n_dep.saturating_mul(sq).saturating_mul(sk) > VERIFY_CELL_CAP {
        // Too large to brute-force — the classifier refuses the same
        // budget, so a mask this big should not exist; skip quietly.
        return diags;
    }
    let (n_q, n_k) = (bm.n_q_tiles, bm.n_k_tiles);
    let mut kept = vec![0u32; n_q * n_k];
    let mut row_live = vec![false; sq];
    let mut coords = [0usize; MAX_RANK];
    for dep in 0..n_dep {
        // Mixed-radix decompose, most-significant axis first — the
        // classifier's own dep_index layout.
        let mut rem = dep;
        for i in (0..dep_axes.len()).rev() {
            coords[dep_axes[i]] = rem % dep_sizes[i];
            rem /= dep_sizes[i];
        }
        kept.fill(0);
        row_live.fill(false);
        for qi in 0..sq {
            coords[q_ax] = qi;
            for ki in 0..sk {
                coords[kv_ax] = ki;
                if eval_index_expr(g, info.cond, &coords[..rank], inputs) != 0.0 {
                    kept[(qi / bq) * n_k + ki / bk] += 1;
                    row_live[qi] = true;
                }
            }
        }
        for qt in 0..n_q {
            let cq = bq.min(sq - qt * bq);
            let has_dead_row = (qt * bq..qt * bq + cq).any(|q| !row_live[q]);
            for kt in 0..n_k {
                let ck = bk.min(sk - kt * bk);
                let n_kept = kept[qt * n_k + kt];
                match bm.class(dep, qt, kt) {
                    TileClass::Full if n_kept != (cq * ck) as u32 => diags.push(mk(format!(
                        "Full tile (dep {dep}, q-tile {qt}, k-tile {kt}) elides the mask \
                         but only {n_kept}/{} positions are live",
                        cq * ck
                    ))),
                    TileClass::Empty if n_kept != 0 => diags.push(mk(format!(
                        "Empty tile (dep {dep}, q-tile {qt}, k-tile {kt}) would be \
                         skipped but {n_kept} positions are live"
                    ))),
                    TileClass::Empty if has_dead_row => diags.push(mk(format!(
                        "undemoted dead-row Empty tile (dep {dep}, q-tile {qt}, k-tile \
                         {kt}): the q-tile contains a fully-dead row, whose dense \
                         sentinel arithmetic a skip cannot reproduce bit-identically"
                    ))),
                    _ => {}
                }
            }
        }
    }
    diags
}

/// Conservative data-flow walk: mark every score axis the predicate's
/// value can vary along (local re-implementation of the classifier's
/// private helper — check 4 must not trust the code it audits).
fn predicate_varies_along(g: &Graph, id: NodeId, axes: &mut [bool]) {
    if !in_graph(g, id) {
        return;
    }
    let node = g.node(id);
    match &node.op {
        Op::Const { .. } => {}
        Op::Iota { axis } => {
            if *axis < axes.len() {
                axes[*axis] = true;
            }
        }
        Op::Input { .. } => {
            for (ax, &sz) in node.shape.iter().enumerate() {
                if sz > 1 && ax < axes.len() {
                    axes[ax] = true;
                }
            }
        }
        Op::Broadcast { input } | Op::Slice { input, .. } => {
            predicate_varies_along(g, *input, axes)
        }
        Op::Pointwise { inputs, .. } => {
            for &i in inputs {
                predicate_varies_along(g, i, axes);
            }
        }
        Op::Matmul { .. } | Op::Reduce { .. } => {
            for a in axes.iter_mut() {
                *a = true;
            }
        }
    }
}
