//! Static plan verification: machine-checked proofs of fusion
//! legality, determinism, and race-freedom before any kernel runs.
//!
//! The verifier ([`verify`]) re-derives, independently of the planner,
//! everything the executor is about to trust: node shapes, the
//! `LogicalGrid` write-set decomposition, the online-softmax
//! determinism contract, and `BlockMask` skip legality. It runs at
//! every plan birth on the `PlanCache` miss path (always in debug
//! builds, behind `FLASHLIGHT_VERIFY` in release) so steady-state
//! serving does zero verify work, and exhaustively via the
//! `flashlight lint` CLI subcommand. See `analysis/README.md`.

pub mod diagnostics;
pub mod verify;

pub use diagnostics::{node_path, rule_at, Certificate, CheckClass, Diagnostic};
pub use verify::{verify_block_mask, verify_cached, verify_with};

use std::cell::Cell;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// How much verification runs at plan birth (`FLASHLIGHT_VERIFY`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VerifyMode {
    /// Skip verification entirely.
    Off,
    /// Verify and report diagnostics on stderr, but keep the plan.
    Warn,
    /// Verify and panic on any diagnostic.
    Strict,
}

/// Resolve `FLASHLIGHT_VERIFY`: `strict` panics on any diagnostic,
/// `0`/`off` disables, any other set value warns. Unset defaults to
/// `Warn` in debug builds (verification always runs under `cargo
/// test`) and `Off` in release (opt-in, since serving pays it on every
/// cache miss).
pub fn resolve_verify(env: Option<&str>) -> VerifyMode {
    match env.map(str::trim) {
        Some("strict") => VerifyMode::Strict,
        Some("0") | Some("off") => VerifyMode::Off,
        Some(_) => VerifyMode::Warn,
        None => {
            if cfg!(debug_assertions) {
                VerifyMode::Warn
            } else {
                VerifyMode::Off
            }
        }
    }
}

static MODE: OnceLock<VerifyMode> = OnceLock::new();

thread_local! {
    // 0 = follow env, otherwise a forced VerifyMode (tests).
    static MODE_OVERRIDE: Cell<u8> = const { Cell::new(0) };
}

/// Force a verify mode on this thread (tests), or `None` to follow the
/// environment again.
pub fn set_verify_override(mode: Option<VerifyMode>) {
    MODE_OVERRIDE.with(|c| {
        c.set(match mode {
            None => 0,
            Some(VerifyMode::Off) => 1,
            Some(VerifyMode::Warn) => 2,
            Some(VerifyMode::Strict) => 3,
        })
    });
}

/// The effective verify mode for this thread.
pub fn verify_mode() -> VerifyMode {
    match MODE_OVERRIDE.with(|c| c.get()) {
        1 => VerifyMode::Off,
        2 => VerifyMode::Warn,
        3 => VerifyMode::Strict,
        _ => *MODE.get_or_init(|| resolve_verify(std::env::var("FLASHLIGHT_VERIFY").ok().as_deref())),
    }
}

// Verification call counters, mirroring `sketch::analyze_call_count`:
// the global counter feeds bench reports; the thread-local one lets
// tests assert exact steady-state-zero-work without interference from
// sibling tests on other harness threads.
static VERIFY_CALLS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static VERIFY_CALLS_LOCAL: Cell<u64> = const { Cell::new(0) };
}

pub(crate) fn note_verify_call() {
    VERIFY_CALLS.fetch_add(1, Ordering::Relaxed);
    VERIFY_CALLS_LOCAL.with(|c| c.set(c.get() + 1));
}

/// Process-wide count of verification runs (any thread).
pub fn verify_call_count() -> u64 {
    VERIFY_CALLS.load(Ordering::Relaxed)
}

/// Verification runs performed by the calling thread — the counter to
/// assert against in tests (the plan cache builds on its caller's
/// thread, so steady-state decode must leave this flat).
pub fn verify_calls_on_this_thread() -> u64 {
    VERIFY_CALLS_LOCAL.with(|c| c.get())
}

/// Outcome of `flashlight lint`.
pub struct LintReport {
    /// Plans that verified clean.
    pub passed: usize,
    /// Plans with at least one diagnostic.
    pub failed: usize,
    /// Pretty-printed report (certificates and diagnostics).
    pub report: String,
}

fn record(
    label: &str,
    res: Result<Certificate, Vec<Diagnostic>>,
    out: &mut String,
    passed: &mut usize,
    failed: &mut usize,
) {
    match res {
        Ok(cert) => {
            *passed += 1;
            let _ = writeln!(out, "  OK   {label}: {cert}");
        }
        Err(diags) => {
            *failed += 1;
            let _ = writeln!(out, "  FAIL {label}: {} diagnostic(s)", diags.len());
            for d in &diags {
                for line in d.to_string().lines() {
                    let _ = writeln!(out, "         {line}");
                }
            }
        }
    }
}

/// Verify every built-in variant across the bucket ladder: paper
/// variants at prefill shapes via `Plan::verify`, serving variants
/// through a `PlanCache` (decode and chunked-prefill q shapes) via
/// [`verify_cached`] — the exact entry point the cache uses at plan
/// birth. Backs the `flashlight lint` CLI subcommand and the fifth
/// `bench_regress.sh` gate.
pub fn lint_builtin_variants() -> LintReport {
    use crate::fusion::{bucket_len, plan, FusionMode, PlanCache, PlanKey};
    use crate::variants::{build, build_serving, paper_variants, serving_variants, AttnShape};

    let mut out = String::new();
    let (mut passed, mut failed) = (0usize, 0usize);
    let _ = writeln!(
        out,
        "flashlight lint: static plan verification \
         (shape / race-freedom / determinism / mask-skip)"
    );
    for v in paper_variants() {
        for seq in [64usize, 128, 256] {
            let shape = AttnShape {
                batch: 1,
                rows: 1,
                heads_q: 4,
                heads_kv: 2,
                seq,
                head_dim: 64,
            };
            let g = build(v, &shape);
            let p = plan(&g, FusionMode::Flashlight);
            record(
                &format!("{:<12} paper seq={seq:<4}", v.name()),
                p.verify(&g),
                &mut out,
                &mut passed,
                &mut failed,
            );
        }
    }
    // The cache would verify on the miss path too (mode permitting);
    // force it off while building so strict mode reports here instead
    // of panicking mid-lint, then verify each entry explicitly.
    set_verify_override(Some(VerifyMode::Off));
    for v in serving_variants() {
        let mut cache = PlanCache::with_block_k(64, 64);
        for kv_len in [64usize, 128, 192, 256] {
            let kv_b = bucket_len(kv_len, 64);
            for q_len in [1usize, 64] {
                let shape = AttnShape {
                    batch: 1,
                    rows: 1,
                    heads_q: 4,
                    heads_kv: 2,
                    seq: kv_b,
                    head_dim: 64,
                };
                let key = PlanKey {
                    tag: "lint",
                    variant: v.name(),
                    heads_q: 4,
                    heads_kv: 2,
                    head_dim: 64,
                    q_len,
                    kv_len: kv_b,
                };
                let entry = cache.get_or_build(key, || build_serving(v, &shape, q_len));
                record(
                    &format!("{:<12} serve kv={kv_b:<4} q={q_len:<3}", v.name()),
                    verify_cached(&entry),
                    &mut out,
                    &mut passed,
                    &mut failed,
                );
            }
        }
    }
    set_verify_override(None);
    let _ = writeln!(out, "lint: {passed} plan(s) clean, {failed} failed");
    LintReport {
        passed,
        failed,
        report: out,
    }
}
